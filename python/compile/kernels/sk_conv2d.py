"""Layer-1 Pallas kernel: sketched matmul over im2col patches (SKConv2d).

SKConv2d [Kasiviswanathan et al. 2017] = im2col + the same fused two-stage
sketched product as SKLinear, with `d_in = C_in·k²` and `d_out = C_out`.
The patch extraction is pure data movement and is done at Layer 2 with
`jax.lax.conv_general_dilated_patches` (XLA fuses it); the FLOP-heavy
sketched GEMM is this kernel.

The kernel tiles the *rows* of the patches matrix (B·H_out·W_out rows — the
large axis for convolutions) so each grid step touches one (rows_tile ×
d_in) panel: grid = (row_tiles, l). VMEM per step = rows_tile·d_in +
d_in·k + k·d_out + rows_tile·d_out floats.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sk_conv_kernel(p_ref, u_ref, v_ref, b_ref, o_ref, *, num_terms):
    """Grid step (i, j): rows tile i × sketch term j.

    p_ref: (T, d_in) — patch rows tile
    u_ref: (d_in, k); v_ref: (k, d_out); b_ref: (d_out,)
    o_ref: (T, d_out) — output tile, accumulated over j
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(b_ref[...], o_ref.shape)

    # u_ref/v_ref blocks carry the leading size-1 term axis — index it off.
    pu = jnp.dot(p_ref[...], u_ref[0], preferred_element_type=jnp.float32)
    o_ref[...] += jnp.dot(pu, v_ref[0], preferred_element_type=jnp.float32) / num_terms


@functools.partial(jax.jit, static_argnames=("rows_tile", "interpret"))
def sk_conv2d_gemm(patches, u, v, b, rows_tile=None, interpret=True):
    """Sketched GEMM over patches.

    Args:
      patches: (R, d_in) im2col rows, R = B·H_out·W_out
      u: (l, d_in, k); v: (l, k, d_out); b: (d_out,)
    Returns:
      (R, d_out)
    """
    rows, d_in = patches.shape
    num_terms, _, k = u.shape
    d_out = v.shape[2]
    if rows_tile is None or rows_tile > rows:
        rows_tile = rows
    assert rows % rows_tile == 0, "rows must divide evenly into tiles"
    kernel = functools.partial(_sk_conv_kernel, num_terms=float(num_terms))
    return pl.pallas_call(
        kernel,
        grid=(rows // rows_tile, num_terms),
        in_specs=[
            pl.BlockSpec((rows_tile, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((1, d_in, k), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((1, k, d_out), lambda i, j: (j, 0, 0)),
            pl.BlockSpec((d_out,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((rows_tile, d_out), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d_out), patches.dtype),
        interpret=interpret,
    )(patches, u, v, b)


def extract_patches(x, kernel, padding):
    """im2col at Layer 2: x (B, C, H, W) → (B·H_out·W_out, C·k²).

    Column order matches the Rust `nn::conv::im2col` (channel-major, then
    ky, kx) so weights are interchangeable between the two paths.
    """
    b = x.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kernel, kernel),
        window_strides=(1, 1),
        padding=[(padding, padding)] * 2,
    )  # (B, C·k·k, H_out, W_out); feature dim is already channel-major
    c_kk = patches.shape[1]
    return patches.transpose(0, 2, 3, 1).reshape(b * patches.shape[2] * patches.shape[3], c_kk)


def sk_conv2d_vmem_floats(rows_tile, d_in, d_out, k):
    """Per-grid-step VMEM residency estimate (floats)."""
    return rows_tile * d_in + d_in * k + k * d_out + rows_tile * d_out


# --- differentiable wrapper (same VJP shape as sk_linear) -------------------


@jax.custom_vjp
def sk_conv2d_layer(patches, u, v, b):
    """Differentiable sketched conv GEMM: Pallas forward, analytic VJP."""
    return sk_conv2d_gemm(patches, u, v, b)


def _fwd(patches, u, v, b):
    return sk_conv2d_gemm(patches, u, v, b), (patches, u, v)


def _bwd(res, g):
    x, u, v = res
    inv_l = 1.0 / u.shape[0]
    gv = jnp.einsum("bo,lko->lbk", g, v)
    dx = jnp.einsum("lbk,lik->bi", gv, u) * inv_l
    du = jnp.einsum("bi,lbk->lik", x, gv) * inv_l
    xu = jnp.einsum("bi,lik->lbk", x, u)
    dv = jnp.einsum("lbk,bo->lko", xu, g) * inv_l
    db = jnp.sum(g, axis=0)
    return dx, du, dv, db


sk_conv2d_layer.defvjp(_fwd, _bwd)
