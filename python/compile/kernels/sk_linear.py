"""Layer-1 Pallas kernel: fused SKLinear forward.

The paper's pawX backend implements the sketched linear layer with CUDA
WMMA tiles staged through shared memory. The TPU rethink (DESIGN.md
§Hardware-Adaptation): both GEMMs of one term — ``(x·U_j)`` and
``(x·U_j)·V_j`` — are fused into a single kernel body so the rank-``k``
intermediate (``B × k``, tiny *by construction*: k is the sketch rank)
lives in VMEM and never round-trips to HBM. The grid iterates over the
``l`` sketch terms; each step streams one ``U_j``/``V_j`` panel from HBM
into VMEM (expressed by the BlockSpec index maps) and accumulates into the
output block, which stays resident across the whole grid.

MXU notes (compile-only on this CPU image — interpret=True at runtime):
the contraction shapes are (B×d_in)·(d_in×k) and (B×k)·(k×d_out); with
d_in, d_out multiples of 128 and B a multiple of 8 both map directly onto
the 128×128 systolic array. VMEM per step = B·d_in + d_in·k + k·d_out +
B·k + B·d_out floats — the aot pipeline asserts this fits the ~16 MiB VMEM
budget for every compiled configuration.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sk_linear_kernel(x_ref, u_ref, v_ref, b_ref, o_ref, *, num_terms):
    """One grid step: accumulate term j's contribution into o_ref.

    Block shapes (leading `l` axis indexed by the grid):
      x_ref: (B, d_in)  — full x, resident every step
      u_ref: (d_in, k)  — term j's left factor
      v_ref: (k, d_out) — term j's right factor
      b_ref: (d_out,)
      o_ref: (B, d_out) — accumulated output block
    """
    j = pl.program_id(0)

    # Initialize the accumulator on the first step with the bias.
    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.broadcast_to(b_ref[...], o_ref.shape)

    # Fused two-stage product; xu (B×k) stays in registers/VMEM scratch.
    # u_ref/v_ref blocks carry the leading size-1 term axis — index it off.
    xu = jnp.dot(x_ref[...], u_ref[0], preferred_element_type=jnp.float32)
    o_ref[...] += jnp.dot(xu, v_ref[0], preferred_element_type=jnp.float32) / num_terms


@functools.partial(jax.jit, static_argnames=("interpret",))
def sk_linear(x, u, v, b, interpret=True):
    """SKLinear forward via the fused Pallas kernel.

    Args:
      x: (B, d_in); u: (l, d_in, k); v: (l, k, d_out); b: (d_out,)
    Returns:
      (B, d_out)
    """
    num_terms, d_in, k = u.shape
    batch, _ = x.shape
    d_out = v.shape[2]
    kernel = functools.partial(_sk_linear_kernel, num_terms=float(num_terms))
    return pl.pallas_call(
        kernel,
        grid=(num_terms,),
        in_specs=[
            pl.BlockSpec((batch, d_in), lambda j: (0, 0)),
            pl.BlockSpec((1, d_in, k), lambda j: (j, 0, 0)),
            pl.BlockSpec((1, k, d_out), lambda j: (j, 0, 0)),
            pl.BlockSpec((d_out,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((batch, d_out), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, d_out), x.dtype),
        interpret=interpret,
    )(x, u, v, b)


def sk_linear_vmem_floats(batch, d_in, d_out, num_terms, k):
    """Per-grid-step VMEM residency estimate (floats) — see module docs."""
    del num_terms  # one term resident per step
    return batch * d_in + d_in * k + k * d_out + batch * k + batch * d_out


# --- differentiable wrapper -------------------------------------------------
#
# pallas_call is not natively differentiable; the Layer-2 training graphs
# need gradients through the sketched layer, so we pair the Pallas forward
# with a hand-derived VJP (the math is two GEMMs per term, mirroring the
# forward):
#
#   y = (1/l)·Σ_j (x·U_j)·V_j + b
#   dx   = (1/l)·Σ_j (g·V_jᵀ)·U_jᵀ
#   dU_j = (1/l)·xᵀ·(g·V_jᵀ)
#   dV_j = (1/l)·(x·U_j)ᵀ·g
#   db   = Σ_rows g


@jax.custom_vjp
def sk_linear_layer(x, u, v, b):
    """Differentiable SKLinear: Pallas forward, analytic VJP backward."""
    return sk_linear(x, u, v, b)


def _sk_linear_fwd(x, u, v, b):
    return sk_linear(x, u, v, b), (x, u, v)


def _sk_linear_bwd(res, g):
    x, u, v = res
    inv_l = 1.0 / u.shape[0]
    gv = jnp.einsum("bo,lko->lbk", g, v)  # g·V_jᵀ per term
    dx = jnp.einsum("lbk,lik->bi", gv, u) * inv_l
    du = jnp.einsum("bi,lbk->lik", x, gv) * inv_l
    xu = jnp.einsum("bi,lik->lbk", x, u)
    dv = jnp.einsum("lbk,bo->lko", xu, g) * inv_l
    db = jnp.sum(g, axis=0)
    return dx, du, dv, db


sk_linear_layer.defvjp(_sk_linear_fwd, _sk_linear_bwd)
