"""Layer-1 Pallas kernel: Performer FAVOR+ linear attention (single head).

The memory story of Figure 3 lives here: the kernel never materializes the
n×n score matrix. Grid = (heads,); per step the VMEM-resident state is the
two (n, m) feature blocks, the (m, d_h) KV accumulator, and the (m,)
normalizer — O(n·m), linear in sequence length.

On a real TPU the natural refinement is a second grid axis over sequence
blocks with the KV state in VMEM scratch (`pl.run_scoped`), which makes
peak VMEM O(block·m). We keep the per-head formulation because (a)
interpret-mode is the only executable path on this image and (b) the HLO
the CPU PJRT runs is identical math either way; the blocked variant's VMEM
arithmetic is recorded in DESIGN.md §Hardware-Adaptation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _performer_kernel(q_ref, k_ref, v_ref, w_ref, o_ref, *, kind):
    """One head: FAVOR+ features + linear attention.

    q_ref/k_ref/v_ref: (n, d_h); w_ref: (d_h, m); o_ref: (n, d_h).
    """
    # Blocks carry the leading size-1 head axis — index it off.
    q = q_ref[0]
    k = k_ref[0]
    v = v_ref[0]
    w = w_ref[0]
    m = w.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.float32(m))

    def features(x):
        proj = jnp.dot(x, w, preferred_element_type=jnp.float32)
        if kind == "softmax":
            sq = jnp.sum(x * x, axis=-1, keepdims=True) / 2.0
            # Scalar stabilizer — per-row would reweight keys and bias the
            # estimator (see ref.softmax_features).
            stab = jnp.max(proj)
            return jnp.exp(proj - sq - stab) * scale
        return jnp.maximum(proj, 0.0) * scale

    pq = features(q)  # (n, m)
    pk = features(k)  # (n, m)
    kv = jnp.dot(pk.T, v, preferred_element_type=jnp.float32)  # (m, d_h)
    z = jnp.sum(pk, axis=0)  # (m,)
    num = jnp.dot(pq, kv, preferred_element_type=jnp.float32)  # (n, d_h)
    den = jnp.maximum(jnp.dot(pq, z), 1e-9)  # (n,)
    o_ref[0] = num / den[:, None]


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def performer_attention(q, k, v, w, kind="softmax", interpret=True):
    """Multi-head FAVOR+ attention.

    Args:
      q, k, v: (h, n, d_h) — per-head projections, q/k pre-scaled by the
        caller (1/√d_h).
      w: (h, d_h, m) random feature projections.
    Returns:
      (h, n, d_h)
    """
    h, n, dh = q.shape
    m = w.shape[2]
    kernel = functools.partial(_performer_kernel, kind=kind)
    return pl.pallas_call(
        kernel,
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dh, m), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, n, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, n, dh), q.dtype),
        interpret=interpret,
    )(q, k, v, w)


def performer_vmem_floats(n, dh, m):
    """Per-head VMEM residency estimate (floats)."""
    return 3 * n * dh + dh * m + 2 * n * m + m * dh + m + n * dh
