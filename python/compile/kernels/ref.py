"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the pytest/hypothesis suite checks the kernels
against (`assert_allclose`), and they double as readable specifications of
the math:

- ``sk_linear_ref``   — SKLinear forward: mean of `l` rank-`k` two-stage
  products (Kasiviswanathan et al. 2017, the paper's [7]).
- ``sk_matmul_ref``   — the same two-stage product without the bias (used by
  SKConv2d on im2col patches).
- ``performer_ref``   — FAVOR+ linear attention (Choromanski et al. 2022,
  the paper's [3]) for softmax and ReLU feature maps.
- ``attention_ref``   — exact softmax attention (the dense baseline).
"""

import jax.numpy as jnp


def sk_linear_ref(x, u, v, b):
    """SKLinear forward.

    Args:
      x: (B, d_in)
      u: (l, d_in, k)   per-term left factors
      v: (l, k, d_out)  per-term right factors
      b: (d_out,)
    Returns:
      (B, d_out): ``(1/l) * sum_j (x @ u[j]) @ v[j] + b``
    """
    xu = jnp.einsum("bi,lik->lbk", x, u)
    y = jnp.einsum("lbk,lko->bo", xu, v) / u.shape[0]
    return y + b


def sk_matmul_ref(x, u, v):
    """Two-stage sketched matmul without bias: (1/l)·Σ_j (x·U_j)·V_j."""
    xu = jnp.einsum("bi,lik->lbk", x, u)
    return jnp.einsum("lbk,lko->bo", xu, v) / u.shape[0]


def softmax_features(x, w):
    """FAVOR+ positive random features for the softmax kernel.

    phi(x) = exp(x·w − ‖x‖²/2 − c) / sqrt(m) with a *scalar* stabilizer
    c = max(x·w) over the whole block. The stabilizer must be shared by all
    rows: a per-row stabilizer on the keys would reweight keys and bias the
    attention estimate (it cancels only for queries, where both numerator
    and denominator carry the same per-row factor).
    x: (n, d_h), w: (d_h, m) → (n, m)
    """
    m = w.shape[1]
    proj = x @ w
    sq = jnp.sum(x * x, axis=-1, keepdims=True) / 2.0
    stab = jnp.max(proj)
    return jnp.exp(proj - sq - stab) / jnp.sqrt(m)


def relu_features(x, w):
    """ReLU random features: max(x·w, 0)/sqrt(m)."""
    m = w.shape[1]
    return jnp.maximum(x @ w, 0.0) / jnp.sqrt(m)


def performer_ref(q, k, v, w, kernel="softmax"):
    """Single-head FAVOR+ linear attention.

    q, k: (n, d_h) pre-scaled by 1/sqrt(d_h) by the caller.
    v: (n, d_h); w: (d_h, m) random projection.
    Returns (n, d_h): phi(Q)·(phi(K)ᵀV) / (phi(Q)·phi(K)ᵀ1).
    """
    feat = softmax_features if kernel == "softmax" else relu_features
    pq = feat(q, w)  # n×m
    pk = feat(k, w)  # n×m
    kv = pk.T @ v  # m×d_h — the O(1)-in-n state
    z = jnp.sum(pk, axis=0)  # m
    num = pq @ kv  # n×d_h
    den = pq @ z  # n
    return num / jnp.maximum(den, 1e-9)[:, None]


def attention_ref(q, k, v):
    """Exact softmax attention for one head; q pre-scaled."""
    scores = q @ k.T
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return p @ v
