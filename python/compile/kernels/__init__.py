"""Layer-1 Pallas kernels and their pure-jnp reference oracles."""

from . import ref  # noqa: F401
from .performer import performer_attention, performer_vmem_floats  # noqa: F401
from .sk_conv2d import (  # noqa: F401
    extract_patches,
    sk_conv2d_gemm,
    sk_conv2d_layer,
    sk_conv2d_vmem_floats,
)
from .sk_linear import sk_linear, sk_linear_layer, sk_linear_vmem_floats  # noqa: F401
