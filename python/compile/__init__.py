"""Panther-RS build-time Python: Pallas kernels (L1), JAX models (L2), and
the AOT lowering pipeline. Never imported at runtime — `make artifacts`
runs once and the Rust binary is self-contained afterwards."""
