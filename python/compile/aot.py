"""AOT pipeline: lower every Layer-2 entry point to HLO **text** and write
`artifacts/manifest.json` describing each artifact's inputs/outputs so the
Rust runtime binds buffers by name.

HLO text — not `lowered.compile()` / serialized protos — is the interchange
format: jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Run via `make artifacts`:  python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.performer import performer_attention, performer_vmem_floats
from .kernels.sk_linear import sk_linear, sk_linear_vmem_floats

# TPU VMEM budget the BlockSpecs must respect (bytes); see DESIGN.md
# §Hardware-Adaptation. Checked for every kernel configuration we compile.
VMEM_BUDGET = 16 * 1024 * 1024


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _flat_names(prefix_tree):
    """Flatten a pytree of name-strings in the same order JAX flattens the
    corresponding value tree (dicts sort by key)."""
    leaves, _ = jax.tree_util.tree_flatten(prefix_tree)
    return leaves


def _name_tree_like(values, prefix):
    """Build a pytree of dotted names shaped like `values`."""
    if isinstance(values, dict):
        return {k: _name_tree_like(v, f"{prefix}.{k}") for k, v in values.items()}
    return prefix


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.manifest = {"artifacts": {}, "models": {}, "kernels": {}}

    def lower(self, name, fn, example_args, arg_name_trees):
        """Lower `fn(*example_args)` and record the artifact.

        arg_name_trees: pytree of names, same structure as example_args.
        """
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, path), "w") as f:
            f.write(text)
        in_names = _flat_names(arg_name_trees)
        in_shapes = [list(x.shape) for x in jax.tree_util.tree_leaves(example_args)]
        out = jax.eval_shape(fn, *example_args)
        out_leaves = jax.tree_util.tree_leaves(out)
        out_shapes = [list(x.shape) for x in out_leaves]
        self.manifest["artifacts"][name] = {
            "path": path,
            "inputs": [
                {"name": n, "shape": s} for n, s in zip(in_names, in_shapes)
            ],
            "outputs": [{"shape": s} for s in out_shapes],
        }
        print(f"  lowered {name}: {len(in_names)} inputs, {len(out_shapes)} outputs, "
              f"{len(text) / 1e6:.2f} MB hlo")
        return out

    def save(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1, sort_keys=True)


def build_kernel_artifacts(b: Builder):
    """Small kernel-level artifacts: runtime smoke tests + micro-benches."""
    # SKLinear kernel: B=32, d=256, l=2, k=32.
    batch, d, l, k = 32, 256, 2, 32
    args = (_spec((batch, d)), _spec((l, d, k)), _spec((l, k, d)), _spec((d,)))
    names = ("x", "u", "v", "b")
    b.lower("k_sk_linear", lambda x, u, v, bb: sk_linear(x, u, v, bb), args, names)
    vmem = sk_linear_vmem_floats(batch, d, d, l, k) * 4
    assert vmem < VMEM_BUDGET, f"k_sk_linear VMEM {vmem} over budget"
    b.manifest["kernels"]["k_sk_linear"] = {
        "vmem_bytes_per_step": vmem,
        "grid": [l],
        "config": {"batch": batch, "d_in": d, "d_out": d, "l": l, "k": k},
    }

    # Performer kernel: h=4, n=128, dh=32, m=64.
    h, n, dh, m = 4, 128, 32, 64
    args = (_spec((h, n, dh)), _spec((h, n, dh)), _spec((h, n, dh)), _spec((h, dh, m)))
    names = ("q", "k", "v", "w")
    b.lower(
        "k_performer",
        lambda q, kk, v, w: performer_attention(q, kk, v, w, kind="softmax"),
        args,
        names,
    )
    vmem = performer_vmem_floats(n, dh, m) * 4
    assert vmem < VMEM_BUDGET, f"k_performer VMEM {vmem} over budget"
    b.manifest["kernels"]["k_performer"] = {
        "vmem_bytes_per_step": vmem,
        "grid": [h],
        "config": {"heads": h, "n": n, "dh": dh, "m": m},
    }


def build_bert_artifacts(b: Builder, cfg: M.BertConfig, lr: float, with_train: bool):
    """init (+train) + eval artifacts for one BERT variant."""
    name = cfg.name()
    params = M.bert_init_params(jax.random.PRNGKey(0), cfg)
    pspecs = {k: _spec(v.shape) for k, v in params.items()}
    pnames = _name_tree_like(pspecs, "params")
    mnames = _name_tree_like(pspecs, "m")
    vnames = _name_tree_like(pspecs, "v")
    tok = _spec((cfg.batch, cfg.seq))

    b.lower(f"{name}_init", M.bert_init_fn(cfg), (_spec(()),), ("seed",))
    if with_train:
        b.lower(
            f"{name}_train",
            M.bert_train_step(cfg, lr),
            (pspecs, pspecs, pspecs, _spec(()), tok, tok, tok),
            (pnames, mnames, vnames, "step", "tokens", "labels", "mask"),
        )
    b.lower(
        f"{name}_eval",
        M.bert_eval_step(cfg),
        (pspecs, tok, tok, tok),
        (pnames, "tokens", "labels", "mask"),
    )
    if with_train:
        # Serving path: per-row scoring for the dynamic batcher.
        b.lower(
            f"{name}_eval_rows",
            M.bert_eval_rows(cfg),
            (pspecs, tok, tok, tok),
            (pnames, "tokens", "labels", "mask"),
        )
    b.manifest["models"][name] = {
        "family": "bert",
        "init": f"{name}_init",
        "train": f"{name}_train" if with_train else None,
        "eval": f"{name}_eval",
        "eval_rows": f"{name}_eval_rows" if with_train else None,
        "param_names": sorted(params.keys()),
        "param_count": int(sum(v.size for v in params.values())),
        "config": {
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "batch": cfg.batch,
            "sketch": list(cfg.sketch) if cfg.sketch else None,
            "lr": lr,
        },
    }


def build_conv_artifacts(b: Builder, cfg: M.ConvConfig, lr: float):
    name = cfg.name()
    params = M.conv_init_params(jax.random.PRNGKey(0), cfg)
    pspecs = {k: _spec(v.shape) for k, v in params.items()}
    pnames = _name_tree_like(pspecs, "params")
    mnames = _name_tree_like(pspecs, "m")
    vnames = _name_tree_like(pspecs, "v")
    img = _spec((cfg.batch, cfg.channels * cfg.image * cfg.image))
    lab = _spec((cfg.batch,))

    b.lower(f"{name}_init", M.conv_init_fn(cfg), (_spec(()),), ("seed",))
    b.lower(
        f"{name}_train",
        M.conv_train_step(cfg, lr),
        (pspecs, pspecs, pspecs, _spec(()), img, lab),
        (pnames, mnames, vnames, "step", "images", "labels"),
    )
    b.lower(f"{name}_predict", M.conv_predict_fn(cfg), (pspecs, img), (pnames, "images"))
    b.manifest["models"][name] = {
        "family": "conv",
        "init": f"{name}_init",
        "train": f"{name}_train",
        "eval": None,
        "predict": f"{name}_predict",
        "param_names": sorted(params.keys()),
        "param_count": int(sum(v.size for v in params.values())),
        "config": {
            "image": cfg.image,
            "channels": cfg.channels,
            "c1": cfg.c1,
            "c2": cfg.c2,
            "kernel": cfg.kernel,
            "classes": cfg.classes,
            "batch": cfg.batch,
            "sketch": list(cfg.sketch) if cfg.sketch else None,
            "lr": lr,
        },
    }


# Tuner candidate grid: eval-only artifacts. The SKAutoTuner (Rust) sketches
# the *trained dense* weights host-side (`SKLinear::from_dense`) and scores
# each candidate through its eval artifact — so candidates don't need train
# graphs. (1, 8) is the headline ~75%-reduction configuration and also gets
# a train graph for the train-from-scratch comparison.
BERT_CANDIDATES = [(1, 4), (1, 8), (1, 16), (1, 32), (2, 8), (2, 16)]
BERT_TRAIN_SKETCH = (1, 8)
CONV_SKETCH = (1, 8)
# 3e-3: the BERT-mini learns the corpus' Markov structure within a few
# hundred steps (at 1e-3 it stalls near the unigram entropy for the length
# of run the examples use).
LR_BERT = 3e-3
LR_CONV = 1e-3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-models", action="store_true",
                    help="only kernel artifacts (fast smoke builds)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    b = Builder(args.out)

    print("[aot] kernel artifacts")
    build_kernel_artifacts(b)

    if not args.skip_models:
        print("[aot] bert dense")
        build_bert_artifacts(b, M.BertConfig(sketch=None), LR_BERT, with_train=True)
        print(f"[aot] bert sketched (train) {BERT_TRAIN_SKETCH}")
        build_bert_artifacts(
            b, M.BertConfig(sketch=BERT_TRAIN_SKETCH), LR_BERT, with_train=True
        )
        for cand in BERT_CANDIDATES:
            if cand == BERT_TRAIN_SKETCH:
                continue  # already built with train
            print(f"[aot] bert candidate {cand}")
            build_bert_artifacts(b, M.BertConfig(sketch=cand), LR_BERT, with_train=False)
        print("[aot] conv dense / sketched")
        build_conv_artifacts(b, M.ConvConfig(sketch=None), LR_CONV)
        build_conv_artifacts(b, M.ConvConfig(sketch=CONV_SKETCH), LR_CONV)

    b.save()
    print(f"[aot] wrote manifest with {len(b.manifest['artifacts'])} artifacts to {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
