"""Layer-2: JAX model graphs (build-time only; never on the request path).

Two model families, each in a dense and a sketched variant:

- **BERT-mini MLM** — a small BERT-style encoder trained with masked
  language modeling, reproducing the paper's §4.2 quality experiment
  ("up to 75% reduction in size while maintaining a comparable MLM loss").
  The sketched variant replaces every Linear map in the encoder blocks
  (QKV, attention output, both FFN matrices) with the fused Pallas
  SKLinear kernel — the same layer set the paper's `SKAutoTuner` targets
  with `layer_names={"type": "Linear"}`.

- **Conv classifier** — a small CNN for the ResNet/CIFAR-10 case study
  (§4.2: "controlled size reduction of 30%, accuracy 89% → 86%"). The
  sketched variant replaces the dominant conv layer with the Pallas
  SKConv2d kernel.

Everything is parameterized by a config dataclass; `aot.py` lowers
`init / train_step / eval_step(/predict)` for each variant to HLO text.
Parameters travel as a flat dict keyed by dotted names; JAX flattens dicts
in sorted-key order, which `aot.py` records in the manifest so the Rust
runtime binds buffers by name, not position guessing.

Integer model inputs (tokens, labels) are passed as f32 and cast inside the
graph — this keeps the Rust↔HLO boundary single-dtype (f32), which the
`xla` crate handles most robustly.
"""

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels.sk_conv2d import extract_patches, sk_conv2d_layer
from .kernels.sk_linear import sk_linear_layer

# ---------------------------------------------------------------------------
# BERT-mini MLM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BertConfig:
    """BERT-mini configuration (defaults sized for CPU training)."""

    vocab: int = 256
    seq: int = 64
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 512
    batch: int = 16
    # Sketch config: None = dense; (num_terms, low_rank) sketches every
    # Linear in the encoder blocks.
    sketch: Optional[Tuple[int, int]] = None

    @property
    def head_dim(self):
        return self.d_model // self.n_heads

    def name(self):
        if self.sketch is None:
            return "bert_dense"
        l, k = self.sketch
        return f"bert_sk_{l}_{k}"

    def param_count(self, params=None):
        """Total parameter count (from shapes)."""
        p = params if params is not None else bert_init_params(jax.random.PRNGKey(0), self)
        return sum(int(v.size) for v in p.values())


def _linear_params(key, name, d_in, d_out, sketch):
    """Parameter dict entries for one (possibly sketched) linear map."""
    if sketch is None:
        w = jax.random.normal(key, (d_in, d_out), jnp.float32) * (2.0 / d_in) ** 0.5
        return {f"{name}.w": w, f"{name}.b": jnp.zeros((d_out,), jnp.float32)}
    l, k = sketch
    ku, kv = jax.random.split(key)
    u = jax.random.normal(ku, (l, d_in, k), jnp.float32) * (1.0 / k) ** 0.5
    v = jax.random.normal(kv, (l, k, d_out), jnp.float32) * (2.0 / d_in) ** 0.5
    return {
        f"{name}.u": u,
        f"{name}.v": v,
        f"{name}.b": jnp.zeros((d_out,), jnp.float32),
    }


def _apply_linear(params, name, x):
    """Apply a linear map by name — dense or sketched, decided by the keys."""
    if f"{name}.w" in params:
        return x @ params[f"{name}.w"] + params[f"{name}.b"]
    return sk_linear_layer(x, params[f"{name}.u"], params[f"{name}.v"], params[f"{name}.b"])


def bert_init_params(key, cfg: BertConfig):
    """Initialize the parameter dict (sorted keys = manifest order)."""
    params = {}
    keys = jax.random.split(key, 3 + 4 * cfg.n_layers)
    params["tok_emb"] = jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02
    params["pos_emb"] = jax.random.normal(keys[1], (cfg.seq, cfg.d_model)) * 0.02
    for i in range(cfg.n_layers):
        kq, ko, k1, k2 = jax.random.split(keys[2 + i], 4)
        blk = f"block{i}"
        params.update(_linear_params(kq, f"{blk}.qkv", cfg.d_model, 3 * cfg.d_model, cfg.sketch))
        params.update(_linear_params(ko, f"{blk}.attn_out", cfg.d_model, cfg.d_model, cfg.sketch))
        params.update(_linear_params(k1, f"{blk}.ff1", cfg.d_model, cfg.d_ff, cfg.sketch))
        params.update(_linear_params(k2, f"{blk}.ff2", cfg.d_ff, cfg.d_model, cfg.sketch))
        for ln in ("ln1", "ln2"):
            params[f"{blk}.{ln}.scale"] = jnp.ones((cfg.d_model,))
            params[f"{blk}.{ln}.bias"] = jnp.zeros((cfg.d_model,))
    params["final_ln.scale"] = jnp.ones((cfg.d_model,))
    params["final_ln.bias"] = jnp.zeros((cfg.d_model,))
    params["head.w"] = jax.random.normal(keys[-1], (cfg.d_model, cfg.vocab)) * 0.02
    params["head.b"] = jnp.zeros((cfg.vocab,))
    return {k: v.astype(jnp.float32) for k, v in params.items()}


def _layer_norm(x, scale, bias, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias


def _attention(cfg: BertConfig, params, blk, x):
    """Exact softmax self-attention; the Q/K/V/out projections are the
    (possibly sketched) Linear layers."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim
    qkv = _apply_linear(params, f"{blk}.qkv", x.reshape(b * s, d)).reshape(b, s, 3, h, dh)
    q = qkv[:, :, 0].transpose(0, 2, 1, 3)  # b,h,s,dh
    k = qkv[:, :, 1].transpose(0, 2, 1, 3)
    v = qkv[:, :, 2].transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) / jnp.sqrt(jnp.float32(dh))
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhst,bhtd->bhsd", p, v).transpose(0, 2, 1, 3).reshape(b * s, d)
    return _apply_linear(params, f"{blk}.attn_out", o).reshape(b, s, d)


def bert_forward(cfg: BertConfig, params, tokens_f32):
    """Logits for a token batch. tokens_f32: (B, S) float-encoded ids."""
    tokens = tokens_f32.astype(jnp.int32)
    x = jnp.take(params["tok_emb"], tokens, axis=0) + params["pos_emb"][None, :, :]
    b, s, d = x.shape
    for i in range(cfg.n_layers):
        blk = f"block{i}"
        a = _attention(cfg, params, blk, _layer_norm(
            x, params[f"{blk}.ln1.scale"], params[f"{blk}.ln1.bias"]))
        x = x + a
        hpre = _layer_norm(x, params[f"{blk}.ln2.scale"], params[f"{blk}.ln2.bias"])
        hidden = _apply_linear(params, f"{blk}.ff1", hpre.reshape(b * s, d))
        hidden = jax.nn.gelu(hidden)
        out = _apply_linear(params, f"{blk}.ff2", hidden).reshape(b, s, d)
        x = x + out
    x = _layer_norm(x, params["final_ln.scale"], params["final_ln.bias"])
    return x.reshape(b * s, d) @ params["head.w"] + params["head.b"]


def bert_mlm_loss(cfg: BertConfig, params, tokens, labels, mask):
    """Masked-LM cross entropy averaged over masked positions.

    tokens/labels: (B, S) f32-encoded ids; mask: (B, S) f32 ∈ {0,1} marking
    the positions that were masked out (loss is computed there only).
    """
    logits = bert_forward(cfg, params, tokens)  # (B·S, V)
    labels_i = labels.astype(jnp.int32).reshape(-1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_i[:, None], axis=1)[:, 0]
    m = mask.reshape(-1)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


# ---------------------------------------------------------------------------
# Adam (shared by both model families)
# ---------------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def adam_update(params, grads, m, v, step, lr):
    """One Adam step over parameter dicts. `step` is the 1-based f32 step."""
    b1t = ADAM_B1 ** step
    b2t = ADAM_B2 ** step
    new_p, new_m, new_v = {}, {}, {}
    for key in params:
        g = grads[key]
        mk = ADAM_B1 * m[key] + (1 - ADAM_B1) * g
        vk = ADAM_B2 * v[key] + (1 - ADAM_B2) * g * g
        mhat = mk / (1 - b1t)
        vhat = vk / (1 - b2t)
        new_p[key] = params[key] - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
        new_m[key] = mk
        new_v[key] = vk
    return new_p, new_m, new_v


def bert_train_step(cfg: BertConfig, lr: float):
    """Build the jittable train step: (params, m, v, step, tokens, labels,
    mask) → (params', m', v', loss)."""

    def step_fn(params, m, v, step, tokens, labels, mask):
        loss, grads = jax.value_and_grad(
            lambda p: bert_mlm_loss(cfg, p, tokens, labels, mask)
        )(params)
        new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr)
        return new_p, new_m, new_v, loss

    return step_fn


def bert_eval_step(cfg: BertConfig):
    """(params, tokens, labels, mask) → loss."""

    def eval_fn(params, tokens, labels, mask):
        return bert_mlm_loss(cfg, params, tokens, labels, mask)

    return eval_fn


def bert_eval_rows(cfg: BertConfig):
    """(params, tokens, labels, mask) → per-sequence losses (B,).

    The serving path: the Rust dynamic batcher merges single-sequence
    scoring requests into one fixed-batch execution and needs per-row
    results to route back to callers (padded rows carry zero mask and
    return 0).
    """

    def eval_fn(params, tokens, labels, mask):
        logits = bert_forward(cfg, params, tokens)  # (B·S, V)
        b, s = tokens.shape
        labels_i = labels.astype(jnp.int32).reshape(-1)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels_i[:, None], axis=1)[:, 0].reshape(b, s)
        m = mask
        return jnp.sum(nll * m, axis=1) / jnp.maximum(jnp.sum(m, axis=1), 1.0)

    return eval_fn


def bert_init_fn(cfg: BertConfig):
    """(seed,) → (params, m, v): init params and zeroed Adam state."""

    def init(seed):
        key = jax.random.PRNGKey(seed.astype(jnp.int32))
        params = bert_init_params(key, cfg)
        zeros = {k: jnp.zeros_like(p) for k, p in params.items()}
        return params, zeros, {k: jnp.zeros_like(p) for k, p in params.items()}

    _ = init  # (split zeros dicts so they are distinct pytrees)

    def init_fixed(seed):
        key = jax.random.PRNGKey(seed.astype(jnp.int32))
        params = bert_init_params(key, cfg)
        m = {k: jnp.zeros_like(p) for k, p in params.items()}
        v = {k: jnp.zeros_like(p) for k, p in params.items()}
        return params, m, v

    return init_fixed


# ---------------------------------------------------------------------------
# Conv classifier (ResNet/CIFAR case-study stand-in)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ConvConfig:
    """Small CNN: conv1 → relu → pool → conv2 → relu → pool → fc."""

    image: int = 16
    channels: int = 3
    c1: int = 16
    c2: int = 32
    kernel: int = 3
    classes: int = 10
    batch: int = 32
    # Sketch for conv2 only (the dominant conv): None = dense.
    sketch: Optional[Tuple[int, int]] = None

    def name(self):
        if self.sketch is None:
            return "conv_dense"
        l, k = self.sketch
        return f"conv_sk_{l}_{k}"

    @property
    def fc_in(self):
        return self.c2 * (self.image // 4) * (self.image // 4)


def conv_init_params(key, cfg: ConvConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d1 = cfg.channels * cfg.kernel * cfg.kernel
    d2 = cfg.c1 * cfg.kernel * cfg.kernel
    params = {
        "conv1.w": jax.random.normal(k1, (d1, cfg.c1)) * (2.0 / d1) ** 0.5,
        "conv1.b": jnp.zeros((cfg.c1,)),
        "fc.w": jax.random.normal(k3, (cfg.fc_in, cfg.classes)) * (2.0 / cfg.fc_in) ** 0.5,
        "fc.b": jnp.zeros((cfg.classes,)),
    }
    if cfg.sketch is None:
        params["conv2.w"] = jax.random.normal(k2, (d2, cfg.c2)) * (2.0 / d2) ** 0.5
        params["conv2.b"] = jnp.zeros((cfg.c2,))
    else:
        l, k = cfg.sketch
        ku, kv = jax.random.split(k2)
        params["conv2.u"] = jax.random.normal(ku, (l, d2, k)) * (1.0 / k) ** 0.5
        params["conv2.v"] = jax.random.normal(kv, (l, k, cfg.c2)) * (2.0 / d2) ** 0.5
        params["conv2.b"] = jnp.zeros((cfg.c2,))
    return {k_: v.astype(jnp.float32) for k_, v in params.items()}


def _pool2(x):
    """2×2 max pool on (B, C, H, W)."""
    b, c, h, w = x.shape
    return jnp.max(x.reshape(b, c, h // 2, 2, w // 2, 2), axis=(3, 5))


def conv_forward(cfg: ConvConfig, params, images):
    """Logits. images: (B, C·H·W) flattened f32 (matches the Rust layout)."""
    b = images.shape[0]
    x = images.reshape(b, cfg.channels, cfg.image, cfg.image)
    # conv1 (dense GEMM over patches).
    p1 = extract_patches(x, cfg.kernel, cfg.kernel // 2)
    y1 = p1 @ params["conv1.w"] + params["conv1.b"]
    h1 = cfg.image
    x = jax.nn.relu(y1.reshape(b, h1, h1, cfg.c1).transpose(0, 3, 1, 2))
    x = _pool2(x)  # (B, c1, H/2, W/2)
    # conv2 — dense or the Pallas sketched GEMM.
    p2 = extract_patches(x, cfg.kernel, cfg.kernel // 2)
    if "conv2.w" in params:
        y2 = p2 @ params["conv2.w"] + params["conv2.b"]
    else:
        y2 = sk_conv2d_layer(p2, params["conv2.u"], params["conv2.v"], params["conv2.b"])
    h2 = cfg.image // 2
    x = jax.nn.relu(y2.reshape(b, h2, h2, cfg.c2).transpose(0, 3, 1, 2))
    x = _pool2(x)  # (B, c2, H/4, W/4)
    return x.reshape(b, cfg.fc_in) @ params["fc.w"] + params["fc.b"]


def conv_loss(cfg: ConvConfig, params, images, labels):
    logits = conv_forward(cfg, params, images)
    labels_i = labels.astype(jnp.int32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels_i[:, None], axis=1))


def conv_train_step(cfg: ConvConfig, lr: float):
    def step_fn(params, m, v, step, images, labels):
        loss, grads = jax.value_and_grad(lambda p: conv_loss(cfg, p, images, labels))(params)
        new_p, new_m, new_v = adam_update(params, grads, m, v, step, lr)
        return new_p, new_m, new_v, loss

    return step_fn


def conv_predict_fn(cfg: ConvConfig):
    def predict(params, images):
        return conv_forward(cfg, params, images)

    return predict


def conv_init_fn(cfg: ConvConfig):
    def init(seed):
        key = jax.random.PRNGKey(seed.astype(jnp.int32))
        params = conv_init_params(key, cfg)
        m = {k: jnp.zeros_like(p) for k, p in params.items()}
        v = {k: jnp.zeros_like(p) for k, p in params.items()}
        return params, m, v

    return init
