"""Layer-2 model graph tests: shapes, losses, gradients, sketch accounting."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

TINY = M.BertConfig(vocab=32, seq=8, d_model=16, n_layers=1, n_heads=2, d_ff=32, batch=2)
TINY_SK = M.BertConfig(
    vocab=32, seq=8, d_model=16, n_layers=1, n_heads=2, d_ff=32, batch=2, sketch=(1, 2)
)


def params_for(cfg):
    return M.bert_init_params(jax.random.PRNGKey(0), cfg)


def batch_for(cfg, key=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(key))
    tokens = jax.random.randint(k1, (cfg.batch, cfg.seq), 2, cfg.vocab).astype(jnp.float32)
    labels = jax.random.randint(k2, (cfg.batch, cfg.seq), 2, cfg.vocab).astype(jnp.float32)
    mask = jnp.ones((cfg.batch, cfg.seq), jnp.float32)
    return tokens, labels, mask


def test_bert_forward_shapes():
    p = params_for(TINY)
    tokens, _, _ = batch_for(TINY)
    logits = M.bert_forward(TINY, p, tokens)
    assert logits.shape == (TINY.batch * TINY.seq, TINY.vocab)


def test_bert_initial_loss_near_uniform():
    # At init the MLM loss should sit near ln(vocab).
    p = params_for(TINY)
    tokens, labels, mask = batch_for(TINY)
    loss = float(M.bert_mlm_loss(TINY, p, tokens, labels, mask))
    assert abs(loss - np.log(TINY.vocab)) < 1.0, loss


def test_bert_sketched_param_reduction():
    dense = sum(v.size for v in params_for(TINY).values())
    sk = sum(v.size for v in params_for(TINY_SK).values())
    assert sk < dense


def test_bert_headline_config_hits_75pct_reduction():
    dense = M.BertConfig(sketch=None)
    sk = M.BertConfig(sketch=M.BERT_TRAIN_SKETCH) if hasattr(M, "BERT_TRAIN_SKETCH") else M.BertConfig(sketch=(1, 8))
    nd = sum(v.size for v in params_for(dense).values())
    ns = sum(v.size for v in params_for(sk).values())
    reduction = 1 - ns / nd
    assert reduction > 0.70, f"reduction {reduction:.3f} below the paper's ~75%"


@pytest.mark.parametrize("cfg", [TINY, TINY_SK], ids=["dense", "sketched"])
def test_bert_train_step_reduces_loss_on_fixed_batch(cfg):
    # Repeatedly stepping on ONE batch must drive its loss down (overfit).
    p = params_for(cfg)
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in p.items()}
    tokens, labels, mask = batch_for(cfg)
    step = jax.jit(M.bert_train_step(cfg, 1e-2))
    first = None
    loss = None
    for i in range(30):
        p, m, v, loss = step(p, m, v, jnp.float32(i + 1), tokens, labels, mask)
        if first is None:
            first = float(loss)
    assert float(loss) < first * 0.8, f"{first} → {float(loss)}"


def test_bert_mask_zero_positions_do_not_contribute():
    p = params_for(TINY)
    tokens, labels, _ = batch_for(TINY)
    # All-zero mask → loss guard returns 0.
    loss = float(M.bert_mlm_loss(TINY, p, tokens, labels, jnp.zeros_like(tokens)))
    assert loss == 0.0


CONV = M.ConvConfig(image=8, c1=4, c2=8, batch=4)
CONV_SK = M.ConvConfig(image=8, c1=4, c2=8, batch=4, sketch=(1, 2))


@pytest.mark.parametrize("cfg", [CONV, CONV_SK], ids=["dense", "sketched"])
def test_conv_forward_and_train(cfg):
    p = M.conv_init_params(jax.random.PRNGKey(0), cfg)
    images = jax.random.normal(jax.random.PRNGKey(1), (cfg.batch, 3 * cfg.image**2))
    labels = jnp.zeros((cfg.batch,), jnp.float32)
    logits = M.conv_forward(cfg, p, images)
    assert logits.shape == (cfg.batch, cfg.classes)
    m = {k: jnp.zeros_like(v) for k, v in p.items()}
    v = {k: jnp.zeros_like(vv) for k, vv in p.items()}
    step = jax.jit(M.conv_train_step(cfg, 1e-2))
    loss0 = None
    loss = None
    for i in range(20):
        p, m, v, loss = step(p, m, v, jnp.float32(i + 1), images, labels)
        if loss0 is None:
            loss0 = float(loss)
    assert float(loss) < loss0


def test_conv_sketch_reduction_near_30pct():
    nd = sum(v.size for v in M.conv_init_params(jax.random.PRNGKey(0), M.ConvConfig()).values())
    ns = sum(
        v.size
        for v in M.conv_init_params(jax.random.PRNGKey(0), M.ConvConfig(sketch=(1, 8))).values()
    )
    reduction = 1 - ns / nd
    assert 0.2 < reduction < 0.45, f"conv reduction {reduction:.3f} not ≈30%"


def test_adam_matches_reference_formula():
    p = {"w": jnp.array([1.0, 2.0])}
    g = {"w": jnp.array([0.5, -0.5])}
    m = {"w": jnp.zeros(2)}
    v = {"w": jnp.zeros(2)}
    new_p, new_m, new_v = M.adam_update(p, g, m, v, jnp.float32(1), 0.1)
    # step 1: mhat = g, vhat = g², update = lr·g/(|g|+eps) = lr·sign(g).
    np.testing.assert_allclose(new_p["w"], p["w"] - 0.1 * np.sign(g["w"]), rtol=1e-4)
    np.testing.assert_allclose(new_m["w"], 0.1 * g["w"], rtol=1e-5)
    np.testing.assert_allclose(new_v["w"], 0.001 * g["w"] ** 2, rtol=1e-3)


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
