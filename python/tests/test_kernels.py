"""Layer-1 kernel correctness: every Pallas kernel vs its pure-jnp oracle,
swept over shapes with hypothesis."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    performer_attention,
    sk_conv2d_gemm,
    sk_conv2d_layer,
    sk_linear,
    sk_linear_layer,
)
from compile.kernels.ref import (
    attention_ref,
    performer_ref,
    sk_linear_ref,
    sk_matmul_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(key, shape, jnp.float32) * scale


# ---------------------------------------------------------------------------
# SKLinear
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 16),
    d_in=st.integers(1, 48),
    d_out=st.integers(1, 48),
    l=st.integers(1, 3),
    k=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_sk_linear_matches_ref(batch, d_in, d_out, l, k, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = rand(keys[0], (batch, d_in))
    u = rand(keys[1], (l, d_in, k))
    v = rand(keys[2], (l, k, d_out))
    b = rand(keys[3], (d_out,))
    got = sk_linear(x, u, v, b)
    want = sk_linear_ref(x, u, v, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sk_linear_zero_input_gives_bias():
    x = jnp.zeros((4, 8))
    u = jnp.ones((2, 8, 3))
    v = jnp.ones((2, 3, 5))
    b = jnp.arange(5, dtype=jnp.float32)
    got = sk_linear(x, u, v, b)
    np.testing.assert_allclose(got, jnp.broadcast_to(b, (4, 5)))


def test_sk_linear_term_averaging():
    # Duplicating the single term must not change the output.
    key = jax.random.PRNGKey(0)
    x = rand(key, (5, 6))
    u1 = rand(jax.random.PRNGKey(1), (1, 6, 2))
    v1 = rand(jax.random.PRNGKey(2), (1, 2, 7))
    b = jnp.zeros((7,))
    once = sk_linear(x, u1, v1, b)
    twice = sk_linear(
        x, jnp.concatenate([u1, u1]), jnp.concatenate([v1, v1]), b
    )
    np.testing.assert_allclose(once, twice, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_sk_linear_vjp_matches_ref_grads(seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = rand(keys[0], (6, 10))
    u = rand(keys[1], (2, 10, 4))
    v = rand(keys[2], (2, 4, 8))
    b = rand(keys[3], (8,))

    def loss_kernel(x, u, v, b):
        return jnp.sum(sk_linear_layer(x, u, v, b) ** 2)

    def loss_ref(x, u, v, b):
        return jnp.sum(sk_linear_ref(x, u, v, b) ** 2)

    g_kernel = jax.grad(loss_kernel, argnums=(0, 1, 2, 3))(x, u, v, b)
    g_ref = jax.grad(loss_ref, argnums=(0, 1, 2, 3))(x, u, v, b)
    for a, r in zip(g_kernel, g_ref):
        np.testing.assert_allclose(a, r, rtol=1e-3, atol=1e-4)


# ---------------------------------------------------------------------------
# SKConv2d GEMM
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(1, 4),
    rows_tile=st.sampled_from([2, 4, 8]),
    d_in=st.integers(1, 32),
    d_out=st.integers(1, 24),
    l=st.integers(1, 3),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_sk_conv2d_gemm_matches_ref(tiles, rows_tile, d_in, d_out, l, k, seed):
    rows = tiles * rows_tile
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    p = rand(keys[0], (rows, d_in))
    u = rand(keys[1], (l, d_in, k))
    v = rand(keys[2], (l, k, d_out))
    b = jnp.zeros((d_out,))
    got = sk_conv2d_gemm(p, u, v, b, rows_tile=rows_tile)
    want = sk_matmul_ref(p, u, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_sk_conv2d_row_tiling_invariance():
    # Different tilings must agree exactly on the same input.
    key = jax.random.PRNGKey(3)
    p = rand(key, (16, 12))
    u = rand(jax.random.PRNGKey(4), (2, 12, 4))
    v = rand(jax.random.PRNGKey(5), (2, 4, 6))
    b = rand(jax.random.PRNGKey(6), (6,))
    a = sk_conv2d_gemm(p, u, v, b, rows_tile=4)
    c = sk_conv2d_gemm(p, u, v, b, rows_tile=16)
    np.testing.assert_allclose(a, c, rtol=1e-5, atol=1e-5)


def test_sk_conv2d_vjp_runs():
    key = jax.random.PRNGKey(7)
    p = rand(key, (8, 9))
    u = rand(jax.random.PRNGKey(8), (1, 9, 3))
    v = rand(jax.random.PRNGKey(9), (1, 3, 5))
    b = jnp.zeros((5,))
    g = jax.grad(lambda pp: jnp.sum(sk_conv2d_layer(pp, u, v, b)))(p)
    assert g.shape == p.shape
    assert bool(jnp.all(jnp.isfinite(g)))


# ---------------------------------------------------------------------------
# Performer attention
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 4),
    n=st.sampled_from([4, 16, 33]),
    dh=st.sampled_from([4, 8]),
    m=st.sampled_from([8, 32]),
    kind=st.sampled_from(["softmax", "relu"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_performer_matches_ref(h, n, dh, m, kind, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = rand(keys[0], (h, n, dh), 0.5)
    k = rand(keys[1], (h, n, dh), 0.5)
    v = rand(keys[2], (h, n, dh))
    w = rand(keys[3], (h, dh, m))
    got = performer_attention(q, k, v, w, kind=kind)
    want = jnp.stack(
        [performer_ref(q[i], k[i], v[i], w[i], kernel=kind) for i in range(h)]
    )
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_performer_approximates_exact_attention():
    # Monte-Carlo check: with many features the softmax-kernel Performer
    # should land near exact attention for small-norm inputs.
    keys = jax.random.split(jax.random.PRNGKey(11), 4)
    n, dh, m = 12, 8, 4096
    q = rand(keys[0], (1, n, dh), 0.3)
    k = rand(keys[1], (1, n, dh), 0.3)
    v = rand(keys[2], (1, n, dh))
    w = rand(keys[3], (1, dh, m))
    approx = performer_attention(q, k, v, w, kind="softmax")[0]
    exact = attention_ref(q[0], k[0], v[0])
    err = float(
        jnp.linalg.norm(approx - exact) / jnp.maximum(jnp.linalg.norm(exact), 1e-9)
    )
    assert err < 0.2, f"performer far from exact attention: {err}"


def test_performer_outputs_finite_for_large_inputs():
    # The row stabilizer must prevent overflow for big projections.
    keys = jax.random.split(jax.random.PRNGKey(12), 4)
    q = rand(keys[0], (2, 8, 4), 10.0)
    k = rand(keys[1], (2, 8, 4), 10.0)
    v = rand(keys[2], (2, 8, 4))
    w = rand(keys[3], (2, 4, 16))
    out = performer_attention(q, k, v, w, kind="softmax")
    assert bool(jnp.all(jnp.isfinite(out)))


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-q"]))
