//! Tiered serving walk-through: pretrain a small nonlinear MLP with a
//! warmup+cosine LR schedule, checkpoint it, sketchify a copy, register
//! **dense** and **sketched** quality tiers of the same service under one
//! memory budget, hammer both from concurrent client threads, route by
//! SLO through a dense/sketched [`Cascade`] — deadline-aware admission
//! with overload shedding and a speculative two-phase reply — then close
//! the loop online: a [`RankAdapter`] measures the sketched tier's real
//! quality on live rows and hot-swaps it up the rank ladder atomically.
//! The finale flips on end-to-end tracing, scripts one overload shed and
//! one speculative upgrade, and exports the run as a Chrome trace plus
//! Prometheus text.
//!
//! This is the paper's pitch end to end: the compressed model is a
//! drop-in *tier* — same request shape, same serving contract (batched
//! results bit-identical to single-row forwards at the configured cap) —
//! and its smaller footprint buys admitted workers under the shared
//! budget.
//!
//! Run with: `cargo run --release --example serve_tiered`

use panther::linalg::Mat;
use panther::nn::{Activation, ForwardCtx, LayerSelector, Linear, Model, SketchPlan};
use panther::rng::Philox;
use panther::serve::{Cascade, ModelServer, Slo, TierConfig, TraceConfig, Upgrade};
use panther::train::{Adam, LrSchedule, ScheduledOpt, Trainer};
use std::time::{Duration, Instant};

const D_IN: usize = 32;
const D_HID: usize = 64;
const D_OUT: usize = 8;

fn build_model(seed: u64) -> Model {
    let mut rng = Philox::seeded(seed);
    let mut m = Model::new();
    m.add("fc1", Linear::random(D_IN, D_HID, &mut rng)).unwrap();
    m.add("act", Activation::gelu()).unwrap();
    m.add("fc2", Linear::random(D_HID, D_OUT, &mut rng)).unwrap();
    m
}

/// Elementwise pass-through that sleeps a fixed time per batch — makes a
/// tier's capacity a scripted fact, so the tracing section can saturate
/// it on cue. Row-independent, so the registration probe admits it.
#[derive(Clone)]
struct SlowLane(Duration);

impl panther::nn::Module for SlowLane {
    fn type_name(&self) -> &'static str {
        "SlowLane"
    }
    fn forward(&self, x: &Mat, _ctx: &ForwardCtx) -> panther::Result<Mat> {
        std::thread::sleep(self.0);
        Ok(x.clone())
    }
    fn params(&self) -> Vec<(String, panther::nn::ParamRef<'_>)> {
        Vec::new()
    }
    fn params_mut(&mut self) -> Vec<(String, panther::nn::ParamMut<'_>)> {
        Vec::new()
    }
    fn boxed_clone(&self) -> Box<dyn panther::nn::Module> {
        Box::new(self.clone())
    }
}

fn main() -> panther::Result<()> {
    // --- 1. pretrain (warmup + cosine schedule) ------------------------------
    let mut rng = Philox::seeded(11);
    let mut model = build_model(1);
    let teacher = build_model(99);
    let ctx = ForwardCtx::new();
    let x = Mat::randn(64, D_IN, &mut rng);
    let target = teacher.forward(&x, &ctx)?;
    let schedule = LrSchedule::WarmupCosine {
        warmup: 20,
        total: 200,
        floor: 0.05,
    };
    let mut trainer = Trainer::new(Box::new(ScheduledOpt::new(
        Box::new(Adam::new(5e-3)),
        schedule,
    )));
    let mut first = f32::NAN;
    let mut last = f32::NAN;
    for step in 0..200 {
        last = trainer.train_step(&mut model, &x, &target, &ctx)?;
        if step == 0 {
            first = last;
        }
    }
    println!("pretrain: loss {first:.4} -> {last:.4} over 200 scheduled steps");

    // --- 2. checkpoint, then load-for-serving --------------------------------
    let dir = std::env::temp_dir().join("panther_serve_tiered");
    std::fs::create_dir_all(&dir)?;
    let ckpt = dir.join("mlp.ckpt");
    trainer.save_checkpoint(&model, "mlp", &ckpt)?;

    // --- 3. sketchify a copy: the cheap tier ---------------------------------
    let mut sk = model.clone_model();
    let report = SketchPlan::new()
        .select(LayerSelector::by_type("Linear"))
        .with(/*num_terms=*/ 1, /*low_rank=*/ 8)
        .seed(3)
        .apply(&mut sk)?;
    println!(
        "sketchified {} layers: {} -> {} params",
        report.converted.len(),
        report.params_before,
        report.params_after
    );

    // --- 4. register both tiers under one memory budget ----------------------
    let base = TierConfig {
        max_batch: 4,
        max_wait: Duration::from_micros(300),
        queue_cap: 512,
        workers: 4,
        ..TierConfig::default()
    };
    // Probe the dense footprint on a throwaway server (its workers would
    // otherwise idle through the whole demo), then budget both real tiers
    // identically.
    let dense_probe = {
        let mut probe_srv = ModelServer::new();
        let info = probe_srv.register_tier("probe", model.clone_model(), D_IN, base.clone())?;
        probe_srv.shutdown();
        info
    };
    let budget = dense_probe.weight_bytes + 2 * dense_probe.peak_batch_bytes;
    let mut server = ModelServer::new();
    let cfg = TierConfig {
        mem_budget: Some(budget),
        ..base
    };
    let dense_info =
        server.register_tier_from_checkpoint("dense", build_model(777), D_IN, &ckpt, cfg.clone())?;
    let sk_info = server.register_tier("sketched", sk, D_IN, cfg)?;
    for info in [&dense_info, &sk_info] {
        println!(
            "tier {:<9} weights {:>9} peak/batch {:>9} workers {} bit-identical {}",
            info.name,
            panther::util::human_bytes(info.weight_bytes),
            panther::util::human_bytes(info.peak_batch_bytes),
            info.workers,
            info.bit_identical_to_unbatched,
        );
    }
    println!(
        "shared budget {}: sketched tier admits {}x the dense workers",
        panther::util::human_bytes(budget),
        sk_info.workers as f64 / dense_info.workers as f64
    );

    // --- 5. concurrent clients hammer both tiers -----------------------------
    let clients = 8;
    let per_client = 200;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let h = server.handle();
            std::thread::spawn(move || {
                let row = Mat::randn(1, D_IN, &mut Philox::seeded(500 + c)).into_vec();
                let tier = if c % 2 == 0 { "dense" } else { "sketched" };
                for _ in 0..per_client {
                    h.infer(tier, &row).expect("request failed");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed();
    let total = clients * per_client;
    println!(
        "\n{total} requests from {clients} clients in {} ({:.0} req/s)\n",
        panther::util::human_duration(wall),
        total as f64 / wall.as_secs_f64()
    );
    println!("{}", server.metrics().report());

    // --- 6. SLO cascade: deadline routing + speculative upgrades -------------
    // The same two tiers become a quality ladder: each request carries a
    // deadline (and optionally a quality floor), the estimator predicts
    // each tier's completion time from the live sensors, and overload on
    // the dense tier sheds down the ladder instead of rejecting.
    let cascade = Cascade::new(&server, &[("dense", 1.0), ("sketched", 0.6)])?;
    let row = Mat::randn(1, D_IN, &mut Philox::seeded(42)).into_vec();
    println!(
        "predicted completion: dense {}, sketched {}",
        panther::util::human_duration(cascade.predict("dense").unwrap()),
        panther::util::human_duration(cascade.predict("sketched").unwrap()),
    );
    let routed = cascade.submit(&row, &Slo::new(Duration::from_millis(50)))?;
    println!(
        "50ms deadline -> tier {:?} at quality {} (shed: {})",
        routed.tier, routed.quality, routed.shed
    );
    routed.wait()?;
    // An impossible contract is a *typed* reject, not a hang or a shrug:
    // a 1µs deadline with a quality floor only the dense tier clears.
    let strict = Slo::new(Duration::from_micros(1)).with_min_quality(0.9);
    match cascade.submit(&row, &strict) {
        Err(e) => println!("1µs deadline  -> {e}"),
        Ok(r) => println!("1µs deadline  -> unexpectedly served by {:?}", r.tier),
    }
    // Speculative mode: answer now from the cheap tier, upgrade to the
    // dense answer when its verification lands.
    let spec = cascade.speculate(&row)?;
    let (first, upgrade) = spec.first();
    let fast = first?;
    match upgrade.upgraded() {
        Upgrade::Upgraded(dense_row) => println!(
            "speculative: fast answer from sketched ({} floats), dense upgrade \
             arrived (first logit {:+.4} -> {:+.4})",
            fast.len(),
            fast[0],
            dense_row[0]
        ),
        Upgrade::Revoked(e) => println!("speculative: upgrade revoked ({e})"),
    }

    // --- 7. online rank adaptation: measure, decide, hot-swap ----------------
    // The sketched tier's 0.6 ladder score was a guess. Attach a rank
    // adapter: it replays real admitted rows through the serving model
    // and the dense reference, publishes the *measured* quality (which
    // the cascade's ladder ordering picks up), and — under a tight error
    // target — walks the tier up the rank ladder via an atomic hot-swap
    // that never drops or corrupts an in-flight request.
    use panther::serve::{AdaptConfig, AdaptDecision, RankAdapter};
    let mut acfg = AdaptConfig::new(LayerSelector::by_type("Linear"), &[4, 8, 16]);
    acfg.initial_rank = 8; // what the "sketched" tier actually serves
    acfg.sketch_seed = 3; // the plan seed it was sketched with
    acfg.target_err = 1e-4; // demand near-dense fidelity: forces a recovery
    let mut adapter = RankAdapter::new(&server, "sketched", model.clone_model(), acfg)?;
    for i in 0..32 {
        adapter.observe(&Mat::randn(1, D_IN, &mut Philox::seeded(600 + i)).into_vec())?;
    }
    let reading = adapter.measure()?.expect("shadow rows present");
    println!(
        "\nmeasured quality of the serving rank-8 sketch: {:.4} (mean rel err {:.4})",
        reading.quality, reading.mean_err
    );
    // The cascade now ranks rungs by *measured* quality, not the labels.
    for (tier, q) in cascade.qualities() {
        println!("  ladder: {tier:<9} effective quality {q:.4}");
    }
    match adapter.step(&server)? {
        AdaptDecision::Swapped {
            from_rank,
            to_rank,
            version,
            candidate_err,
            ..
        } => println!(
            "adapt: rank {from_rank} -> {to_rank} (version {version}, \
             shadow err {candidate_err:.2e}) — applied as an atomic hot-swap"
        ),
        AdaptDecision::Hold { reason, .. } => println!("adapt: held ({reason:?})"),
    }
    // Rank 0 is the dense reference itself: after the recovery swap the
    // "sketched" tier answers bit-identically to the dense tier.
    let via_sketched = server.handle().infer("sketched", &row)?;
    let via_dense = server.handle().infer("dense", &row)?;
    println!(
        "post-swap reply identical to dense tier: {} ({} swap(s) recorded)",
        via_sketched == via_dense,
        server.metrics().tier("sketched").unwrap().swaps()
    );

    // --- 8. tracing: the run as a structured, exportable event stream --------
    // Flip tracing on — every admission from here mints a trace id and
    // accumulates spans — then reproduce the two cascade moments worth
    // seeing in a trace viewer, an overload shed and a speculative
    // upgrade, on a deliberately saturable rung: one worker, batch cap 1,
    // a 1-slot queue, 15ms per batch.
    use panther::util::events::EventClass;
    let tracer = server.enable_tracing(TraceConfig::default());
    let mut slow = Model::new();
    slow.add("throttle", SlowLane(Duration::from_millis(15)))?;
    slow.add("head", Linear::random(D_IN, D_OUT, &mut Philox::seeded(5)))?;
    server.register_tier(
        "slowdense",
        slow,
        D_IN,
        TierConfig {
            max_batch: 1,
            max_wait: Duration::from_micros(100),
            queue_cap: 1,
            workers: 1,
            ..TierConfig::default()
        },
    )?;
    let traced = Cascade::new(&server, &[("slowdense", 1.0), ("sketched", 0.6)])?;
    let h = server.handle();
    // Two warm requests give the completion estimator real 15ms samples.
    h.infer("slowdense", &row)?;
    h.infer("slowdense", &row)?;
    // Saturate the slow rung: one request executing, one parked in its
    // 1-slot queue — the next eligible submit must shed down the ladder.
    let busy = [h.submit("slowdense", &row)?, h.submit("slowdense", &row)?];
    let routed = traced.submit(&row, &Slo::new(Duration::from_millis(500)))?;
    println!("\ntraced submit -> tier {:?} (shed: {})", routed.tier, routed.shed);
    routed.wait()?;
    for p in busy {
        p.wait()?;
    }
    // Speculative two-phase with the rung drained again: the fast answer
    // comes from the sketched rung, the verify leg upgrades on slowdense.
    let spec = traced.speculate(&row)?;
    let (first, upgrade) = spec.first();
    first?;
    let upgraded = matches!(upgrade.upgraded(), Upgrade::Upgraded(_));
    println!("traced speculate -> upgraded: {upgraded}");
    let log = tracer.log();
    let slow_log = log.tiers.iter().find(|t| t.tier == "slowdense").unwrap();
    println!(
        "slowdense events: {} shed, {} speculate, {} upgrade \
         (suppressed {}, ring overflow {})",
        slow_log.recorded(EventClass::Shed),
        slow_log.recorded(EventClass::Speculate),
        slow_log.recorded(EventClass::Upgrade),
        slow_log.suppressed.iter().sum::<u64>(),
        slow_log.overflow,
    );
    let chrome = dir.join("trace_chrome.json");
    std::fs::write(&chrome, log.export_chrome_trace())?;
    let prom_text = server.metrics().prometheus();
    let prom = dir.join("metrics.prom");
    std::fs::write(&prom, &prom_text)?;
    println!("chrome trace -> {} (open in chrome://tracing or Perfetto)", chrome.display());
    println!("prometheus text -> {}, e.g.:", prom.display());
    for line in prom_text.lines() {
        let interesting =
            line.contains("sheds") || line.contains("speculative") || line.contains("upgrades");
        if interesting && line.contains("tier=\"slowdense\"") {
            println!("  {line}");
        }
    }

    // --- 9. graceful drain ---------------------------------------------------
    server.shutdown();
    std::fs::remove_file(&ckpt).ok();
    println!("drained and shut down cleanly");
    Ok(())
}
