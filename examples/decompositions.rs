//! Randomized matrix decompositions: RSVD and CQRRPT vs their deterministic
//! baselines — the library features of Panther §2 ("randomized matrix
//! decompositions (such as pivoted CholeskyQR)").
//!
//! ```bash
//! cargo run --release --example decompositions
//! ```

use panther::decomp::{
    cqrrpt, lstsq_normal_eq, pivoted_cholesky, rsvd, sketched_lstsq, CqrrptOpts, LstsqOpts,
    RsvdOpts,
};
use panther::linalg::{fro_norm, matmul, matmul_tn, ortho_error, qr_thin, svd_jacobi, Mat};
use panther::rng::Philox;
use panther::util::bench::Table;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut rng = Philox::seeded(0);

    // --- RSVD on a decaying spectrum ---------------------------------------
    println!("== RSVD vs Jacobi SVD (300×200, spectrum σ_i = 0.8^i) ==");
    let (m, n, full) = (300usize, 200usize, 60usize);
    let u = qr_thin(&Mat::randn(m, full, &mut rng)).0;
    let v = qr_thin(&Mat::randn(n, full, &mut rng)).0;
    let mut core = Mat::zeros(full, full);
    for i in 0..full {
        core.set(i, i, 0.8f32.powi(i as i32));
    }
    let a = matmul(&matmul(&u, &core), &v.transpose());

    let mut table = Table::new(&["rank", "rsvd err", "optimal err", "ratio", "rsvd ms", "svd ms"]);
    let t0 = Instant::now();
    let exact = svd_jacobi(&a);
    let t_svd = t0.elapsed();
    for rank in [5usize, 10, 20, 40] {
        let t0 = Instant::now();
        let f = rsvd(
            &a,
            &RsvdOpts {
                rank,
                power_iters: 1,
                oversample: 8,
                seed: 3,
            },
        );
        let t_rsvd = t0.elapsed();
        let err = fro_norm(&a.sub(&f.reconstruct()));
        let opt = fro_norm(&a.sub(&exact.truncate(rank).reconstruct()));
        table.row(&[
            rank.to_string(),
            format!("{err:.5}"),
            format!("{opt:.5}"),
            format!("{:.2}×", err / opt.max(1e-12)),
            format!("{:.1}", t_rsvd.as_secs_f64() * 1e3),
            format!("{:.1}", t_svd.as_secs_f64() * 1e3),
        ]);
    }
    println!("{}", table.render());

    // --- CQRRPT on tall matrices -------------------------------------------
    println!("== CQRRPT vs Householder QR (tall, ill-conditioned) ==");
    let mut table = Table::new(&["size", "method", "ms", "‖QᵀQ−I‖", "‖QR−AP‖/‖A‖"]);
    for &(rows, cols) in &[(2000usize, 50usize), (8000, 100)] {
        // Near-dependent columns: κ ≈ 1e4.
        let base = Mat::randn(rows, 1, &mut rng);
        let noise = Mat::randn(rows, cols, &mut rng);
        let mut a = Mat::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                a.set(i, j, base.get(i, 0) + 1e-4 * noise.get(i, j));
            }
        }
        let t0 = Instant::now();
        let f = cqrrpt(&a, &CqrrptOpts::default());
        let t_c = t0.elapsed();
        let ap = a.permute_cols(&f.perm).slice(0, rows, 0, f.rank);
        let recon =
            fro_norm(&matmul(&f.q, &f.r.slice(0, f.rank, 0, f.rank)).sub(&ap)) / fro_norm(&a);
        table.row(&[
            format!("{rows}×{cols}"),
            format!("cqrrpt (rank {})", f.rank),
            format!("{:.1}", t_c.as_secs_f64() * 1e3),
            format!("{:.2e}", ortho_error(&f.q)),
            format!("{recon:.2e}"),
        ]);
        let t0 = Instant::now();
        let (q, r) = qr_thin(&a);
        let t_h = t0.elapsed();
        let recon_h = fro_norm(&matmul(&q, &r).sub(&a)) / fro_norm(&a);
        table.row(&[
            format!("{rows}×{cols}"),
            "householder".to_string(),
            format!("{:.1}", t_h.as_secs_f64() * 1e3),
            format!("{:.2e}", ortho_error(&q)),
            format!("{recon_h:.2e}"),
        ]);
    }
    println!("{}", table.render());

    // --- Pivoted Cholesky ----------------------------------------------------
    println!("== pivoted Cholesky (PSD low-rank compression) ==");
    let b = Mat::randn(150, 12, &mut rng);
    let spd = matmul_tn(&b.transpose(), &b.transpose()); // 150×150 rank-12 PSD
    let mut table = Table::new(&["rank", "rel err", "pivots"]);
    for rank in [4usize, 8, 12, 16] {
        let f = pivoted_cholesky(&spd, rank, 0.0);
        let rec = matmul(&f.l, &f.l.transpose());
        table.row(&[
            rank.to_string(),
            format!("{:.2e}", panther::linalg::rel_error(&rec, &spd)),
            format!("{:?}", &f.pivots[..f.pivots.len().min(4)]),
        ]);
    }
    println!("{}", table.render());

    // --- Sketched least squares ---------------------------------------------
    println!("== sketch-and-precondition least squares (Blendenpik-style) ==");
    let (m, n) = (6000usize, 80usize);
    let a = Mat::randn(m, n, &mut rng);
    let x_true: Vec<f32> = (0..n).map(|i| ((i % 7) as f32 - 3.0) / 3.0).collect();
    let mut b = a.matvec(&x_true);
    for (i, v) in b.iter_mut().enumerate() {
        *v += 0.05 * ((i as f32 * 0.37).sin()); // structured noise
    }
    let t0 = Instant::now();
    let sk = sketched_lstsq(&a, &b, &LstsqOpts::default())?;
    let t_sk = t0.elapsed();
    let t0 = Instant::now();
    let ne = lstsq_normal_eq(&a, &b)?;
    let t_ne = t0.elapsed();
    let resid = |x: &[f32]| -> f64 {
        a.matvec(x)
            .iter()
            .zip(&b)
            .map(|(p, q)| ((p - q) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    println!(
        "  sketched:   {:>8.1?}  residual {:.5}  ({} LSQR iters)",
        t_sk,
        resid(&sk.x),
        sk.iters
    );
    println!(
        "  normal eq:  {:>8.1?}  residual {:.5}",
        t_ne,
        resid(&ne)
    );
    println!("decompositions OK");
    Ok(())
}
