//! Sketchify-then-fine-tune: the paper's headline training workload,
//! end to end on the native layer stack.
//!
//! 1. "Pretrain" a dense MLP (here: fit a random teacher with a few
//!    `Trainer` steps — stand-in for a real pretrained model).
//! 2. Compress its hidden layers with a [`SketchPlan`] — accuracy drops
//!    by the sketch's variance.
//! 3. Fine-tune the low-rank factors with Adam through the *same*
//!    `Module` API — the recovered loss is the paper's "comparable loss
//!    at a fraction of the parameters" claim in miniature.
//! 4. Checkpoint (v2 + optimizer section) and resume to show the
//!    fine-tune continues exactly.
//!
//! ```bash
//! cargo run --release --example finetune_sketched
//! ```

use panther::linalg::Mat;
use panther::nn::{ForwardCtx, LayerSelector, Linear, Model, SketchPlan};
use panther::rng::Philox;
use panther::train::{Adam, Trainer};

fn main() -> anyhow::Result<()> {
    let mut rng = Philox::seeded(42);
    let (d_in, d_hidden, d_out, batch) = (64usize, 128usize, 16usize, 64usize);

    // Fixed regression task: recover a random teacher's outputs.
    let teacher = {
        let mut m = Model::new();
        m.add("t1", Linear::random(d_in, d_hidden, &mut rng))?;
        m.add("t2", Linear::random(d_hidden, d_out, &mut rng))?;
        m
    };
    let ctx = ForwardCtx::new().batch_hint(batch);
    let x = Mat::randn(batch, d_in, &mut rng);
    let x_eval = Mat::randn(batch, d_in, &mut rng);
    let y = teacher.forward(&x, &ctx)?;
    let y_eval = teacher.forward(&x_eval, &ctx)?;

    // --- 1. pretrain dense ------------------------------------------------
    let mut model = Model::new();
    model.add("ffn.fc1", Linear::random(d_in, d_hidden, &mut rng))?;
    model.add("ffn.fc2", Linear::random(d_hidden, d_out, &mut rng))?;
    let dense_params = model.total_params();
    let mut tr = Trainer::new(Box::new(Adam::new(2e-2)));
    for _ in 0..150 {
        tr.train_step(&mut model, &x, &y, &ctx)?;
    }
    let dense_loss = tr.eval_loss(&model, &x_eval, &y_eval, &ctx)?;
    println!("dense   : {dense_params:>7} params, eval loss {dense_loss:.5}");

    // --- 2. sketchify -----------------------------------------------------
    let report = SketchPlan::new()
        .select(LayerSelector::by_regex(r"ffn\.fc\d")?)
        .with(/*num_terms=*/ 1, /*low_rank=*/ 16)
        .seed(7)
        .apply(&mut model)?;
    print!("{report}");
    let sketched_loss = tr.eval_loss(&model, &x_eval, &y_eval, &ctx)?;
    println!(
        "sketched: {:>7} params, eval loss {sketched_loss:.5} (sketch variance)",
        model.total_params()
    );

    // --- 3. fine-tune the factors through the same Module API -------------
    let mut ft = Trainer::new(Box::new(Adam::new(5e-3)));
    for step in 0..300 {
        let loss = ft.train_step(&mut model, &x, &y, &ctx)?;
        if step % 100 == 0 {
            println!("  fine-tune step {step:>3}: train loss {loss:.5}");
        }
    }
    let tuned_loss = ft.eval_loss(&model, &x_eval, &y_eval, &ctx)?;
    println!(
        "tuned   : {:>7} params, eval loss {tuned_loss:.5} ({}x fewer params)",
        model.total_params(),
        dense_params / model.total_params().max(1)
    );
    anyhow::ensure!(
        tuned_loss < sketched_loss,
        "fine-tuning must recover accuracy: {sketched_loss} -> {tuned_loss}"
    );

    // --- 4. checkpoint + exact resume -------------------------------------
    let path = std::env::temp_dir().join("finetune_sketched.ckpt");
    ft.save_checkpoint(&model, "ffn_sketched", &path)?;
    let mut resumed_model = model.clone_model();
    let mut resumed = Trainer::resume(&mut resumed_model, &path)?;
    let a = ft.train_step(&mut model, &x, &y, &ctx)?;
    let b = resumed.train_step(&mut resumed_model, &x, &y, &ctx)?;
    println!("resume  : step {} loss {a:.6} == resumed loss {b:.6}", ft.step);
    anyhow::ensure!(a == b, "checkpoint resume must continue exactly");
    std::fs::remove_file(&path).ok();
    println!("finetune_sketched OK");
    Ok(())
}
