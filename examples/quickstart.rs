//! Quickstart: the paper's Listing 1 — drop-in replacement of dense layers
//! with their sketched counterparts — expressed through the unified
//! `Module` + `SketchPlan` API, plus the cost model that explains when
//! sketching wins.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use panther::linalg::{rel_error, Mat};
use panther::nn::{
    linear_cost, sketch_beats_dense, ForwardCtx, LayerSelector, Linear, Model, Module, SKLinear,
    SketchPlan,
};
use panther::rng::Philox;
use panther::util::bench::{Bencher, Table};

fn main() -> anyhow::Result<()> {
    let mut rng = Philox::seeded(0);

    // --- Listing 1: StandardModel vs PantherModel -------------------------
    // Standard PyTorch model:    nn.Linear(2048, 2048) in an FFN stack
    // Panther-optimized model:   pr.nn.SKLinear(2048, 2048, num_terms=1,
    //                                           low_rank=16)
    // Here the swap is a SketchPlan applied to the registered model — the
    // call-sites don't change because every layer answers Module::forward.
    let d = 2048;
    println!("== drop-in replacement (Listing 1) ==");
    let mut model = Model::new();
    model.add("encoder.ffn.fc1", Linear::random(d, d, &mut rng))?;
    model.add("encoder.ffn.fc2", Linear::random(d, d, &mut rng))?;
    model.add("head.out", Linear::random(d, 10, &mut rng))?;
    let dense_params = model.total_params();

    // Reference output before compression (the Module API is the same for
    // dense and sketched layers).
    let x = Mat::randn(32, d, &mut rng);
    let ctx = ForwardCtx::new().batch_hint(32);
    let y_dense = model.get("encoder.ffn.fc1").unwrap().forward(&x, &ctx)?;

    // Compress every FFN linear, leave the head dense.
    let report = SketchPlan::new()
        .select(LayerSelector::by_regex(r"ffn\.fc\d")?)
        .with(/*num_terms=*/ 1, /*low_rank=*/ 16)
        .seed(0)
        .apply(&mut model)?;
    print!("{report}");
    println!(
        "model: {dense_params} -> {} params; head.out stays {}",
        model.total_params(),
        model.get("head.out").unwrap().type_name()
    );

    // Same call-site, same shapes:
    let y_sk = model.get("encoder.ffn.fc1").unwrap().forward(&x, &ctx)?;
    assert_eq!(y_dense.shape(), y_sk.shape());
    println!(
        "output shapes match: {:?}; sketch relative deviation {:.3} (unbiased, variance ∝ 1/(l·k))",
        y_sk.shape(),
        rel_error(&y_sk, &y_dense)
    );

    // --- Speed: measured, not just modeled --------------------------------
    println!("\n== measured forward latency (B=32, d=2048) ==");
    let dense = Linear::random(d, d, &mut rng);
    let sk = SKLinear::from_dense(&dense, /*num_terms=*/ 1, /*low_rank=*/ 16, &mut rng);
    println!(
        "dense params: {:>10}   sketched params: {:>9}  ({:.1}% of dense)",
        dense.param_count(),
        sk.param_count(),
        sk.compression_ratio() * 100.0
    );
    let bench = Bencher::quick();
    let t_dense = bench.run("dense", || dense.forward(&x));
    let t_sk = bench.run("sketched l=1 k=16", || sk.forward(&x));
    println!("{}", t_dense.report());
    println!("{}", t_sk.report());
    println!(
        "speedup: {:.1}× (FLOP model predicts {:.1}×)",
        t_dense.mean.as_secs_f64() / t_sk.mean.as_secs_f64(),
        panther::nn::cost::predicted_speedup(d, d, 1, 16)
    );

    // --- The cost model and the paper's skip rule -------------------------
    println!("\n== when does sketching win? (the 2lk(din+dout) ≤ din·dout rule) ==");
    let mut table = Table::new(&["config", "params", "fwd FLOPs/row", "wins?"]);
    let dense_cost = linear_cost(d, d, 1, None);
    table.row(&[
        "dense".into(),
        dense_cost.params.to_string(),
        dense_cost.flops.to_string(),
        "-".into(),
    ]);
    for (l, k) in [(1usize, 16usize), (1, 128), (2, 256), (3, 512)] {
        let c = linear_cost(d, d, 1, Some((l, k)));
        table.row(&[
            format!("sk l={l} k={k}"),
            c.params.to_string(),
            c.flops.to_string(),
            sketch_beats_dense(d, d, l, k).to_string(),
        ]);
    }
    println!("{}", table.render());
    println!("quickstart OK");
    Ok(())
}
