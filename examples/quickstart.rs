//! Quickstart: the paper's Listing 1 — drop-in replacement of a dense
//! linear layer with `SKLinear`, plus the cost model that explains when it
//! wins.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use panther::linalg::{rel_error, Mat};
use panther::nn::{linear_cost, sketch_beats_dense, Linear, SKLinear};
use panther::rng::Philox;
use panther::util::bench::{Bencher, Table};

fn main() -> anyhow::Result<()> {
    let mut rng = Philox::seeded(0);

    // --- Listing 1: StandardModel vs PantherModel -------------------------
    // Standard PyTorch model:    nn.Linear(2048, 2048)
    // Panther-optimized model:   pr.nn.SKLinear(2048, 2048, num_terms=1,
    //                                           low_rank=16)
    let d = 2048;
    println!("== drop-in replacement (Listing 1) ==");
    let dense = Linear::random(d, d, &mut rng);
    let sk = SKLinear::from_dense(&dense, /*num_terms=*/ 1, /*low_rank=*/ 16, &mut rng);
    println!(
        "dense params: {:>10}   sketched params: {:>9}  ({:.1}% of dense)",
        dense.param_count(),
        sk.param_count(),
        sk.compression_ratio() * 100.0
    );

    // Same call-site, same shapes:
    let x = Mat::randn(32, d, &mut rng);
    let y_dense = dense.forward(&x);
    let y_sk = sk.forward(&x);
    assert_eq!(y_dense.shape(), y_sk.shape());
    println!(
        "output shapes match: {:?}; sketch relative deviation {:.3} (unbiased, variance ∝ 1/(l·k))",
        y_sk.shape(),
        rel_error(&y_sk, &y_dense)
    );

    // --- Speed: measured, not just modeled --------------------------------
    println!("\n== measured forward latency (B=32, d=2048) ==");
    let bench = Bencher::quick();
    let t_dense = bench.run("dense", || dense.forward(&x));
    let t_sk = bench.run("sketched l=1 k=16", || sk.forward(&x));
    println!("{}", t_dense.report());
    println!("{}", t_sk.report());
    println!(
        "speedup: {:.1}× (FLOP model predicts {:.1}×)",
        t_dense.mean.as_secs_f64() / t_sk.mean.as_secs_f64(),
        panther::nn::cost::predicted_speedup(d, d, 1, 16)
    );

    // --- The cost model and the paper's skip rule -------------------------
    println!("\n== when does sketching win? (the 2lk(din+dout) ≤ din·dout rule) ==");
    let mut table = Table::new(&["config", "params", "fwd FLOPs/row", "wins?"]);
    let dense_cost = linear_cost(d, d, 1, None);
    table.row(&[
        "dense".into(),
        dense_cost.params.to_string(),
        dense_cost.flops.to_string(),
        "-".into(),
    ]);
    for (l, k) in [(1usize, 16usize), (1, 128), (2, 256), (3, 512)] {
        let c = linear_cost(d, d, 1, Some((l, k)));
        table.row(&[
            format!("sk l={l} k={k}"),
            c.params.to_string(),
            c.flops.to_string(),
            sketch_beats_dense(d, d, l, k).to_string(),
        ]);
    }
    println!("{}", table.render());
    println!("quickstart OK");
    Ok(())
}
