//! §4.2 case study: conv classifier on the synthetic CIFAR stand-in.
//! Dense vs SKConv2d at a controlled ~30% model-size reduction — the paper
//! reports 89% → 86% accuracy on ResNet-50/CIFAR-10; the claim under test
//! here is the *shape*: a small, bounded accuracy drop at ~30% reduction.
//!
//! ```bash
//! cargo run --release --example resnet_cifar -- [steps] [seed]
//! ```

use panther::data::ImageDataset;
use panther::rng::Philox;
use panther::runtime::Runtime;
use panther::train::{ConvTrainer, ModelState};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(400);
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let artifacts =
        std::env::var("PANTHER_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let mut rt = Runtime::open(&artifacts)?;
    let ds = ImageDataset::cifar_like();

    let mut results = Vec::new();
    for model in ["conv_dense", "conv_sk_1_8"] {
        let spec = rt.manifest().model(model).unwrap().clone();
        println!("\n== {model}: {} params ==", spec.param_count);
        let mut state = ModelState::init(&mut rt, model, seed as f32)?;
        let mut rng = Philox::new(seed, 10);
        let t0 = std::time::Instant::now();
        let report = {
            let mut trainer = ConvTrainer::new(&mut rt, &ds);
            trainer.train(&mut state, steps, &mut rng)?
        };
        let mut eval_rng = Philox::new(seed, 11);
        let acc = {
            let mut trainer = ConvTrainer::new(&mut rt, &ds);
            trainer.accuracy(&state, 16, &mut eval_rng)?
        };
        println!(
            "{model}: {steps} steps in {:.1?}, final loss {:.4}, accuracy {:.1}%",
            t0.elapsed(),
            report.final_loss,
            acc * 100.0
        );
        results.push((model.to_string(), spec.param_count, acc));
    }

    let (dense_name, dense_params, dense_acc) = &results[0];
    let (sk_name, sk_params, sk_acc) = &results[1];
    let reduction = 1.0 - *sk_params as f64 / *dense_params as f64;
    println!(
        "\n§4.2 case study: {dense_name} {:.1}% vs {sk_name} {:.1}% at {:.1}% size reduction",
        dense_acc * 100.0,
        sk_acc * 100.0,
        reduction * 100.0
    );
    println!(
        "accuracy drop: {:.1} points (paper: 89% → 86% at 30% reduction)",
        (dense_acc - sk_acc) * 100.0
    );
    println!("resnet_cifar OK");
    Ok(())
}
