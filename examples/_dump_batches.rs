use panther::data::TextCorpus;
use panther::rng::Philox;
use std::io::Write;
fn main() {
    let c = TextCorpus::generate(256, 200_000, 0 ^ 0xC0FFEE);
    let mut rng = Philox::new(0, 1);
    let mut f = std::io::BufWriter::new(std::fs::File::create("/tmp/rust_batches.txt").unwrap());
    for _ in 0..600 {
        let b = c.mlm_batch(16, 64, &mut rng);
        for t in [&b.tokens, &b.labels, &b.mask] {
            let s: Vec<String> = t.data().iter().map(|v| format!("{v}")).collect();
            writeln!(f, "{}", s.join(" ")).unwrap();
        }
    }
    println!("dumped");
}
