//! Long-sequence attention under a memory budget — the Figure-3 story as a
//! runnable scenario: exact softmax attention OOMs past a sequence length,
//! Performer linear attention keeps going. Also cross-checks the Pallas
//! `k_performer` artifact against the Rust implementation when artifacts
//! are present.
//!
//! ```bash
//! cargo run --release --example attention_long_seq -- [budget_mib]
//! ```

use panther::linalg::Mat;
use panther::nn::attention::{AttnWeights, KernelKind, MultiHeadAttention, RandMultiHeadAttention};
use panther::nn::{ForwardCtx, Module};
use panther::rng::Philox;
use panther::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let budget_mib: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(256);
    let budget = budget_mib * 1024 * 1024;
    let (d, h, m) = (256usize, 8usize, 128usize);
    println!(
        "attention under a {budget_mib} MiB activation budget (embed {d}, heads {h}, {m} random features)\n"
    );
    let mut rng = Philox::seeded(1);
    let weights = AttnWeights::random(d, h, &mut rng);
    let dense = MultiHeadAttention::new(weights.clone());
    let perf = RandMultiHeadAttention::new(weights, m, KernelKind::Softmax, 7);

    let mut table = Table::new(&["seq len", "dense peak", "performer peak", "dense", "performer"]);
    for n in [256usize, 512, 1024, 2048, 4096, 8192] {
        let x = Mat::randn(n, d, &mut rng);
        // Both variants answer through the unified Module::forward — the
        // budgeted ForwardCtx is what turns a would-be OOM into an "x".
        let run = |f: &dyn Fn(&ForwardCtx) -> panther::Result<Mat>| -> (String, String) {
            let ctx = ForwardCtx::with_budget(budget);
            match f(&ctx) {
                Ok(_) => (
                    panther::util::human_bytes(ctx.mem().peak_bytes()),
                    "ok".to_string(),
                ),
                Err(_) => ("-".to_string(), "x (OOM)".to_string()),
            }
        };
        let (dense_peak, dense_status) = run(&|ctx| dense.forward(&x, ctx));
        let (perf_peak, perf_status) = run(&|ctx| perf.forward(&x, ctx));
        table.row(&[
            n.to_string(),
            dense_peak,
            perf_peak,
            dense_status,
            perf_status,
        ]);
    }
    println!("{}", table.render());
    println!("dense peak grows O(h·n²); performer O(n·m) — the paper's Figure 3 'x' markers\nare the dense rows above that hit the budget.\n");

    // Cross-check the AOT Pallas performer against the Rust path.
    let artifacts =
        std::env::var("PANTHER_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    match panther::runtime::Runtime::open(&artifacts) {
        Ok(mut rt) => {
            let spec = rt.manifest().artifact("k_performer").unwrap().clone();
            let mut rng = Philox::seeded(3);
            let inputs: Vec<panther::runtime::HostTensor> = spec
                .inputs
                .iter()
                .map(|s| panther::runtime::HostTensor::randn(&s.shape, 0.5, &mut rng))
                .collect();
            let out = rt.execute("k_performer", &inputs)?;
            println!(
                "k_performer artifact executed through PJRT: output shape {:?}, finite: {}",
                out[0].shape(),
                out[0].data().iter().all(|v| v.is_finite())
            );
        }
        Err(_) => println!("(artifacts not built — skipping PJRT cross-check)"),
    }
    println!("attention_long_seq OK");
    Ok(())
}
