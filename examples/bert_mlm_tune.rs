//! **End-to-end driver** — reproduces the paper's §4.2 BERT quality
//! experiment and the Listing-2 SKAutoTuner migration flow, exercising all
//! three layers of the stack (Pallas kernels → JAX train graphs → Rust
//! coordinator):
//!
//! 1. Train `bert_dense` (BERT-mini MLM) on the synthetic corpus; log the
//!    loss curve.
//! 2. Train `bert_sk_1_8` (every encoder Linear sketched, ~76% fewer
//!    parameters) from scratch with identical data; log its curve.
//! 3. Run the SKAutoTuner: sketch the *trained dense* weights into every
//!    candidate (l,k) variant, evaluate MLM loss, and pick the smallest
//!    feasible model under a loss constraint — the paper's
//!    `accuracy_threshold` flow.
//!
//! Results land in EXPERIMENTS.md (§4.2 rows).
//!
//! ```bash
//! cargo run --release --example bert_mlm_tune -- [steps] [seed]
//! ```

use panther::data::TextCorpus;
use panther::rng::Philox;
use panther::runtime::Runtime;
use panther::train::{BertTrainer, ModelState};
use panther::tuner::bert_tune::tune_bert_candidates;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(600);
    let seed: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let artifacts =
        std::env::var("PANTHER_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());

    let mut rt = Runtime::open(&artifacts)?;
    let dense_spec = rt.manifest().model("bert_dense").unwrap().clone();
    let sk_spec = rt.manifest().model("bert_sk_1_8").unwrap().clone();
    let vocab = dense_spec.config_usize("vocab").unwrap();
    let corpus = TextCorpus::generate(vocab, 200_000, seed ^ 0xC0FFEE);
    println!(
        "corpus: {} tokens over {vocab} symbols, unigram entropy {:.3} nats (uniform {:.3})",
        corpus.len(),
        corpus.unigram_entropy(),
        (vocab as f64).ln()
    );

    // --- 1+2: train dense and sketched variants on identical data ---------
    let mut curves = Vec::new();
    let mut finals = Vec::new();
    for model in ["bert_dense", "bert_sk_1_8"] {
        let spec = rt.manifest().model(model).unwrap().clone();
        println!(
            "\n== training {model}: {} params ({}) ==",
            spec.param_count,
            match spec.sketch() {
                Some((l, k)) => format!("sketched l={l} k={k}"),
                None => "dense".to_string(),
            }
        );
        let mut state = ModelState::init(&mut rt, model, seed as f32)?;
        let mut data_rng = Philox::new(seed, 1); // same stream for both
        let t0 = std::time::Instant::now();
        let report = {
            let mut trainer = BertTrainer::new(&mut rt, &corpus);
            trainer.train(&mut state, steps, &mut data_rng)?
        };
        let mut eval_rng = Philox::new(seed, 2);
        let eval = {
            let mut trainer = BertTrainer::new(&mut rt, &corpus);
            trainer.evaluate(&state, 8, &mut eval_rng)?
        };
        println!(
            "{model}: {} steps in {:.1?} ({:.2} steps/s), final train loss {:.4}, eval loss {:.4}",
            steps,
            t0.elapsed(),
            steps as f64 / t0.elapsed().as_secs_f64(),
            report.final_loss,
            eval
        );
        curves.push((model.to_string(), report.losses.clone()));
        finals.push((model.to_string(), spec.param_count, eval));
    }

    println!("\n== loss curves (step, loss) ==");
    for (model, curve) in &curves {
        let pts: Vec<String> = curve
            .iter()
            .map(|(s, l)| format!("({s},{l:.3})"))
            .collect();
        println!("{model}: {}", pts.join(" "));
    }
    let reduction = 1.0 - sk_spec.param_count as f64 / dense_spec.param_count as f64;
    println!(
        "\n§4.2 summary: {:.1}% parameter reduction; eval loss dense {:.4} vs sketched {:.4}",
        reduction * 100.0,
        finals[0].2,
        finals[1].2
    );

    // --- 3: SKAutoTuner over candidates (Listing 2) ------------------------
    println!("\n== SKAutoTuner: sketch trained dense weights, constraint = loss margin ==");
    drop(rt); // tune opens its own runtime
    let outcome = tune_bert_candidates(&artifacts, steps.min(120), 4, 0.40, seed)?;
    println!("{outcome}");
    println!("\nbert_mlm_tune OK");
    Ok(())
}
