#!/usr/bin/env python3
"""Generate the *reference* manifest.json for the default (offline) backend.

The PJRT path replaces this file via `make artifacts` (python/compile/aot.py),
which lowers the real JAX graphs to HLO text and writes a manifest whose
artifacts point at `*.hlo.txt` files. This generator instead declares the
same artifact/model inventory with `"ref"` configs naming the builtin graphs
implemented in `rust/src/runtime/reference.rs`, so the full runtime stack
(coordinator, batcher, trainers, tuner) runs with zero external deps.

Keep the shapes here in lockstep with the reference backend's expectations:
params are ordered exactly as `param_names`, factor tensors are stacked
`[l, d_in, k]` / `[l, k, d_out]`.
"""
import json
import os

# --- bert family (BERT-mini MLM stand-in) ---
VOCAB, DIM, HIDDEN, BATCH, SEQ, LR = 256, 64, 1024, 16, 64, 1e-3
# --- conv family (image classifier stand-in) ---
CLASSES, CHANNELS, IMAGE, C_HIDDEN, C_BATCH = 10, 3, 16, 128, 32
PX = CHANNELS * IMAGE * IMAGE


def tensors(named):
    return [{"name": n, "shape": list(s)} for n, s in named]


def outs(shapes):
    return [{"shape": list(s)} for s in shapes]


def numel(shape):
    n = 1
    for d in shape:
        n *= d
    return n


def bert_params(sketch):
    if sketch is None:
        return [
            ("tok_emb", (VOCAB, DIM)),
            ("fc1.w", (DIM, HIDDEN)),
            ("fc2.w", (HIDDEN, DIM)),
        ]
    l, k = sketch
    return [
        ("tok_emb", (VOCAB, DIM)),
        ("fc1.u", (l, DIM, k)),
        ("fc1.v", (l, k, HIDDEN)),
        ("fc2.u", (l, HIDDEN, k)),
        ("fc2.v", (l, k, DIM)),
    ]


def conv_params(sketch):
    if sketch is None:
        return [("fc1.w", (PX, C_HIDDEN)), ("fc2.w", (C_HIDDEN, CLASSES))]
    l, k = sketch
    return [
        ("fc1.u", (l, PX, k)),
        ("fc1.v", (l, k, C_HIDDEN)),
        ("fc2.w", (C_HIDDEN, CLASSES)),
    ]


def state_io(params, prefix_groups=("params", "m", "v")):
    named = []
    for g in prefix_groups:
        named += [(f"{g}.{n}", s) for n, s in params]
    return named


artifacts = {}
models = {}


def add_bert(name, sketch):
    params = bert_params(sketch)
    ref = {
        "vocab": VOCAB,
        "dim": DIM,
        "hidden": HIDDEN,
        "lr": LR,
        "sketch": list(sketch) if sketch else None,
    }
    pshapes = [s for _, s in params]
    batch_io = [("tokens", (BATCH, SEQ)), ("labels", (BATCH, SEQ)), ("mask", (BATCH, SEQ))]
    artifacts[f"{name}_init"] = {
        "path": "builtin",
        "inputs": tensors([("seed", ())]),
        "outputs": outs(pshapes * 3),
        "ref": dict(ref, graph="bert_init"),
    }
    artifacts[f"{name}_train"] = {
        "path": "builtin",
        "inputs": tensors(state_io(params) + [("step", ())] + batch_io),
        "outputs": outs(pshapes * 3 + [()]),
        "ref": dict(ref, graph="bert_train"),
    }
    artifacts[f"{name}_eval"] = {
        "path": "builtin",
        "inputs": tensors([(f"params.{n}", s) for n, s in params] + batch_io),
        "outputs": outs([()]),
        "ref": dict(ref, graph="bert_eval"),
    }
    artifacts[f"{name}_eval_rows"] = {
        "path": "builtin",
        "inputs": tensors([(f"params.{n}", s) for n, s in params] + batch_io),
        "outputs": outs([(BATCH,)]),
        "ref": dict(ref, graph="bert_eval_rows"),
    }
    models[name] = {
        "family": "bert",
        "init": f"{name}_init",
        "train": f"{name}_train",
        "eval": f"{name}_eval",
        "eval_rows": f"{name}_eval_rows",
        "param_names": [n for n, _ in params],
        "param_count": sum(numel(s) for s in pshapes),
        "config": {
            "vocab": VOCAB,
            "dim": DIM,
            "hidden": HIDDEN,
            "batch": BATCH,
            "seq": SEQ,
            "lr": LR,
            "sketch": list(sketch) if sketch else None,
        },
    }


def add_conv(name, sketch):
    params = conv_params(sketch)
    ref = {
        "classes": CLASSES,
        "px": PX,
        "hidden": C_HIDDEN,
        "lr": LR,
        "sketch": list(sketch) if sketch else None,
    }
    pshapes = [s for _, s in params]
    artifacts[f"{name}_init"] = {
        "path": "builtin",
        "inputs": tensors([("seed", ())]),
        "outputs": outs(pshapes * 3),
        "ref": dict(ref, graph="conv_init"),
    }
    artifacts[f"{name}_train"] = {
        "path": "builtin",
        "inputs": tensors(
            state_io(params)
            + [("step", ()), ("images", (C_BATCH, PX)), ("labels", (C_BATCH,))]
        ),
        "outputs": outs(pshapes * 3 + [()]),
        "ref": dict(ref, graph="conv_train"),
    }
    artifacts[f"{name}_predict"] = {
        "path": "builtin",
        "inputs": tensors(
            [(f"params.{n}", s) for n, s in params] + [("images", (C_BATCH, PX))]
        ),
        "outputs": outs([(C_BATCH, CLASSES)]),
        "ref": dict(ref, graph="conv_predict"),
    }
    models[name] = {
        "family": "conv",
        "init": f"{name}_init",
        "train": f"{name}_train",
        "predict": f"{name}_predict",
        "param_names": [n for n, _ in params],
        "param_count": sum(numel(s) for s in pshapes),
        "config": {
            "classes": CLASSES,
            "channels": CHANNELS,
            "image": IMAGE,
            "px": PX,
            "hidden": C_HIDDEN,
            "batch": C_BATCH,
            "lr": LR,
            "sketch": list(sketch) if sketch else None,
        },
    }


# Kernel artifacts (fixed bench-friendly shapes).
artifacts["k_sk_linear"] = {
    "path": "builtin",
    "inputs": tensors(
        [("x", (8, 64)), ("u", (2, 64, 16)), ("v", (2, 16, 32)), ("bias", (32,))]
    ),
    "outputs": outs([(8, 32)]),
    "ref": {"graph": "sk_linear"},
}
artifacts["k_performer"] = {
    "path": "builtin",
    "inputs": tensors(
        [("q", (32, 16)), ("k", (32, 16)), ("v", (32, 16)), ("omega", (16, 24))]
    ),
    "outputs": outs([(32, 16)]),
    "ref": {"graph": "performer"},
}

add_bert("bert_dense", None)
add_bert("bert_sk_1_8", (1, 8))
add_bert("bert_sk_2_16", (2, 16))
add_bert("bert_sk_1_32", (1, 32))
add_conv("conv_dense", None)
add_conv("conv_sk_1_8", (1, 8))

out_path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "manifest.json")
with open(out_path, "w") as f:
    json.dump({"artifacts": artifacts, "models": models}, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"wrote {out_path}: {len(artifacts)} artifacts, {len(models)} models")
for name, m in sorted(models.items()):
    print(f"  {name:<14} {m['param_count']:>8} params")
