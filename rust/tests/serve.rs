//! Integration tests for `panther::serve`: the batched-equals-sequential
//! oracle, concurrent-client stress, padding hygiene, drain-on-shutdown
//! semantics, and the tiered dense/sketched routing path.
//!
//! The oracle tests run the strong contract: with batch caps below the
//! GEMM microkernel height (8), every served result must equal the plain
//! single-row `Module::forward` of its request row **bit for bit**, for
//! any arrival order, occupancy, and padding — including the ragged final
//! batch case.

use panther::linalg::Mat;
use panther::nn::{Activation, ForwardCtx, LayerSelector, Linear, Model, SketchPlan};
use panther::rng::Philox;
use panther::serve::{ModelServer, ServeError, TierConfig};
use std::sync::Arc;
use std::time::Duration;

/// A nonlinear row-independent stack: Linear → GELU → Linear, with a
/// nonzero bias so padding rows produce *nonzero* outputs — if a padded
/// row ever leaked into a live row, the bitwise oracle would see it.
fn mlp(seed: u64, d_in: usize, d_out: usize) -> Model {
    let mut rng = Philox::seeded(seed);
    let mut m = Model::new();
    let mut fc1 = Linear::random(d_in, 12, &mut rng);
    for b in fc1.bias.iter_mut() {
        *b = 0.3;
    }
    m.add("fc1", fc1).unwrap();
    m.add("act", Activation::gelu()).unwrap();
    let mut fc2 = Linear::random(12, d_out, &mut rng);
    for b in fc2.bias.iter_mut() {
        *b = -0.2;
    }
    m.add("fc2", fc2).unwrap();
    m
}

/// The sketched variant of the same stack.
fn sketched(seed: u64, d_in: usize, d_out: usize) -> Model {
    let mut m = mlp(seed, d_in, d_out);
    SketchPlan::new()
        .select(LayerSelector::by_type("Linear"))
        .with(1, 4)
        .seed(17)
        .apply(&mut m)
        .unwrap();
    m
}

fn request_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| Mat::randn(1, d, &mut Philox::seeded(seed + i as u64)).into_vec())
        .collect()
}

/// The oracle baseline: the unbatched single-row forward.
fn solo_forward(model: &Model, row: &[f32]) -> Vec<f32> {
    let ctx = ForwardCtx::new();
    model
        .forward(&Mat::from_vec(1, row.len(), row.to_vec()), &ctx)
        .unwrap()
        .row(0)
        .to_vec()
}

#[test]
fn batched_results_bit_identical_to_single_row_forward() {
    // Caps 1 and 4, concurrent submission (arbitrary arrival order and
    // batch composition), plus a ragged final batch — every reply must be
    // the exact single-row forward.
    let d = 10;
    for (cap, n_requests) in [(1usize, 5usize), (4, 12), (4, 6 /* ragged */)] {
        let model = mlp(42, d, 5);
        let expected: Vec<Vec<f32>> = request_rows(n_requests, d, 900)
            .iter()
            .map(|r| solo_forward(&model, r))
            .collect();
        let mut server = ModelServer::new();
        let info = server
            .register_tier(
                "t",
                model,
                d,
                TierConfig {
                    max_batch: cap,
                    max_wait: Duration::from_millis(2),
                    workers: 2,
                    ..TierConfig::default()
                },
            )
            .unwrap();
        assert!(
            info.bit_identical_to_unbatched,
            "cap {cap} must stay under the packed-kernel boundary"
        );
        let rows = request_rows(n_requests, d, 900);
        let handles: Vec<_> = rows
            .into_iter()
            .map(|row| {
                let h = server.handle();
                std::thread::spawn(move || h.infer("t", &row).unwrap())
            })
            .collect();
        for (want, got) in expected.iter().zip(handles.into_iter().map(|t| t.join().unwrap())) {
            assert_eq!(&got, want, "cap {cap}: served row must be bit-exact");
        }
        server.shutdown();
    }
}

#[test]
fn sketched_tier_is_bit_identical_too() {
    // The compressed tier honors the same contract — the drop-in claim,
    // end to end through the server.
    let d = 10;
    let model = sketched(43, d, 5);
    let rows = request_rows(8, d, 1700);
    let expected: Vec<Vec<f32>> = rows.iter().map(|r| solo_forward(&model, r)).collect();
    let mut server = ModelServer::new();
    server
        .register_tier(
            "sk",
            model,
            d,
            TierConfig {
                max_batch: 4,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let h = server.handle();
    for (row, want) in rows.iter().zip(&expected) {
        assert_eq!(&h.infer("sk", row).unwrap(), want);
    }
}

#[test]
fn concurrent_client_stress_all_replies_correct_and_accounted() {
    // N client threads × M requests each, two tiers competing for the
    // same GEMM pool: every reply correct (bitwise vs the oracle), every
    // request accounted exactly once in the tier metrics.
    let d = 16;
    let (n_threads, m_requests) = (8usize, 25usize);
    let dense = mlp(44, d, 6);
    let sk = sketched(44, d, 6);
    let mut server = ModelServer::new();
    let cfg = TierConfig {
        max_batch: 4,
        max_wait: Duration::from_micros(500),
        queue_cap: 512,
        workers: 3,
        ..TierConfig::default()
    };
    server.register_tier("dense", dense, d, cfg.clone()).unwrap();
    server.register_tier("sketched", sk, d, cfg).unwrap();
    // Oracles, recomputed on fresh copies (the server owns its models).
    let oracle_dense = Arc::new(mlp(44, d, 6));
    let oracle_sk = Arc::new(sketched(44, d, 6));
    let handles: Vec<_> = (0..n_threads)
        .map(|t| {
            let h = server.handle();
            let (od, os) = (Arc::clone(&oracle_dense), Arc::clone(&oracle_sk));
            std::thread::spawn(move || {
                for i in 0..m_requests {
                    let seed = 3000 + (t * m_requests + i) as u64;
                    let row = Mat::randn(1, d, &mut Philox::seeded(seed)).into_vec();
                    let (tier, oracle) = if (t + i) % 2 == 0 {
                        ("dense", &od)
                    } else {
                        ("sketched", &os)
                    };
                    let got = h.infer(tier, &row).unwrap();
                    assert_eq!(got, solo_forward(oracle, &row), "tier {tier} seed {seed}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let metrics = server.metrics();
    let total = n_threads as u64 * m_requests as u64;
    assert_eq!(metrics.total_requests(), total, "every request accounted");
    for tier in ["dense", "sketched"] {
        let tm = metrics.tier(tier).unwrap();
        assert_eq!(tm.requests(), total / 2);
        assert_eq!(tm.errors(), 0);
        assert_eq!(tm.rejected(), 0);
        assert_eq!(tm.queue_depth(), 0, "queue fully drained");
        // Histogram buckets sum to the batch count.
        let hist = tm.occupancy_buckets();
        assert_eq!(hist.iter().sum::<u64>(), tm.batches());
        assert!(tm.latency_p50() <= tm.latency_p99());
        assert!(tm.latency_p99() > Duration::ZERO);
    }
}

#[test]
fn padding_rows_never_leak_into_real_rows() {
    // A single request in a cap-4 batch rides with three all-zero padding
    // rows whose outputs are nonzero (bias ≠ 0) — the reply must still be
    // the solo result, and the batch must be recorded at occupancy 1.
    let d = 10;
    let model = mlp(45, d, 5);
    let row = request_rows(1, d, 2500).pop().unwrap();
    let want = solo_forward(&model, &row);
    // Guard: padding rows really do produce nonzero outputs (if they were
    // zero, this test could not detect a leak).
    let zeros = vec![0.0; d];
    let pad_out = solo_forward(&model, &zeros);
    assert!(pad_out.iter().any(|&v| v != 0.0), "bias must move padding");
    let mut server = ModelServer::new();
    server
        .register_tier(
            "t",
            model,
            d,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(100),
                workers: 1,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let got = server.handle().infer("t", &row).unwrap();
    assert_eq!(got, want);
    let tm = server.metrics().tier("t").unwrap();
    assert_eq!(tm.batches(), 1);
    assert_eq!(tm.occupancy_buckets()[0], 1, "solo request → occupancy 1");
}

#[test]
fn shutdown_drains_queued_requests_then_rejects() {
    // Queue 12 requests asynchronously, shut down immediately: every
    // already-admitted request still gets a correct answer (drain), and
    // submissions after shutdown get the typed error.
    let d = 10;
    let model = mlp(46, d, 5);
    let rows = request_rows(12, d, 4000);
    let expected: Vec<Vec<f32>> = rows.iter().map(|r| solo_forward(&model, r)).collect();
    let mut server = ModelServer::new();
    server
        .register_tier(
            "t",
            model,
            d,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_cap: 64,
                workers: 1,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let h = server.handle();
    let pending: Vec<_> = rows
        .iter()
        .map(|row| h.submit("t", row).unwrap())
        .collect();
    server.shutdown(); // blocks until workers drained + joined
    for (p, want) in pending.into_iter().zip(&expected) {
        assert_eq!(&p.wait().unwrap(), want, "queued request answered on drain");
    }
    assert_eq!(h.infer("t", &rows[0]), Err(ServeError::ShuttingDown));
    assert_eq!(
        h.try_infer("t", &rows[0]),
        Err(ServeError::ShuttingDown)
    );
}

#[test]
fn worker_panic_is_contained_and_tier_keeps_serving() {
    // A forward that panics on a poisoned input: the batch's caller gets
    // a typed Exec error (not a hang), and the worker survives to serve
    // later requests — the same containment policy as the GEMM pool.
    struct Trap;
    impl panther::nn::Module for Trap {
        fn type_name(&self) -> &'static str {
            "Trap"
        }
        fn forward(&self, x: &Mat, _ctx: &ForwardCtx) -> panther::Result<Mat> {
            if x.data().iter().any(|&v| v == 666.0) {
                panic!("trap sprung");
            }
            Ok(x.clone())
        }
        fn params(&self) -> Vec<(String, panther::nn::ParamRef<'_>)> {
            Vec::new()
        }
        fn params_mut(&mut self) -> Vec<(String, panther::nn::ParamMut<'_>)> {
            Vec::new()
        }
        fn boxed_clone(&self) -> Box<dyn panther::nn::Module> {
            Box::new(Trap)
        }
    }
    let mut m = Model::new();
    m.add("trap", Trap).unwrap();
    let mut server = ModelServer::new();
    server
        .register_tier(
            "t",
            m,
            4,
            TierConfig {
                max_batch: 1,
                workers: 1,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let h = server.handle();
    assert_eq!(
        h.infer("t", &[1.0, 2.0, 3.0, 4.0]).unwrap(),
        vec![1.0, 2.0, 3.0, 4.0]
    );
    let err = h.infer("t", &[666.0, 0.0, 0.0, 0.0]).unwrap_err();
    assert!(matches!(err, ServeError::Exec(_)), "{err}");
    // The worker survived the panic and keeps serving.
    assert_eq!(
        h.infer("t", &[5.0, 0.0, 0.0, 1.0]).unwrap(),
        vec![5.0, 0.0, 0.0, 1.0]
    );
    let tm = server.metrics().tier("t").unwrap();
    assert_eq!(tm.errors(), 1);
    assert_eq!(tm.requests(), 3);
}

#[test]
fn panicking_model_leaves_server_serving_and_shutdown_still_drains() {
    // The multi-worker escalation of the containment test above: a model
    // that panics on a poisoned input, served by several workers. One
    // panicking batch must (a) answer its own caller with a typed Exec
    // error, (b) leave every worker serving the remaining traffic, and
    // (c) not poison the drain — a shutdown issued with requests still
    // queued behind the panic answers all of them.
    struct Trap;
    impl panther::nn::Module for Trap {
        fn type_name(&self) -> &'static str {
            "Trap"
        }
        fn forward(&self, x: &Mat, _ctx: &ForwardCtx) -> panther::Result<Mat> {
            if x.data().iter().any(|&v| v == 666.0) {
                panic!("trap sprung");
            }
            Ok(x.clone())
        }
        fn params(&self) -> Vec<(String, panther::nn::ParamRef<'_>)> {
            Vec::new()
        }
        fn params_mut(&mut self) -> Vec<(String, panther::nn::ParamMut<'_>)> {
            Vec::new()
        }
        fn boxed_clone(&self) -> Box<dyn panther::nn::Module> {
            Box::new(Trap)
        }
    }
    let mut m = Model::new();
    m.add("trap", Trap).unwrap();
    let mut server = ModelServer::new();
    server
        .register_tier(
            "t",
            m,
            4,
            TierConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(100),
                queue_cap: 128,
                workers: 3,
                ..TierConfig::default()
            },
        )
        .unwrap();
    // Spring the trap on every worker at least once (3 workers, 6 bombs
    // racing across them), with healthy traffic interleaved.
    let bombs: Vec<_> = (0..6)
        .map(|_| {
            let h = server.handle();
            std::thread::spawn(move || h.infer("t", &[666.0, 0.0, 0.0, 0.0]).unwrap_err())
        })
        .collect();
    let healthy: Vec<_> = (0..12)
        .map(|i| {
            let h = server.handle();
            std::thread::spawn(move || h.infer("t", &[i as f32, 1.0, 2.0, 3.0]).unwrap())
        })
        .collect();
    for b in bombs {
        let err = b.join().unwrap();
        assert!(matches!(err, ServeError::Exec(_)), "{err}");
    }
    for (i, t) in healthy.into_iter().enumerate() {
        assert_eq!(t.join().unwrap(), vec![i as f32, 1.0, 2.0, 3.0]);
    }
    // Queue a final wave (healthy rows behind one more bomb) and shut
    // down immediately: the drain must answer every single one.
    let h = server.handle();
    let last_bomb = h.submit("t", &[666.0, 0.0, 0.0, 0.0]).unwrap();
    let queued: Vec<_> = (0..8)
        .map(|i| (i, h.submit("t", &[i as f32, 5.0, 6.0, 7.0]).unwrap()))
        .collect();
    server.shutdown();
    assert!(matches!(last_bomb.wait(), Err(ServeError::Exec(_))));
    for (i, p) in queued {
        assert_eq!(p.wait().unwrap(), vec![i as f32, 5.0, 6.0, 7.0]);
    }
    let tm = server.metrics().tier("t").unwrap();
    assert_eq!(tm.requests(), 6 + 12 + 1 + 8);
    assert_eq!(tm.errors(), 7, "each bomb errored exactly once");
    assert_eq!(tm.queue_depth(), 0, "nothing left behind after the drain");
}

#[test]
fn row_coupled_models_are_rejected_at_registration() {
    use panther::nn::{AttnWeights, MultiHeadAttention};
    let mut rng = Philox::seeded(47);
    let mut m = Model::new();
    m.add(
        "attn",
        MultiHeadAttention::new(AttnWeights::random(16, 4, &mut rng)),
    )
    .unwrap();
    let mut server = ModelServer::new();
    let err = server
        .register_tier("attn", m, 16, TierConfig::default())
        .unwrap_err();
    assert!(matches!(err, ServeError::RowCoupled(_)), "{err}");
}

#[test]
fn tier_head_group_knob_is_applied_and_results_unchanged() {
    use panther::nn::{AttnWeights, MultiHeadAttention};
    // An attention model served at cap 1 (each request is a whole
    // single-row "sequence"): the tier's head_group knob must lower the
    // probe-measured peak without changing results.
    let d = 32;
    let mut rng = Philox::seeded(48);
    let w = AttnWeights::random(d, 8, &mut rng);
    let build = || {
        let mut m = Model::new();
        m.add("attn", MultiHeadAttention::new(w.clone())).unwrap();
        m
    };
    let base_cfg = TierConfig {
        max_batch: 1,
        workers: 1,
        ..TierConfig::default()
    };
    let mut server = ModelServer::new();
    let info_full = server
        .register_tier("full", build(), d, base_cfg.clone())
        .unwrap();
    let info_chunked = server
        .register_tier(
            "chunked",
            build(),
            d,
            TierConfig {
                head_group: Some(1),
                ..base_cfg
            },
        )
        .unwrap();
    assert!(
        info_chunked.peak_batch_bytes < info_full.peak_batch_bytes,
        "head grouping must shrink the probed peak ({} vs {})",
        info_chunked.peak_batch_bytes,
        info_full.peak_batch_bytes
    );
    let row = request_rows(1, d, 5000).pop().unwrap();
    let h = server.handle();
    assert_eq!(
        h.infer("full", &row).unwrap(),
        h.infer("chunked", &row).unwrap(),
        "chunking is bitwise invisible"
    );
}

/// An attention stack for sequence tiers, at dims small enough that GEMM
/// stays on the unpacked kernel path — the precondition for the bitwise
/// continuous-batching oracle.
fn attn_stack(seed: u64) -> Model {
    use panther::nn::{AttnWeights, MultiHeadAttention};
    let mut rng = Philox::seeded(seed);
    let mut m = Model::new();
    m.add("attn", MultiHeadAttention::new(AttnWeights::random(8, 2, &mut rng)))
        .unwrap();
    let mut head = Linear::random(8, 4, &mut rng);
    for b in head.bias.iter_mut() {
        *b = 0.25;
    }
    m.add("head", head).unwrap();
    m
}

/// The sequence oracle: the standalone masked forward of one sequence.
fn solo_seq_forward(model: &Model, x: &Mat) -> Mat {
    use panther::nn::SeqBatch;
    model
        .forward_seq(x, &SeqBatch::single(x.rows()), &ForwardCtx::new())
        .unwrap()
}

#[test]
fn continuous_batcher_is_bitwise_invariant_to_arrival_interleaving() {
    use panther::serve::SeqTierConfig;
    // Six sequences of mixed lengths served through a single-worker
    // continuous batcher with a 12-token step budget: across three
    // different submission orders (and therefore different step
    // compositions — [3,5,2] packs differently from [6,1,4]...), every
    // sequence's reply must equal its standalone masked forward bit for
    // bit. Attention makes rows *within* a sequence couple, so any
    // cross-sequence leak in the packed step would be loud.
    let lens = [3usize, 5, 2, 4, 6, 1];
    let seqs: Vec<Mat> = lens
        .iter()
        .enumerate()
        .map(|(i, &l)| Mat::randn(l, 8, &mut Philox::seeded(6000 + i as u64)).scale(0.5))
        .collect();
    let oracle_model = attn_stack(71);
    let expected: Vec<Mat> = seqs.iter().map(|x| solo_seq_forward(&oracle_model, x)).collect();
    let orders: [&[usize]; 3] = [&[0, 1, 2, 3, 4, 5], &[5, 4, 3, 2, 1, 0], &[2, 5, 0, 4, 1, 3]];
    for order in orders {
        let mut server = ModelServer::new();
        server
            .register_seq_tier(
                "seq",
                attn_stack(71),
                8,
                SeqTierConfig {
                    max_tokens: 12,
                    max_wait: Duration::from_millis(2),
                    workers: 1,
                    ..SeqTierConfig::default()
                },
            )
            .unwrap();
        let pending: Vec<_> = order
            .iter()
            .map(|&i| (i, server.handle().submit_seq("seq", &seqs[i]).unwrap()))
            .collect();
        for (i, p) in pending {
            let got = p.wait().unwrap();
            assert_eq!(got.shape(), expected[i].shape());
            assert_eq!(
                got.data(),
                expected[i].data(),
                "order {order:?}: sequence {i} diverged from its standalone forward"
            );
        }
        // Token accounting: every valid token executed exactly once.
        let tm = server.metrics().tier("seq").unwrap();
        assert_eq!(tm.tokens(), lens.iter().sum::<usize>() as u64);
        assert_eq!(tm.requests(), lens.len() as u64);
        server.shutdown();
    }
}

#[test]
fn performer_seq_tier_admits_longer_sequences_than_dense_under_same_budget() {
    use panther::nn::{AttnWeights, KernelKind, RandMultiHeadAttention};
    use panther::serve::SeqTierConfig;
    // The paper's linear-attention memory claim as admission capacity:
    // same projection weights, same byte budget — the dense tier's
    // quadratic score tensor caps its admitted length at ~√budget, the
    // Performer's linear feature state caps much later.
    let mut rng = Philox::seeded(73);
    let w = AttnWeights::random(8, 2, &mut rng);
    let dense = {
        use panther::nn::MultiHeadAttention;
        let mut m = Model::new();
        m.add("attn", MultiHeadAttention::new(w.clone())).unwrap();
        m
    };
    let performer = {
        let mut m = Model::new();
        m.add(
            "attn",
            RandMultiHeadAttention::new(w.clone(), 16, KernelKind::Softmax, 91),
        )
        .unwrap();
        m
    };
    let mut server = ModelServer::new();
    let cfg = SeqTierConfig {
        max_tokens: 100_000, // out of the way: the budget decides
        mem_budget: Some(200_000),
        probe_len: 32,
        workers: 1,
        ..SeqTierConfig::default()
    };
    let dense_info = server.register_seq_tier("dense", dense, 8, cfg.clone()).unwrap();
    let perf_info = server.register_seq_tier("perf", performer, 8, cfg).unwrap();
    assert!(dense_info.max_seq_len > 0);
    assert!(
        dense_info.max_seq_len < dense_info.max_tokens,
        "budget must actually pinch the dense tier"
    );
    assert!(
        perf_info.max_seq_len > dense_info.max_seq_len,
        "Performer must admit longer sequences than dense under the same \
         budget ({} vs {})",
        perf_info.max_seq_len,
        dense_info.max_seq_len
    );
    // The advertised cap is enforced, and an admitted length serves.
    let h = server.handle();
    let over = Mat::zeros(dense_info.max_seq_len + 1, 8);
    assert!(matches!(
        h.infer_seq("dense", &over),
        Err(ServeError::SeqTooLong { .. })
    ));
    let ok = Mat::randn(8, 8, &mut Philox::seeded(74)).scale(0.5);
    assert_eq!(h.infer_seq("dense", &ok).unwrap().rows(), 8);
    assert_eq!(h.infer_seq("perf", &ok).unwrap().rows(), 8);
}

#[test]
fn seq_tier_transforms_decode_per_token() {
    use panther::serve::{OutputTransform, SeqTierConfig};
    let x = Mat::randn(5, 8, &mut Philox::seeded(75)).scale(0.5);
    let raw = solo_seq_forward(&attn_stack(76), &x);
    let mut server = ModelServer::new();
    server
        .register_seq_tier(
            "soft",
            attn_stack(76),
            8,
            SeqTierConfig {
                transform: OutputTransform::Softmax,
                ..SeqTierConfig::default()
            },
        )
        .unwrap();
    server
        .register_seq_tier(
            "topk",
            attn_stack(76),
            8,
            SeqTierConfig {
                transform: OutputTransform::TopK(2),
                ..SeqTierConfig::default()
            },
        )
        .unwrap();
    assert_eq!(server.seq_tier_info("soft").unwrap().out_dim, 4);
    assert_eq!(server.seq_tier_info("topk").unwrap().out_dim, 4, "2·k");
    let h = server.handle();
    let soft = h.infer_seq("soft", &x).unwrap();
    assert_eq!(soft.shape(), (5, 4));
    for i in 0..5 {
        let s: f64 = soft.row(i).iter().map(|&v| v as f64).sum();
        assert!((s - 1.0).abs() < 1e-5, "token {i} softmax sums to {s}");
    }
    let topk = h.infer_seq("topk", &x).unwrap();
    assert_eq!(topk.shape(), (5, 4));
    for i in 0..5 {
        let r = topk.row(i);
        // Slot 0 carries the argmax of the raw logit row.
        let am = raw
            .row(i)
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        assert_eq!(r[0] as usize, am, "token {i} top-1 index");
        assert!(r[1] >= r[3], "token {i} logprobs must be descending");
    }
}

#[test]
fn sketched_tier_fits_more_workers_in_the_same_budget() {
    // The capacity story in one assert: at a fixed memory budget, the
    // compressed tier admits at least as many workers as the dense tier —
    // and strictly more when the budget pinches the dense one.
    let d = 64;
    let dense = mlp(49, d, 64);
    let sk = sketched(49, d, 64);
    let mut server = ModelServer::new();
    // Learn the dense footprint, then budget it down to ~1 worker.
    let free = server
        .register_tier("probe", mlp(49, d, 64), d, TierConfig::default())
        .unwrap();
    let budget = free.weight_bytes + 2 * free.peak_batch_bytes;
    let cfg = |b| TierConfig {
        workers: 8,
        mem_budget: Some(b),
        ..TierConfig::default()
    };
    let dense_info = server.register_tier("dense", dense, d, cfg(budget)).unwrap();
    let sk_info = server.register_tier("sk", sk, d, cfg(budget)).unwrap();
    assert!(
        sk_info.weight_bytes < dense_info.weight_bytes,
        "sketching must shrink the weights"
    );
    assert!(
        sk_info.workers > dense_info.workers,
        "smaller tier must admit more workers ({} vs {})",
        sk_info.workers,
        dense_info.workers
    );
}
