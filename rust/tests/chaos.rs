//! Chaos integration tests: seeded fault injection against the full
//! serve stack.
//!
//! The contract under test is the robustness tentpole's headline claim:
//! with a seeded [`FaultPlan`] installed, **every request still gets a
//! reply** — a success or a *typed* error, never a hang or a silent
//! drop — surviving replies are **bitwise identical** to the fault-free
//! run, and the fault counters come out exact because injection is
//! deterministic in (seed, tick).
//!
//! Three fault families, one test each:
//!
//! - worker kills → supervision respawns, re-queued batches lose nothing;
//! - poison inputs → bisection quarantines the culprit, replays the rest;
//! - NaN outputs → the numeric guard converts them to typed errors and
//!   the measured-quality gauge routes the cascade around the sick tier.

use panther::linalg::Mat;
use panther::nn::{Activation, ForwardCtx, Linear, Model};
use panther::rng::Philox;
use panther::serve::{Cascade, FaultPlan, ModelServer, ServeError, Slo, TierConfig};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Nonlinear row-independent stack with nonzero biases (padding leaks
/// would show in the bitwise oracle).
fn mlp(seed: u64, d_in: usize, d_out: usize) -> Model {
    let mut rng = Philox::seeded(seed);
    let mut m = Model::new();
    let mut fc1 = Linear::random(d_in, 12, &mut rng);
    for b in fc1.bias.iter_mut() {
        *b = 0.3;
    }
    m.add("fc1", fc1).unwrap();
    m.add("act", Activation::gelu()).unwrap();
    let mut fc2 = Linear::random(12, d_out, &mut rng);
    for b in fc2.bias.iter_mut() {
        *b = -0.2;
    }
    m.add("fc2", fc2).unwrap();
    m
}

fn request_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| Mat::randn(1, d, &mut Philox::seeded(seed + i as u64)).into_vec())
        .collect()
}

/// The fault-free oracle: the unbatched single-row forward.
fn solo_forward(model: &Model, row: &[f32]) -> Vec<f32> {
    let ctx = ForwardCtx::new();
    model
        .forward(&Mat::from_vec(1, row.len(), row.to_vec()), &ctx)
        .unwrap()
        .row(0)
        .to_vec()
}

/// Poll `ok` until it holds or `deadline` passes; returns the final
/// reading. Used only for counters that settle asynchronously (the
/// supervisor notices a death on its own cadence).
fn poll_until(deadline: Duration, mut ok: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    while start.elapsed() < deadline {
        if ok() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    ok()
}

#[test]
fn seeded_kills_lose_no_request_and_respawn_exactly() {
    // Kill ticks 1 and 3 are pinned: exactly two workers die mid-run, no
    // matter how the racing pool interleaves ticks. Every killed batch is
    // re-queued before the panic, so all 12 requests must come back — and
    // because padded batching is composition-invariant (cap 4 < the GEMM
    // microkernel height), every reply must be bitwise the fault-free
    // single-row forward.
    let d = 10;
    let model = mlp(42, d, 5);
    let rows = request_rows(12, d, 900);
    let expected: Vec<Vec<f32>> = rows.iter().map(|r| solo_forward(&model, r)).collect();
    let plan = Arc::new(FaultPlan::seeded(7).kill_at(&[1, 3]));
    let mut server = ModelServer::new();
    server
        .register_tier(
            "t",
            model,
            d,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                workers: 2,
                faults: Some(Arc::clone(&plan)),
                ..TierConfig::default()
            },
        )
        .unwrap();
    let h = server.handle();
    let pending: Vec<_> = rows.iter().map(|r| h.submit("t", r).unwrap()).collect();
    for (want, p) in expected.iter().zip(pending) {
        assert_eq!(&p.wait().unwrap(), want, "survivor replies must be bitwise fault-free");
    }
    let tm = server.metrics().tier("t").unwrap();
    assert!(
        poll_until(Duration::from_secs(10), || tm.worker_restarts() == 2),
        "both killed workers must be respawned, got {}",
        tm.worker_restarts()
    );
    assert!(
        poll_until(Duration::from_secs(10), || tm.live_workers() == 2),
        "the pool must heal back to full strength, got {}",
        tm.live_workers()
    );
    assert_eq!(tm.errors(), 0, "a kill is invisible to clients");
    assert_eq!(tm.requests(), 12, "every request terminally accounted once");
    // 12 requests in ≥ 3 batches plus 2 re-shipped killed batches.
    assert!(plan.ticks() >= 5, "ticks {}", plan.ticks());
    server.shutdown();
    assert_eq!(tm.worker_restarts(), 2, "drain must not spawn extra workers");
}

/// Panics whenever any input value equals the marker `666.0` —
/// indistinguishable from buggy model code tripping on one bad request.
struct Trap;

impl panther::nn::Module for Trap {
    fn type_name(&self) -> &'static str {
        "Trap"
    }
    fn forward(&self, x: &Mat, _ctx: &ForwardCtx) -> panther::Result<Mat> {
        if x.data().iter().any(|&v| v == 666.0) {
            panic!("trap sprung");
        }
        Ok(x.clone())
    }
    fn params(&self) -> Vec<(String, panther::nn::ParamRef<'_>)> {
        Vec::new()
    }
    fn params_mut(&mut self) -> Vec<(String, panther::nn::ParamMut<'_>)> {
        Vec::new()
    }
    fn boxed_clone(&self) -> Box<dyn panther::nn::Module> {
        Box::new(Trap)
    }
}

#[test]
fn poison_input_is_quarantined_and_batchmates_replayed_bitwise() {
    // One poison row among 31 innocents. Whatever batch composition the
    // worker ships, bisection must corner the poison row alone, strike it
    // out (two solo panics at strikes = 2), and answer it with the typed
    // PoisonedInput — while every innocent is replayed and answered with
    // its exact forward (Trap is the identity on clean rows).
    let d = 4;
    let mut m = Model::new();
    m.add("trap", Trap).unwrap();
    let mut server = ModelServer::new();
    server
        .register_tier(
            "t",
            m,
            d,
            TierConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(50),
                workers: 1,
                quarantine_strikes: 2,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let mut rows = request_rows(31, d, 2200);
    let poison_index = 13;
    rows.insert(poison_index, vec![1.0, 666.0, 3.0, 4.0]);
    let h = server.handle();
    let pending: Vec<_> = rows.iter().map(|r| h.submit("t", r).unwrap()).collect();
    for (i, (row, p)) in rows.iter().zip(pending).enumerate() {
        match p.wait() {
            Ok(got) => {
                assert_ne!(i, poison_index, "the poison row must not succeed");
                assert_eq!(&got, row, "innocent {i} must be replayed bitwise");
            }
            Err(ServeError::PoisonedInput) => {
                assert_eq!(i, poison_index, "only the poison row is quarantined");
            }
            Err(e) => panic!("request {i}: expected Ok or PoisonedInput, got {e}"),
        }
    }
    let tm = server.metrics().tier("t").unwrap();
    assert_eq!(tm.poisoned(), 1, "exactly one request struck out");
    assert_eq!(tm.errors(), 1, "the strike-out is the only client-visible error");
    assert_eq!(tm.requests(), 32, "every request terminally accounted once");
    server.shutdown();
}

#[test]
fn nan_outputs_are_typed_and_demote_the_tier_in_the_cascade() {
    // poison_at(&[1]) NaNs one row of the first shipped batch. The
    // numeric guard must convert it into a typed NonFiniteOutput (never a
    // NaN handed to a client), count the row, and ratchet the tier's
    // measured quality to ≤ 0.5 — below the healthy rung's 0.6, so the
    // next cascade submit prefers "healthy" as the best effective rung
    // (a re-ranking, not a shed).
    let d = 6;
    let sick_model = mlp(42, d, 3);
    let healthy_model = mlp(43, d, 3);
    let sick_oracle = mlp(42, d, 3);
    let healthy_oracle = mlp(43, d, 3);
    let mut server = ModelServer::new();
    server
        .register_tier(
            "sick",
            sick_model,
            d,
            TierConfig {
                max_batch: 2,
                max_wait: Duration::from_millis(20),
                workers: 1,
                faults: Some(Arc::new(FaultPlan::seeded(11).poison_at(&[1]))),
                numeric_guard: true,
                ..TierConfig::default()
            },
        )
        .unwrap();
    server
        .register_tier(
            "healthy",
            healthy_model,
            d,
            TierConfig {
                max_batch: 2,
                workers: 1,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let rows = request_rows(2, d, 3100);
    let h = server.handle();
    let pending: Vec<_> = rows.iter().map(|r| h.submit("sick", r).unwrap()).collect();
    let mut oks = 0;
    let mut nonfinite = 0;
    for (row, p) in rows.iter().zip(pending) {
        match p.wait() {
            Ok(got) => {
                assert_eq!(got, solo_forward(&sick_oracle, row), "clean rows stay bitwise");
                oks += 1;
            }
            Err(ServeError::NonFiniteOutput) => nonfinite += 1,
            Err(e) => panic!("expected Ok or NonFiniteOutput, got {e}"),
        }
    }
    assert_eq!((oks, nonfinite), (1, 1), "one poisoned row, one clean row");
    let tm = server.metrics().tier("sick").unwrap();
    assert_eq!(tm.nonfinite_rows(), 1);
    assert_eq!(tm.errors(), 1);
    let q = tm.measured_quality().expect("the guard must seed the quality gauge");
    assert!(q <= 0.5, "a poisoned batch of ≤ 2 rows degrades to ≤ 0.5, got {q}");
    // The cascade now ranks the degraded tier below the healthy one.
    let cascade = Cascade::new(&server, &[("sick", 1.0), ("healthy", 0.6)]).unwrap();
    assert_eq!(cascade.qualities()[0].0, "healthy", "evidence outranks the static label");
    let routed = cascade.submit(&rows[0], &Slo::new(Duration::MAX)).unwrap();
    assert_eq!(routed.tier, "healthy", "routing must avoid the sick tier");
    assert!(!routed.shed, "picking the best effective rung is not a shed");
    assert_eq!(routed.wait().unwrap(), solo_forward(&healthy_oracle, &rows[0]));
    server.shutdown();
}
