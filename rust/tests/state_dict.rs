//! Serialization tests for the unified layer API: bit-exact
//! `state_dict`/`load_state_dict` round-trips across all six layer types,
//! checkpoint v2 round-trips, and legacy v1 checkpoint migration.

use panther::linalg::Mat;
use panther::nn::attention::{AttnWeights, KernelKind, MultiHeadAttention, RandMultiHeadAttention};
use panther::nn::{Conv2d, ConvShape, ForwardCtx, Linear, Model, Module, SKConv2d, SKLinear};
use panther::rng::Philox;
use panther::runtime::HostTensor;
use panther::train::{checkpoint, ModelState};
use panther::util::prop::prop_check;

/// Round-trip `layer` through its state dict into a zeroed clone and
/// require bit-exact parameters AND a bit-exact forward (the latter
/// catches stale derived state like `SKLinear`'s cached transposes).
fn assert_bit_exact_roundtrip(layer: &dyn Module, x: &Mat) {
    let sd = layer.state_dict();
    let mut fresh = layer.boxed_clone();
    for (_, mut p) in fresh.params_mut() {
        p.data_mut().fill(0.0);
    }
    fresh.load_state_dict(&sd).unwrap();
    let sd2 = fresh.state_dict();
    assert_eq!(sd, sd2, "{}: state dict not bit-exact", layer.type_name());
    let ctx = ForwardCtx::new();
    let ya = layer.forward(x, &ctx).unwrap();
    let yb = fresh.forward(x, &ctx).unwrap();
    assert_eq!(
        ya.data(),
        yb.data(),
        "{}: forward differs after load_state_dict",
        layer.type_name()
    );
}

#[test]
fn state_dict_roundtrip_all_six_layer_types() {
    let mut rng = Philox::seeded(901);
    let x_lin = Mat::randn(4, 12, &mut rng);
    assert_bit_exact_roundtrip(&Linear::random(12, 8, &mut rng), &x_lin);
    assert_bit_exact_roundtrip(&SKLinear::random(12, 8, 2, 3, &mut rng), &x_lin);

    let shape = ConvShape {
        c_in: 2,
        c_out: 3,
        kernel: 3,
        image: 6,
        padding: 1,
    };
    let x_img = Mat::randn(2, 2 * 36, &mut rng);
    assert_bit_exact_roundtrip(&Conv2d::random(shape, &mut rng), &x_img);
    assert_bit_exact_roundtrip(&SKConv2d::random(shape, 2, 4, &mut rng), &x_img);

    let x_tok = Mat::randn(6, 16, &mut rng);
    assert_bit_exact_roundtrip(
        &MultiHeadAttention::new(AttnWeights::random(16, 4, &mut rng)),
        &x_tok,
    );
    assert_bit_exact_roundtrip(
        &RandMultiHeadAttention::new(
            AttnWeights::random(16, 4, &mut rng),
            8,
            KernelKind::Softmax,
            3,
        ),
        &x_tok,
    );
}

#[test]
fn state_dict_roundtrip_property() {
    prop_check("state-dict-roundtrip", 15, |g| {
        let d_in = 1 + g.usize(0..16);
        let d_out = 1 + g.usize(0..16);
        let l = 1 + g.usize(0..3);
        let k = 1 + g.usize(0..5);
        let b = 1 + g.usize(0..4);
        let x = Mat::randn(b, d_in, g.rng());
        let sk = SKLinear::random(d_in, d_out, l, k, g.rng());
        assert_bit_exact_roundtrip(&sk, &x);
        let lin = Linear::random(d_in, d_out, g.rng());
        assert_bit_exact_roundtrip(&lin, &x);
    });
}

#[test]
fn model_state_dict_survives_sketch_plan() {
    // A sketched model's state dict round-trips through a freshly sketched
    // clone of the same architecture.
    let mut rng = Philox::seeded(902);
    let mut m = Model::new();
    m.add("ffn.fc1", Linear::random(24, 24, &mut rng)).unwrap();
    m.add("ffn.fc2", Linear::random(24, 24, &mut rng)).unwrap();
    m.sketchify("ffn.fc1", 1, 4, 7).unwrap();
    let sd = m.state_dict();
    let mut m2 = m.clone_model();
    // Perturb, then restore.
    for l in ["ffn.fc1", "ffn.fc2"] {
        let module = m2.get_mut(l).unwrap();
        for (_, mut p) in module.params_mut() {
            p.data_mut().fill(0.5);
        }
        module.on_params_loaded();
    }
    assert_ne!(m2.state_dict(), sd);
    m2.load_state_dict(&sd).unwrap();
    assert_eq!(m2.state_dict(), sd);
}

fn toy_state() -> ModelState {
    let mut rng = Philox::seeded(903);
    let params = vec![
        HostTensor::randn(&[3, 4], 1.0, &mut rng),
        HostTensor::randn(&[5], 0.5, &mut rng),
    ];
    let m = params
        .iter()
        .map(|t| HostTensor::randn(t.shape(), 0.1, &mut rng))
        .collect();
    let v = params
        .iter()
        .map(|t| HostTensor::randn(t.shape(), 0.01, &mut rng))
        .collect();
    ModelState {
        model: "toy".into(),
        names: vec!["encoder.w".into(), "head.bias".into()],
        params,
        m,
        v,
        step: 7,
    }
}

#[test]
fn checkpoint_v2_roundtrip_is_bit_exact() {
    let state = toy_state();
    let dir = std::env::temp_dir().join("panther_state_dict_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("v2.ckpt");
    checkpoint::save(&state, &path).unwrap();
    let back = checkpoint::load(&path).unwrap();
    assert_eq!(back.model, state.model);
    assert_eq!(back.step, state.step);
    assert_eq!(back.names, state.names);
    assert_eq!(back.params, state.params);
    assert_eq!(back.m, state.m);
    assert_eq!(back.v, state.v);
    // The name-keyed view matches too.
    assert_eq!(back.state_dict(), state.state_dict());
    std::fs::remove_file(path).ok();
}

/// Hand-craft a v1 blob (the legacy positional format: three groups of
/// shape-prefixed tensors, no names) and verify the tensors land under the
/// synthesized positional names with exact values.
#[test]
fn checkpoint_v1_files_still_load() {
    let t0: Vec<f32> = vec![1.5, -2.25, 3.0, 0.125, 7.5, -0.5]; // shape [2,3]
    let t1: Vec<f32> = vec![9.75]; // shape [1]
    let mut blob: Vec<u8> = Vec::new();
    blob.extend_from_slice(b"PNTH");
    blob.extend_from_slice(&1u32.to_le_bytes()); // version 1
    blob.extend_from_slice(&11u64.to_le_bytes()); // step
    let name = b"legacy_model";
    blob.extend_from_slice(&(name.len() as u32).to_le_bytes());
    blob.extend_from_slice(name);
    blob.extend_from_slice(&2u32.to_le_bytes()); // n_params
    for group_scale in [1.0f32, 2.0, 3.0] {
        // params, then m, then v — same shapes, distinguishable data.
        blob.extend_from_slice(&2u32.to_le_bytes()); // rank
        blob.extend_from_slice(&2u64.to_le_bytes());
        blob.extend_from_slice(&3u64.to_le_bytes());
        for x in &t0 {
            blob.extend_from_slice(&(x * group_scale).to_le_bytes());
        }
        blob.extend_from_slice(&1u32.to_le_bytes()); // rank
        blob.extend_from_slice(&1u64.to_le_bytes());
        for x in &t1 {
            blob.extend_from_slice(&(x * group_scale).to_le_bytes());
        }
    }
    let dir = std::env::temp_dir().join("panther_state_dict_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("v1.ckpt");
    std::fs::write(&path, &blob).unwrap();

    let state = checkpoint::load(&path).unwrap();
    assert_eq!(state.model, "legacy_model");
    assert_eq!(state.step, 11);
    assert_eq!(state.names, vec!["param.0", "param.1"]);
    assert_eq!(state.params[0], HostTensor::new(&[2, 3], t0.clone()));
    assert_eq!(state.params[1], HostTensor::new(&[1], t1.clone()));
    let scaled = |xs: &[f32], s: f32| -> Vec<f32> { xs.iter().map(|x| x * s).collect() };
    assert_eq!(state.m[0], HostTensor::new(&[2, 3], scaled(&t0, 2.0)));
    assert_eq!(state.v[0], HostTensor::new(&[2, 3], scaled(&t0, 3.0)));
    assert_eq!(state.m[1], HostTensor::new(&[1], scaled(&t1, 2.0)));
    assert_eq!(state.v[1], HostTensor::new(&[1], scaled(&t1, 3.0)));
    assert_eq!(state.param_named("param.1"), Some(&state.params[1]));

    // Re-saving upgrades the file to v2 losslessly.
    let path2 = dir.join("v1_resaved.ckpt");
    checkpoint::save(&state, &path2).unwrap();
    let back = checkpoint::load(&path2).unwrap();
    assert_eq!(back.names, state.names);
    assert_eq!(back.params, state.params);
    std::fs::remove_file(path).ok();
    std::fs::remove_file(path2).ok();
}
