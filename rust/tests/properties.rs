//! Property tests over the public API: sketch-operator unbiasedness
//! (`E‖Sx‖² ≈ ‖x‖²` averaged over seeds) and GEMM-variant cross-checks
//! against a naive triple-loop oracle, including the small-matrix
//! (`m < 8`) dispatch path of `matmul`.

use panther::linalg::{matmul, matmul_nt, matmul_tn, rel_error, Mat};
use panther::sketch::{CountSketch, GaussianSketch, Sketch, SparseSignSketch, SrhtSketch};
use panther::util::prop::prop_check;

/// Naive `O(mnk)` triple loop with f64 accumulation — the oracle.
fn matmul_oracle(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows());
    let mut c = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut acc = 0f64;
            for p in 0..a.cols() {
                acc += a.get(i, p) as f64 * b.get(p, j) as f64;
            }
            c.set(i, j, acc as f32);
        }
    }
    c
}

#[test]
fn property_gemm_variants_match_oracle_random_shapes() {
    prop_check("gemm-oracle", 24, |g| {
        let m = 1 + g.usize(0..48);
        let k = 1 + g.usize(0..48);
        let n = 1 + g.usize(0..48);
        let a = Mat::randn(m, k, g.rng());
        let b = Mat::randn(k, n, g.rng());
        let oracle = matmul_oracle(&a, &b);
        assert!(
            rel_error(&matmul(&a, &b), &oracle) < 1e-5,
            "matmul ({m},{k},{n})"
        );
        assert!(
            rel_error(&matmul_tn(&a.transpose(), &b), &oracle) < 1e-5,
            "matmul_tn ({m},{k},{n})"
        );
        assert!(
            rel_error(&matmul_nt(&a, &b.transpose()), &oracle) < 1e-5,
            "matmul_nt ({m},{k},{n})"
        );
    });
}

#[test]
fn property_gemm_small_row_path_matches_oracle() {
    // `matmul` dispatches m < 8 to the direct blocked kernel rather than the
    // transpose+NT fast path — cover exactly that branch, with k·n large
    // enough that only the row count keeps it off the fast path.
    prop_check("gemm-small-m", 16, |g| {
        let m = 1 + g.usize(0..7); // m ∈ [1, 7]
        let k = 32 + g.usize(0..64);
        let n = 32 + g.usize(0..64);
        let a = Mat::randn(m, k, g.rng());
        let b = Mat::randn(k, n, g.rng());
        assert!(
            rel_error(&matmul(&a, &b), &matmul_oracle(&a, &b)) < 1e-5,
            "small-m matmul ({m},{k},{n})"
        );
    });
}

/// Squared column norm of column `j`.
fn col_norm2(a: &Mat, j: usize) -> f64 {
    (0..a.rows()).map(|i| (a.get(i, j) as f64).powi(2)).sum()
}

#[test]
fn property_sketches_preserve_norms_in_expectation() {
    // For each operator family: averaged over independent seeds,
    // E‖Sx‖² / ‖x‖² → 1. Concentration at these trial counts keeps the
    // ratio within a generous band; determinism in the property seed keeps
    // the test stable run-to-run.
    prop_check("sketch-unbiasedness", 3, |g| {
        let m = 24 + g.usize(0..40);
        let d = 12 + g.usize(0..8);
        let x = Mat::randn(m, 1, g.rng());
        let orig = col_norm2(&x, 0);
        let trials = 64u64;
        for family in 0..4usize {
            let mut acc = 0f64;
            for t in 0..trials {
                let seed = 7_000 + family as u64 * 101 + t;
                let op: Box<dyn Sketch> = match family {
                    0 => Box::new(GaussianSketch::new(m, d, seed)),
                    1 => Box::new(SparseSignSketch::new(m, d, 6, seed)),
                    2 => Box::new(CountSketch::new(m, d, seed)),
                    _ => Box::new(SrhtSketch::new(m, d, seed)),
                };
                assert_eq!(op.input_dim(), m);
                assert_eq!(op.output_dim(), d);
                acc += col_norm2(&op.apply(&x), 0);
            }
            let ratio = acc / trials as f64 / orig.max(1e-30);
            assert!(
                (0.6..1.4).contains(&ratio),
                "family {family}: E‖Sx‖²/‖x‖² = {ratio} (m={m}, d={d})"
            );
        }
    });
}

#[test]
fn property_sketch_linearity() {
    // Sketches are linear maps: S(αx + y) = αSx + Sy for every operator.
    prop_check("sketch-linearity", 6, |g| {
        let m = 16 + g.usize(0..32);
        let d = 8;
        let alpha = g.f32(-2.0, 2.0);
        let x = Mat::randn(m, 2, g.rng());
        let y = Mat::randn(m, 2, g.rng());
        let ops: Vec<Box<dyn Sketch>> = vec![
            Box::new(GaussianSketch::new(m, d, 3)),
            Box::new(SparseSignSketch::new(m, d, 4, 3)),
            Box::new(CountSketch::new(m, d, 3)),
            Box::new(SrhtSketch::new(m, d, 3)),
        ];
        for op in &ops {
            let lhs = op.apply(&x.scale(alpha).add(&y));
            let rhs = op.apply(&x).scale(alpha).add(&op.apply(&y));
            assert!(
                rel_error(&lhs, &rhs) < 1e-3,
                "operator {}x{} not linear",
                op.output_dim(),
                op.input_dim()
            );
        }
    });
}
