//! Golden accuracy tests for the decomposition suite: `rsvd`, `cqrrpt`
//! and `pivoted_cholesky` on *seeded low-rank-plus-noise* matrices, with
//! reconstruction-error bounds asserted against the deterministic
//! Jacobi-SVD baseline (the Eckart–Young optimum) rather than loose
//! standalone thresholds. `tests/properties.rs` covers sketches and GEMM;
//! this file pins down the decomposition layer the same way.

use panther::decomp::{cqrrpt, pivoted_cholesky, rsvd, CqrrptOpts, RsvdOpts};
use panther::linalg::{fro_norm, matmul, matmul_tn, ortho_error, rel_error, svd_jacobi, Mat};
use panther::rng::Philox;

/// Seeded `rank`-dominant test matrix: `A = U·V + σ·noise`, the shape all
/// three golden tests share (an approximately low-rank matrix with a
/// controlled noise floor, the regime RandNLA methods target).
fn low_rank_plus_noise(m: usize, n: usize, rank: usize, sigma: f32, seed: u64) -> Mat {
    let mut rng = Philox::seeded(seed);
    let u = Mat::randn(m, rank, &mut rng);
    let v = Mat::randn(rank, n, &mut rng);
    let mut a = matmul(&u, &v);
    a.axpy(sigma, &Mat::randn(m, n, &mut rng));
    a
}

#[test]
fn golden_rsvd_tracks_optimal_rank_k_error() {
    let (m, n, r) = (80, 60, 8);
    let a = low_rank_plus_noise(m, n, r, 1e-3, 41);
    // Deterministic baseline: the optimal rank-r error (≈ the noise floor).
    let exact = svd_jacobi(&a);
    let opt_err = fro_norm(&a.sub(&exact.truncate(r).reconstruct()));
    let f = rsvd(
        &a,
        &RsvdOpts {
            rank: r,
            oversample: 8,
            power_iters: 2,
            seed: 7,
        },
    );
    let rand_err = fro_norm(&a.sub(&f.reconstruct()));
    // HMT: with oversampling + power iteration the randomized error sits
    // within a small constant of optimal on a decaying spectrum.
    assert!(
        rand_err <= opt_err * 2.0 + 1e-6,
        "rsvd err {rand_err} vs optimal {opt_err}"
    );
    // And in absolute terms it resolves the low-rank signal: relative
    // error at the noise floor, orders below the signal scale.
    assert!(
        rel_error(&f.reconstruct(), &a) < 1e-2,
        "rel {}",
        rel_error(&f.reconstruct(), &a)
    );
    assert!(ortho_error(&f.u) < 1e-3);
    assert!(ortho_error(&f.v) < 1e-3);
    // Leading singular values must match the deterministic baseline.
    for i in 0..r {
        let (got, want) = (f.s[i], exact.s[i]);
        assert!(
            (got - want).abs() < 0.02 * want.max(1.0),
            "σ_{i}: rsvd {got} vs jacobi {want}"
        );
    }
}

#[test]
fn golden_cqrrpt_full_factorization_matches_svd_scale() {
    let (m, n, r) = (200, 16, 6);
    let a = low_rank_plus_noise(m, n, r, 1e-3, 42);
    let f = cqrrpt(&a, &CqrrptOpts::default());
    assert!(!f.fallback, "well-conditioned input must not fall back");
    // Factorization bound: ‖A·P − Q·R‖/‖A‖ at f32 working accuracy. The
    // noise floor puts κ(A) ≈ 6e3, so allow ε·κ headroom (same bound the
    // property tests use on comparable inputs).
    let ap = a.permute_cols(&f.perm);
    let rec_err = rel_error(&matmul(&f.q, &f.r), &ap);
    assert!(rec_err < 1e-2, "reconstruction rel err {rec_err}");
    assert!(ortho_error(&f.q) < 1e-2, "ortho {}", ortho_error(&f.q));
    // The R factor's leading singular values are A's (QR preserves the
    // spectrum): compare the r dominant ones against the SVD baseline.
    let exact = svd_jacobi(&a);
    let r_svd = svd_jacobi(&f.r);
    for i in 0..r {
        let (got, want) = (r_svd.s[i], exact.s[i]);
        assert!(
            (got - want).abs() < 0.02 * want.max(1.0),
            "σ_{i}: R {got} vs A {want}"
        );
    }
}

#[test]
fn golden_cqrrpt_detects_numerical_rank_of_noisy_low_rank() {
    let (m, n, r) = (150, 12, 5);
    // Noise well below the rank tolerance: the sketch's pivoted QR must
    // report exactly the signal rank.
    let a = low_rank_plus_noise(m, n, r, 1e-6, 43);
    let f = cqrrpt(
        &a,
        &CqrrptOpts {
            rank_tol: 1e-4,
            ..Default::default()
        },
    );
    assert_eq!(f.rank, r, "detected rank {}", f.rank);
    // Q spans the signal range: projection residual at the noise floor.
    let ap = a.permute_cols(&f.perm);
    let proj = matmul(&f.q, &matmul_tn(&f.q, &ap));
    let resid = fro_norm(&ap.sub(&proj)) / fro_norm(&ap);
    assert!(resid < 1e-3, "range residual {resid}");
}

#[test]
fn golden_pivoted_cholesky_tracks_optimal_psd_error() {
    // PSD low-rank-plus-noise Gram matrix: G = BᵀB with B rank-dominant.
    let (n, r) = (40, 6);
    let b = low_rank_plus_noise(60, n, r, 1e-3, 44);
    let g = matmul_tn(&b, &b);
    let f = pivoted_cholesky(&g, r, 0.0);
    assert_eq!(f.l.cols(), r);
    let rec_err = fro_norm(&g.sub(&matmul(&f.l, &f.l.transpose())));
    // Baseline: the optimal rank-r PSD error from the eigendecomposition
    // (= truncated SVD of the symmetric G). Greedy diagonal pivoting is
    // near-optimal on strongly decaying spectra — allow a modest factor.
    let exact = svd_jacobi(&g);
    let opt_err = fro_norm(&g.sub(&exact.truncate(r).reconstruct()));
    assert!(
        rec_err <= opt_err * 4.0 + 1e-4 * fro_norm(&g),
        "pivchol err {rec_err} vs optimal {opt_err}"
    );
    // Sanity on the diagnostic: trace residuals decrease monotonically.
    for w in f.residuals.windows(2) {
        assert!(w[1] <= w[0] * 1.001);
    }
}
