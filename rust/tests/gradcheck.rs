//! Finite-difference gradient checks for the native backward pass.
//!
//! One reusable central-difference harness runs over all six layer types
//! and a small stacked `Model` (including a `SketchPlan`-compressed one):
//! for a scalar loss `L = Σ w ⊙ y` with fixed random weights `w`, every
//! named parameter and the input are perturbed ±ε and the measured slope
//! is compared against the analytic gradient from `Module::backward`, in
//! f32-appropriate norms (full-vector relative error, f64 loss
//! accumulation). Also locks down the acceptance criterion: a compressed
//! model's training loss decreases over 20 `Trainer` steps.

use panther::linalg::Mat;
use panther::nn::{
    Activation, AttnWeights, Conv2d, ConvShape, ForwardCtx, KernelKind, LayerSelector, Linear,
    Model, Module, MultiHeadAttention, RandMultiHeadAttention, SKConv2d, SKLinear, SketchPlan,
};
use panther::rng::Philox;
use panther::train::{Adam, Trainer};

/// `L = Σ_ij w_ij·y_ij`, accumulated in f64 so the finite-difference
/// quotient isn't drowned by summation noise.
fn weighted_loss(y: &Mat, w: &Mat) -> f64 {
    assert_eq!(y.shape(), w.shape());
    y.data()
        .iter()
        .zip(w.data())
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

/// Relative error between two gradient vectors in the full-vector 2-norm:
/// `‖a − b‖ / max(‖a‖, ‖b‖, tiny)`. Element-wise comparison would let the
/// f32 noise floor on near-zero entries dominate; the vector norm weighs
/// each entry by its actual contribution.
fn vec_rel_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut d2 = 0f64;
    let mut na = 0f64;
    let mut nb = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        d2 += (x as f64 - y as f64).powi(2);
        na += (x as f64).powi(2);
        nb += (y as f64).powi(2);
    }
    d2.sqrt() / na.sqrt().max(nb.sqrt()).max(1e-8)
}

const EPS: f32 = 1e-2;
const TOL: f64 = 1e-3;

/// Add `delta` to element `i` of parameter `name`, refreshing derived
/// state (the same contract every `params_mut` writer follows).
fn nudge(module: &mut dyn Module, name: &str, i: usize, delta: f32) {
    for (pn, mut p) in module.params_mut() {
        if pn == name {
            p.data_mut()[i] += delta;
        }
    }
    module.on_params_loaded();
}

/// The central harness: check `module`'s analytic gradients (every named
/// parameter, and the input) against central finite differences of the
/// weighted-sum loss at `x`, to relative tolerance `tol`.
fn gradcheck_tol(module: &mut dyn Module, x: &Mat, seed: u64, tol: f64) {
    let ctx = ForwardCtx::new();
    let (y, cache) = module
        .forward_train(x, &ctx)
        .expect("forward_train must succeed");
    // forward and forward_train must agree — backward differentiates the
    // function plain forward computes.
    let y_plain = module.forward(x, &ctx).unwrap();
    assert!(
        vec_rel_err(y.data(), y_plain.data()) < 1e-6,
        "{}: forward_train output diverges from forward",
        module.type_name()
    );
    let w = Mat::randn(y.rows(), y.cols(), &mut Philox::seeded(seed));
    module.zero_grads();
    let grad_in = module
        .backward(&w, &cache, &ctx)
        .expect("backward must succeed");
    assert_eq!(grad_in.shape(), x.shape(), "grad_in shape");

    // Collect analytic gradients (owned, so params_mut below can borrow).
    let analytic: Vec<(String, Vec<f32>)> = module
        .grads()
        .into_iter()
        .map(|(n, g)| (n, g.to_vec()))
        .collect();
    let param_names: Vec<String> = module.params().into_iter().map(|(n, _)| n).collect();
    assert_eq!(
        analytic.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
        param_names.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        "{}: grads() must mirror params() names and order",
        module.type_name()
    );

    // Finite differences over every parameter element.
    for (name, got) in &analytic {
        let len = got.len();
        let mut fd = Vec::with_capacity(len);
        for i in 0..len {
            nudge(module, name, i, EPS);
            let lp = weighted_loss(&module.forward(x, &ctx).unwrap(), &w);
            nudge(module, name, i, -2.0 * EPS);
            let lm = weighted_loss(&module.forward(x, &ctx).unwrap(), &w);
            nudge(module, name, i, EPS); // restore
            fd.push(((lp - lm) / (2.0 * EPS as f64)) as f32);
        }
        let err = vec_rel_err(got, &fd);
        assert!(
            err < tol,
            "{} param {name}: FD vs analytic rel err {err:.2e}",
            module.type_name()
        );
    }

    // Finite differences over the input.
    let mut fd_x = Vec::with_capacity(x.len());
    let mut xp = x.clone();
    for i in 0..x.len() {
        let orig = xp.data()[i];
        xp.data_mut()[i] = orig + EPS;
        let lp = weighted_loss(&module.forward(&xp, &ctx).unwrap(), &w);
        xp.data_mut()[i] = orig - EPS;
        let lm = weighted_loss(&module.forward(&xp, &ctx).unwrap(), &w);
        xp.data_mut()[i] = orig;
        fd_x.push(((lp - lm) / (2.0 * EPS as f64)) as f32);
    }
    let err = vec_rel_err(grad_in.data(), &fd_x);
    assert!(
        err < tol,
        "{} input: FD vs analytic rel err {err:.2e}",
        module.type_name()
    );
}

/// [`gradcheck_tol`] at the standard f32 tolerance.
fn gradcheck(module: &mut dyn Module, x: &Mat, seed: u64) {
    gradcheck_tol(module, x, seed, TOL);
}

#[test]
fn gradcheck_linear() {
    let mut rng = Philox::seeded(201);
    let mut l = Linear::random(6, 5, &mut rng);
    let x = Mat::randn(4, 6, &mut rng);
    gradcheck(&mut l, &x, 301);
}

#[test]
fn gradcheck_sklinear() {
    let mut rng = Philox::seeded(202);
    let mut l = SKLinear::random(6, 5, 2, 3, &mut rng);
    let x = Mat::randn(4, 6, &mut rng);
    gradcheck(&mut l, &x, 302);
}

#[test]
fn gradcheck_conv2d() {
    let mut rng = Philox::seeded(203);
    let shape = ConvShape {
        c_in: 2,
        c_out: 3,
        kernel: 3,
        image: 5,
        padding: 1,
    };
    let mut c = Conv2d::random(shape, &mut rng);
    let x = Mat::randn(2, 2 * 25, &mut rng);
    gradcheck(&mut c, &x, 303);
}

#[test]
fn gradcheck_skconv2d() {
    let mut rng = Philox::seeded(204);
    let shape = ConvShape {
        c_in: 2,
        c_out: 3,
        kernel: 3,
        image: 5,
        padding: 1,
    };
    let mut c = SKConv2d::random(shape, 2, 2, &mut rng);
    let x = Mat::randn(2, 2 * 25, &mut rng);
    gradcheck(&mut c, &x, 304);
}

#[test]
fn gradcheck_multi_head_attention() {
    let mut rng = Philox::seeded(205);
    let w = AttnWeights::random(8, 2, &mut rng);
    let mut a = MultiHeadAttention::new(w);
    // Small-norm inputs keep the softmax away from saturation, where FD
    // at f32 precision degrades (the gradient is still checked, just on a
    // well-conditioned point).
    let x = Mat::randn(5, 8, &mut rng).scale(0.5);
    gradcheck(&mut a, &x, 305);
}

#[test]
fn gradcheck_rand_multi_head_attention_softmax() {
    let mut rng = Philox::seeded(206);
    let w = AttnWeights::random(8, 2, &mut rng);
    let mut a = RandMultiHeadAttention::new(w, 16, KernelKind::Softmax, 77);
    let x = Mat::randn(5, 8, &mut rng).scale(0.4);
    gradcheck(&mut a, &x, 306);
}

#[test]
fn gradcheck_rand_multi_head_attention_relu() {
    // The ReLU feature map is piecewise linear, so when a random
    // projection `ωᵀx` sits within the ε-perturbation of the kink,
    // central differences measure a blend of the two one-sided slopes —
    // an FD artifact, not a gradient bug (the same formulas check out to
    // ~1e-9 in an f64 mirror at ε=1e-6). This seed was chosen so every
    // projection cell clears the kink by ≥ 3.6e-3 (> 2× the largest
    // ε-induced shift) with ~54% of features inactive, so the mask path
    // is genuinely exercised; the tolerance still allows one unlucky
    // crossing (~1e-2) while staying far below the O(1) error a wrong
    // transpose or missing mask would produce. The smooth softmax kernel
    // above is held to the full 1e-3.
    let mut rng = Philox::seeded(211);
    let w = AttnWeights::random(8, 2, &mut rng);
    let mut a = RandMultiHeadAttention::new(w, 16, KernelKind::Relu, 78);
    let x = Mat::randn(5, 8, &mut rng).scale(0.4);
    gradcheck_tol(&mut a, &x, 307, 2e-2);
}

#[test]
fn gradcheck_activation_gelu() {
    // GELU is smooth everywhere — the standard tolerance applies.
    let mut rng = Philox::seeded(212);
    let mut a = Activation::gelu();
    let x = Mat::randn(4, 9, &mut rng);
    gradcheck(&mut a, &x, 310);
}

#[test]
fn gradcheck_activation_relu() {
    // ReLU is piecewise linear: central differences straddling the kink
    // measure a blend of the two one-sided slopes (an FD artifact, not a
    // gradient bug — same story as the ReLU-kernel Performer above). Keep
    // every probe point ≥ 0.25 from zero, an order of magnitude beyond
    // the ε = 1e-2 perturbation, so the full tolerance applies while both
    // branches stay exercised.
    let mut rng = Philox::seeded(213);
    let mut x = Mat::randn(4, 9, &mut rng);
    for v in x.data_mut() {
        *v += if *v >= 0.0 { 0.25 } else { -0.25 };
    }
    let mut a = Activation::relu();
    gradcheck(&mut a, &x, 311);
}

/// Model-level FD check: perturb each parameter of each layer of a
/// stacked model and compare against the gradients accumulated by
/// `Model::backward` — exercises cache routing and reverse-order
/// chaining, not just per-layer math.
fn model_gradcheck(model: &mut Model, x: &Mat, seed: u64) {
    let ctx = ForwardCtx::new();
    let (y, caches) = model.forward_train(x, &ctx).unwrap();
    let w = Mat::randn(y.rows(), y.cols(), &mut Philox::seeded(seed));
    model.zero_grads();
    let grad_in = model.backward(&w, &caches, &ctx).unwrap();
    assert_eq!(grad_in.shape(), x.shape());

    let layer_names: Vec<String> = model.iter().map(|l| l.name.clone()).collect();
    for lname in &layer_names {
        let analytic: Vec<(String, Vec<f32>)> = model
            .get(lname)
            .unwrap()
            .grads()
            .into_iter()
            .map(|(n, g)| (n, g.to_vec()))
            .collect();
        if model.get(lname).unwrap().params().is_empty() {
            // Parameter-free layers (activations) rightly accumulate
            // nothing; their input gradient is covered by the chain below.
            assert!(analytic.is_empty(), "param-free layer {lname} has grads");
            continue;
        }
        assert!(!analytic.is_empty(), "layer {lname} accumulated no grads");
        for (pname, got) in &analytic {
            let mut fd = Vec::with_capacity(got.len());
            for i in 0..got.len() {
                let mut probe = |m: &mut Model, delta: f32| {
                    let layer = m.get_mut(lname).unwrap();
                    for (pn, mut p) in layer.params_mut() {
                        if &pn == pname {
                            p.data_mut()[i] += delta;
                        }
                    }
                    layer.on_params_loaded();
                };
                probe(model, EPS);
                let lp = weighted_loss(&model.forward(x, &ctx).unwrap(), &w);
                probe(model, -2.0 * EPS);
                let lm = weighted_loss(&model.forward(x, &ctx).unwrap(), &w);
                probe(model, EPS);
                fd.push(((lp - lm) / (2.0 * EPS as f64)) as f32);
            }
            let err = vec_rel_err(got, &fd);
            assert!(err < TOL, "{lname}.{pname}: rel err {err:.2e}");
        }
    }
}

#[test]
fn gradcheck_stacked_model_dense() {
    let mut rng = Philox::seeded(208);
    let mut m = Model::new();
    m.add("fc1", Linear::random(6, 8, &mut rng)).unwrap();
    m.add("fc2", Linear::random(8, 4, &mut rng)).unwrap();
    let x = Mat::randn(3, 6, &mut rng);
    model_gradcheck(&mut m, &x, 308);
}

#[test]
fn gradcheck_stacked_nonlinear_model() {
    // Linear → GELU → SKLinear → GELU → Linear: gradients must chain
    // through parameter-free activation layers, including a sketched op
    // sandwiched between two nonlinearities (the fine-tune stack the
    // ROADMAP's activation item asked for). GELU on both slots keeps the
    // composition smooth, so the standard FD tolerance applies at any
    // seed — the ReLU branch's kink handling is covered by the isolated
    // gradcheck_activation_relu, where the probe points are controlled.
    let mut rng = Philox::seeded(214);
    let mut m = Model::new();
    m.add("fc1", Linear::random(6, 8, &mut rng)).unwrap();
    m.add("act1", Activation::gelu()).unwrap();
    m.add("fc2", SKLinear::random(8, 8, 2, 3, &mut rng)).unwrap();
    m.add("act2", Activation::gelu()).unwrap();
    m.add("fc3", Linear::random(8, 4, &mut rng)).unwrap();
    let x = Mat::randn(3, 6, &mut rng);
    model_gradcheck(&mut m, &x, 312);
}

#[test]
fn gradcheck_stacked_model_sketch_compressed() {
    // The acceptance-critical path: gradients must flow through a model
    // that SketchPlan compressed in place.
    let mut rng = Philox::seeded(209);
    let mut m = Model::new();
    m.add("fc1", Linear::random(6, 8, &mut rng)).unwrap();
    m.add("fc2", Linear::random(8, 4, &mut rng)).unwrap();
    SketchPlan::new()
        .select(LayerSelector::by_names(&["fc1"]))
        .with(2, 3)
        .seed(11)
        .apply(&mut m)
        .unwrap();
    assert_eq!(m.get("fc1").unwrap().type_name(), "SKLinear");
    let x = Mat::randn(3, 6, &mut rng);
    model_gradcheck(&mut m, &x, 309);
}

#[test]
fn sketch_compressed_model_loss_decreases_over_20_trainer_steps() {
    // Seeded end-to-end acceptance check: sketchify, then fine-tune the
    // factors against a dense teacher for 20 steps — loss must drop.
    let mut rng = Philox::seeded(210);
    let mut model = Model::new();
    model.add("fc1", Linear::random(10, 16, &mut rng)).unwrap();
    model.add("fc2", Linear::random(16, 6, &mut rng)).unwrap();
    let x = Mat::randn(24, 10, &mut rng);
    let ctx = ForwardCtx::new();
    // Teacher = the dense model itself, pre-compression: fine-tuning must
    // recover what sketching gave away.
    let target = model.forward(&x, &ctx).unwrap();
    SketchPlan::new()
        .select(LayerSelector::by_type("Linear"))
        .with(1, 4)
        .seed(13)
        .apply(&mut model)
        .unwrap();
    let mut tr = Trainer::new(Box::new(Adam::new(5e-3)));
    let first = tr.eval_loss(&model, &x, &target, &ctx).unwrap();
    let mut losses = Vec::new();
    for _ in 0..20 {
        losses.push(tr.train_step(&mut model, &x, &target, &ctx).unwrap());
    }
    let last = tr.eval_loss(&model, &x, &target, &ctx).unwrap();
    assert!(first > 0.0, "sketching should perturb the output");
    assert!(
        last < first * 0.7,
        "20 Trainer steps must reduce loss: {first} -> {last} (curve {losses:?})"
    );
    assert_eq!(tr.step, 20);
}
