//! Oracle suite for the packed-panel GEMM rewrite: every public kernel
//! entry point (`matmul`, `matmul_nt`, `matmul_tn`, `gemm`, `gemm_batch`)
//! against a serial f64 naive oracle, across shapes spanning the `m < 8`
//! small path, the packing-threshold boundary (`m·k·n = 32³` with
//! `m ≥ 8`), non-divisible MR/NR/MC/NC/KC tile edges, and strided /
//! transposed batch views.
//!
//! Thread-count independence: k is never split across workers, so results
//! are identical at any pool size — CI runs this suite under a
//! `PANTHER_GEMM_THREADS={1,4}` matrix (the pool is process-global and
//! fixed after first use, so the comparison across counts lives in CI,
//! not in-process).

use panther::linalg::{
    gemm, gemm_batch, matmul, matmul_nt, matmul_tn, rel_error, Mat, MatMut, MatRef,
};
use panther::rng::Philox;
use panther::util::prop::prop_check;

/// f64-accumulated naive oracle.
fn oracle(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows(), b.cols());
    for i in 0..a.rows() {
        for j in 0..b.cols() {
            let mut acc = 0f64;
            for p in 0..a.cols() {
                acc += a.get(i, p) as f64 * b.get(p, j) as f64;
            }
            c.set(i, j, acc as f32);
        }
    }
    c
}

#[test]
fn pack_threshold_boundary_shapes() {
    // 8×32×128 = 32768 sits exactly on the packing threshold; one element
    // less in any dimension takes the direct kernel. Both sides of the
    // boundary must agree with the oracle (the dispatch choice is a perf
    // decision, never a results decision).
    let mut rng = Philox::seeded(41);
    for &(m, k, n) in &[
        (8usize, 32usize, 128usize), // exactly 32³, packed
        (8, 32, 127),                // just below, direct kernel
        (7, 64, 128),                // m < 8: always the direct kernel
        (8, 1, 4096),                // k = 1 packed edge
        (9, 4096, 1),                // n = 1: single NR panel, 3 lanes padding
    ] {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let err = rel_error(&matmul(&a, &b), &oracle(&a, &b));
        assert!(err < 1e-5, "({m},{k},{n}): rel {err}");
    }
}

#[test]
fn tile_edge_shapes_all_variants() {
    // Shapes that leave ragged MR/NR microkernel tails and partial MC/NC
    // tiles, with k crossing KC. Checked through all three layout
    // variants so the packing gathers (normal, transposed) are covered.
    let mut rng = Philox::seeded(42);
    for &(m, k, n) in &[
        (65usize, 257usize, 129usize),
        (128, 300, 132),
        (200, 64, 70),
        (13, 513, 9),
    ] {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let want = oracle(&a, &b);
        assert!(rel_error(&matmul(&a, &b), &want) < 1e-5, "matmul ({m},{k},{n})");
        assert!(
            rel_error(&matmul_nt(&a, &b.transpose()), &want) < 1e-5,
            "matmul_nt ({m},{k},{n})"
        );
        assert!(
            rel_error(&matmul_tn(&a.transpose(), &b), &want) < 1e-5,
            "matmul_tn ({m},{k},{n})"
        );
    }
}

#[test]
fn gemm_batch_matches_per_item_oracle_heterogeneous() {
    // Items of different shapes in one call, including one below and one
    // above the parallel threshold, plus transposed operands.
    let mut rng = Philox::seeded(43);
    let dims = [(5usize, 7usize, 3usize), (64, 96, 80), (9, 200, 33)];
    let mats: Vec<(Mat, Mat)> = dims
        .iter()
        .map(|&(m, k, n)| (Mat::randn(m, k, &mut rng), Mat::randn(n, k, &mut rng)))
        .collect();
    let mut outs: Vec<Mat> = dims
        .iter()
        .map(|&(m, _, n)| Mat::filled(m, n, f32::NAN))
        .collect();
    {
        let a: Vec<MatRef> = mats.iter().map(|(a, _)| a.view()).collect();
        // B stored transposed; the view's .t() restores the logical k×n.
        let b: Vec<MatRef> = mats.iter().map(|(_, bt)| bt.view().t()).collect();
        let mut c: Vec<MatMut> = outs.iter_mut().map(|o| o.view_mut()).collect();
        gemm_batch(1.25, &a, &b, 0.0, &mut c);
    }
    for (i, ((a, bt), got)) in mats.iter().zip(&outs).enumerate() {
        let want = oracle(a, &bt.transpose()).scale(1.25);
        let err = rel_error(got, &want);
        assert!(err < 1e-5, "item {i}: rel {err}");
    }
}

#[test]
fn gemm_batch_column_views_equal_sliced_products() {
    // Per-head column views of shared storage vs materialized slices —
    // the attention access pattern, at a shape with ragged n×dh bands.
    let mut rng = Philox::seeded(44);
    let (n, d, h) = (70usize, 48usize, 4usize);
    let dh = d / h;
    let q = Mat::randn(n, d, &mut rng);
    let k = Mat::randn(n, d, &mut rng);
    let mut out = Mat::zeros(n, d);
    let mut scores: Vec<Mat> = (0..h).map(|_| Mat::zeros(n, n)).collect();
    {
        let a: Vec<MatRef> = (0..h)
            .map(|i| q.view().col_range(i * dh, (i + 1) * dh))
            .collect();
        let b: Vec<MatRef> = (0..h)
            .map(|i| k.view().col_range(i * dh, (i + 1) * dh).t())
            .collect();
        let mut c: Vec<MatMut> = scores.iter_mut().map(|s| s.view_mut()).collect();
        gemm_batch(1.0, &a, &b, 0.0, &mut c);
    }
    {
        let a: Vec<MatRef> = scores.iter().map(|s| s.view()).collect();
        let b: Vec<MatRef> = (0..h)
            .map(|i| k.view().col_range(i * dh, (i + 1) * dh))
            .collect();
        let mut c = out.col_bands_mut(dh);
        gemm_batch(1.0, &a, &b, 0.0, &mut c);
    }
    for i in 0..h {
        let qh = q.slice(0, n, i * dh, (i + 1) * dh);
        let kh = k.slice(0, n, i * dh, (i + 1) * dh);
        let s_want = oracle(&qh, &kh.transpose());
        assert!(rel_error(&scores[i], &s_want) < 1e-5, "scores head {i}");
        let o_want = oracle(&s_want, &kh);
        let got = out.slice(0, n, i * dh, (i + 1) * dh);
        assert!(rel_error(&got, &o_want) < 1e-4, "band head {i}");
    }
}

#[test]
fn property_packed_kernel_matches_oracle_random_shapes() {
    // Random shapes biased to straddle the dispatch boundaries (m around
    // MR, work around 32³ and 64³), random alpha/beta. Runs identically
    // under PANTHER_GEMM_THREADS=1 and the default pool — CI's thread
    // matrix executes both; rel err ≤ 1e-5 against the f64 oracle either
    // way.
    prop_check("packed-gemm-oracle", 24, |g| {
        let m = 1 + g.usize(0..80);
        let k = 1 + g.usize(0..300);
        let n = 1 + g.usize(0..150);
        let a = Mat::randn(m, k, g.rng());
        let b = Mat::randn(k, n, g.rng());
        let want = oracle(&a, &b);
        assert!(
            rel_error(&matmul(&a, &b), &want) < 1e-5,
            "matmul ({m},{k},{n})"
        );
        let alpha = *g.choose(&[1.0f32, 0.5, -2.0]);
        let beta = *g.choose(&[0.0f32, 1.0, -0.5]);
        let c0 = Mat::randn(m, n, g.rng());
        let mut c = c0.clone();
        gemm(alpha, &a, &b, beta, &mut c);
        let want_ab = want.scale(alpha).add(&c0.scale(beta));
        // beta-scaled C adds cancellation noise; loosen slightly.
        assert!(
            rel_error(&c, &want_ab) < 1e-4,
            "gemm ({m},{k},{n}) a={alpha} b={beta}"
        );
    });
}

#[test]
fn empty_and_degenerate_batches() {
    // Zero items, zero-k items, zero-row items — all defined, no panics.
    let mut c: Vec<MatMut> = Vec::new();
    gemm_batch(1.0, &[], &[], 0.0, &mut c);
    let a = Mat::zeros(0, 4);
    let b = Mat::zeros(4, 3);
    let mut o = Mat::zeros(0, 3);
    {
        let av = [a.view()];
        let bv = [b.view()];
        let mut cv = [o.view_mut()];
        gemm_batch(1.0, &av, &bv, 0.0, &mut cv);
    }
    assert_eq!(o.shape(), (0, 3));
}
