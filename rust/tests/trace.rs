//! Trace-completeness integration tests: end-to-end request tracing
//! against the full serve stack, under chaos.
//!
//! The contracts under test are the observability tentpole's claims:
//!
//! - **disabled ⇒ invisible**: with no tracer installed the workers hold
//!   `None` and replies are bitwise identical to a traced server's;
//! - **enabled ⇒ complete**: every answered request's span chain can be
//!   reconstructed from the trace log — exactly one `admit`, one
//!   `queue_wait`, one `exec`, and exactly one terminal (`reply` xor
//!   `error`) — even when worker kills requeue its batch, quarantine
//!   bisection replays it, or a hot-swap races its admission;
//! - **exact accounting**: the per-class token bucket records or
//!   suppresses every attempt, never both, never neither — with zero
//!   refill, `recorded == capacity` and `recorded + suppressed ==
//!   attempts` hold exactly;
//! - **profiler attribution**: an attached [`StageProfiler`] sees per-layer
//!   and GEMM pack/kernel wall time without changing any output bit.

use panther::linalg::Mat;
use panther::nn::{Activation, ForwardCtx, Linear, Model};
use panther::rng::Philox;
use panther::serve::{FaultPlan, ModelServer, ServeError, TierConfig, TraceConfig, TraceLog};
use panther::util::events::{EventClass, StageProfiler};
use panther::util::json::Json;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn mlp(seed: u64, d_in: usize, d_out: usize) -> Model {
    let mut rng = Philox::seeded(seed);
    let mut m = Model::new();
    let mut fc1 = Linear::random(d_in, 12, &mut rng);
    for b in fc1.bias.iter_mut() {
        *b = 0.3;
    }
    m.add("fc1", fc1).unwrap();
    m.add("act", Activation::gelu()).unwrap();
    let mut fc2 = Linear::random(12, d_out, &mut rng);
    for b in fc2.bias.iter_mut() {
        *b = -0.2;
    }
    m.add("fc2", fc2).unwrap();
    m
}

fn request_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| Mat::randn(1, d, &mut Philox::seeded(seed + i as u64)).into_vec())
        .collect()
}

fn solo_forward(model: &Model, row: &[f32]) -> Vec<f32> {
    model
        .forward(&Mat::from_vec(1, row.len(), row.to_vec()), &ForwardCtx::new())
        .unwrap()
        .row(0)
        .to_vec()
}

/// Per-class event counts for one trace id across the whole log.
fn counts_for(log: &TraceLog, id: u64) -> [usize; EventClass::COUNT] {
    let mut c = [0usize; EventClass::COUNT];
    for (_, e) in log.events_for(id) {
        c[e.class as usize] += 1;
    }
    c
}

/// Assert the canonical answered-request chain for trace `id`: exactly one
/// admit, one queue_wait span, one exec span, and the given terminal.
fn assert_chain(log: &TraceLog, id: u64, terminal: EventClass) {
    let c = counts_for(log, id);
    assert_eq!(c[EventClass::Admit as usize], 1, "trace {id}: admit count");
    assert_eq!(c[EventClass::QueueWait as usize], 1, "trace {id}: queue_wait count");
    assert_eq!(c[EventClass::Exec as usize], 1, "trace {id}: exec count");
    let (replies, errors) = (c[EventClass::Reply as usize], c[EventClass::Error as usize]);
    assert_eq!(replies + errors, 1, "trace {id}: exactly one terminal");
    assert_eq!(
        c[terminal as usize], 1,
        "trace {id}: expected terminal {}",
        terminal.name()
    );
}

fn poll_until(deadline: Duration, mut ok: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    while start.elapsed() < deadline {
        if ok() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    ok()
}

#[test]
fn disabled_tracing_is_invisible_and_enabled_chains_are_complete() {
    let d = 10;
    let rows = request_rows(12, d, 500);
    let oracle = mlp(42, d, 5);
    let expected: Vec<Vec<f32>> = rows.iter().map(|r| solo_forward(&oracle, r)).collect();
    let cfg = TierConfig {
        max_batch: 4,
        max_wait: Duration::from_millis(2),
        workers: 2,
        ..TierConfig::default()
    };
    // Untraced server: the baseline replies.
    let mut plain = ModelServer::new();
    plain.register_tier("t", mlp(42, d, 5), d, cfg.clone()).unwrap();
    assert!(plain.tracer().is_none(), "tracing is opt-in");
    let h = plain.handle();
    let got_plain: Vec<Vec<f32>> = rows.iter().map(|r| h.infer("t", r).unwrap()).collect();
    plain.shutdown();
    // Traced server, same model, same rows.
    let mut traced = ModelServer::new();
    let tracer = traced.enable_tracing(TraceConfig::default());
    traced.register_tier("t", mlp(42, d, 5), d, cfg).unwrap();
    let h = traced.handle();
    let got_traced: Vec<Vec<f32>> = rows.iter().map(|r| h.infer("t", r).unwrap()).collect();
    assert_eq!(got_plain, expected, "untraced replies match the oracle bitwise");
    assert_eq!(got_traced, expected, "tracing must not change a single output bit");
    // Sequential submission from one thread mints ids 1..=12 in order;
    // each must carry the full chain with a `reply` terminal and no
    // transform span (the tier's transform is Raw).
    let log = tracer.log();
    for id in 1..=12u64 {
        assert_chain(&log, id, EventClass::Reply);
        let c = counts_for(&log, id);
        assert_eq!(c[EventClass::Transform as usize], 0, "raw tier has no transform span");
    }
    // The admit instant precedes the queue_wait span start, which
    // precedes (or ties) the exec span start.
    for id in 1..=12u64 {
        let evs = log.events_for(id);
        assert_eq!(evs[0].1.class, EventClass::Admit, "trace {id} starts at admission");
    }
    // Spans carry durations; the queue wait ends where exec begins.
    let t = &log.tiers[0];
    assert_eq!(t.tier, "t");
    assert_eq!(t.overflow, 0);
    assert_eq!(t.recorded(EventClass::Admit), 12);
    assert_eq!(t.suppressed(EventClass::Admit), 0);
    traced.shutdown();
}

#[test]
fn chains_stay_exact_under_seeded_kills() {
    // Kill ticks 1 and 3: two workers die mid-run and their batches are
    // requeued. Reply-time recording means a killed batch contributes
    // *nothing* — each request still gets exactly one queue_wait/exec
    // span pair, from the attempt that actually answered it.
    let d = 10;
    let model = mlp(42, d, 5);
    let rows = request_rows(12, d, 900);
    let expected: Vec<Vec<f32>> = rows.iter().map(|r| solo_forward(&model, r)).collect();
    let plan = Arc::new(FaultPlan::seeded(7).kill_at(&[1, 3]));
    let mut server = ModelServer::new();
    let tracer = server.enable_tracing(TraceConfig::default());
    server
        .register_tier(
            "t",
            model,
            d,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(2),
                workers: 2,
                faults: Some(Arc::clone(&plan)),
                ..TierConfig::default()
            },
        )
        .unwrap();
    let h = server.handle();
    let pending: Vec<_> = rows.iter().map(|r| h.submit("t", r).unwrap()).collect();
    for (want, p) in expected.iter().zip(pending) {
        assert_eq!(&p.wait().unwrap(), want, "survivors stay bitwise fault-free");
    }
    let log = tracer.log();
    for id in 1..=12u64 {
        assert_chain(&log, id, EventClass::Reply);
    }
    let t = &log.tiers[0];
    assert_eq!(t.recorded(EventClass::Exec), 12, "kills never double-count exec spans");
    assert_eq!(t.recorded(EventClass::Error), 0, "a kill is invisible to clients");
    assert_eq!(t.recorded(EventClass::Fault), 2, "one fault event per armed kill tick");
    // The supervisor respawns both workers and records each restart
    // (tier-level, trace id 0) on its own cadence.
    assert!(
        poll_until(Duration::from_secs(10), || {
            tracer.log().tiers[0].recorded(EventClass::Restart) == 2
        }),
        "both respawns must be traced"
    );
    server.shutdown();
}

/// Panics whenever any input value equals the marker `666.0`.
struct Trap;

impl panther::nn::Module for Trap {
    fn type_name(&self) -> &'static str {
        "Trap"
    }
    fn forward(&self, x: &Mat, _ctx: &ForwardCtx) -> panther::Result<Mat> {
        if x.data().iter().any(|&v| v == 666.0) {
            panic!("trap sprung");
        }
        Ok(x.clone())
    }
    fn params(&self) -> Vec<(String, panther::nn::ParamRef<'_>)> {
        Vec::new()
    }
    fn params_mut(&mut self) -> Vec<(String, panther::nn::ParamMut<'_>)> {
        Vec::new()
    }
    fn boxed_clone(&self) -> Box<dyn panther::nn::Module> {
        Box::new(Trap)
    }
}

#[test]
fn quarantine_bisection_traces_rounds_and_the_poison_chain() {
    let d = 4;
    let mut m = Model::new();
    m.add("trap", Trap).unwrap();
    let mut server = ModelServer::new();
    let tracer = server.enable_tracing(TraceConfig::default());
    server
        .register_tier(
            "t",
            m,
            d,
            TierConfig {
                max_batch: 32,
                max_wait: Duration::from_millis(50),
                workers: 1,
                quarantine_strikes: 2,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let mut rows = request_rows(31, d, 2200);
    let poison_index = 13;
    rows.insert(poison_index, vec![1.0, 666.0, 3.0, 4.0]);
    let h = server.handle();
    let pending: Vec<_> = rows.iter().map(|r| h.submit("t", r).unwrap()).collect();
    for (i, p) in pending.into_iter().enumerate() {
        match p.wait() {
            Ok(_) => assert_ne!(i, poison_index),
            Err(ServeError::PoisonedInput) => assert_eq!(i, poison_index),
            Err(e) => panic!("request {i}: expected Ok or PoisonedInput, got {e}"),
        }
    }
    let log = tracer.log();
    // Ids are minted 1..=32 in submission order; the poison row's chain
    // terminates in an error with the poisoned marker, every innocent's
    // in a reply — each with exactly one exec span despite the replays.
    let poison_id = poison_index as u64 + 1;
    for id in 1..=32u64 {
        if id == poison_id {
            assert_chain(&log, id, EventClass::Error);
            let c = counts_for(&log, id);
            assert_eq!(c[EventClass::Poisoned as usize], 1, "strike-out marker");
            let evs = log.events_for(id);
            let err = evs.iter().find(|(_, e)| e.class == EventClass::Error).unwrap();
            assert_eq!(err.1.detail, "kind=PoisonedInput");
        } else {
            assert_chain(&log, id, EventClass::Reply);
        }
    }
    let t = &log.tiers[0];
    // Bisection rounds are tier-level events. The exact count depends on
    // how the coalescer composed batches, but striking out a poison row
    // always takes at least one traced solo retry.
    assert!(
        t.recorded(EventClass::Quarantine) >= 1,
        "bisection rounds traced, got {}",
        t.recorded(EventClass::Quarantine)
    );
    assert_eq!(t.recorded(EventClass::Poisoned), 1, "exactly one strike-out");
    server.shutdown();
}

#[test]
fn concurrent_hot_swaps_keep_every_chain_complete() {
    let (d, k) = (12usize, 5usize);
    let mut server = ModelServer::new();
    let tracer = server.enable_tracing(TraceConfig::default());
    server
        .register_tier(
            "t",
            mlp(90, d, k),
            d,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(300),
                queue_cap: 2048,
                workers: 3,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let (n_threads, m_requests) = (6usize, 20usize);
    let hammers: Vec<_> = (0..n_threads)
        .map(|t| {
            let h = server.handle();
            std::thread::spawn(move || {
                for i in 0..m_requests {
                    let seed = 7000 + (t * m_requests + i) as u64;
                    let row = Mat::randn(1, d, &mut Philox::seeded(seed)).into_vec();
                    h.infer("t", &row).unwrap();
                }
            })
        })
        .collect();
    // Two hot-swaps race the hammer traffic.
    assert_eq!(server.swap_tier_model("t", mlp(91, d, k)).unwrap(), 1);
    assert_eq!(server.swap_tier_model("t", mlp(92, d, k)).unwrap(), 2);
    for th in hammers {
        th.join().unwrap();
    }
    let total = (n_threads * m_requests) as u64;
    let log = tracer.log();
    for id in 1..=total {
        assert_chain(&log, id, EventClass::Reply);
    }
    let t = &log.tiers[0];
    assert_eq!(t.recorded(EventClass::Swap), 2, "one swap span per publish");
    let swaps: Vec<_> = t
        .events
        .iter()
        .filter(|e| e.class == EventClass::Swap)
        .collect();
    assert_eq!(swaps.len(), 2);
    assert_eq!(swaps[0].detail, "v=1");
    assert_eq!(swaps[1].detail, "v=2");
    for s in swaps {
        assert_eq!(s.trace, 0, "swaps are tier-level events");
    }
    // Every admit carries the version the request pinned — one of the
    // three versions ever live.
    for e in t.events.iter().filter(|e| e.class == EventClass::Admit) {
        assert!(
            ["v=0", "v=1", "v=2"].contains(&e.detail.as_str()),
            "unexpected pinned version {:?}",
            e.detail
        );
    }
    server.shutdown();
}

#[test]
fn rate_limiter_accounts_for_every_suppressed_event_exactly() {
    // Zero refill ⇒ exactly `bucket_capacity` events per class survive;
    // the rest are counted, never silently lost.
    let d = 10;
    let mut server = ModelServer::new();
    let tracer = server.enable_tracing(TraceConfig {
        ring_capacity: 4096,
        bucket_capacity: 8,
        refill_per_sec: 0.0,
    });
    server
        .register_tier(
            "t",
            mlp(42, d, 5),
            d,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                workers: 1,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let h = server.handle();
    let rows = request_rows(20, d, 4100);
    for r in &rows {
        h.infer("t", r).unwrap();
    }
    let log = tracer.log();
    let t = &log.tiers[0];
    for class in [
        EventClass::Admit,
        EventClass::QueueWait,
        EventClass::Exec,
        EventClass::Reply,
    ] {
        assert_eq!(t.recorded(class), 8, "{}: capacity records", class.name());
        assert_eq!(t.suppressed(class), 12, "{}: the rest are counted", class.name());
    }
    assert_eq!(t.recorded(EventClass::Error), 0);
    assert_eq!(t.suppressed(EventClass::Error), 0);
    assert_eq!(t.overflow, 0, "suppression is not ring overflow");
    server.shutdown();
}

#[test]
fn exports_parse_and_cover_the_run() {
    let d = 10;
    let mut server = ModelServer::new();
    let tracer = server.enable_tracing(TraceConfig::default());
    server
        .register_tier(
            "t",
            mlp(42, d, 5),
            d,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                workers: 1,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let h = server.handle();
    for r in &request_rows(6, d, 5100) {
        h.infer("t", r).unwrap();
    }
    let log = tracer.log();
    let jsonl = log.export_jsonl();
    // 6 requests x (admit + queue_wait + exec + reply) = 24 lines.
    assert_eq!(jsonl.lines().count(), 24);
    for line in jsonl.lines() {
        let v = Json::parse(line).expect("every JSONL line parses");
        for key in ["tier", "class", "t_us", "dur_us", "trace", "detail"] {
            assert!(v.get(key).is_some(), "line missing {key}: {line}");
        }
    }
    let chrome = Json::parse(&log.export_chrome_trace()).expect("chrome trace parses");
    let evs = chrome.get("traceEvents").and_then(Json::as_arr).unwrap();
    // 1 process metadata + 24 events.
    assert_eq!(evs.len(), 25);
    // Every event renders as process metadata, a complete span, or an
    // instant — nothing else. (Span-vs-instant is decided by whether the
    // duration rounded to >= 1 µs, so the split is timing-dependent.)
    for e in evs {
        let ph = e.get("ph").and_then(Json::as_str).unwrap();
        assert!(["M", "X", "i"].contains(&ph), "unexpected phase {ph}");
    }
    server.shutdown();
}

#[test]
fn profiler_attributes_stages_without_changing_outputs() {
    // 16x64 through a 64->64 linear clears both packed-GEMM gates
    // (m >= 8 and m*k*n >= 32768), so pack/kernel phases must appear.
    let mut rng = Philox::seeded(77);
    let mut m = Model::new();
    m.add("fc1", Linear::random(64, 64, &mut rng)).unwrap();
    m.add("act", Activation::gelu()).unwrap();
    let x = Mat::randn(16, 64, &mut Philox::seeded(78));
    let ctx = ForwardCtx::new();
    assert!(ctx.profiler().is_none(), "profiling is opt-in");
    let y_plain = m.forward(&x, &ctx).unwrap();
    let prof = Arc::new(StageProfiler::new());
    ctx.set_profiler(Some(Arc::clone(&prof)));
    let y_prof = m.forward(&x, &ctx).unwrap();
    assert_eq!(
        y_plain.data(),
        y_prof.data(),
        "profiling must not change a single output bit"
    );
    let stages = prof.snapshot();
    for stage in ["layer/fc1", "layer/act", "gemm/pack", "gemm/kernel"] {
        let s = stages.get(stage).unwrap_or_else(|| panic!("missing stage {stage}"));
        assert!(s.calls >= 1, "{stage}: calls");
    }
    assert_eq!(stages["layer/fc1"].calls, 1);
    // Detached again: the next forward leaves the profile untouched.
    ctx.set_profiler(None);
    let before = prof.snapshot();
    m.forward(&x, &ctx).unwrap();
    assert_eq!(prof.snapshot(), before);
    // The human report renders one row per stage.
    let rep = prof.report();
    assert!(rep.contains("gemm/pack"), "{rep}");
}
