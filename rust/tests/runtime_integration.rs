//! Integration tests over the PJRT runtime + coordinator + train stack.
//! All tests skip gracefully (with a notice) when `artifacts/` is absent,
//! so `cargo test` works before `make artifacts`; `make test` runs the
//! full set.

use panther::coordinator::RuntimeServer;
use panther::data::{ImageDataset, TextCorpus};
use panther::rng::Philox;
use panther::runtime::{HostTensor, Runtime};
use panther::train::{checkpoint, BertTrainer, ConvTrainer, ModelState};

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

/// The Pallas SKLinear kernel, the pure-jnp reference lowered to HLO, and
/// the Rust CPU implementation must all agree.
#[test]
fn three_implementations_of_sklinear_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let spec = rt.manifest().artifact("k_sk_linear").unwrap().clone();
    let mut rng = Philox::seeded(33);
    let inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| HostTensor::randn(&s.shape, 0.3, &mut rng))
        .collect();
    let kernel_out = rt.execute("k_sk_linear", &inputs).unwrap();
    // Rust path.
    let (x, u, v, b) = (&inputs[0], &inputs[1], &inputs[2], &inputs[3]);
    let l = u.shape()[0];
    let (d_in, k) = (u.shape()[1], u.shape()[2]);
    let d_out = v.shape()[2];
    let mut expect = panther::linalg::Mat::zeros(x.shape()[0], d_out);
    for j in 0..l {
        let uj = panther::linalg::Mat::from_vec(
            d_in,
            k,
            u.data()[j * d_in * k..(j + 1) * d_in * k].to_vec(),
        );
        let vj = panther::linalg::Mat::from_vec(
            k,
            d_out,
            v.data()[j * k * d_out..(j + 1) * k * d_out].to_vec(),
        );
        expect.axpy(
            1.0 / l as f32,
            &panther::linalg::matmul(&panther::linalg::matmul(&x.to_mat(), &uj), &vj),
        );
    }
    for i in 0..expect.rows() {
        for (val, bb) in expect.row_mut(i).iter_mut().zip(b.data()) {
            *val += bb;
        }
    }
    let err = panther::linalg::rel_error(&kernel_out[0].to_mat(), &expect);
    assert!(err < 1e-4, "kernel vs rust: {err}");
}

/// Training is deterministic given seeds: two runs produce identical loss.
#[test]
fn training_is_reproducible() {
    let Some(dir) = artifacts_dir() else { return };
    let corpus = TextCorpus::generate(256, 20_000, 3);
    let run = || {
        let mut rt = Runtime::open(&dir).unwrap();
        let mut state = ModelState::init(&mut rt, "bert_dense", 7.0).unwrap();
        let mut trainer = BertTrainer::new(&mut rt, &corpus);
        let mut rng = Philox::seeded(42);
        trainer.train(&mut state, 3, &mut rng).unwrap().final_loss
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seeds must give identical losses");
}

/// Checkpoint round-trip preserves training state exactly: resuming from a
/// checkpoint produces the same losses as continuing.
#[test]
fn checkpoint_resume_is_exact() {
    let Some(dir) = artifacts_dir() else { return };
    let corpus = TextCorpus::generate(256, 20_000, 4);
    let mut rt = Runtime::open(&dir).unwrap();
    let mut state = ModelState::init(&mut rt, "bert_dense", 1.0).unwrap();
    let mut rng = Philox::seeded(9);
    {
        let mut trainer = BertTrainer::new(&mut rt, &corpus);
        trainer.train(&mut state, 2, &mut rng).unwrap();
    }
    let path = std::env::temp_dir().join("panther_integ.ckpt");
    checkpoint::save(&state, &path).unwrap();

    // Continue directly.
    let mut rng_a = rng.clone();
    let cont = {
        let mut trainer = BertTrainer::new(&mut rt, &corpus);
        trainer.train(&mut state, 2, &mut rng_a).unwrap().final_loss
    };
    // Resume from checkpoint.
    let mut resumed = checkpoint::load(&path).unwrap();
    assert_eq!(resumed.step, 2);
    let mut rng_b = rng.clone();
    let res = {
        let mut trainer = BertTrainer::new(&mut rt, &corpus);
        trainer
            .train(&mut resumed, 2, &mut rng_b)
            .unwrap()
            .final_loss
    };
    assert_eq!(cont, res, "resume must match continuation exactly");
    std::fs::remove_file(path).ok();
}

/// Conv family end-to-end: a few steps of training measurably beats chance.
#[test]
fn conv_learns_above_chance_quickly() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let ds = ImageDataset::cifar_like();
    let mut state = ModelState::init(&mut rt, "conv_dense", 3.0).unwrap();
    let mut trainer = ConvTrainer::new(&mut rt, &ds);
    let mut rng = Philox::seeded(21);
    // The dataset is calibrated hard (high noise) for the §4.2 case study,
    // so give training enough steps to clear chance decisively.
    trainer.train(&mut state, 200, &mut rng).unwrap();
    let acc = trainer.accuracy(&state, 8, &mut rng).unwrap();
    assert!(acc > 0.25, "accuracy {acc} should beat 10% chance");
}

/// Coordinator under concurrent load: many threads, no lost replies, queue
/// drains, metrics consistent.
#[test]
fn coordinator_sustains_concurrent_load() {
    let Some(dir) = artifacts_dir() else { return };
    let server = RuntimeServer::start(dir).unwrap();
    let spec = server
        .handle()
        .manifest()
        .artifact("k_sk_linear")
        .unwrap()
        .clone();
    let n_threads = 8;
    let per_thread = 5;
    let handles: Vec<_> = (0..n_threads)
        .map(|t| {
            let h = server.handle();
            let spec = spec.clone();
            std::thread::spawn(move || {
                let mut rng = Philox::seeded(t as u64);
                for _ in 0..per_thread {
                    let inputs: Vec<HostTensor> = spec
                        .inputs
                        .iter()
                        .map(|s| HostTensor::randn(&s.shape, 0.1, &mut rng))
                        .collect();
                    let out = h.execute("k_sk_linear", inputs).unwrap();
                    assert_eq!(out.len(), 1);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let stats = server.metrics().artifact_stats("k_sk_linear").unwrap();
    assert_eq!(stats.count as usize, n_threads * per_thread);
    assert_eq!(stats.errors, 0);
    assert_eq!(server.handle().queue_depth(), 0);
}

/// The manifest's declared model param_count matches what init returns.
#[test]
fn manifest_param_counts_are_truthful() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    for model in ["bert_dense", "bert_sk_1_8", "conv_dense", "conv_sk_1_8"] {
        let spec = rt.manifest().model(model).unwrap().clone();
        let state = ModelState::init(&mut rt, model, 0.0).unwrap();
        assert_eq!(
            state.param_count(),
            spec.param_count,
            "param_count mismatch for {model}"
        );
    }
}

/// Headline claim of §4.2: the sketched BERT variant is ≥70% smaller.
#[test]
fn sketched_bert_hits_paper_reduction() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::open(&dir).unwrap();
    let dense = rt.manifest().model("bert_dense").unwrap().param_count;
    let sk = rt.manifest().model("bert_sk_1_8").unwrap().param_count;
    let reduction = 1.0 - sk as f64 / dense as f64;
    assert!(
        reduction > 0.70,
        "reduction {reduction:.3} below the paper's ~75%"
    );
}
