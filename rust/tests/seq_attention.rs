//! Sequence-native attention: structural masking and the tiled
//! recomputing backward.
//!
//! Three families of guarantees, each locked down bitwise or against an
//! f64 oracle:
//!
//! 1. **Masking is structural, not additive.** A full-length [`SeqBatch`]
//!    is bitwise-identical to no batch at all (the masked path re-describes
//!    the same matrices and runs the same batched products), and a packed
//!    multi-sequence batch reproduces each sequence's standalone forward
//!    bit for bit at sub-packing-threshold shapes — no −∞ biasing, no
//!    epsilon leak, pad rows exactly zero.
//! 2. **The tiled backward computes the materializing gradient.** A pure
//!    f64 oracle materializes the full probability tensor and checks the
//!    row-dot identity `Σ_j dP_ij·P_ij = Σ_c dO_ic·O_ic` the tiled path
//!    relies on to ≤ 1e-12; the crate's f32 tiled backward matches the
//!    oracle at f32-appropriate norms, and different tile widths agree
//!    with each other.
//! 3. **Peak backward memory scales with the tile, not n².** Measured by
//!    [`MemTracker`], matched exactly against
//!    [`panther::nn::cost::dense_attention_bwd_mem`], and proven by
//!    running under a budget the h·n×n materializing backward could not
//!    fit.
//!
//! Plus finite-difference gradchecks of both attention variants *under
//! masking* — the gradients the serving/training stack actually uses for
//! variable-length batches.

use panther::linalg::Mat;
use panther::nn::{
    AttnWeights, ForwardCtx, KernelKind, Module, MultiHeadAttention, RandMultiHeadAttention,
    SeqBatch,
};
use panther::rng::Philox;
use panther::util::memtrack::MemTracker;

fn attn(seed: u64) -> MultiHeadAttention {
    let mut rng = Philox::seeded(seed);
    MultiHeadAttention::new(AttnWeights::random(8, 2, &mut rng))
}

fn performer(seed: u64, kernel: KernelKind) -> RandMultiHeadAttention {
    let mut rng = Philox::seeded(seed);
    RandMultiHeadAttention::new(AttnWeights::random(8, 2, &mut rng), 16, kernel, 97)
}

/// Copy rows `r0..r1` of `x` into a fresh matrix.
fn rows_of(x: &Mat, r0: usize, r1: usize) -> Mat {
    let mut m = Mat::zeros(r1 - r0, x.cols());
    for r in r0..r1 {
        m.row_mut(r - r0).copy_from_slice(x.row(r));
    }
    m
}

fn vec_rel_err(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut d2 = 0f64;
    let mut na = 0f64;
    let mut nb = 0f64;
    for (&x, &y) in a.iter().zip(b) {
        d2 += (x as f64 - y as f64).powi(2);
        na += (x as f64).powi(2);
        nb += (y as f64).powi(2);
    }
    d2.sqrt() / na.sqrt().max(nb.sqrt()).max(1e-8)
}

// ---------------------------------------------------------------------
// 1. Structural masking: bitwise guarantees.
// ---------------------------------------------------------------------

#[test]
fn full_length_seq_batch_is_bitwise_identical_to_unmasked() {
    let mut rng = Philox::seeded(11);
    let x = Mat::randn(6, 8, &mut rng).scale(0.5);
    let plain = ForwardCtx::new();
    let masked = ForwardCtx::new().with_seq(SeqBatch::single(6));
    // Dense attention: forward, forward_train output, and the gradients
    // of an identical backward all match bit for bit.
    let mut a_p = attn(21);
    let mut a_m = attn(21);
    let y_p = a_p.forward(&x, &plain).unwrap();
    let y_m = a_m.forward(&x, &masked).unwrap();
    assert_eq!(y_p.data(), y_m.data(), "dense forward");
    let (t_p, c_p) = a_p.forward_train(&x, &plain).unwrap();
    let (t_m, c_m) = a_m.forward_train(&x, &masked).unwrap();
    assert_eq!(t_p.data(), t_m.data(), "dense forward_train");
    let g = Mat::randn(6, 8, &mut Philox::seeded(31));
    a_p.zero_grads();
    a_m.zero_grads();
    let dx_p = a_p.backward(&g, &c_p, &plain).unwrap();
    let dx_m = a_m.backward(&g, &c_m, &masked).unwrap();
    assert_eq!(dx_p.data(), dx_m.data(), "dense grad_in");
    for ((np, gp), (nm, gm)) in a_p.grads().into_iter().zip(a_m.grads()) {
        assert_eq!(np, nm);
        assert_eq!(gp, gm, "dense grad {np}");
    }
    // Performer (both kernels): forward bitwise.
    for kernel in [KernelKind::Softmax, KernelKind::Relu] {
        let p = performer(22, kernel);
        let y_p = p.forward(&x, &plain).unwrap();
        let y_m = p.forward(&x, &masked).unwrap();
        assert_eq!(y_p.data(), y_m.data(), "performer {kernel:?} forward");
    }
}

#[test]
fn packed_sequences_reproduce_standalone_forwards_bitwise() {
    // Shapes kept under the GEMM packing threshold so per-row kernel
    // results are independent of co-resident rows — the precondition for
    // a bitwise (not just numerical) claim.
    let lens = [3usize, 5, 4];
    let n: usize = lens.iter().sum();
    let mut rng = Philox::seeded(41);
    let x = Mat::randn(n, 8, &mut rng).scale(0.5);
    let sb = SeqBatch::packed(lens.to_vec()).unwrap();
    let packed_ctx = ForwardCtx::new().with_seq(sb.clone());

    let a = attn(51);
    let y = a.forward(&x, &packed_ctx).unwrap();
    for (off, len) in sb.segments() {
        let xi = rows_of(&x, off, off + len);
        let yi = a
            .forward(&xi, &ForwardCtx::new().with_seq(SeqBatch::single(len)))
            .unwrap();
        for i in 0..len {
            assert_eq!(
                y.row(off + i),
                yi.row(i),
                "dense: packed row {} diverges from standalone seq at {off}+{i}",
                off + i
            );
        }
    }

    for kernel in [KernelKind::Softmax, KernelKind::Relu] {
        let p = performer(52, kernel);
        let y = p.forward(&x, &packed_ctx).unwrap();
        for (off, len) in sb.segments() {
            let xi = rows_of(&x, off, off + len);
            let yi = p
                .forward(&xi, &ForwardCtx::new().with_seq(SeqBatch::single(len)))
                .unwrap();
            for i in 0..len {
                assert_eq!(
                    y.row(off + i),
                    yi.row(i),
                    "performer {kernel:?}: packed row {} diverges at {off}+{i}",
                    off + i
                );
            }
        }
    }
}

#[test]
fn padded_batches_match_packed_and_zero_their_pad_rows() {
    // Two sequences (3 and 2 valid tokens) at stride 4: rows 3 and 7 are
    // padding. Valid rows must equal the standalone per-sequence forward;
    // pad rows must be *exactly* zero — the FAVOR+ denominator and the
    // softmax rows alike must never have seen them.
    let lens = vec![3usize, 2];
    let stride = 4;
    let sb = SeqBatch::padded(lens.clone(), stride).unwrap();
    assert_eq!(sb.total_rows(), 8);
    assert_eq!(sb.token_mask(), vec![1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 0.0, 0.0]);
    let mut rng = Philox::seeded(61);
    // Pad rows get conspicuously large garbage: if any product reads
    // them, the output will show it.
    let mut x = Mat::randn(8, 8, &mut rng).scale(0.5);
    for &r in &[3usize, 7] {
        for v in x.row_mut(r) {
            *v = 1e3;
        }
    }
    let ctx = ForwardCtx::new().with_seq(sb.clone());

    let a = attn(71);
    let y = a.forward(&x, &ctx).unwrap();
    for (off, len) in sb.segments() {
        let xi = rows_of(&x, off, off + len);
        let yi = a
            .forward(&xi, &ForwardCtx::new().with_seq(SeqBatch::single(len)))
            .unwrap();
        for i in 0..len {
            assert_eq!(y.row(off + i), yi.row(i), "dense valid row {}", off + i);
        }
    }
    for &r in &[3usize, 7] {
        assert!(y.row(r).iter().all(|&v| v == 0.0), "dense pad row {r} not zero");
    }

    for kernel in [KernelKind::Softmax, KernelKind::Relu] {
        let p = performer(72, kernel);
        let y = p.forward(&x, &ctx).unwrap();
        for (off, len) in sb.segments() {
            let xi = rows_of(&x, off, off + len);
            let yi = p
                .forward(&xi, &ForwardCtx::new().with_seq(SeqBatch::single(len)))
                .unwrap();
            for i in 0..len {
                assert_eq!(
                    y.row(off + i),
                    yi.row(i),
                    "performer {kernel:?} valid row {}",
                    off + i
                );
            }
        }
        for &r in &[3usize, 7] {
            assert!(
                y.row(r).iter().all(|&v| v == 0.0),
                "performer {kernel:?} pad row {r} not zero"
            );
        }
    }
}

// ---------------------------------------------------------------------
// 2. Tiled backward vs a materializing f64 oracle.
// ---------------------------------------------------------------------

type M64 = Vec<Vec<f64>>;

fn to64(m: &Mat) -> M64 {
    (0..m.rows())
        .map(|i| m.row(i).iter().map(|&v| v as f64).collect())
        .collect()
}

fn zeros64(r: usize, c: usize) -> M64 {
    vec![vec![0.0; c]; r]
}

/// `alpha·a·b + c` where either factor may be transposed.
fn mm64(alpha: f64, a: &M64, ta: bool, b: &M64, tb: bool) -> M64 {
    let (m, ka) = if ta { (a[0].len(), a.len()) } else { (a.len(), a[0].len()) };
    let (kb, n) = if tb { (b[0].len(), b.len()) } else { (b.len(), b[0].len()) };
    assert_eq!(ka, kb);
    let mut c = zeros64(m, n);
    for i in 0..m {
        for p in 0..ka {
            let av = if ta { a[p][i] } else { a[i][p] };
            for j in 0..n {
                let bv = if tb { b[j][p] } else { b[p][j] };
                c[i][j] += alpha * av * bv;
            }
        }
    }
    c
}

fn add64(a: &mut M64, b: &M64) {
    for (ra, rb) in a.iter_mut().zip(b) {
        for (va, vb) in ra.iter_mut().zip(rb) {
            *va += vb;
        }
    }
}

fn flat32(a: &M64) -> Vec<f32> {
    a.iter().flat_map(|r| r.iter().map(|&v| v as f32)).collect()
}

fn cols64(a: &M64, c0: usize, c1: usize) -> M64 {
    a.iter().map(|r| r[c0..c1].to_vec()).collect()
}

struct OracleGrads {
    dwq: M64,
    dwk: M64,
    dwv: M64,
    dwo: M64,
    dx: M64,
    /// Largest gap between the materializing row-dot `Σ_j dP_ij·P_ij` and
    /// the output identity `Σ_c dO_ic·O_ic` the tiled backward uses.
    identity_gap: f64,
}

/// The reference the tiled backward must reproduce: dense multi-head
/// attention backward in f64, materializing the full per-head probability
/// tensor and its gradient — the O(h·n²) scheme the crate deliberately
/// avoids.
fn oracle_backward(x: &Mat, w: &AttnWeights, g: &Mat) -> OracleGrads {
    let (h, d) = (w.num_heads, w.embed_dim);
    let dh = d / h;
    let scale = 1.0 / (dh as f64).sqrt();
    let (x, g) = (to64(x), to64(g));
    let (wq, wk, wv, wo) = (to64(&w.wq), to64(&w.wk), to64(&w.wv), to64(&w.wo));
    let n = x.len();
    let q = mm64(1.0, &x, false, &wq, false);
    let k = mm64(1.0, &x, false, &wk, false);
    let v = mm64(1.0, &x, false, &wv, false);
    let mut concat = zeros64(n, d);
    let mut probs: Vec<M64> = Vec::with_capacity(h);
    for head in 0..h {
        let (c0, c1) = (head * dh, (head + 1) * dh);
        let (qh, kh, vh) = (cols64(&q, c0, c1), cols64(&k, c0, c1), cols64(&v, c0, c1));
        let mut p = mm64(scale, &qh, false, &kh, true);
        for row in p.iter_mut() {
            let mx = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            let mut s = 0.0;
            for e in row.iter_mut() {
                *e = (*e - mx).exp();
                s += *e;
            }
            for e in row.iter_mut() {
                *e /= s;
            }
        }
        let oh = mm64(1.0, &p, false, &vh, false);
        for i in 0..n {
            concat[i][c0..c1].copy_from_slice(&oh[i]);
        }
        probs.push(p);
    }
    let dwo = mm64(1.0, &concat, true, &g, false);
    let dconcat = mm64(1.0, &g, false, &wo, true);
    let mut dq = zeros64(n, d);
    let mut dk = zeros64(n, d);
    let mut dv = zeros64(n, d);
    let mut identity_gap = 0.0f64;
    for head in 0..h {
        let (c0, c1) = (head * dh, (head + 1) * dh);
        let (qh, kh, vh) = (cols64(&q, c0, c1), cols64(&k, c0, c1), cols64(&v, c0, c1));
        let doh = cols64(&dconcat, c0, c1);
        let oh = cols64(&concat, c0, c1);
        let p = &probs[head];
        let dp = mm64(1.0, &doh, false, &vh, true);
        // The row-dot both ways: materializing (over the n-wide dP·P row)
        // and the output identity (over the dh-wide dO·O row) — these are
        // equal because O = P·V, and the tiled backward banks on it.
        let mut ds = zeros64(n, n);
        for i in 0..n {
            let d_mat: f64 = dp[i].iter().zip(&p[i]).map(|(a, b)| a * b).sum();
            let d_ident: f64 = doh[i].iter().zip(&oh[i]).map(|(a, b)| a * b).sum();
            identity_gap = identity_gap.max((d_mat - d_ident).abs());
            for j in 0..n {
                ds[i][j] = p[i][j] * (dp[i][j] - d_mat);
            }
        }
        let dqh = mm64(scale, &ds, false, &kh, false);
        let dkh = mm64(scale, &ds, true, &qh, false);
        let dvh = mm64(1.0, p, true, &doh, false);
        for i in 0..n {
            dq[i][c0..c1].copy_from_slice(&dqh[i]);
            dk[i][c0..c1].copy_from_slice(&dkh[i]);
            dv[i][c0..c1].copy_from_slice(&dvh[i]);
        }
    }
    let dwq = mm64(1.0, &x, true, &dq, false);
    let dwk = mm64(1.0, &x, true, &dk, false);
    let dwv = mm64(1.0, &x, true, &dv, false);
    let mut dx = mm64(1.0, &dq, false, &wq, true);
    add64(&mut dx, &mm64(1.0, &dk, false, &wk, true));
    add64(&mut dx, &mm64(1.0, &dv, false, &wv, true));
    OracleGrads {
        dwq,
        dwk,
        dwv,
        dwo,
        dx,
        identity_gap,
    }
}

/// Run the crate's tiled backward at tile width `tile` and return
/// `(grad_in, [(name, grad)])`.
fn crate_backward(seed: u64, tile: usize, x: &Mat, g: &Mat) -> (Mat, Vec<(String, Vec<f32>)>) {
    let mut a = attn(seed).with_backward_tile(tile);
    let ctx = ForwardCtx::new();
    let (_, cache) = a.forward_train(x, &ctx).unwrap();
    a.zero_grads();
    let dx = a.backward(g, &cache, &ctx).unwrap();
    let grads = a
        .grads()
        .into_iter()
        .map(|(n, v)| (n, v.to_vec()))
        .collect();
    (dx, grads)
}

#[test]
fn tiled_backward_matches_f64_materializing_oracle() {
    let mut rng = Philox::seeded(81);
    let n = 10;
    let x = Mat::randn(n, 8, &mut rng).scale(0.5);
    let g = Mat::randn(n, 8, &mut Philox::seeded(82));
    let model = attn(91);
    let oracle = oracle_backward(&x, &model.weights, &g);
    // The row-dot identity the tiled recomputation rests on is exact (to
    // f64 roundoff) — this is the algebra that lets backward skip the
    // second pass over each probability tile.
    assert!(
        oracle.identity_gap <= 1e-12,
        "row-dot identity gap {:.2e}",
        oracle.identity_gap
    );
    let want = [
        ("wq", flat32(&oracle.dwq)),
        ("wk", flat32(&oracle.dwk)),
        ("wv", flat32(&oracle.dwv)),
        ("wo", flat32(&oracle.dwo)),
    ];
    // Tile widths spanning one key at a time, a partial tile (3 ∤ 10),
    // and single-tile (≥ n, the materializing schedule).
    for tile in [1usize, 3, 64] {
        let (dx, grads) = crate_backward(91, tile, &x, &g);
        for ((name, got), (wname, w)) in grads.iter().zip(&want) {
            assert_eq!(name, wname);
            let err = vec_rel_err(got, w);
            assert!(err < 2e-4, "tile {tile} grad {name}: rel err {err:.2e}");
        }
        let err = vec_rel_err(dx.data(), &flat32(&oracle.dx));
        assert!(err < 2e-4, "tile {tile} grad_in: rel err {err:.2e}");
    }
    // Different tilings of the same gradient agree with each other far
    // inside the oracle tolerance (only f32 summation order differs).
    let (dx_a, g_a) = crate_backward(91, 3, &x, &g);
    let (dx_b, g_b) = crate_backward(91, 64, &x, &g);
    assert!(vec_rel_err(dx_a.data(), dx_b.data()) < 1e-5);
    for ((_, a), (_, b)) in g_a.iter().zip(&g_b) {
        assert!(vec_rel_err(a, b) < 1e-5);
    }
}

#[test]
fn masked_backward_matches_per_sequence_oracle() {
    // A packed two-sequence batch must produce, for every parameter, the
    // *sum* of the two standalone oracles, and a grad_in that is the two
    // standalone grad_ins stacked.
    let lens = [4usize, 6];
    let n: usize = lens.iter().sum();
    let x = Mat::randn(n, 8, &mut Philox::seeded(83)).scale(0.5);
    let g = Mat::randn(n, 8, &mut Philox::seeded(84));
    let model = attn(92);
    let mut want: Option<[M64; 4]> = None;
    let mut want_dx: Vec<f32> = Vec::new();
    let mut off = 0;
    for &len in &lens {
        let o = oracle_backward(
            &rows_of(&x, off, off + len),
            &model.weights,
            &rows_of(&g, off, off + len),
        );
        want_dx.extend(flat32(&o.dx));
        match &mut want {
            None => want = Some([o.dwq, o.dwk, o.dwv, o.dwo]),
            Some(acc) => {
                add64(&mut acc[0], &o.dwq);
                add64(&mut acc[1], &o.dwk);
                add64(&mut acc[2], &o.dwv);
                add64(&mut acc[3], &o.dwo);
            }
        }
        off += len;
    }
    let want = want.unwrap();
    let mut a = attn(92).with_backward_tile(3);
    let ctx = ForwardCtx::new().with_seq(SeqBatch::packed(lens.to_vec()).unwrap());
    let (_, cache) = a.forward_train(&x, &ctx).unwrap();
    a.zero_grads();
    let dx = a.backward(&g, &cache, &ctx).unwrap();
    for ((name, got), w) in a.grads().into_iter().zip(&want) {
        let err = vec_rel_err(got, &flat32(w));
        assert!(err < 2e-4, "masked grad {name}: rel err {err:.2e}");
    }
    let err = vec_rel_err(dx.data(), &want_dx);
    assert!(err < 2e-4, "masked grad_in: rel err {err:.2e}");
}

// ---------------------------------------------------------------------
// 3. Peak backward memory: O(h·n·T), not O(h·n²).
// ---------------------------------------------------------------------

/// Forward on an untracked context, backward on a tracked one: the
/// measured peak is the backward's transient footprint alone.
fn backward_peak(n: usize, tile: usize, tracker: MemTracker) -> panther::Result<u64> {
    let mut a = attn(101).with_backward_tile(tile);
    let x = Mat::randn(n, 8, &mut Philox::seeded(102)).scale(0.5);
    let g = Mat::randn(n, 8, &mut Philox::seeded(103));
    let (_, cache) = a.forward_train(&x, &ForwardCtx::new())?;
    a.zero_grads();
    let ctx = ForwardCtx::with_tracker(tracker.clone());
    a.backward(&g, &cache, &ctx)?;
    Ok(tracker.peak_bytes())
}

#[test]
fn backward_peak_scales_with_tile_width_not_sequence_length() {
    use panther::nn::cost::dense_attention_bwd_mem;
    let (d, h, n) = (8usize, 2usize, 256usize);
    // The measured peak is exactly the model the cost module advertises —
    // serve-tier admission and the bench report both read this formula.
    for tile in [8usize, 64] {
        let t = MemTracker::unlimited();
        let peak = backward_peak(n, tile, t).unwrap();
        assert_eq!(
            peak,
            dense_attention_bwd_mem(n, d, h, tile),
            "tile {tile}: peak vs cost model"
        );
    }
    let peak8 = backward_peak(n, 8, MemTracker::unlimited()).unwrap();
    let peak64 = backward_peak(n, 64, MemTracker::unlimited()).unwrap();
    assert!(peak8 < peak64, "peak must grow with tile width");
    // Doubling n at fixed tile grows the peak linearly (≈2×), nowhere
    // near the 4× a materialized h·n×n tensor would show.
    let peak8_2n = backward_peak(2 * n, 8, MemTracker::unlimited()).unwrap();
    let ratio = peak8_2n as f64 / peak8 as f64;
    assert!(
        ratio < 3.0,
        "peak grew {ratio:.2}x for 2x sequence length (quadratic would be ~4x)"
    );
    // The decisive check: run the whole backward under a budget the old
    // materializing path could not even hold its probability tensor in.
    let prob_tensor = (h * n * n * 4) as u64; // 512 KiB at these shapes
    let budget = dense_attention_bwd_mem(n, d, h, 8);
    assert!(
        budget < prob_tensor,
        "test must be discriminating: {budget} < {prob_tensor}"
    );
    backward_peak(n, 8, MemTracker::with_budget(budget))
        .expect("tiled backward must fit a budget below the h*n*n tensor");
    // And the accounting is honest: one byte less fails cleanly.
    assert!(backward_peak(n, 8, MemTracker::with_budget(budget - 1)).is_err());
}

// ---------------------------------------------------------------------
// 4. Finite-difference gradchecks under masking.
// ---------------------------------------------------------------------

const EPS: f32 = 1e-2;

fn weighted_loss(y: &Mat, w: &Mat) -> f64 {
    y.data()
        .iter()
        .zip(w.data())
        .map(|(&a, &b)| a as f64 * b as f64)
        .sum()
}

fn nudge(module: &mut dyn Module, name: &str, i: usize, delta: f32) {
    for (pn, mut p) in module.params_mut() {
        if pn == name {
            p.data_mut()[i] += delta;
        }
    }
    module.on_params_loaded();
}

/// The gradcheck harness from `tests/gradcheck.rs`, with the forward
/// context (and its [`SeqBatch`]) threaded through every evaluation.
fn gradcheck_ctx(module: &mut dyn Module, x: &Mat, ctx: &ForwardCtx, seed: u64, tol: f64) {
    let (y, cache) = module.forward_train(x, ctx).unwrap();
    let y_plain = module.forward(x, ctx).unwrap();
    assert!(
        vec_rel_err(y.data(), y_plain.data()) < 1e-6,
        "forward_train diverges from forward under masking"
    );
    let w = Mat::randn(y.rows(), y.cols(), &mut Philox::seeded(seed));
    module.zero_grads();
    let grad_in = module.backward(&w, &cache, ctx).unwrap();
    let analytic: Vec<(String, Vec<f32>)> = module
        .grads()
        .into_iter()
        .map(|(n, g)| (n, g.to_vec()))
        .collect();
    for (name, got) in &analytic {
        let mut fd = Vec::with_capacity(got.len());
        for i in 0..got.len() {
            nudge(module, name, i, EPS);
            let lp = weighted_loss(&module.forward(x, ctx).unwrap(), &w);
            nudge(module, name, i, -2.0 * EPS);
            let lm = weighted_loss(&module.forward(x, ctx).unwrap(), &w);
            nudge(module, name, i, EPS);
            fd.push(((lp - lm) / (2.0 * EPS as f64)) as f32);
        }
        let err = vec_rel_err(got, &fd);
        assert!(err < tol, "param {name}: FD vs analytic rel err {err:.2e}");
    }
    let mut fd_x = Vec::with_capacity(x.len());
    let mut xp = x.clone();
    for i in 0..x.len() {
        let orig = xp.data()[i];
        xp.data_mut()[i] = orig + EPS;
        let lp = weighted_loss(&module.forward(&xp, ctx).unwrap(), &w);
        xp.data_mut()[i] = orig - EPS;
        let lm = weighted_loss(&module.forward(&xp, ctx).unwrap(), &w);
        xp.data_mut()[i] = orig;
        fd_x.push(((lp - lm) / (2.0 * EPS as f64)) as f32);
    }
    let err = vec_rel_err(grad_in.data(), &fd_x);
    assert!(err < tol, "input: FD vs analytic rel err {err:.2e}");
}

#[test]
fn masked_gradcheck_multi_head_attention() {
    // Packed [3, 2]: cross-sequence coupling would show up immediately as
    // an FD mismatch on the off-diagonal input blocks.
    let mut a = attn(111);
    let x = Mat::randn(5, 8, &mut Philox::seeded(112)).scale(0.5);
    let ctx = ForwardCtx::new().with_seq(SeqBatch::packed(vec![3, 2]).unwrap());
    gradcheck_ctx(&mut a, &x, &ctx, 113, 1e-3);
}

#[test]
fn masked_gradcheck_multi_head_attention_padded() {
    // Padded [3, 2] at stride 4: pad rows (3 and 7) must carry exactly
    // zero analytic input gradient, and FD agrees because their forward
    // output is structurally zero regardless of their value.
    let mut a = attn(114);
    let x = Mat::randn(8, 8, &mut Philox::seeded(115)).scale(0.5);
    let sb = SeqBatch::padded(vec![3, 2], 4).unwrap();
    let ctx = ForwardCtx::new().with_seq(sb);
    gradcheck_ctx(&mut a, &x, &ctx, 116, 1e-3);
    // Re-run backward to inspect the pad-row gradient directly.
    let (_, cache) = a.forward_train(&x, &ctx).unwrap();
    a.zero_grads();
    let g = Mat::randn(8, 8, &mut Philox::seeded(117));
    let dx = a.backward(&g, &cache, &ctx).unwrap();
    for &r in &[3usize, 7] {
        assert!(
            dx.row(r).iter().all(|&v| v == 0.0),
            "pad row {r} received input gradient"
        );
    }
}

#[test]
fn masked_gradcheck_performer_softmax_kernel() {
    let mut p = performer(121, KernelKind::Softmax);
    let x = Mat::randn(5, 8, &mut Philox::seeded(122)).scale(0.4);
    let ctx = ForwardCtx::new().with_seq(SeqBatch::packed(vec![3, 2]).unwrap());
    gradcheck_ctx(&mut p, &x, &ctx, 123, 1e-3);
}

#[test]
fn masked_gradcheck_performer_relu_kernel() {
    // Same ReLU-kink FD caveat as the unmasked gradcheck: the loosened
    // tolerance covers probe points straddling a feature-map kink, while
    // remaining far below the O(1) error a masking bug would produce.
    let mut p = performer(124, KernelKind::Relu);
    let x = Mat::randn(5, 8, &mut Philox::seeded(125)).scale(0.4);
    let ctx = ForwardCtx::new().with_seq(SeqBatch::packed(vec![3, 2]).unwrap());
    gradcheck_ctx(&mut p, &x, &ctx, 126, 2e-2);
}
