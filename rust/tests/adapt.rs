//! Integration tests for online rank adaptation (`panther::serve::adapt`)
//! and the atomic versioned-layer hot-swap underneath it.
//!
//! The contracts under test, end to end through the server:
//!
//! - **Atomicity**: a hot-swap under concurrent traffic drops nothing and
//!   corrupts nothing. Every request admitted before the swap replies
//!   from the *old* model bit for bit; every request admitted after
//!   replies from the *new* model bit for bit; requests racing the swap
//!   reply from exactly one of the two (never a torn batch).
//! - **Determinism**: the model the adapter publishes at rank `r` is
//!   bitwise identical to a standalone model sketched with the same
//!   plan seed at the same rank — per-layer seeds derive from the layer
//!   name, not build order.
//! - **Evidence-driven routing**: once the adapter measures a tier's
//!   real quality, the cascade orders its ladder by the measurement; a
//!   tier whose measured error crosses a request's `min_quality` floor
//!   stops receiving floored requests entirely.
//!
//! Batch caps stay ≤ 4 (under the GEMM microkernel height) so the
//! bitwise serving oracle of `tests/serve.rs` applies throughout.

use panther::linalg::Mat;
use panther::nn::{Activation, ForwardCtx, LayerSelector, Linear, Model, SketchPlan};
use panther::rng::Philox;
use panther::serve::{
    AdaptConfig, AdaptDecision, Cascade, ModelServer, RankAdapter, ServeError, Slo, TierConfig,
};
use std::sync::Arc;
use std::time::Duration;

/// The same nonlinear row-independent stack as `tests/serve.rs`, biases
/// nonzero so padding leaks would be visible.
fn mlp(seed: u64, d_in: usize, d_out: usize) -> Model {
    let mut rng = Philox::seeded(seed);
    let mut m = Model::new();
    let mut fc1 = Linear::random(d_in, 12, &mut rng);
    for b in fc1.bias.iter_mut() {
        *b = 0.3;
    }
    m.add("fc1", fc1).unwrap();
    m.add("act", Activation::gelu()).unwrap();
    let mut fc2 = Linear::random(12, d_out, &mut rng);
    for b in fc2.bias.iter_mut() {
        *b = -0.2;
    }
    m.add("fc2", fc2).unwrap();
    m
}

/// Rank-4 sketch of the same stack under plan seed 23 — rebuilt calls
/// are bitwise-identical twins (deterministic per-layer seed derivation).
fn sk4(seed: u64, d_in: usize, d_out: usize) -> Model {
    let mut m = mlp(seed, d_in, d_out);
    SketchPlan::new()
        .select(LayerSelector::by_type("Linear"))
        .with(1, 4)
        .seed(23)
        .apply(&mut m)
        .unwrap();
    m
}

/// A model that answers every row with a constant far outside the mlp's
/// output range: guaranteed (clamped) relative error 1 against any mlp
/// reference — the deterministic "quality collapsed" stand-in.
fn garbage(d_in: usize, d_out: usize) -> Model {
    let mut m = Model::new();
    m.add(
        "fc",
        Linear::new(Mat::zeros(d_out, d_in), vec![1.0e6; d_out]),
    )
    .unwrap();
    m
}

fn request_rows(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
    (0..n)
        .map(|i| Mat::randn(1, d, &mut Philox::seeded(seed + i as u64)).into_vec())
        .collect()
}

fn solo_forward(model: &Model, row: &[f32]) -> Vec<f32> {
    model
        .forward(&Mat::from_vec(1, row.len(), row.to_vec()), &ForwardCtx::new())
        .unwrap()
        .row(0)
        .to_vec()
}

#[test]
fn hot_swap_under_concurrent_traffic_is_atomic_and_lossless() {
    let (d, k) = (12usize, 5usize);
    let old_oracle = Arc::new(mlp(90, d, k));
    let new_oracle = Arc::new(sk4(90, d, k));
    let mut server = ModelServer::new();
    server
        .register_tier(
            "t",
            mlp(90, d, k),
            d,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(300),
                queue_cap: 2048,
                workers: 3,
                ..TierConfig::default()
            },
        )
        .unwrap();
    // Hammer threads race the swap: every reply must be bitwise one of
    // the two versions — a mixed batch would match neither oracle.
    let (n_threads, m_requests) = (6usize, 40usize);
    let hammers: Vec<_> = (0..n_threads)
        .map(|t| {
            let h = server.handle();
            let (old, new) = (Arc::clone(&old_oracle), Arc::clone(&new_oracle));
            std::thread::spawn(move || {
                for i in 0..m_requests {
                    let seed = 7000 + (t * m_requests + i) as u64;
                    let row = Mat::randn(1, d, &mut Philox::seeded(seed)).into_vec();
                    let got = h.infer("t", &row).unwrap();
                    let (want_old, want_new) =
                        (solo_forward(&old, &row), solo_forward(&new, &row));
                    assert!(
                        got == want_old || got == want_new,
                        "seed {seed}: reply matches neither model version — torn batch"
                    );
                }
            })
        })
        .collect();
    // Admit a wave of requests, then swap: everything admitted before
    // the publish must reply from the old version, even though it drains
    // after the new one is live.
    let h = server.handle();
    let pre_rows = request_rows(10, d, 8800);
    let pre_pending: Vec<_> = pre_rows
        .iter()
        .map(|row| h.submit("t", row).unwrap())
        .collect();
    let version = server.swap_tier_model("t", sk4(90, d, k)).unwrap();
    assert_eq!(version, 1, "first publish after the registration model");
    for (p, row) in pre_pending.into_iter().zip(&pre_rows) {
        assert_eq!(
            p.wait().unwrap(),
            solo_forward(&old_oracle, row),
            "pre-swap-admitted request must reply from the old version"
        );
    }
    // Everything admitted after the swap returned serves the new model,
    // bitwise equal to the standalone twin built from the same plan.
    for row in &request_rows(10, d, 9900) {
        assert_eq!(
            h.infer("t", row).unwrap(),
            solo_forward(&new_oracle, row),
            "post-swap request must reply from the new version"
        );
    }
    for th in hammers {
        th.join().unwrap();
    }
    let tm = server.metrics().tier("t").unwrap();
    let total = (n_threads * m_requests + 20) as u64;
    assert_eq!(tm.requests(), total, "every request accounted");
    assert_eq!(tm.errors(), 0, "zero errored requests across the swap");
    assert_eq!(tm.rejected(), 0, "zero dropped requests across the swap");
    assert_eq!(tm.queue_depth(), 0);
    assert_eq!(tm.swaps(), 1, "exactly the one publish");
    server.shutdown();
}

#[test]
fn drain_answers_across_multiple_swaps_with_exact_accounting() {
    let (d, k) = (10usize, 5usize);
    let mut server = ModelServer::new();
    server
        .register_tier(
            "t",
            mlp(70, d, k),
            d,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_millis(1),
                queue_cap: 64,
                workers: 1,
                ..TierConfig::default()
            },
        )
        .unwrap();
    let h = server.handle();
    let rows = request_rows(12, d, 4400);
    // Three admission waves under three model versions, queued faster
    // than the single worker drains: the queue holds a version mix, and
    // the batcher's version fence must keep every batch pure.
    let wave_a: Vec<_> = rows[0..4].iter().map(|r| h.submit("t", r).unwrap()).collect();
    assert_eq!(server.swap_tier_model("t", sk4(70, d, k)).unwrap(), 1);
    let wave_b: Vec<_> = rows[4..8].iter().map(|r| h.submit("t", r).unwrap()).collect();
    // Swapping back to a rebuild of the registration model: version 2's
    // weights are bitwise the originals (deterministic constructor).
    assert_eq!(server.swap_tier_model("t", mlp(70, d, k)).unwrap(), 2);
    let wave_c: Vec<_> = rows[8..12].iter().map(|r| h.submit("t", r).unwrap()).collect();
    server.shutdown(); // drain: every admitted request still answers
    let dense_oracle = mlp(70, d, k);
    let sk_oracle = sk4(70, d, k);
    for (p, row) in wave_a.into_iter().zip(&rows[0..4]) {
        assert_eq!(p.wait().unwrap(), solo_forward(&dense_oracle, row));
    }
    for (p, row) in wave_b.into_iter().zip(&rows[4..8]) {
        assert_eq!(p.wait().unwrap(), solo_forward(&sk_oracle, row));
    }
    for (p, row) in wave_c.into_iter().zip(&rows[8..12]) {
        assert_eq!(p.wait().unwrap(), solo_forward(&dense_oracle, row));
    }
    let tm = server.metrics().tier("t").unwrap();
    assert_eq!(tm.swaps(), 2, "exact swap accounting across the drain");
    assert_eq!(tm.requests(), 12);
    assert_eq!(tm.errors(), 0);
    // The drained server publishes nothing further.
    assert_eq!(
        server.swap_tier_model("t", mlp(70, d, k)),
        Err(ServeError::ShuttingDown)
    );
}

#[test]
fn adapter_swap_serves_the_standalone_twin_bitwise() {
    let (d, k) = (10usize, 5usize);
    let mut server = ModelServer::new();
    server
        .register_tier(
            "t",
            mlp(60, d, k),
            d,
            TierConfig {
                max_batch: 4,
                ..TierConfig::default()
            },
        )
        .unwrap();
    // A generous target makes the down-move deterministic: shadow errors
    // are clamped to ≤ 1, so any rank-4 candidate clears the ceiling.
    let mut cfg = AdaptConfig::new(LayerSelector::by_type("Linear"), &[4]);
    cfg.target_err = 2.0;
    cfg.sketch_seed = 23;
    let mut adapter = RankAdapter::new(&server, "t", mlp(60, d, k), cfg).unwrap();
    for row in &request_rows(8, d, 5500) {
        adapter.observe(row).unwrap();
    }
    match adapter.step(&server).unwrap() {
        AdaptDecision::Swapped {
            from_rank: 0,
            to_rank: 4,
            version: 1,
            ..
        } => {}
        other => panic!("expected the down-swap to rank 4, got {other:?}"),
    }
    assert_eq!(adapter.rank(), 4);
    // The served model is now bitwise the standalone twin: same dense
    // seed, same plan seed, same rank, built entirely outside the server.
    let twin = sk4(60, d, k);
    let h = server.handle();
    for row in &request_rows(6, d, 6600) {
        assert_eq!(
            h.infer("t", row).unwrap(),
            solo_forward(&twin, row),
            "adapter-published model must equal its standalone twin bit for bit"
        );
    }
    // The gauges reach the snapshot: rank, swap count, measured quality.
    let snap = server.metrics_snapshot();
    let ts = snap.tiers.iter().find(|t| t.tier == "t").unwrap();
    assert_eq!(ts.swaps, 1);
    assert_eq!(ts.rank, 4);
    assert!(
        ts.measured_quality.is_some(),
        "adapter measurement must surface in the snapshot"
    );
    server.shutdown();
}

#[test]
fn quality_sensor_tracks_what_the_tier_actually_serves() {
    let (d, k) = (10usize, 4usize);
    let mut server = ModelServer::new();
    server
        .register_tier("t", mlp(50, d, k), d, TierConfig::default())
        .unwrap();
    let mut cfg = AdaptConfig::new(LayerSelector::by_type("Linear"), &[4]);
    cfg.sensor_epochs = 1; // window = the current round only, exact reads
    let mut adapter = RankAdapter::new(&server, "t", mlp(50, d, k), cfg).unwrap();
    for row in &request_rows(6, d, 3300) {
        adapter.observe(row).unwrap();
    }
    // Serving exactly the reference: zero error, quality 1.
    let r = adapter.measure().unwrap().unwrap();
    assert_eq!((r.mean_err, r.quality), (0.0, 1.0));
    assert_eq!(
        server.metrics().tier("t").unwrap().measured_quality(),
        Some(1.0)
    );
    // Serving garbage: clamped error 1, quality 0 — the sensor follows
    // the *served* version, not the adapter's own bookkeeping.
    server.swap_tier_model("t", garbage(d, k)).unwrap();
    let r = adapter.measure().unwrap().unwrap();
    assert_eq!((r.mean_err, r.quality), (1.0, 0.0));
    assert_eq!(
        server.metrics().tier("t").unwrap().measured_quality(),
        Some(0.0)
    );
    // And back: a recovery swap restores the perfect reading.
    server.swap_tier_model("t", mlp(50, d, k)).unwrap();
    let r = adapter.measure().unwrap().unwrap();
    assert_eq!((r.mean_err, r.quality), (0.0, 1.0));
    server.shutdown();
}

#[test]
fn measured_quality_reorders_the_cascade_and_floors_requests() {
    // The acceptance scenario: a tier whose static ladder label claims
    // near-dense quality actually serves junk. Once the adapter
    // measures it, the cascade must (a) re-rank the ladder by the
    // evidence and (b) stop routing floored requests to it.
    let (d, k) = (10usize, 4usize);
    let mut server = ModelServer::new();
    server
        .register_tier(
            "gold",
            mlp(80, d, k),
            d,
            TierConfig {
                max_batch: 4,
                workers: 2,
                ..TierConfig::default()
            },
        )
        .unwrap();
    server
        .register_tier(
            "fast",
            garbage(d, k),
            d,
            TierConfig {
                max_batch: 4,
                workers: 2,
                ..TierConfig::default()
            },
        )
        .unwrap();
    // Mislabeled on purpose: "fast" claims 0.95, above "gold"'s 0.9.
    let cascade = Cascade::new(&server, &[("fast", 0.95), ("gold", 0.9)]).unwrap();
    let slo = Slo::new(Duration::from_secs(5)).with_min_quality(0.8);
    let row = request_rows(1, d, 1200).pop().unwrap();
    // Before any measurement the static label wins: floored requests
    // land on the mislabeled rung.
    let routed = cascade.submit(&row, &slo).unwrap();
    assert_eq!(routed.tier, "fast");
    assert_eq!(routed.quality, 0.95);
    routed.wait().unwrap();
    // Measure what "fast" actually serves (reference = the real dense
    // model it pretends to approximate): clamped error 1, quality 0.
    let mut adapter = RankAdapter::new(
        &server,
        "fast",
        mlp(80, d, k),
        AdaptConfig::new(LayerSelector::by_type("Linear"), &[2]),
    )
    .unwrap();
    for r in &request_rows(6, d, 1300) {
        adapter.observe(r).unwrap();
    }
    assert_eq!(adapter.measure().unwrap().unwrap().quality, 0.0);
    // (a) The ladder re-ranks by measured evidence.
    let q = cascade.qualities();
    assert_eq!(q[0], ("gold".to_string(), 0.9));
    assert_eq!(q[1], ("fast".to_string(), 0.0));
    // (b) Floored requests stop reaching the demoted rung entirely.
    for _ in 0..6 {
        let routed = cascade.submit(&row, &slo).unwrap();
        assert_eq!(routed.tier, "gold", "measured 0.0 < floor 0.8 must exclude fast");
        routed.wait().unwrap();
    }
    let metrics = server.metrics();
    assert_eq!(
        metrics.tier("fast").unwrap().requests(),
        1,
        "only the pre-measurement request ever reached the junk tier"
    );
    assert_eq!(metrics.tier("gold").unwrap().requests(), 6);
    // A ladder with no rung at the floor is typed-infeasible, not
    // silently served below quality.
    let only_fast = Cascade::new(&server, &[("fast", 0.95)]).unwrap();
    assert!(matches!(
        only_fast.submit(&row, &slo),
        Err(ServeError::SloInfeasible { .. })
    ));
    server.shutdown();
}

#[test]
fn swap_rejections_are_typed_and_change_nothing() {
    let (d, k) = (10usize, 5usize);
    let mut server = ModelServer::new();
    server
        .register_tier("t", mlp(40, d, k), d, TierConfig::default())
        .unwrap();
    {
        use panther::nn::{AttnWeights, MultiHeadAttention};
        use panther::serve::SeqTierConfig;
        let mut rng = Philox::seeded(41);
        let mut m = Model::new();
        m.add(
            "attn",
            MultiHeadAttention::new(AttnWeights::random(8, 2, &mut rng)),
        )
        .unwrap();
        server
            .register_seq_tier("seq", m, 8, SeqTierConfig::default())
            .unwrap();
    }
    // Wrong output width: rejected before any publish.
    let err = server.swap_tier_model("t", mlp(40, d, k + 1)).unwrap_err();
    assert!(matches!(err, ServeError::BadInput(_)), "{err}");
    // Sequence tiers don't hot-swap.
    let err = server
        .swap_tier_model("seq", mlp(40, 8, 8))
        .unwrap_err();
    assert!(matches!(err, ServeError::BadInput(_)), "{err}");
    // Unknown tier routes the usual typed error.
    assert!(matches!(
        server.swap_tier_model("ghost", mlp(40, d, k)),
        Err(ServeError::UnknownTier { .. })
    ));
    // Nothing was published and the tier still serves the original.
    let tm = server.metrics().tier("t").unwrap();
    assert_eq!(tm.swaps(), 0);
    let oracle = mlp(40, d, k);
    let row = request_rows(1, d, 2200).pop().unwrap();
    assert_eq!(
        server.handle().infer("t", &row).unwrap(),
        solo_forward(&oracle, &row)
    );
    server.shutdown();
}
