//! Integration tests for SLO cascade routing: deterministic synthetic
//! overload (a model whose forward sleeps a known time), shed-to-sketched
//! with counted downgrades, typed infeasibility, bitwise speculative
//! replies, and drain/revoke accounting on shutdown.
//!
//! Determinism policy: the load is synthetic — service time is a
//! `thread::sleep` inside the model, so "dense is overloaded" is a fact
//! the test *constructs* (sleep ≫ deadline), not a race it hopes for.
//! Time margins are ≥ 2× so scheduler jitter cannot flip outcomes.

use panther::linalg::Mat;
use panther::nn::{ForwardCtx, Model};
use panther::serve::{
    Cascade, ModelServer, Routed, ServeError, Slo, TierConfig, Upgrade, UpgradeHandle,
};
use std::time::Duration;

const D: usize = 6;

/// A row-independent affine map with a built-in service time: forward
/// sleeps `delay_ms`, then returns `x·scale + bias` elementwise. The
/// output is a pure function of the input (the sleep only models load),
/// so bitwise oracles against the standalone forward stay exact.
#[derive(Clone)]
struct SleepyAffine {
    delay_ms: u64,
    scale: f32,
    bias: f32,
}

impl panther::nn::Module for SleepyAffine {
    fn type_name(&self) -> &'static str {
        "SleepyAffine"
    }
    fn forward(&self, x: &Mat, _ctx: &ForwardCtx) -> panther::Result<Mat> {
        if self.delay_ms > 0 {
            std::thread::sleep(Duration::from_millis(self.delay_ms));
        }
        let data = x.data().iter().map(|v| v * self.scale + self.bias);
        Ok(Mat::from_vec(x.rows(), x.cols(), data.collect()))
    }
    fn params(&self) -> Vec<(String, panther::nn::ParamRef<'_>)> {
        Vec::new()
    }
    fn params_mut(&mut self) -> Vec<(String, panther::nn::ParamMut<'_>)> {
        Vec::new()
    }
    fn boxed_clone(&self) -> Box<dyn panther::nn::Module> {
        Box::new(self.clone())
    }
}

fn affine_model(delay_ms: u64, scale: f32, bias: f32) -> Model {
    let mut m = Model::new();
    let aff = SleepyAffine {
        delay_ms,
        scale,
        bias,
    };
    m.add("aff", aff).unwrap();
    m
}

/// The standalone single-row forward — the bitwise oracle.
fn solo_forward(model: &Model, row: &[f32]) -> Vec<f32> {
    let x = Mat::from_vec(1, row.len(), row.to_vec());
    model.forward(&x, &ForwardCtx::new()).unwrap().row(0).to_vec()
}

/// Dense = slow + "high quality" (scale 2), sketched = instant + cheap
/// (scale 0.5). `dense_delay_ms` is the synthetic service time.
fn two_tier_server(dense_delay_ms: u64, dense_queue_cap: usize) -> ModelServer {
    let mut server = ModelServer::new();
    server
        .register_tier(
            "dense",
            affine_model(dense_delay_ms, 2.0, 0.25),
            D,
            TierConfig {
                max_batch: 1,
                workers: 1,
                queue_cap: dense_queue_cap,
                max_wait: Duration::from_millis(1),
                ..TierConfig::default()
            },
        )
        .unwrap();
    server
        .register_tier(
            "sketched",
            affine_model(0, 0.5, -0.125),
            D,
            TierConfig {
                max_batch: 4,
                workers: 2,
                queue_cap: 64,
                max_wait: Duration::from_millis(1),
                ..TierConfig::default()
            },
        )
        .unwrap();
    server
}

fn ladder(server: &ModelServer) -> Cascade {
    Cascade::new(server, &[("dense", 1.0), ("sketched", 0.6)]).unwrap()
}

fn row(i: usize) -> Vec<f32> {
    (0..D).map(|j| (i * D + j) as f32 * 0.1 - 1.0).collect()
}

/// Warm the dense tier's execution-time sensor: route one request with
/// an unbounded deadline (best quality wins while sensors are empty),
/// so the windowed exec histogram records the synthetic service time.
fn warm_dense(cascade: &Cascade) {
    let r = cascade.submit(&row(999), &Slo::new(Duration::MAX)).unwrap();
    assert_eq!(r.tier, "dense", "cold sensors route to the best tier");
    assert!(!r.shed);
    r.wait().unwrap();
}

#[test]
fn overload_sheds_to_sketched_with_counted_downgrades() {
    // Dense service time 40 ms vs a 10 ms deadline: once the sensor has
    // seen one batch, *every* deadline-bound request must shed. Dense
    // alone would reject 100 % of them (≥ the 30 % overload bar); the
    // cascade must serve 100 % (≥ the 95 % bar) within capacity.
    let server = two_tier_server(40, 4);
    let cascade = ladder(&server);
    warm_dense(&cascade);
    let sketched_oracle = affine_model(0, 0.5, -0.125);
    let dense_pred = cascade.predict("dense").unwrap();
    assert!(
        dense_pred >= Duration::from_millis(30),
        "sensor must have seen the 40 ms service time, got {dense_pred:?}"
    );
    assert!(cascade.predict("nope").is_none());

    let n = 30;
    let slo = Slo::new(Duration::from_millis(10));
    let mut served = 0;
    for i in 0..n {
        let routed = cascade.submit(&row(i), &slo).unwrap();
        assert_eq!(routed.tier, "sketched", "overloaded dense must shed");
        assert!(routed.shed, "downgrade must be flagged");
        assert!((routed.quality - 0.6).abs() < 1e-6);
        let got = routed.wait().unwrap();
        // Shed replies are the sketched tier's exact forward.
        assert_eq!(got, solo_forward(&sketched_oracle, &row(i)));
        served += 1;
    }
    assert_eq!(served, n, "the cascade serves everything dense would drop");

    let m = server.metrics();
    let dense = m.tier("dense").unwrap();
    let sketched = m.tier("sketched").unwrap();
    assert_eq!(dense.sheds(), n as u64, "every downgrade counted, on the tier shed FROM");
    assert_eq!(dense.slo_rejects(), 0);
    assert_eq!(sketched.requests(), n as u64, "shed work landed on sketched");
    // The snapshot carries the same counters (the shape benches emit).
    let snap = m.snapshot();
    let d = snap.tiers.iter().find(|t| t.tier == "dense").unwrap();
    assert_eq!(d.sheds, n as u64);
}

#[test]
fn infeasible_slo_is_a_typed_reject() {
    let server = two_tier_server(40, 4);
    let cascade = ladder(&server);
    warm_dense(&cascade);
    // Quality floor 0.9 leaves only dense eligible; dense predicts ≥ 40
    // ms against a 5 ms deadline ⇒ typed reject carrying the prediction.
    let slo = Slo::new(Duration::from_millis(5)).with_min_quality(0.9);
    match cascade.submit(&row(0), &slo) {
        Err(ServeError::SloInfeasible {
            deadline,
            best_predicted,
        }) => {
            assert_eq!(deadline, Duration::from_millis(5));
            assert!(best_predicted >= Duration::from_millis(30), "{best_predicted:?}");
        }
        other => panic!("expected SloInfeasible, got {:?}", other.map(|r| r.tier)),
    }
    let dense = server.metrics().tier("dense").unwrap();
    assert_eq!(dense.slo_rejects(), 1, "reject charged to the wanted tier");
    assert_eq!(dense.sheds(), 0, "a reject is not a shed");
    // A floor above the whole ladder can never route.
    let slo = Slo::new(Duration::MAX).with_min_quality(2.0);
    match cascade.submit(&row(1), &slo) {
        Err(ServeError::SloInfeasible { best_predicted, .. }) => {
            assert_eq!(best_predicted, Duration::MAX, "no eligible tier at all");
        }
        other => panic!("expected SloInfeasible, got {:?}", other.map(|r| r.tier)),
    }
    // Without the floor the same deadline is served (by sketched).
    let relaxed = Slo::new(Duration::from_millis(5));
    let got = cascade.infer(&row(2), &relaxed).unwrap();
    assert_eq!(got, solo_forward(&affine_model(0, 0.5, -0.125), &row(2)));
}

#[test]
fn speculative_replies_bitwise_match_their_tiers() {
    let server = two_tier_server(5, 16);
    let cascade = ladder(&server);
    let dense_oracle = affine_model(5, 2.0, 0.25);
    let sketched_oracle = affine_model(0, 0.5, -0.125);
    for i in 0..4 {
        let spec = cascade.speculate(&row(i)).unwrap();
        assert_eq!(spec.fast_tier, "sketched");
        assert_eq!(spec.verify_tier, "dense");
        let (first, handle) = spec.first();
        // Phase 1: the cheap tier's exact forward, immediately.
        assert_eq!(first.unwrap(), solo_forward(&sketched_oracle, &row(i)));
        assert_eq!(handle.tier(), "dense");
        // Phase 2: the dense tier's exact forward, asynchronously.
        match handle.upgraded() {
            Upgrade::Upgraded(v) => assert_eq!(v, solo_forward(&dense_oracle, &row(i))),
            Upgrade::Revoked(e) => panic!("unloaded dense tier must upgrade, got {e}"),
        }
    }
    let dense = server.metrics().tier("dense").unwrap();
    assert_eq!(dense.speculative(), 4);
    assert_eq!(dense.upgrades(), 4);
    assert_eq!(dense.revoked(), 0);
}

#[test]
fn shutdown_drains_or_revokes_every_speculative_upgrade() {
    // Dense: 10 ms service, queue of 2, one worker — some verify legs
    // are admitted (and must be *answered* by the drain), the rest are
    // revoked at speculation time. Either way the books balance.
    let mut server = two_tier_server(10, 2);
    let cascade = ladder(&server);
    let dense_oracle = affine_model(10, 2.0, 0.25);
    let n = 6;
    let mut handles: Vec<(usize, UpgradeHandle)> = Vec::new();
    for i in 0..n {
        let spec = cascade.speculate(&row(i)).unwrap();
        let (first, handle) = spec.first();
        first.unwrap();
        handles.push((i, handle));
    }
    let m = server.metrics();
    let dense = m.tier("dense").unwrap();
    assert_eq!(dense.speculative(), n as u64);
    // Drain while upgrades are still queued: queued verify work must be
    // answered, not dropped, and no worker may be left running.
    server.shutdown();
    assert_eq!(dense.queue_depth(), 0, "drain leaves nothing queued");
    assert_eq!(m.tier("sketched").unwrap().queue_depth(), 0);
    // One handle is abandoned unconsumed — that is an explicit
    // revocation, recorded on drop.
    let (_, abandoned) = handles.pop().unwrap();
    drop(abandoned);
    let mut upgraded = 0u64;
    for (i, handle) in handles {
        match handle.upgraded() {
            // An admitted upgrade that survived the drain is the dense
            // tier's exact forward.
            Upgrade::Upgraded(v) => {
                assert_eq!(v, solo_forward(&dense_oracle, &row(i)));
                upgraded += 1;
            }
            // A revoked one carries a typed reason.
            Upgrade::Revoked(e) => assert!(
                matches!(
                    e,
                    ServeError::QueueFull | ServeError::ShuttingDown | ServeError::Disconnected
                ),
                "untyped revocation: {e}"
            ),
        }
    }
    // The accounting invariant: every speculative attempt is settled.
    assert_eq!(
        dense.speculative(),
        dense.upgrades() + dense.revoked(),
        "speculative work must never be orphaned"
    );
    assert_eq!(dense.upgrades(), upgraded);
    assert!(
        dense.revoked() >= 1,
        "the abandoned handle must be recorded as revoked"
    );
    // After shutdown, new speculation fails fast with a typed error.
    assert!(matches!(
        cascade.speculate(&row(0)),
        Err(ServeError::ShuttingDown)
    ));
}

/// Shared handle to the token stream that releases [`GatedAffine`]
/// forwards, one token per batch.
type Gate = std::sync::Arc<std::sync::Mutex<std::sync::mpsc::Receiver<()>>>;

/// An affine map whose forward blocks until the test sends a token —
/// the queue fills and drains exactly when the test says so, never on
/// scheduler luck.
#[derive(Clone)]
struct GatedAffine {
    gate: Gate,
    scale: f32,
    bias: f32,
}

impl panther::nn::Module for GatedAffine {
    fn type_name(&self) -> &'static str {
        "GatedAffine"
    }
    fn forward(&self, x: &Mat, _ctx: &ForwardCtx) -> panther::Result<Mat> {
        self.gate.lock().unwrap().recv().ok();
        let data = x.data().iter().map(|v| v * self.scale + self.bias);
        Ok(Mat::from_vec(x.rows(), x.cols(), data.collect()))
    }
    fn params(&self) -> Vec<(String, panther::nn::ParamRef<'_>)> {
        Vec::new()
    }
    fn params_mut(&mut self) -> Vec<(String, panther::nn::ParamMut<'_>)> {
        Vec::new()
    }
    fn boxed_clone(&self) -> Box<dyn panther::nn::Module> {
        Box::new(self.clone())
    }
}

/// A one-rung cascade over a gated tier (1 worker, batch 1, queue cap
/// 1) plus the sender that releases one forward per token.
fn gated_single_rung() -> (ModelServer, std::sync::mpsc::Sender<()>) {
    let (tx, rx) = std::sync::mpsc::channel();
    let mut m = panther::nn::Model::new();
    m.add(
        "aff",
        GatedAffine {
            gate: std::sync::Arc::new(std::sync::Mutex::new(rx)),
            scale: 2.0,
            bias: 0.25,
        },
    )
    .unwrap();
    let mut server = ModelServer::new();
    server
        .register_tier(
            "dense",
            m,
            D,
            TierConfig {
                max_batch: 1,
                workers: 1,
                queue_cap: 1,
                max_wait: Duration::from_millis(1),
                ..TierConfig::default()
            },
        )
        .unwrap();
    (server, tx)
}

/// Park the worker on request A and fill the one-slot queue with B, so
/// the next submit deterministically sees `QueueFull`.
fn park_and_fill(cascade: &Cascade, server: &ModelServer) -> (Routed, Routed) {
    let unbounded = Slo::new(Duration::MAX);
    let a = cascade.submit(&row(0), &unbounded).unwrap();
    let dense = server.metrics().tier("dense").unwrap();
    while dense.queue_depth() > 0 {
        std::thread::sleep(Duration::from_micros(100));
    }
    let b = cascade.submit(&row(1), &unbounded).unwrap();
    assert_eq!(dense.queue_depth(), 1, "queue must be at capacity");
    (a, b)
}

#[test]
fn last_rung_queue_full_is_retried_once_and_served() {
    // Worker parked on A, queue holds B: C's first try_submit rejects.
    // The moment the reject is visible we release A — the worker drains
    // B from the queue well inside C's 20 ms backoff window (µs of work
    // against a ≥ 10× margin), so the single retry is admitted.
    let (server, gate) = gated_single_rung();
    let cascade = Cascade::new(&server, &[("dense", 1.0)]).unwrap();
    let (a, b) = park_and_fill(&cascade, &server);
    let dense = server.metrics().tier("dense").unwrap();
    let c = std::thread::spawn({
        let cascade = Cascade::new(&server, &[("dense", 1.0)]).unwrap();
        move || cascade.submit(&row(2), &Slo::new(Duration::MAX))
    });
    while dense.rejected() < 1 {
        std::thread::sleep(Duration::from_micros(100));
    }
    // C is inside its backoff; free the worker so the queue drains.
    gate.send(()).unwrap();
    let routed = c.join().unwrap().expect("retry must admit C");
    assert_eq!(routed.tier, "dense");
    assert!(!routed.shed, "a granted retry is not a shed");
    // Release B and C, then check every reply is the exact forward.
    gate.send(()).unwrap();
    gate.send(()).unwrap();
    let oracle = affine_model(0, 2.0, 0.25);
    for (i, r) in [(0, a), (1, b), (2, routed)] {
        assert_eq!(r.wait().unwrap(), solo_forward(&oracle, &row(i)));
    }
    assert_eq!(dense.rejected(), 1, "one rejected attempt, then admitted");
    assert_eq!(dense.sheds(), 0);
    assert_eq!(dense.slo_rejects(), 0);
    assert_eq!(dense.requests(), 3, "all three requests served");
}

#[test]
fn last_rung_retry_exhausted_is_a_typed_reject() {
    // Nothing drains during the backoff: the retry rejects too, and the
    // request fails typed. Both attempts are counted as rejections.
    let (server, gate) = gated_single_rung();
    let cascade = Cascade::new(&server, &[("dense", 1.0)]).unwrap();
    let (a, b) = park_and_fill(&cascade, &server);
    match cascade.submit(&row(2), &Slo::new(Duration::MAX)) {
        Err(ServeError::SloInfeasible { .. }) => {}
        other => panic!("expected SloInfeasible, got {:?}", other.map(|r| r.tier)),
    }
    let dense = server.metrics().tier("dense").unwrap();
    assert_eq!(dense.rejected(), 2, "first attempt + the one retry");
    assert_eq!(dense.slo_rejects(), 1, "the failure itself is typed and counted once");
    assert_eq!(dense.sheds(), 0, "a retry is never a shed");
    // Unblock A and B so shutdown drains cleanly.
    gate.send(()).unwrap();
    gate.send(()).unwrap();
    a.wait().unwrap();
    b.wait().unwrap();
}

#[test]
fn cascade_construction_is_validated() {
    let server = two_tier_server(0, 4);
    // Unknown tier: the error lists what is registered.
    match Cascade::new(&server, &[("dense", 1.0), ("typo", 0.5)]) {
        Err(ServeError::UnknownTier { name, registered }) => {
            assert_eq!(name, "typo");
            assert_eq!(registered, vec!["dense", "sketched"]);
        }
        _ => panic!("expected UnknownTier"),
    }
    // Duplicates, empty ladders, and non-finite qualities are rejected.
    assert!(matches!(
        Cascade::new(&server, &[("dense", 1.0), ("dense", 0.5)]),
        Err(ServeError::BadInput(_))
    ));
    assert!(matches!(Cascade::new(&server, &[]), Err(ServeError::BadInput(_))));
    assert!(matches!(
        Cascade::new(&server, &[("dense", f32::NAN)]),
        Err(ServeError::BadInput(_))
    ));
    // Speculation needs two rungs.
    let single = Cascade::new(&server, &[("dense", 1.0)]).unwrap();
    assert!(matches!(
        single.speculate(&row(0)),
        Err(ServeError::BadInput(_))
    ));
    // Ladder order is by quality, not declaration order; width checked.
    let c = Cascade::new(&server, &[("sketched", 0.6), ("dense", 1.0)]).unwrap();
    assert_eq!(
        c.tiers(),
        vec![("dense".to_string(), 1.0), ("sketched".to_string(), 0.6)]
    );
    assert!(matches!(
        c.infer(&[0.0; D + 1], &Slo::new(Duration::MAX)),
        Err(ServeError::BadInput(_))
    ));
}
