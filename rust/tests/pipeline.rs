//! Cross-module integration tests that need no artifacts: sketching →
//! decomposition → layer compression compose on the pure-Rust substrate.

use panther::decomp::{cqrrpt, rsvd, CqrrptOpts, RsvdOpts};
use panther::linalg::{fro_norm, matmul, ortho_error, rel_error, Mat};
use panther::nn::{ForwardCtx, LayerSelector, Linear, Model, Module, SKLinear};
use panther::rng::Philox;
use panther::sketch::{GaussianSketch, Sketch, SparseSignSketch};
use panther::tuner::{AccuracyMode, GridSampler, SkAutoTuner, TuningConfig};

/// RSVD of a sketched-then-lifted matrix: the compression pipeline a user
/// would run to pick a rank before configuring SKLinear.
#[test]
fn rsvd_guides_rank_selection_for_sklinear() {
    let mut rng = Philox::seeded(100);
    // A weight matrix with a genuine low-rank core + noise.
    let core = matmul(
        &Mat::randn(128, 8, &mut rng),
        &Mat::randn(8, 128, &mut rng),
    );
    let noise = Mat::randn(128, 128, &mut rng).scale(0.05);
    let w = core.add(&noise);
    // RSVD tells us rank 8 captures most of the energy.
    let f = rsvd(
        &w,
        &RsvdOpts {
            rank: 16,
            power_iters: 2,
            ..Default::default()
        },
    );
    let energy: f64 = f.s.iter().map(|&s| (s as f64).powi(2)).sum();
    let head: f64 = f.s[..8].iter().map(|&s| (s as f64).powi(2)).sum();
    assert!(head / energy > 0.9, "top-8 energy {}", head / energy);

    // The weight-sketch error follows the √(d/(l·k)) law — the quantity
    // the SKAutoTuner's (l,k) search is actually navigating. (Note the
    // two-sided identity sketch does NOT exploit W's low rank at init —
    // that structure is recovered by training the factors afterwards.)
    let dense = Linear::new(w.transpose(), vec![0.0; 128]);
    let x = Mat::randn(16, 128, &mut rng);
    let y_ref = dense.forward(&x);
    let avg_err = |l: usize, k: usize| -> f64 {
        let mut tot = 0.0;
        for t in 0..5 {
            let mut r2 = Philox::seeded(200 + t);
            let sk = SKLinear::from_dense(&dense, l, k, &mut r2);
            tot += rel_error(&sk.forward(&x), &y_ref);
        }
        tot / 5.0
    };
    let coarse = avg_err(1, 8); // √(128/8)   ≈ 4.0
    let mid = avg_err(2, 32); //   √(128/64)  ≈ 1.4
    let fine = avg_err(2, 128); // √(128/256) ≈ 0.7
    assert!(
        fine < mid && mid < coarse,
        "error not decreasing in l·k: {coarse} > {mid} > {fine} expected"
    );
    assert!(fine < 1.0, "high-rank sketch should beat signal scale: {fine}");
    assert!(coarse > 2.0, "low-rank sketch suspiciously good: {coarse}");
}

/// CQRRPT's sketch stage uses the same sparse-sign operator exposed in
/// `sketch` — verify the embedding quality bound that makes CQRRPT sound.
#[test]
fn sparse_sign_embedding_preserves_subspace_geometry() {
    let mut rng = Philox::seeded(101);
    let a = Mat::randn(2000, 30, &mut rng);
    let s = SparseSignSketch::new(2000, 90, 8, 7);
    let sa = s.apply(&a);
    // Singular values of the sketch stay within a modest distortion band of
    // the original (subspace embedding property).
    let sv_a = panther::linalg::svd_jacobi(&a).s;
    let sv_sa = panther::linalg::svd_jacobi(&sa).s;
    for (x, y) in sv_a.iter().zip(&sv_sa) {
        let ratio = (*y as f64) / (*x as f64).max(1e-12);
        assert!(
            (0.5..2.0).contains(&ratio),
            "singular value distorted: {ratio}"
        );
    }
    // And CQRRPT itself stays orthogonal on this input.
    let f = cqrrpt(&a, &CqrrptOpts::default());
    assert!(ortho_error(&f.q) < 1e-3);
}

/// Gaussian sketch + rangefinder + QR: residual decays as rank grows.
#[test]
fn sketch_rank_sweep_monotone_residual() {
    let mut rng = Philox::seeded(102);
    let a = matmul(
        &Mat::randn(200, 24, &mut rng),
        &Mat::randn(24, 100, &mut rng),
    );
    let mut last = f64::INFINITY;
    for d in [4usize, 8, 16, 32] {
        let s = GaussianSketch::new(200, d, 5);
        let sa = s.apply(&a); // d×100 row-space sketch
        // Residual of projecting A's rows onto the sketched row space.
        let (q, _) = panther::linalg::qr_thin(&sa.transpose()); // 100×d
        let proj = matmul(&matmul(&a, &q), &q.transpose());
        let resid = fro_norm(&a.sub(&proj)) / fro_norm(&a);
        assert!(
            resid <= last + 1e-6,
            "residual not decreasing at d={d}: {resid} > {last}"
        );
        last = resid;
    }
    assert!(last < 0.1, "rank-32 sketch residual {last}");
}

/// Full host-side tuner flow on a multi-layer model — Listing 2 without the
/// PJRT runtime (the runtime variant lives in the bert_tune module tests).
#[test]
fn autotuner_compresses_multi_layer_model_under_constraint() {
    let mut rng = Philox::seeded(103);
    let mut model = Model::new();
    for (i, (din, dout)) in [(256usize, 512usize), (512, 256), (256, 64)]
        .iter()
        .enumerate()
    {
        model
            .add(
                &format!("encoder.layer{i}.fc"),
                Linear::random(*din, *dout, &mut rng),
            )
            .unwrap();
    }
    let dense_params = model.total_params();
    let probe = Mat::randn(4, 256, &mut rng);
    // Dense reference and every candidate answer through the same Module
    // API — no downcasting on layer type anywhere in the flow.
    let ctx = ForwardCtx::new();
    let reference = model
        .get("encoder.layer0.fc")
        .unwrap()
        .forward(&probe, &ctx)
        .unwrap();
    let mut tuner = SkAutoTuner::new(
        model,
        TuningConfig {
            selector: LayerSelector::by_regex(r"^encoder\.layer\d+\.fc$").unwrap(),
            space: None,
            separate: false,
        },
        |m| {
            let ctx = ForwardCtx::new();
            let out = m
                .get("encoder.layer0.fc")
                .unwrap()
                .forward(&probe, &ctx)
                .unwrap();
            -rel_error(&out, &reference)
        },
        AccuracyMode::AtLeast(-4.0),
        |m| m.total_params() as f64,
        Box::new(GridSampler::new(9)),
    )
    .unwrap();
    assert_eq!(tuner.matched_layers().len(), 3);
    let outcome = tuner.tune(15).unwrap();
    assert!(outcome.n_feasible > 0);
    let best = tuner.apply_best_params().unwrap();
    assert!(best.total_params() < dense_params);
    // Study persistence round-trips.
    let dir = std::env::temp_dir().join("panther_study_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("study.json");
    tuner.study().save(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = panther::util::json::Json::parse(&text).unwrap();
    assert_eq!(
        parsed.get("trials").unwrap().as_arr().unwrap().len(),
        15
    );
    std::fs::remove_file(path).ok();
}

/// The analytic cost model agrees with actual parameter counts of layers.
#[test]
fn cost_model_matches_layer_reality() {
    let mut rng = Philox::seeded(104);
    for &(din, dout, l, k) in &[(64usize, 32usize, 1usize, 4usize), (128, 256, 2, 16)] {
        let sk = SKLinear::random(din, dout, l, k, &mut rng);
        let c = panther::nn::linear_cost(din, dout, 1, Some((l, k)));
        assert_eq!(sk.param_count(), c.params);
        let dense = Linear::random(din, dout, &mut rng);
        let cd = panther::nn::linear_cost(din, dout, 1, None);
        assert_eq!(dense.param_count(), cd.params);
    }
}
