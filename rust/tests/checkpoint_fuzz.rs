//! Exhaustive corruption fuzz for the v3 checkpoint format.
//!
//! The format's robustness claim is absolute: **any** single corrupted
//! byte — and any truncation — must surface as a *typed*
//! [`CheckpointError`], never as a panic, a hang, or a silently
//! different model. Per-record CRC32s catch payload damage, the
//! whole-file footer CRC catches everything the records do not (header,
//! lengths, names, the footer itself), and the structural parser bounds
//! every allocation by the file size — so this test can afford to try
//! literally every byte and every prefix of a real checkpoint.
//!
//! The second half proves the `.bak` story end to end: whatever byte of
//! the primary is corrupted, [`load_with_recovery`] answers with the
//! previous save, bitwise, and flags the fallback.

use panther::rng::Philox;
use panther::runtime::HostTensor;
use panther::train::checkpoint::{load, load_with_recovery, save, CheckpointError};
use panther::train::ModelState;
use std::panic::catch_unwind;
use std::path::{Path, PathBuf};

/// A deliberately tiny state (a few hundred bytes on disk) so the
/// exhaustive sweeps stay fast.
fn tiny_state(step: u64) -> ModelState {
    let mut rng = Philox::seeded(step + 5);
    let params = vec![
        HostTensor::randn(&[3, 2], 1.0, &mut rng),
        HostTensor::randn(&[2], 0.5, &mut rng),
    ];
    let m = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
    let v = params.iter().map(|t| HostTensor::zeros(t.shape())).collect();
    ModelState {
        model: "fuzz_model".into(),
        names: vec!["w".into(), "b".into()],
        params,
        m,
        v,
        step,
    }
}

fn fuzz_dir(test: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("panther_ckpt_fuzz").join(test);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Loading `path` must yield a typed [`CheckpointError`] — not success,
/// not an untyped error, and above all not a panic.
fn assert_typed_failure(path: &Path, what: &str) {
    let p = path.to_path_buf();
    let outcome = catch_unwind(move || load(p));
    match outcome {
        Ok(Err(err)) => {
            assert!(
                err.downcast_ref::<CheckpointError>().is_some(),
                "{what}: error must be typed, got: {err}"
            );
        }
        Ok(Ok(_)) => panic!("{what}: corrupt checkpoint loaded successfully"),
        Err(_) => panic!("{what}: loader panicked"),
    }
}

#[test]
fn every_byte_flip_loads_as_a_typed_error() {
    let dir = fuzz_dir("byte_flips");
    let path = dir.join("tiny.ckpt");
    save(&tiny_state(7), &path).unwrap();
    let pristine = std::fs::read(&path).unwrap();
    // Sanity: the pristine file loads.
    assert_eq!(load(&path).unwrap().step, 7);
    for i in 0..pristine.len() {
        // Full inversion and a single low-bit flip: the worst and the
        // subtlest damage a byte can take.
        for pattern in [0xFFu8, 0x01] {
            let mut mutated = pristine.clone();
            mutated[i] ^= pattern;
            std::fs::write(&path, &mutated).unwrap();
            assert_typed_failure(&path, &format!("byte {i} ^ {pattern:#04x}"));
        }
    }
    // The pristine bytes still load after the sweep (no state leaked).
    std::fs::write(&path, &pristine).unwrap();
    assert_eq!(load(&path).unwrap().step, 7);
    std::fs::remove_file(&path).ok();
}

#[test]
fn every_truncation_loads_as_a_typed_error() {
    let dir = fuzz_dir("truncations");
    let path = dir.join("tiny.ckpt");
    save(&tiny_state(9), &path).unwrap();
    let pristine = std::fs::read(&path).unwrap();
    for len in 0..pristine.len() {
        std::fs::write(&path, &pristine[..len]).unwrap();
        assert_typed_failure(&path, &format!("truncated to {len} bytes"));
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn backup_recovers_from_every_primary_corruption() {
    let dir = fuzz_dir("recovery");
    let path = dir.join("tiny.ckpt");
    // Two saves: the second demotes the first to `.bak`.
    let old = tiny_state(1);
    save(&old, &path).unwrap();
    save(&tiny_state(2), &path).unwrap();
    // Healthy primary: served as-is, no fallback.
    let (state, recovered) = load_with_recovery(&path).unwrap();
    assert_eq!((state.step, recovered), (2, false));
    let pristine = std::fs::read(&path).unwrap();
    for i in 0..pristine.len() {
        let mut mutated = pristine.clone();
        mutated[i] ^= 0xFF;
        std::fs::write(&path, &mutated).unwrap();
        let p = path.clone();
        let outcome = catch_unwind(move || load_with_recovery(p));
        match outcome {
            Ok(Ok((state, recovered))) => {
                assert!(recovered, "byte {i}: fallback must be flagged");
                assert_eq!(state.step, old.step, "byte {i}: backup must answer");
                for (a, b) in state.params.iter().zip(&old.params) {
                    assert_eq!(a, b, "byte {i}: recovered params must be bitwise");
                }
            }
            Ok(Err(err)) => panic!("byte {i}: recovery failed: {err}"),
            Err(_) => panic!("byte {i}: recovery panicked"),
        }
    }
    std::fs::write(&path, &pristine).unwrap();
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(dir.join("tiny.ckpt.bak")).ok();
}
