//! Serving throughput bench: requests/s vs. batch cap, and dense vs.
//! sketched tier capacity at a fixed memory budget — the `panther::serve`
//! acceptance numbers, emitted machine-readably as `BENCH_serve.json` at
//! the repo root (the CI serve-smoke lane regenerates and parses it).
//!
//! Protocol:
//!
//! - **throughput vs. cap**: one MLP tier per batch cap in {1, 2, 4, 8,
//!   16}; a fixed pool of client threads hammers `infer` in a closed loop
//!   for a fixed request count; requests/s and the realized batch
//!   occupancy are reported. Caps beyond the client count cannot fill —
//!   occupancy in the report tells that story honestly.
//! - **tier capacity**: the dense model and its rank-16 `SketchPlan`
//!   compression register under the *same* memory budget (weights +
//!   workers × probe-measured per-batch activations must fit). The
//!   compressed tier's smaller footprint admits more workers — the
//!   paper's ~75 % memory saving expressed as serving capacity — and both
//!   tiers' requests/s are measured under the same client load.
//!
//! `--quick` shrinks request counts for the CI smoke lane;
//! `PANTHER_BENCH_DIR` redirects the JSON output.

use panther::linalg::{gemm_threads, Mat};
use panther::nn::{Activation, LayerSelector, Linear, Model, SketchPlan};
use panther::rng::Philox;
use panther::serve::{ModelServer, TierConfig};
use panther::util::bench::{JsonReport, Table};
use std::sync::Arc;
use std::time::{Duration, Instant};

const D_IN: usize = 96;
const D_HIDDEN: usize = 128;
const D_OUT: usize = 32;

fn dense_model(seed: u64) -> Model {
    let mut rng = Philox::seeded(seed);
    let mut m = Model::new();
    m.add("fc1", Linear::random(D_IN, D_HIDDEN, &mut rng)).unwrap();
    m.add("act1", Activation::gelu()).unwrap();
    m.add("fc2", Linear::random(D_HIDDEN, D_HIDDEN, &mut rng)).unwrap();
    m.add("act2", Activation::relu()).unwrap();
    m.add("fc3", Linear::random(D_HIDDEN, D_OUT, &mut rng)).unwrap();
    m
}

fn sketched_model(seed: u64) -> Model {
    let mut m = dense_model(seed);
    SketchPlan::new()
        .select(LayerSelector::by_type("Linear"))
        .with(1, 16)
        .seed(7)
        .apply(&mut m)
        .unwrap();
    m
}

/// Closed-loop load: `clients` threads each fire `per_client` blocking
/// requests at `tier`; returns (wall, total requests).
fn hammer(server: &ModelServer, tier: &str, clients: usize, per_client: usize) -> (Duration, u64) {
    let rows: Arc<Vec<Vec<f32>>> = Arc::new(
        (0..clients)
            .map(|i| Mat::randn(1, D_IN, &mut Philox::seeded(7000 + i as u64)).into_vec())
            .collect(),
    );
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let h = server.handle();
            let tier = tier.to_string();
            let rows = Arc::clone(&rows);
            std::thread::spawn(move || {
                for _ in 0..per_client {
                    h.infer(&tier, &rows[c]).expect("serve request failed");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (t0.elapsed(), (clients * per_client) as u64)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let threads = gemm_threads();
    let mut report = JsonReport::new("serve", threads);
    let (clients, per_client) = if quick { (8, 40) } else { (16, 400) };
    println!("# serve throughput ({threads} GEMM threads, {clients} clients)\n");

    // --- requests/s vs. batch cap -------------------------------------------
    let mut table = Table::new(&["cap", "req/s", "mean occupancy", "p50", "p99"]);
    for cap in [1usize, 2, 4, 8, 16] {
        let mut server = ModelServer::new();
        server
            .register_tier(
                "mlp",
                dense_model(1),
                D_IN,
                TierConfig {
                    max_batch: cap,
                    max_wait: Duration::from_micros(200),
                    queue_cap: 1024,
                    workers: 2,
                    ..TierConfig::default()
                },
            )
            .expect("register");
        let (wall, n) = hammer(&server, "mlp", clients, per_client);
        let tm = server.metrics().tier("mlp").unwrap();
        let rps = n as f64 / wall.as_secs_f64();
        table.row(&[
            cap.to_string(),
            format!("{rps:.0}"),
            format!("{:.2}", tm.mean_occupancy()),
            panther::util::human_duration(tm.latency_p50()),
            panther::util::human_duration(tm.latency_p99()),
        ]);
        report.entry_with(
            "throughput",
            &format!("cap={cap} clients={clients}"),
            wall.as_secs_f64() * 1e3,
            &[
                ("rps", rps),
                ("occupancy", tm.mean_occupancy()),
                ("p50_us", tm.latency_p50().as_secs_f64() * 1e6),
                ("p99_us", tm.latency_p99().as_secs_f64() * 1e6),
            ],
        );
        server.shutdown();
    }
    println!("{}", table.render());

    // --- dense vs. sketched capacity at a fixed memory budget ---------------
    // Learn the dense footprint, then set one budget that pinches it.
    let mut probe_srv = ModelServer::new();
    let dense_free = probe_srv
        .register_tier("probe", dense_model(1), D_IN, TierConfig::default())
        .expect("probe");
    probe_srv.shutdown();
    let budget = dense_free.weight_bytes + 2 * dense_free.peak_batch_bytes;

    let mut table = Table::new(&[
        "tier", "weights", "peak/batch", "workers admitted", "req/s",
    ]);
    let mut server = ModelServer::new();
    for (tier, model) in [
        ("dense", dense_model(1)),
        ("sketched", sketched_model(1)),
    ] {
        let info = server
            .register_tier(
                tier,
                model,
                D_IN,
                TierConfig {
                    max_batch: 8,
                    max_wait: Duration::from_micros(200),
                    queue_cap: 1024,
                    workers: 8,
                    mem_budget: Some(budget),
                    ..TierConfig::default()
                },
            )
            .expect("register tier");
        let (wall, n) = hammer(&server, tier, clients, per_client);
        let rps = n as f64 / wall.as_secs_f64();
        table.row(&[
            tier.into(),
            panther::util::human_bytes(info.weight_bytes),
            panther::util::human_bytes(info.peak_batch_bytes),
            info.workers.to_string(),
            format!("{rps:.0}"),
        ]);
        report.entry_with(
            "tier_capacity",
            &format!("{tier} budget={budget}B"),
            wall.as_secs_f64() * 1e3,
            &[
                ("rps", rps),
                ("workers", info.workers as f64),
                ("weight_bytes", info.weight_bytes as f64),
                ("peak_batch_bytes", info.peak_batch_bytes as f64),
            ],
        );
    }
    server.shutdown();
    println!("(shared budget: {})", panther::util::human_bytes(budget));
    println!("{}", table.render());

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}
