//! Serving throughput bench: requests/s vs. batch cap, and dense vs.
//! sketched tier capacity at a fixed memory budget — the `panther::serve`
//! acceptance numbers, emitted machine-readably as `BENCH_serve.json` at
//! the repo root (the CI serve-smoke lane regenerates and parses it).
//!
//! Protocol:
//!
//! - **throughput vs. cap**: one MLP tier per batch cap in {1, 2, 4, 8,
//!   16}; a fixed pool of client threads hammers `infer` in a closed loop
//!   for a fixed request count; requests/s and the realized batch
//!   occupancy are reported. Caps beyond the client count cannot fill —
//!   occupancy in the report tells that story honestly.
//! - **tier capacity**: the dense model and its rank-16 `SketchPlan`
//!   compression register under the *same* memory budget (weights +
//!   workers × probe-measured per-batch activations must fit). The
//!   compressed tier's smaller footprint admits more workers — the
//!   paper's ~75 % memory saving expressed as serving capacity — and both
//!   tiers' requests/s are measured under the same client load.
//! - **sequence tiers**: a dense-attention stack and a Performer stack
//!   with the *same* projection weights register as sequence tiers under
//!   one memory budget; clients submit variable-length sequences through
//!   the continuous batcher. Reported per tier: tokens/s and the
//!   admission cap `max_seq_len` the budget fit bought — the Performer's
//!   linear activation growth admits strictly longer sequences than the
//!   dense tier's quadratic one.
//! - **SLO overload**: a dense tier with a *fixed, throttled* per-batch
//!   service time (so its capacity is known exactly, not hoped for) is
//!   hammered past that capacity. Phase one measures what the dense tier
//!   alone does under fail-fast admission: the reject rate. Phase two
//!   routes the same load through a dense/sketched [`Cascade`] with a
//!   per-request deadline: shed rate, fraction served within deadline,
//!   and tail latency under overload. Phase three measures the
//!   speculative two-phase path's upgrade/revoke split. All three land
//!   in `BENCH_serve.json` (`op = "overload"`) plus one `tier_snapshot`
//!   entry per tier — the machine-diffable overload record.
//! - **adaptation**: one tier walks a rank ladder via atomic hot-swaps
//!   under continuous client traffic. Per rung: the [`RankAdapter`]'s
//!   shadow-measured quality against the dense reference and the publish
//!   latency; plus one in-flight record proving zero errors across every
//!   swap (`op = "adaptation"`).
//!
//! `--quick` shrinks request counts for the CI smoke lane;
//! `PANTHER_BENCH_DIR` redirects the JSON output.

use panther::linalg::{gemm_threads, Mat};
use panther::nn::{
    Activation, AttnWeights, KernelKind, LayerSelector, Linear, Model, MultiHeadAttention,
    RandMultiHeadAttention, SketchPlan,
};
use panther::rng::Philox;
use panther::serve::{Cascade, ModelServer, SeqTierConfig, ServeError, Slo, TierConfig, Upgrade};
use panther::util::bench::{JsonReport, Table};
use std::sync::Arc;
use std::time::{Duration, Instant};

const D_IN: usize = 96;
const D_HIDDEN: usize = 128;
const D_OUT: usize = 32;
/// Embedding width of the sequence-tier attention stacks.
const D_SEQ: usize = 32;

fn dense_model(seed: u64) -> Model {
    let mut rng = Philox::seeded(seed);
    let mut m = Model::new();
    m.add("fc1", Linear::random(D_IN, D_HIDDEN, &mut rng)).unwrap();
    m.add("act1", Activation::gelu()).unwrap();
    m.add("fc2", Linear::random(D_HIDDEN, D_HIDDEN, &mut rng)).unwrap();
    m.add("act2", Activation::relu()).unwrap();
    m.add("fc3", Linear::random(D_HIDDEN, D_OUT, &mut rng)).unwrap();
    m
}

/// Per-batch service-time throttle for the overload section: sleeps a
/// fixed time, then passes rows through unchanged. Elementwise (row
/// independent), so the registration probe admits it — and the sleep
/// makes the tier's capacity an arithmetic fact (`workers / service`
/// batches per second) instead of a machine-speed accident.
#[derive(Clone)]
struct Throttle(Duration);

impl panther::nn::Module for Throttle {
    fn type_name(&self) -> &'static str {
        "Throttle"
    }
    fn forward(&self, x: &Mat, _ctx: &panther::nn::ForwardCtx) -> panther::Result<Mat> {
        std::thread::sleep(self.0);
        Ok(x.clone())
    }
    fn params(&self) -> Vec<(String, panther::nn::ParamRef<'_>)> {
        Vec::new()
    }
    fn params_mut(&mut self) -> Vec<(String, panther::nn::ParamMut<'_>)> {
        Vec::new()
    }
    fn boxed_clone(&self) -> Box<dyn panther::nn::Module> {
        Box::new(self.clone())
    }
}

/// The overload section's "expensive" tier: a throttled linear head with
/// the same request/reply shape as [`sketched_model`], so the two can
/// share a cascade ladder.
fn throttled_dense(seed: u64, service: Duration) -> Model {
    let mut rng = Philox::seeded(seed);
    let mut m = Model::new();
    m.add("throttle", Throttle(service)).unwrap();
    m.add("head", Linear::random(D_IN, D_OUT, &mut rng)).unwrap();
    m
}

fn sketched_model(seed: u64) -> Model {
    let mut m = dense_model(seed);
    SketchPlan::new()
        .select(LayerSelector::by_type("Linear"))
        .with(1, 16)
        .seed(7)
        .apply(&mut m)
        .unwrap();
    m
}

/// Closed-loop load: `clients` threads each fire `per_client` blocking
/// requests at `tier`; returns (wall, total requests).
fn hammer(server: &ModelServer, tier: &str, clients: usize, per_client: usize) -> (Duration, u64) {
    let rows: Arc<Vec<Vec<f32>>> = Arc::new(
        (0..clients)
            .map(|i| Mat::randn(1, D_IN, &mut Philox::seeded(7000 + i as u64)).into_vec())
            .collect(),
    );
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let h = server.handle();
            let tier = tier.to_string();
            let rows = Arc::clone(&rows);
            std::thread::spawn(move || {
                for _ in 0..per_client {
                    h.infer(&tier, &rows[c]).expect("serve request failed");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    (t0.elapsed(), (clients * per_client) as u64)
}

/// Closed-loop variable-length sequence load: `clients` threads each
/// submit `per_client` sequences with lengths cycling over a mixed
/// pattern capped by the tier's admitted maximum; returns (wall, total
/// tokens executed).
fn hammer_seq(
    server: &ModelServer,
    tier: &str,
    clients: usize,
    per_client: usize,
    max_len: usize,
) -> (Duration, u64) {
    // Mixed short/medium/long lengths, clamped to the admission cap (the
    // cap itself is part of the workload: longer sequences are exactly
    // what the Performer tier exists to admit).
    let pattern: Vec<usize> = [8usize, 24, 64, 16, 128, 48]
        .iter()
        .map(|&l| l.min(max_len).max(1))
        .collect();
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let h = server.handle();
            let tier = tier.to_string();
            let pattern = pattern.clone();
            std::thread::spawn(move || {
                let mut tokens = 0u64;
                for i in 0..per_client {
                    let len = pattern[(c + i) % pattern.len()];
                    let seed = 9000 + (c * per_client + i) as u64;
                    let x = Mat::randn(len, D_SEQ, &mut Philox::seeded(seed)).scale(0.5);
                    let y = h.infer_seq(&tier, &x).expect("seq request failed");
                    assert_eq!(y.rows(), len);
                    tokens += len as u64;
                }
                tokens
            })
        })
        .collect();
    let total: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    (t0.elapsed(), total)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let threads = gemm_threads();
    let mut report = JsonReport::new("serve", threads);
    let (clients, per_client) = if quick { (8, 40) } else { (16, 400) };
    println!("# serve throughput ({threads} GEMM threads, {clients} clients)\n");

    // --- requests/s vs. batch cap -------------------------------------------
    let mut table = Table::new(&["cap", "req/s", "mean occupancy", "p50", "p99"]);
    for cap in [1usize, 2, 4, 8, 16] {
        let mut server = ModelServer::new();
        server
            .register_tier(
                "mlp",
                dense_model(1),
                D_IN,
                TierConfig {
                    max_batch: cap,
                    max_wait: Duration::from_micros(200),
                    queue_cap: 1024,
                    workers: 2,
                    ..TierConfig::default()
                },
            )
            .expect("register");
        let (wall, n) = hammer(&server, "mlp", clients, per_client);
        let tm = server.metrics().tier("mlp").unwrap();
        let rps = n as f64 / wall.as_secs_f64();
        table.row(&[
            cap.to_string(),
            format!("{rps:.0}"),
            format!("{:.2}", tm.mean_occupancy()),
            panther::util::human_duration(tm.latency_p50()),
            panther::util::human_duration(tm.latency_p99()),
        ]);
        report.entry_with(
            "throughput",
            &format!("cap={cap} clients={clients}"),
            wall.as_secs_f64() * 1e3,
            &[
                ("rps", rps),
                ("occupancy", tm.mean_occupancy()),
                ("p50_us", tm.latency_p50().as_secs_f64() * 1e6),
                ("p99_us", tm.latency_p99().as_secs_f64() * 1e6),
            ],
        );
        server.shutdown();
    }
    println!("{}", table.render());

    // --- dense vs. sketched capacity at a fixed memory budget ---------------
    // Learn the dense footprint, then set one budget that pinches it.
    let mut probe_srv = ModelServer::new();
    let dense_free = probe_srv
        .register_tier("probe", dense_model(1), D_IN, TierConfig::default())
        .expect("probe");
    probe_srv.shutdown();
    let budget = dense_free.weight_bytes + 2 * dense_free.peak_batch_bytes;

    let mut table = Table::new(&[
        "tier", "weights", "peak/batch", "workers admitted", "req/s",
    ]);
    let mut server = ModelServer::new();
    for (tier, model) in [
        ("dense", dense_model(1)),
        ("sketched", sketched_model(1)),
    ] {
        let info = server
            .register_tier(
                tier,
                model,
                D_IN,
                TierConfig {
                    max_batch: 8,
                    max_wait: Duration::from_micros(200),
                    queue_cap: 1024,
                    workers: 8,
                    mem_budget: Some(budget),
                    ..TierConfig::default()
                },
            )
            .expect("register tier");
        let (wall, n) = hammer(&server, tier, clients, per_client);
        let rps = n as f64 / wall.as_secs_f64();
        table.row(&[
            tier.into(),
            panther::util::human_bytes(info.weight_bytes),
            panther::util::human_bytes(info.peak_batch_bytes),
            info.workers.to_string(),
            format!("{rps:.0}"),
        ]);
        report.entry_with(
            "tier_capacity",
            &format!("{tier} budget={budget}B"),
            wall.as_secs_f64() * 1e3,
            &[
                ("rps", rps),
                ("workers", info.workers as f64),
                ("weight_bytes", info.weight_bytes as f64),
                ("peak_batch_bytes", info.peak_batch_bytes as f64),
            ],
        );
    }
    server.shutdown();
    println!("(shared budget: {})", panther::util::human_bytes(budget));
    println!("{}", table.render());

    // --- sequence tiers: tokens/s and admitted length under one budget ------
    // Same projection weights in both stacks; one budget. The dense
    // tier's peak grows ~quadratically in sequence length, the
    // Performer's linearly — the fit turns that into different admitted
    // maximum lengths, and the variable-length workload into different
    // token throughput.
    let seq_budget: u64 = 5_000_000;
    let attn_w = AttnWeights::random(D_SEQ, 4, &mut Philox::seeded(11));
    let dense_seq = {
        let mut m = Model::new();
        m.add("attn", MultiHeadAttention::new(attn_w.clone())).unwrap();
        m
    };
    let perf_seq = {
        let mut m = Model::new();
        m.add(
            "attn",
            RandMultiHeadAttention::new(attn_w.clone(), 32, KernelKind::Softmax, 13),
        )
        .unwrap();
        m
    };
    let seq_cfg = SeqTierConfig {
        max_tokens: 4096,
        max_wait: Duration::from_micros(200),
        queue_cap: 256,
        workers: 2,
        mem_budget: Some(seq_budget),
        probe_len: 32,
        ..SeqTierConfig::default()
    };
    let mut table = Table::new(&["tier", "max seq len", "tokens/s", "seqs", "mean step occ"]);
    let mut server = ModelServer::new();
    let seq_clients = if quick { 4 } else { 8 };
    let seqs_per_client = if quick { 6 } else { 40 };
    for (tier, model) in [("attn_dense", dense_seq), ("attn_performer", perf_seq)] {
        let info = server
            .register_seq_tier(tier, model, D_SEQ, seq_cfg.clone())
            .expect("register seq tier");
        let len = info.max_seq_len;
        let (wall, tokens) = hammer_seq(&server, tier, seq_clients, seqs_per_client, len);
        let tm = server.metrics().tier(tier).unwrap();
        let tps = tokens as f64 / wall.as_secs_f64();
        table.row(&[
            tier.into(),
            info.max_seq_len.to_string(),
            format!("{tps:.0}"),
            (seq_clients * seqs_per_client).to_string(),
            format!("{:.2}", tm.mean_occupancy()),
        ]);
        report.entry_with(
            "seq_tier",
            &format!("{tier} budget={seq_budget}B"),
            wall.as_secs_f64() * 1e3,
            &[
                ("tokens_per_s", tps),
                ("max_seq_len", info.max_seq_len as f64),
                ("weight_bytes", info.weight_bytes as f64),
                ("seq_stable", if info.seq_stable { 1.0 } else { 0.0 }),
            ],
        );
    }
    server.shutdown();
    println!("(sequence budget: {})", panther::util::human_bytes(seq_budget));
    println!("{}", table.render());

    // --- SLO cascade under synthetic overload -------------------------------
    // Dense capacity is throttled to exactly 1 batch / SERVICE / worker
    // (one worker, batch cap 1, queue of 4), and the client pool offers
    // far more than that — the overload is constructed, not incidental.
    const SERVICE: Duration = Duration::from_millis(3);
    const DEADLINE: Duration = Duration::from_millis(8);
    let per_client_ov = if quick { 30 } else { 120 };
    let mut server = ModelServer::new();
    server
        .register_tier(
            "overdense",
            throttled_dense(1, SERVICE),
            D_IN,
            TierConfig {
                max_batch: 1,
                max_wait: Duration::from_micros(200),
                queue_cap: 4,
                workers: 1,
                ..TierConfig::default()
            },
        )
        .expect("register overdense");
    server
        .register_tier(
            "sketched_ov",
            sketched_model(1),
            D_IN,
            TierConfig {
                max_batch: 8,
                max_wait: Duration::from_micros(200),
                queue_cap: 1024,
                workers: 2,
                ..TierConfig::default()
            },
        )
        .expect("register sketched_ov");
    let rows: Arc<Vec<Vec<f32>>> = Arc::new(
        (0..clients)
            .map(|i| Mat::randn(1, D_IN, &mut Philox::seeded(7700 + i as u64)).into_vec())
            .collect(),
    );

    // Phase one: the dense tier alone under fail-fast admission — what
    // fraction of the offered load it must reject.
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let h = server.handle();
            let rows = Arc::clone(&rows);
            std::thread::spawn(move || {
                let mut rejected = 0u64;
                for _ in 0..per_client_ov {
                    match h.try_infer("overdense", &rows[c]) {
                        Ok(_) => {}
                        Err(ServeError::QueueFull) => rejected += 1,
                        Err(e) => panic!("overload phase failed: {e}"),
                    }
                }
                rejected
            })
        })
        .collect();
    let rejected: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
    let alone_wall = t0.elapsed();
    let offered = (clients * per_client_ov) as u64;
    let reject_rate = rejected as f64 / offered as f64;
    report.entry_with(
        "overload",
        "dense_alone",
        alone_wall.as_secs_f64() * 1e3,
        &[
            ("offered", offered as f64),
            ("reject_rate", reject_rate),
            ("rps", (offered - rejected) as f64 / alone_wall.as_secs_f64()),
        ],
    );

    // Phase two: the same offered load through the cascade, every request
    // carrying a deadline. Sheds replace rejects; the report records how
    // many made the deadline and the tail latency under overload.
    let ladder = [("overdense", 1.0), ("sketched_ov", 0.6)];
    let cascade = Arc::new(Cascade::new(&server, &ladder).expect("cascade"));
    let t0 = Instant::now();
    let handles: Vec<_> = (0..clients)
        .map(|c| {
            let cascade = Arc::clone(&cascade);
            let rows = Arc::clone(&rows);
            std::thread::spawn(move || {
                let (mut served, mut shed, mut within) = (0u64, 0u64, 0u64);
                let mut lat_us: Vec<f64> = Vec::with_capacity(per_client_ov);
                for _ in 0..per_client_ov {
                    let t = Instant::now();
                    let routed = cascade
                        .submit(&rows[c], &Slo::new(DEADLINE))
                        .expect("cascade admission");
                    let was_shed = routed.shed;
                    routed.wait().expect("cascade reply");
                    let e = t.elapsed();
                    served += 1;
                    shed += was_shed as u64;
                    within += (e <= DEADLINE) as u64;
                    lat_us.push(e.as_secs_f64() * 1e6);
                }
                (served, shed, within, lat_us)
            })
        })
        .collect();
    let (mut served, mut shed, mut within) = (0u64, 0u64, 0u64);
    let mut lat_us: Vec<f64> = Vec::new();
    for h in handles {
        let (s, sh, w, l) = h.join().unwrap();
        served += s;
        shed += sh;
        within += w;
        lat_us.extend(l);
    }
    let cascade_wall = t0.elapsed();
    lat_us.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p99_us = lat_us[((lat_us.len() as f64 * 0.99).ceil() as usize - 1).min(lat_us.len() - 1)];
    let shed_rate = shed as f64 / served as f64;
    let within_deadline = within as f64 / served as f64;
    report.entry_with(
        "overload",
        &format!("cascade deadline={}ms", DEADLINE.as_millis()),
        cascade_wall.as_secs_f64() * 1e3,
        &[
            ("shed_rate", shed_rate),
            ("within_deadline", within_deadline),
            ("p99_us", p99_us),
            ("rps", served as f64 / cascade_wall.as_secs_f64()),
        ],
    );

    // Phase three: the speculative two-phase path. Every request answers
    // immediately from the sketched tier; the dense verify leg either
    // upgrades or is revoked (fail-fast under the same overload).
    let spec_n = if quick { 24 } else { 96 };
    let t0 = Instant::now();
    let mut upgrade_handles = Vec::with_capacity(spec_n);
    for i in 0..spec_n {
        let spec = cascade.speculate(&rows[i % clients]).expect("speculate");
        let (first, handle) = spec.first();
        first.expect("fast reply");
        upgrade_handles.push(handle);
    }
    let mut upgrades = 0u64;
    for handle in upgrade_handles {
        match handle.upgraded() {
            Upgrade::Upgraded(_) => upgrades += 1,
            Upgrade::Revoked(_) => {}
        }
    }
    let spec_wall = t0.elapsed();
    report.entry_with(
        "overload",
        "speculative",
        spec_wall.as_secs_f64() * 1e3,
        &[
            ("speculated", spec_n as f64),
            ("upgrade_rate", upgrades as f64 / spec_n as f64),
            ("revoke_rate", (spec_n as u64 - upgrades) as f64 / spec_n as f64),
        ],
    );

    let mut table = Table::new(&["phase", "outcome"]);
    table.row(&[
        "dense alone".into(),
        format!("{:.0}% rejected", 100.0 * reject_rate),
    ]);
    table.row(&[
        "cascade".into(),
        format!(
            "{:.0}% shed, {:.0}% within {}ms, p99 {:.1}ms",
            100.0 * shed_rate,
            100.0 * within_deadline,
            DEADLINE.as_millis(),
            p99_us / 1e3
        ),
    ]);
    table.row(&[
        "speculative".into(),
        format!("{upgrades}/{spec_n} upgraded"),
    ]);
    println!("(overload: dense throttled to {SERVICE:?}/batch, 1 worker)");
    println!("{}", table.render());

    // The frozen per-tier counters (sheds, speculative, upgrades,
    // revoked, windowed tails) ride along as machine-diffable entries.
    server.metrics_snapshot().report_into(&mut report);
    server.shutdown();

    // --- online rank adaptation: quality vs. rank, swap latency -------------
    // One tier walks the rank ladder under continuous client traffic:
    // each rung is hot-swapped in, its *measured* quality (shadow-replay
    // error vs. the dense reference) and the publish latency are
    // recorded, and the background clients assert in flight that no
    // request is ever dropped or corrupted across a swap.
    use panther::serve::{AdaptConfig, RankAdapter};
    use std::sync::atomic::{AtomicBool, Ordering};
    let mut server = ModelServer::new();
    server
        .register_tier(
            "adapt",
            dense_model(1),
            D_IN,
            TierConfig {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
                queue_cap: 1024,
                workers: 2,
                ..TierConfig::default()
            },
        )
        .expect("register adapt");
    let ranks = [8usize, 16, 32];
    let mut acfg = AdaptConfig::new(
        LayerSelector::by_type("Linear"),
        &ranks,
    );
    acfg.sensor_epochs = 1; // one measurement round per rung: exact reads
    let mut adapter =
        RankAdapter::new(&server, "adapt", dense_model(1), acfg).expect("adapter");
    let shadow_rows = if quick { 32 } else { 64 };
    for i in 0..shadow_rows {
        let row = Mat::randn(1, D_IN, &mut Philox::seeded(8600 + i as u64)).into_vec();
        adapter.observe(&row).expect("observe");
    }
    let stop = Arc::new(AtomicBool::new(false));
    let traffic: Vec<_> = (0..4)
        .map(|c| {
            let h = server.handle();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let row = Mat::randn(1, D_IN, &mut Philox::seeded(8800 + c as u64)).into_vec();
                let mut n = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    h.infer("adapt", &row).expect("request across a swap");
                    n += 1;
                }
                n
            })
        })
        .collect();
    let mut table = Table::new(&["rank", "measured quality", "swap latency", "weights"]);
    let t_all = Instant::now();
    for &rank in &ranks {
        let mut m = dense_model(1);
        SketchPlan::new()
            .select(LayerSelector::by_type("Linear"))
            .with(1, rank)
            .seed(7)
            .apply(&mut m)
            .expect("sketch rung");
        let weight_bytes = (m.total_params() * 4) as u64;
        let t0 = Instant::now();
        server.swap_tier_model("adapt", m).expect("hot swap");
        let swap = t0.elapsed();
        let r = adapter.measure().expect("measure").expect("shadow rows present");
        table.row(&[
            rank.to_string(),
            format!("{:.4}", r.quality),
            panther::util::human_duration(swap),
            panther::util::human_bytes(weight_bytes),
        ]);
        report.entry_with(
            "adaptation",
            &format!("rank={rank}"),
            swap.as_secs_f64() * 1e3,
            &[
                ("measured_quality", r.quality),
                ("mean_err", r.mean_err),
                ("swap_us", swap.as_secs_f64() * 1e6),
                ("weight_bytes", weight_bytes as f64),
            ],
        );
    }
    // Back to dense: the recovery swap must read as (exactly) perfect.
    let t0 = Instant::now();
    server
        .swap_tier_model("adapt", dense_model(1))
        .expect("recovery swap");
    let swap = t0.elapsed();
    let r = adapter.measure().expect("measure").expect("shadow rows present");
    table.row(&[
        "dense".into(),
        format!("{:.4}", r.quality),
        panther::util::human_duration(swap),
        panther::util::human_bytes(dense_free.weight_bytes),
    ]);
    report.entry_with(
        "adaptation",
        "rank=dense",
        swap.as_secs_f64() * 1e3,
        &[
            ("measured_quality", r.quality),
            ("mean_err", r.mean_err),
            ("swap_us", swap.as_secs_f64() * 1e6),
            ("weight_bytes", dense_free.weight_bytes as f64),
        ],
    );
    stop.store(true, Ordering::Relaxed);
    let in_flight: u64 = traffic.into_iter().map(|t| t.join().unwrap()).sum();
    let adapt_wall = t_all.elapsed();
    let tm = server.metrics().tier("adapt").unwrap();
    assert_eq!(tm.errors(), 0, "no request may error across a hot swap");
    report.entry_with(
        "adaptation",
        "hot_swap_under_traffic",
        adapt_wall.as_secs_f64() * 1e3,
        &[
            ("requests", in_flight as f64),
            ("swaps", tm.swaps() as f64),
            ("errors", tm.errors() as f64),
            ("rps", in_flight as f64 / adapt_wall.as_secs_f64()),
        ],
    );
    println!(
        "(adaptation: {} swaps under {} in-flight requests, 0 errors)",
        tm.swaps(),
        in_flight
    );
    println!("{}", table.render());
    server.metrics_snapshot().report_into(&mut report);
    server.shutdown();

    // --- chaos: 1 % worker kills under closed-loop load ---------------------
    // Isolated server (the sections above assert zero errors on their own
    // tiers). "steady" is the fault-free control; "chaos" serves the same
    // model under a seeded FaultPlan that kills the executing worker on
    // ~1 % of batch ticks. A kill re-queues its batch before the panic and
    // supervision respawns the worker, so the closed loop must still see
    // zero errors — the faults cost throughput and tail latency only, and
    // the respawn count lands in the report next to them.
    {
        use panther::serve::FaultPlan;
        let mut server = ModelServer::new();
        let chaos_cfg = |faults| TierConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            queue_cap: 1024,
            workers: 2,
            faults,
            ..TierConfig::default()
        };
        server
            .register_tier("steady", dense_model(1), D_IN, chaos_cfg(None))
            .expect("register steady");
        let plan = Arc::new(FaultPlan::seeded(13).kill_rate(0.01));
        server
            .register_tier("chaos", dense_model(1), D_IN, chaos_cfg(Some(Arc::clone(&plan))))
            .expect("register chaos");
        let mut table = Table::new(&["tier", "req/s", "p50", "p99", "restarts"]);
        for tier in ["steady", "chaos"] {
            let (wall, n) = hammer(&server, tier, clients, per_client);
            let tm = server.metrics().tier(tier).unwrap();
            assert_eq!(tm.errors(), 0, "{tier}: kills must stay invisible to clients");
            let rps = n as f64 / wall.as_secs_f64();
            table.row(&[
                tier.to_string(),
                format!("{rps:.0}"),
                panther::util::human_duration(tm.latency_p50()),
                panther::util::human_duration(tm.latency_p99()),
                tm.worker_restarts().to_string(),
            ]);
            report.entry_with(
                "chaos",
                &format!("tier={tier} clients={clients}"),
                wall.as_secs_f64() * 1e3,
                &[
                    ("rps", rps),
                    ("p99_us", tm.latency_p99().as_secs_f64() * 1e6),
                    ("errors", tm.errors() as f64),
                    ("worker_restarts", tm.worker_restarts() as f64),
                    ("poisoned", tm.poisoned() as f64),
                    ("nonfinite_rows", tm.nonfinite_rows() as f64),
                ],
            );
        }
        println!(
            "(chaos: {} worker kills absorbed, 0 client-visible errors)",
            server.metrics().tier("chaos").unwrap().worker_restarts()
        );
        println!("{}", table.render());
        server.shutdown();
    }

    // --- tracing overhead: the same closed loop, untraced then traced -------
    // One tier, hammered twice. The first pass runs with tracing disabled
    // (the request path's never-taken `Option` branch); the second enables
    // a generous TraceConfig (zero refill, capacity above the offered
    // load) so every event class records without suppression. Both rps
    // land under `op = "trace_overhead"`, and the traced run's JSONL and
    // Chrome exports are written next to `BENCH_serve.json` for the CI
    // trace-smoke lane to parse.
    {
        use panther::serve::TraceConfig;
        use panther::util::events::EventClass;
        let mut server = ModelServer::new();
        server
            .register_tier(
                "traced",
                dense_model(1),
                D_IN,
                TierConfig {
                    max_batch: 8,
                    max_wait: Duration::from_micros(200),
                    queue_cap: 1024,
                    workers: 2,
                    ..TierConfig::default()
                },
            )
            .expect("register traced");
        hammer(&server, "traced", clients, 10.min(per_client)); // warm the pool
        let (wall_u, n_u) = hammer(&server, "traced", clients, per_client);
        let untraced_rps = n_u as f64 / wall_u.as_secs_f64();
        let tracer = server.enable_tracing(TraceConfig {
            ring_capacity: 1 << 16,
            bucket_capacity: 1 << 24,
            refill_per_sec: 0.0,
        });
        let (wall_t, n_t) = hammer(&server, "traced", clients, per_client);
        let traced_rps = n_t as f64 / wall_t.as_secs_f64();
        let log = tracer.log();
        let admits: u64 = log.tiers.iter().map(|t| t.recorded(EventClass::Admit)).sum();
        assert_eq!(admits, n_t, "every traced request must record its admission");
        let recorded: u64 = log.tiers.iter().flat_map(|t| t.recorded.iter().copied()).sum();
        let overhead_pct = 100.0 * (untraced_rps - traced_rps) / untraced_rps;
        report.entry_with(
            "trace_overhead",
            &format!("untraced clients={clients}"),
            wall_u.as_secs_f64() * 1e3,
            &[("rps", untraced_rps)],
        );
        report.entry_with(
            "trace_overhead",
            &format!("traced clients={clients}"),
            wall_t.as_secs_f64() * 1e3,
            &[
                ("rps", traced_rps),
                ("overhead_pct", overhead_pct),
                ("events_recorded", recorded as f64),
            ],
        );
        let dir = trace_export_dir();
        let jsonl = dir.join("TRACE_serve.jsonl");
        let chrome = dir.join("TRACE_serve_chrome.json");
        if let Err(e) = std::fs::write(&jsonl, log.export_jsonl()) {
            eprintln!("could not write {}: {e}", jsonl.display());
        }
        if let Err(e) = std::fs::write(&chrome, log.export_chrome_trace()) {
            eprintln!("could not write {}: {e}", chrome.display());
        }
        println!(
            "(tracing: {untraced_rps:.0} req/s untraced, {traced_rps:.0} traced \
             [{overhead_pct:+.1}%], {recorded} events; exports in {})",
            dir.display()
        );
        server.shutdown();
    }

    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
}

/// Where the trace exports go: `$PANTHER_BENCH_DIR` if set, else the
/// nearest ancestor containing `.git` — the same resolution
/// [`JsonReport::write`] uses, so the exports land next to
/// `BENCH_serve.json`.
fn trace_export_dir() -> std::path::PathBuf {
    if let Some(d) = std::env::var_os("PANTHER_BENCH_DIR") {
        return d.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        if dir.join(".git").exists() {
            return dir;
        }
        if !dir.pop() {
            return ".".into();
        }
    }
}
