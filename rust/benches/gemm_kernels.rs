//! GEMM kernel benchmark: the packed-panel microkernel across the shapes
//! the crate actually runs (square dense products, skinny sketch factors,
//! attention-head batches), with GFLOP/s and a machine-readable
//! `BENCH_gemm.json` report at the repo root — the perf baseline every
//! later kernel PR is diffed against.
//!
//! `--quick` shrinks shapes for the CI smoke lane; `PANTHER_BENCH_DIR`
//! redirects the JSON output.

use panther::linalg::{gemm, gemm_batch, gemm_threads, matmul, matmul_tn, Mat, MatMut, MatRef};
use panther::rng::Philox;
use panther::util::bench::{Bencher, JsonReport, Table};

fn gflops(m: usize, k: usize, n: usize, ms: f64) -> f64 {
    (2.0 * m as f64 * k as f64 * n as f64) / (ms / 1e3) / 1e9
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let threads = gemm_threads();
    let mut report = JsonReport::new("gemm", threads);
    println!("# GEMM kernels (packed-panel microkernel, {threads} threads)\n");

    // --- single products -----------------------------------------------------
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(64, 64, 64), (96, 256, 40)]
    } else {
        &[
            (256, 256, 256),
            (512, 512, 512),
            (1024, 1024, 1024),
            (2048, 512, 64),  // sketch first stage: tall×skinny
            (2048, 64, 512),  // sketch second stage: low-rank×wide
            (130, 300, 70),   // ragged tiles (MR/NR/MC/NC edges)
        ]
    };
    let mut rng = Philox::seeded(99);
    let mut table = Table::new(&["op", "shape", "ms", "GFLOP/s"]);
    for &(m, k, n) in shapes {
        let a = Mat::randn(m, k, &mut rng);
        let b = Mat::randn(k, n, &mut rng);
        let t = bench.run(&format!("matmul {m}x{k}x{n}"), || matmul(&a, &b));
        let shape = format!("{m}x{k}x{n}");
        let g = gflops(m, k, n, t.mean_ms());
        table.row(&[
            "matmul".into(),
            shape.clone(),
            format!("{:.3}", t.mean_ms()),
            format!("{g:.2}"),
        ]);
        report.entry("matmul", &shape, t.mean_ms(), Some(g));

        let mut c = Mat::zeros(m, n);
        let t = bench.run(&format!("gemm accum {shape}"), || {
            gemm(0.5, &a, &b, 1.0, &mut c);
        });
        let g = gflops(m, k, n, t.mean_ms());
        table.row(&[
            "gemm(alpha,beta)".into(),
            shape.clone(),
            format!("{:.3}", t.mean_ms()),
            format!("{g:.2}"),
        ]);
        report.entry("gemm_accum", &shape, t.mean_ms(), Some(g));
    }

    // --- Gram/TN shape -------------------------------------------------------
    {
        let (k, m) = if quick { (256, 64) } else { (2048, 256) };
        let a = Mat::randn(k, m, &mut rng);
        let t = bench.run("matmul_tn gram", || matmul_tn(&a, &a));
        let shape = format!("{m}x{k}x{m} (AtA)");
        let g = gflops(m, k, m, t.mean_ms());
        table.row(&[
            "matmul_tn".into(),
            shape.clone(),
            format!("{:.3}", t.mean_ms()),
            format!("{g:.2}"),
        ]);
        report.entry("matmul_tn", &shape, t.mean_ms(), Some(g));
    }

    // --- batched per-head attention shape ------------------------------------
    // h independent (n×dh)·(dh×n) score products over strided column views
    // of shared projections — one gemm_batch call, the attention hot shape.
    {
        let (n, d, h) = if quick { (128, 64, 8) } else { (512, 512, 8) };
        let dh = d / h;
        let q = Mat::randn(n, d, &mut rng);
        let k = Mat::randn(n, d, &mut rng);
        let mut scores: Vec<Mat> = (0..h).map(|_| Mat::zeros(n, n)).collect();
        let t = bench.run("gemm_batch heads", || {
            let a: Vec<MatRef> = (0..h)
                .map(|i| q.view().col_range(i * dh, (i + 1) * dh))
                .collect();
            let b: Vec<MatRef> = (0..h)
                .map(|i| k.view().col_range(i * dh, (i + 1) * dh).t())
                .collect();
            let mut c: Vec<MatMut> = scores.iter_mut().map(|s| s.view_mut()).collect();
            gemm_batch(1.0, &a, &b, 0.0, &mut c);
        });
        let shape = format!("{h}x({n}x{dh}x{n})");
        let g = gflops(n, dh, n, t.mean_ms() / h as f64);
        table.row(&[
            "gemm_batch".into(),
            shape.clone(),
            format!("{:.3}", t.mean_ms()),
            format!("{g:.2}"),
        ]);
        report.entry("gemm_batch_heads", &shape, t.mean_ms(), Some(g));
    }

    println!("{}", table.render());
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_gemm.json: {e}"),
    }
    println!("gemm_kernels done");
}
