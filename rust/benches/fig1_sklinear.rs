//! Figure 1 reproduction: forward-pass runtime of SKLinear vs dense Linear.
//!
//! Paper setup: d_in = d_out = 8192, l ∈ {1,2,3}, k ∈ {16,…,512}, mean over
//! 200 trials on T4/P100 GPUs; configurations violating the skip rule
//! `2lk(d_in+d_out) > d_in·d_out` are skipped.
//!
//! This CPU reproduction keeps the protocol but scales d to
//! {1024, 2048, 4096} (the environment has no GPU; both sides run on the
//! same Rust GEMM substrate, so the *relative* curves — who wins at which
//! (l,k), where the crossover sits — are the reproduced object).

use panther::linalg::Mat;
use panther::nn::cost::predicted_speedup;
use panther::nn::{sketch_beats_dense, Linear, SKLinear};
use panther::rng::Philox;
use panther::util::bench::{Bencher, Table};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let batch = 32usize;
    let dims: &[usize] = if quick { &[1024] } else { &[1024, 2048, 4096] };
    let terms: &[usize] = &[1, 2, 3];
    let ranks: &[usize] = &[16, 32, 64, 128, 256, 512];
    let bench = if quick {
        Bencher::quick()
    } else {
        Bencher::paper()
    };

    println!("# Figure 1: SKLinear forward runtime vs dense (batch {batch})");
    println!("# paper: d=8192 on T4/P100; here d∈{dims:?} on CPU, same-substrate comparison\n");
    let mut rng = Philox::seeded(42);
    for &d in dims {
        let dense = Linear::random(d, d, &mut rng);
        let x = Mat::randn(batch, d, &mut rng);
        let t_dense = bench.run(&format!("dense d={d}"), || dense.forward(&x));
        println!("== d_in = d_out = {d} ==");
        println!("dense: {:.3} ms", t_dense.mean_ms());
        let mut table = Table::new(&["l", "k", "ms", "speedup", "flop-model", "params vs dense"]);
        for &l in terms {
            for &k in ranks {
                if !sketch_beats_dense(d, d, l, k) {
                    table.row(&[
                        l.to_string(),
                        k.to_string(),
                        "skipped".into(),
                        "-".into(),
                        "-".into(),
                        "(2lk(din+dout) > din·dout)".into(),
                    ]);
                    continue;
                }
                let sk = SKLinear::from_dense(&dense, l, k, &mut rng);
                let t = bench.run(&format!("sk d={d} l={l} k={k}"), || sk.forward(&x));
                table.row(&[
                    l.to_string(),
                    k.to_string(),
                    format!("{:.3}", t.mean_ms()),
                    format!("{:.2}×", t_dense.mean_ms() / t.mean_ms()),
                    format!("{:.2}×", predicted_speedup(d, d, l, k)),
                    format!("{:.1}%", sk.compression_ratio() * 100.0),
                ]);
            }
        }
        println!("{}", table.render());
    }
    println!("fig1_sklinear done");
}
