//! Decomposition benchmarks: RSVD and CQRRPT vs deterministic baselines.
//!
//! Not a numbered figure in the paper (the decompositions are §2 library
//! features), but the RandNLA literature the paper builds on — [9] for
//! CQRRPT, Halko et al. for RSVD — reports exactly these two tables:
//! runtime scaling on tall matrices and error/orthogonality quality.

use panther::decomp::{
    cqrrpt, lstsq_normal_eq, rangefinder, rsvd, sketched_lstsq, CqrrptOpts, LstsqOpts,
    RangefinderOpts, RsvdOpts,
};
use panther::linalg::{fro_norm, matmul, matmul_tn, ortho_error, qr_thin, svd_jacobi, Mat};
use panther::rng::Philox;
use panther::util::bench::{Bencher, Table};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bench = if quick {
        Bencher::quick()
    } else {
        Bencher {
            min_samples: 5,
            max_samples: 20,
            target_time: std::time::Duration::from_secs(2),
            warmup: std::time::Duration::from_millis(100),
        }
    };
    let mut rng = Philox::seeded(1);

    // --- CQRRPT runtime scaling on tall matrices ---------------------------
    println!("# CQRRPT vs Householder QR — tall matrices (runtime + quality)\n");
    let sizes: &[(usize, usize)] = if quick {
        &[(2000, 50)]
    } else {
        &[(2000, 50), (8000, 100), (20000, 100)]
    };
    let mut table = Table::new(&[
        "size", "cqrrpt ms", "householder ms", "speedup", "cqrrpt ‖QᵀQ−I‖", "hh ‖QᵀQ−I‖",
    ]);
    for &(m, n) in sizes {
        let a = Mat::randn(m, n, &mut rng);
        let t_c = bench.run(&format!("cqrrpt {m}x{n}"), || {
            cqrrpt(&a, &CqrrptOpts::default())
        });
        let t_h = bench.run(&format!("householder {m}x{n}"), || qr_thin(&a));
        let f = cqrrpt(&a, &CqrrptOpts::default());
        let (q, _) = qr_thin(&a);
        table.row(&[
            format!("{m}×{n}"),
            format!("{:.1}", t_c.mean_ms()),
            format!("{:.1}", t_h.mean_ms()),
            format!("{:.2}×", t_h.mean_ms() / t_c.mean_ms()),
            format!("{:.2e}", ortho_error(&f.q)),
            format!("{:.2e}", ortho_error(&q)),
        ]);
    }
    println!("{}", table.render());

    // --- RSVD runtime + error vs exact SVD ---------------------------------
    println!("# RSVD vs Jacobi SVD — runtime and near-optimality\n");
    let (m, n) = if quick { (200, 120) } else { (500, 300) };
    let a = Mat::randn(m, n, &mut rng);
    let t_svd = bench.run("jacobi svd", || svd_jacobi(&a));
    let exact = svd_jacobi(&a);
    let mut table = Table::new(&["rank", "rsvd ms", "svd ms", "speedup", "err ratio vs optimal"]);
    for &rank in &[8usize, 16, 32] {
        let opts = RsvdOpts {
            rank,
            power_iters: 1,
            oversample: 8,
            seed: 9,
        };
        let t_r = bench.run(&format!("rsvd k={rank}"), || rsvd(&a, &opts));
        let f = rsvd(&a, &opts);
        let err = fro_norm(&a.sub(&f.reconstruct()));
        let opt = fro_norm(&a.sub(&exact.truncate(rank).reconstruct()));
        table.row(&[
            rank.to_string(),
            format!("{:.1}", t_r.mean_ms()),
            format!("{:.1}", t_svd.mean_ms()),
            format!("{:.1}×", t_svd.mean_ms() / t_r.mean_ms()),
            format!("{:.3}×", err / opt.max(1e-12)),
        ]);
    }
    println!("{}", table.render());

    // --- Ablation: power iterations in the rangefinder ---------------------
    println!("# Ablation: rangefinder power iterations (slow-decay spectrum)\n");
    let (m2, n2, full) = (300usize, 200usize, 100usize);
    let u = qr_thin(&Mat::randn(m2, full, &mut rng)).0;
    let v = qr_thin(&Mat::randn(n2, full, &mut rng)).0;
    let mut core = Mat::zeros(full, full);
    for i in 0..full {
        core.set(i, i, 1.0 / (i + 1) as f32);
    }
    let slow = matmul(&matmul(&u, &core), &v.transpose());
    let mut table = Table::new(&["power iters", "ms", "capture error"]);
    for q_iters in 0..=3usize {
        let opts = RangefinderOpts {
            rank: 16,
            oversample: 8,
            power_iters: q_iters,
            seed: 5,
        };
        let t = bench.run(&format!("rangefinder q={q_iters}"), || {
            rangefinder(&slow, &opts)
        });
        let qb = rangefinder(&slow, &opts);
        let resid = {
            let proj = matmul(&qb, &matmul_tn(&qb, &slow));
            fro_norm(&slow.sub(&proj)) / fro_norm(&slow)
        };
        table.row(&[
            q_iters.to_string(),
            format!("{:.1}", t.mean_ms()),
            format!("{resid:.4}"),
        ]);
    }
    println!("{}", table.render());

    // --- Sketched least squares vs normal equations -------------------------
    println!("# Sketch-and-precondition least squares vs normal equations\n");
    let mut table = Table::new(&["size", "sketched ms", "normal-eq ms", "speedup", "lsqr iters"]);
    let ls_sizes: &[(usize, usize)] = if quick {
        &[(4000, 60)]
    } else {
        &[(4000, 60), (16000, 120)]
    };
    for &(m, n) in ls_sizes {
        let a = Mat::randn(m, n, &mut rng);
        let bvec: Vec<f32> = a.matvec(&vec![1.0f32; n]);
        let t_s = bench.run(&format!("sketched lstsq {m}x{n}"), || {
            sketched_lstsq(&a, &bvec, &LstsqOpts::default()).unwrap()
        });
        let t_n = bench.run(&format!("normal eq {m}x{n}"), || {
            lstsq_normal_eq(&a, &bvec).unwrap()
        });
        let r = sketched_lstsq(&a, &bvec, &LstsqOpts::default()).unwrap();
        table.row(&[
            format!("{m}×{n}"),
            format!("{:.1}", t_s.mean_ms()),
            format!("{:.1}", t_n.mean_ms()),
            format!("{:.2}×", t_n.mean_ms() / t_s.mean_ms()),
            r.iters.to_string(),
        ]);
    }
    println!("{}", table.render());

    // --- Ablation: CholeskyQR2 refinement in CQRRPT --------------------------
    println!("# Ablation: CQRRPT CholeskyQR2 refinement\n");
    let a = Mat::randn(8000, 80, &mut rng);
    let mut table = Table::new(&["variant", "ms", "‖QᵀQ−I‖"]);
    for refine in [false, true] {
        let opts = CqrrptOpts {
            refine,
            ..Default::default()
        };
        let t = bench.run(&format!("cqrrpt refine={refine}"), || cqrrpt(&a, &opts));
        let f = cqrrpt(&a, &opts);
        table.row(&[
            if refine { "choleskyqr2" } else { "single pass" }.to_string(),
            format!("{:.1}", t.mean_ms()),
            format!("{:.2e}", ortho_error(&f.q)),
        ]);
    }
    println!("{}", table.render());
    println!("decomp done");
}
