//! Figure 2 reproduction: forward-pass runtime of SKConv2d vs dense Conv2d.
//!
//! Paper setup: input/output channels 256×2048, 9×9 kernel, 64×64 image,
//! l ∈ {1,2,3}, k ∈ {8,16,32}. Dense convolution at those sizes is a
//! 256·81 × 2048 GEMM over 64²·B patch rows — CPU-scaled here to channels
//! 64×512 and a 32×32 image (paper shapes with `--paper`, slow on CPU).
//! Both paths share the im2col, so the measured difference is exactly the
//! sketched-vs-dense GEMM — the object of the figure.

use panther::linalg::Mat;
use panther::nn::conv::{im2col, Conv2d, ConvShape, SKConv2d};
use panther::nn::cost::sketch_beats_dense;
use panther::rng::Philox;
use panther::util::bench::{Bencher, Table};

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let paper = std::env::args().any(|a| a == "--paper");
    let (c_in, c_out, kernel, image) = if paper {
        (256usize, 2048usize, 9usize, 64usize)
    } else if quick {
        (32, 128, 5, 16)
    } else {
        (64, 512, 9, 32)
    };
    let batch = 1usize;
    let shape = ConvShape {
        c_in,
        c_out,
        kernel,
        image,
        padding: kernel / 2,
    };
    let bench = if quick {
        Bencher::quick()
    } else {
        Bencher::paper()
    };
    let d_in = shape.patch_dim();

    println!("# Figure 2: SKConv2d forward runtime vs dense Conv2d");
    println!(
        "# channels {c_in}→{c_out}, kernel {kernel}×{kernel}, image {image}×{image} (paper: 256→2048, 9, 64)\n"
    );
    let mut rng = Philox::seeded(7);
    let dense = Conv2d::random(shape, &mut rng);
    let x = Mat::randn(batch, c_in * image * image, &mut rng);
    // Shared im2col (both sides pay it; measured separately below).
    let t_im2col = bench.run("im2col", || im2col(&x, &shape));
    let cols = im2col(&x, &shape);
    let t_dense = bench.run("dense conv gemm", || dense.forward_cols(&cols));
    println!(
        "im2col: {:.3} ms; dense GEMM: {:.3} ms (patch dim {d_in})",
        t_im2col.mean_ms(),
        t_dense.mean_ms()
    );
    let mut table = Table::new(&["l", "k", "gemm ms", "speedup", "params vs dense"]);
    for &l in &[1usize, 2, 3] {
        for &k in &[8usize, 16, 32] {
            if !sketch_beats_dense(d_in, c_out, l, k) {
                table.row(&[
                    l.to_string(),
                    k.to_string(),
                    "skipped".into(),
                    "-".into(),
                    "-".into(),
                ]);
                continue;
            }
            let sk = SKConv2d::from_dense(&dense, l, k, &mut rng);
            let t = bench.run(&format!("sk l={l} k={k}"), || sk.forward_cols(&cols));
            table.row(&[
                l.to_string(),
                k.to_string(),
                format!("{:.3}", t.mean_ms()),
                format!("{:.2}×", t_dense.mean_ms() / t.mean_ms()),
                format!("{:.1}%", sk.compression_ratio() * 100.0),
            ]);
        }
    }
    println!("{}", table.render());
    println!("fig2_skconv2d done");
}
