//! End-to-end runtime benchmarks over the AOT artifacts: per-step latency
//! of the dense vs sketched BERT train/eval executables, kernel-artifact
//! latency, and coordinator round-trip overhead.
//!
//! This is the §4.2-adjacent "what do you actually pay per step" table —
//! the figure benches isolate layer math; this one times the full compiled
//! HLO through PJRT, exactly what production inference/training would run.

use panther::coordinator::RuntimeServer;
use panther::data::TextCorpus;
use panther::rng::Philox;
use panther::runtime::{HostTensor, Runtime};
use panther::train::{BertTrainer, ModelState};
use panther::util::bench::{Bencher, JsonReport, Table};

fn main() -> anyhow::Result<()> {
    let mut report = JsonReport::new("e2e", panther::linalg::gemm_threads());
    // --- attention + conv forward ms/step ------------------------------------
    // The layer-level hot paths the packed/batched kernel work targets:
    // dense + Performer attention and dense + sketched conv forwards at a
    // BERT-ish shape, no artifacts needed. Recorded in BENCH_e2e.json so
    // kernel regressions on these paths are diffable across PRs.
    {
        use panther::nn::attention::{
            AttnWeights, KernelKind, MultiHeadAttention, RandMultiHeadAttention,
        };
        use panther::nn::conv::{Conv2d, ConvShape, SKConv2d};
        use panther::nn::{ForwardCtx, Module};
        println!("# Module forward ms/step (attention + conv hot paths)\n");
        let bench = Bencher::quick();
        let mut rng = Philox::seeded(23);
        let (n, d, h, m) = (512usize, 256usize, 8usize, 128usize);
        let x = panther::linalg::Mat::randn(n, d, &mut rng);
        let ctx = ForwardCtx::new();
        let mut table = Table::new(&["layer", "shape", "fwd ms"]);
        let w = AttnWeights::random(d, h, &mut rng);
        let mha = MultiHeadAttention::new(w.clone());
        let t = bench.run("mha fwd", || mha.forward(&x, &ctx).unwrap());
        let shape = format!("n={n} d={d} h={h}");
        table.row(&["MultiHeadAttention".into(), shape.clone(), format!("{:.3}", t.mean_ms())]);
        report.entry("attention_fwd", &shape, t.mean_ms(), None);
        let perf = RandMultiHeadAttention::new(w, m, KernelKind::Softmax, 5);
        let t = bench.run("performer fwd", || perf.forward(&x, &ctx).unwrap());
        let shape = format!("n={n} d={d} h={h} m={m}");
        table.row(&["RandMultiHeadAttention".into(), shape.clone(), format!("{:.3}", t.mean_ms())]);
        report.entry("performer_fwd", &shape, t.mean_ms(), None);
        let cshape = ConvShape { c_in: 32, c_out: 128, kernel: 3, image: 32, padding: 1 };
        let xc_cols = cshape.c_in * cshape.image * cshape.image;
        let xc = panther::linalg::Mat::randn(4, xc_cols, &mut rng);
        let conv = Conv2d::random(cshape, &mut rng);
        let t = bench.run("conv fwd", || Module::forward(&conv, &xc, &ctx).unwrap());
        let shape = "B=4 32->128 k3 im32".to_string();
        table.row(&["Conv2d".into(), shape.clone(), format!("{:.3}", t.mean_ms())]);
        report.entry("conv_fwd", &shape, t.mean_ms(), None);
        let skconv = SKConv2d::random(cshape, 2, 8, &mut rng);
        let t = bench.run("skconv fwd", || Module::forward(&skconv, &xc, &ctx).unwrap());
        table.row(&["SKConv2d l=2 r=8".into(), shape.clone(), format!("{:.3}", t.mean_ms())]);
        report.entry("skconv_fwd", &shape, t.mean_ms(), None);
        println!("{}", table.render());
    }

    // --- native Trainer step latency: dense vs sketched ---------------------
    // The nn-side loss→backward→step loop needs no artifacts, so it runs
    // (and is timed) unconditionally: what one fine-tune step costs on the
    // dense stack vs the same stack after SketchPlan compression.
    {
        use panther::nn::{ForwardCtx, LayerSelector, Linear, Model, SketchPlan};
        use panther::train::{Adam, Trainer};
        println!("# Native Trainer (Module backward): ms/step dense vs sketched\n");
        let bench = Bencher::quick();
        let mut rng = Philox::seeded(17);
        let (d, batch) = (512usize, 64usize);
        let x = panther::linalg::Mat::randn(batch, d, &mut rng);
        let build = |rng: &mut Philox| {
            let mut m = Model::new();
            m.add("ffn.fc1", Linear::random(d, d, rng)).unwrap();
            m.add("ffn.fc2", Linear::random(d, d, rng)).unwrap();
            m
        };
        let teacher = build(&mut rng);
        let ctx = ForwardCtx::new().batch_hint(batch);
        let y = teacher.forward(&x, &ctx)?;
        let mut table = Table::new(&["model", "params", "train ms/step"]);
        for sketched in [false, true] {
            let mut model = build(&mut Philox::seeded(18));
            let label = if sketched {
                SketchPlan::new()
                    .select(LayerSelector::by_regex(r"ffn\.fc\d")?)
                    .with(1, 16)
                    .seed(5)
                    .apply(&mut model)?;
                "mlp_sk_1_16"
            } else {
                "mlp_dense"
            };
            let params = model.total_params();
            let mut tr = Trainer::new(Box::new(Adam::new(1e-3)));
            tr.train_step(&mut model, &x, &y, &ctx)?; // warm (grad buffers)
            let t = bench.run(label, || tr.train_step(&mut model, &x, &y, &ctx).unwrap());
            table.row(&[
                label.to_string(),
                params.to_string(),
                format!("{:.2}", t.mean_ms()),
            ]);
            report.entry(
                &format!("trainer_{label}"),
                &format!("batch={batch} d={d}"),
                t.mean_ms(),
                None,
            );
        }
        println!("{}", table.render());
    }

    // The JSON report covers the artifact-free sections above, so the bench
    // smoke lane (no PJRT, no prebuilt artifacts) still seeds the perf
    // trajectory.
    match report.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_e2e.json: {e}"),
    }

    let artifacts =
        std::env::var("PANTHER_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        println!("artifacts/ not built — run `make artifacts` first; skipping e2e bench");
        return Ok(());
    }
    let bench = Bencher::quick();
    let mut rng = Philox::seeded(3);

    // --- kernel artifacts ---------------------------------------------------
    println!("# Kernel artifact latency (PJRT CPU)\n");
    let mut rt = Runtime::open(&artifacts)?;
    let mut table = Table::new(&["artifact", "mean", "median"]);
    for name in ["k_sk_linear", "k_performer"] {
        let spec = rt.manifest().artifact(name).unwrap().clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::randn(&s.shape, 0.5, &mut rng))
            .collect();
        rt.execute(name, &inputs)?; // compile outside the timer
        let t = bench.run(name, || rt.execute(name, &inputs).unwrap());
        table.row(&[
            name.to_string(),
            format!("{:.3} ms", t.mean_ms()),
            format!("{:.3} ms", t.median_ms()),
        ]);
    }
    println!("{}", table.render());

    // --- train/eval step latency: dense vs sketched -------------------------
    println!("# BERT train/eval step latency: dense vs sketched (batch from manifest)\n");
    let corpus = TextCorpus::generate(256, 50_000, 5);
    let mut table = Table::new(&["model", "params", "train ms/step", "eval ms/batch"]);
    for model in ["bert_dense", "bert_sk_1_8"] {
        let spec = rt.manifest().model(model).unwrap().clone();
        let mut state = ModelState::init(&mut rt, model, 0.0)?;
        let mut data_rng = Philox::seeded(9);
        let (batch, seq) = (
            spec.config_usize("batch").unwrap(),
            spec.config_usize("seq").unwrap(),
        );
        let mb = corpus.mlm_batch(batch, seq, &mut data_rng);
        let train_art = spec.train.clone().unwrap();
        // Warm both executables.
        {
            let mut tr = BertTrainer::new(&mut rt, &corpus);
            tr.step(&mut state, &train_art, &mb)?;
        }
        let t_train = {
            let mut tr = BertTrainer::new(&mut rt, &corpus);
            bench.run(&format!("{model} train"), || {
                tr.step(&mut state, &train_art, &mb).unwrap()
            })
        };
        let t_eval = {
            let mut tr = BertTrainer::new(&mut rt, &corpus);
            let mut erng = Philox::seeded(10);
            bench.run(&format!("{model} eval"), || {
                tr.evaluate(&state, 1, &mut erng).unwrap()
            })
        };
        table.row(&[
            model.to_string(),
            spec.param_count.to_string(),
            format!("{:.2}", t_train.mean_ms()),
            format!("{:.2}", t_eval.mean_ms()),
        ]);
    }
    println!("{}", table.render());

    // --- checkpoint serialization --------------------------------------------
    // Chunked bulk IO (64 KiB little-endian chunks) rather than one 4-byte
    // write per f32 — size and seconds reported so regressions on this
    // path stay visible.
    {
        let state = ModelState::init(&mut rt, "bert_dense", 0.0)?;
        let path = std::env::temp_dir().join("panther_e2e_bench.ckpt");
        let t0 = std::time::Instant::now();
        panther::train::checkpoint::save(&state, &path)?;
        let t_save = t0.elapsed();
        let bytes = std::fs::metadata(&path)?.len();
        let t1 = std::time::Instant::now();
        let restored = panther::train::checkpoint::load(&path)?;
        let t_load = t1.elapsed();
        println!("# Checkpoint (v2, name-keyed, bulk tensor IO)\n");
        println!(
            "{} written in {:.2?} ({:.0} MB/s), loaded in {:.2?}; {} named tensors\n",
            panther::util::human_bytes(bytes),
            t_save,
            bytes as f64 / 1e6 / t_save.as_secs_f64().max(1e-9),
            t_load,
            restored.names.len()
        );
        std::fs::remove_file(&path).ok();
    }
    drop(rt);

    // --- coordinator round-trip overhead -------------------------------------
    println!("# Coordinator RPC overhead (request → runtime thread → reply)\n");
    let server = RuntimeServer::start(&artifacts)?;
    let h = server.handle();
    let spec = h.manifest().artifact("k_sk_linear").unwrap().clone();
    let inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| HostTensor::zeros(&s.shape))
        .collect();
    h.execute("k_sk_linear", inputs.clone())?; // warm
    let t_rpc = bench.run("coordinator rpc", || {
        h.execute("k_sk_linear", inputs.clone()).unwrap()
    });
    println!("{}", t_rpc.report());
    println!(
        "(direct runtime call above was ~the kernel latency; the difference is channel + clone overhead)\n"
    );

    // --- dynamic batcher throughput ------------------------------------------
    // Serving-path table: requests/s scoring single sequences, unbatched
    // (one exec per request) vs coalesced through the DynamicBatcher.
    if server
        .handle()
        .manifest()
        .model("bert_dense")
        .and_then(|m| m.eval_rows.clone())
        .is_some()
    {
        println!("# Dynamic batcher: single-sequence MLM scoring throughput\n");
        let mut rt2 = Runtime::open(&artifacts)?;
        let state = panther::train::ModelState::init(&mut rt2, "bert_dense", 0.0)?;
        drop(rt2);
        fn mk_req(seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
            let seq = 64usize;
            let mut rng = Philox::seeded(seed);
            use panther::rng::Rng;
            let tokens: Vec<f32> = (0..seq)
                .map(|_| (2 + rng.next_below(254)) as f32)
                .collect();
            let mut mask = vec![0f32; seq];
            for m in mask.iter_mut().take(16) {
                *m = 1.0;
            }
            (tokens.clone(), tokens, mask)
        }
        let n_requests = 64usize;
        // Batched path.
        let batcher = panther::coordinator::DynamicBatcher::start_from_state(
            server.handle(),
            &state,
            std::time::Duration::from_millis(20),
        )?;
        let t0 = std::time::Instant::now();
        let workers: Vec<_> = (0..n_requests)
            .map(|i| {
                let h = batcher.handle();
                std::thread::spawn(move || {
                    let (t, l, m) = mk_req(i as u64);
                    h.score(&t, &l, &m).unwrap()
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        let batched = t0.elapsed();
        let occupancy = batcher.handle().stats().mean_occupancy();
        println!(
            "batched:   {n_requests} requests in {:.1?} ({:.1} req/s, mean occupancy {:.1} rows/exec)",
            batched,
            n_requests as f64 / batched.as_secs_f64(),
            occupancy
        );
        println!(
            "(each execution scores a fixed 16-row batch; unbatched serving would pay one\n full execution per request — occupancy is the measured coalescing factor)\n"
        );
    }
    println!("e2e_runtime done");
    Ok(())
}
