//! Figure 3 reproduction: peak forward memory of Performer linear attention
//! vs exact multi-head attention.
//!
//! Paper setup: embed dim 512, softmax kernel, sequence lengths up to 8192,
//! heads ∈ {4,8,16}, random features ∈ {64,128,256}; "x" markers where
//! PyTorch OOMs on the GPU.
//!
//! Reproduction: both attention implementations route every activation
//! through the accounting allocator (`util::memtrack`); a device-memory
//! budget turns would-be OOMs into clean "x" rows. Peak bytes are measured,
//! not modeled (the analytic model in `nn::cost` is cross-checked against
//! the measurement here).

use panther::linalg::Mat;
use panther::nn::attention::{
    AttnWeights, KernelKind, MultiHeadAttention, RandMultiHeadAttention,
};
use panther::nn::cost::{dense_attention_mem, performer_attention_mem};
use panther::nn::{ForwardCtx, Module};
use panther::rng::Philox;
use panther::util::bench::Table;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let d = 512usize;
    // 4 GiB "device" budget (a T4 has 16 GiB, but it also holds weights,
    // optimizer state and framework overhead; the crossover shape is what
    // matters).
    let budget: u64 = 4 * 1024 * 1024 * 1024;
    let seqs: &[usize] = if quick {
        &[512, 2048]
    } else {
        &[512, 1024, 2048, 4096, 8192]
    };
    let heads: &[usize] = if quick { &[8] } else { &[4, 8, 16] };
    let features: &[usize] = if quick { &[128] } else { &[64, 128, 256] };

    println!("# Figure 3: peak forward memory, Performer vs dense MHA (embed {d}, softmax kernel)");
    println!(
        "# budget {} — rows marked 'x' exceed it (the paper's OOM markers)",
        panther::util::human_bytes(budget)
    );
    println!(
        "# fwd ms: wall time of the measured forward call (single sample — the\n\
         # memory accounting is the figure; ms rows feed the perf trajectory)\n"
    );
    let mut rng = Philox::seeded(11);
    let mut table = Table::new(&[
        "seq",
        "heads",
        "m",
        "dense peak",
        "dense ms",
        "dense",
        "performer peak",
        "perf ms",
        "performer",
        "model dense",
        "model perf",
    ]);
    for &h in heads {
        let weights = AttnWeights::random(d, h, &mut rng);
        let dense = MultiHeadAttention::new(weights.clone());
        for &n in seqs {
            let x = Mat::randn(n, d, &mut rng);
            let ctx_d = ForwardCtx::with_budget(budget);
            let t0 = std::time::Instant::now();
            let dense_res = dense.forward(&x, &ctx_d);
            let dense_ms = t0.elapsed().as_secs_f64() * 1e3;
            let (dense_peak, dense_ms_s, dense_status) = match dense_res {
                Ok(_) => (
                    panther::util::human_bytes(ctx_d.mem().peak_bytes()),
                    format!("{dense_ms:.1}"),
                    "ok".to_string(),
                ),
                Err(_) => ("-".into(), "-".into(), "x".to_string()),
            };
            for &m in features {
                let perf = RandMultiHeadAttention::new(weights.clone(), m, KernelKind::Softmax, 3);
                let ctx_p = ForwardCtx::with_budget(budget);
                let t0 = std::time::Instant::now();
                let perf_res = perf.forward(&x, &ctx_p);
                let perf_ms = t0.elapsed().as_secs_f64() * 1e3;
                let (perf_peak, perf_ms_s, perf_status) = match perf_res {
                    Ok(_) => (
                        panther::util::human_bytes(ctx_p.mem().peak_bytes()),
                        format!("{perf_ms:.1}"),
                        "ok".to_string(),
                    ),
                    Err(_) => ("-".into(), "-".into(), "x".to_string()),
                };
                table.row(&[
                    n.to_string(),
                    h.to_string(),
                    m.to_string(),
                    dense_peak.clone(),
                    dense_ms_s.clone(),
                    dense_status.clone(),
                    perf_peak,
                    perf_ms_s,
                    perf_status,
                    panther::util::human_bytes(dense_attention_mem(n, d, h)),
                    panther::util::human_bytes(performer_attention_mem(n, d, h, m)),
                ]);
            }
        }
    }
    println!("{}", table.render());
    println!("fig3_attention_mem done");
}
