//! Tuner-engine benchmarks: sampler throughput and study overhead — the
//! coordinator-side cost of SKAutoTuner (§2.2). The paper's pitch is that
//! the tuner "automates the trade-off analysis"; this bench shows the
//! automation itself is cheap relative to a single candidate evaluation
//! (which costs milliseconds-to-seconds of model execution).

use panther::tuner::{
    Direction, GridSampler, MedianPruner, NoPruner, RandomSampler, Sampler, SearchSpace, Study,
    TpeSampler,
};
use panther::util::bench::{Bencher, Table};

fn drive(study: &mut Study, trials: usize) {
    for i in 0..trials {
        let mut t = study.ask();
        // Synthetic objective over the sketch space.
        let l = t.params["num_terms"].as_f64().unwrap();
        let k = t.params["low_rank"].as_f64().unwrap();
        let value = (k - 24.0).abs() + 4.0 * (l - 1.0);
        study.tell(&mut t, value, i % 7 != 0);
    }
}

fn main() {
    let bench = Bencher::quick();
    println!("# Tuner engine overhead\n");
    let mut table = Table::new(&["sampler", "100-trial study", "per trial"]);
    for name in ["random", "grid", "tpe"] {
        let t = bench.run(name, || {
            let sampler: Box<dyn Sampler> = match name {
                "random" => Box::new(RandomSampler::new(1)),
                "grid" => Box::new(GridSampler::new(1)),
                _ => Box::new(TpeSampler::new(1)),
            };
            let mut study = Study::new(
                "bench",
                Direction::Minimize,
                SearchSpace::auto_sketch(64),
                sampler,
                Box::new(NoPruner),
            );
            drive(&mut study, 100);
            study.best_value()
        });
        table.row(&[
            name.to_string(),
            format!("{:.3} ms", t.mean_ms()),
            format!("{:.1} µs", t.mean_ms() * 10.0),
        ]);
    }
    println!("{}", table.render());

    // Pruner evaluation cost on a deep history.
    let t = bench.run("median-pruner check", || {
        let mut study = Study::new(
            "p",
            Direction::Minimize,
            SearchSpace::auto_sketch(64),
            Box::new(RandomSampler::new(2)),
            Box::new(MedianPruner::default()),
        );
        for _ in 0..50 {
            let mut tr = study.ask();
            for step in 0..10 {
                if study.should_prune(&mut tr, step, step as f64) {
                    break;
                }
            }
            if tr.state != panther::tuner::TrialState::Pruned {
                study.tell(&mut tr, 1.0, true);
            }
        }
        study.trials().len()
    });
    println!("50 trials × 10 interim reports with MedianPruner: {}", t.report());
    println!("tuner_overhead done");
}
