//! Randomized SVD (Halko–Martinsson–Tropp 2011).

use super::rangefinder::{rangefinder, RangefinderOpts};
use crate::linalg::{matmul, matmul_tn, svd_jacobi, Mat, Svd};

/// RSVD options.
#[derive(Debug, Clone)]
pub struct RsvdOpts {
    pub rank: usize,
    pub oversample: usize,
    pub power_iters: usize,
    pub seed: u64,
}

impl Default for RsvdOpts {
    fn default() -> Self {
        RsvdOpts {
            rank: 10,
            oversample: 8,
            power_iters: 1,
            seed: 0,
        }
    }
}

/// Rank-`k` randomized SVD: Stage A finds `Q` spanning the approximate
/// range; Stage B takes the exact SVD of the small matrix `B = QᵀA` and
/// lifts: `A ≈ (Q·Ũ)·diag(s)·Vᵀ`. Cost: O(mnk) + O(nk²) instead of O(mn²).
pub fn rsvd(a: &Mat, opts: &RsvdOpts) -> Svd {
    let q = rangefinder(
        a,
        &RangefinderOpts {
            rank: opts.rank,
            oversample: opts.oversample,
            power_iters: opts.power_iters,
            seed: opts.seed,
        },
    );
    let b = matmul_tn(&q, a); // (k+p) × n
    let small = svd_jacobi(&b);
    let u = matmul(&q, &small.u); // m × r
    Svd {
        u,
        s: small.s,
        v: small.v,
    }
    .truncate(opts.rank)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_norm, ortho_error, rel_error};
    use crate::rng::Philox;
    use crate::util::prop::prop_check;

    fn low_rank(m: usize, n: usize, r: usize, seed: u64) -> Mat {
        let mut rng = Philox::seeded(seed);
        matmul(&Mat::randn(m, r, &mut rng), &Mat::randn(r, n, &mut rng))
    }

    #[test]
    fn recovers_exact_low_rank() {
        let a = low_rank(80, 50, 6, 81);
        let f = rsvd(&a, &RsvdOpts { rank: 6, ..Default::default() });
        assert!(rel_error(&f.reconstruct(), &a) < 1e-3);
        assert!(ortho_error(&f.u) < 1e-3);
        assert!(ortho_error(&f.v) < 1e-3);
        assert_eq!(f.s.len(), 6);
    }

    #[test]
    fn near_optimal_vs_truncated_svd() {
        // RSVD error should be within a modest factor of the optimal
        // (Eckart–Young) rank-k error.
        let mut rng = Philox::seeded(82);
        let a = Mat::randn(60, 40, &mut rng);
        let k = 10;
        let exact = svd_jacobi(&a);
        let opt_err = fro_norm(&a.sub(&exact.truncate(k).reconstruct()));
        let f = rsvd(&a, &RsvdOpts { rank: k, oversample: 10, power_iters: 2, seed: 3 });
        let rand_err = fro_norm(&a.sub(&f.reconstruct()));
        assert!(
            rand_err <= opt_err * 1.5 + 1e-6,
            "rsvd {rand_err} vs optimal {opt_err}"
        );
    }

    #[test]
    fn singular_values_close_on_decaying_spectrum() {
        let mut rng = Philox::seeded(83);
        let (m, n, full) = (70, 50, 20);
        let u = crate::linalg::qr_thin(&Mat::randn(m, full, &mut rng)).0;
        let v = crate::linalg::qr_thin(&Mat::randn(n, full, &mut rng)).0;
        let mut core = Mat::zeros(full, full);
        for i in 0..full {
            core.set(i, i, (0.5f32).powi(i as i32));
        }
        let a = matmul(&matmul(&u, &core), &v.transpose());
        let f = rsvd(&a, &RsvdOpts { rank: 5, power_iters: 2, seed: 7, oversample: 10 });
        for i in 0..5 {
            let truth = (0.5f32).powi(i as i32);
            assert!(
                (f.s[i] - truth).abs() < 0.05 * truth,
                "σ_{i}: {} vs {}",
                f.s[i],
                truth
            );
        }
    }

    #[test]
    fn property_rank_and_orthogonality() {
        prop_check("rsvd-props", 10, |g| {
            let m = 20 + g.usize(0..40);
            let n = 10 + g.usize(0..30);
            let k = g.usize(1..=8.min(n.min(m)));
            let a = Mat::randn(m, n, g.rng());
            let f = rsvd(&a, &RsvdOpts { rank: k, seed: 11, ..Default::default() });
            assert_eq!(f.u.shape(), (m, k.min(n).min(m)));
            assert!(ortho_error(&f.u) < 1e-3);
            // Singular values sorted.
            for w in f.s.windows(2) {
                assert!(w[0] >= w[1] - 1e-6);
            }
        });
    }
}
