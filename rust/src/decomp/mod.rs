//! Randomized matrix decompositions — the algorithmic heart of Panther.
//!
//! - [`rsvd`] — randomized SVD (Halko–Martinsson–Tropp): rangefinder +
//!   power iteration + small exact SVD.
//! - [`cqrrpt`] — CholeskyQR with Randomization and Pivoting for Tall
//!   matrices (Melnichenko et al. 2025, the paper's reference [9]).
//! - [`pivoted_cholesky`] — low-rank PSD approximation with greedy
//!   diagonal pivoting.
//! - Deterministic baselines ([`crate::linalg::qr_thin`],
//!   [`crate::linalg::svd_jacobi`]) live in `linalg`; the decomposition
//!   benches compare against them.

mod cqrrpt;
mod lstsq;
mod pivoted_cholesky;
mod rangefinder;
mod rsvd;

pub use cqrrpt::{cqrrpt, CqrrptOpts, CqrrptResult};
pub use lstsq::{lstsq_normal_eq, sketched_lstsq, LstsqOpts, LstsqResult};
pub use pivoted_cholesky::{pivoted_cholesky, PivCholResult};
pub use rangefinder::{rangefinder, RangefinderOpts};
pub use rsvd::{rsvd, RsvdOpts};
