//! Pivoted (partial) Cholesky: greedy low-rank approximation of a PSD
//! matrix, `A ≈ L·Lᵀ` with `L` m×k. Listed among Panther's randomized
//! decompositions ("pivoted CholeskyQR"); also the standard tool for kernel
//! matrix compression.

use crate::linalg::Mat;

/// Result of the pivoted Cholesky.
pub struct PivCholResult {
    /// The m×k factor (rows in original ordering).
    pub l: Mat,
    /// Pivot order (indices selected, best first).
    pub pivots: Vec<usize>,
    /// Trace residual after each step (diagnostic; length k).
    pub residuals: Vec<f64>,
}

/// Greedy diagonal-pivoted partial Cholesky of PSD `a`, stopping at rank
/// `max_rank` or when the trace residual falls below `tol · trace(A)`.
pub fn pivoted_cholesky(a: &Mat, max_rank: usize, tol: f64) -> PivCholResult {
    let n = a.rows();
    assert_eq!(a.rows(), a.cols());
    let mut diag: Vec<f64> = (0..n).map(|i| a.get(i, i) as f64).collect();
    let trace0: f64 = diag.iter().sum();
    let mut l: Vec<Vec<f64>> = Vec::new(); // columns of L
    let mut pivots = Vec::new();
    let mut residuals = Vec::new();
    let k = max_rank.min(n);
    for step in 0..k {
        // Largest remaining diagonal.
        let (p, &dmax) = diag
            .iter()
            .enumerate()
            .max_by(|x, y| x.1.partial_cmp(y.1).unwrap())
            .unwrap();
        if dmax <= tol * trace0.max(1e-300) || dmax <= 0.0 {
            break;
        }
        let sq = dmax.sqrt();
        // New column: (A[:,p] - Σ_j L[:,j]·L[p,j]) / sqrt(d_p)
        let mut col = vec![0f64; n];
        for i in 0..n {
            let mut v = a.get(i, p) as f64;
            for lj in &l {
                v -= lj[i] * lj[p];
            }
            col[i] = v / sq;
        }
        for i in 0..n {
            diag[i] -= col[i] * col[i];
        }
        let _ = step;
        pivots.push(p);
        residuals.push(diag.iter().cloned().fold(0.0, f64::max).max(0.0));
        l.push(col);
    }
    let rank = l.len();
    let mut lm = Mat::zeros(n, rank);
    for (j, col) in l.iter().enumerate() {
        for i in 0..n {
            lm.set(i, j, col[i] as f32);
        }
    }
    PivCholResult {
        l: lm,
        pivots,
        residuals,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_tn, rel_error};
    use crate::rng::Philox;

    #[test]
    fn exact_on_low_rank_psd() {
        let mut rng = Philox::seeded(101);
        let b = Mat::randn(20, 4, &mut rng);
        let a = matmul(&b, &b.transpose()); // PSD, rank 4
        let f = pivoted_cholesky(&a, 10, 1e-8);
        assert!(f.l.cols() <= 5, "rank inflated: {}", f.l.cols());
        let rec = matmul(&f.l, &f.l.transpose());
        assert!(rel_error(&rec, &a) < 1e-3);
    }

    #[test]
    fn full_rank_full_factorization() {
        let mut rng = Philox::seeded(102);
        let b = Mat::randn(30, 12, &mut rng);
        let a = matmul_tn(&b, &b); // 12×12 SPD
        let f = pivoted_cholesky(&a, 12, 0.0);
        assert_eq!(f.l.cols(), 12);
        let rec = matmul(&f.l, &f.l.transpose());
        assert!(rel_error(&rec, &a) < 1e-3);
    }

    #[test]
    fn pivots_unique_and_residual_decreasing() {
        let mut rng = Philox::seeded(103);
        let b = Mat::randn(25, 25, &mut rng);
        let a = matmul(&b, &b.transpose());
        let f = pivoted_cholesky(&a, 15, 0.0);
        let set: std::collections::HashSet<_> = f.pivots.iter().collect();
        assert_eq!(set.len(), f.pivots.len());
        for w in f.residuals.windows(2) {
            assert!(w[1] <= w[0] * 1.001, "residual grew: {:?}", w);
        }
    }

    #[test]
    fn truncation_gives_partial_approx() {
        let mut rng = Philox::seeded(104);
        let b = Mat::randn(20, 20, &mut rng);
        let a = matmul(&b, &b.transpose());
        let f2 = pivoted_cholesky(&a, 2, 0.0);
        let f8 = pivoted_cholesky(&a, 8, 0.0);
        let e2 = rel_error(&matmul(&f2.l, &f2.l.transpose()), &a);
        let e8 = rel_error(&matmul(&f8.l, &f8.l.transpose()), &a);
        assert!(e8 < e2, "more rank must reduce error: {e8} vs {e2}");
    }
}
