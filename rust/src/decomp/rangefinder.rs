//! Randomized rangefinder: find `Q` with orthonormal columns such that
//! `‖A − QQᵀA‖` is small. Stage A of RSVD (Halko et al., §4).

use crate::linalg::{matmul, matmul_tn, qr_thin, Mat};
use crate::sketch::{GaussianSketch, Sketch};

/// Rangefinder options.
#[derive(Debug, Clone)]
pub struct RangefinderOpts {
    /// Target rank `k`.
    pub rank: usize,
    /// Oversampling `p` (Halko recommends 5–10).
    pub oversample: usize,
    /// Power-iteration count `q` (0 = plain sketch; 1–2 sharpens spectra
    /// with slow decay).
    pub power_iters: usize,
    /// RNG seed.
    pub seed: u64,
}

impl Default for RangefinderOpts {
    fn default() -> Self {
        RangefinderOpts {
            rank: 10,
            oversample: 8,
            power_iters: 1,
            seed: 0,
        }
    }
}

/// Compute an orthonormal basis `Q (m × (k+p))` for the approximate range of
/// `A (m × n)` via `Y = (A Aᵀ)^q · A · Ω` with QR re-orthonormalization
/// between powers (numerically essential for q ≥ 1).
pub fn rangefinder(a: &Mat, opts: &RangefinderOpts) -> Mat {
    let (m, n) = a.shape();
    let l = (opts.rank + opts.oversample).min(n).min(m).max(1);
    // Ω: n × l gaussian. Applying our Sketch trait on Aᵀ would transpose; we
    // materialize Ω directly instead for clarity.
    let omega = GaussianSketch::new(n, l, opts.seed).to_dense().transpose(); // n×l
    let mut y = matmul(a, &omega); // m×l
    let (mut q, _) = qr_thin(&y);
    for _ in 0..opts.power_iters {
        // Z = Aᵀ Q ; re-orthonormalize; Y = A Z ; re-orthonormalize.
        let z = matmul_tn(a, &q); // n×l
        let (zq, _) = qr_thin(&z);
        y = matmul(a, &zq);
        let (qq, _) = qr_thin(&y);
        q = qq;
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_norm, ortho_error};
    use crate::rng::Philox;

    /// Matrix with exactly rank r.
    fn low_rank(m: usize, n: usize, r: usize, seed: u64) -> Mat {
        let mut rng = Philox::seeded(seed);
        let u = Mat::randn(m, r, &mut rng);
        let v = Mat::randn(r, n, &mut rng);
        matmul(&u, &v)
    }

    #[test]
    fn captures_exact_low_rank() {
        let a = low_rank(60, 40, 5, 71);
        let q = rangefinder(
            &a,
            &RangefinderOpts {
                rank: 5,
                oversample: 5,
                power_iters: 0,
                seed: 1,
            },
        );
        assert!(ortho_error(&q) < 1e-4);
        // A − QQᵀA should vanish for exact rank-5 input.
        let qqta = matmul(&q, &matmul_tn(&q, &a));
        let resid = fro_norm(&a.sub(&qqta)) / fro_norm(&a);
        assert!(resid < 1e-4, "residual {resid}");
    }

    #[test]
    fn power_iteration_helps_slow_decay() {
        // Spectrum σ_i = 1/i (slow decay): power iteration must improve the
        // captured energy at fixed rank.
        let mut rng = Philox::seeded(72);
        let (m, n, full) = (80, 60, 30);
        let u = crate::linalg::qr_thin(&Mat::randn(m, full, &mut rng)).0;
        let v = crate::linalg::qr_thin(&Mat::randn(n, full, &mut rng)).0;
        let mut core = Mat::zeros(full, full);
        for i in 0..full {
            core.set(i, i, 1.0 / (i + 1) as f32);
        }
        let a = matmul(&matmul(&u, &core), &v.transpose());
        let resid = |q: &Mat| {
            let qqta = matmul(q, &matmul_tn(q, &a));
            fro_norm(&a.sub(&qqta)) / fro_norm(&a)
        };
        let q0 = rangefinder(&a, &RangefinderOpts { rank: 8, oversample: 4, power_iters: 0, seed: 5 });
        let q2 = rangefinder(&a, &RangefinderOpts { rank: 8, oversample: 4, power_iters: 2, seed: 5 });
        assert!(
            resid(&q2) <= resid(&q0) * 1.05,
            "power iters should not hurt: {} vs {}",
            resid(&q2),
            resid(&q0)
        );
    }

    #[test]
    fn rank_clamped_to_dims() {
        let a = low_rank(10, 6, 3, 73);
        let q = rangefinder(
            &a,
            &RangefinderOpts {
                rank: 50,
                oversample: 50,
                power_iters: 0,
                seed: 2,
            },
        );
        assert!(q.cols() <= 6);
    }
}
