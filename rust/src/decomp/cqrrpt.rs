//! CQRRPT — CholeskyQR with Randomization and Pivoting for Tall matrices
//! (Melnichenko, Balabanov, Murray, Demmel, Mahoney, Luszczek 2025; the
//! paper's reference [9]).
//!
//! For a tall `A (m × n, m ≫ n)`:
//! 1. Sketch: `A_sk = S·A` with a sparse-sign embedding, `d = γ·n` rows.
//! 2. Pivot:  column-pivoted QR of the *small* sketch → permutation `P`,
//!    triangular `R_sk`, numerical rank `r`.
//! 3. Precondition: `A_pre = (A·P)[:, :r] · R_sk[:r,:r]⁻¹` — now nearly
//!    orthonormal, with condition number O(1) w.h.p.
//! 4. CholeskyQR: `G = A_preᵀA_pre`, `L = chol(G)`, `Q = A_pre·L⁻ᵀ`,
//!    `R = Lᵀ·R_sk`.
//!
//! The expensive steps (sketch apply, Gram matrix, triangular solve) are all
//! GEMM-shaped over the tall dimension — exactly why the method wins on tall
//! inputs — while the O(n³) pivoted QR runs on the d×n sketch only.
//! Cholesky failure (the preconditioner wasn't good enough, e.g. the
//! numerical rank was overestimated) triggers a documented fallback to
//! Householder QR.

use crate::linalg::{
    cholesky_lower, matmul, matmul_tn, qr_cp, qr_thin, solve_triu_right, Mat,
};
use crate::sketch::{Sketch, SparseSignSketch};

/// CQRRPT options.
#[derive(Debug, Clone)]
pub struct CqrrptOpts {
    /// Sketch size factor γ: the embedding has `max(γ·n, n+1)` rows.
    pub gamma: f64,
    /// Nonzeros per column of the sparse-sign embedding.
    pub nnz: usize,
    /// Numerical-rank tolerance for the pivoted QR on the sketch.
    pub rank_tol: f64,
    /// CholeskyQR2 refinement: run a second CholeskyQR pass on `Q`,
    /// pushing orthogonality from O(κ·ε) to O(ε) at the cost of one more
    /// Gram+solve over the tall dimension (the "CholeskyQR2" variant
    /// discussed in the CQRRPT literature). Ablated in `benches/decomp`.
    pub refine: bool,
    /// RNG seed.
    pub seed: u64,
}

impl Default for CqrrptOpts {
    fn default() -> Self {
        CqrrptOpts {
            gamma: 1.25,
            nnz: 8,
            rank_tol: 1e-6,
            refine: false,
            seed: 0,
        }
    }
}

/// Result of CQRRPT: `A·P ≈ Q·R` with `Q` m×r orthonormal, `R` r×n upper
/// trapezoidal (on the permuted ordering), `perm` the column permutation,
/// `rank` the detected numerical rank, and `fallback` flagging whether the
/// Householder fallback was taken.
pub struct CqrrptResult {
    pub q: Mat,
    pub r: Mat,
    pub perm: Vec<usize>,
    pub rank: usize,
    pub fallback: bool,
}

/// Run CQRRPT on a tall matrix.
pub fn cqrrpt(a: &Mat, opts: &CqrrptOpts) -> CqrrptResult {
    let (m, n) = a.shape();
    assert!(m >= n, "cqrrpt expects a tall matrix (got {m}x{n})");
    assert!(n > 0);
    // 1. Sketch.
    let d = (((n as f64) * opts.gamma).ceil() as usize).clamp(n + 1, m);
    let s = SparseSignSketch::new(m, d, opts.nnz, opts.seed);
    let a_sk = s.apply(a); // d × n
    // 2. Pivoted QR of the sketch.
    let f = qr_cp(&a_sk, opts.rank_tol);
    let rank = f.rank.max(1).min(n);
    let perm = f.perm.clone();
    let r_sk = f.r.slice(0, rank, 0, rank); // leading triangular block
    // 3. Precondition the (permuted, truncated) tall matrix.
    let ap = a.permute_cols(&perm).slice(0, m, 0, rank);
    let a_pre = solve_triu_right(&ap, &r_sk); // m × r
    // 4. CholeskyQR on the well-conditioned A_pre.
    let gram = matmul_tn(&a_pre, &a_pre); // r × r
    match cholesky_lower(&gram) {
        Ok(l) => {
            // Q = A_pre · L⁻ᵀ  (L⁻ᵀ upper-triangular): solve X·Lᵀ = A_pre.
            let lt = l.transpose();
            let q = solve_triu_right(&a_pre, &lt);
            // R = Lᵀ · R_sk[:r, :]. Leading block: (A·P)₁..ᵣ = A_pre·R₁ =
            // Q·Lᵀ·R₁ exactly. Trailing columns inherit the sketch-space
            // coefficients: (A·P)ⱼ ≈ (A·P)₁..ᵣ·R₁⁻¹·R_sk[:r, j], which
            // telescopes to the same Lᵀ·R_sk[:r, j].
            let r_full = f.r.slice(0, rank, 0, n);
            let r = matmul(&lt, &r_full);
            let (q, r) = if opts.refine {
                // CholeskyQR2: one more pass on the already-well-conditioned
                // Q gives essentially machine-precision orthogonality.
                let gram2 = matmul_tn(&q, &q);
                match cholesky_lower(&gram2) {
                    Ok(l2) => {
                        let l2t = l2.transpose();
                        (solve_triu_right(&q, &l2t), matmul(&l2t, &r))
                    }
                    Err(_) => (q, r),
                }
            } else {
                (q, r)
            };
            CqrrptResult {
                q,
                r,
                perm,
                rank,
                fallback: false,
            }
        }
        Err(_) => {
            // Preconditioner failed — fall back to Householder on A·P.
            let ap_full = a.permute_cols(&perm);
            let (q, r) = qr_thin(&ap_full);
            CqrrptResult {
                q: q.slice(0, m, 0, rank),
                r: r.slice(0, rank, 0, n),
                perm,
                rank,
                fallback: true,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_norm, ortho_error, rel_error};
    use crate::rng::Philox;
    use crate::util::prop::prop_check;

    #[test]
    fn full_rank_tall_reconstruction() {
        let mut rng = Philox::seeded(91);
        let a = Mat::randn(200, 20, &mut rng);
        let f = cqrrpt(&a, &CqrrptOpts::default());
        assert!(!f.fallback);
        assert_eq!(f.rank, 20);
        assert!(ortho_error(&f.q) < 1e-3, "ortho {}", ortho_error(&f.q));
        let rec = matmul(&f.q, &f.r);
        let ap = a.permute_cols(&f.perm);
        assert!(rel_error(&rec, &ap) < 1e-3, "rel {}", rel_error(&rec, &ap));
    }

    #[test]
    fn orthogonality_beats_plain_choleskyqr_on_ill_conditioned() {
        // Near-dependent columns: every column is a shared direction plus
        // 1e-4 noise, so κ(A) ≈ 1e4 and κ(AᵀA) ≈ 1e8 — far beyond what f32
        // CholeskyQR tolerates (its orthogonality loss scales as κ²·ε).
        // Note a *graded* matrix would not do here: pure diagonal scaling is
        // implicitly equilibrated by Cholesky and stays easy.
        let mut rng = Philox::seeded(92);
        let base = Mat::randn(300, 1, &mut rng);
        let noise = Mat::randn(300, 12, &mut rng);
        let mut a = Mat::zeros(300, 12);
        for j in 0..12 {
            for i in 0..300 {
                a.set(i, j, base.get(i, 0) + 1e-4 * noise.get(i, j));
            }
        }
        let f = cqrrpt(&a, &CqrrptOpts::default());
        let cq_err = ortho_error(&f.q);
        assert!(cq_err < 1e-2, "CQRRPT ortho error {cq_err}");
        // Plain CholeskyQR for comparison: Q = A·L⁻ᵀ on the raw Gram matrix.
        let gram = matmul_tn(&a, &a);
        match cholesky_lower(&gram) {
            Ok(l) => {
                let q_plain = solve_triu_right(&a, &l.transpose());
                let plain_err = ortho_error(&q_plain);
                assert!(
                    cq_err < plain_err / 10.0,
                    "cqrrpt {cq_err} should beat plain CholeskyQR {plain_err} by 10x"
                );
            }
            Err(_) => {
                // Cholesky broke on the raw Gram — the exact failure mode
                // CQRRPT's preconditioning exists to avoid.
            }
        }
    }

    #[test]
    fn rank_deficient_detected() {
        let mut rng = Philox::seeded(93);
        let u = Mat::randn(150, 4, &mut rng);
        let v = Mat::randn(4, 10, &mut rng);
        let a = matmul(&u, &v);
        let f = cqrrpt(&a, &CqrrptOpts { rank_tol: 1e-4, ..Default::default() });
        assert_eq!(f.rank, 4, "detected rank {}", f.rank);
        // Q spans the range: ‖A·P − QQᵀ(A·P)‖ small.
        let ap = a.permute_cols(&f.perm);
        let proj = matmul(&f.q, &matmul_tn(&f.q, &ap));
        assert!(fro_norm(&ap.sub(&proj)) / fro_norm(&ap) < 1e-3);
    }

    #[test]
    fn property_reconstruction_various_shapes() {
        prop_check("cqrrpt-props", 12, |g| {
            let n = g.usize(1..15);
            let m = n * (2 + g.usize(0..8)) + g.usize(0..10);
            let a = Mat::randn(m, n, g.rng());
            let f = cqrrpt(&a, &CqrrptOpts { seed: 17, ..Default::default() });
            assert!(ortho_error(&f.q) < 1e-2);
            let rec = matmul(&f.q, &f.r);
            let ap = a.permute_cols(&f.perm);
            assert!(rel_error(&rec, &ap) < 1e-2);
        });
    }

    #[test]
    fn choleskyqr2_refinement_tightens_orthogonality() {
        let mut rng = Philox::seeded(95);
        // Mildly ill-conditioned input so the single-pass error is visible.
        let base = Mat::randn(400, 1, &mut rng);
        let noise = Mat::randn(400, 10, &mut rng);
        let mut a = Mat::zeros(400, 10);
        for j in 0..10 {
            for i in 0..400 {
                a.set(i, j, base.get(i, 0) + 1e-3 * noise.get(i, j));
            }
        }
        let plain = cqrrpt(&a, &CqrrptOpts::default());
        let refined = cqrrpt(
            &a,
            &CqrrptOpts {
                refine: true,
                ..Default::default()
            },
        );
        let e_plain = ortho_error(&plain.q);
        let e_ref = ortho_error(&refined.q);
        assert!(
            e_ref <= e_plain * 1.05,
            "refinement must not hurt: {e_ref} vs {e_plain}"
        );
        // And the refined factorization still reconstructs.
        let ap = a.permute_cols(&refined.perm);
        assert!(rel_error(&matmul(&refined.q, &refined.r), &ap) < 1e-2);
    }

    #[test]
    fn perm_is_valid_permutation() {
        let mut rng = Philox::seeded(94);
        let a = Mat::randn(100, 8, &mut rng);
        let f = cqrrpt(&a, &CqrrptOpts::default());
        let mut seen = vec![false; 8];
        for &p in &f.perm {
            assert!(!seen[p]);
            seen[p] = true;
        }
    }
}
