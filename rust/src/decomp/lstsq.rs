//! Sketch-and-precondition least squares (Blendenpik / LSRN style) — the
//! "sketching-based regression" the paper's introduction lists among the
//! matured RandNLA tools.
//!
//! To solve `min‖Ax − b‖₂` for tall `A (m × n, m ≫ n)`:
//! 1. Sketch `A_sk = S·A` (sparse sign, `d = γ·n` rows).
//! 2. QR the small sketch: `A_sk = Q_sk·R` → `R` is a near-perfect
//!    preconditioner: `κ(A·R⁻¹) = O(1)` w.h.p.
//! 3. Run LSQR on the preconditioned system to machine-ish accuracy in a
//!    handful of iterations (each iteration two matvecs — O(mn)).
//!
//! Total: O(mn·log n + n³) versus O(mn²) for dense normal equations /
//! Householder — the classical RandNLA win on tall systems.

use crate::linalg::{qr_thin, solve_triu, Mat};
use crate::sketch::{Sketch, SparseSignSketch};
use anyhow::Result;

/// Options for the sketched solver.
#[derive(Debug, Clone)]
pub struct LstsqOpts {
    /// Sketch size factor γ (d = max(γ·n, n+16) rows).
    pub gamma: f64,
    /// Sparse-sign nonzeros per column.
    pub nnz: usize,
    /// LSQR iteration cap.
    pub max_iters: usize,
    /// Relative residual-gradient tolerance ‖Aᵀr‖/(‖A‖·‖r‖).
    pub tol: f64,
    pub seed: u64,
}

impl Default for LstsqOpts {
    fn default() -> Self {
        LstsqOpts {
            gamma: 2.0,
            nnz: 8,
            max_iters: 100,
            // f32 storage: the residual estimate stalls near 1e-6·‖b‖;
            // asking for more just burns iterations.
            tol: 1e-6,
            seed: 0,
        }
    }
}

/// Result of the sketched solve.
pub struct LstsqResult {
    pub x: Vec<f32>,
    /// LSQR iterations used.
    pub iters: usize,
    /// Final residual norm ‖Ax − b‖.
    pub residual: f64,
}

/// Solve `min‖Ax − b‖` by sketch-precondition-LSQR.
pub fn sketched_lstsq(a: &Mat, b: &[f32], opts: &LstsqOpts) -> Result<LstsqResult> {
    let (m, n) = a.shape();
    anyhow::ensure!(b.len() == m, "rhs length {} != rows {m}", b.len());
    anyhow::ensure!(m >= n && n > 0, "need a tall system");
    // 1. Sketch + QR → R.
    let d = (((n as f64) * opts.gamma).ceil() as usize).clamp(n + 16, m.max(n + 16));
    let s = SparseSignSketch::new(m, d.min(m), opts.nnz, opts.seed);
    let a_sk = s.apply(a);
    let (_q, r) = qr_thin(&a_sk);
    // 2. LSQR on min‖(A·R⁻¹)y − b‖, x = R⁻¹y.
    // Operators: apply   v ↦ A·(R⁻¹v)   and   u ↦ R⁻ᵀ·(Aᵀu).
    let apply = |v: &[f32]| -> Vec<f32> {
        let rv = solve_triu(&r, &Mat::from_vec(n, 1, v.to_vec()));
        a.matvec(rv.data())
    };
    let apply_t = |u: &[f32]| -> Vec<f32> {
        let atu = a.matvec_t(u);
        // R⁻ᵀ·w: solve Rᵀ·z = w (lower-triangular solve on Rᵀ).
        solve_tril_t(&r, &atu)
    };
    let (y, iters) = lsqr(m, n, apply, apply_t, b, opts.max_iters, opts.tol);
    let x = solve_triu(&r, &Mat::from_vec(n, 1, y)).into_vec();
    // Residual.
    let ax = a.matvec(&x);
    let residual = ax
        .iter()
        .zip(b)
        .map(|(p, q)| ((p - q) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    Ok(LstsqResult { x, iters, residual })
}

/// Dense baseline via normal equations + Cholesky (O(mn²)); used by tests
/// and the regression bench as the comparison point.
pub fn lstsq_normal_eq(a: &Mat, b: &[f32]) -> Result<Vec<f32>> {
    let gram = crate::linalg::matmul_tn(a, a);
    let atb = a.matvec_t(b);
    let l = crate::linalg::cholesky_lower(&gram)?;
    // Solve L·z = Aᵀb, then Lᵀ·x = z.
    let z = solve_tril(&l, &atb);
    let x = solve_triu(&l.transpose(), &Mat::from_vec(z.len(), 1, z)).into_vec();
    Ok(x)
}

/// Solve lower-triangular `L·z = w`.
fn solve_tril(l: &Mat, w: &[f32]) -> Vec<f32> {
    let n = l.rows();
    let mut z = vec![0f64; n];
    for i in 0..n {
        let mut s = w[i] as f64;
        for p in 0..i {
            s -= l.get(i, p) as f64 * z[p];
        }
        z[i] = s / l.get(i, i) as f64;
    }
    z.into_iter().map(|v| v as f32).collect()
}

/// Solve `Rᵀ·z = w` with `R` upper-triangular (forward substitution).
fn solve_tril_t(r: &Mat, w: &[f32]) -> Vec<f32> {
    let n = r.rows();
    let mut z = vec![0f64; n];
    for i in 0..n {
        let mut s = w[i] as f64;
        for p in 0..i {
            s -= r.get(p, i) as f64 * z[p];
        }
        z[i] = s / r.get(i, i) as f64;
    }
    z.into_iter().map(|v| v as f32).collect()
}

/// Textbook LSQR (Paige–Saunders) on an abstract operator pair.
fn lsqr(
    m: usize,
    n: usize,
    apply: impl Fn(&[f32]) -> Vec<f32>,
    apply_t: impl Fn(&[f32]) -> Vec<f32>,
    b: &[f32],
    max_iters: usize,
    tol: f64,
) -> (Vec<f32>, usize) {
    let norm = |v: &[f32]| v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt();
    let scale = |v: &mut [f32], s: f64| {
        for x in v.iter_mut() {
            *x = (*x as f64 * s) as f32;
        }
    };
    let mut x = vec![0f32; n];
    let mut u = b.to_vec();
    let beta0 = norm(&u);
    if beta0 == 0.0 {
        return (x, 0);
    }
    scale(&mut u, 1.0 / beta0);
    let mut v = apply_t(&u);
    let mut alpha = norm(&v);
    if alpha == 0.0 {
        return (x, 0);
    }
    scale(&mut v, 1.0 / alpha);
    let mut w = v.clone();
    let mut phi_bar = beta0;
    let mut rho_bar = alpha;
    let mut phi_prev = f64::INFINITY;
    let _ = m;
    for iter in 0..max_iters {
        // Bidiagonalization step.
        let mut au = apply(&v);
        for (a_, u_) in au.iter_mut().zip(&u) {
            *a_ -= (alpha * *u_ as f64) as f32;
        }
        let beta = norm(&au);
        if beta > 0.0 {
            u = au;
            scale(&mut u, 1.0 / beta);
            let mut atv = apply_t(&u);
            for (a_, v_) in atv.iter_mut().zip(&v) {
                *a_ -= (beta * *v_ as f64) as f32;
            }
            alpha = norm(&atv);
            if alpha > 0.0 {
                v = atv;
                scale(&mut v, 1.0 / alpha);
            }
        }
        // Givens rotation.
        let rho = (rho_bar * rho_bar + beta * beta).sqrt();
        let c = rho_bar / rho;
        let s = beta / rho;
        let theta = s * alpha;
        rho_bar = -c * alpha;
        let phi = c * phi_bar;
        phi_bar *= s;
        // Update x, w.
        let t1 = phi / rho;
        let t2 = -theta / rho;
        for i in 0..n {
            x[i] = (x[i] as f64 + t1 * w[i] as f64) as f32;
            w[i] = (v[i] as f64 + t2 * w[i] as f64) as f32;
        }
        // Convergence: residual estimate (phi_bar) small, operator
        // exhausted, or stagnation (phi_bar no longer shrinking — the f32
        // noise floor for least-squares problems with nonzero residual).
        let prev = phi_prev;
        phi_prev = phi_bar;
        if phi_bar / beta0 < tol
            || alpha.abs() < 1e-300
            || (iter > 5 && phi_bar > prev * 0.999)
        {
            return (x, iter + 1);
        }
    }
    (x, max_iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::{Philox, Rng};
    use crate::util::prop::prop_check;

    fn residual(a: &Mat, x: &[f32], b: &[f32]) -> f64 {
        a.matvec(x)
            .iter()
            .zip(b)
            .map(|(p, q)| ((p - q) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    }

    #[test]
    fn consistent_system_solved_exactly() {
        let mut rng = Philox::seeded(201);
        let a = Mat::randn(500, 20, &mut rng);
        let x_true: Vec<f32> = (0..20).map(|i| (i as f32 - 10.0) / 5.0).collect();
        let b = a.matvec(&x_true);
        let r = sketched_lstsq(&a, &b, &LstsqOpts::default()).unwrap();
        let err: f64 = r
            .x
            .iter()
            .zip(&x_true)
            .map(|(p, q)| ((p - q) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!(err < 1e-3, "x error {err}");
        assert!(r.residual < 1e-2, "residual {}", r.residual);
        assert!(r.iters < 30, "iters {}", r.iters);
    }

    #[test]
    fn matches_normal_equations_on_noisy_system() {
        let mut rng = Philox::seeded(202);
        let a = Mat::randn(800, 30, &mut rng);
        let mut b: Vec<f32> = a.matvec(&vec![1.0; 30]);
        for v in &mut b {
            *v += 0.1 * rng.next_normal();
        }
        let x_sketch = sketched_lstsq(&a, &b, &LstsqOpts::default()).unwrap();
        let x_dense = lstsq_normal_eq(&a, &b).unwrap();
        let r_sketch = residual(&a, &x_sketch.x, &b);
        let r_dense = residual(&a, &x_dense, &b);
        assert!(
            (r_sketch - r_dense).abs() < 1e-2 * r_dense,
            "residuals differ: {r_sketch} vs {r_dense}"
        );
    }

    #[test]
    fn preconditioning_keeps_iterations_flat_as_conditioning_degrades() {
        // Iterations should stay O(1) even as κ(A) grows — the point of the
        // sketch preconditioner.
        let mut rng = Philox::seeded(203);
        let mut worst = 0usize;
        for decade in 1..=4 {
            let mut a = Mat::randn(600, 15, &mut rng);
            for j in 0..15 {
                let s = 10f32.powf(-(j as f32) * decade as f32 / 15.0);
                for i in 0..600 {
                    a.set(i, j, a.get(i, j) * s);
                }
            }
            let b: Vec<f32> = (0..600).map(|_| rng.next_normal()).collect();
            let r = sketched_lstsq(&a, &b, &LstsqOpts::default()).unwrap();
            worst = worst.max(r.iters);
        }
        assert!(worst <= 60, "iterations blew up: {worst}");
    }

    #[test]
    fn property_random_tall_systems() {
        prop_check("lstsq-props", 10, |g| {
            let n = 2 + g.usize(0..10);
            let m = n * 10 + g.usize(0..50);
            let a = Mat::randn(m, n, g.rng());
            let x_true: Vec<f32> = (0..n).map(|_| g.normal()).collect();
            let b = a.matvec(&x_true);
            let r = sketched_lstsq(&a, &b, &LstsqOpts { seed: 5, ..Default::default() })
                .unwrap();
            let err: f64 = r
                .x
                .iter()
                .zip(&x_true)
                .map(|(p, q)| ((p - q) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            let scale: f64 = x_true.iter().map(|&v| (v as f64).powi(2)).sum::<f64>().sqrt();
            assert!(err < 1e-2 * scale.max(1.0), "err {err}");
        });
    }

    #[test]
    fn rejects_bad_shapes() {
        let a = Mat::zeros(10, 20); // wide
        assert!(sketched_lstsq(&a, &vec![0.0; 10], &LstsqOpts::default()).is_err());
        let a2 = Mat::zeros(30, 3);
        assert!(sketched_lstsq(&a2, &vec![0.0; 7], &LstsqOpts::default()).is_err());
    }
}
