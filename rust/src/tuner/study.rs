//! Study: trial lifecycle, best-trial selection, constraints, persistence.

use super::pruner::Pruner;
use super::sampler::Sampler;
use super::space::{ParamAssignment, SearchSpace};
use crate::util::json::Json;
use anyhow::{Context, Result};

/// Optimization direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Minimize,
    Maximize,
}

/// Trial lifecycle state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrialState {
    Running,
    Complete,
    Pruned,
    Failed,
}

/// One evaluation of a parameter assignment.
#[derive(Debug, Clone)]
pub struct Trial {
    pub id: usize,
    pub params: ParamAssignment,
    pub state: TrialState,
    /// Objective value (canonical: lower is better — maximize studies
    /// negate on the way in and out).
    pub value: Option<f64>,
    /// Interim values reported during the trial (step, value).
    pub interim: Vec<(usize, f64)>,
    /// Whether the trial satisfied all constraints (e.g. accuracy ≥ thresh).
    pub feasible: bool,
}

impl Trial {
    pub fn new(id: usize) -> Self {
        Trial {
            id,
            params: ParamAssignment::new(),
            state: TrialState::Running,
            value: None,
            interim: Vec::new(),
            feasible: true,
        }
    }
}

/// An Optuna-style study: ask → (run) → tell.
pub struct Study {
    pub name: String,
    pub direction: Direction,
    pub space: SearchSpace,
    sampler: Box<dyn Sampler>,
    pruner: Box<dyn Pruner>,
    trials: Vec<Trial>,
}

impl Study {
    pub fn new(
        name: &str,
        direction: Direction,
        space: SearchSpace,
        sampler: Box<dyn Sampler>,
        pruner: Box<dyn Pruner>,
    ) -> Self {
        Study {
            name: name.to_string(),
            direction,
            space,
            sampler,
            pruner,
            trials: Vec::new(),
        }
    }

    fn canon(&self, objective: f64) -> f64 {
        match self.direction {
            Direction::Minimize => objective,
            Direction::Maximize => -objective,
        }
    }

    fn uncanon(&self, v: f64) -> f64 {
        match self.direction {
            Direction::Minimize => v,
            Direction::Maximize => -v,
        }
    }

    /// Ask for the next trial (samples parameters from history).
    pub fn ask(&mut self) -> Trial {
        let id = self.trials.len();
        let params = self.sampler.sample(&self.space, &self.trials);
        let mut t = Trial::new(id);
        t.params = params;
        self.trials.push(t.clone());
        t
    }

    /// Report an interim value; returns true if the pruner says stop.
    pub fn should_prune(&mut self, trial: &mut Trial, step: usize, objective: f64) -> bool {
        let v = self.canon(objective);
        trial.interim.push((step, v));
        let verdict = self.pruner.should_prune(&self.trials, trial, step, v);
        if verdict {
            trial.state = TrialState::Pruned;
            self.sync(trial);
        }
        verdict
    }

    /// Complete a trial with its objective and feasibility.
    pub fn tell(&mut self, trial: &mut Trial, objective: f64, feasible: bool) {
        trial.value = Some(self.canon(objective));
        trial.feasible = feasible;
        trial.state = TrialState::Complete;
        self.sync(trial);
    }

    /// Mark a trial failed (runtime error).
    pub fn tell_failed(&mut self, trial: &mut Trial) {
        trial.state = TrialState::Failed;
        self.sync(trial);
    }

    fn sync(&mut self, trial: &Trial) {
        self.trials[trial.id] = trial.clone();
    }

    pub fn trials(&self) -> &[Trial] {
        &self.trials
    }

    /// Best *feasible* completed trial (respecting constraints), if any.
    pub fn best_trial(&self) -> Option<&Trial> {
        self.trials
            .iter()
            .filter(|t| t.state == TrialState::Complete && t.feasible && t.value.is_some())
            .min_by(|a, b| a.value.partial_cmp(&b.value).unwrap())
    }

    /// Best objective in user orientation.
    pub fn best_value(&self) -> Option<f64> {
        self.best_trial().map(|t| self.uncanon(t.value.unwrap()))
    }

    /// Serialize the study to JSON (trial history + params).
    pub fn to_json(&self) -> Json {
        let mut trials = Vec::new();
        for t in &self.trials {
            let mut o = Json::obj();
            o.set("id", t.id);
            o.set(
                "state",
                match t.state {
                    TrialState::Running => "running",
                    TrialState::Complete => "complete",
                    TrialState::Pruned => "pruned",
                    TrialState::Failed => "failed",
                },
            );
            if let Some(v) = t.value {
                o.set("value", self.uncanon(v));
            }
            o.set("feasible", t.feasible);
            let mut params = Json::obj();
            for (k, v) in &t.params {
                params.set(k, v.to_json());
            }
            o.set("params", params);
            trials.push(o);
        }
        let mut root = Json::obj();
        root.set("study", self.name.as_str());
        root.set(
            "direction",
            match self.direction {
                Direction::Minimize => "minimize",
                Direction::Maximize => "maximize",
            },
        );
        root.set("trials", Json::Arr(trials));
        root
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_json().to_pretty())
            .with_context(|| format!("writing study to {:?}", path.as_ref()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::pruner::NoPruner;
    use crate::tuner::sampler::RandomSampler;
    use crate::tuner::space::SearchSpace;
    use crate::util::prop::prop_check;

    fn study(direction: Direction) -> Study {
        Study::new(
            "test",
            direction,
            SearchSpace::new().int("x", 0, 10),
            Box::new(RandomSampler::new(1)),
            Box::new(NoPruner),
        )
    }

    #[test]
    fn minimize_selects_smallest() {
        let mut s = study(Direction::Minimize);
        for v in [3.0, 1.0, 2.0] {
            let mut t = s.ask();
            s.tell(&mut t, v, true);
        }
        assert_eq!(s.best_value(), Some(1.0));
    }

    #[test]
    fn maximize_selects_largest() {
        let mut s = study(Direction::Maximize);
        for v in [3.0, 1.0, 2.0] {
            let mut t = s.ask();
            s.tell(&mut t, v, true);
        }
        assert_eq!(s.best_value(), Some(3.0));
    }

    #[test]
    fn infeasible_trials_never_best() {
        let mut s = study(Direction::Minimize);
        let mut t1 = s.ask();
        s.tell(&mut t1, 0.1, false); // better value but infeasible
        let mut t2 = s.ask();
        s.tell(&mut t2, 5.0, true);
        assert_eq!(s.best_value(), Some(5.0));
    }

    #[test]
    fn failed_trials_have_no_value() {
        let mut s = study(Direction::Minimize);
        let mut t = s.ask();
        s.tell_failed(&mut t);
        assert!(s.best_trial().is_none());
        assert_eq!(s.trials()[0].state, TrialState::Failed);
    }

    #[test]
    fn json_roundtrippable_and_user_oriented() {
        let mut s = study(Direction::Maximize);
        let mut t = s.ask();
        s.tell(&mut t, 7.5, true);
        let j = s.to_json();
        let trials = j.get("trials").unwrap().as_arr().unwrap();
        // User sees 7.5, not the internal -7.5.
        assert_eq!(trials[0].get("value").unwrap().as_f64(), Some(7.5));
        let text = j.to_pretty();
        assert!(crate::util::json::Json::parse(&text).is_ok());
    }

    #[test]
    fn property_study_invariants() {
        // For any mixture of tells, invariants hold: every Complete trial
        // has a value; Pruned/Failed have none unless told; best is minimal
        // among feasible completes.
        prop_check("study-invariants", 30, |g| {
            let mut s = study(Direction::Minimize);
            let n = g.usize(1..20);
            for _ in 0..n {
                let mut t = s.ask();
                match g.usize(0..3) {
                    0 => {
                        let v = g.f32(0.0, 100.0) as f64;
                        let feasible = g.bool(0.7);
                        s.tell(&mut t, v, feasible);
                    }
                    1 => s.tell_failed(&mut t),
                    _ => { /* leave running */ }
                }
            }
            let mut best_seen: Option<f64> = None;
            for t in s.trials() {
                match t.state {
                    TrialState::Complete => {
                        assert!(t.value.is_some());
                        if t.feasible {
                            best_seen = Some(match best_seen {
                                None => t.value.unwrap(),
                                Some(b) => b.min(t.value.unwrap()),
                            });
                        }
                    }
                    TrialState::Failed | TrialState::Running => {
                        assert!(t.value.is_none());
                    }
                    TrialState::Pruned => {}
                }
            }
            assert_eq!(s.best_value(), best_seen);
        });
    }
}
