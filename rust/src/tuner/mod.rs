//! SKAutoTuner — the paper's §2.2 contribution, rebuilt in Rust.
//!
//! "Users specify high-level constraints, such as a memory budget or
//! accuracy tolerance, and the tuner explores the configuration space" —
//! here as an Optuna-style ask/tell study engine:
//!
//! - [`space`] — search-space definition (int/categorical/log-uniform
//!   dimensions; the `(num_terms, low_rank)` space sketching introduces).
//! - [`sampler`] — [`RandomSampler`], [`GridSampler`], and [`TpeSampler`]
//!   (Tree-structured Parzen Estimator, the algorithm behind Optuna's
//!   default sampler).
//! - [`study`] — trial lifecycle (running → complete/pruned/failed),
//!   constraint handling (accuracy threshold), best-trial selection, JSON
//!   persistence.
//! - [`pruner`] — median pruning of unpromising trials from interim
//!   reports.
//! - [`autotuner`] — the high-level `SKAutoTuner` mirroring the paper's
//!   Listing 2: layer selection (type/regex/name), per-layer or joint
//!   search, `apply_best_params`.

pub mod autotuner;
pub mod bert_tune;
pub mod pruner;
pub mod sampler;
pub mod space;
pub mod study;

pub use autotuner::{AccuracyMode, SkAutoTuner, TuneOutcome, TuningConfig};
pub use pruner::{MedianPruner, NoPruner, Pruner, SuccessiveHalvingPruner};
pub use sampler::{GridSampler, RandomSampler, Sampler, TpeSampler};
pub use space::{Categorical, Dimension, ParamValue, SearchSpace};
pub use study::{Direction, Study, Trial, TrialState};
