//! End-to-end SKAutoTuner flow over the AOT BERT artifacts — the paper's
//! §4.2 experiment ("replace the dense linear layers within the model with
//! Panther's SKLinear equivalents … up to 75% reduction in size while
//! maintaining a comparable MLM loss"):
//!
//! 1. Train `bert_dense` for a few steps (the "pre-trained BERT" stand-in).
//! 2. For every sketched candidate variant in the manifest, build its
//!    parameters **from the trained dense weights** host-side — the same
//!    unbiased two-factor sketch as [`crate::nn::SKLinear::from_dense`],
//!    matching the paper's `copy_weights=True`.
//! 3. Score each candidate: eval MLM loss (constraint: within
//!    `loss_margin` of dense) and parameter count (objective).
//! 4. Report the best feasible candidate.

use crate::data::TextCorpus;
use crate::linalg::{matmul_tn, Mat};
use crate::rng::Philox;
use crate::runtime::{HostTensor, ModelSpec, Runtime};
use crate::train::{BertTrainer, ModelState};
use anyhow::{Context, Result};

/// One scored candidate.
#[derive(Debug, Clone)]
pub struct CandidateReport {
    pub name: String,
    pub sketch: (usize, usize),
    pub param_count: usize,
    pub reduction: f64,
    pub eval_loss: f32,
    pub eval_latency: std::time::Duration,
    pub feasible: bool,
}

/// Outcome of the artifact-driven tuning run.
pub struct BertTuneOutcome {
    pub dense_loss: f32,
    pub dense_params: usize,
    pub threshold: f32,
    pub candidates: Vec<CandidateReport>,
    pub best: Option<CandidateReport>,
}

impl std::fmt::Display for BertTuneOutcome {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "dense: {} params, eval loss {:.4} (threshold {:.4})",
            self.dense_params, self.dense_loss, self.threshold
        )?;
        let mut t = crate::util::bench::Table::new(&[
            "candidate", "(l,k)", "params", "reduction", "eval loss", "latency", "feasible",
        ]);
        for c in &self.candidates {
            t.row(&[
                c.name.clone(),
                format!("({},{})", c.sketch.0, c.sketch.1),
                c.param_count.to_string(),
                format!("{:.1}%", c.reduction * 100.0),
                format!("{:.4}", c.eval_loss),
                crate::util::human_duration(c.eval_latency),
                c.feasible.to_string(),
            ]);
        }
        write!(f, "{}", t.render())?;
        match &self.best {
            Some(b) => write!(
                f,
                "best: {} — {:.1}% smaller at loss {:.4} (dense {:.4})",
                b.name,
                b.reduction * 100.0,
                b.eval_loss,
                self.dense_loss
            ),
            None => write!(f, "no feasible candidate — relax --loss-margin"),
        }
    }
}

/// Sketch trained dense parameters into a candidate variant's layout.
///
/// For every candidate parameter `X.u` / `X.v` the dense model must have
/// `X.w (d_in × d_out)`; we draw `S_j ~ N(0, 1/k)` per term and set
/// `U_j = S_j`, `V_j = S_jᵀ·W`. All other parameters copy through.
pub fn sketch_params_from_dense(
    dense_spec: &ModelSpec,
    dense_params: &[HostTensor],
    cand_spec: &ModelSpec,
    cand_shapes: &[(String, Vec<usize>)],
    seed: u64,
) -> Result<Vec<HostTensor>> {
    let dense_by_name = |n: &str| -> Option<&HostTensor> {
        dense_spec
            .param_names
            .iter()
            .position(|p| p == n)
            .and_then(|i| dense_params.get(i))
    };
    let mut rng = Philox::seeded(seed);
    let mut out = Vec::with_capacity(cand_spec.param_names.len());
    // Cache per-prefix sketches so `.u` and `.v` of one layer share S_j.
    let mut sketch_cache: std::collections::HashMap<String, Vec<Mat>> = Default::default();
    for (name, shape) in cand_shapes {
        if let Some(prefix) = name.strip_suffix(".u") {
            let (l, d_in, k) = (shape[0], shape[1], shape[2]);
            let s_list = sketch_cache.entry(prefix.to_string()).or_insert_with(|| {
                (0..l)
                    .map(|_| Mat::randn(d_in, k, &mut rng).scale((1.0 / k as f32).sqrt()))
                    .collect()
            });
            let mut data = Vec::with_capacity(l * d_in * k);
            for s in s_list.iter() {
                data.extend_from_slice(s.data());
            }
            out.push(HostTensor::new(shape, data));
        } else if let Some(prefix) = name.strip_suffix(".v") {
            let (l, k, d_out) = (shape[0], shape[1], shape[2]);
            let w = dense_by_name(&format!("{prefix}.w"))
                .with_context(|| format!("dense model lacks {prefix}.w"))?;
            let d_in = w.shape()[0];
            anyhow::ensure!(w.shape()[1] == d_out, "shape mismatch at {prefix}");
            let wmat = w.to_mat();
            let s_list = sketch_cache.entry(prefix.to_string()).or_insert_with(|| {
                (0..l)
                    .map(|_| Mat::randn(d_in, k, &mut rng).scale((1.0 / k as f32).sqrt()))
                    .collect()
            });
            let mut data = Vec::with_capacity(l * k * d_out);
            for s in s_list.iter() {
                let vj = matmul_tn(s, &wmat); // k × d_out
                data.extend_from_slice(vj.data());
            }
            out.push(HostTensor::new(shape, data));
        } else {
            // Pass-through parameter (embeddings, LN, biases, head, …).
            let t = dense_by_name(name)
                .with_context(|| format!("dense model lacks parameter {name}"))?;
            anyhow::ensure!(
                t.shape() == shape.as_slice(),
                "pass-through {name}: {:?} vs {:?}",
                t.shape(),
                shape
            );
            out.push(t.clone());
        }
    }
    Ok(out)
}

/// Candidate param names + shapes, read from the candidate's eval artifact.
fn candidate_param_shapes(rt: &Runtime, spec: &ModelSpec) -> Result<Vec<(String, Vec<usize>)>> {
    let eval = spec.eval.as_ref().context("candidate has no eval artifact")?;
    let art = rt.manifest().artifact(eval).context("missing artifact")?;
    Ok(art
        .inputs
        .iter()
        .filter_map(|s| {
            s.name
                .strip_prefix("params.")
                .map(|n| (n.to_string(), s.shape.clone()))
        })
        .collect())
}

/// The full tuning flow (see module docs).
pub fn tune_bert_candidates(
    artifacts: &str,
    train_steps: u64,
    eval_batches: usize,
    loss_margin: f64,
    seed: u64,
) -> Result<BertTuneOutcome> {
    let mut rt = Runtime::open(artifacts)?;
    let dense_spec = rt
        .manifest()
        .model("bert_dense")
        .context("bert_dense missing")?
        .clone();
    let vocab = dense_spec.config_usize("vocab").unwrap_or(256);
    let batch = dense_spec.config_usize("batch").unwrap_or(16);
    let seq = dense_spec.config_usize("seq").unwrap_or(64);
    let corpus = TextCorpus::generate(vocab, 200_000, seed ^ 0xC0FFEE);

    // 1. Pre-train dense.
    let mut state = ModelState::init(&mut rt, "bert_dense", seed as f32)?;
    {
        let mut trainer = BertTrainer::new(&mut rt, &corpus);
        let mut rng = Philox::new(seed, 1);
        trainer.train(&mut state, train_steps, &mut rng)?;
    }
    // 2. Dense eval loss.
    let mut eval_rng = Philox::new(seed, 2);
    let dense_loss = {
        let mut trainer = BertTrainer::new(&mut rt, &corpus);
        trainer.evaluate(&state, eval_batches, &mut eval_rng)?
    };
    let threshold = dense_loss + loss_margin as f32;

    // 3. Candidates.
    let cand_names: Vec<String> = rt
        .manifest()
        .models_in_family("bert")
        .iter()
        .filter(|m| m.sketch().is_some())
        .map(|m| m.name.clone())
        .collect();
    let mut candidates = Vec::new();
    for name in cand_names {
        let spec = rt.manifest().model(&name).unwrap().clone();
        let shapes = candidate_param_shapes(&rt, &spec)?;
        let params =
            sketch_params_from_dense(&dense_spec, &state.params, &spec, &shapes, seed ^ 0x5EED)?;
        let mut rng = Philox::new(seed, 3);
        let t0 = std::time::Instant::now();
        let loss = {
            let mut trainer = BertTrainer::new(&mut rt, &corpus);
            trainer.evaluate_params(
                spec.eval.as_ref().unwrap(),
                &params,
                eval_batches,
                batch,
                seq,
                &mut rng,
            )?
        };
        let latency = t0.elapsed() / eval_batches.max(1) as u32;
        let param_count: usize = params.iter().map(|t| t.len()).sum();
        candidates.push(CandidateReport {
            name: name.clone(),
            sketch: spec.sketch().unwrap(),
            param_count,
            reduction: 1.0 - param_count as f64 / dense_spec.param_count as f64,
            eval_loss: loss,
            eval_latency: latency,
            feasible: loss <= threshold,
        });
        crate::log_info!(
            "candidate {name}: loss {loss:.4} ({}), {:.1}% reduction",
            if loss <= threshold { "feasible" } else { "infeasible" },
            candidates.last().unwrap().reduction * 100.0
        );
    }
    // 4. Best = max reduction among feasible.
    let best = candidates
        .iter()
        .filter(|c| c.feasible)
        .max_by(|a, b| a.reduction.partial_cmp(&b.reduction).unwrap())
        .cloned();
    Ok(BertTuneOutcome {
        dense_loss,
        dense_params: dense_spec.param_count,
        threshold,
        candidates,
        best,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<String> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| dir.to_str().unwrap().to_string())
    }

    #[test]
    fn sketched_candidate_params_preserve_passthrough() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let mut rt = Runtime::open(&dir).unwrap();
        let dense_spec = rt.manifest().model("bert_dense").unwrap().clone();
        let state = ModelState::init(&mut rt, "bert_dense", 0.0).unwrap();
        let cand_spec = rt.manifest().model("bert_sk_1_8").unwrap().clone();
        let shapes = candidate_param_shapes(&rt, &cand_spec).unwrap();
        let params =
            sketch_params_from_dense(&dense_spec, &state.params, &cand_spec, &shapes, 1).unwrap();
        assert_eq!(params.len(), cand_spec.param_names.len());
        // tok_emb passes through identically.
        let idx_c = shapes.iter().position(|(n, _)| n == "tok_emb").unwrap();
        let idx_d = dense_spec
            .param_names
            .iter()
            .position(|n| n == "tok_emb")
            .unwrap();
        assert_eq!(params[idx_c], state.params[idx_d]);
        // Sketched candidate is much smaller.
        let total: usize = params.iter().map(|t| t.len()).sum();
        assert!(total < dense_spec.param_count / 2);
    }

    #[test]
    fn quick_tune_flow_runs() {
        let Some(dir) = artifacts() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        // Minimal steps — this is a wiring test, not the experiment.
        let outcome = tune_bert_candidates(&dir, 2, 1, 5.0, 0).unwrap();
        assert!(outcome.dense_loss.is_finite());
        assert!(!outcome.candidates.is_empty());
        // With a huge margin every candidate is feasible and best exists.
        assert!(outcome.best.is_some());
        let display = format!("{outcome}");
        assert!(display.contains("best:"));
    }
}
