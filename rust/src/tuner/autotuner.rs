//! `SkAutoTuner` — the high-level tuning API of the paper's Listing 2:
//!
//! ```text
//! tuner = SKAutoTuner(model, configs, accuracy_eval_func, accuracy_threshold,
//!                     optmization_eval_func, search_algorithm=OptunaSearch(...))
//! tuner.tune()
//! optimized_model = tuner.apply_best_params()
//! ```
//!
//! The tuner owns a *dense base model*; each trial clones it, sketchifies
//! the selected layers with the sampled `(num_terms, low_rank)`, and scores
//! the result with two user callbacks:
//!
//! - `accuracy_fn` — the quality metric (e.g. −MLM-loss or accuracy);
//!   trials below `accuracy_threshold` are marked **infeasible** and can
//!   never win (the paper's `accuracy_threshold` constraint).
//! - `objective_fn` — what to optimize (minimize): latency, parameter
//!   count, memory, ….

use super::pruner::NoPruner;
use super::sampler::Sampler;
use super::space::{ParamAssignment, SearchSpace};
use super::study::{Direction, Study};
use crate::nn::{LayerSelector, Model, SketchPlan};
use anyhow::{Context, Result};

/// How accuracy constrains the search.
#[derive(Debug, Clone, Copy)]
pub enum AccuracyMode {
    /// Feasible iff `accuracy_fn(model) >= threshold`.
    AtLeast(f64),
    /// Feasible iff `accuracy_fn(model) <= threshold` (for loss metrics).
    AtMost(f64),
}

impl AccuracyMode {
    fn feasible(&self, acc: f64) -> bool {
        match self {
            AccuracyMode::AtLeast(t) => acc >= *t,
            AccuracyMode::AtMost(t) => acc <= *t,
        }
    }
}

/// Layer selection + space, one per `LayerConfig` of the paper.
pub struct TuningConfig {
    pub selector: LayerSelector,
    /// Search space; `None` = the paper's `params="auto"`.
    pub space: Option<SearchSpace>,
    /// `separate=true`: independent (l,k) per matched layer;
    /// `false`: one shared (l,k) for all matched layers.
    pub separate: bool,
}

impl TuningConfig {
    pub fn all_linear() -> Self {
        TuningConfig {
            selector: LayerSelector::by_type("Linear"),
            space: None,
            separate: false,
        }
    }
}

/// Outcome of `tune()`.
pub struct TuneOutcome {
    /// Best parameter assignment (layer-prefixed when separate).
    pub best_params: ParamAssignment,
    pub best_objective: f64,
    pub best_accuracy: f64,
    pub n_trials: usize,
    pub n_feasible: usize,
}

/// The tuner itself. Generic over the two evaluation callbacks.
pub struct SkAutoTuner<A, O>
where
    A: FnMut(&Model) -> f64,
    O: FnMut(&Model) -> f64,
{
    base: Model,
    config: TuningConfig,
    accuracy_fn: A,
    objective_fn: O,
    accuracy_mode: AccuracyMode,
    study: Study,
    matched: Vec<String>,
    /// Accuracy per trial id (for reporting).
    accuracies: Vec<f64>,
}

impl<A, O> SkAutoTuner<A, O>
where
    A: FnMut(&Model) -> f64,
    O: FnMut(&Model) -> f64,
{
    pub fn new(
        base: Model,
        config: TuningConfig,
        accuracy_fn: A,
        accuracy_mode: AccuracyMode,
        objective_fn: O,
        sampler: Box<dyn Sampler>,
    ) -> Result<Self> {
        let matched = base.select(&config.selector);
        anyhow::ensure!(!matched.is_empty(), "selector matched no layers");
        // Build the search space: auto per-layer max rank, or user-provided.
        let space = match (&config.space, config.separate) {
            (Some(s), false) => s.clone(),
            (Some(s), true) => {
                let mut joint = SearchSpace::new();
                for layer in &matched {
                    for (dim_name, dim) in &s.dims {
                        joint
                            .dims
                            .insert(format!("{layer}::{dim_name}"), dim.clone());
                    }
                }
                joint
            }
            (None, separate) => {
                let auto = SearchSpace::auto_sketch(64);
                if !separate {
                    auto
                } else {
                    let mut joint = SearchSpace::new();
                    for layer in &matched {
                        for (dim_name, dim) in &auto.dims {
                            joint
                                .dims
                                .insert(format!("{layer}::{dim_name}"), dim.clone());
                        }
                    }
                    joint
                }
            }
        };
        let study = Study::new(
            "skautotune",
            Direction::Minimize,
            space,
            sampler,
            Box::new(NoPruner),
        );
        Ok(SkAutoTuner {
            base,
            config,
            accuracy_fn,
            objective_fn,
            accuracy_mode,
            study,
            matched,
            accuracies: Vec::new(),
        })
    }

    /// Names of the layers being tuned.
    pub fn matched_layers(&self) -> &[String] {
        &self.matched
    }

    /// Build a candidate model for an assignment: clone the dense base and
    /// compress the matched layers through a [`SketchPlan`] — the same
    /// single path every other compression user takes.
    fn candidate(&self, params: &ParamAssignment, seed: u64) -> Result<Model> {
        let mut model = self.base.clone_model();
        let mut plan = SketchPlan::new().seed(seed);
        for layer in &self.matched {
            let (terms_key, rank_key) = if self.config.separate {
                (format!("{layer}::num_terms"), format!("{layer}::low_rank"))
            } else {
                ("num_terms".to_string(), "low_rank".to_string())
            };
            let l = params
                .get(&terms_key)
                .and_then(|v| v.as_usize())
                .context("missing num_terms")?;
            let k = params
                .get(&rank_key)
                .and_then(|v| v.as_usize())
                .context("missing low_rank")?;
            plan = plan
                .select(LayerSelector::by_names(&[layer.as_str()]))
                .with(l, k);
        }
        let report = plan.apply(&mut model)?;
        anyhow::ensure!(
            report.skipped.is_empty(),
            "candidate left layers unconverted: {:?}",
            report
                .skipped
                .iter()
                .map(|s| s.name.as_str())
                .collect::<Vec<_>>()
        );
        Ok(model)
    }

    /// Run `n_trials` trials.
    pub fn tune(&mut self, n_trials: usize) -> Result<TuneOutcome> {
        let mut n_feasible = 0usize;
        for trial_idx in 0..n_trials {
            let mut trial = self.study.ask();
            let model = match self.candidate(&trial.params, 0xA0_u64 + trial_idx as u64) {
                Ok(m) => m,
                Err(e) => {
                    crate::log_warn!("trial {trial_idx} candidate build failed: {e}");
                    self.study.tell_failed(&mut trial);
                    self.accuracies.push(f64::NAN);
                    continue;
                }
            };
            let acc = (self.accuracy_fn)(&model);
            let obj = (self.objective_fn)(&model);
            let feasible = self.accuracy_mode.feasible(acc);
            if feasible {
                n_feasible += 1;
            }
            crate::log_info!(
                "trial {trial_idx}: params {:?} acc {acc:.4} obj {obj:.4} feasible {feasible}",
                trial
                    .params
                    .iter()
                    .map(|(k, v)| format!("{k}={v:?}"))
                    .collect::<Vec<_>>()
            );
            self.study.tell(&mut trial, obj, feasible);
            self.accuracies.push(acc);
        }
        let best = self
            .study
            .best_trial()
            .context("no feasible trial found — relax the accuracy threshold")?;
        Ok(TuneOutcome {
            best_params: best.params.clone(),
            best_objective: best.value.unwrap(),
            best_accuracy: self.accuracies[best.id],
            n_trials,
            n_feasible,
        })
    }

    /// Rebuild the model with the best found configuration (the paper's
    /// `apply_best_params()`).
    pub fn apply_best_params(&self) -> Result<Model> {
        let best = self
            .study
            .best_trial()
            .context("tune() has not found a feasible trial")?;
        self.candidate(&best.params, 0xBE57)
    }

    pub fn study(&self) -> &Study {
        &self.study
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::nn::{ForwardCtx, Linear, Module};
    use crate::rng::Philox;
    use crate::tuner::sampler::{GridSampler, RandomSampler};

    /// Model with two linear layers; accuracy = fidelity to the dense
    /// forward on a probe batch, objective = parameter count.
    fn base_model() -> (Model, Mat, Mat) {
        let mut rng = Philox::seeded(1);
        let mut m = Model::new();
        // Layers must be large enough that rank-≤64 sketches actually
        // shrink them (the auto space caps low_rank at 64).
        m.add("fc1", Linear::random(256, 256, &mut rng)).unwrap();
        m.add("fc2", Linear::random(256, 128, &mut rng)).unwrap();
        let probe = Mat::randn(8, 256, &mut rng);
        // Reference output of fc1 (we score fidelity on the first layer) —
        // dense and sketched candidates answer through the same Module API.
        let ctx = ForwardCtx::new();
        let reference = m.get("fc1").unwrap().forward(&probe, &ctx).unwrap();
        (m, probe, reference)
    }

    fn fidelity(model: &Model, probe: &Mat, reference: &Mat) -> f64 {
        let ctx = ForwardCtx::new();
        let out = model.get("fc1").unwrap().forward(probe, &ctx).unwrap();
        // Higher is better: negative relative error.
        -crate::linalg::rel_error(&out, reference)
    }

    #[test]
    fn tuner_finds_feasible_config_and_shrinks_model() {
        let (model, probe, reference) = base_model();
        let dense_params = model.total_params();
        let mut tuner = SkAutoTuner::new(
            model,
            TuningConfig::all_linear(),
            |m| fidelity(m, &probe, &reference),
            // The two-sided sketch's relative error is ≈ √(d/(l·k)) ≈ 2.0
            // at d=256, l·k=64 — so −2.5 is satisfiable only by the
            // larger-rank configurations, making the constraint meaningful.
            AccuracyMode::AtLeast(-2.5),
            |m| m.total_params() as f64,
            Box::new(GridSampler::new(3)),
        )
        .unwrap();
        assert_eq!(tuner.matched_layers(), &["fc1", "fc2"]);
        let outcome = tuner.tune(15).unwrap();
        assert!(outcome.n_feasible > 0);
        let best = tuner.apply_best_params().unwrap();
        assert!(
            best.total_params() < dense_params,
            "best {} vs dense {dense_params}",
            best.total_params()
        );
        assert_eq!(best.get("fc1").unwrap().type_name(), "SKLinear");
    }

    #[test]
    fn infeasible_threshold_errors_cleanly() {
        let (model, probe, reference) = base_model();
        let mut tuner = SkAutoTuner::new(
            model,
            TuningConfig::all_linear(),
            |m| fidelity(m, &probe, &reference),
            AccuracyMode::AtLeast(0.5), // fidelity is ≤ 0 by construction
            |m| m.total_params() as f64,
            Box::new(RandomSampler::new(5)),
        )
        .unwrap();
        assert!(tuner.tune(5).is_err());
    }

    #[test]
    fn separate_mode_builds_per_layer_dimensions() {
        let (model, probe, reference) = base_model();
        let tuner = SkAutoTuner::new(
            model,
            TuningConfig {
                selector: LayerSelector::by_type("Linear"),
                space: None,
                separate: true,
            },
            |m| fidelity(m, &probe, &reference),
            AccuracyMode::AtLeast(-10.0),
            |m| m.total_params() as f64,
            Box::new(RandomSampler::new(7)),
        )
        .unwrap();
        let dims: Vec<&String> = tuner.study().space.dims.keys().collect();
        assert!(dims.iter().any(|d| d.starts_with("fc1::")));
        assert!(dims.iter().any(|d| d.starts_with("fc2::")));
        assert_eq!(dims.len(), 4);
    }

    #[test]
    fn selector_matching_nothing_is_an_error() {
        let (model, _, _) = base_model();
        let r = SkAutoTuner::new(
            model,
            TuningConfig {
                selector: LayerSelector::by_type("Conv2d"),
                space: None,
                separate: false,
            },
            |_| 0.0,
            AccuracyMode::AtLeast(0.0),
            |_| 0.0,
            Box::new(RandomSampler::new(1)),
        );
        assert!(r.is_err());
    }
}
