//! Samplers: how the next trial's parameters are chosen.

use super::space::{Dimension, ParamAssignment, ParamValue, SearchSpace};
use super::study::{Trial, TrialState};
use crate::rng::{Philox, Rng};

/// A sampler proposes the next parameter assignment given trial history.
pub trait Sampler: Send {
    fn sample(&mut self, space: &SearchSpace, history: &[Trial]) -> ParamAssignment;
}

/// Uniform random search (Optuna's `RandomSampler`).
pub struct RandomSampler {
    rng: Philox,
}

impl RandomSampler {
    pub fn new(seed: u64) -> Self {
        RandomSampler {
            rng: Philox::seeded(seed),
        }
    }

    fn sample_dim(rng: &mut Philox, dim: &Dimension) -> ParamValue {
        match dim {
            Dimension::Int { lo, hi } => {
                ParamValue::Int(lo + rng.next_below((hi - lo + 1) as u32) as i64)
            }
            Dimension::IntLog { lo, hi } => {
                let vals = dim.grid_values().unwrap();
                let _ = (lo, hi);
                vals[rng.next_below(vals.len() as u32) as usize].clone()
            }
            Dimension::Float { lo, hi } => {
                ParamValue::Float(lo + rng.next_f64() * (hi - lo))
            }
            Dimension::Cat(c) => c.choices[rng.next_below(c.choices.len() as u32) as usize].clone(),
        }
    }
}

impl Sampler for RandomSampler {
    fn sample(&mut self, space: &SearchSpace, _history: &[Trial]) -> ParamAssignment {
        space
            .dims
            .iter()
            .map(|(name, dim)| (name.clone(), Self::sample_dim(&mut self.rng, dim)))
            .collect()
    }
}

/// Exhaustive grid search over discrete spaces; falls back to random for
/// float dimensions. Wraps around when the grid is exhausted.
pub struct GridSampler {
    cursor: usize,
    fallback: RandomSampler,
}

impl GridSampler {
    pub fn new(seed: u64) -> Self {
        GridSampler {
            cursor: 0,
            fallback: RandomSampler::new(seed),
        }
    }
}

impl Sampler for GridSampler {
    fn sample(&mut self, space: &SearchSpace, history: &[Trial]) -> ParamAssignment {
        let grids: Option<Vec<(String, Vec<ParamValue>)>> = space
            .dims
            .iter()
            .map(|(n, d)| d.grid_values().map(|g| (n.clone(), g)))
            .collect();
        let Some(grids) = grids else {
            return self.fallback.sample(space, history);
        };
        let total: usize = grids.iter().map(|(_, g)| g.len()).product();
        let mut idx = self.cursor % total.max(1);
        self.cursor += 1;
        let mut out = ParamAssignment::new();
        for (name, grid) in &grids {
            out.insert(name.clone(), grid[idx % grid.len()].clone());
            idx /= grid.len();
        }
        out
    }
}

/// Tree-structured Parzen Estimator (Bergstra et al. 2011) — the algorithm
/// behind Optuna's default sampler, reproduced for this crate.
///
/// Split completed trials into the best γ-fraction (`good`) and the rest
/// (`bad`); model each group's parameter distribution with a Parzen
/// (kernel-density) mixture; sample candidates from `good` and keep the one
/// maximizing the density ratio l(x)/g(x).
pub struct TpeSampler {
    rng: Philox,
    /// Number of startup trials sampled randomly before TPE kicks in.
    pub n_startup: usize,
    /// Fraction of trials considered "good".
    pub gamma: f64,
    /// Candidates drawn per dimension when optimizing the ratio.
    pub n_ei_candidates: usize,
}

impl TpeSampler {
    pub fn new(seed: u64) -> Self {
        TpeSampler {
            rng: Philox::seeded(seed),
            n_startup: 8,
            gamma: 0.25,
            n_ei_candidates: 24,
        }
    }

    /// KDE log-density of `x` under gaussian kernels at `points` (bandwidth
    /// by Scott's rule, floored to keep support wide).
    fn log_kde(points: &[f64], x: f64, span: f64) -> f64 {
        if points.is_empty() {
            return 0.0;
        }
        let n = points.len() as f64;
        let bw = (span * n.powf(-0.2)).max(span * 0.05).max(1e-9);
        let mut acc = 0f64;
        for &p in points {
            let z = (x - p) / bw;
            acc += (-0.5 * z * z).exp();
        }
        (acc / (n * bw)).max(1e-300).ln()
    }

    fn numeric_of(v: &ParamValue) -> f64 {
        v.as_f64().unwrap_or(0.0)
    }
}

impl Sampler for TpeSampler {
    fn sample(&mut self, space: &SearchSpace, history: &[Trial]) -> ParamAssignment {
        let mut complete: Vec<&Trial> = history
            .iter()
            .filter(|t| t.state == TrialState::Complete && t.value.is_some())
            .collect();
        if complete.len() < self.n_startup {
            return RandomSampler {
                rng: Philox::new(0xDEAD, complete.len() as u64 ^ self.rng.next_u64()),
            }
            .sample(space, history);
        }
        // Lower is better (study negates for maximize).
        complete.sort_by(|a, b| a.value.partial_cmp(&b.value).unwrap());
        let n_good = ((complete.len() as f64 * self.gamma).ceil() as usize)
            .clamp(1, complete.len() - 1);
        let (good, bad) = complete.split_at(n_good);

        let mut out = ParamAssignment::new();
        for (name, dim) in &space.dims {
            let collect = |set: &[&Trial]| -> Vec<f64> {
                set.iter()
                    .filter_map(|t| t.params.get(name))
                    .map(Self::numeric_of)
                    .collect()
            };
            let good_pts = collect(good);
            let bad_pts = collect(bad);
            match dim {
                Dimension::Cat(c) => {
                    // Categorical TPE: weighted by counts in good vs bad.
                    let score = |choice: &ParamValue| {
                        let g = good
                            .iter()
                            .filter(|t| t.params.get(name) == Some(choice))
                            .count() as f64
                            + 0.5;
                        let b = bad
                            .iter()
                            .filter(|t| t.params.get(name) == Some(choice))
                            .count() as f64
                            + 0.5;
                        (g / good.len() as f64) / (b / bad.len() as f64)
                    };
                    let best = c
                        .choices
                        .iter()
                        .max_by(|x, y| score(x).partial_cmp(&score(y)).unwrap())
                        .unwrap()
                        .clone();
                    out.insert(name.clone(), best);
                }
                _ => {
                    // Numeric: candidates from good KDE (jittered resamples),
                    // scored by density ratio.
                    let (lo, hi, is_int, log_grid) = match dim {
                        Dimension::Int { lo, hi } => (*lo as f64, *hi as f64, true, None),
                        Dimension::IntLog { .. } => {
                            let vals = dim.grid_values().unwrap();
                            (0.0, 0.0, true, Some(vals))
                        }
                        Dimension::Float { lo, hi } => (*lo, *hi, false, None),
                        Dimension::Cat(_) => unreachable!(),
                    };
                    if let Some(vals) = log_grid {
                        // Discrete log grid: treat as categorical over values.
                        let score = |choice: &ParamValue| {
                            let x = Self::numeric_of(choice);
                            let span = vals.len() as f64;
                            Self::log_kde(&good_pts, x, span)
                                - Self::log_kde(&bad_pts, x, span)
                        };
                        let best = vals
                            .iter()
                            .max_by(|x, y| score(x).partial_cmp(&score(y)).unwrap())
                            .unwrap()
                            .clone();
                        out.insert(name.clone(), best);
                        continue;
                    }
                    let span = (hi - lo).max(1e-9);
                    let mut best_x = lo + self.rng.next_f64() * span;
                    let mut best_score = f64::NEG_INFINITY;
                    for _ in 0..self.n_ei_candidates {
                        // Resample around a good point.
                        let x = if good_pts.is_empty() {
                            lo + self.rng.next_f64() * span
                        } else {
                            let c = good_pts
                                [self.rng.next_below(good_pts.len() as u32) as usize];
                            (c + self.rng.next_normal() as f64 * span * 0.1).clamp(lo, hi)
                        };
                        let s = Self::log_kde(&good_pts, x, span)
                            - Self::log_kde(&bad_pts, x, span);
                        if s > best_score {
                            best_score = s;
                            best_x = x;
                        }
                    }
                    let v = if is_int {
                        ParamValue::Int(best_x.round().clamp(lo, hi) as i64)
                    } else {
                        ParamValue::Float(best_x)
                    };
                    out.insert(name.clone(), v);
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::study::{Trial, TrialState};

    fn space() -> SearchSpace {
        SearchSpace::new()
            .int("num_terms", 1, 3)
            .int_log("low_rank", 4, 32)
            .float("lr", 0.0, 1.0)
    }

    fn trial(id: usize, terms: i64, rank: i64, lr: f64, value: f64) -> Trial {
        let mut t = Trial::new(id);
        t.params.insert("num_terms".into(), ParamValue::Int(terms));
        t.params.insert("low_rank".into(), ParamValue::Int(rank));
        t.params.insert("lr".into(), ParamValue::Float(lr));
        t.state = TrialState::Complete;
        t.value = Some(value);
        t
    }

    #[test]
    fn random_respects_bounds() {
        let mut s = RandomSampler::new(1);
        let sp = space();
        for _ in 0..200 {
            let a = s.sample(&sp, &[]);
            let terms = a["num_terms"].as_i64().unwrap();
            assert!((1..=3).contains(&terms));
            let rank = a["low_rank"].as_i64().unwrap();
            assert!([4, 8, 16, 32].contains(&rank));
            let lr = a["lr"].as_f64().unwrap();
            assert!((0.0..1.0).contains(&lr));
        }
    }

    #[test]
    fn grid_enumerates_discrete_space_exactly() {
        let sp = SearchSpace::new().int("a", 0, 2).int_choices("b", &[10, 20]);
        let mut s = GridSampler::new(0);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..6 {
            let a = s.sample(&sp, &[]);
            seen.insert((a["a"].as_i64().unwrap(), a["b"].as_i64().unwrap()));
        }
        assert_eq!(seen.len(), 6, "grid must cover all 6 combos");
    }

    #[test]
    fn tpe_concentrates_on_good_region() {
        // Optimum near lr=0.2; good trials cluster there. After startup, TPE
        // should propose lr closer to 0.2 than uniform random on average.
        let mut history = Vec::new();
        for i in 0..30 {
            let lr = i as f64 / 30.0;
            let value = (lr - 0.2).abs(); // lower better
            history.push(trial(i, 1, 8, lr, value));
        }
        let mut tpe = TpeSampler::new(7);
        let sp = space();
        let mut acc = 0f64;
        let n = 40;
        for _ in 0..n {
            let a = tpe.sample(&sp, &history);
            acc += (a["lr"].as_f64().unwrap() - 0.2).abs();
        }
        let mean_dist = acc / n as f64;
        // Uniform would give E|x − 0.2| ≈ 0.34.
        assert!(mean_dist < 0.25, "TPE mean distance {mean_dist}");
    }

    #[test]
    fn tpe_random_during_startup() {
        let mut tpe = TpeSampler::new(3);
        let sp = space();
        let a = tpe.sample(&sp, &[]);
        assert!(a.contains_key("lr"));
    }
}
