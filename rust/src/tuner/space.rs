//! Search-space definition.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// A sampled parameter value.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    Int(i64),
    Float(f64),
    Str(String),
}

impl ParamValue {
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            ParamValue::Int(v) => Some(*v),
            ParamValue::Float(v) => Some(*v as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            ParamValue::Int(v) => Some(*v as f64),
            ParamValue::Float(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            ParamValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            ParamValue::Int(v) => Json::Num(*v as f64),
            ParamValue::Float(v) => Json::Num(*v),
            ParamValue::Str(s) => Json::Str(s.clone()),
        }
    }
}

/// Categorical choice set.
#[derive(Debug, Clone)]
pub struct Categorical {
    pub choices: Vec<ParamValue>,
}

/// One search dimension.
#[derive(Debug, Clone)]
pub enum Dimension {
    /// Integer range [lo, hi] inclusive.
    Int { lo: i64, hi: i64 },
    /// Integer range sampled log-uniformly (for ranks: 4..512).
    IntLog { lo: i64, hi: i64 },
    /// Float range [lo, hi).
    Float { lo: f64, hi: f64 },
    /// Explicit categorical choices.
    Cat(Categorical),
}

impl Dimension {
    /// All values of a discrete dimension (None for Float).
    pub fn grid_values(&self) -> Option<Vec<ParamValue>> {
        match self {
            Dimension::Int { lo, hi } => Some((*lo..=*hi).map(ParamValue::Int).collect()),
            Dimension::IntLog { lo, hi } => {
                // Powers of two within the range (the natural rank grid).
                let mut v = Vec::new();
                let mut x = *lo;
                while x <= *hi {
                    v.push(ParamValue::Int(x));
                    x *= 2;
                }
                Some(v)
            }
            Dimension::Cat(c) => Some(c.choices.clone()),
            Dimension::Float { .. } => None,
        }
    }
}

/// Named collection of dimensions. BTreeMap for deterministic ordering.
#[derive(Debug, Clone, Default)]
pub struct SearchSpace {
    pub dims: BTreeMap<String, Dimension>,
}

impl SearchSpace {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn int(mut self, name: &str, lo: i64, hi: i64) -> Self {
        self.dims.insert(name.into(), Dimension::Int { lo, hi });
        self
    }

    pub fn int_log(mut self, name: &str, lo: i64, hi: i64) -> Self {
        self.dims.insert(name.into(), Dimension::IntLog { lo, hi });
        self
    }

    pub fn float(mut self, name: &str, lo: f64, hi: f64) -> Self {
        self.dims.insert(name.into(), Dimension::Float { lo, hi });
        self
    }

    pub fn categorical(mut self, name: &str, choices: &[&str]) -> Self {
        self.dims.insert(
            name.into(),
            Dimension::Cat(Categorical {
                choices: choices
                    .iter()
                    .map(|s| ParamValue::Str(s.to_string()))
                    .collect(),
            }),
        );
        self
    }

    pub fn int_choices(mut self, name: &str, choices: &[i64]) -> Self {
        self.dims.insert(
            name.into(),
            Dimension::Cat(Categorical {
                choices: choices.iter().map(|&v| ParamValue::Int(v)).collect(),
            }),
        );
        self
    }

    /// The default space sketching introduces — the paper's `params="auto"`:
    /// `num_terms ∈ {1,2,3}`, `low_rank ∈ {4,8,…,max_rank}` (log grid).
    pub fn auto_sketch(max_rank: i64) -> Self {
        SearchSpace::new()
            .int("num_terms", 1, 3)
            .int_log("low_rank", 4, max_rank)
    }

    /// Cartesian size of the discrete grid (None if any float dim).
    pub fn grid_size(&self) -> Option<usize> {
        let mut total = 1usize;
        for d in self.dims.values() {
            total = total.checked_mul(d.grid_values()?.len())?;
        }
        Some(total)
    }
}

/// A concrete assignment of all dimensions.
pub type ParamAssignment = BTreeMap<String, ParamValue>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auto_sketch_space_shape() {
        let s = SearchSpace::auto_sketch(64);
        assert_eq!(s.dims.len(), 2);
        // 3 term choices × ranks {4,8,16,32,64} = 15.
        assert_eq!(s.grid_size(), Some(15));
    }

    #[test]
    fn float_dim_has_no_grid() {
        let s = SearchSpace::new().float("lr", 1e-5, 1e-1);
        assert_eq!(s.grid_size(), None);
    }

    #[test]
    fn int_log_grid_powers_of_two() {
        let d = Dimension::IntLog { lo: 4, hi: 32 };
        let vals: Vec<i64> = d
            .grid_values()
            .unwrap()
            .iter()
            .map(|v| v.as_i64().unwrap())
            .collect();
        assert_eq!(vals, vec![4, 8, 16, 32]);
    }

    #[test]
    fn param_value_conversions() {
        assert_eq!(ParamValue::Int(5).as_usize(), Some(5));
        assert_eq!(ParamValue::Float(2.5).as_f64(), Some(2.5));
        assert_eq!(ParamValue::Str("x".into()).as_str(), Some("x"));
        assert_eq!(ParamValue::Str("x".into()).as_i64(), None);
    }
}
