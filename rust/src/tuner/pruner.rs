//! Pruners: early-stop unpromising trials from interim reports.

use super::study::{Trial, TrialState};

/// Decides whether a running trial should be stopped early.
pub trait Pruner: Send {
    /// `value` is the canonical (lower-better) interim value at `step`.
    fn should_prune(&self, history: &[Trial], trial: &Trial, step: usize, value: f64) -> bool;
}

/// Never prunes.
pub struct NoPruner;

impl Pruner for NoPruner {
    fn should_prune(&self, _h: &[Trial], _t: &Trial, _s: usize, _v: f64) -> bool {
        false
    }
}

/// Optuna's `MedianPruner`: stop if the trial's interim value is worse than
/// the median of completed trials' values at the same step.
pub struct MedianPruner {
    /// Number of completed trials required before pruning activates.
    pub n_startup_trials: usize,
    /// Steps at the start of each trial that are never pruned.
    pub n_warmup_steps: usize,
}

impl Default for MedianPruner {
    fn default() -> Self {
        MedianPruner {
            n_startup_trials: 4,
            n_warmup_steps: 1,
        }
    }
}

impl Pruner for MedianPruner {
    fn should_prune(&self, history: &[Trial], trial: &Trial, step: usize, value: f64) -> bool {
        if step < self.n_warmup_steps {
            return false;
        }
        // Interim values of other trials at the same (or nearest ≤) step.
        let mut peers: Vec<f64> = history
            .iter()
            .filter(|t| t.id != trial.id && t.state == TrialState::Complete)
            .filter_map(|t| {
                t.interim
                    .iter()
                    .rev()
                    .find(|(s, _)| *s <= step)
                    .map(|(_, v)| *v)
            })
            .collect();
        if peers.len() < self.n_startup_trials {
            return false;
        }
        peers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = peers[peers.len() / 2];
        value > median
    }
}

/// Successive-halving (ASHA-style) pruner: at each rung (step =
/// `min_resource·η^r`), keep only the top `1/η` fraction of trials seen at
/// that rung; everything else is stopped. Asynchronous: decisions use
/// whatever history exists when a trial reaches the rung.
pub struct SuccessiveHalvingPruner {
    /// First rung (steps before any pruning decision).
    pub min_resource: usize,
    /// Reduction factor η (Optuna default 4; 3 in the ASHA paper).
    pub eta: usize,
}

impl Default for SuccessiveHalvingPruner {
    fn default() -> Self {
        SuccessiveHalvingPruner {
            min_resource: 1,
            eta: 3,
        }
    }
}

impl SuccessiveHalvingPruner {
    /// Is `step` exactly a rung boundary?
    fn rung(&self, step: usize) -> Option<u32> {
        if step < self.min_resource {
            return None;
        }
        let mut r = self.min_resource;
        let mut i = 0u32;
        while r < step {
            r *= self.eta;
            i += 1;
        }
        (r == step).then_some(i)
    }
}

impl Pruner for SuccessiveHalvingPruner {
    fn should_prune(&self, history: &[Trial], trial: &Trial, step: usize, value: f64) -> bool {
        let Some(_rung) = self.rung(step) else {
            return false;
        };
        // Competitors' values at (or before) the same rung step.
        let mut peers: Vec<f64> = history
            .iter()
            .filter(|t| t.id != trial.id)
            .filter_map(|t| {
                t.interim
                    .iter()
                    .rev()
                    .find(|(s, _)| *s <= step)
                    .map(|(_, v)| *v)
            })
            .collect();
        if peers.len() < self.eta {
            return false; // not enough signal at this rung yet
        }
        peers.sort_by(|a, b| a.partial_cmp(b).unwrap());
        // Survive only in the top 1/η fraction (lower is better).
        let cutoff_idx = (peers.len() / self.eta).max(1) - 1;
        value > peers[cutoff_idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::study::Trial;

    fn completed(id: usize, interim: &[(usize, f64)]) -> Trial {
        let mut t = Trial::new(id);
        t.state = TrialState::Complete;
        t.interim = interim.to_vec();
        t.value = interim.last().map(|&(_, v)| v);
        t
    }

    #[test]
    fn no_pruner_never_prunes() {
        let t = Trial::new(0);
        assert!(!NoPruner.should_prune(&[], &t, 100, f64::INFINITY));
    }

    #[test]
    fn median_pruner_stops_bad_trials() {
        let history: Vec<Trial> = (0..6)
            .map(|i| completed(i, &[(0, 1.0), (5, 0.5), (10, 0.3)]))
            .collect();
        let p = MedianPruner::default();
        let t = Trial::new(99);
        // Way worse than the 0.5 median at step 5.
        assert!(p.should_prune(&history, &t, 5, 2.0));
        // Better than median — keep going.
        assert!(!p.should_prune(&history, &t, 5, 0.3));
    }

    #[test]
    fn sh_rungs_are_geometric() {
        let p = SuccessiveHalvingPruner {
            min_resource: 2,
            eta: 3,
        };
        assert_eq!(p.rung(1), None);
        assert_eq!(p.rung(2), Some(0));
        assert_eq!(p.rung(6), Some(1));
        assert_eq!(p.rung(18), Some(2));
        assert_eq!(p.rung(7), None, "non-rung steps never prune");
    }

    #[test]
    fn sh_keeps_top_fraction() {
        let p = SuccessiveHalvingPruner {
            min_resource: 4,
            eta: 4,
        };
        // 8 peers with values 1..8 at step 4.
        let history: Vec<Trial> = (0..8)
            .map(|i| completed(i, &[(4, (i + 1) as f64)]))
            .collect();
        let t = Trial::new(99);
        // Top quarter = values ≤ 2. A 1.5 survives; a 5.0 is pruned.
        assert!(!p.should_prune(&history, &t, 4, 1.5));
        assert!(p.should_prune(&history, &t, 4, 5.0));
        // Off-rung step: never prune.
        assert!(!p.should_prune(&history, &t, 5, 100.0));
    }

    #[test]
    fn sh_insufficient_peers_no_prune() {
        let p = SuccessiveHalvingPruner::default();
        let history: Vec<Trial> = (0..2).map(|i| completed(i, &[(1, 0.0)])).collect();
        let t = Trial::new(9);
        assert!(!p.should_prune(&history, &t, 1, 100.0));
    }

    #[test]
    fn median_pruner_respects_warmup_and_startup() {
        let p = MedianPruner {
            n_startup_trials: 4,
            n_warmup_steps: 3,
        };
        let history: Vec<Trial> = (0..6).map(|i| completed(i, &[(5, 0.1)])).collect();
        let t = Trial::new(9);
        assert!(!p.should_prune(&history, &t, 2, 100.0), "warmup");
        let small: Vec<Trial> = (0..2).map(|i| completed(i, &[(5, 0.1)])).collect();
        assert!(!p.should_prune(&small, &t, 5, 100.0), "startup");
    }
}
