//! SLO-driven admission control: the per-request deadline/quality
//! contract ([`Slo`]), the per-tier completion-time estimator
//! ([`predict_latency`] over a [`TierLoad`] sensor reading), and the
//! shedding policy ([`admit`]) that picks which tier of a quality ladder
//! a request runs on.
//!
//! This is the *decision* layer — pure functions over sensor readings,
//! deterministic and unit-testable with no threads. The *mechanism*
//! (reading live metrics, submitting to tier queues, speculative
//! upgrades) lives in [`super::cascade`].
//!
//! ## The estimator
//!
//! A tier's predicted completion time for a newly admitted request is
//!
//! ```text
//! B_eff      = clamp(ceil(mean_occupancy), 1, max_batch)     (max_batch before any batch)
//! n_batches  = ceil((queue_depth + 1) / B_eff)
//! rounds     = ceil(n_batches / workers)
//! predicted  = max_wait + s · rounds
//! ```
//!
//! where `s` is the tier's windowed median per-batch execution time
//! (queue wait excluded — see [`super::TierMetrics::windowed_exec`]).
//! `B_eff` converts observed occupancy into how many queued requests one
//! executed batch retires; `rounds` is how many sequential batch
//! executions the request waits behind once the worker pool fans out;
//! `max_wait` is the batcher's coalescing wait, charged in full (the
//! pessimistic bound for a lone request). A tier that has executed no
//! batch inside the sliding window predicts `max_wait` alone — an idle
//! tier is assumed fast, so cold starts route optimistically rather than
//! rejecting on no evidence.
//!
//! ## The shedding policy
//!
//! [`admit`] walks the quality ladder **best quality first** over the
//! tiers whose quality clears the request's floor, and routes to the
//! first whose prediction meets the deadline: requests get the best
//! quality the current load affords, and overload on the dense tier
//! *sheds* down the ladder (a counted quality downgrade) instead of
//! rejecting. Only when no eligible tier predicts in time is the request
//! rejected, with the best prediction attached
//! ([`super::ServeError::SloInfeasible`]).

use std::time::Duration;

/// A request's service-level objective: answer within `deadline`, from a
/// tier of quality at least `min_quality`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Slo {
    /// Wall-clock completion deadline, measured from admission.
    pub deadline: Duration,
    /// Quality floor: tiers below this never serve the request, even if
    /// that means rejecting it. Qualities are the cascade's per-tier
    /// scores (conventionally `1.0` = dense, lower = sketched).
    pub min_quality: f32,
}

impl Slo {
    /// Deadline-only SLO: any tier quality is acceptable.
    pub fn new(deadline: Duration) -> Self {
        Slo {
            deadline,
            min_quality: 0.0,
        }
    }

    /// Add a quality floor.
    pub fn with_min_quality(mut self, q: f32) -> Self {
        self.min_quality = q;
        self
    }
}

/// One tier's sensor reading, as fed to [`predict_latency`]. The cascade
/// fills this from live [`super::TierMetrics`]; tests construct it
/// directly.
#[derive(Debug, Clone, Copy)]
pub struct TierLoad {
    /// Requests queued ahead of this one (enqueued, not yet batched).
    pub queue_depth: usize,
    /// Mean live rows per executed batch (0 before the first batch).
    pub mean_occupancy: f64,
    /// Windowed median per-batch execution time (zero when the tier has
    /// been idle past the sliding window — the optimistic cold start).
    pub exec_p50: Duration,
    /// The tier's batch cap.
    pub max_batch: usize,
    /// The batcher's coalescing wait.
    pub max_wait: Duration,
    /// Worker threads draining the queue.
    pub workers: usize,
}

/// Predicted completion time of a request admitted to a tier under
/// `load` — the estimator formula in the module docs. Saturates at
/// `Duration::MAX` instead of overflowing on absurd inputs.
pub fn predict_latency(load: &TierLoad) -> Duration {
    let max_batch = load.max_batch.max(1);
    let b_eff = if load.mean_occupancy > 0.0 {
        (load.mean_occupancy.ceil() as usize).clamp(1, max_batch)
    } else {
        // No occupancy evidence yet: assume batches fill to the cap.
        max_batch
    };
    let n_batches = load.queue_depth.saturating_add(1).div_ceil(b_eff);
    let rounds = n_batches.div_ceil(load.workers.max(1));
    let exec = load
        .exec_p50
        .checked_mul(u32::try_from(rounds).unwrap_or(u32::MAX))
        .unwrap_or(Duration::MAX);
    load.max_wait.checked_add(exec).unwrap_or(Duration::MAX)
}

/// What [`admit`] decided for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decision {
    /// Route to ladder index `index` (0 = best quality). `shed_from` is
    /// the index of the best *eligible* tier when the request was routed
    /// below it — the recorded quality downgrade; `None` when the
    /// request got its best eligible tier.
    Route {
        index: usize,
        shed_from: Option<usize>,
    },
    /// No eligible tier predicts completion inside the deadline.
    /// `best_predicted` is the fastest eligible prediction
    /// (`Duration::MAX` when the quality floor leaves no tier eligible).
    Infeasible { best_predicted: Duration },
}

/// The shedding policy: pick a ladder index for a request with `slo`
/// given each tier's `(quality, predicted completion)`, ordered best
/// quality first (the cascade's canonical order). Pure — no clocks, no
/// queues — so the policy is exhaustively testable.
pub fn admit(slo: &Slo, tiers: &[(f32, Duration)]) -> Decision {
    let mut first_eligible: Option<usize> = None;
    let mut best_predicted = Duration::MAX;
    for (i, &(quality, predicted)) in tiers.iter().enumerate() {
        if quality < slo.min_quality {
            continue;
        }
        if first_eligible.is_none() {
            first_eligible = Some(i);
        }
        best_predicted = best_predicted.min(predicted);
        if predicted <= slo.deadline {
            let shed_from = first_eligible.filter(|&f| f != i);
            return Decision::Route {
                index: i,
                shed_from,
            };
        }
    }
    Decision::Infeasible { best_predicted }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load() -> TierLoad {
        TierLoad {
            queue_depth: 0,
            mean_occupancy: 0.0,
            exec_p50: Duration::from_millis(4),
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            workers: 2,
        }
    }

    #[test]
    fn estimator_charges_wait_plus_rounds() {
        // Empty queue, no occupancy evidence: one batch, one round.
        assert_eq!(predict_latency(&load()), Duration::from_millis(5));
        // 8 queued + this one at B_eff = max_batch = 4 ⇒ 3 batches over
        // 2 workers ⇒ 2 rounds ⇒ 1 + 2·4 ms.
        let l = TierLoad {
            queue_depth: 8,
            ..load()
        };
        assert_eq!(predict_latency(&l), Duration::from_millis(9));
        // Observed occupancy 1.0 shrinks B_eff: 9 requests ⇒ 9 batches
        // over 2 workers ⇒ 5 rounds ⇒ 1 + 5·4 ms.
        let l = TierLoad {
            queue_depth: 8,
            mean_occupancy: 1.0,
            ..load()
        };
        assert_eq!(predict_latency(&l), Duration::from_millis(21));
        // Fractional occupancy rounds up (2.3 ⇒ 3 rows per batch).
        let l = TierLoad {
            queue_depth: 5,
            mean_occupancy: 2.3,
            ..load()
        };
        // 6 requests / 3 per batch = 2 batches / 2 workers = 1 round.
        assert_eq!(predict_latency(&l), Duration::from_millis(5));
    }

    #[test]
    fn estimator_cold_start_is_optimistic_and_degenerate_inputs_saturate() {
        // Idle past the window: no exec evidence ⇒ only the wait term.
        let l = TierLoad {
            exec_p50: Duration::ZERO,
            queue_depth: 100,
            ..load()
        };
        assert_eq!(predict_latency(&l), Duration::from_millis(1));
        // Zero workers / zero cap are clamped, not divide-by-zero.
        let l = TierLoad {
            workers: 0,
            max_batch: 0,
            ..load()
        };
        assert_eq!(predict_latency(&l), Duration::from_millis(5));
        // Saturation instead of overflow.
        let l = TierLoad {
            exec_p50: Duration::MAX,
            queue_depth: usize::MAX - 1,
            ..load()
        };
        assert_eq!(predict_latency(&l), Duration::MAX);
    }

    #[test]
    fn admit_prefers_best_quality_that_meets_deadline() {
        let ms = Duration::from_millis;
        let slo = Slo::new(ms(10));
        // Both feasible: the best-quality tier wins even though the
        // cheaper one is faster.
        let d = admit(&slo, &[(1.0, ms(8)), (0.6, ms(2))]);
        assert_eq!(
            d,
            Decision::Route {
                index: 0,
                shed_from: None
            }
        );
    }

    #[test]
    fn admit_sheds_overloaded_dense_to_sketched() {
        let ms = Duration::from_millis;
        let slo = Slo::new(ms(10));
        // Dense predicts past the deadline: shed to the sketched tier,
        // recording the downgrade from index 0.
        let d = admit(&slo, &[(1.0, ms(50)), (0.6, ms(2))]);
        assert_eq!(
            d,
            Decision::Route {
                index: 1,
                shed_from: Some(0)
            }
        );
    }

    #[test]
    fn admit_respects_quality_floor() {
        let ms = Duration::from_millis;
        // Floor 0.9 makes the sketched tier ineligible: the request
        // waits on dense even though sketched is faster...
        let slo = Slo::new(ms(60)).with_min_quality(0.9);
        let d = admit(&slo, &[(1.0, ms(50)), (0.6, ms(2))]);
        assert_eq!(
            d,
            Decision::Route {
                index: 0,
                shed_from: None
            }
        );
        // ...and when dense cannot meet the deadline either, the floor
        // turns shedding into a typed reject carrying dense's prediction.
        let slo = Slo::new(ms(10)).with_min_quality(0.9);
        let d = admit(&slo, &[(1.0, ms(50)), (0.6, ms(2))]);
        assert_eq!(
            d,
            Decision::Infeasible {
                best_predicted: ms(50)
            }
        );
        // A floor above every tier leaves nothing eligible.
        let slo = Slo::new(ms(10)).with_min_quality(2.0);
        let d = admit(&slo, &[(1.0, ms(1)), (0.6, ms(1))]);
        assert_eq!(
            d,
            Decision::Infeasible {
                best_predicted: Duration::MAX
            }
        );
    }

    #[test]
    fn admit_infeasible_reports_fastest_eligible_prediction() {
        let ms = Duration::from_millis;
        let slo = Slo::new(ms(1));
        let d = admit(&slo, &[(1.0, ms(50)), (0.6, ms(7))]);
        assert_eq!(
            d,
            Decision::Infeasible {
                best_predicted: ms(7)
            }
        );
        // Empty ladder: infeasible by construction.
        assert_eq!(
            admit(&slo, &[]),
            Decision::Infeasible {
                best_predicted: Duration::MAX
            }
        );
    }
}
