//! The speculative dense/sketched cascade: SLO-routed admission over a
//! quality ladder of row tiers, overload shedding, and two-phase
//! speculative replies.
//!
//! A [`Cascade`] sits *in front of* existing [`super::ModelServer`] row
//! tiers — it owns no queues and no workers, it only decides (via
//! [`super::slo`]) which tier's queue each request joins, reading the
//! live sensors every tier already records ([`super::TierMetrics`]).
//! Tiers keep serving explicitly-named traffic through
//! [`super::ServeHandle`] at the same time; cascade routing is an
//! overlay, not a takeover.
//!
//! Two admission modes:
//!
//! - [`Cascade::submit`] / [`Cascade::infer`] — deadline-aware routing:
//!   the best-quality tier whose predicted completion meets the
//!   request's [`Slo`] gets it; overload sheds down the ladder (counted
//!   as a quality downgrade on the tier shed *from*); a request no tier
//!   can serve in time gets a typed [`ServeError::SloInfeasible`].
//! - [`Cascade::speculate`] — answer fast, verify asynchronously: the
//!   request is submitted to the cheapest tier *and* the best tier at
//!   once. [`SpecReply::first`] blocks only for the cheap answer and
//!   hands back an [`UpgradeHandle`]; [`UpgradeHandle::upgraded`] later
//!   yields the best tier's answer ([`Upgrade::Upgraded`]) or a typed
//!   revocation ([`Upgrade::Revoked`]) if the verify leg failed, was
//!   never admitted, or the server drained first. Dropping the handle
//!   without consuming it counts as an explicit revocation — the
//!   accounting invariant `speculative == upgrades + revoked` holds on
//!   every path, so shutdown can prove no speculative work was orphaned.
//!
//! Ladder order is **live**: each submit ranks tiers by their *measured*
//! quality (the [`super::adapt`] shadow-stream sensor) when one exists,
//! falling back to the static construction-time score — so a hot-swap
//! that changes a tier's rank re-orders routing as soon as the adapter
//! re-measures, without rebuilding the cascade.

use super::batcher::{ModelSlot, ServeRequest, TierQueue};
use super::metrics::TierMetrics;
use super::router::{Router, Tier};
use super::slo::{admit, predict_latency, Decision, Slo, TierLoad};
use super::trace::TraceCtx;
use super::{ModelServer, PendingReply, ServeError, TierInfo};
use crate::util::events::EventClass;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// How long [`Cascade::submit`] waits before its one retry of the last
/// remaining rung after a `QueueFull` rejection — one short drain
/// window (several batch slots at serving-scale exec times) charged to
/// the caller's wall clock, bounded so a doomed request still fails
/// fast.
const LAST_RUNG_BACKOFF: Duration = Duration::from_millis(20);

/// One rung of the quality ladder: a registered row tier plus the
/// quality score the cascade ranks it by.
struct Rung {
    name: String,
    quality: f32,
    queue: Arc<TierQueue<ServeRequest>>,
    info: TierInfo,
    metrics: Arc<TierMetrics>,
    /// The tier's versioned model slot: cascade submissions capture the
    /// current version at admission exactly like [`super::ServeHandle`]
    /// submissions do, so hot-swaps stay atomic under cascade traffic.
    slot: Arc<ModelSlot>,
}

impl Rung {
    /// The quality score routing ranks this rung by *right now*: the
    /// tier's **measured** quality (the rank adapter's shadow-stream
    /// sensor, see [`super::adapt`]) when one exists, else the static
    /// score the ladder was built with. A swap to a different rank shows
    /// up here as soon as the adapter re-measures — the ladder re-orders
    /// itself around evidence instead of trusting construction-time
    /// labels.
    fn effective_quality(&self) -> f32 {
        match self.metrics.measured_quality() {
            Some(q) => q as f32,
            None => self.quality,
        }
    }
}

/// SLO router over a ladder of row tiers, ordered best quality first.
/// Cheap to construct and immutable — build one per ladder and share it
/// across client threads (it is `Send + Sync`).
pub struct Cascade {
    rungs: Vec<Rung>,
    /// The owning server's router, read live for the tracer: cascade
    /// admissions mint trace ids exactly like [`super::ServeHandle`]
    /// admissions, plus cascade-only events (shed, SLO reject,
    /// speculation, upgrade/revoke).
    router: Arc<Router>,
}

impl Cascade {
    /// Build a cascade over `ladder` — `(tier name, quality)` pairs of
    /// row tiers already registered on `server`. Tiers are ranked by
    /// descending quality; ties break toward the earlier ladder entry.
    ///
    /// All tiers must be row tiers with identical `in_dim` and `out_dim`
    /// (a shed or a speculative upgrade substitutes one tier's answer
    /// for another's, so the reply shape must not depend on routing),
    /// qualities must be finite, and names must be distinct.
    pub fn new(server: &ModelServer, ladder: &[(&str, f32)]) -> Result<Cascade, ServeError> {
        if ladder.is_empty() {
            return Err(ServeError::BadInput("empty cascade ladder".into()));
        }
        let mut rungs = Vec::with_capacity(ladder.len());
        for &(name, quality) in ladder {
            if !quality.is_finite() {
                return Err(ServeError::BadInput(format!(
                    "tier {name:?}: quality {quality} is not finite"
                )));
            }
            if rungs.iter().any(|r: &Rung| r.name == name) {
                return Err(ServeError::BadInput(format!(
                    "tier {name:?} appears twice in the ladder"
                )));
            }
            let tier = server.router.get(name)?;
            let (queue, info, slot) = match &*tier {
                Tier::Row { queue, info, slot, .. } => {
                    (Arc::clone(queue), info.clone(), Arc::clone(slot))
                }
                Tier::Seq { .. } => {
                    return Err(ServeError::BadInput(format!(
                        "tier {name:?} serves sequences — cascades route \
                         single-row requests"
                    )))
                }
            };
            let metrics = server.metrics.tier_entry(name);
            rungs.push(Rung {
                name: name.to_string(),
                quality,
                queue,
                info,
                metrics,
                slot,
            });
        }
        let (d0, o0) = (rungs[0].info.in_dim, rungs[0].info.out_dim);
        for r in &rungs[1..] {
            if r.info.in_dim != d0 || r.info.out_dim != o0 {
                return Err(ServeError::BadInput(format!(
                    "tier {:?} is {}→{} but tier {:?} is {}→{} — cascade \
                     tiers must share request and reply shapes",
                    rungs[0].name, d0, o0, r.name, r.info.in_dim, r.info.out_dim
                )));
            }
        }
        // Best static quality first; stable sort keeps ladder order on
        // ties. This is the *baseline* order — every submit re-ranks by
        // effective (measured-when-available) quality, so the stored
        // order only decides ties among unmeasured tiers.
        rungs.sort_by(|a, b| b.quality.partial_cmp(&a.quality).expect("finite"));
        Ok(Cascade {
            rungs,
            router: Arc::clone(&server.router),
        })
    }

    /// The ladder as `(name, static quality)`, best static quality first
    /// — the construction-time labels. See [`Cascade::qualities`] for
    /// the live (measured) view routing actually uses.
    pub fn tiers(&self) -> Vec<(String, f32)> {
        let entry = |r: &Rung| (r.name.clone(), r.quality);
        self.rungs.iter().map(entry).collect()
    }

    /// The live ladder as `(name, effective quality)`, best first —
    /// effective quality is the tier's **measured** quality (the rank
    /// adapter's shadow-stream sensor) when one exists, else its static
    /// score. This is exactly the ordering and the values the next
    /// [`Cascade::submit`] will hand to the admission policy.
    pub fn qualities(&self) -> Vec<(String, f32)> {
        let order = self.effective_order();
        order
            .into_iter()
            .map(|i| (self.rungs[i].name.clone(), self.rungs[i].effective_quality()))
            .collect()
    }

    /// Rung indices sorted best effective quality first (stable: ties
    /// keep the static best-first baseline order).
    fn effective_order(&self) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.rungs.len()).collect();
        order.sort_by(|&a, &b| {
            let (qa, qb) = (
                self.rungs[a].effective_quality(),
                self.rungs[b].effective_quality(),
            );
            // Measured qualities are clamped finite at the sensor and
            // static ones validated at construction.
            qb.partial_cmp(&qa).expect("finite quality")
        });
        order
    }

    /// Request row width (identical across the ladder).
    pub fn in_dim(&self) -> usize {
        self.rungs[0].info.in_dim
    }

    /// Live sensor reading for rung `i` — what the estimator sees.
    fn load(&self, i: usize) -> TierLoad {
        let r = &self.rungs[i];
        // A supervised tier advertises its *live* pool size: while a
        // crashed worker waits out its respawn backoff the latency
        // estimator sees the reduced drain rate, not the configured one.
        // The gauge is zero only for unsupervised tiers — fall back to
        // the static worker count there.
        let live = r.metrics.live_workers();
        TierLoad {
            queue_depth: r.metrics.queue_depth(),
            mean_occupancy: r.metrics.mean_occupancy(),
            exec_p50: r.metrics.windowed_exec().p50(),
            max_batch: r.info.max_batch,
            max_wait: r.info.max_wait,
            workers: if live > 0 { live } else { r.info.workers },
        }
    }

    /// Predicted completion time of a request admitted to tier `name`
    /// right now (`None` for names outside the ladder) — the exact
    /// number [`Cascade::submit`] compares against deadlines, exposed
    /// for observability.
    pub fn predict(&self, name: &str) -> Option<Duration> {
        let i = self.rungs.iter().position(|r| r.name == name)?;
        Some(predict_latency(&self.load(i)))
    }

    fn check_width(&self, row: &[f32]) -> Result<(), ServeError> {
        if row.len() != self.in_dim() {
            return Err(ServeError::BadInput(format!(
                "cascade serves rows of width {}, got {}",
                self.in_dim(),
                row.len()
            )));
        }
        Ok(())
    }

    /// Route one request by its SLO (the policy in [`super::slo`]):
    /// best-quality eligible tier whose prediction meets the deadline;
    /// a full queue falls through to the next rung (the prediction was
    /// stale — shed anyway rather than reject). When the rejecting rung
    /// is the *last* one standing, it is retried exactly once after a
    /// short bounded backoff before the request fails. Returns the
    /// in-flight [`Routed`] reply, or [`ServeError::SloInfeasible`]
    /// when no eligible tier can make the deadline.
    pub fn submit(&self, row: &[f32], slo: &Slo) -> Result<Routed, ServeError> {
        self.check_width(row)?;
        // Rank the ladder by *effective* quality for this submit: a tier
        // whose measured quality has drifted below its static label (or
        // below the request's floor) is ranked — and floored — by the
        // evidence, not the label.
        let order = self.effective_order();
        let eq: Vec<f32> = self.rungs.iter().map(Rung::effective_quality).collect();
        // The best eligible rung in the full ladder: routing anywhere
        // below it is the recorded quality downgrade, and rejects are
        // charged to it (the tier the request *wanted*; a floor above
        // the whole ladder charges the top rung).
        let first_eligible = order.iter().copied().find(|&i| eq[i] >= slo.min_quality);
        let top = order[0];
        // (original rung index, (quality, predicted)) — rungs that turn
        // out QueueFull are removed before re-running the policy, so the
        // loop strictly shrinks the candidate set and must terminate
        // (the one last-rung retry is bounded by the flag below).
        let mut candidates: Vec<(usize, (f32, Duration))> = order
            .iter()
            .map(|&i| (i, (eq[i], predict_latency(&self.load(i)))))
            .collect();
        let mut last_rung_retried = false;
        loop {
            let ladder: Vec<(f32, Duration)> = candidates.iter().map(|c| c.1).collect();
            match admit(slo, &ladder) {
                Decision::Infeasible { best_predicted } => {
                    let charged = first_eligible.unwrap_or(top);
                    self.rungs[charged].metrics.record_slo_reject();
                    if let Some(tr) = self.router.tracer() {
                        let detail = format!("deadline_us={}", slo.deadline.as_micros());
                        tr.tier(&self.rungs[charged].name)
                            .record_now(EventClass::SloReject, 0, detail);
                    }
                    return Err(ServeError::SloInfeasible {
                        deadline: slo.deadline,
                        best_predicted,
                    });
                }
                Decision::Route { index, .. } => {
                    let orig = candidates[index].0;
                    let rung = &self.rungs[orig];
                    let (tx, rx) = mpsc::channel();
                    let model = rung.slot.current();
                    let trace = self.admit_trace(&rung.name, model.version);
                    let trace_id = trace.as_ref().map_or(0, TraceCtx::id);
                    let req = ServeRequest {
                        row: row.to_vec(),
                        reply: tx,
                        enqueued: Instant::now(),
                        model,
                        trace: trace.clone(),
                    };
                    match rung.queue.try_submit(req) {
                        Ok(()) => {
                            // Shed = routed below the best eligible rung
                            // of the FULL ladder — including when that
                            // rung dropped out as QueueFull (overload is
                            // exactly the downgrade worth counting).
                            let shed = first_eligible.is_some_and(|f| f != orig);
                            if shed {
                                let f = first_eligible.expect("shed implies eligible");
                                self.rungs[f].metrics.record_shed();
                                // The shed is charged to the tier the
                                // request *wanted* — record it on that
                                // tier's event stream, tagged with the
                                // routed request's trace id so the trace
                                // links the downgrade to the reply.
                                if let Some(tr) = self.router.tracer() {
                                    let detail = format!("to={}", rung.name);
                                    tr.tier(&self.rungs[f].name)
                                        .record_now(EventClass::Shed, trace_id, detail);
                                }
                            }
                            return Ok(Routed {
                                tier: rung.name.clone(),
                                quality: eq[orig],
                                shed,
                                pending: PendingReply { rx },
                            });
                        }
                        Err(ServeError::QueueFull) => {
                            // The admission attempt minted a trace id
                            // that will never reach a worker — close its
                            // chain so every admit has a terminal.
                            if let Some(t) = &trace {
                                t.instant(EventClass::Error, "kind=QueueFull".to_string());
                            }
                            // When the rejecting rung is the ONLY rung
                            // left there is nowhere to shed — the next
                            // stop is SloInfeasible. Wait out one short
                            // drain window and retry it once before
                            // giving up. Counter semantics: every
                            // `try_submit` attempt ticks the tier's
                            // `rejected` counter (a granted retry shows
                            // as rejected == 1 with the request still
                            // served), and a retry is never a shed —
                            // shed counts only routing *below* the best
                            // eligible rung.
                            if candidates.len() == 1 && !last_rung_retried {
                                last_rung_retried = true;
                                std::thread::sleep(LAST_RUNG_BACKOFF);
                            } else {
                                candidates.remove(index);
                            }
                        }
                        Err(e) => {
                            if let Some(t) = &trace {
                                t.instant(EventClass::Error, "kind=Admission".to_string());
                            }
                            return Err(e);
                        }
                    }
                }
            }
        }
    }

    /// Mint an admission trace context on `tier` (recording the `admit`
    /// instant with the pinned model version) when tracing is enabled —
    /// the same admission-point contract as [`super::ServeHandle`].
    fn admit_trace(&self, tier: &str, version: u64) -> Option<TraceCtx> {
        self.router.tracer().map(|tr| {
            let ctx = tr.ctx(tier);
            ctx.instant(EventClass::Admit, format!("v={version}"));
            ctx
        })
    }

    /// [`Cascade::submit`] + wait: route by SLO and block for the reply.
    pub fn infer(&self, row: &[f32], slo: &Slo) -> Result<Vec<f32>, ServeError> {
        self.submit(row, slo)?.wait()
    }

    /// Speculative mode: submit `row` to the cheapest rung (the fast
    /// answer) *and* to the best rung (the asynchronous verification).
    /// Needs a ladder of at least two tiers. The cheap leg uses blocking
    /// admission — the caller asked for an answer; the verify leg uses
    /// fail-fast admission — under overload the upgrade is revoked
    /// immediately instead of adding load, and the revocation is
    /// recorded (`speculative == upgrades + revoked` always).
    pub fn speculate(&self, row: &[f32]) -> Result<SpecReply, ServeError> {
        self.check_width(row)?;
        if self.rungs.len() < 2 {
            return Err(ServeError::BadInput(
                "speculative mode needs at least two tiers (fast + verify)".into(),
            ));
        }
        // Fast and verify legs are the worst and best rungs by
        // *effective* quality — after a swap degrades (or an upgrade
        // improves) a tier's measured quality, speculation re-picks its
        // legs accordingly.
        let order = self.effective_order();
        let fast = &self.rungs[order[order.len() - 1]];
        let best = &self.rungs[order[0]];
        // Fast leg first: if the server is draining, fail the whole call
        // before any speculative accounting opens.
        let (tx, rx) = mpsc::channel();
        let fmodel = fast.slot.current();
        let ftrace = self.admit_trace(&fast.name, fmodel.version);
        let fast_id = ftrace.as_ref().map_or(0, TraceCtx::id);
        let freq = ServeRequest {
            row: row.to_vec(),
            reply: tx,
            enqueued: Instant::now(),
            model: fmodel,
            trace: ftrace.clone(),
        };
        if let Err(e) = fast.queue.submit(freq) {
            if let Some(t) = &ftrace {
                t.instant(EventClass::Error, "kind=Admission".to_string());
            }
            return Err(e);
        }
        let first = PendingReply { rx };
        // Verify leg: every attempt is counted as speculative work, and
        // every failure path immediately closes the books as revoked.
        best.metrics.record_speculative();
        let (vtx, vrx) = mpsc::channel();
        let vmodel = best.slot.current();
        let vtrace = self.admit_trace(&best.name, vmodel.version);
        if let Some(t) = &vtrace {
            // Tie the verify leg's trace to the fast leg's id so a trace
            // viewer can pair the two halves of one speculation.
            t.instant(EventClass::Speculate, format!("fast={fast_id}"));
        }
        let vreq = ServeRequest {
            row: row.to_vec(),
            reply: vtx,
            enqueued: Instant::now(),
            model: vmodel,
            trace: vtrace.clone(),
        };
        let state = match best.queue.try_submit(vreq) {
            Ok(()) => UpgradeState::Pending(PendingReply { rx: vrx }),
            Err(e) => {
                best.metrics.record_revoked();
                if let Some(t) = &vtrace {
                    t.instant(EventClass::Revoke, "kind=QueueFull".to_string());
                }
                UpgradeState::Revoked(e)
            }
        };
        Ok(SpecReply {
            fast_tier: fast.name.clone(),
            verify_tier: best.name.clone(),
            first,
            upgrade: UpgradeHandle {
                tier: best.name.clone(),
                state,
                metrics: Arc::clone(&best.metrics),
                trace: vtrace,
            },
        })
    }
}

/// An SLO-routed in-flight request: which tier took it, at what quality,
/// and whether that was a shed (a downgrade below the best eligible
/// tier).
pub struct Routed {
    /// Name of the tier serving the request.
    pub tier: String,
    /// That tier's effective quality at routing time (measured when the
    /// rank adapter has a reading, else the static ladder score).
    pub quality: f32,
    /// Whether routing downgraded below the best eligible tier.
    pub shed: bool,
    pending: PendingReply,
}

impl Routed {
    /// Block until the request's batch completes.
    pub fn wait(self) -> Result<Vec<f32>, ServeError> {
        self.pending.wait()
    }
}

enum UpgradeState {
    /// Verification in flight.
    Pending(PendingReply),
    /// Verification will never arrive; the error says why.
    Revoked(ServeError),
    /// [`UpgradeHandle::upgraded`] already settled the books.
    Consumed,
}

/// The second phase of a speculative reply. Exactly one of three things
/// happens to it, and each is recorded on the verify tier's metrics:
///
/// - [`UpgradeHandle::upgraded`] returns [`Upgrade::Upgraded`] — counted
///   as an upgrade;
/// - [`UpgradeHandle::upgraded`] returns [`Upgrade::Revoked`] (verify
///   leg rejected, failed, or drained) — counted as revoked;
/// - the handle is dropped unconsumed — counted as revoked (the caller
///   walked away; any still-queued verify work drains normally into a
///   dead channel).
pub struct UpgradeHandle {
    tier: String,
    state: UpgradeState,
    metrics: Arc<TierMetrics>,
    /// The verify leg's trace context (when tracing was on at
    /// speculation time): the upgrade/revoke outcome is recorded on the
    /// same trace id the verify request executed under.
    trace: Option<TraceCtx>,
}

impl UpgradeHandle {
    /// The tier verification runs on.
    pub fn tier(&self) -> &str {
        &self.tier
    }

    /// Block for the verification outcome. A drained server yields
    /// [`Upgrade::Revoked`] with the shutdown/disconnect error — queued
    /// upgrades are answered by the drain, never silently dropped.
    pub fn upgraded(mut self) -> Upgrade {
        match std::mem::replace(&mut self.state, UpgradeState::Consumed) {
            UpgradeState::Pending(p) => match p.wait() {
                Ok(v) => {
                    self.metrics.record_upgrade();
                    if let Some(t) = &self.trace {
                        t.instant(EventClass::Upgrade, String::new());
                    }
                    Upgrade::Upgraded(v)
                }
                Err(e) => {
                    self.metrics.record_revoked();
                    if let Some(t) = &self.trace {
                        t.instant(EventClass::Revoke, "kind=Exec".to_string());
                    }
                    Upgrade::Revoked(e)
                }
            },
            UpgradeState::Revoked(e) => Upgrade::Revoked(e),
            UpgradeState::Consumed => unreachable!("upgraded consumes self"),
        }
    }
}

impl Drop for UpgradeHandle {
    fn drop(&mut self) {
        if matches!(self.state, UpgradeState::Pending(_)) {
            // Abandoned before the outcome: close the books as revoked.
            self.metrics.record_revoked();
            if let Some(t) = &self.trace {
                t.instant(EventClass::Revoke, "kind=Dropped".to_string());
            }
            self.state = UpgradeState::Consumed;
        }
    }
}

/// Outcome of a speculative verification.
#[derive(Debug, Clone, PartialEq)]
pub enum Upgrade {
    /// The best tier's answer, delivered.
    Upgraded(Vec<f32>),
    /// No upgrade will come; the error says why (queue full at
    /// speculation time, execution failure, or server drain).
    Revoked(ServeError),
}

/// A two-phase speculative reply: the fast tier's answer now, the best
/// tier's later.
pub struct SpecReply {
    /// Tier serving the immediate answer (cheapest rung).
    pub fast_tier: String,
    /// Tier verifying asynchronously (best rung).
    pub verify_tier: String,
    first: PendingReply,
    upgrade: UpgradeHandle,
}

impl SpecReply {
    /// Block for the fast answer; the [`UpgradeHandle`] delivers (or
    /// revokes) the verification later.
    pub fn first(self) -> (Result<Vec<f32>, ServeError>, UpgradeHandle) {
        (self.first.wait(), self.upgrade)
    }
}
