//! Request-scoped tracing for the serve stack.
//!
//! Every admitted request gets a trace id at admission and accumulates
//! structured [`Event`]s — queue wait, exec, transform, terminal
//! reply/error, plus the cascade/chaos machinery around it (sheds,
//! speculation legs, quarantine rounds, hot-swap fences, supervisor
//! restarts) — into one bounded [`EventRing`] per tier. A per-class
//! token-bucket [`ClassLimiter`] caps the record rate so a chaos storm
//! cannot flood the ring, with exact recorded/suppressed accounting.
//!
//! The contract with the hot path mirrors `FaultPlan`: when tracing is
//! off the workers hold `None` and pay a single never-taken branch;
//! replies are bitwise identical either way (tested in `tests/trace.rs`).
//!
//! **Per-answered-request span chain** (recorded at reply time so worker
//! kills, requeues, and quarantine replays cannot double-count): exactly
//! one `admit`, one `queue_wait` span, one `exec` span, and exactly one
//! terminal (`reply` xor `error`), plus one `transform` span when the
//! tier's output transform is not `Raw`.
//!
//! Exporters: [`TraceLog::export_jsonl`] (one JSON object per line) and
//! [`TraceLog::export_chrome_trace`] (a `chrome://tracing` / Perfetto
//! loadable JSON document).

use crate::util::events::{ClassLimiter, Event, EventClass, EventRing};
use crate::util::json::Json;
use crate::util::lock_ignore_poison;
use crate::util::log as plog;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Tracing knobs. Defaults keep a few thousand recent events per tier and
/// admit bursts of ~1024 per event class with a modest steady-state refill.
#[derive(Debug, Clone, Copy)]
pub struct TraceConfig {
    /// Per-tier ring capacity (oldest events are evicted past this).
    pub ring_capacity: usize,
    /// Token-bucket burst capacity per event class.
    pub bucket_capacity: u64,
    /// Token refill rate per class per second; `0.0` never refills
    /// (exactly `bucket_capacity` events per class get recorded — the
    /// deterministic setting the accounting tests use).
    pub refill_per_sec: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            ring_capacity: 4096,
            bucket_capacity: 1024,
            refill_per_sec: 256.0,
        }
    }
}

/// Per-tier trace sink: one ring + one rate limiter. Carries its own copy
/// of the tracer's start instant so recording needs no back-reference.
pub struct TierTrace {
    name: String,
    start: Instant,
    ring: EventRing,
    limiter: ClassLimiter,
}

impl TierTrace {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Record an instant event happening now.
    pub fn record_now(&self, class: EventClass, trace: u64, detail: String) {
        self.record_at(class, Instant::now(), Duration::ZERO, trace, detail);
    }

    /// Record a span that started at `at` and lasted `dur` (`ZERO` = instant).
    /// Suppressed events (rate limiter) are counted, not recorded — and the
    /// suppression also covers the warn/error log line, so the token bucket
    /// rate-limits structured logging too.
    pub fn record_at(
        &self,
        class: EventClass,
        at: Instant,
        dur: Duration,
        trace: u64,
        detail: String,
    ) {
        if !self.limiter.admit(class) {
            return;
        }
        if let Some(lv) = class.severity() {
            plog::log(
                lv,
                "panther::serve::trace",
                &format!(
                    "tier={} event={} trace={} {}",
                    self.name,
                    class.name(),
                    trace,
                    detail
                ),
            );
        }
        let t_us = at
            .checked_duration_since(self.start)
            .unwrap_or_default()
            .as_micros() as u64;
        self.ring.push(Event {
            t_us,
            dur_us: dur.as_micros() as u64,
            class,
            trace,
            detail,
        });
    }
}

/// A request's handle into its tier's trace sink: the trace id plus the
/// sink. Cloned onto both legs of a speculative pair.
#[derive(Clone)]
pub struct TraceCtx {
    id: u64,
    tier: Arc<TierTrace>,
}

impl TraceCtx {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn tier(&self) -> &Arc<TierTrace> {
        &self.tier
    }

    /// Record an instant event for this request.
    pub fn instant(&self, class: EventClass, detail: String) {
        self.tier.record_now(class, self.id, detail);
    }

    /// Record a span for this request.
    pub fn span_at(&self, class: EventClass, at: Instant, dur: Duration, detail: String) {
        self.tier.record_at(class, at, dur, self.id, detail);
    }
}

/// The tracer: allocates trace ids and owns one [`TierTrace`] per tier.
pub struct Tracer {
    start: Instant,
    next_id: AtomicU64,
    cfg: TraceConfig,
    tiers: Mutex<HashMap<String, Arc<TierTrace>>>,
}

impl Tracer {
    pub fn new(cfg: TraceConfig) -> Tracer {
        Tracer {
            start: Instant::now(),
            next_id: AtomicU64::new(1),
            cfg,
            tiers: Mutex::new(HashMap::new()),
        }
    }

    /// The per-tier sink, created on first use.
    pub fn tier(&self, name: &str) -> Arc<TierTrace> {
        let mut tiers = lock_ignore_poison(&self.tiers);
        Arc::clone(tiers.entry(name.to_string()).or_insert_with(|| {
            Arc::new(TierTrace {
                name: name.to_string(),
                start: self.start,
                ring: EventRing::new(self.cfg.ring_capacity),
                limiter: ClassLimiter::new(self.cfg.bucket_capacity, self.cfg.refill_per_sec),
            })
        }))
    }

    /// Mint a fresh trace id bound to `tier`'s sink (ids start at 1; 0 is
    /// reserved for tier-level events).
    pub fn ctx(&self, tier: &str) -> TraceCtx {
        TraceCtx {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            tier: self.tier(tier),
        }
    }

    /// Snapshot every tier's retained events and exact per-class accounting.
    pub fn log(&self) -> TraceLog {
        let tiers = lock_ignore_poison(&self.tiers);
        let mut out: Vec<TierTraceLog> = tiers
            .values()
            .map(|t| {
                let mut recorded = [0u64; EventClass::COUNT];
                let mut suppressed = [0u64; EventClass::COUNT];
                for c in EventClass::ALL {
                    recorded[c as usize] = t.limiter.recorded(c);
                    suppressed[c as usize] = t.limiter.suppressed(c);
                }
                TierTraceLog {
                    tier: t.name.clone(),
                    events: t.ring.snapshot(),
                    recorded,
                    suppressed,
                    overflow: t.ring.overflow(),
                }
            })
            .collect();
        out.sort_by(|a, b| a.tier.cmp(&b.tier));
        TraceLog { tiers: out }
    }
}

/// One tier's snapshot: retained events (oldest first) plus the exact
/// per-class recorded/suppressed counters and the ring-overflow count.
pub struct TierTraceLog {
    pub tier: String,
    pub events: Vec<Event>,
    pub recorded: [u64; EventClass::COUNT],
    pub suppressed: [u64; EventClass::COUNT],
    /// Events evicted from the ring to make room (distinct from
    /// rate-limiter suppression: these were recorded, then aged out).
    pub overflow: u64,
}

impl TierTraceLog {
    pub fn recorded(&self, class: EventClass) -> u64 {
        self.recorded[class as usize]
    }

    pub fn suppressed(&self, class: EventClass) -> u64 {
        self.suppressed[class as usize]
    }
}

/// Point-in-time export of the whole tracer (tiers sorted by name).
pub struct TraceLog {
    pub tiers: Vec<TierTraceLog>,
}

impl TraceLog {
    /// All retained events across tiers that belong to `trace` id,
    /// ordered by start time. The per-request chain-completeness tests
    /// reconstruct each reply's path from this.
    pub fn events_for(&self, trace: u64) -> Vec<(&str, &Event)> {
        let mut out: Vec<(&str, &Event)> = self
            .tiers
            .iter()
            .flat_map(|t| {
                t.events
                    .iter()
                    .filter(|e| e.trace == trace)
                    .map(|e| (t.tier.as_str(), e))
            })
            .collect();
        out.sort_by_key(|(_, e)| e.t_us);
        out
    }

    /// One JSON object per line:
    /// `{"tier":..,"class":..,"t_us":..,"dur_us":..,"trace":..,"detail":..}`.
    pub fn export_jsonl(&self) -> String {
        let mut out = String::new();
        for t in &self.tiers {
            for e in &t.events {
                let mut o = Json::obj();
                o.set("tier", t.tier.as_str())
                    .set("class", e.class.name())
                    .set("t_us", e.t_us)
                    .set("dur_us", e.dur_us)
                    .set("trace", e.trace)
                    .set("detail", e.detail.as_str());
                out.push_str(&o.to_string());
                out.push('\n');
            }
        }
        out
    }

    /// Chrome trace-event JSON (`chrome://tracing` / Perfetto). Tiers map
    /// to processes (with `process_name` metadata), trace ids to threads;
    /// spans are `ph:"X"` complete events, instants are `ph:"i"`.
    pub fn export_chrome_trace(&self) -> String {
        let mut events: Vec<Json> = Vec::new();
        for (pid, t) in self.tiers.iter().enumerate() {
            let mut meta = Json::obj();
            let mut args = Json::obj();
            args.set("name", t.tier.as_str());
            meta.set("ph", "M")
                .set("name", "process_name")
                .set("pid", pid)
                .set("args", args);
            events.push(meta);
            for e in &t.events {
                let mut o = Json::obj();
                let mut args = Json::obj();
                args.set("detail", e.detail.as_str());
                o.set("name", e.class.name())
                    .set("cat", "serve")
                    .set("pid", pid)
                    .set("tid", e.trace)
                    .set("ts", e.t_us)
                    .set("args", args);
                if e.dur_us > 0 {
                    o.set("ph", "X").set("dur", e.dur_us);
                } else {
                    o.set("ph", "i").set("s", "t");
                }
                events.push(o);
            }
        }
        let mut doc = Json::obj();
        doc.set("traceEvents", Json::Arr(events));
        doc.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_start_at_one_and_increment() {
        let tr = Tracer::new(TraceConfig::default());
        let a = tr.ctx("t");
        let b = tr.ctx("t");
        assert_eq!(a.id(), 1);
        assert_eq!(b.id(), 2);
        // Same tier name → same sink.
        assert!(Arc::ptr_eq(a.tier(), b.tier()));
    }

    #[test]
    fn jsonl_round_trips() {
        let tr = Tracer::new(TraceConfig::default());
        let c = tr.ctx("t");
        c.instant(EventClass::Admit, "v=0".to_string());
        c.span_at(
            EventClass::Exec,
            Instant::now(),
            Duration::from_micros(250),
            String::new(),
        );
        let log = tr.log();
        let jsonl = log.export_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in &lines {
            let v = Json::parse(line).expect("jsonl line parses");
            assert_eq!(v.get("tier").and_then(Json::as_str), Some("t"));
            assert_eq!(v.get("trace").and_then(Json::as_f64), Some(1.0));
        }
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("class").and_then(Json::as_str), Some("admit"));
        assert_eq!(first.get("detail").and_then(Json::as_str), Some("v=0"));
    }

    #[test]
    fn chrome_trace_shape() {
        let tr = Tracer::new(TraceConfig::default());
        let c = tr.ctx("fast");
        c.instant(EventClass::Admit, String::new());
        c.span_at(
            EventClass::Exec,
            Instant::now(),
            Duration::from_micros(100),
            String::new(),
        );
        tr.tier("slow")
            .record_now(EventClass::Restart, 0, "worker=1".to_string());
        let doc = Json::parse(&tr.log().export_chrome_trace()).expect("chrome trace parses");
        let evs = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .expect("traceEvents array");
        // 2 process_name metadata + 3 events.
        assert_eq!(evs.len(), 5);
        let metas: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("M"))
            .collect();
        assert_eq!(metas.len(), 2);
        let spans: Vec<_> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
            .collect();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].get("dur").and_then(Json::as_f64), Some(100.0));
        assert_eq!(spans[0].get("name").and_then(Json::as_str), Some("exec"));
    }

    #[test]
    fn limiter_suppression_is_accounted() {
        let tr = Tracer::new(TraceConfig {
            ring_capacity: 64,
            bucket_capacity: 3,
            refill_per_sec: 0.0,
        });
        let sink = tr.tier("t");
        for i in 0..10 {
            sink.record_now(EventClass::Fault, 0, format!("n={i}"));
        }
        let log = tr.log();
        let t = &log.tiers[0];
        assert_eq!(t.recorded(EventClass::Fault), 3);
        assert_eq!(t.suppressed(EventClass::Fault), 7);
        assert_eq!(t.events.len(), 3);
        assert_eq!(t.overflow, 0);
    }

    #[test]
    fn ring_overflow_is_separate_from_suppression() {
        let tr = Tracer::new(TraceConfig {
            ring_capacity: 2,
            bucket_capacity: 1024,
            refill_per_sec: 0.0,
        });
        let sink = tr.tier("t");
        for i in 0..5 {
            sink.record_now(EventClass::Reply, i, String::new());
        }
        let log = tr.log();
        let t = &log.tiers[0];
        assert_eq!(t.recorded(EventClass::Reply), 5);
        assert_eq!(t.suppressed(EventClass::Reply), 0);
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.overflow, 3);
        // The retained events are the newest ones.
        assert_eq!(t.events[0].trace, 3);
        assert_eq!(t.events[1].trace, 4);
    }
}
