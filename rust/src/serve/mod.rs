//! `panther::serve` — native model serving: generic dynamic batching over
//! any [`Model`], tiered dense/sketched routing, bounded-queue
//! backpressure, and graceful drain.
//!
//! The [`crate::coordinator`] serves exactly one workload (MLM scoring
//! through the fixed-shape artifact runtime); this subsystem serves *any*
//! native layer stack behind the [`crate::nn::Module`] API — which is
//! where the paper's "drop-in compressed layers" claim meets production:
//! a `SketchPlan`-compressed model is registered as a cheaper **tier** of
//! the same service, and its smaller footprint (the paper's memory
//! saving) becomes admission-controlled serving *capacity*.
//!
//! ## Shape
//!
//! - [`ModelServer`] owns one or more checkpoint-loadable [`Model`]s, each
//!   registered as a named tier ([`ModelServer::register_tier`] /
//!   [`ModelServer::register_tier_from_checkpoint`]).
//! - Each tier runs a bounded request queue (backpressure: blocking
//!   [`ServeHandle::infer`] or fail-fast [`ServeHandle::try_infer`]) and a
//!   pool of inference workers, each with its own warm
//!   [`crate::nn::ForwardCtx`]/`Workspace` arena; the GEMM tiles of every
//!   worker land on the process-wide kernel thread pool.
//! - Workers coalesce single-row requests into **padded row-stacked
//!   batches** (max-batch/max-wait policy) and run one `Model::forward`
//!   per batch. Every batch is padded to exactly `max_batch` rows, which
//!   pins the GEMM kernel path: a request's result is bit-identical
//!   across arrival orders and batch compositions, and registration
//!   *probes* (bitwise) that co-rows and padding never leak into a live
//!   row — see [`router`] for the exact guarantees.
//! - [`Metrics`] tracks queue depth, a batch-occupancy histogram, and
//!   p50/p99 end-to-end latency per tier, reusing the
//!   [`crate::util::stats`] shapes the coordinator's batcher records.
//! - [`ModelServer::shutdown`] drains: admissions stop with a typed
//!   error, queued requests still get answers, workers exit, threads
//!   join. Dropping the server does the same.
//!
//! ```
//! use panther::linalg::Mat;
//! use panther::nn::{Linear, Model};
//! use panther::rng::Philox;
//! use panther::serve::{ModelServer, TierConfig};
//!
//! # fn main() -> panther::Result<()> {
//! let mut rng = Philox::seeded(0);
//! let mut model = Model::new();
//! model.add("fc", Linear::random(16, 4, &mut rng))?;
//! let mut server = ModelServer::new();
//! server.register_tier("dense", model, 16, TierConfig::default())?;
//! let y = server.handle().infer("dense", &[0.1; 16])?;
//! assert_eq!(y.len(), 4);
//! server.shutdown();
//! # Ok(()) }
//! ```

pub mod batcher;
pub mod metrics;
pub mod router;

pub use metrics::{Metrics, TierMetrics};

use crate::nn::Model;
use batcher::{worker_loop, ServeRequest, TierQueue};
use router::{probe_model, Router, Tier};
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Typed serving errors — admission control ([`ServeError::QueueFull`],
/// [`ServeError::ShuttingDown`]) must be distinguishable from execution
/// failures, so this is an enum rather than an `anyhow` blob. Converts
/// into [`anyhow::Error`] via `std::error::Error` for callers on the
/// crate-wide [`crate::Result`].
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// No tier registered under this name.
    UnknownTier(String),
    /// A tier with this name already exists.
    DuplicateTier(String),
    /// The request row does not match the tier's input width.
    BadInput(String),
    /// Admission control: the tier's bounded queue is at capacity
    /// (non-blocking path only — blocking submits wait instead).
    QueueFull,
    /// The server is draining; no new requests are admitted.
    ShuttingDown,
    /// The reply channel died before an answer arrived (a worker panic).
    Disconnected,
    /// The model's forward failed while executing the batch.
    Exec(String),
    /// Registration probe: the model couples batch rows (attention-style
    /// layers), so row-batched serving would corrupt results.
    RowCoupled(String),
    /// Registration probe failed to run the model.
    Probe(String),
    /// A tier worker thread could not be spawned (registration is rolled
    /// back — no partial tier is left behind).
    Spawn(String),
    /// The tier's memory budget cannot fit the model plus at least one
    /// worker's batch footprint.
    Budget(String),
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownTier(t) => write!(f, "no tier named {t:?}"),
            ServeError::DuplicateTier(t) => write!(f, "tier {t:?} already registered"),
            ServeError::BadInput(m) => write!(f, "bad request: {m}"),
            ServeError::QueueFull => write!(f, "tier queue full (admission rejected)"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::Disconnected => write!(f, "reply channel disconnected"),
            ServeError::Exec(m) => write!(f, "batch execution failed: {m}"),
            ServeError::RowCoupled(m) => write!(f, "model not row-batchable: {m}"),
            ServeError::Probe(m) => write!(f, "registration probe failed: {m}"),
            ServeError::Spawn(m) => write!(f, "spawning tier worker failed: {m}"),
            ServeError::Budget(m) => write!(f, "memory budget too small: {m}"),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-tier serving policy.
#[derive(Debug, Clone)]
pub struct TierConfig {
    /// Batch cap — every executed batch is padded to exactly this many
    /// rows (the fixed-shape policy that makes results composition
    /// invariant). Caps below 8 (the GEMM microkernel height) additionally
    /// keep row results bit-identical to unbatched single-row forwards for
    /// row-wise stacks; [`TierInfo::bit_identical_to_unbatched`] records
    /// what the probe measured.
    pub max_batch: usize,
    /// How long a worker waits for co-riders after the first request of a
    /// batch arrives.
    pub max_wait: Duration,
    /// Bounded queue length — the backpressure boundary.
    pub queue_cap: usize,
    /// Requested worker threads (may be reduced by `mem_budget`).
    pub workers: usize,
    /// Optional tier memory budget in bytes: weights + per-worker
    /// peak-batch activations must fit, and the admitted worker count is
    /// `(budget − weights) / peak_batch_bytes`, capped at `workers`. This
    /// is where a sketched tier's smaller footprint turns into capacity.
    pub mem_budget: Option<u64>,
    /// Bound attention head-state memory: forwarded to
    /// [`crate::nn::Module::set_head_group`] on every layer before the
    /// probe, so the measured per-batch footprint (and therefore the
    /// budget admission) reflects it.
    pub head_group: Option<usize>,
}

impl Default for TierConfig {
    fn default() -> Self {
        TierConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 256,
            workers: 2,
            mem_budget: None,
            head_group: None,
        }
    }
}

/// What registration admitted and measured for a tier.
#[derive(Debug, Clone)]
pub struct TierInfo {
    pub name: String,
    /// Request row width.
    pub in_dim: usize,
    /// Result row width.
    pub out_dim: usize,
    /// Batch cap (every batch executes padded to this).
    pub max_batch: usize,
    /// Admitted worker threads (≤ the requested count under a budget).
    pub workers: usize,
    /// Stored parameter bytes of the tier's model.
    pub weight_bytes: u64,
    /// Probe-measured peak activation bytes of one padded batch.
    pub peak_batch_bytes: u64,
    /// Whether the cap-padded forward reproduced the unbatched single-row
    /// forward bit-for-bit in the probe (see [`router`] docs).
    pub bit_identical_to_unbatched: bool,
}

/// The serving front end: tier registry + worker pools + metrics.
pub struct ModelServer {
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    closed: bool,
}

impl Default for ModelServer {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelServer {
    pub fn new() -> Self {
        ModelServer {
            router: Arc::new(Router::default()),
            metrics: Arc::new(Metrics::default()),
            workers: Vec::new(),
            closed: false,
        }
    }

    /// Register `model` as tier `name`, serving rows of width `in_dim`.
    /// Runs the registration probe (row independence, footprint), applies
    /// the memory-budget admission, and spawns the tier's workers. The
    /// model is shared read-only by all of them.
    pub fn register_tier(
        &mut self,
        name: &str,
        mut model: Model,
        in_dim: usize,
        cfg: TierConfig,
    ) -> Result<TierInfo, ServeError> {
        if self.closed {
            return Err(ServeError::ShuttingDown);
        }
        if in_dim == 0 || cfg.max_batch == 0 || cfg.queue_cap == 0 || cfg.workers == 0 {
            return Err(ServeError::BadInput(
                "in_dim, max_batch, queue_cap and workers must be positive".into(),
            ));
        }
        // Duplicate check up front (registration holds &mut self, so this
        // cannot race): nothing below runs for a name that would collide.
        if self.router.get(name).is_ok() {
            return Err(ServeError::DuplicateTier(name.to_string()));
        }
        if let Some(g) = cfg.head_group {
            model.set_head_group(g);
        }
        let probe = probe_model(&model, in_dim, cfg.max_batch)?;
        let weight_bytes = (model.total_params() * 4) as u64;
        let workers = match cfg.mem_budget {
            None => cfg.workers,
            Some(budget) => {
                let avail = budget.checked_sub(weight_bytes).unwrap_or(0);
                let fit = (avail / probe.peak_batch_bytes.max(1)) as usize;
                let admitted = fit.min(cfg.workers);
                if admitted == 0 {
                    return Err(ServeError::Budget(format!(
                        "budget {budget} B < {weight_bytes} B weights + \
                         {} B per-batch activations",
                        probe.peak_batch_bytes
                    )));
                }
                admitted
            }
        };
        let info = TierInfo {
            name: name.to_string(),
            in_dim,
            out_dim: probe.out_dim,
            max_batch: cfg.max_batch,
            workers,
            weight_bytes,
            peak_batch_bytes: probe.peak_batch_bytes,
            bit_identical_to_unbatched: probe.bit_identical_to_unbatched,
        };
        let tier_metrics = self.metrics.tier_entry(name);
        let queue = Arc::new(TierQueue::new(cfg.queue_cap, Arc::clone(&tier_metrics)));
        // Spawn the full worker pool BEFORE the tier becomes routable: a
        // mid-pool spawn failure must not leave a live worker-less tier
        // whose queue would admit requests nobody drains. On failure the
        // (unreachable) queue is closed, already-spawned workers drain out
        // and join, and no tier is registered.
        let model = Arc::new(model);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (m, q, tm) = (Arc::clone(&model), Arc::clone(&queue), Arc::clone(&tier_metrics));
            let (cap, wait) = (cfg.max_batch, cfg.max_wait);
            let spawned = std::thread::Builder::new()
                .name(format!("panther-serve-{name}-{i}"))
                .spawn(move || worker_loop(m, q, cap, wait, in_dim, tm));
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    queue.close();
                    for h in handles {
                        let _ = h.join();
                    }
                    self.metrics.remove_tier(name);
                    return Err(ServeError::Spawn(e.to_string()));
                }
            }
        }
        let inserted = self.router.insert(
            name,
            Tier {
                queue: Arc::clone(&queue),
                info: info.clone(),
            },
        );
        if let Err(e) = inserted {
            // Unreachable given the up-front duplicate check, but never
            // leak a spawned pool: close, join, report.
            queue.close();
            for h in handles {
                let _ = h.join();
            }
            self.metrics.remove_tier(name);
            return Err(e);
        }
        self.workers.extend(handles);
        Ok(info)
    }

    /// [`ModelServer::register_tier`] with weights restored from a
    /// checkpoint (v1 or v2): `arch` provides the architecture, the
    /// checkpoint the parameters — the same contract as
    /// [`Model::load_state_dict`].
    pub fn register_tier_from_checkpoint(
        &mut self,
        name: &str,
        mut arch: Model,
        in_dim: usize,
        path: impl AsRef<Path>,
        cfg: TierConfig,
    ) -> crate::Result<TierInfo> {
        let state = crate::train::checkpoint::load(path)?;
        arch.load_state_dict(&state.state_dict())?;
        Ok(self.register_tier(name, arch, in_dim, cfg)?)
    }

    /// Cloneable client handle.
    pub fn handle(&self) -> ServeHandle {
        ServeHandle {
            router: Arc::clone(&self.router),
        }
    }

    /// The server-wide metrics registry.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Registered tier names, sorted.
    pub fn tiers(&self) -> Vec<String> {
        self.router.names()
    }

    /// What registration admitted for `name`.
    pub fn tier_info(&self, name: &str) -> Option<TierInfo> {
        self.router.get(name).ok().map(|t| t.info.clone())
    }

    /// Graceful drain: stop admissions (subsequent submits get
    /// [`ServeError::ShuttingDown`]), answer everything already queued,
    /// then join every worker thread. Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.closed = true;
        self.router.close_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Cloneable client handle: route a request to a tier, blocking
/// ([`ServeHandle::infer`]), fail-fast ([`ServeHandle::try_infer`]), or
/// asynchronously ([`ServeHandle::submit`] + [`PendingReply::wait`]).
#[derive(Clone)]
pub struct ServeHandle {
    router: Arc<Router>,
}

impl ServeHandle {
    #[allow(clippy::type_complexity)]
    fn request(
        &self,
        tier: &str,
        row: &[f32],
    ) -> Result<(Arc<Tier>, ServeRequest, PendingReply), ServeError> {
        let t = self.router.get(tier)?;
        if row.len() != t.info.in_dim {
            return Err(ServeError::BadInput(format!(
                "tier {tier:?} serves rows of width {}, got {}",
                t.info.in_dim,
                row.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        let req = ServeRequest {
            row: row.to_vec(),
            reply: tx,
            enqueued: Instant::now(),
        };
        Ok((t, req, PendingReply { rx }))
    }

    /// Enqueue a request, blocking while the tier queue is full. The
    /// reply arrives when the batch it joins completes.
    pub fn submit(&self, tier: &str, row: &[f32]) -> Result<PendingReply, ServeError> {
        let (t, req, pending) = self.request(tier, row)?;
        t.queue.submit(req)?;
        Ok(pending)
    }

    /// [`ServeHandle::submit`] without blocking: a full queue is an
    /// immediate [`ServeError::QueueFull`].
    pub fn try_submit(&self, tier: &str, row: &[f32]) -> Result<PendingReply, ServeError> {
        let (t, req, pending) = self.request(tier, row)?;
        t.queue.try_submit(req)?;
        Ok(pending)
    }

    /// Score one row (blocks until its batch completes).
    pub fn infer(&self, tier: &str, row: &[f32]) -> Result<Vec<f32>, ServeError> {
        self.submit(tier, row)?.wait()
    }

    /// [`ServeHandle::infer`] with fail-fast admission.
    pub fn try_infer(&self, tier: &str, row: &[f32]) -> Result<Vec<f32>, ServeError> {
        self.try_submit(tier, row)?.wait()
    }
}

/// An in-flight request; [`PendingReply::wait`] blocks for the result.
pub struct PendingReply {
    rx: mpsc::Receiver<Result<Vec<f32>, ServeError>>,
}

impl PendingReply {
    /// Block until the request's batch completes.
    pub fn wait(self) -> Result<Vec<f32>, ServeError> {
        self.rx.recv().map_err(|_| ServeError::Disconnected)?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Linear;
    use crate::rng::Philox;

    fn mlp(seed: u64) -> Model {
        let mut rng = Philox::seeded(seed);
        let mut m = Model::new();
        m.add("fc1", Linear::random(8, 16, &mut rng)).unwrap();
        m.add("fc2", Linear::random(16, 4, &mut rng)).unwrap();
        m
    }

    #[test]
    fn register_serve_shutdown_roundtrip() {
        let mut server = ModelServer::new();
        let info = server
            .register_tier("dense", mlp(1), 8, TierConfig::default())
            .unwrap();
        assert_eq!(info.out_dim, 4);
        assert_eq!(info.workers, 2);
        assert!(info.bit_identical_to_unbatched);
        assert_eq!(server.tiers(), vec!["dense"]);
        let h = server.handle();
        let y = h.infer("dense", &[0.5; 8]).unwrap();
        assert_eq!(y.len(), 4);
        // Unknown tier and wrong width are typed errors.
        assert!(matches!(
            h.infer("nope", &[0.0; 8]),
            Err(ServeError::UnknownTier(_))
        ));
        assert!(matches!(
            h.infer("dense", &[0.0; 3]),
            Err(ServeError::BadInput(_))
        ));
        server.shutdown();
        assert_eq!(h.infer("dense", &[0.5; 8]), Err(ServeError::ShuttingDown));
        // Idempotent.
        server.shutdown();
    }

    #[test]
    fn duplicate_and_degenerate_registrations_rejected() {
        let mut server = ModelServer::new();
        server
            .register_tier("t", mlp(2), 8, TierConfig::default())
            .unwrap();
        assert!(matches!(
            server.register_tier("t", mlp(2), 8, TierConfig::default()),
            Err(ServeError::DuplicateTier(_))
        ));
        let bad = TierConfig {
            workers: 0,
            ..TierConfig::default()
        };
        assert!(matches!(
            server.register_tier("z", mlp(2), 8, bad),
            Err(ServeError::BadInput(_))
        ));
    }

    #[test]
    fn memory_budget_admits_fewer_workers_or_rejects() {
        let mut server = ModelServer::new();
        // Probe first to learn the real numbers, then budget exactly one
        // worker's worth.
        let free = server
            .register_tier("probe", mlp(3), 8, TierConfig::default())
            .unwrap();
        let one_worker = free.weight_bytes + free.peak_batch_bytes;
        let cfg = TierConfig {
            workers: 4,
            mem_budget: Some(one_worker),
            ..TierConfig::default()
        };
        let info = server.register_tier("budgeted", mlp(3), 8, cfg).unwrap();
        assert_eq!(info.workers, 1, "budget fits exactly one worker");
        // A budget smaller than the weights alone is a clean error.
        let cfg = TierConfig {
            mem_budget: Some(free.weight_bytes / 2),
            ..TierConfig::default()
        };
        assert!(matches!(
            server.register_tier("tiny", mlp(3), 8, cfg),
            Err(ServeError::Budget(_))
        ));
    }

    #[test]
    fn checkpoint_registration_serves_trained_weights() {
        use crate::train::{Adam, Trainer};
        let mut model = mlp(4);
        let mut rng = Philox::seeded(5);
        let x = crate::linalg::Mat::randn(16, 8, &mut rng);
        let target = crate::linalg::Mat::randn(16, 4, &mut rng);
        let ctx = crate::nn::ForwardCtx::new();
        let mut tr = Trainer::new(Box::new(Adam::new(0.01)));
        for _ in 0..3 {
            tr.train_step(&mut model, &x, &target, &ctx).unwrap();
        }
        let dir = std::env::temp_dir().join("panther_serve_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tier.ckpt");
        tr.save_checkpoint(&model, "mlp", &path).unwrap();

        let mut server = ModelServer::new();
        server
            .register_tier_from_checkpoint("dense", mlp(999), 8, &path, TierConfig::default())
            .unwrap();
        let row: Vec<f32> = x.row(0).to_vec();
        let got = server.handle().infer("dense", &row).unwrap();
        // The served result is the trained model's single-row forward.
        let want = model.forward(&crate::linalg::Mat::from_vec(1, 8, row), &ctx).unwrap();
        assert_eq!(got.as_slice(), want.row(0));
        std::fs::remove_file(&path).ok();
    }
}
