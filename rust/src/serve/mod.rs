//! `panther::serve` — native model serving: generic dynamic batching over
//! any [`Model`], tiered dense/sketched routing, bounded-queue
//! backpressure, and graceful drain.
//!
//! The [`crate::coordinator`] serves exactly one workload (MLM scoring
//! through the fixed-shape artifact runtime); this subsystem serves *any*
//! native layer stack behind the [`crate::nn::Module`] API — which is
//! where the paper's "drop-in compressed layers" claim meets production:
//! a `SketchPlan`-compressed model is registered as a cheaper **tier** of
//! the same service, and its smaller footprint (the paper's memory
//! saving) becomes admission-controlled serving *capacity*.
//!
//! ## Shape
//!
//! - [`ModelServer`] owns one or more checkpoint-loadable [`Model`]s, each
//!   registered as a named tier ([`ModelServer::register_tier`] /
//!   [`ModelServer::register_tier_from_checkpoint`]).
//! - Each tier runs a bounded request queue (backpressure: blocking
//!   [`ServeHandle::infer`] or fail-fast [`ServeHandle::try_infer`]) and a
//!   pool of inference workers, each with its own warm
//!   [`crate::nn::ForwardCtx`]/`Workspace` arena; the GEMM tiles of every
//!   worker land on the process-wide kernel thread pool.
//! - Workers coalesce single-row requests into **padded row-stacked
//!   batches** (max-batch/max-wait policy) and run one `Model::forward`
//!   per batch. Every batch is padded to exactly `max_batch` rows, which
//!   pins the GEMM kernel path: a request's result is bit-identical
//!   across arrival orders and batch compositions, and registration
//!   *probes* (bitwise) that co-rows and padding never leak into a live
//!   row — see [`router`] for the exact guarantees.
//! - **Sequence tiers** ([`ModelServer::register_seq_tier`]) serve whole
//!   variable-length sequences through attention-bearing stacks that row
//!   tiers must reject as `RowCoupled`. Workers run a **continuous
//!   batcher**: each step packs queued sequences FIFO into one
//!   [`crate::nn::SeqBatch`]-masked forward while their summed lengths
//!   fit the tier's token budget — sequences are admitted and retired at
//!   every step boundary, never held for stragglers. A memory budget on
//!   a sequence tier buys *length*, not workers: registration probes
//!   peak activations at two lengths, fits `α·n² + β·n`
//!   ([`crate::nn::cost::max_len_under_budget`]), and advertises the
//!   largest admitted sequence as [`SeqTierInfo::max_seq_len`] — where a
//!   Performer tier (α ≈ 0) admits strictly longer sequences than a
//!   dense-attention tier (α > 0) under the same budget.
//! - Tiers can decode server-side before replying
//!   ([`OutputTransform`]): row-wise softmax, or a top-k `(index,
//!   logprob)` shortlist that shrinks vocab-wide logit rows to `2·k`
//!   floats.
//! - [`Metrics`] tracks queue depth, a batch-occupancy histogram, and
//!   p50/p99 end-to-end latency per tier (cumulative *and* sliding
//!   window), reusing the [`crate::util::stats`] shapes the
//!   coordinator's batcher records; [`Metrics::snapshot`] freezes the
//!   whole registry into one JSON-serializable shape.
//! - A [`Cascade`] overlays SLO routing on the registered row tiers:
//!   requests carry an [`Slo`] (deadline + quality floor), the
//!   [`slo`] estimator predicts each tier's completion time from the
//!   live sensors, overload on the dense tier *sheds* to the sketched
//!   tier as a counted quality downgrade, and a speculative mode
//!   answers from the cheap tier immediately while the dense tier
//!   verifies asynchronously through a two-phase
//!   [`SpecReply::first`] / [`UpgradeHandle::upgraded`] reply.
//! - **Fault tolerance**: each tier's worker pool runs under a
//!   self-healing supervisor — a panicked worker is respawned with the
//!   same warm setup under capped exponential backoff, `worker_restarts`
//!   and the `live_workers` gauge record it, and a degraded pool
//!   advertises its real capacity to SLO routing. A seeded per-tier
//!   [`FaultPlan`] (chaos testing) injects worker kills, mid-batch
//!   panics, exec delays, and NaN output poisoning reproducibly.
//!   Opt-in **poison-input quarantine** bisects a panicking batch to
//!   isolate the culprit request ([`ServeError::PoisonedInput`]) while
//!   replaying its innocent batch-mates, and an opt-in **numeric guard**
//!   converts NaN/Inf output rows into typed
//!   [`ServeError::NonFiniteOutput`] replies that degrade the tier's
//!   measured-quality gauge.
//! - [`ModelServer::shutdown`] drains: admissions stop with a typed
//!   error, queued requests still get answers, workers exit, threads
//!   join. Dropping the server does the same.
//!
//! ```
//! use panther::linalg::Mat;
//! use panther::nn::{Linear, Model};
//! use panther::rng::Philox;
//! use panther::serve::{ModelServer, TierConfig};
//!
//! # fn main() -> panther::Result<()> {
//! let mut rng = Philox::seeded(0);
//! let mut model = Model::new();
//! model.add("fc", Linear::random(16, 4, &mut rng))?;
//! let mut server = ModelServer::new();
//! server.register_tier("dense", model, 16, TierConfig::default())?;
//! let y = server.handle().infer("dense", &[0.1; 16])?;
//! assert_eq!(y.len(), 4);
//! server.shutdown();
//! # Ok(()) }
//! ```

pub mod adapt;
pub mod batcher;
pub mod cascade;
pub mod fault;
pub mod metrics;
pub mod router;
pub mod slo;
pub mod trace;
pub mod transform;

pub use adapt::{AdaptConfig, AdaptDaemon, AdaptDecision, HoldReason, QualityReading, RankAdapter};
pub use cascade::{Cascade, Routed, SpecReply, Upgrade, UpgradeHandle};
pub use fault::{BatchFaults, FaultPlan};
pub use metrics::{Metrics, MetricsSnapshot, TierMetrics, TierSnapshot};
pub use slo::{predict_latency, Decision, Slo, TierLoad};
pub use trace::{TierTrace, TierTraceLog, TraceConfig, TraceCtx, TraceLog, Tracer};
pub use transform::OutputTransform;

use crate::linalg::Mat;
use crate::nn::Model;
use crate::util::events::EventClass;
use batcher::{ModelSlot, RowWorker, SeqServeRequest, SeqWorker, ServeRequest, TierQueue};
use router::{probe_model, probe_seq_model, Router, Tier};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// Typed serving errors — admission control ([`ServeError::QueueFull`],
/// [`ServeError::ShuttingDown`]) must be distinguishable from execution
/// failures, so this is an enum rather than an `anyhow` blob. Converts
/// into [`anyhow::Error`] via `std::error::Error` for callers on the
/// crate-wide [`crate::Result`].
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// No tier registered under this name (and no default tier took the
    /// request). Carries the registered names so the message says what
    /// *would* have routed.
    UnknownTier {
        name: String,
        registered: Vec<String>,
    },
    /// A tier with this name already exists.
    DuplicateTier(String),
    /// The request row does not match the tier's input width.
    BadInput(String),
    /// Admission control: the tier's bounded queue is at capacity
    /// (non-blocking path only — blocking submits wait instead).
    QueueFull,
    /// The server is draining; no new requests are admitted.
    ShuttingDown,
    /// The reply channel died before an answer arrived (a worker panic).
    Disconnected,
    /// The model's forward failed while executing the batch.
    Exec(String),
    /// Quarantine isolated this request as the one whose presence makes
    /// batch execution panic: its solo execution panicked
    /// [`TierConfig::quarantine_strikes`] times. Its batch-mates were
    /// replayed to normal replies.
    PoisonedInput,
    /// The tier's [`TierConfig::numeric_guard`] found NaN/Inf in this
    /// request's output row(s); the reply is withheld rather than
    /// shipping non-finite values downstream.
    NonFiniteOutput,
    /// Registration probe: the model couples batch rows (attention-style
    /// layers), so row-batched serving would corrupt results.
    RowCoupled(String),
    /// Registration probe failed to run the model.
    Probe(String),
    /// A tier worker thread could not be spawned (registration is rolled
    /// back — no partial tier is left behind).
    Spawn(String),
    /// The tier's memory budget cannot fit the model plus at least one
    /// worker's batch footprint.
    Budget(String),
    /// A sequence request exceeds the tier's admitted maximum length
    /// (the memory-fit/token-budget cap in
    /// [`SeqTierInfo::max_seq_len`]).
    SeqTooLong { len: usize, max: usize },
    /// SLO admission: no eligible tier's predicted completion time meets
    /// the request's deadline — not even after shedding to the cheapest
    /// tier. Carries the best prediction the estimator saw, so callers
    /// can tell a hopeless deadline from a transient overload.
    SloInfeasible {
        deadline: Duration,
        best_predicted: Duration,
    },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::UnknownTier { name, registered } => {
                if registered.is_empty() {
                    write!(f, "no tier named {name:?} (no tiers registered)")
                } else {
                    write!(
                        f,
                        "no tier named {name:?} (registered tiers: {})",
                        registered.join(", ")
                    )
                }
            }
            ServeError::DuplicateTier(t) => write!(f, "tier {t:?} already registered"),
            ServeError::BadInput(m) => write!(f, "bad request: {m}"),
            ServeError::QueueFull => write!(f, "tier queue full (admission rejected)"),
            ServeError::ShuttingDown => write!(f, "server is shutting down"),
            ServeError::Disconnected => write!(f, "reply channel disconnected"),
            ServeError::Exec(m) => write!(f, "batch execution failed: {m}"),
            ServeError::PoisonedInput => write!(
                f,
                "request quarantined: its presence makes batch execution panic"
            ),
            ServeError::NonFiniteOutput => {
                write!(f, "model produced non-finite output for this request")
            }
            ServeError::RowCoupled(m) => write!(f, "model not row-batchable: {m}"),
            ServeError::Probe(m) => write!(f, "registration probe failed: {m}"),
            ServeError::Spawn(m) => write!(f, "spawning tier worker failed: {m}"),
            ServeError::Budget(m) => write!(f, "memory budget too small: {m}"),
            ServeError::SeqTooLong { len, max } => write!(
                f,
                "sequence of {len} tokens exceeds the tier's admitted \
                 maximum of {max}"
            ),
            ServeError::SloInfeasible {
                deadline,
                best_predicted,
            } => write!(
                f,
                "no tier can meet the {deadline:?} deadline (best \
                 predicted completion {best_predicted:?})"
            ),
        }
    }
}

impl std::error::Error for ServeError {}

/// Per-tier serving policy.
#[derive(Debug, Clone)]
pub struct TierConfig {
    /// Batch cap — every executed batch is padded to exactly this many
    /// rows (the fixed-shape policy that makes results composition
    /// invariant). Caps below 8 (the GEMM microkernel height) additionally
    /// keep row results bit-identical to unbatched single-row forwards for
    /// row-wise stacks; [`TierInfo::bit_identical_to_unbatched`] records
    /// what the probe measured.
    pub max_batch: usize,
    /// How long a worker waits for co-riders after the first request of a
    /// batch arrives.
    pub max_wait: Duration,
    /// Bounded queue length — the backpressure boundary.
    pub queue_cap: usize,
    /// Requested worker threads (may be reduced by `mem_budget`).
    pub workers: usize,
    /// Optional tier memory budget in bytes: weights + per-worker
    /// peak-batch activations must fit, and the admitted worker count is
    /// `(budget − weights) / peak_batch_bytes`, capped at `workers`. This
    /// is where a sketched tier's smaller footprint turns into capacity.
    pub mem_budget: Option<u64>,
    /// Bound attention head-state memory: forwarded to
    /// [`crate::nn::Module::set_head_group`] on every layer before the
    /// probe, so the measured per-batch footprint (and therefore the
    /// budget admission) reflects it.
    pub head_group: Option<usize>,
    /// Server-side decode applied to each result row before it is
    /// replied (see [`OutputTransform`]); `Raw` is a zero-copy no-op.
    pub transform: OutputTransform,
    /// Seeded fault-injection plan for chaos testing ([`FaultPlan`]).
    /// `None` — the default — costs the workers one branch per batch.
    pub faults: Option<Arc<FaultPlan>>,
    /// Poison-input quarantine: when a batch's forward *panics*, retry
    /// its requests in bisected sub-batches and answer the request whose
    /// solo execution panics this many times with
    /// [`ServeError::PoisonedInput`], replaying its innocent batch-mates.
    /// `0` (the default) disables quarantine — a panic fails the whole
    /// batch with [`ServeError::Exec`], the pre-quarantine behavior.
    pub quarantine_strikes: u32,
    /// Scan each batch's output rows for NaN/Inf and answer affected
    /// requests with [`ServeError::NonFiniteOutput`] instead of shipping
    /// garbage; bad rows are counted (`nonfinite_rows`) and degrade the
    /// tier's measured-quality gauge so a cascade routes around it.
    pub numeric_guard: bool,
}

impl Default for TierConfig {
    fn default() -> Self {
        TierConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
            queue_cap: 256,
            workers: 2,
            mem_budget: None,
            head_group: None,
            transform: OutputTransform::Raw,
            faults: None,
            quarantine_strikes: 0,
            numeric_guard: false,
        }
    }
}

/// Per-tier policy for **sequence** serving: whole variable-length
/// sequences in, one result row per token out, packed per step by the
/// continuous batcher.
#[derive(Debug, Clone)]
pub struct SeqTierConfig {
    /// Per-step packed token budget: each batcher step admits queued
    /// sequences FIFO while their summed lengths fit this many rows.
    /// Also an upper bound on the admitted per-sequence length.
    pub max_tokens: usize,
    /// How long a step waits for co-sequences after its first admit.
    pub max_wait: Duration,
    /// Bounded queue length (in sequences) — the backpressure boundary.
    pub queue_cap: usize,
    /// Worker threads, each running its own continuous-batching loop.
    pub workers: usize,
    /// Optional tier memory budget in bytes. For sequence tiers the
    /// budget buys *length*, not worker count: registration probes peak
    /// activation bytes at two sequence lengths, fits the
    /// `α·n² + β·n` model ([`crate::nn::cost::max_len_under_budget`]),
    /// and admits only sequences whose predicted peak (plus weights)
    /// fits. A Performer tier measures α ≈ 0 and advertises a far longer
    /// [`SeqTierInfo::max_seq_len`] than a dense-attention tier under
    /// the *same* budget — the paper's linear-attention memory claim
    /// turned into admission capacity.
    pub mem_budget: Option<u64>,
    /// Forwarded to [`crate::nn::Module::set_head_group`] before the
    /// probe, as for row tiers.
    pub head_group: Option<usize>,
    /// Server-side decode applied per token row of each sequence reply.
    pub transform: OutputTransform,
    /// Probe sequence length `n0` for the admission fit (measured at
    /// `n0` and `2·n0`).
    pub probe_len: usize,
    /// Seeded fault-injection plan, as for [`TierConfig::faults`].
    pub faults: Option<Arc<FaultPlan>>,
    /// Quarantine strikes, as for [`TierConfig::quarantine_strikes`] —
    /// the isolated unit here is a whole sequence.
    pub quarantine_strikes: u32,
    /// Numeric guard, as for [`TierConfig::numeric_guard`] — a sequence
    /// whose reply contains any non-finite token row gets
    /// [`ServeError::NonFiniteOutput`].
    pub numeric_guard: bool,
}

impl Default for SeqTierConfig {
    fn default() -> Self {
        SeqTierConfig {
            max_tokens: 256,
            max_wait: Duration::from_millis(1),
            queue_cap: 64,
            workers: 2,
            mem_budget: None,
            head_group: None,
            transform: OutputTransform::Raw,
            probe_len: 16,
            faults: None,
            quarantine_strikes: 0,
            numeric_guard: false,
        }
    }
}

/// What registration admitted and measured for a tier.
#[derive(Debug, Clone)]
pub struct TierInfo {
    pub name: String,
    /// Request row width.
    pub in_dim: usize,
    /// Result row width.
    pub out_dim: usize,
    /// Batch cap (every batch executes padded to this).
    pub max_batch: usize,
    /// Coalescing wait of the tier's batcher — the worst extra queue
    /// time a lone request spends waiting for co-riders; the admission
    /// estimator's pessimistic wait term.
    pub max_wait: Duration,
    /// Admitted worker threads (≤ the requested count under a budget).
    pub workers: usize,
    /// Stored parameter bytes of the tier's model.
    pub weight_bytes: u64,
    /// Probe-measured peak activation bytes of one padded batch.
    pub peak_batch_bytes: u64,
    /// Whether the cap-padded forward reproduced the unbatched single-row
    /// forward bit-for-bit in the probe (see [`router`] docs).
    pub bit_identical_to_unbatched: bool,
}

/// What registration admitted and measured for a **sequence** tier.
#[derive(Debug, Clone)]
pub struct SeqTierInfo {
    pub name: String,
    /// Token row width.
    pub in_dim: usize,
    /// Reply row width per token (after the tier's
    /// [`OutputTransform`]).
    pub out_dim: usize,
    /// Per-step packed token budget of the continuous batcher.
    pub max_tokens: usize,
    /// Admitted worker threads.
    pub workers: usize,
    /// Stored parameter bytes of the tier's model.
    pub weight_bytes: u64,
    /// Longest single sequence this tier admits: `max_tokens` capped by
    /// the memory-budget fit ([`crate::nn::cost::max_len_under_budget`]
    /// over the two probe measurements). Under the same budget a
    /// Performer tier's cap strictly exceeds a dense-attention tier's —
    /// linear vs quadratic activation growth.
    pub max_seq_len: usize,
    /// Whether the registration probe reproduced a solo sequence
    /// bit-for-bit when packed behind a co-sequence. Attention mixes
    /// within a sequence by design but never across [`crate::nn::SeqBatch`]
    /// segments; this records what the probe measured at the tier's
    /// probe length.
    pub seq_stable: bool,
}

/// The serving front end: tier registry + worker pools (one self-healing
/// supervisor thread per tier) + metrics.
pub struct ModelServer {
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    closed: Arc<AtomicBool>,
}

impl Default for ModelServer {
    fn default() -> Self {
        Self::new()
    }
}

impl ModelServer {
    pub fn new() -> Self {
        ModelServer {
            router: Arc::new(Router::default()),
            metrics: Arc::new(Metrics::default()),
            workers: Vec::new(),
            closed: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Turn on request tracing: every admission from here on mints a
    /// trace id and records its span chain into per-tier event rings (see
    /// [`trace`]). Workers and supervisors capture their tier's sink at
    /// **registration**, so enable tracing *before* registering tiers to
    /// also get tier-level events (quarantine rounds, fault arms,
    /// restarts); per-request admission/exec/reply events work either
    /// way. Returns the tracer for snapshots/export. With tracing off the
    /// hot path pays one never-taken `Option` branch (the `FaultPlan`
    /// idiom) and replies are bitwise identical.
    pub fn enable_tracing(&self, cfg: TraceConfig) -> Arc<Tracer> {
        let tracer = Arc::new(Tracer::new(cfg));
        self.router.set_tracer(Some(Arc::clone(&tracer)));
        tracer
    }

    /// The installed tracer, if [`ModelServer::enable_tracing`] ran.
    pub fn tracer(&self) -> Option<Arc<Tracer>> {
        self.router.tracer()
    }

    /// Stop tracing new admissions. Already-admitted requests keep their
    /// trace contexts and finish recording into the (still live) sinks.
    pub fn disable_tracing(&self) {
        self.router.set_tracer(None);
    }

    /// Register `model` as tier `name`, serving rows of width `in_dim`.
    /// Runs the registration probe (row independence, footprint), applies
    /// the memory-budget admission, and spawns the tier's workers. The
    /// model is shared read-only by all of them.
    pub fn register_tier(
        &mut self,
        name: &str,
        mut model: Model,
        in_dim: usize,
        cfg: TierConfig,
    ) -> Result<TierInfo, ServeError> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        if in_dim == 0 || cfg.max_batch == 0 || cfg.queue_cap == 0 || cfg.workers == 0 {
            return Err(ServeError::BadInput(
                "in_dim, max_batch, queue_cap and workers must be positive".into(),
            ));
        }
        // Duplicate check up front (registration holds &mut self, so this
        // cannot race): nothing below runs for a name that would collide.
        if self.router.get(name).is_ok() {
            return Err(ServeError::DuplicateTier(name.to_string()));
        }
        if let Some(g) = cfg.head_group {
            model.set_head_group(g);
        }
        let probe = probe_model(&model, in_dim, cfg.max_batch)?;
        if let Err(m) = cfg.transform.validate(probe.out_dim) {
            return Err(ServeError::BadInput(m));
        }
        let weight_bytes = (model.total_params() * 4) as u64;
        let workers = match cfg.mem_budget {
            None => cfg.workers,
            Some(budget) => {
                let avail = budget.checked_sub(weight_bytes).unwrap_or(0);
                let fit = (avail / probe.peak_batch_bytes.max(1)) as usize;
                let admitted = fit.min(cfg.workers);
                if admitted == 0 {
                    return Err(ServeError::Budget(format!(
                        "budget {budget} B < {weight_bytes} B weights + \
                         {} B per-batch activations",
                        probe.peak_batch_bytes
                    )));
                }
                admitted
            }
        };
        let info = TierInfo {
            name: name.to_string(),
            in_dim,
            out_dim: cfg.transform.out_width(probe.out_dim),
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            workers,
            weight_bytes,
            peak_batch_bytes: probe.peak_batch_bytes,
            bit_identical_to_unbatched: probe.bit_identical_to_unbatched,
        };
        let tier_metrics = self.metrics.tier_entry(name);
        let queue = Arc::new(TierQueue::new(cfg.queue_cap, Arc::clone(&tier_metrics)));
        // Spawn the full worker pool BEFORE the tier becomes routable: a
        // mid-pool spawn failure must not leave a live worker-less tier
        // whose queue would admit requests nobody drains. On failure the
        // (unreachable) queue is closed, already-spawned workers drain out
        // and join, and no tier is registered.
        //
        // The model goes into a versioned slot rather than to the workers:
        // every admitted request captures the slot's current version, and
        // workers execute each batch on the version its requests captured
        // — which is what lets [`ModelServer::swap_tier_model`] publish a
        // new model later without touching the worker pool.
        let slot = Arc::new(ModelSlot::new(model));
        let tier_trace = self.router.tracer().map(|t| t.tier(name));
        let spec = RowWorker {
            queue: Arc::clone(&queue),
            max_batch: cfg.max_batch,
            max_wait: cfg.max_wait,
            in_dim,
            transform: cfg.transform,
            metrics: Arc::clone(&tier_metrics),
            faults: cfg.faults.clone(),
            quarantine_strikes: cfg.quarantine_strikes,
            numeric_guard: cfg.numeric_guard,
            trace: tier_trace.clone(),
        };
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let w = spec.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("panther-serve-{name}-{i}"))
                .spawn(move || w.run());
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    queue.close();
                    for h in handles {
                        let _ = h.join();
                    }
                    self.metrics.remove_tier(name);
                    return Err(ServeError::Spawn(e.to_string()));
                }
            }
        }
        tier_metrics.set_live_workers(workers);
        let inserted = self.router.insert(
            name,
            Tier::Row {
                queue: Arc::clone(&queue),
                info: info.clone(),
                slot,
                raw_out: probe.out_dim,
            },
        );
        if let Err(e) = inserted {
            // Unreachable given the up-front duplicate check, but never
            // leak a spawned pool: close, join, report.
            queue.close();
            for h in handles {
                let _ = h.join();
            }
            self.metrics.remove_tier(name);
            return Err(e);
        }
        let rtier = name.to_string();
        let respawn = move |k: usize| {
            let w = spec.clone();
            std::thread::Builder::new()
                .name(format!("panther-serve-{rtier}-r{k}"))
                .spawn(move || w.run())
        };
        let dq = Arc::clone(&queue);
        self.supervise(
            name,
            handles,
            tier_metrics,
            tier_trace,
            move || dq.is_drained(),
            respawn,
        );
        Ok(info)
    }

    /// Hand a freshly spawned worker pool to a per-tier supervisor
    /// thread, which respawns panicked workers (same warm setup — the
    /// spec clones into an identical loop) under capped exponential
    /// backoff. If even the supervisor thread cannot be spawned the pool
    /// keeps serving unsupervised — registration never fails for want of
    /// self-healing.
    fn supervise(
        &mut self,
        name: &str,
        handles: Vec<std::thread::JoinHandle<()>>,
        metrics: Arc<TierMetrics>,
        trace: Option<Arc<TierTrace>>,
        drained: impl Fn() -> bool + Send + 'static,
        respawn: impl Fn(usize) -> std::io::Result<std::thread::JoinHandle<()>> + Send + 'static,
    ) {
        // The handles ride a channel so a failed supervisor spawn leaves
        // them in hand for the unsupervised fallback.
        let (tx, rx) = mpsc::channel::<Vec<std::thread::JoinHandle<()>>>();
        let spawned = std::thread::Builder::new()
            .name(format!("panther-supervise-{name}"))
            .spawn(move || {
                let handles = rx.recv().unwrap_or_default();
                supervise_pool(handles, &metrics, trace.as_deref(), drained, respawn);
            });
        match spawned {
            Ok(h) => {
                let _ = tx.send(handles);
                self.workers.push(h);
            }
            Err(_) => self.workers.extend(handles),
        }
    }

    /// [`ModelServer::register_tier`] with weights restored from a
    /// checkpoint (v1–v3): `arch` provides the architecture, the
    /// checkpoint the parameters — the same contract as
    /// [`Model::load_state_dict`]. Loads through
    /// [`crate::train::checkpoint::load_with_recovery`]: a checkpoint
    /// whose CRC32 checksums do not verify falls back to its `.bak`
    /// sibling, and a corrupt pair is a typed
    /// [`crate::train::checkpoint::CheckpointError`] — never a tier
    /// serving silently wrong weights.
    pub fn register_tier_from_checkpoint(
        &mut self,
        name: &str,
        mut arch: Model,
        in_dim: usize,
        path: impl AsRef<Path>,
        cfg: TierConfig,
    ) -> crate::Result<TierInfo> {
        let (state, _recovered) = crate::train::checkpoint::load_with_recovery(path)?;
        arch.load_state_dict(&state.state_dict())?;
        Ok(self.register_tier(name, arch, in_dim, cfg)?)
    }

    /// Atomically hot-swap row tier `name`'s model without dropping a
    /// single request. The replacement is vetted like a registration
    /// (same probe: row independence, one output row per input row, the
    /// tier's input/output widths) and then **published** for future
    /// admissions only:
    ///
    /// - requests admitted before the swap keep the version they
    ///   captured — their replies are bit-identical to the old model's,
    ///   even if they execute after the swap;
    /// - batches never mix versions (the batcher fences on the
    ///   version key), so the swap lands exactly on a batch boundary;
    /// - workers are untouched — no queue pause, no thread churn.
    ///
    /// Returns the new version number (registration is version 0) and
    /// bumps the tier's `swaps` counter. Worker admission is *not*
    /// re-run: a swap changes weights, not the worker pool, so callers
    /// that care about memory budgets (the rank adapter) must check
    /// headroom before swapping. Sequence tiers are not swappable
    /// ([`ServeError::BadInput`]).
    pub fn swap_tier_model(&self, name: &str, model: Model) -> Result<u64, ServeError> {
        self.swap_handle().swap_tier_model(name, model)
    }

    /// A cloneable, `&self`-only handle that can hot-swap tier models
    /// without borrowing the server — what a background
    /// [`adapt::AdaptDaemon`] holds so the server stays free for
    /// registration, metrics, and shutdown on other threads.
    pub fn swap_handle(&self) -> SwapHandle {
        SwapHandle {
            router: Arc::clone(&self.router),
            metrics: Arc::clone(&self.metrics),
            closed: Arc::clone(&self.closed),
        }
    }

    /// Register `model` as **sequence** tier `name`: whole variable-length
    /// sequences (an `n × in_dim` token matrix each) in, one result row
    /// per token out. Workers run a continuous batcher — each step packs
    /// queued sequences FIFO into one [`crate::nn::SeqBatch`]-masked
    /// forward while their summed lengths fit `cfg.max_tokens`, so
    /// admission and retirement happen at every step boundary rather than
    /// per fixed batch.
    ///
    /// Unlike [`ModelServer::register_tier`], row-coupling (attention) is
    /// *expected* here — masking confines mixing to within each sequence,
    /// and the probe records cross-segment bitwise stability in
    /// [`SeqTierInfo::seq_stable`] instead of rejecting. A memory budget
    /// buys *length*: the probe measures peak activation bytes at
    /// `cfg.probe_len` and `2·probe_len`, fits `α·n² + β·n`, and
    /// [`SeqTierInfo::max_seq_len`] is the largest admitted length
    /// (requests beyond it get [`ServeError::SeqTooLong`]).
    pub fn register_seq_tier(
        &mut self,
        name: &str,
        mut model: Model,
        in_dim: usize,
        cfg: SeqTierConfig,
    ) -> Result<SeqTierInfo, ServeError> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        if in_dim == 0
            || cfg.max_tokens == 0
            || cfg.queue_cap == 0
            || cfg.workers == 0
            || cfg.probe_len == 0
        {
            return Err(ServeError::BadInput(
                "in_dim, max_tokens, queue_cap, workers and probe_len must be positive".into(),
            ));
        }
        if self.router.get(name).is_ok() {
            return Err(ServeError::DuplicateTier(name.to_string()));
        }
        if let Some(g) = cfg.head_group {
            model.set_head_group(g);
        }
        let probe = probe_seq_model(&model, in_dim, cfg.probe_len)?;
        if let Err(m) = cfg.transform.validate(probe.out_dim) {
            return Err(ServeError::BadInput(m));
        }
        let weight_bytes = (model.total_params() * 4) as u64;
        let max_seq_len = match cfg.mem_budget {
            None => cfg.max_tokens,
            Some(budget) => {
                let fit = crate::nn::cost::max_len_under_budget(
                    cfg.probe_len,
                    probe.peak0,
                    probe.peak1,
                    weight_bytes,
                    budget,
                );
                if fit == 0 {
                    return Err(ServeError::Budget(format!(
                        "budget {budget} B < {weight_bytes} B weights + the \
                         peak activations of even a 1-token sequence \
                         (probe: {} B at n={}, {} B at n={})",
                        probe.peak0,
                        cfg.probe_len,
                        probe.peak1,
                        2 * cfg.probe_len
                    )));
                }
                fit.min(cfg.max_tokens)
            }
        };
        let info = SeqTierInfo {
            name: name.to_string(),
            in_dim,
            out_dim: cfg.transform.out_width(probe.out_dim),
            max_tokens: cfg.max_tokens,
            workers: cfg.workers,
            weight_bytes,
            max_seq_len,
            seq_stable: probe.seq_stable,
        };
        let tier_metrics = self.metrics.tier_entry(name);
        let queue = Arc::new(TierQueue::new(cfg.queue_cap, Arc::clone(&tier_metrics)));
        // Same all-or-nothing spawn discipline as register_tier: the tier
        // only becomes routable once its whole worker pool is live.
        let model = Arc::new(model);
        let tier_trace = self.router.tracer().map(|t| t.tier(name));
        let spec = SeqWorker {
            model: Arc::clone(&model),
            queue: Arc::clone(&queue),
            max_tokens: cfg.max_tokens,
            max_wait: cfg.max_wait,
            in_dim,
            transform: cfg.transform,
            metrics: Arc::clone(&tier_metrics),
            faults: cfg.faults.clone(),
            quarantine_strikes: cfg.quarantine_strikes,
            numeric_guard: cfg.numeric_guard,
            trace: tier_trace.clone(),
        };
        let mut handles = Vec::with_capacity(cfg.workers);
        for i in 0..cfg.workers {
            let w = spec.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("panther-serve-{name}-{i}"))
                .spawn(move || w.run());
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    queue.close();
                    for h in handles {
                        let _ = h.join();
                    }
                    self.metrics.remove_tier(name);
                    return Err(ServeError::Spawn(e.to_string()));
                }
            }
        }
        tier_metrics.set_live_workers(cfg.workers);
        let inserted = self.router.insert(
            name,
            Tier::Seq {
                queue: Arc::clone(&queue),
                info: info.clone(),
            },
        );
        if let Err(e) = inserted {
            queue.close();
            for h in handles {
                let _ = h.join();
            }
            self.metrics.remove_tier(name);
            return Err(e);
        }
        let rtier = name.to_string();
        let respawn = move |k: usize| {
            let w = spec.clone();
            std::thread::Builder::new()
                .name(format!("panther-serve-{rtier}-r{k}"))
                .spawn(move || w.run())
        };
        let dq = Arc::clone(&queue);
        self.supervise(
            name,
            handles,
            tier_metrics,
            tier_trace,
            move || dq.is_drained(),
            respawn,
        );
        Ok(info)
    }

    /// Cloneable client handle.
    pub fn handle(&self) -> ServeHandle {
        ServeHandle {
            router: Arc::clone(&self.router),
        }
    }

    /// The server-wide metrics registry.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Registered tier names, sorted.
    pub fn tiers(&self) -> Vec<String> {
        self.router.names()
    }

    /// Configure the fallback tier for request routing: requests naming
    /// an unknown tier route here instead of erroring (info lookups stay
    /// strict). The tier must already be registered.
    pub fn set_default_tier(&self, name: &str) -> Result<(), ServeError> {
        self.router.set_default(name)
    }

    /// Freeze every tier's counters into one JSON-serializable
    /// [`MetricsSnapshot`] (convenience for `self.metrics().snapshot()`).
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// What registration admitted for row tier `name` (`None` for
    /// unknown names and for sequence tiers — see
    /// [`ModelServer::seq_tier_info`]).
    pub fn tier_info(&self, name: &str) -> Option<TierInfo> {
        match self.router.get(name).ok().as_deref() {
            Some(Tier::Row { info, .. }) => Some(info.clone()),
            _ => None,
        }
    }

    /// What registration admitted for sequence tier `name`.
    pub fn seq_tier_info(&self, name: &str) -> Option<SeqTierInfo> {
        match self.router.get(name).ok().as_deref() {
            Some(Tier::Seq { info, .. }) => Some(info.clone()),
            _ => None,
        }
    }

    /// Graceful drain: stop admissions (subsequent submits get
    /// [`ServeError::ShuttingDown`]), answer everything already queued,
    /// then join every tier supervisor (which joins its worker pool).
    /// Idempotent; also runs on drop.
    pub fn shutdown(&mut self) {
        self.closed.store(true, Ordering::SeqCst);
        self.router.close_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for ModelServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The per-tier supervision loop: poll the pool, join finished workers,
/// and respawn the ones that died by panic — under capped exponential
/// backoff (1 ms doubling to a 128 ms cap across *consecutive* crash
/// cycles; a quiet cycle resets the streak) so a hard-crashing model
/// cannot hot-loop thread creation. A worker that exits cleanly (queue
/// closed and drained) is not respawned; a panic before the drain
/// completes is, even during shutdown, so re-queued requests still get
/// answers. The `live_workers` gauge tracks running threads throughout —
/// a degraded tier advertises its real capacity to SLO routing. Returns
/// (ending the supervisor thread) once every worker has exited cleanly.
fn supervise_pool(
    mut handles: Vec<std::thread::JoinHandle<()>>,
    metrics: &TierMetrics,
    trace: Option<&TierTrace>,
    drained: impl Fn() -> bool,
    respawn: impl Fn(usize) -> std::io::Result<std::thread::JoinHandle<()>>,
) {
    const POLL: Duration = Duration::from_millis(2);
    const MAX_BACKOFF_SHIFT: u32 = 7;
    let mut streak: u32 = 0;
    let mut respawned: usize = 0;
    while !handles.is_empty() {
        let mut deaths = 0usize;
        let mut i = 0;
        while i < handles.len() {
            if handles[i].is_finished() {
                let panicked = handles.swap_remove(i).join().is_err();
                metrics.live_workers_sub(1);
                if panicked && !drained() {
                    deaths += 1;
                }
            } else {
                i += 1;
            }
        }
        if deaths > 0 {
            std::thread::sleep(Duration::from_millis(1u64 << streak.min(MAX_BACKOFF_SHIFT)));
            streak += 1;
            for _ in 0..deaths {
                metrics.record_worker_restart();
                respawned += 1;
                if let Ok(h) = respawn(respawned) {
                    metrics.live_workers_add(1);
                    handles.push(h);
                    if let Some(t) = trace {
                        t.record_now(EventClass::Restart, 0, format!("respawn={respawned}"));
                    }
                }
            }
        } else {
            streak = 0;
            if handles.is_empty() {
                break;
            }
            std::thread::sleep(POLL);
        }
    }
}

/// A cloneable handle for hot-swapping tier models from other threads
/// (see [`ModelServer::swap_handle`]). Carries exactly the shared state
/// [`ModelServer::swap_tier_model`] needs — router, metrics, and the
/// drain flag — so a background adapter daemon can swap without holding
/// any borrow of the server.
#[derive(Clone)]
pub struct SwapHandle {
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    closed: Arc<AtomicBool>,
}

impl SwapHandle {
    /// [`ModelServer::swap_tier_model`] — same vetting (registration
    /// probe, raw output width) and the same publish-for-future-admissions
    /// atomicity; the server method delegates here.
    pub fn swap_tier_model(&self, name: &str, model: Model) -> Result<u64, ServeError> {
        if self.closed.load(Ordering::SeqCst) {
            return Err(ServeError::ShuttingDown);
        }
        let tier = self.router.get(name)?;
        let (info, slot) = match &*tier {
            Tier::Row { info, slot, .. } => (info, slot),
            Tier::Seq { .. } => {
                return Err(ServeError::BadInput(format!(
                    "tier {name} is a sequence tier — hot-swap serves row tiers only"
                )));
            }
        };
        let t_probe = Instant::now();
        let probe = probe_model(&model, info.in_dim, info.max_batch)?;
        // The tier's transform was validated against the registration
        // model's raw output width; the replacement must keep that raw
        // interface exactly (the post-transform `info.out_dim` the
        // clients see then follows).
        let expected = tier.raw_out_dim().expect("row tier has a raw width");
        if probe.out_dim != expected {
            return Err(ServeError::BadInput(format!(
                "replacement for tier {name} maps {} -> {}, expected {} -> {expected}",
                info.in_dim, probe.out_dim, info.in_dim,
            )));
        }
        let version = slot.publish(model);
        if let Some(tm) = self.metrics.tier(name) {
            tm.record_swap();
        }
        // Tier-level swap fence event: span covers re-probe + publish.
        if let Some(tracer) = self.router.tracer() {
            tracer.tier(name).record_at(
                EventClass::Swap,
                t_probe,
                t_probe.elapsed(),
                0,
                format!("v={version}"),
            );
        }
        Ok(version)
    }
}

/// Cloneable client handle: route a request to a tier, blocking
/// ([`ServeHandle::infer`]), fail-fast ([`ServeHandle::try_infer`]), or
/// asynchronously ([`ServeHandle::submit`] + [`PendingReply::wait`]).
#[derive(Clone)]
pub struct ServeHandle {
    router: Arc<Router>,
}

impl ServeHandle {
    #[allow(clippy::type_complexity)]
    fn request(
        &self,
        tier: &str,
        row: &[f32],
    ) -> Result<(Arc<TierQueue<ServeRequest>>, ServeRequest, PendingReply), ServeError> {
        // `route`, not `get`: unknown names fall back to the server's
        // default tier when one is configured.
        let t = self.router.route(tier)?;
        let (queue, info, slot) = match &*t {
            Tier::Row { queue, info, slot, .. } => (Arc::clone(queue), info, slot),
            Tier::Seq { info, .. } => {
                return Err(ServeError::BadInput(format!(
                    "tier {:?} serves sequences — use infer_seq/submit_seq",
                    info.name
                )))
            }
        };
        if row.len() != info.in_dim {
            return Err(ServeError::BadInput(format!(
                "tier {:?} serves rows of width {}, got {}",
                info.name,
                info.in_dim,
                row.len()
            )));
        }
        let (tx, rx) = mpsc::channel();
        // Capture the tier's current model version at admission: this is
        // the hot-swap atomicity point. Whatever `swap_tier_model`
        // publishes later, this request executes — and replies — on the
        // version it captured here.
        let model = slot.current();
        // Admission is also the tracing point: mint the trace id and
        // record the `admit` instant (carrying the pinned version). One
        // never-taken branch when tracing is off.
        let trace = self.router.tracer().map(|tr| {
            let ctx = tr.ctx(&info.name);
            ctx.instant(EventClass::Admit, format!("v={}", model.version));
            ctx
        });
        let req = ServeRequest {
            row: row.to_vec(),
            reply: tx,
            enqueued: Instant::now(),
            model,
            trace,
        };
        Ok((queue, req, PendingReply { rx }))
    }

    #[allow(clippy::type_complexity)]
    fn seq_request(
        &self,
        tier: &str,
        tokens: &Mat,
    ) -> Result<(Arc<TierQueue<SeqServeRequest>>, SeqServeRequest, PendingSeqReply), ServeError>
    {
        let t = self.router.route(tier)?;
        let (queue, info) = match &*t {
            Tier::Seq { queue, info } => (Arc::clone(queue), info),
            Tier::Row { info, .. } => {
                return Err(ServeError::BadInput(format!(
                    "tier {:?} serves single rows — use infer/submit",
                    info.name
                )))
            }
        };
        if tokens.cols() != info.in_dim {
            return Err(ServeError::BadInput(format!(
                "tier {:?} serves token rows of width {}, got {}",
                info.name,
                info.in_dim,
                tokens.cols()
            )));
        }
        if tokens.rows() == 0 {
            return Err(ServeError::BadInput("empty sequence".into()));
        }
        if tokens.rows() > info.max_seq_len {
            return Err(ServeError::SeqTooLong {
                len: tokens.rows(),
                max: info.max_seq_len,
            });
        }
        let (tx, rx) = mpsc::channel();
        let trace = self.router.tracer().map(|tr| {
            let ctx = tr.ctx(&info.name);
            ctx.instant(EventClass::Admit, format!("tokens={}", tokens.rows()));
            ctx
        });
        let req = SeqServeRequest {
            tokens: tokens.clone(),
            reply: tx,
            enqueued: Instant::now(),
            trace,
        };
        Ok((queue, req, PendingSeqReply { rx }))
    }

    /// Enqueue a request, blocking while the tier queue is full. The
    /// reply arrives when the batch it joins completes.
    pub fn submit(&self, tier: &str, row: &[f32]) -> Result<PendingReply, ServeError> {
        let (queue, req, pending) = self.request(tier, row)?;
        queue.submit(req)?;
        Ok(pending)
    }

    /// [`ServeHandle::submit`] without blocking: a full queue is an
    /// immediate [`ServeError::QueueFull`].
    pub fn try_submit(&self, tier: &str, row: &[f32]) -> Result<PendingReply, ServeError> {
        let (queue, req, pending) = self.request(tier, row)?;
        queue.try_submit(req)?;
        Ok(pending)
    }

    /// Score one row (blocks until its batch completes).
    pub fn infer(&self, tier: &str, row: &[f32]) -> Result<Vec<f32>, ServeError> {
        self.submit(tier, row)?.wait()
    }

    /// [`ServeHandle::infer`] with fail-fast admission.
    pub fn try_infer(&self, tier: &str, row: &[f32]) -> Result<Vec<f32>, ServeError> {
        self.try_submit(tier, row)?.wait()
    }

    /// Enqueue a whole sequence (an `n × in_dim` token matrix) on a
    /// sequence tier, blocking while its queue is full. Length admission
    /// is checked here ([`ServeError::SeqTooLong`] beyond
    /// [`SeqTierInfo::max_seq_len`]); the reply is the per-token result
    /// matrix for exactly this sequence, independent of which co-sequences
    /// the continuous batcher packed alongside it.
    pub fn submit_seq(&self, tier: &str, tokens: &Mat) -> Result<PendingSeqReply, ServeError> {
        let (queue, req, pending) = self.seq_request(tier, tokens)?;
        queue.submit(req)?;
        Ok(pending)
    }

    /// [`ServeHandle::submit_seq`] without blocking: a full queue is an
    /// immediate [`ServeError::QueueFull`].
    pub fn try_submit_seq(&self, tier: &str, tokens: &Mat) -> Result<PendingSeqReply, ServeError> {
        let (queue, req, pending) = self.seq_request(tier, tokens)?;
        queue.try_submit(req)?;
        Ok(pending)
    }

    /// Score one sequence (blocks until its batcher step completes).
    pub fn infer_seq(&self, tier: &str, tokens: &Mat) -> Result<Mat, ServeError> {
        self.submit_seq(tier, tokens)?.wait()
    }

    /// [`ServeHandle::infer_seq`] with fail-fast admission.
    pub fn try_infer_seq(&self, tier: &str, tokens: &Mat) -> Result<Mat, ServeError> {
        self.try_submit_seq(tier, tokens)?.wait()
    }

    /// Registered tier names, sorted (same view as
    /// [`ModelServer::tiers`] — handles are cloneable and outlive the
    /// borrow of the server).
    pub fn tiers(&self) -> Vec<String> {
        self.router.names()
    }

    /// What registration admitted for row tier `name` (strict — the
    /// default-tier fallback applies to requests, not info lookups).
    pub fn tier_info(&self, name: &str) -> Option<TierInfo> {
        match self.router.get(name).ok().as_deref() {
            Some(Tier::Row { info, .. }) => Some(info.clone()),
            _ => None,
        }
    }

    /// What registration admitted for sequence tier `name`.
    pub fn seq_tier_info(&self, name: &str) -> Option<SeqTierInfo> {
        match self.router.get(name).ok().as_deref() {
            Some(Tier::Seq { info, .. }) => Some(info.clone()),
            _ => None,
        }
    }
}

/// An in-flight request; [`PendingReply::wait`] blocks for the result.
pub struct PendingReply {
    rx: mpsc::Receiver<Result<Vec<f32>, ServeError>>,
}

impl PendingReply {
    /// Block until the request's batch completes.
    pub fn wait(self) -> Result<Vec<f32>, ServeError> {
        self.rx.recv().map_err(|_| ServeError::Disconnected)?
    }
}

/// An in-flight sequence request; [`PendingSeqReply::wait`] blocks for
/// the per-token result matrix.
pub struct PendingSeqReply {
    rx: mpsc::Receiver<Result<Mat, ServeError>>,
}

impl PendingSeqReply {
    /// Block until the sequence's batcher step completes.
    pub fn wait(self) -> Result<Mat, ServeError> {
        self.rx.recv().map_err(|_| ServeError::Disconnected)?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Linear;
    use crate::rng::Philox;

    fn mlp(seed: u64) -> Model {
        let mut rng = Philox::seeded(seed);
        let mut m = Model::new();
        m.add("fc1", Linear::random(8, 16, &mut rng)).unwrap();
        m.add("fc2", Linear::random(16, 4, &mut rng)).unwrap();
        m
    }

    #[test]
    fn register_serve_shutdown_roundtrip() {
        let mut server = ModelServer::new();
        let info = server
            .register_tier("dense", mlp(1), 8, TierConfig::default())
            .unwrap();
        assert_eq!(info.out_dim, 4);
        assert_eq!(info.workers, 2);
        assert!(info.bit_identical_to_unbatched);
        assert_eq!(server.tiers(), vec!["dense"]);
        let h = server.handle();
        let y = h.infer("dense", &[0.5; 8]).unwrap();
        assert_eq!(y.len(), 4);
        // Unknown tier and wrong width are typed errors; the former
        // names the registered tiers.
        match h.infer("nope", &[0.0; 8]) {
            Err(ServeError::UnknownTier { name, registered }) => {
                assert_eq!(name, "nope");
                assert_eq!(registered, vec!["dense"]);
            }
            other => panic!("expected UnknownTier, got {other:?}"),
        }
        assert!(matches!(
            h.infer("dense", &[0.0; 3]),
            Err(ServeError::BadInput(_))
        ));
        server.shutdown();
        assert_eq!(h.infer("dense", &[0.5; 8]), Err(ServeError::ShuttingDown));
        // Idempotent.
        server.shutdown();
    }

    #[test]
    fn duplicate_and_degenerate_registrations_rejected() {
        let mut server = ModelServer::new();
        server
            .register_tier("t", mlp(2), 8, TierConfig::default())
            .unwrap();
        assert!(matches!(
            server.register_tier("t", mlp(2), 8, TierConfig::default()),
            Err(ServeError::DuplicateTier(_))
        ));
        let bad = TierConfig {
            workers: 0,
            ..TierConfig::default()
        };
        assert!(matches!(
            server.register_tier("z", mlp(2), 8, bad),
            Err(ServeError::BadInput(_))
        ));
    }

    #[test]
    fn memory_budget_admits_fewer_workers_or_rejects() {
        let mut server = ModelServer::new();
        // Probe first to learn the real numbers, then budget exactly one
        // worker's worth.
        let free = server
            .register_tier("probe", mlp(3), 8, TierConfig::default())
            .unwrap();
        let one_worker = free.weight_bytes + free.peak_batch_bytes;
        let cfg = TierConfig {
            workers: 4,
            mem_budget: Some(one_worker),
            ..TierConfig::default()
        };
        let info = server.register_tier("budgeted", mlp(3), 8, cfg).unwrap();
        assert_eq!(info.workers, 1, "budget fits exactly one worker");
        // A budget smaller than the weights alone is a clean error.
        let cfg = TierConfig {
            mem_budget: Some(free.weight_bytes / 2),
            ..TierConfig::default()
        };
        assert!(matches!(
            server.register_tier("tiny", mlp(3), 8, cfg),
            Err(ServeError::Budget(_))
        ));
    }

    #[test]
    fn checkpoint_registration_serves_trained_weights() {
        use crate::train::{Adam, Trainer};
        let mut model = mlp(4);
        let mut rng = Philox::seeded(5);
        let x = crate::linalg::Mat::randn(16, 8, &mut rng);
        let target = crate::linalg::Mat::randn(16, 4, &mut rng);
        let ctx = crate::nn::ForwardCtx::new();
        let mut tr = Trainer::new(Box::new(Adam::new(0.01)));
        for _ in 0..3 {
            tr.train_step(&mut model, &x, &target, &ctx).unwrap();
        }
        let dir = std::env::temp_dir().join("panther_serve_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tier.ckpt");
        tr.save_checkpoint(&model, "mlp", &path).unwrap();

        let mut server = ModelServer::new();
        server
            .register_tier_from_checkpoint("dense", mlp(999), 8, &path, TierConfig::default())
            .unwrap();
        let row: Vec<f32> = x.row(0).to_vec();
        let got = server.handle().infer("dense", &row).unwrap();
        // The served result is the trained model's single-row forward.
        let want = model.forward(&crate::linalg::Mat::from_vec(1, 8, row), &ctx).unwrap();
        assert_eq!(got.as_slice(), want.row(0));
        std::fs::remove_file(&path).ok();
    }

    fn attn_model(seed: u64) -> Model {
        use crate::nn::{AttnWeights, MultiHeadAttention};
        let mut rng = Philox::seeded(seed);
        let mut m = Model::new();
        m.add("attn", MultiHeadAttention::new(AttnWeights::random(8, 2, &mut rng)))
            .unwrap();
        m.add("head", Linear::random(8, 4, &mut rng)).unwrap();
        m
    }

    #[test]
    fn seq_tier_serves_attention_and_enforces_length() {
        use crate::nn::{ForwardCtx, SeqBatch};
        let mut server = ModelServer::new();
        let cfg = SeqTierConfig {
            max_tokens: 24,
            probe_len: 6,
            ..SeqTierConfig::default()
        };
        let info = server.register_seq_tier("seq", attn_model(7), 8, cfg).unwrap();
        assert_eq!(info.out_dim, 4);
        assert_eq!(info.max_seq_len, 24, "no budget: cap is the token budget");
        assert_eq!(server.seq_tier_info("seq").unwrap().max_seq_len, 24);
        assert!(server.tier_info("seq").is_none(), "not a row tier");
        let h = server.handle();
        let mut rng = Philox::seeded(8);
        let x = Mat::randn(5, 8, &mut rng);
        let got = h.infer_seq("seq", &x).unwrap();
        // The served sequence result is the standalone masked forward.
        let want = attn_model(7)
            .forward_seq(&x, &SeqBatch::single(5), &ForwardCtx::new())
            .unwrap();
        assert_eq!(got.data(), want.data());
        // Length admission and shape validation are typed errors.
        let long = Mat::zeros(25, 8);
        assert!(matches!(
            h.infer_seq("seq", &long),
            Err(ServeError::SeqTooLong { len: 25, max: 24 })
        ));
        assert!(matches!(
            h.infer_seq("seq", &Mat::zeros(3, 5)),
            Err(ServeError::BadInput(_))
        ));
        assert!(matches!(
            h.infer_seq("seq", &Mat::zeros(0, 8)),
            Err(ServeError::BadInput(_))
        ));
        // Row API on a sequence tier (and vice versa) is a typed error.
        assert!(matches!(h.infer("seq", &[0.0; 8]), Err(ServeError::BadInput(_))));
        server.register_tier("row", mlp(1), 8, TierConfig::default()).unwrap();
        assert!(matches!(
            h.infer_seq("row", &Mat::zeros(2, 8)),
            Err(ServeError::BadInput(_))
        ));
        server.shutdown();
        assert!(matches!(
            h.infer_seq("seq", &Mat::zeros(2, 8)),
            Err(ServeError::ShuttingDown)
        ));
    }

    #[test]
    fn default_tier_catches_unknown_names() {
        let mut server = ModelServer::new();
        server
            .register_tier("dense", mlp(1), 8, TierConfig::default())
            .unwrap();
        let h = server.handle();
        // Before a default is set, unknown names error.
        assert!(matches!(
            h.infer("typo", &[0.5; 8]),
            Err(ServeError::UnknownTier { .. })
        ));
        // A default must name a registered tier.
        assert!(matches!(
            server.set_default_tier("nope"),
            Err(ServeError::UnknownTier { .. })
        ));
        server.set_default_tier("dense").unwrap();
        // The same request now routes through the fallback and answers
        // exactly what the tier would have.
        let via_fallback = h.infer("typo", &[0.5; 8]).unwrap();
        let direct = h.infer("dense", &[0.5; 8]).unwrap();
        assert_eq!(via_fallback, direct);
        // Info lookups stay strict.
        assert!(server.tier_info("typo").is_none());
        assert!(h.tier_info("typo").is_none());
        assert_eq!(h.tier_info("dense").unwrap().max_batch, 4);
        assert_eq!(h.tiers(), vec!["dense"]);
        server.shutdown();
    }

    #[test]
    fn transforms_decode_server_side() {
        let mut server = ModelServer::new();
        let cfg = TierConfig {
            transform: OutputTransform::TopK(2),
            ..TierConfig::default()
        };
        let info = server.register_tier("topk", mlp(9), 8, cfg).unwrap();
        assert_eq!(info.out_dim, 4, "2·k floats per row");
        let y = server.handle().infer("topk", &[0.3; 8]).unwrap();
        assert_eq!(y.len(), 4);
        // Replies are (index, logprob) pairs: indices in range, logprobs ≤ 0.
        assert!((y[0] as usize) < 4 && (y[2] as usize) < 4);
        assert!(y[1] <= 0.0 && y[3] <= y[1]);
        // TopK wider than the model's output is rejected at registration.
        let bad = TierConfig {
            transform: OutputTransform::TopK(99),
            ..TierConfig::default()
        };
        assert!(matches!(
            server.register_tier("wide", mlp(9), 8, bad),
            Err(ServeError::BadInput(_))
        ));
    }
}
