//! Deterministic, seeded fault injection for chaos-testing the serve
//! stack.
//!
//! A [`FaultPlan`] is installed per tier through
//! [`super::TierConfig::faults`]. Workers call [`FaultPlan::begin_batch`]
//! exactly once per shipped batch; the returned [`BatchFaults`] says which
//! faults fire for that batch *tick* (a global 1-based counter across the
//! tier's workers). When no plan is installed the worker hot path pays a
//! single `Option` branch and touches none of this module.
//!
//! Injection points (all decided purely from the plan's configuration,
//! its seed, and the tick — so a chaos run is reproducible bit-for-bit):
//!
//! - **kill before forward** — the worker re-queues its batch at the front
//!   of the tier queue and panics *outside* the forward `catch_unwind`,
//!   killing the worker thread. No request is lost and none sees an error:
//!   the re-queued rows are re-batched by surviving (or respawned)
//!   workers, and because padded batching is bitwise-stable across batch
//!   composition, replies match the fault-free run exactly. This is the
//!   supervision path's test vector.
//! - **panic mid-batch** — a panic raised *inside* the forward region,
//!   indistinguishable from model code panicking. With quarantine off the
//!   batch fails with [`super::ServeError::Exec`]; with quarantine on the
//!   bisection retry re-executes the requests (the injected panic does not
//!   re-fire at later ticks, so a transient panic costs nothing).
//! - **exec delay** — `thread::sleep` before the forward, inflating
//!   observed exec latency for SLO/overload experiments.
//! - **poison output rows** — one deterministic row of the batch output is
//!   overwritten with NaN after the forward, exercising the
//!   [`super::TierConfig::numeric_guard`] sweep and its `nonfinite_rows`
//!   accounting.
//!
//! Faults can be pinned to explicit ticks (`*_at`) for exact-count
//! assertions, or fired at a seeded rate (`*_rate`) for throughput-style
//! chaos (e.g. the bench's 1%-kill section). Both can be combined.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Domain-separation tags so each injection point draws independent
/// decisions from the same seed.
const TAG_KILL: u64 = 0x4b49;
const TAG_PANIC: u64 = 0x5041;
const TAG_DELAY: u64 = 0x4445;
const TAG_POISON: u64 = 0x504f;

/// splitmix64 finalizer: a cheap, well-mixed hash of (seed, tag, tick).
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Uniform draw in `[0, 1)` from a hash value (53 mantissa bits).
fn unit(h: u64) -> f64 {
    (h >> 11) as f64 / (1u64 << 53) as f64
}

/// Seeded, per-tier fault plan (see the module docs for the injection
/// points). Construct with [`FaultPlan::seeded`], configure with the
/// builder methods, install via [`super::TierConfig::faults`].
#[derive(Debug, Default)]
pub struct FaultPlan {
    seed: u64,
    kill_ticks: Vec<u64>,
    kill_rate: f64,
    panic_ticks: Vec<u64>,
    panic_rate: f64,
    delay_ticks: Vec<u64>,
    delay_rate: f64,
    delay: Duration,
    poison_ticks: Vec<u64>,
    poison_rate: f64,
    tick: AtomicU64,
}

impl FaultPlan {
    /// A quiet plan; the seed drives every rate-based decision.
    pub fn seeded(seed: u64) -> Self {
        FaultPlan {
            seed,
            ..FaultPlan::default()
        }
    }

    /// Kill the executing worker (batch re-queued first) at exactly these
    /// 1-based batch ticks.
    pub fn kill_at(mut self, ticks: &[u64]) -> Self {
        self.kill_ticks.extend_from_slice(ticks);
        self
    }

    /// Kill the executing worker on a seeded `rate` fraction of ticks.
    pub fn kill_rate(mut self, rate: f64) -> Self {
        self.kill_rate = rate;
        self
    }

    /// Panic inside the forward region at exactly these ticks.
    pub fn panic_at(mut self, ticks: &[u64]) -> Self {
        self.panic_ticks.extend_from_slice(ticks);
        self
    }

    /// Panic inside the forward region on a seeded `rate` fraction of
    /// ticks.
    pub fn panic_rate(mut self, rate: f64) -> Self {
        self.panic_rate = rate;
        self
    }

    /// Sleep `delay` before the forward at exactly these ticks.
    pub fn delay_at(mut self, ticks: &[u64], delay: Duration) -> Self {
        self.delay_ticks.extend_from_slice(ticks);
        self.delay = delay;
        self
    }

    /// Sleep `delay` before the forward on a seeded `rate` fraction of
    /// ticks.
    pub fn delay_rate(mut self, rate: f64, delay: Duration) -> Self {
        self.delay_rate = rate;
        self.delay = delay;
        self
    }

    /// Overwrite one deterministic output row with NaN at exactly these
    /// ticks.
    pub fn poison_at(mut self, ticks: &[u64]) -> Self {
        self.poison_ticks.extend_from_slice(ticks);
        self
    }

    /// Overwrite one deterministic output row with NaN on a seeded `rate`
    /// fraction of ticks.
    pub fn poison_rate(mut self, rate: f64) -> Self {
        self.poison_rate = rate;
        self
    }

    /// Batch ticks consumed so far.
    pub fn ticks(&self) -> u64 {
        self.tick.load(Ordering::Relaxed)
    }

    fn fires(&self, tag: u64, ticks: &[u64], rate: f64, tick: u64) -> bool {
        ticks.contains(&tick) || (rate > 0.0 && unit(mix(self.seed ^ tag ^ tick)) < rate)
    }

    /// Consume the next batch tick and decide which faults fire for a
    /// batch of `rows` used rows. Called once per shipped batch.
    pub fn begin_batch(&self, rows: usize) -> BatchFaults {
        let tick = self.tick.fetch_add(1, Ordering::Relaxed) + 1;
        let poison_fires =
            rows > 0 && self.fires(TAG_POISON, &self.poison_ticks, self.poison_rate, tick);
        let poison_row = if poison_fires {
            Some((mix(self.seed ^ TAG_POISON ^ tick.rotate_left(17)) % rows as u64) as usize)
        } else {
            None
        };
        BatchFaults {
            kill_before_forward: self.fires(TAG_KILL, &self.kill_ticks, self.kill_rate, tick),
            panic_mid_batch: self.fires(TAG_PANIC, &self.panic_ticks, self.panic_rate, tick),
            exec_delay: if self.fires(TAG_DELAY, &self.delay_ticks, self.delay_rate, tick) {
                Some(self.delay)
            } else {
                None
            },
            poison_row,
        }
    }
}

/// The faults that fire for one batch tick (see [`FaultPlan`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchFaults {
    /// Re-queue the batch and panic outside the forward `catch_unwind`.
    pub kill_before_forward: bool,
    /// Panic inside the forward region (as model code would).
    pub panic_mid_batch: bool,
    /// Sleep this long before the forward.
    pub exec_delay: Option<Duration>,
    /// Used-row index whose output is overwritten with NaN.
    pub poison_row: Option<usize>,
}

impl BatchFaults {
    /// True when no fault fires this tick.
    pub fn is_quiet(&self) -> bool {
        !self.kill_before_forward
            && !self.panic_mid_batch
            && self.exec_delay.is_none()
            && self.poison_row.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quiet_plan_stays_quiet() {
        let plan = FaultPlan::seeded(42);
        for rows in [1usize, 4, 32] {
            assert!(plan.begin_batch(rows).is_quiet());
        }
        assert_eq!(plan.ticks(), 3);
    }

    #[test]
    fn pinned_ticks_fire_exactly_once_each() {
        let plan = FaultPlan::seeded(1)
            .kill_at(&[2])
            .panic_at(&[3])
            .poison_at(&[4])
            .delay_at(&[5], Duration::from_millis(1));
        let mut kills = 0;
        let mut panics = 0;
        let mut poisons = 0;
        let mut delays = 0;
        for _ in 0..10 {
            let f = plan.begin_batch(8);
            kills += f.kill_before_forward as u32;
            panics += f.panic_mid_batch as u32;
            poisons += f.poison_row.is_some() as u32;
            delays += f.exec_delay.is_some() as u32;
        }
        assert_eq!((kills, panics, poisons, delays), (1, 1, 1, 1));
    }

    #[test]
    fn rate_decisions_are_seed_deterministic_and_roughly_calibrated() {
        let a = FaultPlan::seeded(7).kill_rate(0.1);
        let b = FaultPlan::seeded(7).kill_rate(0.1);
        let mut fired = 0u32;
        for _ in 0..2000 {
            let fa = a.begin_batch(4).kill_before_forward;
            let fb = b.begin_batch(4).kill_before_forward;
            assert_eq!(fa, fb);
            fired += fa as u32;
        }
        // 10% of 2000 with generous slack: the point is calibration, not
        // exactness.
        assert!((100..=300).contains(&fired), "fired {fired}");
        // A different seed fires on a different tick set.
        let c = FaultPlan::seeded(8).kill_rate(0.1);
        let diverges = (0..2000).any(|t| {
            c.begin_batch(4).kill_before_forward
                != FaultPlan::seeded(7).kill_rate(0.1).fires(TAG_KILL, &[], 0.1, t + 1)
        });
        assert!(diverges);
    }

    #[test]
    fn poison_row_is_in_range_and_deterministic() {
        let plan = FaultPlan::seeded(9).poison_rate(1.0);
        let mut rows_hit = Vec::new();
        for _ in 0..64 {
            let f = plan.begin_batch(8);
            let r = f.poison_row.expect("rate 1.0 always fires");
            assert!(r < 8);
            rows_hit.push(r);
        }
        let again = FaultPlan::seeded(9).poison_rate(1.0);
        let replay: Vec<usize> = (0..64)
            .map(|_| again.begin_batch(8).poison_row.unwrap())
            .collect();
        assert_eq!(rows_hit, replay);
        // The hash spreads across rows rather than pinning one index.
        assert!(rows_hit.iter().collect::<std::collections::HashSet<_>>().len() > 1);
    }

    #[test]
    fn zero_rows_never_poisons() {
        let plan = FaultPlan::seeded(3).poison_rate(1.0);
        assert_eq!(plan.begin_batch(0).poison_row, None);
    }
}
