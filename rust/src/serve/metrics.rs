//! Serving metrics: per-tier queue depth, batch-occupancy histogram,
//! latency percentiles, and the SLO-controller sensors.
//!
//! Reuses the [`crate::util::stats`] histogram shapes that
//! `coordinator::batcher` records (one [`OccupancyHist`] per batching
//! queue) and follows the same interior-mutability pattern as
//! [`crate::coordinator::CoordinatorMetrics`]: workers write through
//! `&self`, anyone reads, locks are poison-tolerant so a panicking worker
//! cannot cascade into panics on every later read.
//!
//! Two kinds of time histograms coexist per tier:
//!
//! - **cumulative** ([`DurationHist`]) — the long-run record examples and
//!   benches report;
//! - **windowed** ([`WindowedHist`] behind a wall-clock rotation) — the
//!   sensor the [`crate::serve::slo`] admission controller reads, where
//!   an idle hour must not let stale history steer routing. The window
//!   covers the last [`WINDOW_EPOCHS`]`×`[`WINDOW_EPOCH`] of samples.
//!
//! The whole registry serializes as one machine-readable shape through
//! [`Metrics::snapshot`], which benches emit into `BENCH_serve.json` via
//! [`crate::util::bench::JsonReport`] — CI and humans consume the same
//! struct.

use crate::util::json::Json;
use crate::util::stats::{DurationHist, OccupancyHist, WindowedHist};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Epochs in each windowed sensor histogram.
pub const WINDOW_EPOCHS: usize = 8;
/// Wall-clock length of one window epoch; the full sliding window spans
/// `WINDOW_EPOCHS × WINDOW_EPOCH` = 2 s.
pub const WINDOW_EPOCH: Duration = Duration::from_millis(250);

/// A [`WindowedHist`] rotated on wall time: every access first retires
/// the epochs the clock has moved past, so reads never see samples older
/// than the window span (plus one epoch of quantization).
struct RotatingWindow {
    hist: WindowedHist,
    /// Start of the epoch currently recording.
    started: Instant,
}

impl Default for RotatingWindow {
    fn default() -> Self {
        RotatingWindow {
            hist: WindowedHist::new(WINDOW_EPOCHS),
            started: Instant::now(),
        }
    }
}

impl RotatingWindow {
    /// Retire elapsed epochs. Bounded at one full ring — after a long
    /// idle stretch the window is simply empty, not rotated thousands of
    /// times.
    fn tick(&mut self) {
        let elapsed = self.started.elapsed();
        if elapsed < WINDOW_EPOCH {
            return;
        }
        self.advance((elapsed.as_nanos() / WINDOW_EPOCH.as_nanos()) as u64);
    }

    /// Advance the ring by `steps` epochs. A gap of a full ring or more
    /// clears every epoch in one move and re-anchors the grid at "now" —
    /// which is also what makes huge `steps` safe: the old
    /// `started += WINDOW_EPOCH * steps` re-anchor wrapped through the
    /// `u32` epoch multiply and could push `started` decades into the
    /// future, freezing the window (monotonic `elapsed()` saturates to
    /// zero, so `tick` would never rotate again). Below a full ring
    /// `steps < WINDOW_EPOCHS`, so the grid-preserving multiply cannot
    /// overflow.
    fn advance(&mut self, steps: u64) {
        if steps >= self.hist.epochs() as u64 {
            for _ in 0..self.hist.epochs() {
                self.hist.rotate();
            }
            self.started = Instant::now();
            return;
        }
        for _ in 0..steps {
            self.hist.rotate();
        }
        // Re-anchor on the epoch grid so quantization does not drift.
        self.started += WINDOW_EPOCH * steps as u32;
        if self.started.elapsed() >= WINDOW_EPOCH {
            self.started = Instant::now();
        }
    }

    fn record(&mut self, d: Duration) {
        self.tick();
        self.hist.record(d);
    }

    fn snapshot(&mut self) -> DurationHist {
        self.tick();
        self.hist.snapshot()
    }
}

/// Live counters for one tier. Shared (`Arc`) between the tier's queue,
/// its workers, the server-level [`Metrics`] registry, and any
/// [`crate::serve::Cascade`] routing over the tier.
#[derive(Default)]
pub struct TierMetrics {
    /// Requests enqueued but not yet picked into a batch.
    depth: AtomicUsize,
    /// Requests turned away by admission control (`try_submit` on a full
    /// queue).
    rejected: AtomicU64,
    /// Requests answered with an execution error.
    errors: AtomicU64,
    /// Token rows executed through packed sequence steps (sequence tiers
    /// only — row tiers leave this at zero).
    tokens: AtomicU64,
    /// Requests that named this tier as their best eligible quality but
    /// were routed to a cheaper tier by the SLO cascade (the recorded
    /// quality downgrade).
    sheds: AtomicU64,
    /// Speculative verification requests issued against this tier.
    speculative: AtomicU64,
    /// Speculative upgrades this tier delivered to a caller.
    upgrades: AtomicU64,
    /// Speculative upgrades revoked instead of delivered (submit failed,
    /// execution error, or the caller abandoned the handle).
    revoked: AtomicU64,
    /// Requests rejected as `SloInfeasible` with this tier as their best
    /// eligible quality — not even a downgrade could meet the deadline.
    slo_rejects: AtomicU64,
    /// Model versions published through the hot-swap path (the rank
    /// adapter's applied moves).
    swaps: AtomicU64,
    /// Worker threads respawned by the tier supervisor after a panic
    /// escaped the forward `catch_unwind` (e.g. an injected kill).
    worker_restarts: AtomicU64,
    /// Requests answered with [`crate::serve::ServeError::PoisonedInput`]
    /// after quarantine bisection isolated them as the reproducible cause
    /// of batch panics.
    poisoned: AtomicU64,
    /// Output rows the numeric guard converted to
    /// [`crate::serve::ServeError::NonFiniteOutput`] because they carried
    /// NaN/Inf.
    nonfinite_rows: AtomicU64,
    /// Worker threads currently alive (set at registration, maintained by
    /// the supervisor: a dead worker lowers it until its respawn lands).
    /// Zero means "not supervised" — readers fall back to the static
    /// configured count.
    live_workers: AtomicUsize,
    /// Current sketch-rank gauge (0 = dense / never set) — written by
    /// [`crate::serve::RankAdapter`] alongside each swap.
    rank: AtomicUsize,
    /// Latest measured quality score from the shadow-replay sensor
    /// (`1 − relative error` vs. the dense reference; `None` until the
    /// first measurement). The cascade prefers this over the static
    /// ladder score when present.
    measured_quality: Mutex<Option<f64>>,
    occupancy: Mutex<OccupancyHist>,
    /// End-to-end latency (enqueue → reply), queue wait included.
    latency: Mutex<DurationHist>,
    /// Per-batch model execution time (forward only, no queue wait) —
    /// the service-time sensor of the admission estimator.
    exec: Mutex<DurationHist>,
    /// Windowed twin of `latency`.
    latency_win: Mutex<RotatingWindow>,
    /// Windowed twin of `exec`.
    exec_win: Mutex<RotatingWindow>,
}

impl TierMetrics {
    fn occ(&self) -> MutexGuard<'_, OccupancyHist> {
        crate::util::lock_ignore_poison(&self.occupancy)
    }

    fn lat(&self) -> MutexGuard<'_, DurationHist> {
        crate::util::lock_ignore_poison(&self.latency)
    }

    fn exe(&self) -> MutexGuard<'_, DurationHist> {
        crate::util::lock_ignore_poison(&self.exec)
    }

    pub(crate) fn depth_add(&self, n: usize) {
        self.depth.fetch_add(n, Ordering::SeqCst);
    }

    pub(crate) fn depth_sub(&self, n: usize) {
        self.depth.fetch_sub(n, Ordering::SeqCst);
    }

    pub(crate) fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_error(&self, n: u64) {
        self.errors.fetch_add(n, Ordering::SeqCst);
    }

    pub(crate) fn record_batch(&self, used: usize, capacity: usize) {
        self.occ().record(used, capacity);
    }

    pub(crate) fn record_tokens(&self, n: u64) {
        self.tokens.fetch_add(n, Ordering::SeqCst);
    }

    pub(crate) fn record_latency(&self, d: Duration) {
        self.lat().record(d);
        crate::util::lock_ignore_poison(&self.latency_win).record(d);
    }

    pub(crate) fn record_exec(&self, d: Duration) {
        self.exe().record(d);
        crate::util::lock_ignore_poison(&self.exec_win).record(d);
    }

    pub(crate) fn record_shed(&self) {
        self.sheds.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_speculative(&self) {
        self.speculative.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_upgrade(&self) {
        self.upgrades.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_revoked(&self) {
        self.revoked.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_slo_reject(&self) {
        self.slo_rejects.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_swap(&self) {
        self.swaps.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn set_rank(&self, rank: usize) {
        self.rank.store(rank, Ordering::SeqCst);
    }

    pub(crate) fn set_measured_quality(&self, q: f64) {
        *crate::util::lock_ignore_poison(&self.measured_quality) = Some(q);
    }

    /// Ratchet the measured-quality gauge *down* to `q` (numeric guard:
    /// a sick batch lowers the score immediately; only a real measurement
    /// — [`TierMetrics::set_measured_quality`] — can raise it again).
    pub(crate) fn degrade_measured_quality(&self, q: f64) {
        let mut cur = crate::util::lock_ignore_poison(&self.measured_quality);
        *cur = Some(cur.map_or(q, |c| c.min(q)));
    }

    pub(crate) fn record_worker_restart(&self) {
        self.worker_restarts.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_poisoned(&self) {
        self.poisoned.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_nonfinite_rows(&self, n: u64) {
        self.nonfinite_rows.fetch_add(n, Ordering::SeqCst);
    }

    pub(crate) fn set_live_workers(&self, n: usize) {
        self.live_workers.store(n, Ordering::SeqCst);
    }

    pub(crate) fn live_workers_sub(&self, n: usize) {
        self.live_workers.fetch_sub(n, Ordering::SeqCst);
    }

    pub(crate) fn live_workers_add(&self, n: usize) {
        self.live_workers.fetch_add(n, Ordering::SeqCst);
    }

    /// Requests currently queued (submitted, not yet batched).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    /// Requests rejected by admission control.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::SeqCst)
    }

    /// Requests answered with an execution error.
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::SeqCst)
    }

    /// Token rows executed through packed sequence steps.
    pub fn tokens(&self) -> u64 {
        self.tokens.load(Ordering::SeqCst)
    }

    /// Requests shed away from this tier by the SLO cascade (counted
    /// quality downgrades).
    pub fn sheds(&self) -> u64 {
        self.sheds.load(Ordering::SeqCst)
    }

    /// Speculative verification requests issued against this tier.
    pub fn speculative(&self) -> u64 {
        self.speculative.load(Ordering::SeqCst)
    }

    /// Speculative upgrades delivered by this tier.
    pub fn upgrades(&self) -> u64 {
        self.upgrades.load(Ordering::SeqCst)
    }

    /// Speculative upgrades revoked instead of delivered.
    pub fn revoked(&self) -> u64 {
        self.revoked.load(Ordering::SeqCst)
    }

    /// Requests rejected as SLO-infeasible with this tier as their best
    /// eligible quality.
    pub fn slo_rejects(&self) -> u64 {
        self.slo_rejects.load(Ordering::SeqCst)
    }

    /// Model versions hot-swapped into this tier.
    pub fn swaps(&self) -> u64 {
        self.swaps.load(Ordering::SeqCst)
    }

    /// Worker threads respawned by the tier supervisor after a panic.
    pub fn worker_restarts(&self) -> u64 {
        self.worker_restarts.load(Ordering::SeqCst)
    }

    /// Requests answered as `PoisonedInput` by quarantine bisection.
    pub fn poisoned(&self) -> u64 {
        self.poisoned.load(Ordering::SeqCst)
    }

    /// Output rows the numeric guard rejected as NaN/Inf.
    pub fn nonfinite_rows(&self) -> u64 {
        self.nonfinite_rows.load(Ordering::SeqCst)
    }

    /// Worker threads currently alive (0 when the tier is unsupervised).
    pub fn live_workers(&self) -> usize {
        self.live_workers.load(Ordering::SeqCst)
    }

    /// Current sketch-rank gauge (0 until the rank adapter sets it).
    pub fn rank(&self) -> usize {
        self.rank.load(Ordering::SeqCst)
    }

    /// Latest shadow-replay quality score (`1 − relative error` against
    /// the dense reference), `None` before the first measurement.
    pub fn measured_quality(&self) -> Option<f64> {
        *crate::util::lock_ignore_poison(&self.measured_quality)
    }

    /// Batches executed.
    pub fn batches(&self) -> u64 {
        self.occ().batches()
    }

    /// Requests that completed through an executed batch.
    pub fn requests(&self) -> u64 {
        self.occ().requests()
    }

    /// Mean live rows per executed batch.
    pub fn mean_occupancy(&self) -> f64 {
        self.occ().mean()
    }

    /// Snapshot of the occupancy histogram (index = rows used − 1).
    pub fn occupancy_buckets(&self) -> Vec<u64> {
        self.occ().buckets().to_vec()
    }

    /// Median end-to-end latency (approximate; see
    /// [`DurationHist::quantile`]).
    pub fn latency_p50(&self) -> Duration {
        self.lat().p50()
    }

    /// 99th-percentile end-to-end latency (approximate).
    pub fn latency_p99(&self) -> Duration {
        self.lat().p99()
    }

    /// Mean end-to-end latency (exact).
    pub fn latency_mean(&self) -> Duration {
        self.lat().mean()
    }

    /// Median per-batch execution time (cumulative).
    pub fn exec_p50(&self) -> Duration {
        self.exe().p50()
    }

    /// 99th-percentile per-batch execution time (cumulative).
    pub fn exec_p99(&self) -> Duration {
        self.exe().p99()
    }

    /// Sliding-window snapshot of end-to-end latency — empty after the
    /// tier has been idle for the window span.
    pub fn windowed_latency(&self) -> DurationHist {
        crate::util::lock_ignore_poison(&self.latency_win).snapshot()
    }

    /// Sliding-window snapshot of per-batch execution time — the
    /// service-time sensor of [`crate::serve::slo::predict_latency`].
    pub fn windowed_exec(&self) -> DurationHist {
        crate::util::lock_ignore_poison(&self.exec_win).snapshot()
    }
}

/// One tier's counters frozen at a point in time — the machine-readable
/// shape behind [`Metrics::snapshot`]. Times are microseconds (f64) so
/// the struct serializes losslessly through [`Json`]'s f64 numbers.
#[derive(Debug, Clone)]
pub struct TierSnapshot {
    pub tier: String,
    pub queue_depth: usize,
    pub requests: u64,
    pub batches: u64,
    pub mean_occupancy: f64,
    pub tokens: u64,
    /// Cumulative end-to-end latency percentiles, µs.
    pub p50_us: f64,
    pub p99_us: f64,
    /// Sliding-window end-to-end latency percentiles, µs (0 when idle
    /// past the window span).
    pub win_p50_us: f64,
    pub win_p99_us: f64,
    /// Sliding-window sample count backing the windowed percentiles.
    pub win_samples: u64,
    pub rejected: u64,
    pub errors: u64,
    pub sheds: u64,
    pub speculative: u64,
    pub upgrades: u64,
    pub revoked: u64,
    pub slo_rejects: u64,
    /// Model versions hot-swapped into the tier.
    pub swaps: u64,
    /// Worker threads respawned by the supervisor after a panic.
    pub worker_restarts: u64,
    /// Requests answered as `PoisonedInput` by quarantine bisection.
    pub poisoned: u64,
    /// Output rows the numeric guard rejected as NaN/Inf.
    pub nonfinite_rows: u64,
    /// Worker threads alive at snapshot time (0 when unsupervised).
    pub live_workers: usize,
    /// Sketch-rank gauge (0 until the adapter sets it).
    pub rank: usize,
    /// Shadow-replay quality score, `None` before the first measurement.
    pub measured_quality: Option<f64>,
}

fn us(d: Duration) -> f64 {
    d.as_secs_f64() * 1e6
}

impl TierSnapshot {
    fn from_tier(name: &str, m: &TierMetrics) -> TierSnapshot {
        let win = m.windowed_latency();
        TierSnapshot {
            tier: name.to_string(),
            queue_depth: m.queue_depth(),
            requests: m.requests(),
            batches: m.batches(),
            mean_occupancy: m.mean_occupancy(),
            tokens: m.tokens(),
            p50_us: us(m.latency_p50()),
            p99_us: us(m.latency_p99()),
            win_p50_us: us(win.p50()),
            win_p99_us: us(win.p99()),
            win_samples: win.count(),
            rejected: m.rejected(),
            errors: m.errors(),
            sheds: m.sheds(),
            speculative: m.speculative(),
            upgrades: m.upgrades(),
            revoked: m.revoked(),
            slo_rejects: m.slo_rejects(),
            swaps: m.swaps(),
            worker_restarts: m.worker_restarts(),
            poisoned: m.poisoned(),
            nonfinite_rows: m.nonfinite_rows(),
            live_workers: m.live_workers(),
            rank: m.rank(),
            measured_quality: m.measured_quality(),
        }
    }

    /// Serialize as one JSON object (all counters as numbers).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("tier", self.tier.as_str())
            .set("queue_depth", self.queue_depth)
            .set("requests", self.requests as f64)
            .set("batches", self.batches as f64)
            .set("mean_occupancy", self.mean_occupancy)
            .set("tokens", self.tokens as f64)
            .set("p50_us", self.p50_us)
            .set("p99_us", self.p99_us)
            .set("win_p50_us", self.win_p50_us)
            .set("win_p99_us", self.win_p99_us)
            .set("win_samples", self.win_samples as f64)
            .set("rejected", self.rejected as f64)
            .set("errors", self.errors as f64)
            .set("sheds", self.sheds as f64)
            .set("speculative", self.speculative as f64)
            .set("upgrades", self.upgrades as f64)
            .set("revoked", self.revoked as f64)
            .set("slo_rejects", self.slo_rejects as f64)
            .set("swaps", self.swaps as f64)
            .set("worker_restarts", self.worker_restarts as f64)
            .set("poisoned", self.poisoned as f64)
            .set("nonfinite_rows", self.nonfinite_rows as f64)
            .set("live_workers", self.live_workers as f64)
            .set("rank", self.rank as f64);
        // JSON has no NaN: the key is simply absent until the sensor has
        // measured (consumers treat "missing" as "static score only").
        if let Some(q) = self.measured_quality {
            o.set("measured_quality", q);
        }
        o
    }
}

/// The whole registry frozen at a point in time, one [`TierSnapshot`]
/// per tier, sorted by tier name.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub tiers: Vec<TierSnapshot>,
}

impl MetricsSnapshot {
    /// Serialize as `{"tiers": [...]}`.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set(
            "tiers",
            Json::Arr(self.tiers.iter().map(TierSnapshot::to_json).collect()),
        );
        o
    }

    /// Emit one `op = "tier_snapshot"` entry per tier into a bench
    /// report — benches and the CI overload-smoke lane consume the same
    /// shape `BENCH_serve.json` carries everywhere else.
    pub fn report_into(&self, report: &mut crate::util::bench::JsonReport) {
        for t in &self.tiers {
            let mut e = t.to_json();
            e.set("op", "tier_snapshot").set("shape", t.tier.as_str());
            report.push_entry(e);
        }
    }
}

/// Server-wide metrics registry: one [`TierMetrics`] per registered tier.
#[derive(Default)]
pub struct Metrics {
    tiers: Mutex<HashMap<String, Arc<TierMetrics>>>,
}

impl Metrics {
    fn locked(&self) -> MutexGuard<'_, HashMap<String, Arc<TierMetrics>>> {
        crate::util::lock_ignore_poison(&self.tiers)
    }

    /// Register (or fetch) the counters for `tier`.
    pub(crate) fn tier_entry(&self, tier: &str) -> Arc<TierMetrics> {
        Arc::clone(
            self.locked()
                .entry(tier.to_string())
                .or_insert_with(|| Arc::new(TierMetrics::default())),
        )
    }

    /// Drop a tier's counters (registration rollback — a tier that never
    /// went live must not leave a ghost row in the report).
    pub(crate) fn remove_tier(&self, tier: &str) {
        self.locked().remove(tier);
    }

    /// Counters for `tier`, if registered.
    pub fn tier(&self, tier: &str) -> Option<Arc<TierMetrics>> {
        self.locked().get(tier).cloned()
    }

    /// Completed requests summed over all tiers.
    pub fn total_requests(&self) -> u64 {
        self.locked().values().map(|t| t.requests()).sum()
    }

    /// Freeze every tier's counters into one JSON-serializable struct
    /// (sorted by tier name) — the single shape examples, benches, and
    /// CI read.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let map = self.locked();
        let mut names: Vec<&String> = map.keys().collect();
        names.sort();
        MetricsSnapshot {
            tiers: names
                .into_iter()
                .map(|n| TierSnapshot::from_tier(n, &map[n]))
                .collect(),
        }
    }

    /// Render a per-tier summary table (example epilogues, `serve` demos).
    /// Every [`TierSnapshot`] counter family has a column — including the
    /// hot-swap, supervision, and quarantine counters — so the human
    /// table never lags the machine-readable snapshot.
    pub fn report(&self) -> String {
        let snap = self.snapshot();
        let mut t = crate::util::bench::Table::new(&[
            "tier", "requests", "batches", "occ", "tokens", "depth", "p50", "p99", "rejected",
            "errors", "sheds", "upgrades", "slo_rej", "swaps", "restarts", "poisoned", "nonfin",
            "live", "quality",
        ]);
        for s in &snap.tiers {
            t.row(&[
                s.tier.clone(),
                s.requests.to_string(),
                s.batches.to_string(),
                format!("{:.2}", s.mean_occupancy),
                s.tokens.to_string(),
                s.queue_depth.to_string(),
                crate::util::human_duration(Duration::from_secs_f64(s.p50_us / 1e6)),
                crate::util::human_duration(Duration::from_secs_f64(s.p99_us / 1e6)),
                s.rejected.to_string(),
                s.errors.to_string(),
                s.sheds.to_string(),
                s.upgrades.to_string(),
                s.slo_rejects.to_string(),
                s.swaps.to_string(),
                s.worker_restarts.to_string(),
                s.poisoned.to_string(),
                s.nonfinite_rows.to_string(),
                s.live_workers.to_string(),
                match s.measured_quality {
                    Some(q) => format!("{q:.4}"),
                    None => "-".to_string(),
                },
            ]);
        }
        t.render()
    }

    /// Render every tier's counters in Prometheus text exposition format
    /// (version 0.0.4): monotone counters as `panther_<name>_total` and
    /// point-in-time readings as `panther_<name>` gauges, one
    /// `{tier="..."}` sample per registered tier. Scrape-ready — serve it
    /// from any HTTP handler as `text/plain; version=0.0.4`.
    pub fn prometheus(&self) -> String {
        let snap = self.snapshot();
        let mut out = String::new();
        let counters: [(&str, fn(&TierSnapshot) -> f64); 14] = [
            ("requests", |s| s.requests as f64),
            ("batches", |s| s.batches as f64),
            ("tokens", |s| s.tokens as f64),
            ("rejected", |s| s.rejected as f64),
            ("errors", |s| s.errors as f64),
            ("sheds", |s| s.sheds as f64),
            ("speculative", |s| s.speculative as f64),
            ("upgrades", |s| s.upgrades as f64),
            ("revoked", |s| s.revoked as f64),
            ("slo_rejects", |s| s.slo_rejects as f64),
            ("swaps", |s| s.swaps as f64),
            ("worker_restarts", |s| s.worker_restarts as f64),
            ("poisoned", |s| s.poisoned as f64),
            ("nonfinite_rows", |s| s.nonfinite_rows as f64),
        ];
        for (name, get) in counters {
            out.push_str(&format!("# TYPE panther_{name}_total counter\n"));
            for s in &snap.tiers {
                out.push_str(&format!(
                    "panther_{name}_total{{tier=\"{}\"}} {}\n",
                    s.tier,
                    get(s)
                ));
            }
        }
        let gauges: [(&str, fn(&TierSnapshot) -> Option<f64>); 7] = [
            ("queue_depth", |s| Some(s.queue_depth as f64)),
            ("mean_occupancy", |s| Some(s.mean_occupancy)),
            ("live_workers", |s| Some(s.live_workers as f64)),
            ("rank", |s| Some(s.rank as f64)),
            ("latency_p50_us", |s| Some(s.p50_us)),
            ("latency_p99_us", |s| Some(s.p99_us)),
            ("measured_quality", |s| s.measured_quality),
        ];
        for (name, get) in gauges {
            out.push_str(&format!("# TYPE panther_{name} gauge\n"));
            for s in &snap.tiers {
                // Prometheus has no "missing": an unmeasured gauge emits
                // no sample for that tier rather than a fake zero.
                if let Some(v) = get(s) {
                    out.push_str(&format!("panther_{name}{{tier=\"{}\"}} {v}\n", s.tier));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_counters_aggregate() {
        let m = Metrics::default();
        let t = m.tier_entry("dense");
        t.depth_add(3);
        t.depth_sub(2);
        t.record_batch(2, 4);
        t.record_batch(4, 4);
        t.record_latency(Duration::from_millis(2));
        t.record_latency(Duration::from_millis(8));
        t.record_rejected();
        t.record_error(2);
        t.record_tokens(48);
        assert_eq!(t.tokens(), 48);
        assert_eq!(t.queue_depth(), 1);
        assert_eq!(t.batches(), 2);
        assert_eq!(t.requests(), 6);
        assert!((t.mean_occupancy() - 3.0).abs() < 1e-12);
        assert_eq!(t.occupancy_buckets(), vec![0, 1, 0, 1]);
        assert!(t.latency_p50() <= t.latency_p99());
        assert!(t.latency_p99() <= Duration::from_millis(8));
        assert_eq!(t.rejected(), 1);
        assert_eq!(t.errors(), 2);
        assert_eq!(m.total_requests(), 6);
        // Same entry handed back on re-registration.
        let t2 = m.tier_entry("dense");
        assert_eq!(t2.batches(), 2);
        assert!(m.tier("nope").is_none());
        let rep = m.report();
        assert!(rep.contains("| dense"), "{rep}");
    }

    #[test]
    fn slo_counters_and_exec_sensor() {
        let m = Metrics::default();
        let t = m.tier_entry("dense");
        t.record_shed();
        t.record_shed();
        t.record_speculative();
        t.record_upgrade();
        t.record_revoked();
        t.record_slo_reject();
        assert_eq!(t.sheds(), 2);
        assert_eq!(t.speculative(), 1);
        assert_eq!(t.upgrades(), 1);
        assert_eq!(t.revoked(), 1);
        assert_eq!(t.slo_rejects(), 1);
        t.record_exec(Duration::from_millis(3));
        t.record_exec(Duration::from_millis(5));
        assert!(t.exec_p50() > Duration::ZERO);
        assert!(t.exec_p50() <= t.exec_p99());
        // Freshly recorded samples are inside the sliding window.
        let win = t.windowed_exec();
        assert_eq!(win.count(), 2);
        assert!(win.p99() <= Duration::from_millis(5));
        t.record_latency(Duration::from_millis(7));
        assert_eq!(t.windowed_latency().count(), 1);
    }

    #[test]
    fn rotating_window_saturates_huge_epoch_gaps() {
        // Regression: the old re-anchor multiplied `WINDOW_EPOCH` by
        // `steps.min(u32::MAX)`, so an idle gap of more than u32::MAX
        // epochs truncated — and even in-range products pushed `started`
        // decades into the future, where monotonic `elapsed()` saturates
        // to zero and the window never rotates again. The saturating
        // advance clears the ring in one move instead.
        let mut w = RotatingWindow::default();
        w.hist.record(Duration::from_millis(3));
        // Exactly the overflow boundary and far past it: both clear.
        for steps in [
            WINDOW_EPOCHS as u64,
            u32::MAX as u64,
            u32::MAX as u64 + 1,
            u64::MAX,
        ] {
            w.hist.record(Duration::from_millis(3));
            w.advance(steps);
            assert_eq!(w.hist.snapshot().count(), 0, "steps={steps}");
            // `started` is re-anchored at "now", not in the future: the
            // next record lands in a live epoch and is visible.
            w.hist.record(Duration::from_millis(1));
            assert_eq!(w.hist.snapshot().count(), 1, "steps={steps}");
            w.advance(WINDOW_EPOCHS as u64);
        }
        // Below a full ring the advance is a plain rotation: a sample
        // survives epochs-1 steps and expires on the next.
        w.hist.record(Duration::from_millis(2));
        w.advance(WINDOW_EPOCHS as u64 - 1);
        assert_eq!(w.hist.snapshot().count(), 1);
        w.advance(1);
        assert_eq!(w.hist.snapshot().count(), 0);
    }

    #[test]
    fn swap_and_quality_gauges_reach_snapshot_json() {
        let m = Metrics::default();
        let t = m.tier_entry("sk");
        // Unmeasured: no key in the JSON, None in the snapshot.
        let snap = m.snapshot();
        assert_eq!(snap.tiers[0].measured_quality, None);
        assert_eq!(snap.tiers[0].swaps, 0);
        assert!(snap.to_json().to_pretty().contains("\"swaps\""));
        assert!(!snap.to_json().to_pretty().contains("measured_quality"));
        t.record_swap();
        t.record_swap();
        t.set_rank(12);
        t.set_measured_quality(0.875);
        assert_eq!(t.swaps(), 2);
        assert_eq!(t.rank(), 12);
        assert_eq!(t.measured_quality(), Some(0.875));
        let snap = m.snapshot();
        assert_eq!(snap.tiers[0].swaps, 2);
        assert_eq!(snap.tiers[0].rank, 12);
        assert_eq!(snap.tiers[0].measured_quality, Some(0.875));
        let doc = Json::parse(&snap.to_json().to_pretty()).unwrap();
        let tiers = doc.get("tiers").and_then(Json::as_arr).unwrap();
        assert_eq!(tiers[0].get("swaps").and_then(Json::as_f64), Some(2.0));
        assert_eq!(tiers[0].get("rank").and_then(Json::as_f64), Some(12.0));
        assert_eq!(
            tiers[0].get("measured_quality").and_then(Json::as_f64),
            Some(0.875)
        );
    }

    #[test]
    fn fault_counters_and_quality_ratchet() {
        let m = Metrics::default();
        let t = m.tier_entry("dense");
        t.set_live_workers(3);
        t.live_workers_sub(1);
        t.record_worker_restart();
        t.live_workers_add(1);
        t.record_poisoned();
        t.record_nonfinite_rows(4);
        assert_eq!(t.worker_restarts(), 1);
        assert_eq!(t.poisoned(), 1);
        assert_eq!(t.nonfinite_rows(), 4);
        assert_eq!(t.live_workers(), 3);
        // The guard only ratchets quality down; measurements raise it.
        t.degrade_measured_quality(0.5);
        assert_eq!(t.measured_quality(), Some(0.5));
        t.degrade_measured_quality(0.8);
        assert_eq!(t.measured_quality(), Some(0.5));
        t.set_measured_quality(0.9);
        assert_eq!(t.measured_quality(), Some(0.9));
        let snap = m.snapshot();
        assert_eq!(snap.tiers[0].worker_restarts, 1);
        assert_eq!(snap.tiers[0].poisoned, 1);
        assert_eq!(snap.tiers[0].nonfinite_rows, 4);
        assert_eq!(snap.tiers[0].live_workers, 3);
        let doc = Json::parse(&snap.to_json().to_pretty()).unwrap();
        let tiers = doc.get("tiers").and_then(Json::as_arr).unwrap();
        assert_eq!(
            tiers[0].get("worker_restarts").and_then(Json::as_f64),
            Some(1.0)
        );
        assert_eq!(tiers[0].get("poisoned").and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            tiers[0].get("nonfinite_rows").and_then(Json::as_f64),
            Some(4.0)
        );
        assert_eq!(
            tiers[0].get("live_workers").and_then(Json::as_f64),
            Some(3.0)
        );
    }

    #[test]
    fn report_table_tracks_every_snapshot_family() {
        let m = Metrics::default();
        let t = m.tier_entry("dense");
        t.record_swap();
        t.record_worker_restart();
        t.record_poisoned();
        t.record_nonfinite_rows(2);
        t.set_live_workers(3);
        let rep = m.report();
        for col in ["swaps", "restarts", "poisoned", "nonfin", "live", "quality"] {
            assert!(rep.contains(col), "missing column {col}:\n{rep}");
        }
        // Unmeasured quality renders as a dash, not a fake number.
        assert!(rep.contains('-'), "{rep}");
        t.set_measured_quality(0.875);
        assert!(m.report().contains("0.8750"), "{}", m.report());
    }

    #[test]
    fn prometheus_exposition_shape() {
        let m = Metrics::default();
        let t = m.tier_entry("dense");
        t.record_batch(2, 4);
        t.record_error(1);
        t.record_poisoned();
        t.set_live_workers(2);
        let text = m.prometheus();
        assert!(text.contains("# TYPE panther_requests_total counter\n"));
        assert!(text.contains("panther_requests_total{tier=\"dense\"} 2\n"));
        assert!(text.contains("panther_errors_total{tier=\"dense\"} 1\n"));
        assert!(text.contains("panther_poisoned_total{tier=\"dense\"} 1\n"));
        assert!(text.contains("# TYPE panther_live_workers gauge\n"));
        assert!(text.contains("panther_live_workers{tier=\"dense\"} 2\n"));
        // Unmeasured quality emits no sample (never a fake zero).
        assert!(!text.contains("panther_measured_quality{"));
        t.set_measured_quality(0.5);
        assert!(m
            .prometheus()
            .contains("panther_measured_quality{tier=\"dense\"} 0.5\n"));
        // Every line is either a TYPE comment or `name{tier="..."} value`.
        for line in text.lines() {
            assert!(
                line.starts_with("# TYPE panther_") || line.starts_with("panther_"),
                "{line}"
            );
        }
    }

    #[test]
    fn snapshot_serializes_and_roundtrips() {
        let m = Metrics::default();
        let t = m.tier_entry("a");
        t.record_batch(3, 4);
        t.record_latency(Duration::from_millis(2));
        t.record_shed();
        m.tier_entry("b").record_batch(1, 2);
        let snap = m.snapshot();
        assert_eq!(snap.tiers.len(), 2);
        assert_eq!(snap.tiers[0].tier, "a", "sorted by name");
        assert_eq!(snap.tiers[0].requests, 3);
        assert_eq!(snap.tiers[0].sheds, 1);
        assert_eq!(snap.tiers[0].win_samples, 1);
        assert!(snap.tiers[0].win_p50_us > 0.0);
        // Through the JSON writer and back.
        let doc = Json::parse(&snap.to_json().to_pretty()).unwrap();
        let tiers = doc.get("tiers").and_then(Json::as_arr).unwrap();
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].get("tier").and_then(Json::as_str), Some("a"));
        assert_eq!(tiers[0].get("sheds").and_then(Json::as_f64), Some(1.0));
        // And through a JsonReport as tier_snapshot entries.
        let mut r = crate::util::bench::JsonReport::new("unit", 1);
        snap.report_into(&mut r);
        let doc = Json::parse(&r.to_json().to_pretty()).unwrap();
        let entries = doc.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(
            entries[0].get("op").and_then(Json::as_str),
            Some("tier_snapshot")
        );
        assert_eq!(entries[0].get("shape").and_then(Json::as_str), Some("a"));
    }
}
