//! Serving metrics: per-tier queue depth, batch-occupancy histogram, and
//! latency percentiles.
//!
//! Reuses the [`crate::util::stats`] histogram shapes that
//! `coordinator::batcher` records (one [`OccupancyHist`] per batching
//! queue) and follows the same interior-mutability pattern as
//! [`crate::coordinator::CoordinatorMetrics`]: workers write through
//! `&self`, anyone reads, locks are poison-tolerant so a panicking worker
//! cannot cascade into panics on every later read.

use crate::util::stats::{DurationHist, OccupancyHist};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

/// Live counters for one tier. Shared (`Arc`) between the tier's queue,
/// its workers, and the server-level [`Metrics`] registry.
#[derive(Default)]
pub struct TierMetrics {
    /// Requests enqueued but not yet picked into a batch.
    depth: AtomicUsize,
    /// Requests turned away by admission control (`try_submit` on a full
    /// queue).
    rejected: AtomicU64,
    /// Requests answered with an execution error.
    errors: AtomicU64,
    /// Token rows executed through packed sequence steps (sequence tiers
    /// only — row tiers leave this at zero).
    tokens: AtomicU64,
    occupancy: Mutex<OccupancyHist>,
    /// End-to-end latency (enqueue → reply), queue wait included.
    latency: Mutex<DurationHist>,
}

impl TierMetrics {
    fn occ(&self) -> MutexGuard<'_, OccupancyHist> {
        crate::util::lock_ignore_poison(&self.occupancy)
    }

    fn lat(&self) -> MutexGuard<'_, DurationHist> {
        crate::util::lock_ignore_poison(&self.latency)
    }

    pub(crate) fn depth_add(&self, n: usize) {
        self.depth.fetch_add(n, Ordering::SeqCst);
    }

    pub(crate) fn depth_sub(&self, n: usize) {
        self.depth.fetch_sub(n, Ordering::SeqCst);
    }

    pub(crate) fn record_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::SeqCst);
    }

    pub(crate) fn record_error(&self, n: u64) {
        self.errors.fetch_add(n, Ordering::SeqCst);
    }

    pub(crate) fn record_batch(&self, used: usize, capacity: usize) {
        self.occ().record(used, capacity);
    }

    pub(crate) fn record_tokens(&self, n: u64) {
        self.tokens.fetch_add(n, Ordering::SeqCst);
    }

    pub(crate) fn record_latency(&self, d: Duration) {
        self.lat().record(d);
    }

    /// Requests currently queued (submitted, not yet batched).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    /// Requests rejected by admission control.
    pub fn rejected(&self) -> u64 {
        self.rejected.load(Ordering::SeqCst)
    }

    /// Requests answered with an execution error.
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::SeqCst)
    }

    /// Token rows executed through packed sequence steps.
    pub fn tokens(&self) -> u64 {
        self.tokens.load(Ordering::SeqCst)
    }

    /// Batches executed.
    pub fn batches(&self) -> u64 {
        self.occ().batches()
    }

    /// Requests that completed through an executed batch.
    pub fn requests(&self) -> u64 {
        self.occ().requests()
    }

    /// Mean live rows per executed batch.
    pub fn mean_occupancy(&self) -> f64 {
        self.occ().mean()
    }

    /// Snapshot of the occupancy histogram (index = rows used − 1).
    pub fn occupancy_buckets(&self) -> Vec<u64> {
        self.occ().buckets().to_vec()
    }

    /// Median end-to-end latency (approximate; see
    /// [`DurationHist::quantile`]).
    pub fn latency_p50(&self) -> Duration {
        self.lat().p50()
    }

    /// 99th-percentile end-to-end latency (approximate).
    pub fn latency_p99(&self) -> Duration {
        self.lat().p99()
    }

    /// Mean end-to-end latency (exact).
    pub fn latency_mean(&self) -> Duration {
        self.lat().mean()
    }
}

/// Server-wide metrics registry: one [`TierMetrics`] per registered tier.
#[derive(Default)]
pub struct Metrics {
    tiers: Mutex<HashMap<String, Arc<TierMetrics>>>,
}

impl Metrics {
    fn locked(&self) -> MutexGuard<'_, HashMap<String, Arc<TierMetrics>>> {
        crate::util::lock_ignore_poison(&self.tiers)
    }

    /// Register (or fetch) the counters for `tier`.
    pub(crate) fn tier_entry(&self, tier: &str) -> Arc<TierMetrics> {
        Arc::clone(
            self.locked()
                .entry(tier.to_string())
                .or_insert_with(|| Arc::new(TierMetrics::default())),
        )
    }

    /// Drop a tier's counters (registration rollback — a tier that never
    /// went live must not leave a ghost row in the report).
    pub(crate) fn remove_tier(&self, tier: &str) {
        self.locked().remove(tier);
    }

    /// Counters for `tier`, if registered.
    pub fn tier(&self, tier: &str) -> Option<Arc<TierMetrics>> {
        self.locked().get(tier).cloned()
    }

    /// Completed requests summed over all tiers.
    pub fn total_requests(&self) -> u64 {
        self.locked().values().map(|t| t.requests()).sum()
    }

    /// Render a per-tier summary table (example epilogues, `serve` demos).
    pub fn report(&self) -> String {
        let map = self.locked();
        let mut names: Vec<&String> = map.keys().collect();
        names.sort();
        let mut t = crate::util::bench::Table::new(&[
            "tier", "requests", "batches", "occ", "tokens", "depth", "p50", "p99", "rejected",
            "errors",
        ]);
        for n in names {
            let m = &map[n];
            t.row(&[
                n.clone(),
                m.requests().to_string(),
                m.batches().to_string(),
                format!("{:.2}", m.mean_occupancy()),
                m.tokens().to_string(),
                m.queue_depth().to_string(),
                crate::util::human_duration(m.latency_p50()),
                crate::util::human_duration(m.latency_p99()),
                m.rejected().to_string(),
                m.errors().to_string(),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_counters_aggregate() {
        let m = Metrics::default();
        let t = m.tier_entry("dense");
        t.depth_add(3);
        t.depth_sub(2);
        t.record_batch(2, 4);
        t.record_batch(4, 4);
        t.record_latency(Duration::from_millis(2));
        t.record_latency(Duration::from_millis(8));
        t.record_rejected();
        t.record_error(2);
        t.record_tokens(48);
        assert_eq!(t.tokens(), 48);
        assert_eq!(t.queue_depth(), 1);
        assert_eq!(t.batches(), 2);
        assert_eq!(t.requests(), 6);
        assert!((t.mean_occupancy() - 3.0).abs() < 1e-12);
        assert_eq!(t.occupancy_buckets(), vec![0, 1, 0, 1]);
        assert!(t.latency_p50() <= t.latency_p99());
        assert!(t.latency_p99() <= Duration::from_millis(8));
        assert_eq!(t.rejected(), 1);
        assert_eq!(t.errors(), 2);
        assert_eq!(m.total_requests(), 6);
        // Same entry handed back on re-registration.
        let t2 = m.tier_entry("dense");
        assert_eq!(t2.batches(), 2);
        assert!(m.tier("nope").is_none());
        let rep = m.report();
        assert!(rep.contains("| dense"), "{rep}");
    }
}
