//! Online rank adaptation: measure each sketched tier's *actual* quality
//! against its dense sibling on live traffic, and move the tier up or
//! down the rank ladder through atomic hot-swaps — without dropping or
//! corrupting a single in-flight request.
//!
//! The serving stack registers a sketched tier with a *static* quality
//! label chosen offline. Real traffic drifts: the rank that was accurate
//! enough on the tuning set may not be on today's inputs, and a rank
//! that is more accurate than needed wastes memory and time. The
//! [`RankAdapter`] closes that loop with three pieces:
//!
//! 1. **Shadow stream** — [`RankAdapter::observe`] retains a bounded
//!    ring of real admitted rows. [`RankAdapter::measure`] replays them
//!    through the tier's *current* model and through the dense reference
//!    (both via [`Model::forward_rows`] padded to the tier's batch cap —
//!    the exact serving forward), records each row's relative L2 error
//!    into a [`WindowedHist`] sensor, and publishes `1 − mean error` as
//!    the tier's measured quality ([`super::TierMetrics`] gauge). The
//!    window spans the last [`AdaptConfig::sensor_epochs`] measurement
//!    rounds, so stale history ages out by construction.
//! 2. **Controller** — [`RankAdapter::step`] compares the windowed mean
//!    error against [`AdaptConfig::target_err`]: too much error proposes
//!    richer ranks (up to and including the dense reference itself),
//!    comfortably below `target − hysteresis` proposes the next cheaper
//!    rank. Candidates are evaluated on the same shadow ring through a
//!    [`crate::tuner`] [`Study`] (grid-sampled, median-pruned on
//!    per-chunk interim error), constrained by the tier's memory
//!    headroom ([`AdaptConfig::mem_budget`]); the cheapest candidate
//!    meeting the error ceiling wins. The decision rule is deterministic
//!    — same shadow rows, same verdict.
//! 3. **Atomic apply** — the winning candidate is rebuilt from the dense
//!    reference through [`SketchPlan`] (per-layer seeds derived from
//!    [`AdaptConfig::sketch_seed`] and the layer name, so an identical
//!    standalone build is *bitwise* identical) and published through
//!    [`super::ModelServer::swap_tier_model`]: requests admitted before
//!    the swap reply from the old version bit-for-bit, batches never mix
//!    versions, and workers are untouched.
//!
//! The cascade ([`super::Cascade`]) reads the measured-quality gauge on
//! every submit, so a swap (or a drift) re-orders SLO routing as soon as
//! the adapter re-measures — no rebuild, no restart.

use super::batcher::ModelSlot;
use super::metrics::TierMetrics;
use super::router::{probe_model, Tier};
use super::{ModelServer, ServeError, SwapHandle};
use crate::nn::{ForwardCtx, LayerSelector, Model, SketchPlan};
use crate::tuner::{Direction, GridSampler, MedianPruner, ParamValue, SearchSpace, Study, Trial};
use crate::util::lock_ignore_poison;
use crate::util::stats::WindowedHist;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Policy knobs for one tier's rank adapter. Fields are public — set
/// what the defaults from [`AdaptConfig::new`] don't cover.
pub struct AdaptConfig {
    /// Candidate sketch ranks, strictly ascending (higher rank = closer
    /// to dense = better quality). The dense reference itself is always
    /// the implicit top rung, reported as rank `0`.
    pub ladder: Vec<usize>,
    /// Sketch terms per converted layer (the `l` of the paper's
    /// `(l, k)`).
    pub num_terms: usize,
    /// Base seed of every candidate's [`SketchPlan`]. Per-layer seeds
    /// derive from this and the layer *name*, so a standalone model
    /// sketched with the same seed/rank is bitwise identical to what
    /// the adapter publishes — the hot-swap stress tests rely on it.
    pub sketch_seed: u64,
    /// Which layers each candidate plan converts.
    pub selector: LayerSelector,
    /// Relative-error ceiling the controller keeps the tier under: mean
    /// windowed error above this proposes a richer rank.
    pub target_err: f64,
    /// Down-move margin: a cheaper rank is adopted only while error
    /// stays under `target_err − hysteresis`, which keeps the
    /// controller from oscillating at the boundary. `0.0` (the
    /// constructor default) means `target_err / 4`.
    pub hysteresis: f64,
    /// Optional memory ceiling for *up*-moves: a candidate is rejected
    /// unless its weights plus every worker's probe-measured batch
    /// footprint fit. `None` = unconstrained.
    pub mem_budget: Option<u64>,
    /// Rows retained in the shadow ring (FIFO beyond this).
    pub shadow_capacity: usize,
    /// Sketch rank of the model the tier is *currently* serving (`0` if
    /// it serves the dense reference). Must be `0` or a ladder entry.
    pub initial_rank: usize,
    /// Measurement rounds the quality sensor's sliding window spans.
    pub sensor_epochs: usize,
}

impl AdaptConfig {
    /// Config with conventional defaults: 1 sketch term, seed 7, 5%
    /// error target, `target/4` hysteresis, 256 shadow rows, dense
    /// start, 8-round sensor window, no memory ceiling.
    pub fn new(selector: LayerSelector, ladder: &[usize]) -> Self {
        AdaptConfig {
            ladder: ladder.to_vec(),
            num_terms: 1,
            sketch_seed: 7,
            selector,
            target_err: 0.05,
            hysteresis: 0.0,
            mem_budget: None,
            shadow_capacity: 256,
            initial_rank: 0,
            sensor_epochs: 8,
        }
    }
}

/// One [`RankAdapter::measure`] round's result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QualityReading {
    /// Windowed mean relative L2 error vs. the dense reference.
    pub mean_err: f64,
    /// `1 − mean_err` clamped to `[0, 1]` — what the metrics gauge and
    /// the cascade see.
    pub quality: f64,
    /// Shadow rows replayed this round.
    pub rows: usize,
    /// Total samples in the sensor window backing `mean_err`.
    pub window_rows: u64,
}

/// Why [`RankAdapter::step`] held instead of swapping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HoldReason {
    /// No shadow rows observed yet — nothing to measure against.
    NoShadowTraffic,
    /// Error is inside the `[target − hysteresis, target]` band.
    WithinBand,
    /// Error exceeds the target but the tier already serves the dense
    /// reference — there is no richer rung.
    AtBestRung,
    /// Error clears the down-move margin but the tier already serves
    /// the cheapest ladder rank.
    AtCheapestRung,
    /// Every proposed candidate failed shadow evaluation (error above
    /// the ceiling, pruned, or over the memory budget).
    CandidateRejected,
}

/// What one [`RankAdapter::step`] decided.
#[derive(Debug, Clone, PartialEq)]
pub enum AdaptDecision {
    /// No swap; `live_err` is this round's windowed mean error when one
    /// was measurable.
    Hold {
        reason: HoldReason,
        live_err: Option<f64>,
    },
    /// A new model version was published.
    Swapped {
        /// Rank served before the swap (`0` = dense).
        from_rank: usize,
        /// Rank serving now (`0` = dense).
        to_rank: usize,
        /// Version number [`super::ModelServer::swap_tier_model`]
        /// returned.
        version: u64,
        /// The live windowed error that triggered the move.
        live_err: f64,
        /// The winning candidate's shadow-replay error.
        candidate_err: f64,
    },
}

/// Guard against NaN/Inf from degenerate outputs: a non-finite error
/// reads as total disagreement.
fn sane(err: f64) -> f64 {
    if err.is_finite() {
        err.clamp(0.0, 1.0)
    } else {
        1.0
    }
}

/// Errors ride through the [`WindowedHist`] as durations: `err` seconds
/// keeps nanosecond (1e-9) resolution over the whole `[0, 1]` range.
fn err_to_dur(err: f64) -> Duration {
    Duration::from_secs_f64(sane(err))
}

/// Online rank controller for one row tier (see module docs). One
/// adapter per tier; drive it from a single control thread —
/// measurement and stepping take `&mut self` by design.
pub struct RankAdapter {
    tier: String,
    reference: Model,
    cfg: AdaptConfig,
    slot: Arc<ModelSlot>,
    metrics: Arc<TierMetrics>,
    in_dim: usize,
    max_batch: usize,
    workers: usize,
    peak_batch_bytes: u64,
    shadow: VecDeque<Vec<f32>>,
    sensor: WindowedHist,
    rank: usize,
    rounds: usize,
}

impl RankAdapter {
    /// Attach an adapter to row tier `tier` of `server`. `reference` is
    /// the dense model every candidate is rebuilt (and every quality
    /// measurement is judged) against — pass a clone of the weights the
    /// tier was sketched from. Validates the config, probes the
    /// reference against the tier's interface, and pre-builds the
    /// cheapest candidate so a selector that matches nothing fails here
    /// rather than mid-adaptation.
    pub fn new(
        server: &ModelServer,
        tier: &str,
        reference: Model,
        cfg: AdaptConfig,
    ) -> Result<RankAdapter, ServeError> {
        let bad = |m: String| Err(ServeError::BadInput(m));
        if cfg.ladder.is_empty() {
            return bad("adapt ladder must name at least one sketch rank".into());
        }
        if cfg.ladder.windows(2).any(|w| w[0] >= w[1]) || cfg.ladder[0] == 0 {
            return bad(format!(
                "adapt ladder {:?} must be strictly ascending positive ranks",
                cfg.ladder
            ));
        }
        if cfg.num_terms == 0 || cfg.shadow_capacity == 0 || cfg.sensor_epochs == 0 {
            return bad("num_terms, shadow_capacity and sensor_epochs must be positive".into());
        }
        if !(cfg.target_err.is_finite() && cfg.target_err > 0.0) {
            return bad(format!("target_err {} must be finite and positive", cfg.target_err));
        }
        if !(cfg.hysteresis.is_finite() && (0.0..cfg.target_err).contains(&cfg.hysteresis)) {
            return bad(format!(
                "hysteresis {} must be finite, non-negative and below target_err {}",
                cfg.hysteresis, cfg.target_err
            ));
        }
        if cfg.initial_rank != 0 && !cfg.ladder.contains(&cfg.initial_rank) {
            return bad(format!(
                "initial_rank {} is neither 0 (dense) nor a ladder entry of {:?}",
                cfg.initial_rank, cfg.ladder
            ));
        }
        let t = server.router.get(tier)?;
        let (info, slot) = match &*t {
            Tier::Row { info, slot, .. } => (info.clone(), Arc::clone(slot)),
            Tier::Seq { .. } => {
                return bad(format!(
                    "tier {tier} is a sequence tier — rank adaptation serves row tiers only"
                ))
            }
        };
        // The reference must present the tier's exact raw interface:
        // candidates built from it inherit the widths the swap probe
        // enforces, and quality deltas are only meaningful against a
        // reference answering the same question.
        let raw_out = t.raw_out_dim().expect("row tier has a raw width");
        let probe = probe_model(&reference, info.in_dim, info.max_batch)?;
        if probe.out_dim != raw_out {
            return bad(format!(
                "dense reference maps {} -> {}, tier {tier} serves {} -> {raw_out}",
                info.in_dim, probe.out_dim, info.in_dim,
            ));
        }
        let metrics = server.metrics.tier_entry(tier);
        metrics.set_rank(cfg.initial_rank);
        let adapter = RankAdapter {
            tier: tier.to_string(),
            reference,
            sensor: WindowedHist::new(cfg.sensor_epochs),
            rank: cfg.initial_rank,
            slot,
            metrics,
            in_dim: info.in_dim,
            max_batch: info.max_batch,
            workers: info.workers,
            peak_batch_bytes: info.peak_batch_bytes,
            shadow: VecDeque::with_capacity(cfg.shadow_capacity.min(4096)),
            rounds: 0,
            cfg,
        };
        // Fail a selector typo at attach time, not on the first move.
        adapter.build(adapter.cfg.ladder[0])?;
        Ok(adapter)
    }

    /// Feed one admitted request row into the shadow ring (FIFO-bounded
    /// at [`AdaptConfig::shadow_capacity`]). Call it from the admission
    /// path on a *sample* of traffic — the ring is the ground truth
    /// every quality measurement and candidate evaluation replays.
    pub fn observe(&mut self, row: &[f32]) -> Result<(), ServeError> {
        if row.len() != self.in_dim {
            return Err(ServeError::BadInput(format!(
                "tier {:?} rows are width {}, got {}",
                self.tier,
                self.in_dim,
                row.len()
            )));
        }
        if self.shadow.len() == self.cfg.shadow_capacity {
            self.shadow.pop_front();
        }
        self.shadow.push_back(row.to_vec());
        Ok(())
    }

    /// The tier this adapter controls.
    pub fn tier(&self) -> &str {
        &self.tier
    }

    /// Sketch rank currently served (`0` = dense).
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Rows currently in the shadow ring.
    pub fn shadow_len(&self) -> usize {
        self.shadow.len()
    }

    /// Down-move margin in effect (explicit, or the `target/4` default).
    fn margin(&self) -> f64 {
        if self.cfg.hysteresis > 0.0 {
            self.cfg.hysteresis
        } else {
            self.cfg.target_err / 4.0
        }
    }

    /// The full quality ladder, ascending: configured sketch ranks then
    /// the dense reference (rank 0) as the top rung.
    fn positions(&self) -> Vec<usize> {
        let mut p = self.cfg.ladder.clone();
        p.push(0);
        p
    }

    /// Rebuild the candidate at `rank` from the dense reference —
    /// `rank == 0` is the reference itself, otherwise a [`SketchPlan`]
    /// at `(num_terms, rank)` under the configured seed. Deterministic:
    /// the same call always produces bitwise-identical weights.
    fn build(&self, rank: usize) -> Result<Model, ServeError> {
        let mut m = self.reference.clone_model();
        if rank > 0 {
            SketchPlan::new()
                .select(self.cfg.selector.clone())
                .with(self.cfg.num_terms, rank)
                .seed(self.cfg.sketch_seed)
                .apply(&mut m)
                .map_err(|e| ServeError::BadInput(format!("candidate at rank {rank}: {e:#}")))?;
        }
        Ok(m)
    }

    /// Whether `candidate` fits the configured memory ceiling alongside
    /// the tier's worker pool. The per-worker batch footprint is the
    /// registration probe's measurement — activations scale with batch
    /// shape, not weights, so it stays representative across ranks.
    fn mem_ok(&self, candidate: &Model) -> bool {
        match self.cfg.mem_budget {
            None => true,
            Some(budget) => {
                let weights = (candidate.total_params() * 4) as u64;
                weights.saturating_add(self.workers as u64 * self.peak_batch_bytes) <= budget
            }
        }
    }

    /// Per-row relative L2 errors of `model` vs. the dense reference
    /// over `chunk`, replayed exactly like the serving path (padded
    /// row-stack at the tier's batch cap).
    fn chunk_errors(&self, model: &Model, chunk: &[&[f32]]) -> Result<Vec<f64>, ServeError> {
        let exec = |e: anyhow::Error| ServeError::Exec(format!("shadow replay: {e:#}"));
        let ctx = ForwardCtx::new().batch_hint(self.max_batch);
        let got = model.forward_rows(chunk, self.max_batch, &ctx).map_err(exec)?;
        let want = self
            .reference
            .forward_rows(chunk, self.max_batch, &ctx)
            .map_err(exec)?;
        Ok(got
            .iter()
            .zip(&want)
            .map(|(g, w)| {
                let mut diff = 0.0f64;
                let mut norm = 0.0f64;
                for (a, b) in g.iter().zip(w) {
                    diff += (*a as f64 - *b as f64).powi(2);
                    norm += (*b as f64).powi(2);
                }
                sane(diff.sqrt() / (norm.sqrt() + 1e-12))
            })
            .collect())
    }

    /// Replay the whole shadow ring through the tier's **currently
    /// served** model version, fold this round's per-row errors into the
    /// sliding sensor window, and publish the windowed quality to the
    /// tier's metrics (where the cascade reads it). Returns `None` with
    /// an empty ring.
    pub fn measure(&mut self) -> Result<Option<QualityReading>, ServeError> {
        if self.shadow.is_empty() {
            return Ok(None);
        }
        let live = self.slot.current();
        let rows: Vec<&[f32]> = self.shadow.iter().map(|r| r.as_slice()).collect();
        self.sensor.rotate();
        let mut measured = 0usize;
        for chunk in rows.chunks(self.max_batch) {
            for e in self.chunk_errors(&live.model, chunk)? {
                self.sensor.record(err_to_dur(e));
                measured += 1;
            }
        }
        let snap = self.sensor.snapshot();
        let mean_err = snap.mean().as_secs_f64();
        let quality = 1.0 - mean_err.min(1.0);
        self.metrics.set_measured_quality(quality);
        Ok(Some(QualityReading {
            mean_err,
            quality,
            rows: measured,
            window_rows: snap.count(),
        }))
    }

    /// Shadow-evaluate one candidate under the study's pruner: per-chunk
    /// running mean error is reported as the trial's interim objective,
    /// and a hopeless candidate stops early (`None`).
    fn candidate_error(
        &self,
        model: &Model,
        study: &mut Study,
        trial: &mut Trial,
    ) -> Result<Option<f64>, ServeError> {
        let rows: Vec<&[f32]> = self.shadow.iter().map(|r| r.as_slice()).collect();
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for (ci, chunk) in rows.chunks(self.max_batch).enumerate() {
            for e in self.chunk_errors(model, chunk)? {
                sum += e;
                n += 1;
            }
            if study.should_prune(trial, ci, sum / n as f64) {
                return Ok(None);
            }
        }
        Ok(Some(sum / n as f64))
    }

    /// Propose `candidates` (ascending quality = cheapest first) through
    /// a tuner study, adopt the first one whose shadow error clears
    /// `ceiling` and whose weights fit the memory budget, and publish it
    /// atomically. Candidate order is the decision rule: the cheapest
    /// rank that is good enough wins.
    fn try_swap(
        &mut self,
        swap: &SwapHandle,
        candidates: &[usize],
        ceiling: f64,
        live_err: f64,
    ) -> Result<AdaptDecision, ServeError> {
        let ranks: Vec<i64> = candidates.iter().map(|&r| r as i64).collect();
        let space = SearchSpace::new().int_choices("rank", &ranks);
        let mut study = Study::new(
            &format!("adapt-{}-{}", self.tier, self.rounds),
            Direction::Minimize,
            space,
            Box::new(GridSampler::new(self.cfg.sketch_seed)),
            Box::new(MedianPruner {
                n_startup_trials: 1,
                n_warmup_steps: 1,
            }),
        );
        for _ in 0..candidates.len() {
            let mut trial = study.ask();
            let rank = trial
                .params
                .get("rank")
                .and_then(ParamValue::as_usize)
                .expect("rank dimension is a usize grid");
            let candidate = self.build(rank)?;
            if !self.mem_ok(&candidate) {
                study.tell(&mut trial, f64::INFINITY, false);
                continue;
            }
            let Some(err) = self.candidate_error(&candidate, &mut study, &mut trial)? else {
                continue; // pruned — already recorded by should_prune
            };
            let feasible = err <= ceiling;
            study.tell(&mut trial, err, feasible);
            if feasible {
                let version = swap.swap_tier_model(&self.tier, candidate)?;
                let from_rank = self.rank;
                self.rank = rank;
                self.metrics.set_rank(rank);
                // The sensor described the outgoing version: restart it
                // and seed the gauge with the winner's shadow reading so
                // routing never consults stale evidence.
                self.sensor = WindowedHist::new(self.cfg.sensor_epochs);
                self.metrics.set_measured_quality(1.0 - err.min(1.0));
                return Ok(AdaptDecision::Swapped {
                    from_rank,
                    to_rank: rank,
                    version,
                    live_err,
                    candidate_err: err,
                });
            }
        }
        Ok(AdaptDecision::Hold {
            reason: HoldReason::CandidateRejected,
            live_err: Some(live_err),
        })
    }

    /// One controller round: measure, decide, and (maybe) swap — the
    /// deterministic decision rule in the module docs. Call it
    /// periodically from a control loop (or let an [`AdaptDaemon`] call
    /// it on a cadence); it is cheap while the tier holds (one shadow
    /// replay) and does one extra replay per evaluated candidate when it
    /// moves.
    pub fn step(&mut self, server: &ModelServer) -> Result<AdaptDecision, ServeError> {
        self.step_with(&server.swap_handle())
    }

    /// [`RankAdapter::step`] against a [`SwapHandle`] instead of the
    /// server itself — what a background thread holds, since the handle
    /// is `'static` and borrow-free.
    pub fn step_with(&mut self, swap: &SwapHandle) -> Result<AdaptDecision, ServeError> {
        self.rounds += 1;
        let Some(reading) = self.measure()? else {
            return Ok(AdaptDecision::Hold {
                reason: HoldReason::NoShadowTraffic,
                live_err: None,
            });
        };
        let positions = self.positions();
        let cur = positions
            .iter()
            .position(|&r| r == self.rank)
            .expect("current rank validated against the ladder");
        let err = reading.mean_err;
        if err > self.cfg.target_err {
            // Too coarse: walk richer rungs cheapest-first; the dense
            // top rung (error 0) guarantees a feasible candidate unless
            // the memory budget blocks everything.
            if cur + 1 == positions.len() {
                return Ok(AdaptDecision::Hold {
                    reason: HoldReason::AtBestRung,
                    live_err: Some(err),
                });
            }
            let candidates = positions[cur + 1..].to_vec();
            self.try_swap(swap, &candidates, self.cfg.target_err, err)
        } else if err <= self.cfg.target_err - self.margin() {
            // Comfortably accurate: probe exactly one rung down, and
            // only adopt it if it also clears the margin (hysteresis —
            // an adopted down-move must not immediately bounce back).
            if cur == 0 {
                return Ok(AdaptDecision::Hold {
                    reason: HoldReason::AtCheapestRung,
                    live_err: Some(err),
                });
            }
            let candidates = [positions[cur - 1]];
            self.try_swap(swap, &candidates, self.cfg.target_err - self.margin(), err)
        } else {
            Ok(AdaptDecision::Hold {
                reason: HoldReason::WithinBand,
                live_err: Some(err),
            })
        }
    }
}

/// Background cadence thread driving a [`RankAdapter`]: calls
/// [`RankAdapter::step_with`] every `interval` until shut down, so the
/// adapter no longer needs a caller-owned control loop. The daemon owns
/// the adapter behind a mutex and forwards [`AdaptDaemon::observe`] into
/// it, so the shadow stream keeps filling while the controller runs on
/// its own thread.
///
/// Failure policy: a step that errors (e.g. a swap racing a shutdown) is
/// *counted* ([`AdaptDaemon::swap_errors`]) and the cadence continues —
/// a transient swap failure must not kill the control loop. Shutdown is
/// graceful and prompt (the interval sleep is sliced so even long
/// cadences stop within a few milliseconds) and runs on drop too.
pub struct AdaptDaemon {
    adapter: Arc<Mutex<RankAdapter>>,
    stop: Arc<AtomicBool>,
    steps: Arc<AtomicU64>,
    swap_errors: Arc<AtomicU64>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl AdaptDaemon {
    /// Spawn the cadence thread. The daemon holds a [`SwapHandle`], not
    /// the server, so the server stays free for other threads; after
    /// [`ModelServer::shutdown`] the steps keep ticking but swaps return
    /// [`ServeError::ShuttingDown`] (counted, not fatal) until the
    /// daemon itself is shut down.
    pub fn spawn(
        server: &ModelServer,
        adapter: RankAdapter,
        interval: Duration,
    ) -> Result<Self, ServeError> {
        let swap = server.swap_handle();
        let name = format!("panther-adapt-{}", adapter.tier());
        let adapter = Arc::new(Mutex::new(adapter));
        let stop = Arc::new(AtomicBool::new(false));
        let steps = Arc::new(AtomicU64::new(0));
        let swap_errors = Arc::new(AtomicU64::new(0));
        let (a, s, st, se) = (
            Arc::clone(&adapter),
            Arc::clone(&stop),
            Arc::clone(&steps),
            Arc::clone(&swap_errors),
        );
        let handle = std::thread::Builder::new()
            .name(name)
            .spawn(move || {
                while !s.load(Ordering::SeqCst) {
                    // Sliced sleep: a multi-second cadence still reacts
                    // to shutdown within one slice.
                    let wake = Instant::now() + interval;
                    while Instant::now() < wake {
                        if s.load(Ordering::SeqCst) {
                            return;
                        }
                        std::thread::sleep(Duration::from_millis(2).min(interval));
                    }
                    if lock_ignore_poison(&a).step_with(&swap).is_err() {
                        se.fetch_add(1, Ordering::Relaxed);
                    }
                    st.fetch_add(1, Ordering::Relaxed);
                }
            })
            .map_err(|e| ServeError::Spawn(e.to_string()))?;
        Ok(AdaptDaemon {
            adapter,
            stop,
            steps,
            swap_errors,
            handle: Some(handle),
        })
    }

    /// Feed one admitted row into the adapter's shadow ring (see
    /// [`RankAdapter::observe`]).
    pub fn observe(&self, row: &[f32]) -> Result<(), ServeError> {
        lock_ignore_poison(&self.adapter).observe(row)
    }

    /// The adapter's current rank.
    pub fn rank(&self) -> usize {
        lock_ignore_poison(&self.adapter).rank()
    }

    /// Controller rounds completed so far.
    pub fn steps(&self) -> u64 {
        self.steps.load(Ordering::Relaxed)
    }

    /// Steps that returned an error (swap refused, tier gone, server
    /// draining) — counted and survived, per the failure policy.
    pub fn swap_errors(&self) -> u64 {
        self.swap_errors.load(Ordering::Relaxed)
    }

    /// Stop the cadence and join the thread. Also runs on drop.
    pub fn shutdown(mut self) {
        self.halt();
    }

    fn halt(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for AdaptDaemon {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Linear;
    use crate::rng::Philox;
    use crate::serve::TierConfig;

    fn dense(seed: u64) -> Model {
        let mut rng = Philox::seeded(seed);
        let mut m = Model::new();
        m.add("fc1", Linear::random(8, 24, &mut rng)).unwrap();
        m.add("fc2", Linear::random(24, 4, &mut rng)).unwrap();
        m
    }

    fn cfg(ladder: &[usize]) -> AdaptConfig {
        AdaptConfig::new(LayerSelector::by_type("Linear"), ladder)
    }

    #[test]
    fn config_validation_is_typed_and_early() {
        let mut server = ModelServer::new();
        server
            .register_tier("t", dense(1), 8, TierConfig::default())
            .unwrap();
        let reject = |c: AdaptConfig| {
            matches!(
                RankAdapter::new(&server, "t", dense(1), c),
                Err(ServeError::BadInput(_))
            )
        };
        assert!(reject(cfg(&[])), "empty ladder");
        assert!(reject(cfg(&[8, 4])), "descending ladder");
        assert!(reject(cfg(&[0, 4])), "zero rank");
        let mut c = cfg(&[4, 8]);
        c.target_err = 0.0;
        assert!(reject(c), "zero target");
        let mut c = cfg(&[4, 8]);
        c.initial_rank = 6;
        assert!(reject(c), "initial rank outside ladder");
        let mut c = cfg(&[4, 8]);
        c.hysteresis = 1.0;
        assert!(reject(c), "hysteresis at/above target");
        // Selector matching nothing fails at attach, not at first move.
        let c = AdaptConfig::new(LayerSelector::by_names(&["nope"]), &[4]);
        assert!(matches!(
            RankAdapter::new(&server, "t", dense(1), c),
            Err(ServeError::BadInput(_))
        ));
        // Reference with the wrong interface is rejected.
        let mut rng = Philox::seeded(9);
        let mut wrong = Model::new();
        wrong.add("fc", Linear::random(8, 5, &mut rng)).unwrap();
        assert!(matches!(
            RankAdapter::new(&server, "t", wrong, cfg(&[4])),
            Err(ServeError::BadInput(_))
        ));
        // Unknown tier errors through the router.
        assert!(matches!(
            RankAdapter::new(&server, "ghost", dense(1), cfg(&[4])),
            Err(ServeError::UnknownTier { .. })
        ));
    }

    #[test]
    fn shadow_ring_is_bounded_fifo_and_width_checked() {
        let mut server = ModelServer::new();
        server
            .register_tier("t", dense(2), 8, TierConfig::default())
            .unwrap();
        let mut c = cfg(&[4]);
        c.shadow_capacity = 3;
        let mut a = RankAdapter::new(&server, "t", dense(2), c).unwrap();
        assert!(matches!(
            a.observe(&[0.0; 5]),
            Err(ServeError::BadInput(_))
        ));
        for i in 0..5 {
            a.observe(&[i as f32; 8]).unwrap();
        }
        assert_eq!(a.shadow_len(), 3);
        // Oldest rows were evicted: the ring holds rows 2, 3, 4.
        assert_eq!(a.shadow[0][0], 2.0);
        assert_eq!(a.shadow[2][0], 4.0);
    }

    #[test]
    fn measure_scores_dense_as_perfect_and_publishes_the_gauge() {
        let mut server = ModelServer::new();
        server
            .register_tier("t", dense(3), 8, TierConfig::default())
            .unwrap();
        let mut a = RankAdapter::new(&server, "t", dense(3), cfg(&[4])).unwrap();
        assert_eq!(a.measure().unwrap(), None, "empty ring measures nothing");
        let mut rng = Philox::seeded(4);
        for _ in 0..6 {
            let row = crate::linalg::Mat::randn(1, 8, &mut rng);
            a.observe(row.row(0)).unwrap();
        }
        // The tier serves exactly the reference: zero error, quality 1.
        let r = a.measure().unwrap().unwrap();
        assert_eq!(r.mean_err, 0.0);
        assert_eq!(r.quality, 1.0);
        assert_eq!(r.rows, 6);
        assert_eq!(r.window_rows, 6);
        assert_eq!(
            server.metrics().tier("t").unwrap().measured_quality(),
            Some(1.0)
        );
    }

    /// A reference whose sketch is *exact at any rank*: with zero
    /// weights, `V = Sᵀ·Wᵀ = 0`, so dense and every sketched candidate
    /// output exactly the bias row. That turns the controller's
    /// error-driven decisions deterministic — no dependence on what
    /// relative error a random sketch happens to achieve.
    fn exact_ref(bias: f32) -> Model {
        let mut m = Model::new();
        m.add(
            "fc",
            Linear::new(crate::linalg::Mat::zeros(4, 8), vec![bias; 4]),
        )
        .unwrap();
        m
    }

    #[test]
    fn step_walks_down_the_ladder_and_recovers_up() {
        let mut server = ModelServer::new();
        server
            .register_tier("t", exact_ref(0.5), 8, TierConfig::default())
            .unwrap();
        let mut a =
            RankAdapter::new(&server, "t", exact_ref(0.5), cfg(&[2, 4])).unwrap();
        for i in 0..4 {
            a.observe(&[i as f32 + 1.0; 8]).unwrap();
        }
        // Serving the dense reference: error is exactly 0, so each step
        // probes one rung down — and the zero-weight sketch is exact, so
        // each probe is adopted, until the ladder bottoms out.
        match a.step(&server).unwrap() {
            AdaptDecision::Swapped {
                from_rank: 0,
                to_rank: 4,
                version: 1,
                candidate_err,
                ..
            } => assert_eq!(candidate_err, 0.0),
            other => panic!("expected the first down-swap, got {other:?}"),
        }
        match a.step(&server).unwrap() {
            AdaptDecision::Swapped {
                from_rank: 4,
                to_rank: 2,
                version: 2,
                ..
            } => {}
            other => panic!("expected the second down-swap, got {other:?}"),
        }
        assert!(matches!(
            a.step(&server).unwrap(),
            AdaptDecision::Hold {
                reason: HoldReason::AtCheapestRung,
                ..
            }
        ));
        let tm = server.metrics().tier("t").unwrap();
        assert_eq!((a.rank(), tm.rank(), tm.swaps()), (2, 2, 2));
        // Degrade what the tier serves behind the adapter's back: a
        // wrong-bias model reads as relative error 1 (clamped). The
        // up-walk proposes [4, dense]; rank 4 rebuilt from the reference
        // is exact, so the cheapest richer rung wins.
        server.swap_tier_model("t", exact_ref(9.0)).unwrap();
        match a.step(&server).unwrap() {
            AdaptDecision::Swapped {
                from_rank: 2,
                to_rank: 4,
                version: 4,
                live_err,
                candidate_err,
            } => {
                assert!(live_err > a.cfg.target_err, "live err {live_err}");
                assert_eq!(candidate_err, 0.0);
            }
            other => panic!("expected the recovery up-swap, got {other:?}"),
        }
        assert_eq!(a.rank(), 4);
        assert_eq!(server.metrics().tier("t").unwrap().swaps(), 4);
        server.shutdown();
    }

    #[test]
    fn memory_budget_blocks_every_up_candidate() {
        let mut server = ModelServer::new();
        server
            .register_tier("t", exact_ref(0.5), 8, TierConfig::default())
            .unwrap();
        // The tier serves garbage and the controller wants up — but a
        // zero budget rejects every richer candidate, so it must hold
        // (and say why) rather than swap over the ceiling.
        server.swap_tier_model("t", exact_ref(9.0)).unwrap();
        let mut c = cfg(&[2, 4]);
        c.initial_rank = 2;
        c.mem_budget = Some(0);
        let mut a = RankAdapter::new(&server, "t", exact_ref(0.5), c).unwrap();
        for i in 0..4 {
            a.observe(&[i as f32 + 1.0; 8]).unwrap();
        }
        let d = a.step(&server).unwrap();
        assert!(
            matches!(
                d,
                AdaptDecision::Hold {
                    reason: HoldReason::CandidateRejected,
                    ..
                }
            ),
            "budget must reject every richer candidate: {d:?}"
        );
        assert_eq!(a.rank(), 2, "no swap under a blocking budget");
        assert_eq!(
            server.metrics().tier("t").unwrap().swaps(),
            1,
            "only the setup swap"
        );
        server.shutdown();
    }

    #[test]
    fn down_probe_rejects_candidates_above_the_ceiling() {
        // Real random weights: a random sketch carries real error, so
        // under a near-zero target the down-probe's candidate misses the
        // ceiling and the tier keeps serving dense.
        let mut server = ModelServer::new();
        server
            .register_tier("t", dense(5), 8, TierConfig::default())
            .unwrap();
        let mut c = cfg(&[4]);
        c.target_err = 1e-9;
        let mut a = RankAdapter::new(&server, "t", dense(5), c).unwrap();
        let mut rng = Philox::seeded(6);
        for _ in 0..6 {
            let row = crate::linalg::Mat::randn(1, 8, &mut rng);
            a.observe(row.row(0)).unwrap();
        }
        match a.step(&server).unwrap() {
            AdaptDecision::Hold {
                reason: HoldReason::CandidateRejected,
                live_err,
            } => assert_eq!(live_err, Some(0.0), "dense serving measures zero error"),
            other => panic!("expected a rejected down-probe, got {other:?}"),
        }
        assert_eq!(a.rank(), 0);
        assert_eq!(server.metrics().tier("t").unwrap().swaps(), 0);
        server.shutdown();
    }

    #[test]
    fn daemon_walks_the_ladder_on_its_own_cadence() {
        let mut server = ModelServer::new();
        server
            .register_tier("t", exact_ref(0.5), 8, TierConfig::default())
            .unwrap();
        let a = RankAdapter::new(&server, "t", exact_ref(0.5), cfg(&[2, 4])).unwrap();
        let daemon = AdaptDaemon::spawn(&server, a, Duration::from_millis(2)).unwrap();
        for i in 0..4 {
            daemon.observe(&[i as f32 + 1.0; 8]).unwrap();
        }
        // Zero measured error walks the tier down rung by rung with no
        // caller-driven stepping; wait (bounded) for the bottom rung.
        let deadline = Instant::now() + Duration::from_secs(10);
        while daemon.rank() != 2 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(daemon.rank(), 2, "daemon reached the cheapest rung");
        assert!(daemon.steps() >= 2, "at least the two down-swap rounds ran");
        assert_eq!(daemon.swap_errors(), 0, "happy path counts no errors");
        let tm = server.metrics().tier("t").unwrap();
        assert_eq!((tm.rank(), tm.swaps()), (2, 2));
        // Graceful shutdown: the observe/rank surface stays usable right
        // up to the join, and dropping after shutdown is a no-op.
        daemon.shutdown();
        server.shutdown();
    }

    #[test]
    fn daemon_counts_swap_errors_against_a_draining_server() {
        let mut server = ModelServer::new();
        server
            .register_tier("t", exact_ref(0.5), 8, TierConfig::default())
            .unwrap();
        let a = RankAdapter::new(&server, "t", exact_ref(0.5), cfg(&[2, 4])).unwrap();
        let daemon = AdaptDaemon::spawn(&server, a, Duration::from_millis(2)).unwrap();
        for i in 0..4 {
            daemon.observe(&[i as f32 + 1.0; 8]).unwrap();
        }
        // Drain the server out from under the daemon: its next down-swap
        // attempt gets ShuttingDown, which the cadence must survive and
        // count rather than die on.
        server.shutdown();
        let deadline = Instant::now() + Duration::from_secs(10);
        while daemon.swap_errors() == 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(daemon.swap_errors() >= 1, "swap against a drained server is counted");
        assert_eq!(daemon.rank(), 0, "no swap landed");
        daemon.shutdown();
    }
}
