//! The generic dynamic batcher under every serving tier: a bounded request
//! queue plus the worker loop that coalesces single-row requests into
//! padded row-stacked batches.
//!
//! This generalizes what [`crate::coordinator::DynamicBatcher`] does for
//! the fixed-shape artifact runtime to *any* native [`Model`]: requests
//! queue (bounded — backpressure, not unbounded growth), a worker takes
//! the first request, waits at most `max_wait` for up to `max_batch − 1`
//! more (classic size-or-timeout coalescing), pads the stack to exactly
//! `max_batch` rows with zeros, runs **one** `Model::forward`, and routes
//! each live row's result back to its caller.
//!
//! **Why pad to the full cap.** The GEMM substrate picks its kernel from
//! the product shape, so executing every batch at one fixed row count
//! pins the kernel path: a request's result is a pure function of its own
//! row and the tier's cap — bit-identical across arrival orders and batch
//! compositions. Registration additionally probes that padding rows never
//! leak into live rows (see [`super::router`]), which is what rules out
//! row-coupled layers like attention at caps > 1.

use super::fault::{BatchFaults, FaultPlan};
use super::metrics::TierMetrics;
use super::trace::{TierTrace, TraceCtx};
use super::transform::OutputTransform;
use super::ServeError;
use crate::linalg::Mat;
use crate::nn::{ForwardCtx, Model, SeqBatch};
use crate::util::events::EventClass;
use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// One published model version of a row tier. Requests capture the
/// version live at admission, so a reply is always computed on the
/// weights that were current when the request was accepted — the
/// atomicity half of the hot-swap contract.
pub(crate) struct ModelVersion {
    pub(crate) model: Model,
    /// Monotonic publish counter (0 = the registration model).
    pub(crate) version: u64,
}

/// The versioned model slot of a row tier: an atomically swappable
/// `Arc<ModelVersion>`. [`ModelSlot::publish`] installs a new model for
/// *future* admissions only — requests already queued keep their captured
/// `Arc`, in-flight batches finish on the old weights, and the old
/// version is freed when its last queued request retires. Workers never
/// touch the slot (they read the batch head's captured version), so a
/// swap costs admissions one uncontended mutex grab and workers nothing.
pub(crate) struct ModelSlot {
    cur: Mutex<Arc<ModelVersion>>,
}

impl ModelSlot {
    pub(crate) fn new(model: Model) -> Self {
        ModelSlot {
            cur: Mutex::new(Arc::new(ModelVersion { model, version: 0 })),
        }
    }

    /// The version new admissions capture.
    pub(crate) fn current(&self) -> Arc<ModelVersion> {
        Arc::clone(&crate::util::lock_ignore_poison(&self.cur))
    }

    /// Atomically install `model` as the next version; returns the new
    /// version number.
    pub(crate) fn publish(&self, model: Model) -> u64 {
        let mut g = crate::util::lock_ignore_poison(&self.cur);
        let version = g.version + 1;
        *g = Arc::new(ModelVersion { model, version });
        version
    }
}

/// How much of a batch's budget one queued request consumes. Row requests
/// all weigh 1 (the budget is the batch cap); sequence requests weigh
/// their token count (the budget is the tier's per-step token budget).
pub(crate) trait BatchItem {
    fn weight(&self) -> usize;

    /// Model-version fence for batch formation: coalescing never mixes
    /// requests with different keys in one batch, so every batch executes
    /// on exactly one model version. Kinds without versioning (sequence
    /// tiers) keep the default constant key.
    fn version_key(&self) -> u64 {
        0
    }
}

/// One queued inference request: a single feature row plus its reply
/// channel, enqueue time (end-to-end latency is measured from here), and
/// the model version captured at admission.
pub(crate) struct ServeRequest {
    pub(crate) row: Vec<f32>,
    pub(crate) reply: mpsc::Sender<Result<Vec<f32>, ServeError>>,
    pub(crate) enqueued: Instant,
    pub(crate) model: Arc<ModelVersion>,
    /// Trace context minted at admission (`None` when tracing is off —
    /// the hot path's single never-taken branch). The request's span
    /// chain is recorded at *reply* time, so kills/requeues/quarantine
    /// replays never double-count a request's events.
    pub(crate) trace: Option<TraceCtx>,
}

impl BatchItem for ServeRequest {
    fn weight(&self) -> usize {
        1
    }

    fn version_key(&self) -> u64 {
        self.model.version
    }
}

/// One queued *sequence* request: a whole `len × in_dim` token matrix.
/// The reply is the transformed per-token output matrix.
pub(crate) struct SeqServeRequest {
    pub(crate) tokens: Mat,
    pub(crate) reply: mpsc::Sender<Result<Mat, ServeError>>,
    pub(crate) enqueued: Instant,
    /// See [`ServeRequest::trace`].
    pub(crate) trace: Option<TraceCtx>,
}

impl BatchItem for SeqServeRequest {
    fn weight(&self) -> usize {
        self.tokens.rows()
    }
}

struct QueueInner<R> {
    deque: VecDeque<R>,
    closed: bool,
}

/// Bounded MPMC request queue with blocking and non-blocking admission —
/// the backpressure boundary of a tier. Closing the queue stops new
/// admissions; already-queued requests drain (workers keep pulling until
/// the queue is empty, then exit). Generic over the request kind: row
/// tiers queue [`ServeRequest`]s, sequence tiers queue
/// [`SeqServeRequest`]s; batch formation is weight-budgeted through
/// [`BatchItem`], which makes the row batcher (weight 1, budget =
/// `max_batch`) and the continuous sequence batcher (weight = tokens,
/// budget = `max_tokens`) the same admission loop.
pub(crate) struct TierQueue<R: BatchItem> {
    inner: Mutex<QueueInner<R>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    metrics: Arc<TierMetrics>,
}

impl<R: BatchItem> TierQueue<R> {
    pub(crate) fn new(cap: usize, metrics: Arc<TierMetrics>) -> Self {
        TierQueue {
            inner: Mutex::new(QueueInner {
                deque: VecDeque::with_capacity(cap),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
            metrics,
        }
    }

    fn locked(&self) -> MutexGuard<'_, QueueInner<R>> {
        crate::util::lock_ignore_poison(&self.inner)
    }

    fn wait<'a>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, QueueInner<R>>,
    ) -> MutexGuard<'a, QueueInner<R>> {
        cv.wait(guard)
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Enqueue, blocking while the queue is at capacity. Errors once the
    /// tier is shutting down (also when shutdown happens mid-wait).
    pub(crate) fn submit(&self, req: R) -> Result<(), ServeError> {
        let mut g = self.locked();
        loop {
            if g.closed {
                return Err(ServeError::ShuttingDown);
            }
            if g.deque.len() < self.cap {
                break;
            }
            g = self.wait(&self.not_full, g);
        }
        g.deque.push_back(req);
        self.metrics.depth_add(1);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Enqueue without blocking: a full queue is an immediate
    /// [`ServeError::QueueFull`] — the admission-control path.
    pub(crate) fn try_submit(&self, req: R) -> Result<(), ServeError> {
        let mut g = self.locked();
        if g.closed {
            return Err(ServeError::ShuttingDown);
        }
        if g.deque.len() >= self.cap {
            self.metrics.record_rejected();
            return Err(ServeError::QueueFull);
        }
        g.deque.push_back(req);
        self.metrics.depth_add(1);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pull the next batch: block for the first request, then coalesce
    /// more FIFO requests while their summed [`BatchItem::weight`] fits
    /// `max_weight` **and** their [`BatchItem::version_key`] matches the
    /// head's, waiting at most `max_wait` after the first pull. A front
    /// request that does not fit the remaining budget — or was admitted
    /// against a different model version — stays queued for the *next*
    /// step, so a batch never mixes model versions and a hot-swap lands
    /// exactly on a batch boundary. Returns `None` when the queue is
    /// closed *and* fully drained — the worker-exit signal. During a
    /// drain (closed, non-empty) batches keep forming from whatever is
    /// queued, without waiting for more.
    pub(crate) fn next_batch(&self, max_weight: usize, max_wait: Duration) -> Option<Vec<R>> {
        let mut g = self.locked();
        loop {
            if !g.deque.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.wait(&self.not_empty, g);
        }
        let mut batch = Vec::new();
        // The head request ships unconditionally (admission already
        // bounded single-request weight), so an over-budget head cannot
        // wedge the queue.
        let first = g.deque.pop_front().expect("non-empty");
        let mut weight = first.weight();
        let version = first.version_key();
        batch.push(first);
        // `None` = un-representable deadline (e.g. `max_wait =
        // Duration::MAX`, a natural "always wait for a full batch"):
        // coalesce without a timeout instead of panicking on Instant
        // overflow.
        let deadline = Instant::now().checked_add(max_wait);
        loop {
            while let Some(front) = g.deque.front() {
                if weight + front.weight() > max_weight || front.version_key() != version {
                    break;
                }
                let req = g.deque.pop_front().expect("front exists");
                weight += req.weight();
                batch.push(req);
            }
            // Budget exhausted, or a queued head that must wait for the
            // next step: ship what we have.
            if weight >= max_weight || !g.deque.is_empty() {
                break;
            }
            if g.closed {
                break;
            }
            match deadline {
                None => {
                    g = self.wait(&self.not_empty, g);
                }
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        break;
                    }
                    let (guard, timeout) = self
                        .not_empty
                        .wait_timeout(g, dl - now)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    g = guard;
                    if timeout.timed_out() && g.deque.is_empty() {
                        break;
                    }
                }
            }
        }
        self.metrics.depth_sub(batch.len());
        drop(g);
        // Every pop freed queue slots; wake all blocked submitters (they
        // re-check capacity under the lock).
        self.not_full.notify_all();
        Some(batch)
    }

    /// Push a batch back to the *front* of the queue in order, bypassing
    /// the capacity bound (the requests already held seats when they were
    /// admitted). Used by an injected worker kill: the dying worker
    /// re-queues its batch so no request is lost — transiently exceeding
    /// capacity beats deadlocking a single-worker tier on its own full
    /// queue.
    pub(crate) fn requeue_front(&self, batch: Vec<R>) {
        let n = batch.len();
        if n == 0 {
            return;
        }
        let mut g = self.locked();
        for r in batch.into_iter().rev() {
            g.deque.push_front(r);
        }
        self.metrics.depth_add(n);
        drop(g);
        self.not_empty.notify_all();
    }

    /// Whether the queue has been closed (the supervisor's exit check).
    pub(crate) fn is_closed(&self) -> bool {
        self.locked().closed
    }

    /// Closed *and* empty: nothing left for a worker to do. The
    /// supervisor stops respawning at this point — a panic during the
    /// final drain is still respawned so re-queued requests get served.
    pub(crate) fn is_drained(&self) -> bool {
        let g = self.locked();
        g.closed && g.deque.is_empty()
    }

    /// Stop admissions and wake everyone: blocked submitters error out,
    /// idle workers drain and exit.
    pub(crate) fn close(&self) {
        self.locked().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Requests currently queued.
    #[cfg(test)]
    fn len(&self) -> usize {
        self.locked().deque.len()
    }
}

/// How one padded forward attempt ended. The worker's control flow
/// branches on *how* a batch failed because only panics are eligible for
/// quarantine bisection — a typed model error re-runs identically, so
/// retrying it would just double the work.
enum ExecOutcome {
    /// Forward succeeded with the expected row count.
    Done(Mat),
    /// Typed failure (model error or row-count mismatch): the batch fails
    /// with [`ServeError::Exec`], exactly as before quarantine existed.
    Failed(String),
    /// The forward panicked (payload rendered to a string); the warm
    /// context has already been replaced by the time this is returned.
    Panicked(String),
}

/// The per-worker batch-execution spec + loop. Each worker owns a warm
/// [`ForwardCtx`] (its [`crate::nn::Workspace`] arena makes steady-state
/// inference forwards allocation-free) and one reusable `max_batch × d_in`
/// input matrix; the GEMM work inside `Model::forward` lands on the
/// process-wide kernel pool shared by all tiers.
///
/// This is the buffer-reusing twin of [`Model::forward_rows`] (same
/// stack/pad-to-cap/unstack contract, validated once at registration by
/// the probe): the public entry point allocates per call, the worker must
/// not.
///
/// A panicking forward is caught per batch (the same worker-panic
/// containment policy as [`crate::util::threadpool::ThreadPool`]): the
/// batch's callers get a typed [`ServeError::Exec`] instead of a hang,
/// the warm context is discarded (its scratch state may be mid-borrow),
/// and the worker keeps serving. With `quarantine_strikes > 0` the panic
/// instead enters bisection quarantine (see [`RowWorker::quarantine`]):
/// innocent requests are replayed and only the culprit is answered with
/// [`ServeError::PoisonedInput`].
///
/// Workers do not own a model: each batch executes on the
/// [`ModelVersion`] its requests captured at admission (the queue never
/// mixes versions in one batch), which is what makes a hot-swap
/// invisible to in-flight work — the old `Arc` lives exactly as long as
/// requests admitted against it.
///
/// The spec is `Clone` so the supervisor can respawn a crashed worker
/// with the identical setup (same warm-context construction, same
/// queue/metrics handles).
#[derive(Clone)]
pub(crate) struct RowWorker {
    pub(crate) queue: Arc<TierQueue<ServeRequest>>,
    pub(crate) max_batch: usize,
    pub(crate) max_wait: Duration,
    pub(crate) in_dim: usize,
    pub(crate) transform: OutputTransform,
    pub(crate) metrics: Arc<TierMetrics>,
    /// Seeded fault plan (chaos testing); `None` — the production default
    /// — costs one branch per batch.
    pub(crate) faults: Option<Arc<FaultPlan>>,
    /// Panic strikes before a lone request is declared poisoned; `0`
    /// disables quarantine (panic ⇒ whole batch fails typed, as always).
    pub(crate) quarantine_strikes: u32,
    /// Scan outputs for non-finite rows and answer those requests with
    /// [`ServeError::NonFiniteOutput`] instead of shipping garbage.
    pub(crate) numeric_guard: bool,
    /// The tier's trace sink for tier-level events (fault arms,
    /// quarantine rounds), captured at registration; `None` when tracing
    /// was off — one branch per batch, like `faults`.
    pub(crate) trace: Option<Arc<TierTrace>>,
}

impl RowWorker {
    pub(crate) fn run(&self) {
        let mut ctx = ForwardCtx::new().batch_hint(self.max_batch);
        let mut x = Mat::zeros(self.max_batch, self.in_dim);
        while let Some(batch) = self.queue.next_batch(self.max_batch, self.max_wait) {
            // One fault tick per *shipped* batch; quarantine re-executions
            // below do not consult the plan, so pinned ticks map 1:1 to
            // shipped batches and chaos assertions stay exact.
            let fb = self.faults.as_ref().map(|f| f.begin_batch(batch.len()));
            if let (Some(t), Some(f)) = (&self.trace, &fb) {
                if !f.is_quiet() {
                    t.record_now(EventClass::Fault, 0, fault_detail(f));
                }
            }
            if let Some(f) = &fb {
                if let Some(d) = f.exec_delay {
                    std::thread::sleep(d);
                }
                if f.kill_before_forward {
                    // Re-queue first so no request is lost, then die
                    // outside the forward catch_unwind: this takes the
                    // whole worker thread down and is what the supervisor
                    // respawn path recovers from.
                    self.queue.requeue_front(batch);
                    panic!("fault injection: worker killed before forward");
                }
            }
            let panic_mid = fb.as_ref().is_some_and(|f| f.panic_mid_batch);
            let poison_row = fb.as_ref().and_then(|f| f.poison_row);
            // Exec is timed here, in the caller, so every reply path can
            // record the same `queue_wait`/`exec` spans for its requests.
            let t_exec = Instant::now();
            let outcome = self.exec(&batch, &mut ctx, &mut x, panic_mid);
            let exec_dur = t_exec.elapsed();
            match outcome {
                ExecOutcome::Done(mut y) => {
                    self.reply_success(batch, &mut y, poison_row, t_exec, exec_dur)
                }
                ExecOutcome::Failed(msg) => {
                    fail_batch(batch, &self.metrics, self.max_batch, msg, t_exec, exec_dur)
                }
                ExecOutcome::Panicked(cause) => {
                    if self.quarantine_strikes > 0 {
                        self.quarantine(batch, &mut ctx, &mut x);
                    } else {
                        let msg = format!("forward panicked: {cause}");
                        fail_batch(batch, &self.metrics, self.max_batch, msg, t_exec, exec_dur);
                    }
                }
            }
        }
    }

    /// One padded forward over `batch` (live rows in `0..len`, tail
    /// zeroed — a previous batch's rows must not linger). Records
    /// `record_exec` for every attempt, success or not: failed batches
    /// held the worker just as long, and the SLO admission estimator
    /// divides queue depth by this sensor. On panic the warm context is
    /// replaced (its scratch state may be mid-borrow) before returning.
    fn exec(
        &self,
        batch: &[ServeRequest],
        ctx: &mut ForwardCtx,
        x: &mut Mat,
        panic_mid: bool,
    ) -> ExecOutcome {
        let used = batch.len();
        let model = Arc::clone(&batch[0].model);
        let model = &model.model;
        for (i, req) in batch.iter().enumerate() {
            x.row_mut(i).copy_from_slice(&req.row);
        }
        for i in used..self.max_batch {
            x.row_mut(i).fill(0.0);
        }
        let t_exec = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if panic_mid {
                panic!("fault injection: panic mid-batch");
            }
            model.forward(&*x, &*ctx)
        }));
        self.metrics.record_exec(t_exec.elapsed());
        match result {
            // The probe pinned rows-out == rows-in at registration; check
            // it in release too — row routing must never misattribute.
            Ok(Ok(y)) if y.rows() == self.max_batch => ExecOutcome::Done(y),
            Ok(Ok(y)) => ExecOutcome::Failed(format!(
                "model mapped {} rows to {} — cannot route rows",
                self.max_batch,
                y.rows()
            )),
            Ok(Err(e)) => ExecOutcome::Failed(format!("{e:#}")),
            Err(payload) => {
                let cause = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                *ctx = ForwardCtx::new().batch_hint(self.max_batch);
                ExecOutcome::Panicked(cause)
            }
        }
    }

    /// Retire a successful batch: inject the (test-only) output poison,
    /// run the numeric guard, record all metrics, then reply. All metrics
    /// for a batch are recorded BEFORE any reply is sent: a client that
    /// unblocks from `infer` must already see its own request accounted
    /// (tests read counters right after replies).
    fn reply_success(
        &self,
        batch: Vec<ServeRequest>,
        y: &mut Mat,
        poison_row: Option<usize>,
        exec_at: Instant,
        exec_dur: Duration,
    ) {
        let used = batch.len();
        if let Some(r) = poison_row.filter(|&r| r < used) {
            y.row_mut(r).fill(f32::NAN);
        }
        // The guard scans only live rows (padding is the model's business)
        // and runs before the transform so a softmax cannot launder a NaN
        // into a uniform-looking distribution.
        let mut bad = vec![false; used];
        if self.numeric_guard {
            let mut n_bad = 0u64;
            for (i, flag) in bad.iter_mut().enumerate() {
                if y.row(i).iter().any(|v| !v.is_finite()) {
                    *flag = true;
                    n_bad += 1;
                }
            }
            if n_bad > 0 {
                self.metrics.record_nonfinite_rows(n_bad);
                self.metrics.record_error(n_bad);
                // Feed the cascade's quality gauge so routing steers
                // around a numerically sick tier (ratchets down; only an
                // explicit probe raises it back).
                self.metrics.degrade_measured_quality(1.0 - n_bad as f64 / used as f64);
            }
        }
        for req in &batch {
            self.metrics.record_latency(req.enqueued.elapsed());
        }
        self.metrics.record_batch(used, self.max_batch);
        // Raw mode skips the transform allocation entirely — the reply
        // rows are views into the batch output.
        let t_tf = Instant::now();
        let decoded = match self.transform {
            OutputTransform::Raw => None,
            t => Some(t.apply(y)),
        };
        let tf_dur = t_tf.elapsed();
        let rows = decoded.as_ref().unwrap_or(y);
        for (i, req) in batch.into_iter().enumerate() {
            // The span chain is recorded before this request's reply is
            // sent — a client that unblocks already sees its full chain,
            // same discipline as the metrics above.
            if let Some(tr) = &req.trace {
                record_request_spans(tr, req.enqueued, exec_at, exec_dur);
                if decoded.is_some() {
                    tr.span_at(EventClass::Transform, t_tf, tf_dur, String::new());
                }
                if bad[i] {
                    tr.instant(EventClass::NonFinite, String::new());
                    tr.instant(EventClass::Error, "kind=NonFiniteOutput".to_string());
                } else {
                    tr.instant(EventClass::Reply, String::new());
                }
            }
            let _ = req.reply.send(if bad[i] {
                Err(ServeError::NonFiniteOutput)
            } else {
                Ok(rows.row(i).to_vec())
            });
        }
    }

    /// Bisection quarantine for a panicked batch: split it in half and
    /// re-execute each side, recursing into whichever side still panics,
    /// until culprits are isolated as singletons. A *singleton* whose solo
    /// execution has panicked `quarantine_strikes` times is answered with
    /// [`ServeError::PoisonedInput`]; every other request is replayed to a
    /// normal reply. Padded batching is bitwise-stable across batch
    /// composition, so replayed sub-batches reproduce the fault-free
    /// outputs exactly.
    ///
    /// Strikes count *solo* panics only — a multi-request batch panic
    /// cannot be attributed to any one row, so it triggers a split rather
    /// than a strike. The one exception is a shipped batch that was
    /// already a singleton: its panic *was* a solo execution, so it
    /// arrives here with one strike.
    ///
    /// Quarantine re-executions never consult the fault plan: injection is
    /// per shipped batch, which keeps chaos accounting deterministic.
    fn quarantine(&self, batch: Vec<ServeRequest>, ctx: &mut ForwardCtx, x: &mut Mat) {
        let mut stack: Vec<(Vec<ServeRequest>, u32)> = Vec::new();
        if batch.len() == 1 {
            stack.push((batch, 1));
        } else {
            let mut left = batch;
            let right = left.split_off(left.len() / 2);
            stack.push((right, 0));
            stack.push((left, 0));
        }
        while let Some((group, strikes)) = stack.pop() {
            if group.len() == 1 {
                self.retry_singleton(group, strikes, ctx, x);
                continue;
            }
            // Tier-level bisection-round event: one per re-executed group.
            if let Some(t) = &self.trace {
                t.record_now(EventClass::Quarantine, 0, format!("group={}", group.len()));
            }
            let t_exec = Instant::now();
            let outcome = self.exec(&group, ctx, x, false);
            let dur = t_exec.elapsed();
            match outcome {
                ExecOutcome::Done(mut y) => self.reply_success(group, &mut y, None, t_exec, dur),
                ExecOutcome::Failed(msg) => {
                    fail_batch(group, &self.metrics, self.max_batch, msg, t_exec, dur)
                }
                ExecOutcome::Panicked(_) => {
                    let mut left = group;
                    let right = left.split_off(left.len() / 2);
                    stack.push((right, 0));
                    stack.push((left, 0));
                }
            }
        }
    }

    /// Solo-execute one quarantined request until it succeeds or accrues
    /// `quarantine_strikes` solo panics, then answer it with
    /// [`ServeError::PoisonedInput`] (counted under both `errors` and
    /// `poisoned`, and terminal-accounted through `record_batch` exactly
    /// like any other reply).
    fn retry_singleton(
        &self,
        mut group: Vec<ServeRequest>,
        mut strikes: u32,
        ctx: &mut ForwardCtx,
        x: &mut Mat,
    ) {
        // The last solo attempt's timing backs the struck-out request's
        // `exec` span (zero-length if it arrived pre-struck).
        let (mut last_at, mut last_dur) = (Instant::now(), Duration::ZERO);
        loop {
            if strikes >= self.quarantine_strikes {
                let req = group.pop().expect("singleton group");
                self.metrics.record_error(1);
                self.metrics.record_poisoned();
                self.metrics.record_latency(req.enqueued.elapsed());
                self.metrics.record_batch(1, self.max_batch);
                if let Some(tr) = &req.trace {
                    record_request_spans(tr, req.enqueued, last_at, last_dur);
                    tr.instant(EventClass::Poisoned, format!("strikes={strikes}"));
                    tr.instant(EventClass::Error, "kind=PoisonedInput".to_string());
                }
                let _ = req.reply.send(Err(ServeError::PoisonedInput));
                return;
            }
            if let Some(t) = &self.trace {
                t.record_now(EventClass::Quarantine, 0, format!("solo strikes={strikes}"));
            }
            last_at = Instant::now();
            let outcome = self.exec(&group, ctx, x, false);
            last_dur = last_at.elapsed();
            match outcome {
                ExecOutcome::Done(mut y) => {
                    self.reply_success(group, &mut y, None, last_at, last_dur);
                    return;
                }
                ExecOutcome::Failed(msg) => {
                    fail_batch(group, &self.metrics, self.max_batch, msg, last_at, last_dur);
                    return;
                }
                ExecOutcome::Panicked(_) => strikes += 1,
            }
        }
    }
}

/// Record the guaranteed per-request span chain at reply time: the
/// `queue_wait` span (enqueue → exec start) and the `exec` span (the
/// batched forward the request rode in). The caller records the terminal
/// (`reply` xor `error`) right after, so every answered request's chain is
/// `admit → queue_wait → exec → terminal` — exactly once each.
fn record_request_spans(trace: &TraceCtx, enqueued: Instant, exec_at: Instant, exec_dur: Duration) {
    let wait = exec_at.checked_duration_since(enqueued).unwrap_or_default();
    trace.span_at(EventClass::QueueWait, enqueued, wait, String::new());
    trace.span_at(EventClass::Exec, exec_at, exec_dur, String::new());
}

/// Compact `what-fired` tag for a tier-level `fault` event.
fn fault_detail(f: &BatchFaults) -> String {
    let mut parts: Vec<&str> = Vec::new();
    if f.kill_before_forward {
        parts.push("kill");
    }
    if f.panic_mid_batch {
        parts.push("panic");
    }
    if f.exec_delay.is_some() {
        parts.push("delay");
    }
    if f.poison_row.is_some() {
        parts.push("poison");
    }
    parts.join("+")
}

/// Answer every request of a failed batch with [`ServeError::Exec`],
/// recording all counters first (same reply-after-accounting order as the
/// success path).
fn fail_batch(
    batch: Vec<ServeRequest>,
    metrics: &TierMetrics,
    max_batch: usize,
    msg: String,
    exec_at: Instant,
    exec_dur: Duration,
) {
    metrics.record_error(batch.len() as u64);
    for req in &batch {
        metrics.record_latency(req.enqueued.elapsed());
    }
    metrics.record_batch(batch.len(), max_batch);
    for req in batch {
        if let Some(tr) = &req.trace {
            record_request_spans(tr, req.enqueued, exec_at, exec_dur);
            tr.instant(EventClass::Error, "kind=Exec".to_string());
        }
        let _ = req.reply.send(Err(ServeError::Exec(msg.clone())));
    }
}

/// The continuous sequence batcher: each step admits queued sequences
/// FIFO up to the tier's `max_tokens` budget, packs them into one
/// variable-row matrix with a [`SeqBatch`] descriptor, runs **one**
/// masked `Model::forward_seq`, and retires every admitted sequence with
/// its own result slice (transformed per tier). A sequence that does not
/// fit the current step's remaining budget simply rides the next step —
/// admission and retirement are per step, so long and short sequences
/// share the tier without head-of-line blocking beyond one step.
///
/// Panic containment matches [`RowWorker`]: a panicking forward fails
/// only its own step's sequences and the warm context is replaced
/// (`forward_seq` restores the context's sequence batch even on error,
/// so the ctx is only discarded on a panic). Fault injection, quarantine
/// bisection (over whole sequences — the culprit unit here is a
/// sequence, not a row), and the numeric guard all mirror the row
/// worker; the spec is `Clone` for the same supervisor-respawn reason.
#[derive(Clone)]
pub(crate) struct SeqWorker {
    pub(crate) model: Arc<Model>,
    pub(crate) queue: Arc<TierQueue<SeqServeRequest>>,
    pub(crate) max_tokens: usize,
    pub(crate) max_wait: Duration,
    pub(crate) in_dim: usize,
    pub(crate) transform: OutputTransform,
    pub(crate) metrics: Arc<TierMetrics>,
    pub(crate) faults: Option<Arc<FaultPlan>>,
    pub(crate) quarantine_strikes: u32,
    pub(crate) numeric_guard: bool,
    /// See [`RowWorker::trace`].
    pub(crate) trace: Option<Arc<TierTrace>>,
}

impl SeqWorker {
    pub(crate) fn run(&self) {
        let mut ctx = ForwardCtx::new();
        while let Some(batch) = self.queue.next_batch(self.max_tokens, self.max_wait) {
            // The fault tick's `rows` is the packed token count, so the
            // poison fault lands on a token row, matching what the guard
            // scans.
            let fb = self.faults.as_ref().map(|f| {
                let total: usize = batch.iter().map(|r| r.tokens.rows()).sum();
                f.begin_batch(total)
            });
            if let (Some(t), Some(f)) = (&self.trace, &fb) {
                if !f.is_quiet() {
                    t.record_now(EventClass::Fault, 0, fault_detail(f));
                }
            }
            if let Some(f) = &fb {
                if let Some(d) = f.exec_delay {
                    std::thread::sleep(d);
                }
                if f.kill_before_forward {
                    self.queue.requeue_front(batch);
                    panic!("fault injection: worker killed before forward");
                }
            }
            let panic_mid = fb.as_ref().is_some_and(|f| f.panic_mid_batch);
            let poison_row = fb.as_ref().and_then(|f| f.poison_row);
            let t_exec = Instant::now();
            let outcome = self.exec(&batch, &mut ctx, panic_mid);
            let exec_dur = t_exec.elapsed();
            match outcome {
                ExecOutcome::Done(mut y) => {
                    self.reply_success(batch, &mut y, poison_row, t_exec, exec_dur)
                }
                ExecOutcome::Failed(msg) => {
                    fail_seq_batch(batch, &self.metrics, msg, t_exec, exec_dur)
                }
                ExecOutcome::Panicked(cause) => {
                    if self.quarantine_strikes > 0 {
                        self.quarantine(batch, &mut ctx);
                    } else {
                        let msg = format!("forward panicked: {cause}");
                        fail_seq_batch(batch, &self.metrics, msg, t_exec, exec_dur);
                    }
                }
            }
        }
    }

    /// One packed masked forward over `batch`. `record_exec` fires for
    /// every attempted forward (a pack failure never reaches execution
    /// and records nothing, as before).
    fn exec(
        &self,
        batch: &[SeqServeRequest],
        ctx: &mut ForwardCtx,
        panic_mid: bool,
    ) -> ExecOutcome {
        let lens: Vec<usize> = batch.iter().map(|r| r.tokens.rows()).collect();
        let total: usize = lens.iter().sum();
        let mut x = Mat::zeros(total, self.in_dim);
        let mut off = 0;
        for req in batch {
            for i in 0..req.tokens.rows() {
                x.row_mut(off + i).copy_from_slice(req.tokens.row(i));
            }
            off += req.tokens.rows();
        }
        let sb = match SeqBatch::packed(lens) {
            Ok(sb) => sb,
            Err(e) => return ExecOutcome::Failed(format!("{e:#}")),
        };
        let t_exec = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if panic_mid {
                panic!("fault injection: panic mid-batch");
            }
            self.model.forward_seq(&x, &sb, &*ctx)
        }));
        self.metrics.record_exec(t_exec.elapsed());
        match result {
            Ok(Ok(y)) if y.rows() == total => ExecOutcome::Done(y),
            Ok(Ok(y)) => ExecOutcome::Failed(format!(
                "model mapped {total} packed token rows to {} — cannot \
                 route sequence slices",
                y.rows()
            )),
            Ok(Err(e)) => ExecOutcome::Failed(format!("{e:#}")),
            Err(payload) => {
                let cause = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                *ctx = ForwardCtx::new();
                ExecOutcome::Panicked(cause)
            }
        }
    }

    /// Retire a successful step: poison injection, numeric guard (a
    /// sequence is bad if *any* of its token rows is non-finite —
    /// `nonfinite_rows` counts token rows, `errors` counts sequences),
    /// metrics, then per-sequence replies.
    fn reply_success(
        &self,
        batch: Vec<SeqServeRequest>,
        y: &mut Mat,
        poison_row: Option<usize>,
        exec_at: Instant,
        exec_dur: Duration,
    ) {
        let lens: Vec<usize> = batch.iter().map(|r| r.tokens.rows()).collect();
        let total: usize = lens.iter().sum();
        if let Some(r) = poison_row.filter(|&r| r < total) {
            y.row_mut(r).fill(f32::NAN);
        }
        let mut bad = vec![false; batch.len()];
        if self.numeric_guard {
            let mut bad_rows = 0u64;
            let mut bad_seqs = 0u64;
            let mut off = 0;
            for (s, &len) in lens.iter().enumerate() {
                let mut seq_bad = false;
                for i in 0..len {
                    if y.row(off + i).iter().any(|v| !v.is_finite()) {
                        seq_bad = true;
                        bad_rows += 1;
                    }
                }
                off += len;
                if seq_bad {
                    bad[s] = true;
                    bad_seqs += 1;
                }
            }
            if bad_rows > 0 {
                self.metrics.record_nonfinite_rows(bad_rows);
                self.metrics.record_error(bad_seqs);
                self.metrics
                    .degrade_measured_quality(1.0 - bad_rows as f64 / total.max(1) as f64);
            }
        }
        for req in &batch {
            self.metrics.record_latency(req.enqueued.elapsed());
        }
        self.metrics.record_batch(batch.len(), batch.len());
        self.metrics.record_tokens(total as u64);
        let mut off = 0;
        for (s, (req, &len)) in batch.into_iter().zip(&lens).enumerate() {
            let start = off;
            off += len;
            if bad[s] {
                if let Some(tr) = &req.trace {
                    record_request_spans(tr, req.enqueued, exec_at, exec_dur);
                    tr.instant(EventClass::NonFinite, String::new());
                    tr.instant(EventClass::Error, "kind=NonFiniteOutput".to_string());
                }
                let _ = req.reply.send(Err(ServeError::NonFiniteOutput));
                continue;
            }
            let mut slice = Mat::zeros(len, y.cols());
            for i in 0..len {
                slice.row_mut(i).copy_from_slice(y.row(start + i));
            }
            let t_tf = Instant::now();
            let (out, transformed) = match self.transform {
                OutputTransform::Raw => (slice, false),
                t => (t.apply(&slice), true),
            };
            if let Some(tr) = &req.trace {
                record_request_spans(tr, req.enqueued, exec_at, exec_dur);
                if transformed {
                    tr.span_at(EventClass::Transform, t_tf, t_tf.elapsed(), String::new());
                }
                tr.instant(EventClass::Reply, String::new());
            }
            let _ = req.reply.send(Ok(out));
        }
    }

    /// [`RowWorker::quarantine`] over whole sequences: bisect the step's
    /// sequence list, re-execute each side, and strike out lone sequences
    /// whose solo steps panic `quarantine_strikes` times.
    fn quarantine(&self, batch: Vec<SeqServeRequest>, ctx: &mut ForwardCtx) {
        let mut stack: Vec<(Vec<SeqServeRequest>, u32)> = Vec::new();
        if batch.len() == 1 {
            stack.push((batch, 1));
        } else {
            let mut left = batch;
            let right = left.split_off(left.len() / 2);
            stack.push((right, 0));
            stack.push((left, 0));
        }
        while let Some((group, strikes)) = stack.pop() {
            if group.len() == 1 {
                self.retry_singleton(group, strikes, ctx);
                continue;
            }
            if let Some(t) = &self.trace {
                t.record_now(EventClass::Quarantine, 0, format!("group={}", group.len()));
            }
            let t_exec = Instant::now();
            let outcome = self.exec(&group, ctx, false);
            let dur = t_exec.elapsed();
            match outcome {
                ExecOutcome::Done(mut y) => self.reply_success(group, &mut y, None, t_exec, dur),
                ExecOutcome::Failed(msg) => {
                    fail_seq_batch(group, &self.metrics, msg, t_exec, dur)
                }
                ExecOutcome::Panicked(_) => {
                    let mut left = group;
                    let right = left.split_off(left.len() / 2);
                    stack.push((right, 0));
                    stack.push((left, 0));
                }
            }
        }
    }

    /// Solo-step one quarantined sequence until success or
    /// `quarantine_strikes` solo panics ⇒ [`ServeError::PoisonedInput`].
    fn retry_singleton(
        &self,
        mut group: Vec<SeqServeRequest>,
        mut strikes: u32,
        ctx: &mut ForwardCtx,
    ) {
        let (mut last_at, mut last_dur) = (Instant::now(), Duration::ZERO);
        loop {
            if strikes >= self.quarantine_strikes {
                let req = group.pop().expect("singleton group");
                self.metrics.record_error(1);
                self.metrics.record_poisoned();
                self.metrics.record_latency(req.enqueued.elapsed());
                self.metrics.record_batch(1, 1);
                if let Some(tr) = &req.trace {
                    record_request_spans(tr, req.enqueued, last_at, last_dur);
                    tr.instant(EventClass::Poisoned, format!("strikes={strikes}"));
                    tr.instant(EventClass::Error, "kind=PoisonedInput".to_string());
                }
                let _ = req.reply.send(Err(ServeError::PoisonedInput));
                return;
            }
            if let Some(t) = &self.trace {
                t.record_now(EventClass::Quarantine, 0, format!("solo strikes={strikes}"));
            }
            last_at = Instant::now();
            let outcome = self.exec(&group, ctx, false);
            last_dur = last_at.elapsed();
            match outcome {
                ExecOutcome::Done(mut y) => {
                    self.reply_success(group, &mut y, None, last_at, last_dur);
                    return;
                }
                ExecOutcome::Failed(msg) => {
                    fail_seq_batch(group, &self.metrics, msg, last_at, last_dur);
                    return;
                }
                ExecOutcome::Panicked(_) => strikes += 1,
            }
        }
    }
}

/// [`fail_batch`] for sequence steps.
fn fail_seq_batch(
    batch: Vec<SeqServeRequest>,
    metrics: &TierMetrics,
    msg: String,
    exec_at: Instant,
    exec_dur: Duration,
) {
    metrics.record_error(batch.len() as u64);
    for req in &batch {
        metrics.record_latency(req.enqueued.elapsed());
    }
    metrics.record_batch(batch.len(), batch.len().max(1));
    for req in batch {
        if let Some(tr) = &req.trace {
            record_request_spans(tr, req.enqueued, exec_at, exec_dur);
            tr.instant(EventClass::Error, "kind=Exec".to_string());
        }
        let _ = req.reply.send(Err(ServeError::Exec(msg.clone())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};

    fn req_versioned(
        v: f32,
        version: u64,
    ) -> (ServeRequest, mpsc::Receiver<Result<Vec<f32>, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (
            ServeRequest {
                row: vec![v],
                reply: tx,
                enqueued: Instant::now(),
                model: Arc::new(ModelVersion {
                    model: Model::new(),
                    version,
                }),
                trace: None,
            },
            rx,
        )
    }

    fn req(v: f32) -> (ServeRequest, mpsc::Receiver<Result<Vec<f32>, ServeError>>) {
        req_versioned(v, 0)
    }

    fn queue(cap: usize) -> Arc<TierQueue<ServeRequest>> {
        Arc::new(TierQueue::new(cap, Arc::new(TierMetrics::default())))
    }

    fn seq_req(len: usize) -> (SeqServeRequest, mpsc::Receiver<Result<Mat, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (
            SeqServeRequest {
                tokens: Mat::zeros(len, 1),
                reply: tx,
                enqueued: Instant::now(),
                trace: None,
            },
            rx,
        )
    }

    #[test]
    fn requeue_front_preserves_order_and_bypasses_capacity() {
        let q = queue(2);
        let (r1, _rx1) = req(1.0);
        let (r2, _rx2) = req(2.0);
        q.try_submit(r1).unwrap();
        q.try_submit(r2).unwrap();
        let batch = q.next_batch(2, Duration::from_millis(1)).unwrap();
        assert_eq!(q.metrics.queue_depth(), 0);
        // A newcomer takes the freed seats, then the dying worker's batch
        // goes back in *front* of it, above the capacity bound.
        let (r3, _rx3) = req(3.0);
        q.try_submit(r3).unwrap();
        q.requeue_front(batch);
        assert_eq!(q.len(), 3);
        assert_eq!(q.metrics.queue_depth(), 3);
        let replay = q.next_batch(4, Duration::from_millis(1)).unwrap();
        let order: Vec<f32> = replay.iter().map(|r| r.row[0]).collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
        assert!(!q.is_closed());
        assert!(!q.is_drained());
        q.close();
        assert!(q.is_closed());
        assert!(q.is_drained());
    }

    #[test]
    fn try_submit_rejects_when_full_and_recovers() {
        let q = queue(2);
        let (r1, _rx1) = req(1.0);
        let (r2, _rx2) = req(2.0);
        let (r3, _rx3) = req(3.0);
        q.try_submit(r1).unwrap();
        q.try_submit(r2).unwrap();
        // Admission control: full queue is an immediate typed error.
        let err = q.try_submit(r3).unwrap_err();
        assert_eq!(err, ServeError::QueueFull);
        assert_eq!(q.metrics.rejected(), 1);
        assert_eq!(q.metrics.queue_depth(), 2);
        // Draining a batch frees capacity again.
        let batch = q.next_batch(2, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(q.metrics.queue_depth(), 0);
        let (r4, _rx4) = req(4.0);
        q.try_submit(r4).unwrap();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn blocking_submit_waits_for_capacity() {
        let q = queue(1);
        let (r1, _rx1) = req(1.0);
        q.submit(r1).unwrap();
        let unblocked = Arc::new(AtomicBool::new(false));
        let (q2, flag) = (Arc::clone(&q), Arc::clone(&unblocked));
        let h = std::thread::spawn(move || {
            let (r2, _rx2) = req(2.0);
            q2.submit(r2).unwrap(); // blocks until the batch below drains
            flag.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert!(!unblocked.load(Ordering::SeqCst), "submit must backpressure");
        let b = q.next_batch(1, Duration::from_millis(1)).unwrap();
        assert_eq!(b.len(), 1);
        h.join().unwrap();
        assert!(unblocked.load(Ordering::SeqCst));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn coalesces_up_to_cap_and_times_out() {
        let q = queue(16);
        for v in 0..5 {
            let (r, _rx) = req(v as f32);
            q.submit(r).unwrap();
        }
        // Cap 4: first batch takes exactly 4 without waiting.
        let b1 = q.next_batch(4, Duration::from_millis(50)).unwrap();
        assert_eq!(b1.len(), 4);
        // One left: the wait budget elapses and the ragged batch ships.
        let t0 = Instant::now();
        let b2 = q.next_batch(4, Duration::from_millis(20)).unwrap();
        assert_eq!(b2.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(15), "honored max_wait");
        // FIFO order preserved across batches.
        assert_eq!(b1[0].row, vec![0.0]);
        assert_eq!(b2[0].row, vec![4.0]);
    }

    #[test]
    fn token_budget_coalescing_defers_oversized_heads() {
        // Sequences of 6, 3, 4, 2 tokens against a 10-token step budget:
        // step 1 admits 6+3 (4 would overflow), step 2 admits 4+2. The
        // deferred head is NOT skipped over — FIFO order is preserved,
        // it just rides the next step.
        let q: Arc<TierQueue<SeqServeRequest>> =
            Arc::new(TierQueue::new(16, Arc::new(TierMetrics::default())));
        for len in [6usize, 3, 4, 2] {
            let (r, _rx) = seq_req(len);
            q.submit(r).unwrap();
        }
        let step1 = q.next_batch(10, Duration::from_millis(5)).unwrap();
        assert_eq!(
            step1.iter().map(|r| r.weight()).collect::<Vec<_>>(),
            vec![6, 3]
        );
        let step2 = q.next_batch(10, Duration::from_millis(5)).unwrap();
        assert_eq!(
            step2.iter().map(|r| r.weight()).collect::<Vec<_>>(),
            vec![4, 2]
        );
        // An over-budget head still ships alone instead of wedging.
        let (big, _rx) = seq_req(99);
        q.submit(big).unwrap();
        let step3 = q.next_batch(10, Duration::from_millis(5)).unwrap();
        assert_eq!(step3.len(), 1);
        assert_eq!(step3[0].weight(), 99);
    }

    #[test]
    fn version_fence_splits_batches_at_the_swap_boundary() {
        // Three v0 requests, then two v1: one pull must stop at the
        // version boundary even though the cap (8) has room, and the next
        // pull picks up the v1 run — a hot-swap always lands between
        // batches, never inside one.
        let q = queue(16);
        for v in 0..3 {
            let (r, _rx) = req_versioned(v as f32, 0);
            q.submit(r).unwrap();
        }
        for v in 3..5 {
            let (r, _rx) = req_versioned(v as f32, 1);
            q.submit(r).unwrap();
        }
        // The fence must not cost the fenced batch a coalescing window:
        // with newer-version requests already queued behind it, the pull
        // ships immediately instead of waiting out `max_wait`.
        let t0 = Instant::now();
        let b1 = q.next_batch(8, Duration::from_secs(5)).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(1), "no wait at fence");
        assert_eq!(b1.len(), 3);
        assert!(b1.iter().all(|r| r.version_key() == 0));
        let b2 = q.next_batch(8, Duration::from_millis(5)).unwrap();
        assert_eq!(b2.len(), 2);
        assert!(b2.iter().all(|r| r.version_key() == 1));
        // FIFO order is preserved across the fence.
        assert_eq!(b1[0].row, vec![0.0]);
        assert_eq!(b2[0].row, vec![3.0]);
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = queue(8);
        let mut rxs = Vec::new();
        for v in 0..3 {
            let (r, rx) = req(v as f32);
            q.submit(r).unwrap();
            rxs.push(rx);
        }
        q.close();
        // New admissions fail on both paths.
        let (r, _rx) = req(9.0);
        assert_eq!(q.submit(r).unwrap_err(), ServeError::ShuttingDown);
        let (r, _rx) = req(9.0);
        assert_eq!(q.try_submit(r).unwrap_err(), ServeError::ShuttingDown);
        // Drain: queued requests still come out (without waiting on the
        // coalescing clock), then None.
        let t0 = Instant::now();
        let b = q.next_batch(8, Duration::from_secs(10)).unwrap();
        assert_eq!(b.len(), 3);
        assert!(t0.elapsed() < Duration::from_secs(1), "drain must not wait");
        assert!(q.next_batch(8, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn close_wakes_blocked_worker() {
        let q = queue(4);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.next_batch(4, Duration::from_millis(5)));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        // The blocked worker wakes and sees the exit signal.
        assert!(h.join().unwrap().is_none());
    }
}
