//! The generic dynamic batcher under every serving tier: a bounded request
//! queue plus the worker loop that coalesces single-row requests into
//! padded row-stacked batches.
//!
//! This generalizes what [`crate::coordinator::DynamicBatcher`] does for
//! the fixed-shape artifact runtime to *any* native [`Model`]: requests
//! queue (bounded — backpressure, not unbounded growth), a worker takes
//! the first request, waits at most `max_wait` for up to `max_batch − 1`
//! more (classic size-or-timeout coalescing), pads the stack to exactly
//! `max_batch` rows with zeros, runs **one** `Model::forward`, and routes
//! each live row's result back to its caller.
//!
//! **Why pad to the full cap.** The GEMM substrate picks its kernel from
//! the product shape, so executing every batch at one fixed row count
//! pins the kernel path: a request's result is a pure function of its own
//! row and the tier's cap — bit-identical across arrival orders and batch
//! compositions. Registration additionally probes that padding rows never
//! leak into live rows (see [`super::router`]), which is what rules out
//! row-coupled layers like attention at caps > 1.

use super::metrics::TierMetrics;
use super::transform::OutputTransform;
use super::ServeError;
use crate::linalg::Mat;
use crate::nn::{ForwardCtx, Model, SeqBatch};
use std::collections::VecDeque;
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// One published model version of a row tier. Requests capture the
/// version live at admission, so a reply is always computed on the
/// weights that were current when the request was accepted — the
/// atomicity half of the hot-swap contract.
pub(crate) struct ModelVersion {
    pub(crate) model: Model,
    /// Monotonic publish counter (0 = the registration model).
    pub(crate) version: u64,
}

/// The versioned model slot of a row tier: an atomically swappable
/// `Arc<ModelVersion>`. [`ModelSlot::publish`] installs a new model for
/// *future* admissions only — requests already queued keep their captured
/// `Arc`, in-flight batches finish on the old weights, and the old
/// version is freed when its last queued request retires. Workers never
/// touch the slot (they read the batch head's captured version), so a
/// swap costs admissions one uncontended mutex grab and workers nothing.
pub(crate) struct ModelSlot {
    cur: Mutex<Arc<ModelVersion>>,
}

impl ModelSlot {
    pub(crate) fn new(model: Model) -> Self {
        ModelSlot {
            cur: Mutex::new(Arc::new(ModelVersion { model, version: 0 })),
        }
    }

    /// The version new admissions capture.
    pub(crate) fn current(&self) -> Arc<ModelVersion> {
        Arc::clone(&crate::util::lock_ignore_poison(&self.cur))
    }

    /// Atomically install `model` as the next version; returns the new
    /// version number.
    pub(crate) fn publish(&self, model: Model) -> u64 {
        let mut g = crate::util::lock_ignore_poison(&self.cur);
        let version = g.version + 1;
        *g = Arc::new(ModelVersion { model, version });
        version
    }
}

/// How much of a batch's budget one queued request consumes. Row requests
/// all weigh 1 (the budget is the batch cap); sequence requests weigh
/// their token count (the budget is the tier's per-step token budget).
pub(crate) trait BatchItem {
    fn weight(&self) -> usize;

    /// Model-version fence for batch formation: coalescing never mixes
    /// requests with different keys in one batch, so every batch executes
    /// on exactly one model version. Kinds without versioning (sequence
    /// tiers) keep the default constant key.
    fn version_key(&self) -> u64 {
        0
    }
}

/// One queued inference request: a single feature row plus its reply
/// channel, enqueue time (end-to-end latency is measured from here), and
/// the model version captured at admission.
pub(crate) struct ServeRequest {
    pub(crate) row: Vec<f32>,
    pub(crate) reply: mpsc::Sender<Result<Vec<f32>, ServeError>>,
    pub(crate) enqueued: Instant,
    pub(crate) model: Arc<ModelVersion>,
}

impl BatchItem for ServeRequest {
    fn weight(&self) -> usize {
        1
    }

    fn version_key(&self) -> u64 {
        self.model.version
    }
}

/// One queued *sequence* request: a whole `len × in_dim` token matrix.
/// The reply is the transformed per-token output matrix.
pub(crate) struct SeqServeRequest {
    pub(crate) tokens: Mat,
    pub(crate) reply: mpsc::Sender<Result<Mat, ServeError>>,
    pub(crate) enqueued: Instant,
}

impl BatchItem for SeqServeRequest {
    fn weight(&self) -> usize {
        self.tokens.rows()
    }
}

struct QueueInner<R> {
    deque: VecDeque<R>,
    closed: bool,
}

/// Bounded MPMC request queue with blocking and non-blocking admission —
/// the backpressure boundary of a tier. Closing the queue stops new
/// admissions; already-queued requests drain (workers keep pulling until
/// the queue is empty, then exit). Generic over the request kind: row
/// tiers queue [`ServeRequest`]s, sequence tiers queue
/// [`SeqServeRequest`]s; batch formation is weight-budgeted through
/// [`BatchItem`], which makes the row batcher (weight 1, budget =
/// `max_batch`) and the continuous sequence batcher (weight = tokens,
/// budget = `max_tokens`) the same admission loop.
pub(crate) struct TierQueue<R: BatchItem> {
    inner: Mutex<QueueInner<R>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: usize,
    metrics: Arc<TierMetrics>,
}

impl<R: BatchItem> TierQueue<R> {
    pub(crate) fn new(cap: usize, metrics: Arc<TierMetrics>) -> Self {
        TierQueue {
            inner: Mutex::new(QueueInner {
                deque: VecDeque::with_capacity(cap),
                closed: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
            cap: cap.max(1),
            metrics,
        }
    }

    fn locked(&self) -> MutexGuard<'_, QueueInner<R>> {
        crate::util::lock_ignore_poison(&self.inner)
    }

    fn wait<'a>(
        &self,
        cv: &Condvar,
        guard: MutexGuard<'a, QueueInner<R>>,
    ) -> MutexGuard<'a, QueueInner<R>> {
        cv.wait(guard)
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    /// Enqueue, blocking while the queue is at capacity. Errors once the
    /// tier is shutting down (also when shutdown happens mid-wait).
    pub(crate) fn submit(&self, req: R) -> Result<(), ServeError> {
        let mut g = self.locked();
        loop {
            if g.closed {
                return Err(ServeError::ShuttingDown);
            }
            if g.deque.len() < self.cap {
                break;
            }
            g = self.wait(&self.not_full, g);
        }
        g.deque.push_back(req);
        self.metrics.depth_add(1);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Enqueue without blocking: a full queue is an immediate
    /// [`ServeError::QueueFull`] — the admission-control path.
    pub(crate) fn try_submit(&self, req: R) -> Result<(), ServeError> {
        let mut g = self.locked();
        if g.closed {
            return Err(ServeError::ShuttingDown);
        }
        if g.deque.len() >= self.cap {
            self.metrics.record_rejected();
            return Err(ServeError::QueueFull);
        }
        g.deque.push_back(req);
        self.metrics.depth_add(1);
        drop(g);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Pull the next batch: block for the first request, then coalesce
    /// more FIFO requests while their summed [`BatchItem::weight`] fits
    /// `max_weight` **and** their [`BatchItem::version_key`] matches the
    /// head's, waiting at most `max_wait` after the first pull. A front
    /// request that does not fit the remaining budget — or was admitted
    /// against a different model version — stays queued for the *next*
    /// step, so a batch never mixes model versions and a hot-swap lands
    /// exactly on a batch boundary. Returns `None` when the queue is
    /// closed *and* fully drained — the worker-exit signal. During a
    /// drain (closed, non-empty) batches keep forming from whatever is
    /// queued, without waiting for more.
    pub(crate) fn next_batch(&self, max_weight: usize, max_wait: Duration) -> Option<Vec<R>> {
        let mut g = self.locked();
        loop {
            if !g.deque.is_empty() {
                break;
            }
            if g.closed {
                return None;
            }
            g = self.wait(&self.not_empty, g);
        }
        let mut batch = Vec::new();
        // The head request ships unconditionally (admission already
        // bounded single-request weight), so an over-budget head cannot
        // wedge the queue.
        let first = g.deque.pop_front().expect("non-empty");
        let mut weight = first.weight();
        let version = first.version_key();
        batch.push(first);
        // `None` = un-representable deadline (e.g. `max_wait =
        // Duration::MAX`, a natural "always wait for a full batch"):
        // coalesce without a timeout instead of panicking on Instant
        // overflow.
        let deadline = Instant::now().checked_add(max_wait);
        loop {
            while let Some(front) = g.deque.front() {
                if weight + front.weight() > max_weight || front.version_key() != version {
                    break;
                }
                let req = g.deque.pop_front().expect("front exists");
                weight += req.weight();
                batch.push(req);
            }
            // Budget exhausted, or a queued head that must wait for the
            // next step: ship what we have.
            if weight >= max_weight || !g.deque.is_empty() {
                break;
            }
            if g.closed {
                break;
            }
            match deadline {
                None => {
                    g = self.wait(&self.not_empty, g);
                }
                Some(dl) => {
                    let now = Instant::now();
                    if now >= dl {
                        break;
                    }
                    let (guard, timeout) = self
                        .not_empty
                        .wait_timeout(g, dl - now)
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    g = guard;
                    if timeout.timed_out() && g.deque.is_empty() {
                        break;
                    }
                }
            }
        }
        self.metrics.depth_sub(batch.len());
        drop(g);
        // Every pop freed queue slots; wake all blocked submitters (they
        // re-check capacity under the lock).
        self.not_full.notify_all();
        Some(batch)
    }

    /// Stop admissions and wake everyone: blocked submitters error out,
    /// idle workers drain and exit.
    pub(crate) fn close(&self) {
        self.locked().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Requests currently queued.
    #[cfg(test)]
    fn len(&self) -> usize {
        self.locked().deque.len()
    }
}

/// The per-worker batch-execution loop. Each worker owns a warm
/// [`ForwardCtx`] (its [`crate::nn::Workspace`] arena makes steady-state
/// inference forwards allocation-free) and one reusable `max_batch × d_in`
/// input matrix; the GEMM work inside `Model::forward` lands on the
/// process-wide kernel pool shared by all tiers.
///
/// This is the buffer-reusing twin of [`Model::forward_rows`] (same
/// stack/pad-to-cap/unstack contract, validated once at registration by
/// the probe): the public entry point allocates per call, the worker must
/// not.
///
/// A panicking forward is caught per batch (the same worker-panic
/// containment policy as [`crate::util::threadpool::ThreadPool`]): the
/// batch's callers get a typed [`ServeError::Exec`] instead of a hang,
/// the warm context is discarded (its scratch state may be mid-borrow),
/// and the worker keeps serving.
///
/// Workers do not own a model: each batch executes on the
/// [`ModelVersion`] its requests captured at admission (the queue never
/// mixes versions in one batch), which is what makes a hot-swap
/// invisible to in-flight work — the old `Arc` lives exactly as long as
/// requests admitted against it.
pub(crate) fn worker_loop(
    queue: Arc<TierQueue<ServeRequest>>,
    max_batch: usize,
    max_wait: Duration,
    in_dim: usize,
    transform: OutputTransform,
    metrics: Arc<TierMetrics>,
) {
    let mut ctx = ForwardCtx::new().batch_hint(max_batch);
    let mut x = Mat::zeros(max_batch, in_dim);
    while let Some(batch) = queue.next_batch(max_batch, max_wait) {
        let used = batch.len();
        let model = Arc::clone(&batch[0].model);
        let model = &model.model;
        // Live rows in 0..used, padding rows zeroed (previous batch's rows
        // past `used` must not linger — zero the whole tail).
        for (i, req) in batch.iter().enumerate() {
            x.row_mut(i).copy_from_slice(&req.row);
        }
        for i in used..max_batch {
            x.row_mut(i).fill(0.0);
        }
        let t_exec = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model.forward(&x, &ctx)
        }));
        // Per-batch service time (queue wait excluded) — the sensor the
        // SLO admission estimator divides queue depth by. Failed batches
        // count too: they held the worker just as long.
        metrics.record_exec(t_exec.elapsed());
        // All metrics for a batch are recorded BEFORE any reply is sent:
        // a client that unblocks from `infer` must already see its own
        // request accounted (tests read counters right after replies).
        match result {
            // The probe pinned rows-out == rows-in at registration; check
            // it in release too — row routing must never misattribute.
            Ok(Ok(y)) if y.rows() == max_batch => {
                for req in &batch {
                    metrics.record_latency(req.enqueued.elapsed());
                }
                metrics.record_batch(used, max_batch);
                // Raw mode skips the transform allocation entirely — the
                // reply rows are views into the batch output.
                let decoded = match transform {
                    OutputTransform::Raw => None,
                    t => Some(t.apply(&y)),
                };
                let rows = decoded.as_ref().unwrap_or(&y);
                for (i, req) in batch.into_iter().enumerate() {
                    let _ = req.reply.send(Ok(rows.row(i).to_vec()));
                }
            }
            Ok(Ok(y)) => {
                let msg = format!(
                    "model mapped {max_batch} rows to {} — cannot route rows",
                    y.rows()
                );
                fail_batch(batch, &metrics, max_batch, msg);
            }
            Ok(Err(e)) => fail_batch(batch, &metrics, max_batch, format!("{e:#}")),
            Err(payload) => {
                let cause = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                // The context may hold wedged RefCell borrows from the
                // unwound forward — start fresh.
                ctx = ForwardCtx::new().batch_hint(max_batch);
                fail_batch(batch, &metrics, max_batch, format!("forward panicked: {cause}"));
            }
        }
    }
}

/// Answer every request of a failed batch with [`ServeError::Exec`],
/// recording all counters first (same reply-after-accounting order as the
/// success path).
fn fail_batch(batch: Vec<ServeRequest>, metrics: &TierMetrics, max_batch: usize, msg: String) {
    metrics.record_error(batch.len() as u64);
    for req in &batch {
        metrics.record_latency(req.enqueued.elapsed());
    }
    metrics.record_batch(batch.len(), max_batch);
    for req in batch {
        let _ = req.reply.send(Err(ServeError::Exec(msg.clone())));
    }
}

/// The continuous sequence batcher: each step admits queued sequences
/// FIFO up to the tier's `max_tokens` budget, packs them into one
/// variable-row matrix with a [`SeqBatch`] descriptor, runs **one**
/// masked `Model::forward_seq`, and retires every admitted sequence with
/// its own result slice (transformed per tier). A sequence that does not
/// fit the current step's remaining budget simply rides the next step —
/// admission and retirement are per step, so long and short sequences
/// share the tier without head-of-line blocking beyond one step.
///
/// Panic containment matches [`worker_loop`]: a panicking forward fails
/// only its own step's sequences and the warm context is replaced
/// (`forward_seq` restores the context's sequence batch even on error,
/// so the ctx is only discarded on a panic).
pub(crate) fn seq_worker_loop(
    model: Arc<Model>,
    queue: Arc<TierQueue<SeqServeRequest>>,
    max_tokens: usize,
    max_wait: Duration,
    in_dim: usize,
    transform: OutputTransform,
    metrics: Arc<TierMetrics>,
) {
    let mut ctx = ForwardCtx::new();
    while let Some(batch) = queue.next_batch(max_tokens, max_wait) {
        let lens: Vec<usize> = batch.iter().map(|r| r.tokens.rows()).collect();
        let total: usize = lens.iter().sum();
        let mut x = Mat::zeros(total, in_dim);
        let mut off = 0;
        for req in &batch {
            for i in 0..req.tokens.rows() {
                x.row_mut(off + i).copy_from_slice(req.tokens.row(i));
            }
            off += req.tokens.rows();
        }
        let sb = match SeqBatch::packed(lens.clone()) {
            Ok(sb) => sb,
            Err(e) => {
                fail_seq_batch(batch, &metrics, format!("{e:#}"));
                continue;
            }
        };
        let t_exec = Instant::now();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            model.forward_seq(&x, &sb, &ctx)
        }));
        metrics.record_exec(t_exec.elapsed());
        match result {
            Ok(Ok(y)) if y.rows() == total => {
                for req in &batch {
                    metrics.record_latency(req.enqueued.elapsed());
                }
                metrics.record_batch(batch.len(), batch.len());
                metrics.record_tokens(total as u64);
                let mut off = 0;
                for (req, &len) in batch.into_iter().zip(&lens) {
                    let mut slice = Mat::zeros(len, y.cols());
                    for i in 0..len {
                        slice.row_mut(i).copy_from_slice(y.row(off + i));
                    }
                    off += len;
                    let out = match transform {
                        OutputTransform::Raw => slice,
                        t => t.apply(&slice),
                    };
                    let _ = req.reply.send(Ok(out));
                }
            }
            Ok(Ok(y)) => {
                let msg = format!(
                    "model mapped {total} packed token rows to {} — cannot \
                     route sequence slices",
                    y.rows()
                );
                fail_seq_batch(batch, &metrics, msg);
            }
            Ok(Err(e)) => fail_seq_batch(batch, &metrics, format!("{e:#}")),
            Err(payload) => {
                let cause = payload
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_string());
                ctx = ForwardCtx::new();
                fail_seq_batch(batch, &metrics, format!("forward panicked: {cause}"));
            }
        }
    }
}

/// [`fail_batch`] for sequence steps.
fn fail_seq_batch(batch: Vec<SeqServeRequest>, metrics: &TierMetrics, msg: String) {
    metrics.record_error(batch.len() as u64);
    for req in &batch {
        metrics.record_latency(req.enqueued.elapsed());
    }
    metrics.record_batch(batch.len(), batch.len().max(1));
    for req in batch {
        let _ = req.reply.send(Err(ServeError::Exec(msg.clone())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};

    fn req_versioned(
        v: f32,
        version: u64,
    ) -> (ServeRequest, mpsc::Receiver<Result<Vec<f32>, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (
            ServeRequest {
                row: vec![v],
                reply: tx,
                enqueued: Instant::now(),
                model: Arc::new(ModelVersion {
                    model: Model::new(),
                    version,
                }),
            },
            rx,
        )
    }

    fn req(v: f32) -> (ServeRequest, mpsc::Receiver<Result<Vec<f32>, ServeError>>) {
        req_versioned(v, 0)
    }

    fn queue(cap: usize) -> Arc<TierQueue<ServeRequest>> {
        Arc::new(TierQueue::new(cap, Arc::new(TierMetrics::default())))
    }

    fn seq_req(len: usize) -> (SeqServeRequest, mpsc::Receiver<Result<Mat, ServeError>>) {
        let (tx, rx) = mpsc::channel();
        (
            SeqServeRequest {
                tokens: Mat::zeros(len, 1),
                reply: tx,
                enqueued: Instant::now(),
            },
            rx,
        )
    }

    #[test]
    fn try_submit_rejects_when_full_and_recovers() {
        let q = queue(2);
        let (r1, _rx1) = req(1.0);
        let (r2, _rx2) = req(2.0);
        let (r3, _rx3) = req(3.0);
        q.try_submit(r1).unwrap();
        q.try_submit(r2).unwrap();
        // Admission control: full queue is an immediate typed error.
        let err = q.try_submit(r3).unwrap_err();
        assert_eq!(err, ServeError::QueueFull);
        assert_eq!(q.metrics.rejected(), 1);
        assert_eq!(q.metrics.queue_depth(), 2);
        // Draining a batch frees capacity again.
        let batch = q.next_batch(2, Duration::from_millis(1)).unwrap();
        assert_eq!(batch.len(), 2);
        assert_eq!(q.metrics.queue_depth(), 0);
        let (r4, _rx4) = req(4.0);
        q.try_submit(r4).unwrap();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn blocking_submit_waits_for_capacity() {
        let q = queue(1);
        let (r1, _rx1) = req(1.0);
        q.submit(r1).unwrap();
        let unblocked = Arc::new(AtomicBool::new(false));
        let (q2, flag) = (Arc::clone(&q), Arc::clone(&unblocked));
        let h = std::thread::spawn(move || {
            let (r2, _rx2) = req(2.0);
            q2.submit(r2).unwrap(); // blocks until the batch below drains
            flag.store(true, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(30));
        assert!(!unblocked.load(Ordering::SeqCst), "submit must backpressure");
        let b = q.next_batch(1, Duration::from_millis(1)).unwrap();
        assert_eq!(b.len(), 1);
        h.join().unwrap();
        assert!(unblocked.load(Ordering::SeqCst));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn coalesces_up_to_cap_and_times_out() {
        let q = queue(16);
        for v in 0..5 {
            let (r, _rx) = req(v as f32);
            q.submit(r).unwrap();
        }
        // Cap 4: first batch takes exactly 4 without waiting.
        let b1 = q.next_batch(4, Duration::from_millis(50)).unwrap();
        assert_eq!(b1.len(), 4);
        // One left: the wait budget elapses and the ragged batch ships.
        let t0 = Instant::now();
        let b2 = q.next_batch(4, Duration::from_millis(20)).unwrap();
        assert_eq!(b2.len(), 1);
        assert!(t0.elapsed() >= Duration::from_millis(15), "honored max_wait");
        // FIFO order preserved across batches.
        assert_eq!(b1[0].row, vec![0.0]);
        assert_eq!(b2[0].row, vec![4.0]);
    }

    #[test]
    fn token_budget_coalescing_defers_oversized_heads() {
        // Sequences of 6, 3, 4, 2 tokens against a 10-token step budget:
        // step 1 admits 6+3 (4 would overflow), step 2 admits 4+2. The
        // deferred head is NOT skipped over — FIFO order is preserved,
        // it just rides the next step.
        let q: Arc<TierQueue<SeqServeRequest>> =
            Arc::new(TierQueue::new(16, Arc::new(TierMetrics::default())));
        for len in [6usize, 3, 4, 2] {
            let (r, _rx) = seq_req(len);
            q.submit(r).unwrap();
        }
        let step1 = q.next_batch(10, Duration::from_millis(5)).unwrap();
        assert_eq!(
            step1.iter().map(|r| r.weight()).collect::<Vec<_>>(),
            vec![6, 3]
        );
        let step2 = q.next_batch(10, Duration::from_millis(5)).unwrap();
        assert_eq!(
            step2.iter().map(|r| r.weight()).collect::<Vec<_>>(),
            vec![4, 2]
        );
        // An over-budget head still ships alone instead of wedging.
        let (big, _rx) = seq_req(99);
        q.submit(big).unwrap();
        let step3 = q.next_batch(10, Duration::from_millis(5)).unwrap();
        assert_eq!(step3.len(), 1);
        assert_eq!(step3[0].weight(), 99);
    }

    #[test]
    fn version_fence_splits_batches_at_the_swap_boundary() {
        // Three v0 requests, then two v1: one pull must stop at the
        // version boundary even though the cap (8) has room, and the next
        // pull picks up the v1 run — a hot-swap always lands between
        // batches, never inside one.
        let q = queue(16);
        for v in 0..3 {
            let (r, _rx) = req_versioned(v as f32, 0);
            q.submit(r).unwrap();
        }
        for v in 3..5 {
            let (r, _rx) = req_versioned(v as f32, 1);
            q.submit(r).unwrap();
        }
        // The fence must not cost the fenced batch a coalescing window:
        // with newer-version requests already queued behind it, the pull
        // ships immediately instead of waiting out `max_wait`.
        let t0 = Instant::now();
        let b1 = q.next_batch(8, Duration::from_secs(5)).unwrap();
        assert!(t0.elapsed() < Duration::from_secs(1), "no wait at fence");
        assert_eq!(b1.len(), 3);
        assert!(b1.iter().all(|r| r.version_key() == 0));
        let b2 = q.next_batch(8, Duration::from_millis(5)).unwrap();
        assert_eq!(b2.len(), 2);
        assert!(b2.iter().all(|r| r.version_key() == 1));
        // FIFO order is preserved across the fence.
        assert_eq!(b1[0].row, vec![0.0]);
        assert_eq!(b2[0].row, vec![3.0]);
    }

    #[test]
    fn close_drains_then_signals_exit() {
        let q = queue(8);
        let mut rxs = Vec::new();
        for v in 0..3 {
            let (r, rx) = req(v as f32);
            q.submit(r).unwrap();
            rxs.push(rx);
        }
        q.close();
        // New admissions fail on both paths.
        let (r, _rx) = req(9.0);
        assert_eq!(q.submit(r).unwrap_err(), ServeError::ShuttingDown);
        let (r, _rx) = req(9.0);
        assert_eq!(q.try_submit(r).unwrap_err(), ServeError::ShuttingDown);
        // Drain: queued requests still come out (without waiting on the
        // coalescing clock), then None.
        let t0 = Instant::now();
        let b = q.next_batch(8, Duration::from_secs(10)).unwrap();
        assert_eq!(b.len(), 3);
        assert!(t0.elapsed() < Duration::from_secs(1), "drain must not wait");
        assert!(q.next_batch(8, Duration::from_millis(1)).is_none());
    }

    #[test]
    fn close_wakes_blocked_worker() {
        let q = queue(4);
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.next_batch(4, Duration::from_millis(5)));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        // The blocked worker wakes and sees the exit signal.
        assert!(h.join().unwrap().is_none());
    }
}
