//! Tier registry + registration-time model vetting.
//!
//! A [`super::ModelServer`] serves several *quality tiers* of the same
//! workload — e.g. a dense model next to its `SketchPlan`-compressed
//! variant — each behind its own bounded queue and worker pool. The
//! router is the name → tier map requests are routed by.
//!
//! Registration **probes** the model before a single request is accepted:
//!
//! 1. *Row independence* — a probe row is forwarded padded-alone and
//!    padded among random co-rows at the tier's batch cap; the two row
//!    results must match **bit-for-bit**, otherwise batching would let
//!    co-riders (and the zero padding) leak into results. This is what
//!    rejects row-coupled layers (attention mixes sequence rows) at caps
//!    above 1, with a clean error instead of silent corruption.
//! 2. *Footprint* — the padded-batch forward runs under a
//!    [`MemTracker`], so the tier knows its peak per-batch activation
//!    bytes; together with the stored weight bytes this drives the
//!    memory-budget admission in [`super::ModelServer::register_tier`]
//!    (smaller sketched models ⇒ more workers per byte — the paper's
//!    memory saving turned into serving capacity).
//! 3. *Unbatched equivalence* — whether the cap-padded forward is
//!    additionally bit-identical to the plain single-row
//!    `Module::forward`. True whenever cap and shapes keep every product
//!    on the same GEMM kernel (always at caps below the microkernel
//!    height of 8); recorded in [`super::TierInfo`] so callers know which
//!    guarantee they hold.

use super::batcher::{ModelSlot, SeqServeRequest, ServeRequest, TierQueue};
use super::trace::Tracer;
use super::{SeqTierInfo, ServeError, TierInfo};
use crate::linalg::Mat;
use crate::nn::{ForwardCtx, Model, SeqBatch};
use crate::rng::Philox;
use crate::util::memtrack::MemTracker;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

/// One registered tier: the model replicaset behind its queue. Row tiers
/// batch single-row requests to a fixed cap; sequence tiers pack
/// variable-length sequences under a per-step token budget. The two
/// request protocols are type-separated end to end — routing a row call
/// at a sequence tier (or vice versa) is a typed error, not a reshape.
pub(crate) enum Tier {
    Row {
        queue: Arc<TierQueue<ServeRequest>>,
        info: TierInfo,
        /// The tier's versioned model: admissions capture the current
        /// version here; the rank adapter publishes new versions through
        /// it. Sequence tiers are not hot-swappable (their workers own a
        /// static `Arc<Model>`), hence no slot on the `Seq` arm.
        slot: Arc<ModelSlot>,
        /// Raw (pre-transform) model output width, fixed at registration.
        /// Hot-swap replacements must reproduce it exactly so the tier's
        /// transform — and the `info.out_dim` clients see — keep holding.
        raw_out: usize,
    },
    Seq {
        queue: Arc<TierQueue<SeqServeRequest>>,
        info: SeqTierInfo,
    },
}

impl Tier {
    fn close(&self) {
        match self {
            Tier::Row { queue, .. } => queue.close(),
            Tier::Seq { queue, .. } => queue.close(),
        }
    }

    /// Raw model output width for row tiers; `None` for sequence tiers.
    pub(crate) fn raw_out_dim(&self) -> Option<usize> {
        match self {
            Tier::Row { raw_out, .. } => Some(*raw_out),
            Tier::Seq { .. } => None,
        }
    }
}

/// Name → tier map shared between the server and its client handles,
/// plus the optional default tier unknown names fall back to on the
/// *request-routing* path (info lookups stay strict).
#[derive(Default)]
pub(crate) struct Router {
    tiers: Mutex<HashMap<String, Arc<Tier>>>,
    default_tier: Mutex<Option<String>>,
    /// Lock-free "is tracing on?" flag mirroring `tracer`, so the
    /// admission hot path pays one relaxed load when tracing is off.
    tracing: AtomicBool,
    tracer: Mutex<Option<Arc<Tracer>>>,
}

/// The typed unknown-tier error, carrying the registered names so the
/// message tells the caller what *would* have routed.
fn unknown(map: &HashMap<String, Arc<Tier>>, name: &str) -> ServeError {
    let mut registered: Vec<String> = map.keys().cloned().collect();
    registered.sort();
    ServeError::UnknownTier {
        name: name.to_string(),
        registered,
    }
}

impl Router {
    fn locked(&self) -> MutexGuard<'_, HashMap<String, Arc<Tier>>> {
        crate::util::lock_ignore_poison(&self.tiers)
    }

    pub(crate) fn insert(&self, name: &str, tier: Tier) -> Result<(), ServeError> {
        let mut map = self.locked();
        if map.contains_key(name) {
            return Err(ServeError::DuplicateTier(name.to_string()));
        }
        map.insert(name.to_string(), Arc::new(tier));
        Ok(())
    }

    /// Strict lookup — unknown names error (listing what is registered)
    /// even when a default tier is configured. Info lookups and
    /// registration duplicate checks use this: a fallback that silently
    /// answered `tier_info("typo")` with another tier's limits would be
    /// worse than the error.
    pub(crate) fn get(&self, name: &str) -> Result<Arc<Tier>, ServeError> {
        let map = self.locked();
        map.get(name).cloned().ok_or_else(|| unknown(&map, name))
    }

    /// Request-routing lookup: an unknown name falls back to the
    /// configured default tier when one is set, so a fleet can repoint
    /// stale clients instead of hard-erroring them.
    pub(crate) fn route(&self, name: &str) -> Result<Arc<Tier>, ServeError> {
        let map = self.locked();
        if let Some(t) = map.get(name) {
            return Ok(Arc::clone(t));
        }
        if let Some(d) = crate::util::lock_ignore_poison(&self.default_tier).as_deref() {
            if let Some(t) = map.get(d) {
                return Ok(Arc::clone(t));
            }
        }
        Err(unknown(&map, name))
    }

    /// Configure the fallback tier for [`Router::route`]. The tier must
    /// already be registered.
    pub(crate) fn set_default(&self, name: &str) -> Result<(), ServeError> {
        let map = self.locked();
        if !map.contains_key(name) {
            return Err(unknown(&map, name));
        }
        *crate::util::lock_ignore_poison(&self.default_tier) = Some(name.to_string());
        Ok(())
    }

    /// Registered tier names, sorted.
    pub(crate) fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.locked().keys().cloned().collect();
        v.sort();
        v
    }

    /// Every registered tier with its name, sorted by name — the
    /// enumeration the SLO cascade walks to pick a quality ladder.
    pub(crate) fn entries(&self) -> Vec<(String, Arc<Tier>)> {
        let mut v: Vec<(String, Arc<Tier>)> = self
            .locked()
            .iter()
            .map(|(k, t)| (k.clone(), Arc::clone(t)))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    /// Install (or clear) the tracer. Admissions pick it up immediately;
    /// worker/supervisor tier-level sinks are captured at registration.
    pub(crate) fn set_tracer(&self, t: Option<Arc<Tracer>>) {
        let mut cur = crate::util::lock_ignore_poison(&self.tracer);
        self.tracing.store(t.is_some(), Ordering::Relaxed);
        *cur = t;
    }

    /// The installed tracer, if tracing is enabled. One relaxed load when
    /// off — the hot-path cost the acceptance criteria bound.
    pub(crate) fn tracer(&self) -> Option<Arc<Tracer>> {
        if !self.tracing.load(Ordering::Relaxed) {
            return None;
        }
        crate::util::lock_ignore_poison(&self.tracer).clone()
    }

    /// Close every tier queue (stops admissions; queued work drains).
    pub(crate) fn close_all(&self) {
        for tier in self.locked().values() {
            tier.close();
        }
    }
}

/// Registration probe results (see the module docs).
pub(crate) struct ProbeReport {
    pub(crate) out_dim: usize,
    pub(crate) peak_batch_bytes: u64,
    pub(crate) bit_identical_to_unbatched: bool,
}

/// Seed for the probe rows — fixed, so registration is deterministic.
const PROBE_SEED: u64 = 0x5e21e;

/// Vet `model` for row-batched serving at `max_batch` (see module docs).
pub(crate) fn probe_model(
    model: &Model,
    in_dim: usize,
    max_batch: usize,
) -> Result<ProbeReport, ServeError> {
    let mut rng = Philox::seeded(PROBE_SEED);
    let probe = Mat::randn(1, in_dim, &mut rng).scale(0.5);
    let fail = |e: anyhow::Error| ServeError::Probe(format!("{e:#}"));
    // Plain single-row forward — the unbatched baseline.
    let solo = model.forward(&probe, &ForwardCtx::new()).map_err(fail)?;
    if solo.rows() != 1 {
        return Err(ServeError::Probe(format!(
            "model maps 1 input row to {} output rows — row-routed serving \
             needs one result row per request row",
            solo.rows()
        )));
    }
    // Padded alone at the cap, under a tracker: the per-batch footprint.
    // Both padded forwards go through `Model::forward_rows` — the public
    // form of the stack/pad/unstack contract the worker loop's
    // buffer-reusing twin implements — so the probe validates the very
    // sequence it vouches for (incl. the rows-out == rows-in check).
    let tracker = MemTracker::unlimited();
    let ctx1 = ForwardCtx::with_tracker(tracker.clone()).batch_hint(max_batch);
    let alone = model
        .forward_rows(&[probe.row(0)], max_batch, &ctx1)
        .map_err(fail)?;
    // Padded among random co-rows: co-riders must not leak into row 0.
    let co = Mat::randn(max_batch, in_dim, &mut rng).scale(0.5);
    let mut rows: Vec<&[f32]> = vec![probe.row(0)];
    for i in 1..max_batch {
        rows.push(co.row(i));
    }
    let mixed = model
        .forward_rows(&rows, max_batch, &ForwardCtx::new().batch_hint(max_batch))
        .map_err(fail)?;
    if alone[0] != mixed[0] {
        return Err(ServeError::RowCoupled(format!(
            "a row's result changed with its batch co-rows at cap {max_batch} \
             — the model couples rows (attention-style layers cannot be \
             row-batched; serve them at max_batch = 1)"
        )));
    }
    Ok(ProbeReport {
        out_dim: alone[0].len(),
        peak_batch_bytes: tracker.peak_bytes(),
        bit_identical_to_unbatched: alone[0].as_slice() == solo.row(0),
    })
}

/// Sequence-tier probe results: peak activation bytes at two sequence
/// lengths (`n0`, `2·n0`) — the two points
/// [`crate::nn::cost::max_len_under_budget`] fits its admission model
/// through — plus whether a packed co-sequence is bitwise invisible.
pub(crate) struct SeqProbeReport {
    pub(crate) out_dim: usize,
    /// Peak activation bytes of a single length-`n0` sequence forward.
    pub(crate) peak0: u64,
    /// Peak activation bytes of a single length-`2·n0` sequence forward.
    pub(crate) peak1: u64,
    /// Whether the probe sequence's packed-with-a-co-sequence result was
    /// bit-identical to its packed-alone result. Unlike the row probe
    /// this does NOT reject on mismatch — sequence tiers exist precisely
    /// to serve row-coupled (attention) stacks, whose masking makes
    /// co-sequences *structurally* invisible; the flag records whether
    /// the GEMM kernel path also kept them *bitwise* invisible at the
    /// probe shapes.
    pub(crate) seq_stable: bool,
}

/// Vet `model` for packed-sequence serving and measure the two peak
/// points of the admission fit (see module docs and [`SeqProbeReport`]).
pub(crate) fn probe_seq_model(
    model: &Model,
    in_dim: usize,
    n0: usize,
) -> Result<SeqProbeReport, ServeError> {
    let mut rng = Philox::seeded(PROBE_SEED);
    let probe = Mat::randn(n0, in_dim, &mut rng).scale(0.5);
    let fail = |e: anyhow::Error| ServeError::Probe(format!("{e:#}"));
    let tr0 = MemTracker::unlimited();
    let ctx0 = ForwardCtx::with_tracker(tr0.clone());
    let solo = model
        .forward_seq(&probe, &SeqBatch::single(n0), &ctx0)
        .map_err(fail)?;
    if solo.rows() != n0 {
        return Err(ServeError::Probe(format!(
            "model maps a {n0}-token sequence to {} output rows — \
             sequence serving needs one result row per token",
            solo.rows()
        )));
    }
    let long = Mat::randn(2 * n0, in_dim, &mut rng).scale(0.5);
    let tr1 = MemTracker::unlimited();
    let ctx1 = ForwardCtx::with_tracker(tr1.clone());
    model
        .forward_seq(&long, &SeqBatch::single(2 * n0), &ctx1)
        .map_err(fail)?;
    // Pack the probe sequence BEHIND a random co-sequence (offset ≠ 0 is
    // the harder case for kernel-path stability) and compare its slice
    // against the packed-alone result, bit for bit.
    let co = Mat::randn(n0, in_dim, &mut rng).scale(0.5);
    let mut x = Mat::zeros(2 * n0, in_dim);
    for i in 0..n0 {
        x.row_mut(i).copy_from_slice(co.row(i));
        x.row_mut(n0 + i).copy_from_slice(probe.row(i));
    }
    let sb = SeqBatch::packed(vec![n0, n0]).map_err(fail)?;
    let packed = model.forward_seq(&x, &sb, &ForwardCtx::new()).map_err(fail)?;
    let seq_stable = (0..n0).all(|i| packed.row(n0 + i) == solo.row(i));
    Ok(SeqProbeReport {
        out_dim: solo.cols(),
        peak0: tr0.peak_bytes(),
        peak1: tr1.peak_bytes(),
        seq_stable,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{AttnWeights, Linear, MultiHeadAttention};

    #[test]
    fn probe_accepts_row_independent_and_rejects_coupled() {
        let mut rng = Philox::seeded(9);
        let mut mlp = Model::new();
        mlp.add("fc", Linear::random(8, 4, &mut rng)).unwrap();
        let rep = probe_model(&mlp, 8, 4).unwrap();
        assert_eq!(rep.out_dim, 4);
        assert!(rep.peak_batch_bytes > 0);
        // Cap 4 keeps every product on the small kernels: the padded
        // forward is bit-identical to the unbatched one.
        assert!(rep.bit_identical_to_unbatched);

        let mut attn = Model::new();
        attn.add(
            "attn",
            MultiHeadAttention::new(AttnWeights::random(8, 2, &mut rng)),
        )
        .unwrap();
        let err = probe_model(&attn, 8, 4).unwrap_err();
        assert!(matches!(err, ServeError::RowCoupled(_)), "{err}");
        // At cap 1 a whole "row = the entire request" model is fine.
        assert!(probe_model(&attn, 8, 1).is_ok());
    }

    #[test]
    fn seq_probe_measures_two_points_and_stability() {
        let mut rng = Philox::seeded(11);
        let mut attn = Model::new();
        attn.add(
            "attn",
            MultiHeadAttention::new(AttnWeights::random(8, 2, &mut rng)),
        )
        .unwrap();
        // The row probe rejects this model; the seq probe admits it.
        assert!(matches!(
            probe_model(&attn, 8, 4).unwrap_err(),
            ServeError::RowCoupled(_)
        ));
        let rep = probe_seq_model(&attn, 8, 8).unwrap();
        assert_eq!(rep.out_dim, 8);
        assert!(rep.peak1 > rep.peak0, "longer sequence, bigger peak");
        // Small shapes stay below the GEMM packing threshold, where
        // per-segment masking is bitwise exact.
        assert!(rep.seq_stable, "co-sequence leaked into the probe result");
    }

    #[test]
    fn router_insert_get_duplicate() {
        use crate::serve::metrics::TierMetrics;
        let r = Router::default();
        let mk = |n: &str| Tier::Row {
            queue: Arc::new(TierQueue::new(4, Arc::new(TierMetrics::default()))),
            slot: Arc::new(ModelSlot::new(Model::new())),
            raw_out: 2,
            info: TierInfo {
                name: n.into(),
                in_dim: 2,
                out_dim: 2,
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
                workers: 1,
                weight_bytes: 0,
                peak_batch_bytes: 0,
                bit_identical_to_unbatched: true,
            },
        };
        r.insert("a", mk("a")).unwrap();
        assert!(matches!(
            r.insert("a", mk("a")),
            Err(ServeError::DuplicateTier(_))
        ));
        assert!(r.get("a").is_ok());
        // The unknown-tier error names what IS registered.
        match r.get("b") {
            Err(ServeError::UnknownTier { name, registered }) => {
                assert_eq!(name, "b");
                assert_eq!(registered, vec!["a"]);
            }
            other => panic!("expected UnknownTier, got {other:?}"),
        }
        assert_eq!(r.names(), vec!["a"]);
        assert_eq!(r.entries().len(), 1);
        assert_eq!(r.entries()[0].0, "a");
    }

    #[test]
    fn router_default_tier_fallback_routes_only_requests() {
        use crate::serve::metrics::TierMetrics;
        let r = Router::default();
        let mk = |n: &str| Tier::Row {
            queue: Arc::new(TierQueue::new(4, Arc::new(TierMetrics::default()))),
            slot: Arc::new(ModelSlot::new(Model::new())),
            raw_out: 2,
            info: TierInfo {
                name: n.into(),
                in_dim: 2,
                out_dim: 2,
                max_batch: 4,
                max_wait: std::time::Duration::from_millis(1),
                workers: 1,
                weight_bytes: 0,
                peak_batch_bytes: 0,
                bit_identical_to_unbatched: true,
            },
        };
        r.insert("a", mk("a")).unwrap();
        r.insert("b", mk("b")).unwrap();
        // No default configured: routing an unknown name errors.
        assert!(matches!(
            r.route("typo"),
            Err(ServeError::UnknownTier { .. })
        ));
        // The default must itself be registered.
        assert!(matches!(
            r.set_default("nope"),
            Err(ServeError::UnknownTier { .. })
        ));
        r.set_default("b").unwrap();
        // Unknown names now route to the fallback; known names still
        // route to themselves.
        let routed = r.route("typo").unwrap();
        match &*routed {
            Tier::Row { info, .. } => assert_eq!(info.name, "b"),
            _ => panic!("expected row tier"),
        }
        let direct = r.route("a").unwrap();
        match &*direct {
            Tier::Row { info, .. } => assert_eq!(info.name, "a"),
            _ => panic!("expected row tier"),
        }
        // Strict lookups do NOT fall back — info for a typo stays an
        // error even with a default configured.
        assert!(matches!(
            r.get("typo"),
            Err(ServeError::UnknownTier { .. })
        ));
    }
}
