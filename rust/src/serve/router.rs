//! Tier registry + registration-time model vetting.
//!
//! A [`super::ModelServer`] serves several *quality tiers* of the same
//! workload — e.g. a dense model next to its `SketchPlan`-compressed
//! variant — each behind its own bounded queue and worker pool. The
//! router is the name → tier map requests are routed by.
//!
//! Registration **probes** the model before a single request is accepted:
//!
//! 1. *Row independence* — a probe row is forwarded padded-alone and
//!    padded among random co-rows at the tier's batch cap; the two row
//!    results must match **bit-for-bit**, otherwise batching would let
//!    co-riders (and the zero padding) leak into results. This is what
//!    rejects row-coupled layers (attention mixes sequence rows) at caps
//!    above 1, with a clean error instead of silent corruption.
//! 2. *Footprint* — the padded-batch forward runs under a
//!    [`MemTracker`], so the tier knows its peak per-batch activation
//!    bytes; together with the stored weight bytes this drives the
//!    memory-budget admission in [`super::ModelServer::register_tier`]
//!    (smaller sketched models ⇒ more workers per byte — the paper's
//!    memory saving turned into serving capacity).
//! 3. *Unbatched equivalence* — whether the cap-padded forward is
//!    additionally bit-identical to the plain single-row
//!    `Module::forward`. True whenever cap and shapes keep every product
//!    on the same GEMM kernel (always at caps below the microkernel
//!    height of 8); recorded in [`super::TierInfo`] so callers know which
//!    guarantee they hold.

use super::batcher::TierQueue;
use super::{ServeError, TierInfo};
use crate::linalg::Mat;
use crate::nn::{ForwardCtx, Model};
use crate::rng::Philox;
use crate::util::memtrack::MemTracker;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

/// One registered tier: the model replicaset behind a queue.
pub(crate) struct Tier {
    pub(crate) queue: Arc<TierQueue>,
    pub(crate) info: TierInfo,
}

/// Name → tier map shared between the server and its client handles.
#[derive(Default)]
pub(crate) struct Router {
    tiers: Mutex<HashMap<String, Arc<Tier>>>,
}

impl Router {
    fn locked(&self) -> MutexGuard<'_, HashMap<String, Arc<Tier>>> {
        crate::util::lock_ignore_poison(&self.tiers)
    }

    pub(crate) fn insert(&self, name: &str, tier: Tier) -> Result<(), ServeError> {
        let mut map = self.locked();
        if map.contains_key(name) {
            return Err(ServeError::DuplicateTier(name.to_string()));
        }
        map.insert(name.to_string(), Arc::new(tier));
        Ok(())
    }

    pub(crate) fn get(&self, name: &str) -> Result<Arc<Tier>, ServeError> {
        self.locked()
            .get(name)
            .cloned()
            .ok_or_else(|| ServeError::UnknownTier(name.to_string()))
    }

    /// Registered tier names, sorted.
    pub(crate) fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.locked().keys().cloned().collect();
        v.sort();
        v
    }

    /// Close every tier queue (stops admissions; queued work drains).
    pub(crate) fn close_all(&self) {
        for tier in self.locked().values() {
            tier.queue.close();
        }
    }
}

/// Registration probe results (see the module docs).
pub(crate) struct ProbeReport {
    pub(crate) out_dim: usize,
    pub(crate) peak_batch_bytes: u64,
    pub(crate) bit_identical_to_unbatched: bool,
}

/// Seed for the probe rows — fixed, so registration is deterministic.
const PROBE_SEED: u64 = 0x5e21e;

/// Vet `model` for row-batched serving at `max_batch` (see module docs).
pub(crate) fn probe_model(
    model: &Model,
    in_dim: usize,
    max_batch: usize,
) -> Result<ProbeReport, ServeError> {
    let mut rng = Philox::seeded(PROBE_SEED);
    let probe = Mat::randn(1, in_dim, &mut rng).scale(0.5);
    let fail = |e: anyhow::Error| ServeError::Probe(format!("{e:#}"));
    // Plain single-row forward — the unbatched baseline.
    let solo = model.forward(&probe, &ForwardCtx::new()).map_err(fail)?;
    if solo.rows() != 1 {
        return Err(ServeError::Probe(format!(
            "model maps 1 input row to {} output rows — row-routed serving \
             needs one result row per request row",
            solo.rows()
        )));
    }
    // Padded alone at the cap, under a tracker: the per-batch footprint.
    // Both padded forwards go through `Model::forward_rows` — the public
    // form of the stack/pad/unstack contract the worker loop's
    // buffer-reusing twin implements — so the probe validates the very
    // sequence it vouches for (incl. the rows-out == rows-in check).
    let tracker = MemTracker::unlimited();
    let ctx1 = ForwardCtx::with_tracker(tracker.clone()).batch_hint(max_batch);
    let alone = model
        .forward_rows(&[probe.row(0)], max_batch, &ctx1)
        .map_err(fail)?;
    // Padded among random co-rows: co-riders must not leak into row 0.
    let co = Mat::randn(max_batch, in_dim, &mut rng).scale(0.5);
    let mut rows: Vec<&[f32]> = vec![probe.row(0)];
    for i in 1..max_batch {
        rows.push(co.row(i));
    }
    let mixed = model
        .forward_rows(&rows, max_batch, &ForwardCtx::new().batch_hint(max_batch))
        .map_err(fail)?;
    if alone[0] != mixed[0] {
        return Err(ServeError::RowCoupled(format!(
            "a row's result changed with its batch co-rows at cap {max_batch} \
             — the model couples rows (attention-style layers cannot be \
             row-batched; serve them at max_batch = 1)"
        )));
    }
    Ok(ProbeReport {
        out_dim: alone[0].len(),
        peak_batch_bytes: tracker.peak_bytes(),
        bit_identical_to_unbatched: alone[0].as_slice() == solo.row(0),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{AttnWeights, Linear, MultiHeadAttention};

    #[test]
    fn probe_accepts_row_independent_and_rejects_coupled() {
        let mut rng = Philox::seeded(9);
        let mut mlp = Model::new();
        mlp.add("fc", Linear::random(8, 4, &mut rng)).unwrap();
        let rep = probe_model(&mlp, 8, 4).unwrap();
        assert_eq!(rep.out_dim, 4);
        assert!(rep.peak_batch_bytes > 0);
        // Cap 4 keeps every product on the small kernels: the padded
        // forward is bit-identical to the unbatched one.
        assert!(rep.bit_identical_to_unbatched);

        let mut attn = Model::new();
        attn.add(
            "attn",
            MultiHeadAttention::new(AttnWeights::random(8, 2, &mut rng)),
        )
        .unwrap();
        let err = probe_model(&attn, 8, 4).unwrap_err();
        assert!(matches!(err, ServeError::RowCoupled(_)), "{err}");
        // At cap 1 a whole "row = the entire request" model is fine.
        assert!(probe_model(&attn, 8, 1).is_ok());
    }

    #[test]
    fn router_insert_get_duplicate() {
        use crate::serve::metrics::TierMetrics;
        let r = Router::default();
        let mk = || Tier {
            queue: Arc::new(TierQueue::new(4, Arc::new(TierMetrics::default()))),
            info: TierInfo {
                name: "a".into(),
                in_dim: 2,
                out_dim: 2,
                max_batch: 4,
                workers: 1,
                weight_bytes: 0,
                peak_batch_bytes: 0,
                bit_identical_to_unbatched: true,
            },
        };
        r.insert("a", mk()).unwrap();
        assert!(matches!(
            r.insert("a", mk()),
            Err(ServeError::DuplicateTier(_))
        ));
        assert!(r.get("a").is_ok());
        assert!(matches!(r.get("b"), Err(ServeError::UnknownTier(_))));
        assert_eq!(r.names(), vec!["a"]);
    }
}
