//! Server-side output transforms: the decode step that turns raw model
//! rows into what the client actually wants — probabilities or a compact
//! top-k shortlist — applied per tier inside the worker loop, after the
//! batched forward and before each row is routed back to its caller.
//!
//! Doing this server-side matters for sequence tiers: a token-level
//! top-k over a vocab-wide logit row shrinks the reply from `vocab`
//! floats to `2·k`, so the decode cost is paid once in the worker (on
//! rows that are already hot in cache) instead of shipping full logit
//! matrices to every client. All reductions accumulate in f64, matching
//! the crate-wide numeric policy.

use crate::linalg::Mat;

/// What a tier's workers do to each raw output row before replying.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutputTransform {
    /// Ship raw model rows (logits) unchanged — the default, and the only
    /// mode with a zero-copy worker fast path.
    Raw,
    /// Row-wise softmax: each reply row sums to 1. Width unchanged.
    Softmax,
    /// Per-row top-k decode: each reply row is `k` `(index, logprob)`
    /// pairs flattened to `2·k` floats — `[idx_0, lp_0, idx_1, lp_1, …]`,
    /// sorted by descending logit, ties broken toward the lower index.
    TopK(usize),
}

impl OutputTransform {
    /// Reply-row width for a raw model width of `raw` columns.
    pub fn out_width(&self, raw: usize) -> usize {
        match self {
            OutputTransform::Raw | OutputTransform::Softmax => raw,
            OutputTransform::TopK(k) => 2 * k,
        }
    }

    /// Whether this transform is usable on rows of `raw` columns.
    /// `TopK(0)` and `k > raw` are rejected at tier registration, not at
    /// request time.
    pub fn validate(&self, raw: usize) -> Result<(), String> {
        match self {
            OutputTransform::Raw | OutputTransform::Softmax => Ok(()),
            OutputTransform::TopK(0) => Err("TopK(0) selects nothing".into()),
            OutputTransform::TopK(k) if *k > raw => Err(format!(
                "TopK({k}) over rows of width {raw} — k must be ≤ the \
                 model's output width"
            )),
            OutputTransform::TopK(_) => Ok(()),
        }
    }

    /// Apply the transform row-wise. [`OutputTransform::Raw`] clones; the
    /// worker loops skip the call entirely in that mode.
    pub fn apply(&self, y: &Mat) -> Mat {
        match self {
            OutputTransform::Raw => y.clone(),
            OutputTransform::Softmax => {
                let mut out = Mat::zeros(y.rows(), y.cols());
                for i in 0..y.rows() {
                    softmax_row_into(y.row(i), out.row_mut(i));
                }
                out
            }
            OutputTransform::TopK(k) => {
                let mut out = Mat::zeros(y.rows(), 2 * k);
                let mut order: Vec<usize> = Vec::with_capacity(y.cols());
                for i in 0..y.rows() {
                    let row = y.row(i);
                    let (mx, lse) = log_sum_exp(row);
                    order.clear();
                    order.extend(0..row.len());
                    // Full sort keeps the output deterministic (stable
                    // index tie-break) — reply rows must be a pure
                    // function of the logit row.
                    order.sort_by(|&a, &b| {
                        row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal)
                            .then(a.cmp(&b))
                    });
                    let orow = out.row_mut(i);
                    for (slot, &j) in order.iter().take(*k).enumerate() {
                        orow[2 * slot] = j as f32;
                        orow[2 * slot + 1] = ((row[j] as f64 - mx) - lse) as f32;
                    }
                }
                out
            }
        }
    }
}

/// `(max, ln Σ exp(x − max))` of a row, f64-accumulated.
fn log_sum_exp(row: &[f32]) -> (f64, f64) {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let s: f64 = row.iter().map(|&v| (v as f64 - mx).exp()).sum();
    (mx, s.ln())
}

fn softmax_row_into(row: &[f32], out: &mut [f32]) {
    let (mx, lse) = log_sum_exp(row);
    for (o, &v) in out.iter_mut().zip(row) {
        *o = ((v as f64 - mx) - lse).exp() as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    #[test]
    fn widths_and_validation() {
        assert_eq!(OutputTransform::Raw.out_width(7), 7);
        assert_eq!(OutputTransform::Softmax.out_width(7), 7);
        assert_eq!(OutputTransform::TopK(3).out_width(7), 6);
        assert!(OutputTransform::TopK(0).validate(7).is_err());
        assert!(OutputTransform::TopK(8).validate(7).is_err());
        assert!(OutputTransform::TopK(7).validate(7).is_ok());
        assert!(OutputTransform::Softmax.validate(1).is_ok());
    }

    #[test]
    fn softmax_rows_normalize_and_order_preserves() {
        let mut rng = Philox::seeded(61);
        let y = Mat::randn(5, 9, &mut rng);
        let p = OutputTransform::Softmax.apply(&y);
        assert_eq!(p.shape(), y.shape());
        for i in 0..5 {
            let s: f64 = p.row(i).iter().map(|&v| v as f64).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
            // Monotone: argmax of logits is argmax of probabilities.
            let am = |r: &[f32]| {
                r.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0
            };
            assert_eq!(am(y.row(i)), am(p.row(i)));
        }
    }

    #[test]
    fn topk_picks_the_right_indices_with_logprobs() {
        let y = Mat::from_vec(1, 4, vec![0.5, 3.0, -1.0, 3.0]);
        let t = OutputTransform::TopK(2).apply(&y);
        assert_eq!(t.shape(), (1, 4));
        let r = t.row(0);
        // Tied 3.0s: lower index wins slot 0.
        assert_eq!(r[0], 1.0);
        assert_eq!(r[2], 3.0);
        assert_eq!(r[1], r[3], "equal logits, equal logprobs");
        // logprob matches an independent softmax of the same row.
        let p = OutputTransform::Softmax.apply(&y);
        assert!((r[1] - p.row(0)[1].ln()).abs() < 1e-5);
        // Raw is the identity.
        assert_eq!(OutputTransform::Raw.apply(&y).data(), y.data());
    }
}
