//! `panther` — CLI for the Panther-RS framework.
//!
//! Subcommands:
//! - `info`       — artifact/model inventory
//! - `train`      — train a model variant (BERT-mini MLM or conv classifier)
//! - `tune`       — run the SKAutoTuner over BERT sketch candidates
//! - `decompose`  — RSVD / CQRRPT vs deterministic baselines
//! - `smoke`      — execute kernel artifacts once and check numerics (CI)

#![allow(clippy::needless_range_loop)]

use anyhow::{bail, Context, Result};
use panther::coordinator::RuntimeServer;
use panther::data::{ImageDataset, TextCorpus};
use panther::decomp::{cqrrpt, rsvd, CqrrptOpts, RsvdOpts};
use panther::linalg::{fro_norm, matmul, ortho_error, qr_thin, svd_jacobi, Mat};
use panther::rng::Philox;
use panther::runtime::{HostTensor, Runtime};
use panther::train::{BertTrainer, ConvTrainer, ModelState};
use panther::util::cli::Command;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let Some(sub) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    match sub.as_str() {
        "info" => cmd_info(rest),
        "train" => cmd_train(rest),
        "tune" => cmd_tune(rest),
        "decompose" => cmd_decompose(rest),
        "smoke" => cmd_smoke(rest),
        "--help" | "help" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (see `panther help`)"),
    }
}

fn print_usage() {
    println!(
        "panther {} — RandNLA model compression (Rust + JAX + Pallas)\n\n\
         subcommands:\n\
         \x20 info        artifact & model inventory\n\
         \x20 train       train a model variant end-to-end\n\
         \x20 tune        SKAutoTuner over BERT sketch candidates\n\
         \x20 decompose   RSVD / CQRRPT vs deterministic baselines\n\
         \x20 smoke       execute each kernel artifact once\n\n\
         run `panther <subcommand> --help` for options",
        panther::VERSION
    );
}

fn wants_help(args: &[String]) -> bool {
    args.iter().any(|a| a == "--help" || a == "-h")
}

fn cmd_info(args: &[String]) -> Result<()> {
    let cmd = Command::new("info", "artifact & model inventory").opt(
        "artifacts",
        "artifact directory",
        Some("artifacts"),
    );
    if wants_help(args) {
        println!("{}", cmd.help());
        return Ok(());
    }
    let parsed = cmd.parse(args).map_err(anyhow::Error::msg)?;
    let rt = Runtime::open(parsed.get_or("artifacts", "artifacts"))?;
    println!("panther {} — artifact inventory", panther::VERSION);
    println!("execution backend: {}", rt.backend_name());
    println!("\nmodels:");
    for name in rt.manifest().model_names() {
        let m = rt.manifest().model(name).unwrap();
        println!(
            "  {:<16} family={:<5} params={:>9} sketch={:?}",
            m.name,
            m.family,
            m.param_count,
            m.sketch()
        );
    }
    println!("\nartifacts:");
    for name in rt.manifest().artifact_names() {
        let a = rt.manifest().artifact(name).unwrap();
        println!(
            "  {:<24} {:>3} inputs {:>3} outputs  ({})",
            a.name,
            a.inputs.len(),
            a.outputs.len(),
            a.path
        );
    }
    Ok(())
}

fn cmd_train(args: &[String]) -> Result<()> {
    let cmd = Command::new("train", "train a model variant")
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("model", "model name from the manifest", Some("bert_dense"))
        .opt("steps", "training steps", Some("100"))
        .opt("seed", "init + data seed", Some("0"))
        .opt("eval-batches", "eval batches after training", Some("8"))
        .opt("checkpoint", "path to save the final state", None);
    if wants_help(args) {
        println!("{}", cmd.help());
        return Ok(());
    }
    let p = cmd.parse(args).map_err(anyhow::Error::msg)?;
    let mut rt = Runtime::open(p.get_or("artifacts", "artifacts"))?;
    let model = p.get_or("model", "bert_dense").to_string();
    let steps = p.get_u64("steps", 100);
    let seed = p.get_u64("seed", 0);
    let spec = rt
        .manifest()
        .model(&model)
        .with_context(|| format!("unknown model {model}"))?
        .clone();
    let mut state = ModelState::init(&mut rt, &model, seed as f32)?;
    println!(
        "training {model} ({} params) for {steps} steps",
        state.param_count()
    );
    let mut data_rng = Philox::new(seed, 1);
    match spec.family.as_str() {
        "bert" => {
            let corpus = TextCorpus::generate(
                spec.config_usize("vocab").unwrap_or(256),
                200_000,
                seed ^ 0xC0FFEE,
            );
            let mut trainer = BertTrainer::new(&mut rt, &corpus);
            let report = trainer.train(&mut state, steps, &mut data_rng)?;
            let eval = trainer.evaluate(&state, p.get_usize("eval-batches", 8), &mut data_rng)?;
            println!(
                "done in {:.1?}: final train loss {:.4}, eval loss {:.4}",
                report.wall, report.final_loss, eval
            );
        }
        "conv" => {
            let ds = ImageDataset::cifar_like();
            let mut trainer = ConvTrainer::new(&mut rt, &ds);
            let report = trainer.train(&mut state, steps, &mut data_rng)?;
            let acc = trainer.accuracy(&state, p.get_usize("eval-batches", 8), &mut data_rng)?;
            println!(
                "done in {:.1?}: final loss {:.4}, accuracy {:.1}%",
                report.wall,
                report.final_loss,
                acc * 100.0
            );
        }
        f => bail!("unknown model family {f}"),
    }
    if let Some(ckpt) = p.get("checkpoint") {
        panther::train::checkpoint::save(&state, ckpt)?;
        println!("checkpoint written to {ckpt}");
    }
    Ok(())
}

fn cmd_tune(args: &[String]) -> Result<()> {
    let cmd = Command::new("tune", "SKAutoTuner over BERT sketch candidates")
        .opt("artifacts", "artifact directory", Some("artifacts"))
        .opt("train-steps", "dense pre-training steps", Some("60"))
        .opt("eval-batches", "eval batches per candidate", Some("4"))
        .opt(
            "loss-margin",
            "allowed eval-loss increase over dense",
            Some("0.35"),
        )
        .opt("seed", "seed", Some("0"));
    if wants_help(args) {
        println!("{}", cmd.help());
        return Ok(());
    }
    let p = cmd.parse(args).map_err(anyhow::Error::msg)?;
    let outcome = panther::tuner::bert_tune::tune_bert_candidates(
        p.get_or("artifacts", "artifacts"),
        p.get_u64("train-steps", 60),
        p.get_usize("eval-batches", 4),
        p.get_f64("loss-margin", 0.35),
        p.get_u64("seed", 0),
    )?;
    println!("{outcome}");
    Ok(())
}

fn cmd_decompose(args: &[String]) -> Result<()> {
    let cmd = Command::new("decompose", "randomized decompositions demo")
        .opt("rows", "matrix rows", Some("2000"))
        .opt("cols", "matrix cols", Some("64"))
        .opt("rank", "RSVD target rank", Some("16"))
        .opt("seed", "seed", Some("0"));
    if wants_help(args) {
        println!("{}", cmd.help());
        return Ok(());
    }
    let p = cmd.parse(args).map_err(anyhow::Error::msg)?;
    let (m, n) = (p.get_usize("rows", 2000), p.get_usize("cols", 64));
    let rank = p.get_usize("rank", 16);
    let mut rng = Philox::seeded(p.get_u64("seed", 0));
    let a = Mat::randn(m, n, &mut rng);

    let t0 = std::time::Instant::now();
    let f = cqrrpt(&a, &CqrrptOpts::default());
    let t_cqrrpt = t0.elapsed();
    let t0 = std::time::Instant::now();
    let (q_hh, _r) = qr_thin(&a);
    let t_hh = t0.elapsed();
    println!("CQRRPT on {m}×{n}:");
    println!(
        "  cqrrpt:      {:>10.2?}  ‖QᵀQ−I‖ = {:.2e}  (fallback: {})",
        t_cqrrpt,
        ortho_error(&f.q),
        f.fallback
    );
    println!(
        "  householder: {:>10.2?}  ‖QᵀQ−I‖ = {:.2e}",
        t_hh,
        ortho_error(&q_hh)
    );

    let t0 = std::time::Instant::now();
    let rs = rsvd(
        &a,
        &RsvdOpts {
            rank,
            ..Default::default()
        },
    );
    let t_rsvd = t0.elapsed();
    let t0 = std::time::Instant::now();
    let exact = svd_jacobi(&a);
    let t_svd = t0.elapsed();
    let opt = fro_norm(&a.sub(&exact.truncate(rank).reconstruct()));
    let got = fro_norm(&a.sub(&rs.reconstruct()));
    println!("RSVD rank {rank}:");
    println!("  rsvd:        {t_rsvd:>10.2?}  ‖A−Â‖ = {got:.4}");
    println!("  jacobi svd:  {t_svd:>10.2?}  optimal rank-{rank} error = {opt:.4}");
    println!("  suboptimality: {:.3}×", got / opt.max(1e-12));
    Ok(())
}

fn cmd_smoke(args: &[String]) -> Result<()> {
    let cmd = Command::new("smoke", "execute each kernel artifact once").opt(
        "artifacts",
        "artifact directory",
        Some("artifacts"),
    );
    if wants_help(args) {
        println!("{}", cmd.help());
        return Ok(());
    }
    let p = cmd.parse(args).map_err(anyhow::Error::msg)?;
    let server = RuntimeServer::start(p.get_or("artifacts", "artifacts"))?;
    let h = server.handle();
    for name in ["k_sk_linear", "k_performer"] {
        let spec = h
            .manifest()
            .artifact(name)
            .with_context(|| format!("missing {name}"))?
            .clone();
        let inputs: Vec<HostTensor> = spec
            .inputs
            .iter()
            .map(|s| HostTensor::zeros(&s.shape))
            .collect();
        let out = h.execute(name, inputs)?;
        println!("{name}: OK ({} outputs)", out.len());
    }
    // Verify against the Rust reference path: random SKLinear inputs.
    let spec = h.manifest().artifact("k_sk_linear").unwrap().clone();
    let mut rng = Philox::seeded(7);
    let inputs: Vec<HostTensor> = spec
        .inputs
        .iter()
        .map(|s| HostTensor::randn(&s.shape, 0.5, &mut rng))
        .collect();
    let out = h.execute("k_sk_linear", inputs.clone())?;
    let (x, u, v, bias) = (&inputs[0], &inputs[1], &inputs[2], &inputs[3]);
    let l = u.shape()[0];
    let (d_in, k) = (u.shape()[1], u.shape()[2]);
    let d_out = v.shape()[2];
    let mut expect = Mat::zeros(x.shape()[0], d_out);
    for j in 0..l {
        let uj = Mat::from_vec(d_in, k, u.data()[j * d_in * k..(j + 1) * d_in * k].to_vec());
        let vj = Mat::from_vec(
            k,
            d_out,
            v.data()[j * k * d_out..(j + 1) * k * d_out].to_vec(),
        );
        expect.axpy(1.0 / l as f32, &matmul(&matmul(&x.to_mat(), &uj), &vj));
    }
    for i in 0..expect.rows() {
        for (val, bb) in expect.row_mut(i).iter_mut().zip(bias.data()) {
            *val += bb;
        }
    }
    let err = panther::linalg::rel_error(&out[0].to_mat(), &expect);
    println!("k_sk_linear vs rust reference: rel error {err:.2e}");
    anyhow::ensure!(err < 1e-4, "kernel/reference mismatch");
    println!("{}", server.metrics().report());
    Ok(())
}
