//! Dynamic request batching — the serving-side coordinator feature.
//!
//! The AOT executables have fixed batch shape (`B × S` baked at lowering),
//! but serving traffic arrives one sequence at a time. The
//! [`DynamicBatcher`] does what a vLLM-style router does: queue incoming
//! single-sequence scoring requests, coalesce up to `B` of them (or
//! whatever arrived within `max_wait`), pad the remainder with zero-mask
//! rows (which score 0 and are discarded), execute the `eval_rows`
//! artifact once, and route each row's result back to its caller.
//!
//! Throughput scales ~B× over one-request-per-execution at full occupancy;
//! the occupancy histogram is exported for the e2e bench.
//!
//! This batcher is welded to the artifact runtime and the MLM-scoring
//! request shape; [`crate::serve`] generalizes the same
//! queue/coalesce/pad/route loop to *any* native [`crate::nn::Module`]
//! model, with tiered routing and admission control on top. The
//! occupancy statistics here share their histogram type
//! ([`crate::util::stats::OccupancyHist`]) with the serve-side tiers.

use super::RuntimeHandle;
use crate::runtime::HostTensor;
use anyhow::{anyhow, Context, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// One MLM scoring request: a single sequence (tokens/labels/mask, each
/// `seq` long, f32-encoded as the artifacts expect).
pub struct ScoreRequest {
    pub tokens: Vec<f32>,
    pub labels: Vec<f32>,
    pub mask: Vec<f32>,
    reply: mpsc::Sender<Result<f32>>,
}

/// Cloneable submission handle.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: mpsc::Sender<ScoreRequest>,
    seq: usize,
    stats: Arc<BatcherStats>,
}

impl BatcherHandle {
    /// Score one sequence (blocks until the batch it joins completes).
    pub fn score(&self, tokens: &[f32], labels: &[f32], mask: &[f32]) -> Result<f32> {
        anyhow::ensure!(
            tokens.len() == self.seq && labels.len() == self.seq && mask.len() == self.seq,
            "sequence length must be {} (artifact shape)",
            self.seq
        );
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(ScoreRequest {
                tokens: tokens.to_vec(),
                labels: labels.to_vec(),
                mask: mask.to_vec(),
                reply: rtx,
            })
            .map_err(|_| anyhow!("batcher stopped"))?;
        rrx.recv().map_err(|_| anyhow!("batcher dropped reply"))?
    }

    pub fn stats(&self) -> &BatcherStats {
        &self.stats
    }
}

/// Occupancy statistics, backed by the shared
/// [`crate::util::stats::OccupancyHist`] the serve-side tiers record too
/// (this used to be a private duplicate histogram).
#[derive(Default)]
pub struct BatcherStats {
    inner: Mutex<crate::util::stats::OccupancyHist>,
}

impl BatcherStats {
    /// Poison-tolerant lock: a panic on a scoring thread must not turn
    /// every later stats read into an `unwrap` panic cascade.
    fn locked(&self) -> std::sync::MutexGuard<'_, crate::util::stats::OccupancyHist> {
        crate::util::lock_ignore_poison(&self.inner)
    }

    fn record(&self, used: usize, capacity: usize) {
        self.locked().record(used, capacity);
    }

    pub fn batches(&self) -> u64 {
        self.locked().batches()
    }

    pub fn requests(&self) -> u64 {
        self.locked().requests()
    }

    /// Mean rows per executed batch.
    pub fn mean_occupancy(&self) -> f64 {
        self.locked().mean()
    }

    /// Snapshot of the underlying occupancy histogram (the live stats
    /// keep recording).
    pub fn snapshot(&self) -> crate::util::stats::OccupancyHist {
        self.locked().clone()
    }

    /// Fold another batcher's occupancy into this one
    /// ([`crate::util::stats::OccupancyHist::merge`]) — the aggregate
    /// view when a process runs several batchers (one per served
    /// model). Snapshots `other` first, so the two locks are never held
    /// together.
    pub fn merge_from(&self, other: &BatcherStats) {
        let o = other.snapshot();
        self.locked().merge(&o);
    }
}

/// The batcher service. Dropping it stops the worker thread.
pub struct DynamicBatcher {
    handle: BatcherHandle,
    stop: Arc<AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl DynamicBatcher {
    /// Start a batcher for `model`'s `eval_rows` artifact, using trained
    /// `params` (cloned into the service thread).
    pub fn start(
        runtime: RuntimeHandle,
        model: &str,
        params: Vec<HostTensor>,
        max_wait: Duration,
    ) -> Result<Self> {
        let spec = runtime
            .manifest()
            .model(model)
            .with_context(|| format!("unknown model {model}"))?
            .clone();
        let art = spec
            .eval_rows
            .clone()
            .with_context(|| format!("model {model} has no eval_rows artifact"))?;
        let batch = spec.config_usize("batch").context("missing batch")?;
        let seq = spec.config_usize("seq").context("missing seq")?;
        anyhow::ensure!(
            params.len() == spec.param_names.len(),
            "params arity {} != {}",
            params.len(),
            spec.param_names.len()
        );
        let (tx, rx) = mpsc::channel::<ScoreRequest>();
        let stats = Arc::new(BatcherStats::default());
        let stop = Arc::new(AtomicBool::new(false));
        let handle = BatcherHandle {
            tx,
            seq,
            stats: Arc::clone(&stats),
        };
        let stop2 = Arc::clone(&stop);
        let join = std::thread::Builder::new()
            .name("panther-batcher".into())
            .spawn(move || {
                batcher_loop(rx, runtime, art, params, batch, seq, max_wait, stats, stop2)
            })
            .context("spawning batcher thread")?;
        Ok(DynamicBatcher {
            handle,
            stop,
            join: Some(join),
        })
    }

    /// Start a batcher straight from a trained (possibly
    /// checkpoint-restored) [`crate::train::ModelState`]. States with real
    /// names (checkpoint v2) are matched to the manifest's `param_names`
    /// *by name* — they stay valid even if the manifest reorders
    /// parameters, and any unresolvable name is an error rather than a
    /// guess. Only genuinely positional states (no names, or the
    /// synthesized `param.{i}` names a legacy v1 restore carries) fall
    /// back to positional order, arity-checked by
    /// [`DynamicBatcher::start`].
    pub fn start_from_state(
        runtime: RuntimeHandle,
        state: &crate::train::ModelState,
        max_wait: Duration,
    ) -> Result<Self> {
        let spec = runtime
            .manifest()
            .model(&state.model)
            .with_context(|| format!("unknown model {}", state.model))?
            .clone();
        let positional = state.names.is_empty()
            || state
                .names
                .iter()
                .enumerate()
                .all(|(i, n)| n == &format!("param.{i}"));
        let params = if positional {
            state.params.clone()
        } else {
            spec.param_names
                .iter()
                .map(|n| {
                    state.param_named(n).cloned().ok_or_else(|| {
                        anyhow!(
                            "state for {} has no parameter named {n} — manifest and \
                             checkpoint disagree",
                            state.model
                        )
                    })
                })
                .collect::<Result<Vec<HostTensor>>>()?
        };
        let model = state.model.clone();
        Self::start(runtime, &model, params, max_wait)
    }

    pub fn handle(&self) -> BatcherHandle {
        self.handle.clone()
    }
}

impl Drop for DynamicBatcher {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn batcher_loop(
    rx: mpsc::Receiver<ScoreRequest>,
    runtime: RuntimeHandle,
    artifact: String,
    params: Vec<HostTensor>,
    batch: usize,
    seq: usize,
    max_wait: Duration,
    stats: Arc<BatcherStats>,
    stop: Arc<AtomicBool>,
) {
    let mut pending: Vec<ScoreRequest> = Vec::with_capacity(batch);
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        // Block for the first request of a batch; then drain greedily until
        // full or the wait budget elapses (classic size-or-timeout policy).
        match rx.recv_timeout(Duration::from_millis(50)) {
            Ok(req) => pending.push(req),
            Err(mpsc::RecvTimeoutError::Timeout) => continue,
            Err(mpsc::RecvTimeoutError::Disconnected) => return,
        }
        let deadline = Instant::now() + max_wait;
        while pending.len() < batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => pending.push(req),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // Assemble the padded batch.
        let used = pending.len();
        let mut tokens = vec![0f32; batch * seq];
        let mut labels = vec![0f32; batch * seq];
        let mut mask = vec![0f32; batch * seq];
        for (i, r) in pending.iter().enumerate() {
            tokens[i * seq..(i + 1) * seq].copy_from_slice(&r.tokens);
            labels[i * seq..(i + 1) * seq].copy_from_slice(&r.labels);
            mask[i * seq..(i + 1) * seq].copy_from_slice(&r.mask);
            // padding rows stay all-zero: mask 0 → loss 0, discarded.
        }
        let mut inputs = params.clone();
        inputs.push(HostTensor::new(&[batch, seq], tokens));
        inputs.push(HostTensor::new(&[batch, seq], labels));
        inputs.push(HostTensor::new(&[batch, seq], mask));
        let result = runtime.execute(&artifact, inputs);
        stats.record(used, batch);
        match result {
            Ok(out) => {
                let rows = &out[0];
                for (i, r) in pending.drain(..).enumerate() {
                    let _ = r.reply.send(Ok(rows.data()[i]));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in pending.drain(..) {
                    let _ = r.reply.send(Err(anyhow!("batch execution failed: {msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::RuntimeServer;
    use crate::train::ModelState;

    #[test]
    fn batcher_stats_merge_aggregates() {
        // Two batchers' stats folded into an aggregate view — the
        // multi-model process shape. No runtime needed: BatcherStats is
        // plain accounting.
        let a = BatcherStats::default();
        let b = BatcherStats::default();
        a.record(3, 4);
        a.record(4, 4);
        b.record(1, 8);
        let agg = BatcherStats::default();
        agg.merge_from(&a);
        agg.merge_from(&b);
        assert_eq!(agg.batches(), 3);
        assert_eq!(agg.requests(), 8);
        assert_eq!(agg.snapshot().buckets(), &[1, 0, 1, 1, 0, 0, 0, 0]);
        // The sources keep recording independently.
        a.record(2, 4);
        assert_eq!(agg.batches(), 3, "snapshot semantics: no live link");
    }

    fn setup() -> Option<(RuntimeServer, ModelState)> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts/ not built");
            return None;
        }
        // Check the serving artifact exists (older artifact dirs may lack it).
        let mut rt = crate::runtime::Runtime::open(&dir).ok()?;
        if rt
            .manifest()
            .model("bert_dense")
            .and_then(|m| m.eval_rows.clone())
            .is_none()
        {
            eprintln!("skipping: artifacts predate eval_rows (re-run `make artifacts`)");
            return None;
        }
        let state = ModelState::init(&mut rt, "bert_dense", 0.0).unwrap();
        drop(rt);
        let server = RuntimeServer::start(dir).unwrap();
        Some((server, state))
    }

    fn fake_request(seq: usize, seed: u64) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        use crate::rng::{Philox, Rng};
        let mut rng = Philox::seeded(seed);
        let tokens: Vec<f32> = (0..seq)
            .map(|_| (2 + rng.next_below(254)) as f32)
            .collect();
        let labels = tokens.clone();
        let mut mask = vec![0f32; seq];
        for m in mask.iter_mut().take(seq / 4) {
            *m = 1.0;
        }
        (tokens, labels, mask)
    }

    #[test]
    fn concurrent_requests_all_answered() {
        let Some((server, state)) = setup() else {
            return;
        };
        // Exercise the name-keyed path: start straight from the state.
        let batcher =
            DynamicBatcher::start_from_state(server.handle(), &state, Duration::from_millis(30))
                .unwrap();
        let seq = 64;
        let threads: Vec<_> = (0..10)
            .map(|i| {
                let h = batcher.handle();
                std::thread::spawn(move || {
                    let (t, l, m) = fake_request(seq, i);
                    h.score(&t, &l, &m).unwrap()
                })
            })
            .collect();
        let scores: Vec<f32> = threads.into_iter().map(|t| t.join().unwrap()).collect();
        assert_eq!(scores.len(), 10);
        assert!(scores.iter().all(|s| s.is_finite() && *s > 0.0));
        let stats = batcher.handle();
        assert_eq!(stats.stats().requests(), 10);
        // Coalescing actually happened: fewer batches than requests.
        assert!(stats.stats().batches() < 10, "no batching occurred");
    }

    #[test]
    fn results_independent_of_batch_composition() {
        let Some((server, state)) = setup() else {
            return;
        };
        let batcher = DynamicBatcher::start(
            server.handle(),
            "bert_dense",
            state.params,
            Duration::from_millis(5),
        )
        .unwrap();
        let (t, l, m) = fake_request(64, 42);
        // Alone.
        let solo = batcher.handle().score(&t, &l, &m).unwrap();
        // Amid other traffic.
        let h2 = batcher.handle();
        let noise: Vec<_> = (0..6)
            .map(|i| {
                let h = batcher.handle();
                std::thread::spawn(move || {
                    let (t, l, m) = fake_request(64, 100 + i);
                    h.score(&t, &l, &m).unwrap()
                })
            })
            .collect();
        let busy = h2.score(&t, &l, &m).unwrap();
        for n in noise {
            n.join().unwrap();
        }
        assert!(
            (solo - busy).abs() < 1e-5,
            "padding/composition changed a result: {solo} vs {busy}"
        );
    }

    #[test]
    fn wrong_length_rejected() {
        let Some((server, state)) = setup() else {
            return;
        };
        let batcher = DynamicBatcher::start(
            server.handle(),
            "bert_dense",
            state.params,
            Duration::from_millis(5),
        )
        .unwrap();
        let err = batcher.handle().score(&[0.0; 3], &[0.0; 3], &[0.0; 3]);
        assert!(err.is_err());
    }
}
