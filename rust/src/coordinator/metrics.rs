//! Coordinator metrics: per-artifact latency/throughput counters.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

/// Aggregate stats for one artifact.
#[derive(Debug, Clone, Default)]
pub struct ArtifactStats {
    pub count: u64,
    pub errors: u64,
    pub total: Duration,
    pub min: Option<Duration>,
    pub max: Duration,
}

impl ArtifactStats {
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            self.total / self.count as u32
        }
    }
}

/// Shared metrics sink (interior mutability; the runtime thread writes,
/// anyone reads).
#[derive(Default)]
pub struct CoordinatorMetrics {
    stats: Mutex<HashMap<String, ArtifactStats>>,
}

impl CoordinatorMetrics {
    /// Poison-tolerant lock: the counters stay structurally valid even if a
    /// recording thread panicked, so a poisoned mutex must not cascade into
    /// panics on every later read.
    fn locked(&self) -> std::sync::MutexGuard<'_, HashMap<String, ArtifactStats>> {
        crate::util::lock_ignore_poison(&self.stats)
    }

    pub fn record(&self, artifact: &str, latency: Duration, ok: bool) {
        let mut map = self.locked();
        let s = map.entry(artifact.to_string()).or_default();
        s.count += 1;
        if !ok {
            s.errors += 1;
        }
        s.total += latency;
        s.min = Some(s.min.map_or(latency, |m| m.min(latency)));
        s.max = s.max.max(latency);
    }

    pub fn artifact_stats(&self, artifact: &str) -> Option<ArtifactStats> {
        self.locked().get(artifact).cloned()
    }

    pub fn total_requests(&self) -> u64 {
        self.locked().values().map(|s| s.count).sum()
    }

    pub fn total_errors(&self) -> u64 {
        self.locked().values().map(|s| s.errors).sum()
    }

    /// Render a summary table (for `panther info` / example epilogues).
    pub fn report(&self) -> String {
        let map = self.locked();
        let mut names: Vec<&String> = map.keys().collect();
        names.sort();
        let mut t = crate::util::bench::Table::new(&["artifact", "count", "errors", "mean", "max"]);
        for n in names {
            let s = &map[n];
            t.row(&[
                n.clone(),
                s.count.to_string(),
                s.errors.to_string(),
                crate::util::human_duration(s.mean()),
                crate::util::human_duration(s.max),
            ]);
        }
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_aggregates() {
        let m = CoordinatorMetrics::default();
        m.record("a", Duration::from_millis(10), true);
        m.record("a", Duration::from_millis(20), true);
        m.record("a", Duration::from_millis(30), false);
        let s = m.artifact_stats("a").unwrap();
        assert_eq!(s.count, 3);
        assert_eq!(s.errors, 1);
        assert_eq!(s.mean(), Duration::from_millis(20));
        assert_eq!(s.min, Some(Duration::from_millis(10)));
        assert_eq!(s.max, Duration::from_millis(30));
        assert_eq!(m.total_requests(), 3);
        assert_eq!(m.total_errors(), 1);
    }

    #[test]
    fn report_renders() {
        let m = CoordinatorMetrics::default();
        m.record("x", Duration::from_micros(5), true);
        let r = m.report();
        assert!(r.contains("| x"));
    }
}
