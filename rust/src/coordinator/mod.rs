//! Coordinator: multi-threaded access to the single-threaded runtime.
//!
//! Execution backends may be `!Send` (the PJRT client wraps raw C
//! pointers), so one dedicated **runtime service thread** owns the
//! [`Runtime`]; everything else (tuner workers, examples, benches) talks to
//! it through a cloneable [`RuntimeHandle`] over an mpsc channel. This is the same
//! leader-owns-the-engine shape as a vLLM-style router: requests queue,
//! the service thread executes in arrival order, per-artifact latency and
//! queue-depth metrics are tracked, and backpressure falls out of the
//! bounded queue.

pub mod batcher;
pub mod metrics;

pub use batcher::{BatcherHandle, DynamicBatcher, ScoreRequest};
pub use metrics::{ArtifactStats, CoordinatorMetrics};

use crate::runtime::{HostTensor, Manifest, Runtime};
use anyhow::{anyhow, Context, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Instant;

/// Upper bound on queued requests before `submit` blocks the caller —
/// simple backpressure so a fast producer cannot grow the queue unboundedly.
const QUEUE_CAP: usize = 64;

enum Msg {
    Execute {
        artifact: String,
        inputs: Vec<HostTensor>,
        reply: mpsc::Sender<Result<Vec<HostTensor>>>,
    },
    Shutdown,
}

/// Cloneable handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::SyncSender<Msg>,
    metrics: Arc<CoordinatorMetrics>,
    manifest: Arc<Manifest>,
    depth: Arc<AtomicUsize>,
}

/// The runtime service: owns the thread; dropping it shuts the thread down.
pub struct RuntimeServer {
    handle: RuntimeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl RuntimeServer {
    /// Start the service over an artifact directory.
    pub fn start(artifact_dir: impl Into<std::path::PathBuf>) -> Result<Self> {
        let dir = artifact_dir.into();
        // Open once on the caller thread to fail fast + grab the manifest,
        // then hand the path to the service thread (Runtime itself is !Send).
        let probe = Runtime::open(&dir)?;
        let manifest = Arc::new(probe.manifest().clone());
        drop(probe);
        let (tx, rx) = mpsc::sync_channel::<Msg>(QUEUE_CAP);
        let metrics = Arc::new(CoordinatorMetrics::default());
        let depth = Arc::new(AtomicUsize::new(0));
        let m2 = Arc::clone(&metrics);
        let d2 = Arc::clone(&depth);
        let join = std::thread::Builder::new()
            .name("panther-runtime".into())
            .spawn(move || {
                let mut rt = match Runtime::open(&dir) {
                    Ok(rt) => rt,
                    Err(e) => {
                        crate::log_error!("runtime thread failed to open artifacts: {e}");
                        // Drain, replying with errors.
                        while let Ok(msg) = rx.recv() {
                            if let Msg::Execute { reply, .. } = msg {
                                let _ = reply.send(Err(anyhow!("runtime unavailable")));
                            } else {
                                break;
                            }
                        }
                        return;
                    }
                };
                while let Ok(msg) = rx.recv() {
                    match msg {
                        Msg::Execute {
                            artifact,
                            inputs,
                            reply,
                        } => {
                            d2.fetch_sub(1, Ordering::SeqCst);
                            let t0 = Instant::now();
                            let res = rt.execute(&artifact, &inputs);
                            m2.record(&artifact, t0.elapsed(), res.is_ok());
                            let _ = reply.send(res);
                        }
                        Msg::Shutdown => break,
                    }
                }
            })
            .context("spawning runtime thread")?;
        Ok(RuntimeServer {
            handle: RuntimeHandle {
                tx,
                metrics,
                manifest,
                depth,
            },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }

    pub fn metrics(&self) -> Arc<CoordinatorMetrics> {
        Arc::clone(&self.handle.metrics)
    }
}

impl Drop for RuntimeServer {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Msg::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl RuntimeHandle {
    /// Execute an artifact (synchronous RPC to the service thread).
    pub fn execute(&self, artifact: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.depth.fetch_add(1, Ordering::SeqCst);
        self.tx
            .send(Msg::Execute {
                artifact: artifact.to_string(),
                inputs,
                reply: reply_tx,
            })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread dropped reply"))?
    }

    /// Current queue depth (requests submitted but not yet started).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn metrics(&self) -> &CoordinatorMetrics {
        &self.metrics
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn serves_requests_from_many_threads() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let server = RuntimeServer::start(dir).unwrap();
        let spec = server
            .handle()
            .manifest()
            .artifact("k_sk_linear")
            .unwrap()
            .clone();
        let mk_inputs = || -> Vec<HostTensor> {
            spec.inputs
                .iter()
                .map(|s| HostTensor::zeros(&s.shape))
                .collect()
        };
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let h = server.handle();
                let inputs = mk_inputs();
                std::thread::spawn(move || h.execute("k_sk_linear", inputs).unwrap())
            })
            .collect();
        for t in handles {
            let out = t.join().unwrap();
            assert_eq!(out.len(), 1);
        }
        let stats = server.metrics().artifact_stats("k_sk_linear").unwrap();
        assert_eq!(stats.count, 4);
        assert_eq!(stats.errors, 0);
    }

    #[test]
    fn errors_propagate_to_caller() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts/ not built");
            return;
        };
        let server = RuntimeServer::start(dir).unwrap();
        let res = server.handle().execute("nope", vec![]);
        assert!(res.is_err());
        assert_eq!(server.metrics().total_errors(), 1);
    }
}
