//! # Panther-RS
//!
//! A production-oriented reproduction of **Panther: Faster and Cheaper
//! Computations with Randomized Numerical Linear Algebra** as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! Panther consolidates Randomized Numerical Linear Algebra (RandNLA)
//! techniques — sketched linear layers, sketched 2D convolution, Performer
//! style random-feature attention, and randomized matrix decompositions
//! (RSVD, CQRRPT) — behind a drop-in layer API, with an AutoTuner that
//! searches sketching hyper-parameters under accuracy constraints.
//!
//! ## Architecture
//!
//! - **Layer 1 (build-time Python)** — Pallas kernels for the compute
//!   hot-spots (`python/compile/kernels/`), verified against pure-jnp
//!   oracles.
//! - **Layer 2 (build-time Python)** — JAX model graphs (BERT-mini MLM,
//!   conv classifier; dense and sketched variants) lowered once to HLO text
//!   artifacts by `python/compile/aot.py`.
//! - **Layer 3 (this crate)** — everything at run time: the [`runtime`]
//!   (an `ExecBackend` seam with an offline reference executor by default
//!   and the PJRT client behind the non-default `pjrt` cargo feature), the
//!   [`tuner`] (the paper's `SKAutoTuner`), the [`coordinator`] that
//!   schedules tuning trials and evaluation batches, the [`train`] driver,
//!   the [`serve`] subsystem (generic dynamic batching + tiered
//!   dense/sketched routing over native models), and a pure-Rust RandNLA
//!   substrate ([`linalg`], [`sketch`], [`decomp`], [`nn`]) used by the
//!   benchmark harness and the host-side decomposition API.
//!
//! Python is never on the request path: the default build executes the
//! committed reference artifacts (`rust/artifacts/manifest.json`) with no
//! external dependencies, and after `make artifacts` a `--features pjrt`
//! build runs the real lowered HLO instead.
//!
//! ## Quickstart
//!
//! Every layer implements the [`nn::Module`] trait, and compression is a
//! [`nn::SketchPlan`] — the paper's Listing-1 "swap `nn.Linear` for
//! `pr.nn.SKLinear`" without changing any call-sites:
//!
//! ```
//! use panther::linalg::Mat;
//! use panther::nn::{ForwardCtx, LayerSelector, Linear, Model, Module, SketchPlan};
//! use panther::rng::Philox;
//!
//! # fn main() -> panther::Result<()> {
//! let mut rng = Philox::seeded(0);
//! let mut model = Model::new();
//! model.add("ffn.fc1", Linear::random(128, 128, &mut rng))?;
//! model.add("ffn.fc2", Linear::random(128, 128, &mut rng))?;
//! let dense_params = model.total_params();
//!
//! // Forward through the unified Module API (dense, for reference).
//! let x = Mat::randn(8, 128, &mut rng);
//! let ctx = ForwardCtx::new();
//! let y_dense = model.get("ffn.fc1").unwrap().forward(&x, &ctx)?;
//!
//! // Compress both FFN linears with rank-16 sketches of their weights.
//! let report = SketchPlan::new()
//!     .select(LayerSelector::by_regex(r"ffn\.fc\d")?)
//!     .with(/*num_terms=*/ 1, /*low_rank=*/ 16)
//!     .seed(0)
//!     .apply(&mut model)?;
//! assert_eq!(report.converted.len(), 2);
//! assert!(model.total_params() < dense_params);
//!
//! // Same call-site, same shapes.
//! let y_sk = model.get("ffn.fc1").unwrap().forward(&x, &ctx)?;
//! assert_eq!(y_dense.shape(), y_sk.shape());
//! # Ok(()) }
//! ```

// Dense numeric kernels index heavily by design; the iterator rewrites
// clippy suggests for these loops obscure the math.
#![allow(clippy::needless_range_loop)]

pub mod coordinator;
pub mod data;
pub mod decomp;
pub mod linalg;
pub mod nn;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod sketch;
pub mod train;
pub mod tuner;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Library version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
