//! # Panther-RS
//!
//! A production-oriented reproduction of **Panther: Faster and Cheaper
//! Computations with Randomized Numerical Linear Algebra** as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! Panther consolidates Randomized Numerical Linear Algebra (RandNLA)
//! techniques — sketched linear layers, sketched 2D convolution, Performer
//! style random-feature attention, and randomized matrix decompositions
//! (RSVD, CQRRPT) — behind a drop-in layer API, with an AutoTuner that
//! searches sketching hyper-parameters under accuracy constraints.
//!
//! ## Architecture
//!
//! - **Layer 1 (build-time Python)** — Pallas kernels for the compute
//!   hot-spots (`python/compile/kernels/`), verified against pure-jnp
//!   oracles.
//! - **Layer 2 (build-time Python)** — JAX model graphs (BERT-mini MLM,
//!   conv classifier; dense and sketched variants) lowered once to HLO text
//!   artifacts by `python/compile/aot.py`.
//! - **Layer 3 (this crate)** — everything at run time: the [`runtime`]
//!   (an `ExecBackend` seam with an offline reference executor by default
//!   and the PJRT client behind the non-default `pjrt` cargo feature), the
//!   [`tuner`] (the paper's `SKAutoTuner`), the [`coordinator`] that
//!   schedules tuning trials and evaluation batches, the [`train`] driver,
//!   and a pure-Rust RandNLA substrate ([`linalg`], [`sketch`], [`decomp`],
//!   [`nn`]) used by the benchmark harness and the host-side decomposition
//!   API.
//!
//! Python is never on the request path: the default build executes the
//! committed reference artifacts (`rust/artifacts/manifest.json`) with no
//! external dependencies, and after `make artifacts` a `--features pjrt`
//! build runs the real lowered HLO instead.
//!
//! ## Quickstart
//!
//! ```
//! use panther::nn::{Linear, SKLinear};
//! use panther::linalg::Mat;
//! use panther::rng::Philox;
//!
//! let mut rng = Philox::seeded(0);
//! // A dense layer and its sketched drop-in replacement.
//! let dense = Linear::random(128, 128, &mut rng);
//! let sk = SKLinear::from_dense(&dense, /*num_terms=*/ 1, /*low_rank=*/ 16, &mut rng);
//! assert!(sk.param_count() < dense.param_count());
//!
//! // Same call-site, same shapes.
//! let x = Mat::randn(8, 128, &mut rng);
//! let y_dense = dense.forward(&x);
//! let y_sk = sk.forward(&x);
//! assert_eq!(y_dense.shape(), y_sk.shape());
//! ```

// Dense numeric kernels index heavily by design; the iterator rewrites
// clippy suggests for these loops obscure the math.
#![allow(clippy::needless_range_loop)]

pub mod coordinator;
pub mod data;
pub mod decomp;
pub mod linalg;
pub mod nn;
pub mod rng;
pub mod runtime;
pub mod sketch;
pub mod train;
pub mod tuner;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Library version string (mirrors `Cargo.toml`).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
