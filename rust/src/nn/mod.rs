//! Neural-network layer library: dense layers and their RandNLA drop-in
//! replacements, mirroring Panther's `panther.nn` (`SKLinear`, `SKConv2d`,
//! `RandMultiHeadAttention`).
//!
//! Two execution paths exist for each layer:
//! - the **CPU reference forward** implemented here on [`crate::linalg`],
//!   used by the figure benches (dense and sketched run on the *same*
//!   substrate, so relative speedups are meaningful), and
//! - the **AOT path**: the same math compiled from the Pallas/JAX layers
//!   into HLO artifacts and executed through [`crate::runtime`].
//!
//! [`cost`] holds the analytic parameter/FLOP/memory models, including the
//! paper's benchmark-skip rule `2·l·k·(d_in+d_out) > d_in·d_out`.

pub mod attention;
pub mod conv;
pub mod cost;
pub mod linear;
pub mod model;

pub use attention::{KernelKind, MultiHeadAttention, RandMultiHeadAttention};
pub use conv::{Conv2d, ConvShape, SKConv2d};
pub use cost::{conv_cost, linear_cost, sketch_beats_dense, LayerCost};
pub use linear::{Linear, SKLinear};
pub use model::{LayerKind, LayerSelector, Model, NamedLayer};
