//! Neural-network layer library: dense layers and their RandNLA drop-in
//! replacements, mirroring Panther's `panther.nn` (`SKLinear`, `SKConv2d`,
//! `RandMultiHeadAttention`), plus parameter-free [`Activation`] layers
//! (ReLU/GELU) so stacks are not linear-only between sketched ops.
//!
//! All layer types implement the unified [`Module`] trait —
//! `forward(x, ctx)` with a shared [`ForwardCtx`] (memory accounting +
//! scratch + batch metadata), a differentiable `forward_train`/`backward`
//! pair with named gradient accumulation (trained by
//! [`crate::train::Trainer`], locked down by the finite-difference suite
//! in `tests/gradcheck.rs`), named parameter views, and a
//! `state_dict`/`load_state_dict` named-tensor API. Model compression is a
//! [`SketchPlan`]: select layers (type / regex / names), pick
//! `(num_terms, low_rank)`, apply, and read the per-layer
//! [`CompressionReport`].
//!
//! Two execution paths exist for each layer:
//! - the **CPU reference forward** implemented here on [`crate::linalg`],
//!   used by the figure benches (dense and sketched run on the *same*
//!   substrate, so relative speedups are meaningful), and
//! - the **AOT path**: the same math compiled from the Pallas/JAX layers
//!   into HLO artifacts and executed through [`crate::runtime`].
//!
//! Batches of variable-length sequences are first-class: a [`SeqBatch`]
//! installed on the [`ForwardCtx`] tells sequence-aware layers (the
//! attention variants) how the input rows decompose into sequences, and
//! pad positions are masked *structurally* — excluded from every softmax
//! row and FAVOR+ normalizer by exact-length views, never by −∞ biasing.
//! Row-wise layers ignore the batch (the default
//! [`Module::is_sequence_aware`] adapter), so mixed stacks work unchanged.
//!
//! [`cost`] holds the analytic parameter/FLOP/memory models, including the
//! paper's benchmark-skip rule `2·l·k·(d_in+d_out) > d_in·d_out`; they are
//! cross-checked against the [`Module::param_count`] registry in tests
//! rather than serving as the source of truth.

pub mod activation;
pub mod attention;
pub mod conv;
pub mod cost;
pub mod linear;
pub mod model;
pub mod module;
pub mod plan;

pub use activation::{ActKind, Activation};
pub use attention::{
    AttnWeights, KernelKind, MultiHeadAttention, RandMultiHeadAttention, ATTN_BWD_TILE,
};
pub use conv::{Conv2d, ConvShape, SKConv2d};
pub use cost::{conv_cost, linear_cost, sketch_beats_dense, LayerCost};
pub use linear::{Linear, SKLinear};
pub use model::{LayerSelector, Model, NamedModule, ReplaceShapeMismatch};
pub use module::{
    Cache, ForwardCtx, GradStore, Module, ParamMut, ParamRef, SeqBatch, StateDict, Workspace,
    WsMat,
};
pub use plan::{CompressionReport, LayerReport, SketchPlan, Sketchable, SkippedLayer};
