//! Analytic cost models for dense vs sketched layers.
//!
//! The paper computes layer-size reduction analytically for linear and
//! convolution layers ("the reduction in layer size can be computed
//! analytically [7]") and skips benchmark configurations where
//! `2·l·k·(d_in + d_out) > d_in·d_out` — configurations that cannot yield a
//! theoretical speedup. This module is the single source of truth for those
//! formulas; benches, the tuner's search-space pruning, and the examples all
//! call into it.

/// Cost summary for one layer configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerCost {
    /// Stored parameters.
    pub params: usize,
    /// Multiply-accumulate FLOPs for one forward pass at batch size `b`
    /// (2 FLOPs per MAC).
    pub flops: u64,
    /// Bytes of parameter storage (f32).
    pub param_bytes: u64,
}

/// Dense/sketched cost of a linear layer at batch `b`. `low_rank = None`
/// gives the dense layer.
pub fn linear_cost(d_in: usize, d_out: usize, b: usize, sketch: Option<(usize, usize)>) -> LayerCost {
    match sketch {
        None => {
            let params = d_in * d_out + d_out;
            LayerCost {
                params,
                flops: 2 * (b * d_in * d_out) as u64,
                param_bytes: params as u64 * 4,
            }
        }
        Some((l, k)) => {
            let params = l * k * (d_in + d_out) + d_out;
            LayerCost {
                params,
                flops: 2 * (b * l * k * (d_in + d_out)) as u64,
                param_bytes: params as u64 * 4,
            }
        }
    }
}

/// Dense/sketched cost of a conv layer (square kernel, stride 1).
/// `spatial_out` is `H_out·W_out`; the im2col GEMM has
/// `d_in = c_in·kernel²`, `d_out = c_out`, batch `b·spatial_out`.
pub fn conv_cost(
    c_in: usize,
    c_out: usize,
    kernel: usize,
    spatial_out: usize,
    b: usize,
    sketch: Option<(usize, usize)>,
) -> LayerCost {
    linear_cost(c_in * kernel * kernel, c_out, b * spatial_out, sketch)
}

/// The paper's Figure-1/2 skip rule: a sketched configuration can only beat
/// dense when `2·l·k·(d_in+d_out) ≤ d_in·d_out`. (The factor 2 counts both
/// sketch factors of the two-sided construction in [7].)
pub fn sketch_beats_dense(d_in: usize, d_out: usize, l: usize, k: usize) -> bool {
    2 * l * k * (d_in + d_out) <= d_in * d_out
}

/// Speedup predicted by the FLOP model (dense / sketched); > 1 means the
/// sketch wins. The Figure-1 curve shape comes straight from this ratio.
pub fn predicted_speedup(d_in: usize, d_out: usize, l: usize, k: usize) -> f64 {
    (d_in * d_out) as f64 / (l * k * (d_in + d_out)) as f64
}

/// Peak forward activation memory (bytes) for exact softmax attention:
/// the h×n×n score tensor plus four n×d projections.
pub fn dense_attention_mem(n: usize, d: usize, h: usize) -> u64 {
    ((h * n * n + 4 * n * d) * 4) as u64
}

/// Peak forward activation memory for Performer linear attention with `m`
/// random features: per head two n×m feature blocks, the m×d_h state and
/// the length-m normalizer — all h heads alive at once for the batched
/// per-head products — plus four n×d projections. Still linear in n.
pub fn performer_attention_mem(n: usize, d: usize, h: usize, m: usize) -> u64 {
    let dh = d / h;
    ((h * (2 * n * m + m * dh + m) + 4 * n * d) * 4) as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_linear_cost() {
        let c = linear_cost(100, 50, 8, None);
        assert_eq!(c.params, 5050);
        assert_eq!(c.flops, 2 * 8 * 100 * 50);
    }

    #[test]
    fn sketched_linear_cost() {
        let c = linear_cost(100, 50, 8, Some((2, 4)));
        assert_eq!(c.params, 2 * 4 * 150 + 50);
        assert_eq!(c.flops, 2 * 8 * 2 * 4 * 150);
    }

    #[test]
    fn skip_rule_matches_paper_examples() {
        // d=8192, l=1, k=16: 2·16·16384 = 524288 ≪ 8192² — keep.
        assert!(sketch_beats_dense(8192, 8192, 1, 16));
        // d=256, l=3, k=512: 2·3·512·512 = 1.5M > 65536 — skip.
        assert!(!sketch_beats_dense(256, 256, 3, 512));
    }

    #[test]
    fn speedup_monotone_in_k() {
        let s16 = predicted_speedup(8192, 8192, 1, 16);
        let s256 = predicted_speedup(8192, 8192, 1, 256);
        assert!(s16 > s256);
        assert!(s16 > 100.0); // 8192²/(16·16384) = 256
    }

    #[test]
    fn conv_cost_equals_linear_on_patches() {
        let c = conv_cost(64, 128, 3, 32 * 32, 2, None);
        let l = linear_cost(64 * 9, 128, 2 * 32 * 32, None);
        assert_eq!(c, l);
    }

    #[test]
    fn attention_memory_crossover() {
        // At long n dense must exceed performer; at tiny n it may not.
        let (n, d, h, m) = (4096, 512, 8, 128);
        assert!(dense_attention_mem(n, d, h) > performer_attention_mem(n, d, h, m));
    }
}
