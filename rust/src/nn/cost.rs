//! Analytic cost models for dense vs sketched layers.
//!
//! The paper computes layer-size reduction analytically for linear and
//! convolution layers ("the reduction in layer size can be computed
//! analytically [7]") and skips benchmark configurations where
//! `2·l·k·(d_in + d_out) > d_in·d_out` — configurations that cannot yield a
//! theoretical speedup. This module is the single source of truth for those
//! formulas; benches, the tuner's search-space pruning, and the examples all
//! call into it.

/// Cost summary for one layer configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerCost {
    /// Stored parameters.
    pub params: usize,
    /// Multiply-accumulate FLOPs for one forward pass at batch size `b`
    /// (2 FLOPs per MAC).
    pub flops: u64,
    /// Bytes of parameter storage (f32).
    pub param_bytes: u64,
}

/// Dense/sketched cost of a linear layer at batch `b`. `low_rank = None`
/// gives the dense layer.
pub fn linear_cost(d_in: usize, d_out: usize, b: usize, sketch: Option<(usize, usize)>) -> LayerCost {
    match sketch {
        None => {
            let params = d_in * d_out + d_out;
            LayerCost {
                params,
                flops: 2 * (b * d_in * d_out) as u64,
                param_bytes: params as u64 * 4,
            }
        }
        Some((l, k)) => {
            let params = l * k * (d_in + d_out) + d_out;
            LayerCost {
                params,
                flops: 2 * (b * l * k * (d_in + d_out)) as u64,
                param_bytes: params as u64 * 4,
            }
        }
    }
}

/// Dense/sketched cost of a conv layer (square kernel, stride 1).
/// `spatial_out` is `H_out·W_out`; the im2col GEMM has
/// `d_in = c_in·kernel²`, `d_out = c_out`, batch `b·spatial_out`.
pub fn conv_cost(
    c_in: usize,
    c_out: usize,
    kernel: usize,
    spatial_out: usize,
    b: usize,
    sketch: Option<(usize, usize)>,
) -> LayerCost {
    linear_cost(c_in * kernel * kernel, c_out, b * spatial_out, sketch)
}

/// The paper's Figure-1/2 skip rule: a sketched configuration can only beat
/// dense when `2·l·k·(d_in+d_out) ≤ d_in·d_out`. (The factor 2 counts both
/// sketch factors of the two-sided construction in [7].)
pub fn sketch_beats_dense(d_in: usize, d_out: usize, l: usize, k: usize) -> bool {
    2 * l * k * (d_in + d_out) <= d_in * d_out
}

/// Speedup predicted by the FLOP model (dense / sketched); > 1 means the
/// sketch wins. The Figure-1 curve shape comes straight from this ratio.
pub fn predicted_speedup(d_in: usize, d_out: usize, l: usize, k: usize) -> f64 {
    (d_in * d_out) as f64 / (l * k * (d_in + d_out)) as f64
}

/// Peak forward activation memory (bytes) for exact softmax attention:
/// the h×n×n score tensor plus four n×d projections.
pub fn dense_attention_mem(n: usize, d: usize, h: usize) -> u64 {
    ((h * n * n + 4 * n * d) * 4) as u64
}

/// Peak forward activation memory for Performer linear attention with `m`
/// random features: per head two n×m feature blocks, the m×d_h state and
/// the length-m normalizer — all h heads alive at once for the batched
/// per-head products — plus four n×d projections. Still linear in n.
pub fn performer_attention_mem(n: usize, d: usize, h: usize, m: usize) -> u64 {
    let dh = d / h;
    ((h * (2 * n * m + m * dh + m) + 4 * n * d) * 4) as u64
}

/// Peak *backward* activation memory (bytes) for the tiled recomputing
/// dense-attention backward with key-tile width `tile`: four n×d gradient
/// blocks, one probability + one score-gradient tile (h·n×T each) and the
/// T×d dK/dV staging blocks. Linear in n for fixed T — the h·n² term of a
/// materializing backward is gone.
pub fn dense_attention_bwd_mem(n: usize, d: usize, h: usize, tile: usize) -> u64 {
    ((4 * n * d + 2 * h * n * tile + 2 * tile * d) * 4) as u64
}

/// Largest sequence length that fits a serving-tier memory budget, from
/// two peak-memory probe measurements. Fits the quadratic model
/// `peak(n) ≈ α·n² + β·n` through `(n0, peak0)` and `(2·n0, peak1)`
/// (α = (peak1 − 2·peak0)/(2·n0²), β from the first point, both clamped
/// at zero) and returns the largest `n` with
/// `fixed_bytes + peak(n) ≤ budget`, or 0 when even n = 1 does not fit.
/// Dense attention measures α > 0 and gets a √budget-ish cap; Performer
/// measures α ≈ 0 and its cap scales linearly in the remaining budget —
/// the admission asymmetry the serve layer advertises per tier.
pub fn max_len_under_budget(n0: usize, peak0: u64, peak1: u64, fixed_bytes: u64, budget: u64) -> usize {
    assert!(n0 > 0, "probe length must be positive");
    if budget == 0 {
        // Unlimited budget: no admission cap from memory.
        return usize::MAX;
    }
    let n0f = n0 as f64;
    let alpha = ((peak1 as f64 - 2.0 * peak0 as f64) / (2.0 * n0f * n0f)).max(0.0);
    let beta = ((peak0 as f64 - alpha * n0f * n0f) / n0f).max(0.0);
    let fits = |n: usize| -> bool {
        let nf = n as f64;
        fixed_bytes as f64 + alpha * nf * nf + beta * nf <= budget as f64
    };
    if !fits(1) {
        return 0;
    }
    // Exponential search for an upper bound, then binary search. Capped
    // at 2^32 rows — beyond any real admission limit.
    let mut hi = 1usize;
    while hi < (1usize << 32) && fits(hi) {
        hi <<= 1;
    }
    if fits(hi) {
        return hi;
    }
    let mut lo = hi >> 1; // fits
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if fits(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_linear_cost() {
        let c = linear_cost(100, 50, 8, None);
        assert_eq!(c.params, 5050);
        assert_eq!(c.flops, 2 * 8 * 100 * 50);
    }

    #[test]
    fn sketched_linear_cost() {
        let c = linear_cost(100, 50, 8, Some((2, 4)));
        assert_eq!(c.params, 2 * 4 * 150 + 50);
        assert_eq!(c.flops, 2 * 8 * 2 * 4 * 150);
    }

    #[test]
    fn skip_rule_matches_paper_examples() {
        // d=8192, l=1, k=16: 2·16·16384 = 524288 ≪ 8192² — keep.
        assert!(sketch_beats_dense(8192, 8192, 1, 16));
        // d=256, l=3, k=512: 2·3·512·512 = 1.5M > 65536 — skip.
        assert!(!sketch_beats_dense(256, 256, 3, 512));
    }

    #[test]
    fn speedup_monotone_in_k() {
        let s16 = predicted_speedup(8192, 8192, 1, 16);
        let s256 = predicted_speedup(8192, 8192, 1, 256);
        assert!(s16 > s256);
        assert!(s16 > 100.0); // 8192²/(16·16384) = 256
    }

    #[test]
    fn conv_cost_equals_linear_on_patches() {
        let c = conv_cost(64, 128, 3, 32 * 32, 2, None);
        let l = linear_cost(64 * 9, 128, 2 * 32 * 32, None);
        assert_eq!(c, l);
    }

    #[test]
    fn attention_memory_crossover() {
        // At long n dense must exceed performer; at tiny n it may not.
        let (n, d, h, m) = (4096, 512, 8, 128);
        assert!(dense_attention_mem(n, d, h) > performer_attention_mem(n, d, h, m));
    }

    #[test]
    fn tiled_backward_memory_linear_in_n() {
        let (d, h, t) = (512, 8, 64);
        let m1 = dense_attention_bwd_mem(1024, d, h, t);
        let m2 = dense_attention_bwd_mem(2048, d, h, t);
        // Doubling n roughly doubles the tiled backward's peak...
        assert!((m2 as f64 / m1 as f64) < 2.1);
        // ...and it stays far below the materializing h·n² footprint.
        assert!(m2 < dense_attention_mem(2048, d, h));
    }

    #[test]
    fn max_len_fit_recovers_quadratic_and_linear() {
        // Synthetic quadratic profile peak(n) = 8n² + 100n.
        let peak = |n: u64| 8 * n * n + 100 * n;
        let cap = max_len_under_budget(32, peak(32), peak(64), 0, peak(500));
        assert!((495..=505).contains(&cap), "quadratic cap {cap}");
        // Linear profile peak(n) = 1000n: cap scales with budget.
        let cap_lin = max_len_under_budget(32, 32_000, 64_000, 0, 1_000_000);
        assert!((995..=1000).contains(&cap_lin), "linear cap {cap_lin}");
        // Fixed bytes eat into the budget.
        let with_fixed = max_len_under_budget(32, 32_000, 64_000, 500_000, 1_000_000);
        assert!(with_fixed < cap_lin);
        // Unlimited budget → no cap; impossible budget → 0.
        assert_eq!(max_len_under_budget(32, 1, 2, 0, 0), usize::MAX);
        assert_eq!(max_len_under_budget(32, 32_000, 64_000, 0, 500), 0);
    }
}
