//! The unified layer interface: [`Module`].
//!
//! Panther's headline claim is *drop-in replacement* — `SKLinear` slots in
//! wherever `Linear` was. Before this trait existed the six layer types had
//! three different `forward` signatures (attention demanded a `MemTracker`,
//! linear and conv took none) and parameter counts were hand-maintained
//! closed-form expressions. [`Module`] unifies all of it:
//!
//! - `forward(&self, x, ctx)` with a shared [`ForwardCtx`] carrying the
//!   memory tracker, a reusable scratch buffer, and batch metadata;
//! - `forward_train(&self, x, ctx)` / `backward(&mut self, g, cache, ctx)`
//!   — the differentiable path: an opt-in activation [`Cache`] (inference
//!   forwards build none) and per-parameter gradient accumulation into a
//!   [`GradStore`], exposed as named `grads()` views mirroring `params()`;
//! - `params()` / `params_mut()` exposing *named* parameter views, from
//!   which `param_count`, [`Module::state_dict`] and
//!   [`Module::load_state_dict`] are derived — one source of truth;
//! - `type_name()` for selector matching (the paper's
//!   `LayerConfig(layer_names={"type": "Linear"})`).
//!
//! Sketching lives in the companion [`super::plan`] module: dense layers
//! advertise a sketched replacement via [`Module::as_sketchable`], and
//! [`super::plan::SketchPlan`] is the single compression path.

use crate::linalg::Mat;
use crate::runtime::HostTensor;
use crate::util::memtrack::MemTracker;
use anyhow::{anyhow, bail, ensure, Result};
use std::any::Any;
use std::cell::{RefCell, RefMut};

use super::plan::Sketchable;

/// Opaque per-call activation cache returned by [`Module::forward_train`]
/// and consumed by [`Module::backward`]. Each layer stores whatever it
/// needs to differentiate (inputs, intermediate products, softmax rows)
/// behind a type-erased box, so the trait stays object-safe and inference
/// forwards — which never build a cache — pay nothing.
pub struct Cache(Box<dyn Any + Send>);

impl Cache {
    /// Wrap a layer-private cache value.
    pub fn new<T: Any + Send>(value: T) -> Self {
        Cache(Box::new(value))
    }

    /// Borrow the cache as the layer's concrete type. Errors (instead of
    /// panicking) when a cache from a different layer is passed in — the
    /// typical bug is zipping layer and cache lists misaligned.
    pub fn downcast<T: Any>(&self) -> Result<&T> {
        self.0
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow!("backward got a cache built by a different layer type"))
    }
}

/// Accumulated per-parameter gradients, name-keyed exactly like
/// [`Module::params`]. Buffers are allocated lazily on the first
/// [`GradStore::accum`] for a name, so layers that never train allocate
/// nothing; repeated backwards accumulate (micro-batching), and
/// [`GradStore::zero`] resets between optimizer steps without freeing.
#[derive(Clone, Debug, Default)]
pub struct GradStore {
    bufs: Vec<(String, Vec<f32>)>,
}

impl GradStore {
    /// `self[name] += alpha · delta`, allocating a zeroed buffer of
    /// `delta.len()` on first use. Panics on a length mismatch with an
    /// earlier accumulation under the same name (that is a layer bug, not
    /// a user input).
    pub fn accum(&mut self, name: &str, alpha: f32, delta: &[f32]) {
        let idx = match self.bufs.iter().position(|(n, _)| n == name) {
            Some(i) => i,
            None => {
                self.bufs.push((name.to_string(), vec![0.0; delta.len()]));
                self.bufs.len() - 1
            }
        };
        let buf = &mut self.bufs[idx].1;
        assert_eq!(buf.len(), delta.len(), "gradient size changed for {name}");
        for (b, &d) in buf.iter_mut().zip(delta) {
            *b += alpha * d;
        }
    }

    /// The accumulated gradient for `name`, if any backward has touched it.
    pub fn get(&self, name: &str) -> Option<&[f32]> {
        self.bufs
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, b)| b.as_slice())
    }

    /// Named flat views of every accumulated gradient, in first-touch
    /// order (layers accumulate in their `params()` order, so the orders
    /// coincide after one backward).
    pub fn views(&self) -> Vec<(String, &[f32])> {
        self.bufs
            .iter()
            .map(|(n, b)| (n.clone(), b.as_slice()))
            .collect()
    }

    /// Reset every buffer to zero, keeping the allocations.
    pub fn zero(&mut self) {
        for (_, b) in &mut self.bufs {
            b.fill(0.0);
        }
    }

    /// Multiply every accumulated gradient by `s` — the layer-level half
    /// of global-norm gradient clipping (see
    /// [`crate::train::clip_grad_norm`]).
    pub fn scale(&mut self, s: f32) {
        for (_, b) in &mut self.bufs {
            for v in b.iter_mut() {
                *v *= s;
            }
        }
    }

    /// True when no gradient has ever been accumulated.
    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

/// A reusable arena of f32 scratch matrices for the forward/backward hot
/// paths: per-head score blocks, feature maps, projection-space gradient
/// accumulators — everything that used to be a fresh `Mat` per call.
/// Buffers are [`Workspace::take`]n with unspecified contents (the GEMM
/// `beta = 0` store path never reads its output, so recycled storage is
/// safe) and return to the arena when their [`WsMat`] guard drops:
/// steady-state *inference* forwards and the transient scratch of
/// *backward* allocate nothing. Training forwards are the exception —
/// they [`WsMat::detach`] their buffers into the activation cache, whose
/// teardown frees them (caches drop far from any workspace handle), so a
/// training step still allocates its cache.
///
/// The arena is a perf cache, not an accounting object: layers keep
/// charging their *logical* activation footprint against the
/// [`MemTracker`] exactly as before, so the Figure-3 memory numbers are
/// unchanged by buffer reuse.
#[derive(Default)]
pub struct Workspace {
    pool: RefCell<Vec<Mat>>,
}

impl Workspace {
    pub fn new() -> Self {
        Workspace::default()
    }

    /// Borrow a `rows × cols` matrix from the arena. **Contents are
    /// unspecified** — the borrower must overwrite every element it reads
    /// (GEMM's `beta = 0` path and `copy_from_slice` both qualify).
    pub fn take(&self, rows: usize, cols: usize) -> WsMat<'_> {
        let mut m = self.pool.borrow_mut().pop().unwrap_or_else(|| Mat::zeros(0, 0));
        m.resize(rows, cols);
        WsMat {
            ws: self,
            mat: Some(m),
        }
    }

    /// Like [`Workspace::take`], but zero-filled (for accumulation loops
    /// that read before the first full overwrite).
    pub fn take_zeroed(&self, rows: usize, cols: usize) -> WsMat<'_> {
        let mut m = self.take(rows, cols);
        m.data_mut().fill(0.0);
        m
    }

    /// Return a matrix to the arena (the non-guard path — used when a
    /// buffer was [`WsMat::detach`]ed or built elsewhere).
    pub fn give(&self, m: Mat) {
        self.pool.borrow_mut().push(m);
    }

    /// Buffers currently resident in the arena (diagnostics/tests).
    pub fn pooled(&self) -> usize {
        self.pool.borrow().len()
    }
}

/// Guard over a [`Workspace`]-owned matrix: derefs to [`Mat`], returns the
/// buffer to the arena on drop. [`WsMat::detach`] converts to a plain
/// owned `Mat` (for activation caches that outlive the call).
pub struct WsMat<'ws> {
    ws: &'ws Workspace,
    mat: Option<Mat>,
}

impl WsMat<'_> {
    /// Keep the buffer: it leaves the arena for good (or until a later
    /// [`Workspace::give`]).
    pub fn detach(mut self) -> Mat {
        self.mat.take().expect("WsMat holds a Mat until dropped")
    }
}

impl std::ops::Deref for WsMat<'_> {
    type Target = Mat;
    fn deref(&self) -> &Mat {
        self.mat.as_ref().expect("WsMat holds a Mat until dropped")
    }
}

impl std::ops::DerefMut for WsMat<'_> {
    fn deref_mut(&mut self) -> &mut Mat {
        self.mat.as_mut().expect("WsMat holds a Mat until dropped")
    }
}

impl Drop for WsMat<'_> {
    fn drop(&mut self) {
        if let Some(m) = self.mat.take() {
            self.ws.pool.borrow_mut().push(m);
        }
    }
}

/// Shared two-stage sketched forward: `y = (1/l)·Σ_j (x·U_j)·V_j + bias`.
/// The B×k intermediate is recycled across terms in `xu` (a workspace or
/// fresh buffer — resized here, contents overwritten by the `beta = 0`
/// first stage); the second stage accumulates into `y`, which must start
/// zeroed. One body for `SKLinear` and `SKConv2d`'s inference paths, so
/// the sketch math cannot drift between layers (the training forwards
/// differ structurally — they cache every per-term intermediate).
pub(crate) fn sketched_product_into(
    x: &Mat,
    u: &[Mat],
    v: &[Mat],
    bias: &[f32],
    xu: &mut Mat,
    y: &mut Mat,
) {
    let inv_l = 1.0 / u.len() as f32;
    for (uj, vj) in u.iter().zip(v) {
        xu.resize(x.rows(), uj.cols());
        crate::linalg::gemm(1.0, x, uj, 0.0, xu);
        crate::linalg::gemm(inv_l, xu, vj, 1.0, y);
    }
    for i in 0..y.rows() {
        for (vv, b) in y.row_mut(i).iter_mut().zip(bias) {
            *vv += b;
        }
    }
}

/// Resolve a head-group knob against a layer's head count: `0` means
/// "all at once", anything else clamps to `[1, heads]` — the one
/// definition both attention variants share (see
/// [`Module::set_head_group`]).
pub(crate) fn effective_head_group(knob: usize, heads: usize) -> usize {
    if knob == 0 {
        heads
    } else {
        knob.clamp(1, heads)
    }
}

/// Per-column sums of `g` — the bias gradient shared by every layer whose
/// forward broadcasts a bias over output rows.
pub(crate) fn col_sums(g: &Mat) -> Vec<f32> {
    let mut out = vec![0f64; g.cols()];
    for i in 0..g.rows() {
        for (o, &v) in out.iter_mut().zip(g.row(i)) {
            *o += v as f64;
        }
    }
    out.into_iter().map(|v| v as f32).collect()
}

/// A batch of variable-length sequences sharing one row-stacked matrix.
///
/// The nn layer's unit of batching used to be the *row*; attention made
/// that a lie (its rows couple), so sequences are now first-class: a
/// `SeqBatch` describes how the rows of a forward input decompose into
/// sequences, in one of two layouts:
///
/// - **packed** ([`SeqBatch::packed`]): sequences are concatenated
///   back-to-back — row block `i` starts where block `i−1` ended and
///   there are no padding rows. This is the serving layout (no wasted
///   rows).
/// - **padded** ([`SeqBatch::padded`]): every sequence owns a fixed
///   `stride` of rows, of which the first `len` are valid and the rest
///   are padding. This is the training layout (rectangular `B × L` MLM
///   batches flattened row-major); [`SeqBatch::token_mask`] derives the
///   per-row validity mask the masked losses consume.
///
/// Sequence-aware layers ([`Module::is_sequence_aware`]) read the batch
/// from [`ForwardCtx::seq_batch`] and restrict their cross-row math to
/// each sequence's valid rows — pad positions are excluded *structurally*
/// (they never enter a softmax row or a FAVOR+ kv/z sum), which is exact
/// masking: a pad key's attention probability is identically zero. All
/// other layers are row-wise and ignore the batch — the default adapter
/// is the identity, so `Linear`/`SKLinear`/conv/activation semantics are
/// untouched. A full-length batch is bitwise-identical to running with no
/// `SeqBatch` at all (the per-sequence views then span every row).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SeqBatch {
    lens: Vec<usize>,
    /// `Some(stride)` for the padded layout, `None` for packed.
    stride: Option<usize>,
}

impl SeqBatch {
    /// Packed layout: sequences of the given lengths concatenated with no
    /// padding rows. Errors on an empty batch or a zero-length sequence.
    pub fn packed(lens: Vec<usize>) -> Result<Self> {
        ensure!(!lens.is_empty(), "SeqBatch needs at least one sequence");
        ensure!(
            lens.iter().all(|&l| l > 0),
            "SeqBatch sequence lengths must be positive"
        );
        Ok(SeqBatch { lens, stride: None })
    }

    /// Padded layout: each sequence owns `stride` rows, the first `len`
    /// valid. Errors on an empty batch, a zero length, or `len > stride`.
    pub fn padded(lens: Vec<usize>, stride: usize) -> Result<Self> {
        ensure!(!lens.is_empty(), "SeqBatch needs at least one sequence");
        ensure!(
            lens.iter().all(|&l| l > 0 && l <= stride),
            "SeqBatch lengths must be in 1..=stride ({stride})"
        );
        Ok(SeqBatch {
            lens,
            stride: Some(stride),
        })
    }

    /// One full-length sequence of `n` rows — the shape every pre-sequence
    /// caller implicitly meant.
    pub fn single(n: usize) -> Self {
        SeqBatch {
            lens: vec![n],
            stride: None,
        }
    }

    /// Number of sequences in the batch.
    pub fn num_seqs(&self) -> usize {
        self.lens.len()
    }

    /// Per-sequence valid lengths.
    pub fn lens(&self) -> &[usize] {
        &self.lens
    }

    /// Longest valid length in the batch.
    pub fn max_len(&self) -> usize {
        self.lens.iter().copied().max().unwrap_or(0)
    }

    /// Valid (non-pad) rows over all sequences.
    pub fn total_tokens(&self) -> usize {
        self.lens.iter().sum()
    }

    /// Rows of the matrix this batch describes (padding included).
    pub fn total_rows(&self) -> usize {
        match self.stride {
            Some(s) => s * self.lens.len(),
            None => self.total_tokens(),
        }
    }

    /// True when no row is padding (every sequence fills its slot).
    pub fn is_full(&self) -> bool {
        match self.stride {
            Some(s) => self.lens.iter().all(|&l| l == s),
            None => true,
        }
    }

    /// `(row_offset, valid_len)` of every sequence, in order.
    pub fn segments(&self) -> Vec<(usize, usize)> {
        match self.stride {
            Some(s) => self
                .lens
                .iter()
                .enumerate()
                .map(|(i, &l)| (i * s, l))
                .collect(),
            None => {
                let mut off = 0;
                self.lens
                    .iter()
                    .map(|&l| {
                        let o = off;
                        off += l;
                        (o, l)
                    })
                    .collect()
            }
        }
    }

    /// Per-row validity mask over [`SeqBatch::total_rows`] rows: `1.0` for
    /// valid positions, `0.0` for padding — the mask shape
    /// [`crate::train::masked_xent_loss`] consumes.
    pub fn token_mask(&self) -> Vec<f32> {
        let mut mask = vec![0.0; self.total_rows()];
        for (off, len) in self.segments() {
            for m in &mut mask[off..off + len] {
                *m = 1.0;
            }
        }
        mask
    }
}

/// Name-keyed tensor state of a module or model. Keys are the names from
/// [`Module::params`], dot-prefixed with the layer path at the model level
/// (`encoder.fc1.weight`). This is also the in-memory shape of a checkpoint
/// v2 payload, so `nn` layers and the runtime's
/// [`crate::train::ModelState`] exchange weights through the same
/// representation.
pub type StateDict = Vec<(String, HostTensor)>;

/// Shared per-call context for [`Module::forward`].
///
/// Bundles the three things a layer forward may need beyond its input:
///
/// - a [`MemTracker`] — every sizable temporary is accounted against it, so
///   budgeted trackers turn would-be OOMs into clean errors (the Figure-3
///   "x" markers);
/// - a reusable scratch matrix — convolution borrows it for the im2col
///   patch buffer, so repeated forwards don't re-allocate the largest
///   temporary. Its retained capacity stays charged against the tracker
///   for the context's lifetime (the buffer really is resident);
/// - an advisory batch hint — metadata for schedulers and batching layers;
///   forwards still size themselves from the actual input.
pub struct ForwardCtx {
    mem: MemTracker,
    scratch: RefCell<Mat>,
    /// Accounting for the scratch buffer's high-water capacity:
    /// `(guard, accounted_bytes)`.
    scratch_guard: RefCell<Option<(crate::util::memtrack::MemGuard, u64)>>,
    /// Reusable scratch arena for the attention/conv hot paths (see
    /// [`Workspace`]).
    ws: Workspace,
    batch_hint: Option<usize>,
    /// Sequence decomposition of the current forward's rows, if any (see
    /// [`SeqBatch`]). Interior-mutable so long-lived warm contexts (serve
    /// workers) can swap it per step without rebuilding the workspace.
    seq: RefCell<Option<SeqBatch>>,
    /// Stage profiler attached for the current forward, if any (see
    /// [`crate::util::events::StageProfiler`]). Interior-mutable like
    /// `seq` so warm serve contexts can attach/detach per step; when
    /// `None` (the default) the only cost is one never-taken branch per
    /// profiled stage.
    profiler: RefCell<Option<std::sync::Arc<crate::util::events::StageProfiler>>>,
}

impl ForwardCtx {
    /// Context with unlimited memory accounting.
    pub fn new() -> Self {
        Self::with_tracker(MemTracker::unlimited())
    }

    /// Context whose allocations fail past `bytes` live bytes.
    pub fn with_budget(bytes: u64) -> Self {
        Self::with_tracker(MemTracker::with_budget(bytes))
    }

    /// Context around an existing tracker (the tracker handle is shared, so
    /// the caller keeps visibility into `peak_bytes`/`live_bytes`).
    pub fn with_tracker(mem: MemTracker) -> Self {
        ForwardCtx {
            mem,
            scratch: RefCell::new(Mat::zeros(0, 0)),
            scratch_guard: RefCell::new(None),
            ws: Workspace::new(),
            batch_hint: None,
            seq: RefCell::new(None),
            profiler: RefCell::new(None),
        }
    }

    /// The reusable scratch arena shared by every forward/backward through
    /// this context. Buffer reuse is invisible to the memory accounting —
    /// layers still charge logical activation sizes against
    /// [`ForwardCtx::mem`].
    pub fn workspace(&self) -> &Workspace {
        &self.ws
    }

    /// Attach an advisory expected-batch-rows hint.
    pub fn batch_hint(mut self, rows: usize) -> Self {
        self.batch_hint = Some(rows);
        self
    }

    /// The advisory batch hint, if any.
    pub fn expected_batch(&self) -> Option<usize> {
        self.batch_hint
    }

    /// Attach a sequence decomposition at construction time (builder form
    /// of [`ForwardCtx::set_seq_batch`]).
    pub fn with_seq(self, sb: SeqBatch) -> Self {
        *self.seq.borrow_mut() = Some(sb);
        self
    }

    /// Install (or clear, with `None`) the sequence decomposition that
    /// sequence-aware layers read during the next forward/backward.
    /// Interior-mutable so a warm per-worker context can be re-pointed at a
    /// new batch each serving step.
    pub fn set_seq_batch(&self, sb: Option<SeqBatch>) {
        *self.seq.borrow_mut() = sb;
    }

    /// The current sequence decomposition, if one is installed.
    pub fn seq_batch(&self) -> Option<SeqBatch> {
        self.seq.borrow().clone()
    }

    /// Attach (or detach, with `None`) a stage profiler. While attached,
    /// [`crate::nn::Model::forward`] attributes wall time per layer and
    /// the GEMM kernels attribute pack/kernel phases to it. Interior-
    /// mutable so a warm per-worker context can toggle profiling between
    /// steps without rebuilding its workspace.
    pub fn set_profiler(&self, p: Option<std::sync::Arc<crate::util::events::StageProfiler>>) {
        *self.profiler.borrow_mut() = p;
    }

    /// The attached stage profiler, if any.
    pub fn profiler(&self) -> Option<std::sync::Arc<crate::util::events::StageProfiler>> {
        self.profiler.borrow().clone()
    }

    /// The `(row_offset, valid_len)` segments a sequence-aware layer should
    /// restrict its cross-row math to, for an input of `rows` rows. With no
    /// [`SeqBatch`] installed this is the single full-length segment
    /// `[(0, rows)]` — the pre-sequence semantics. Panics if an installed
    /// batch does not describe exactly `rows` rows (a shape bug, like any
    /// other dimension mismatch).
    pub fn segments_for(&self, rows: usize) -> Vec<(usize, usize)> {
        match self.seq.borrow().as_ref() {
            None => vec![(0, rows)],
            Some(sb) => {
                assert_eq!(
                    sb.total_rows(),
                    rows,
                    "SeqBatch describes {} rows but the forward input has {}",
                    sb.total_rows(),
                    rows
                );
                sb.segments()
            }
        }
    }

    /// The memory tracker all forwards account against.
    pub fn mem(&self) -> &MemTracker {
        &self.mem
    }

    /// Borrow the shared scratch matrix resized to `rows × cols`. Contents
    /// are unspecified; the borrower must overwrite every element it reads.
    /// Growth is charged against the tracker and the high-water capacity
    /// stays charged for the context's lifetime — a budget error here is
    /// the same clean OOM signal as any other tracked allocation. Panics if
    /// the scratch is already borrowed (layers must not hold it across
    /// nested forwards).
    pub fn scratch_mat(
        &self,
        rows: usize,
        cols: usize,
    ) -> Result<RefMut<'_, Mat>, crate::util::memtrack::MemError> {
        let needed = (rows * cols * std::mem::size_of::<f32>()) as u64;
        {
            let mut slot = self.scratch_guard.borrow_mut();
            let accounted = match slot.as_ref() {
                Some((_, bytes)) => *bytes,
                None => 0,
            };
            if needed > accounted {
                // Realloc: release the old charge, then charge the full new
                // capacity. If the budget refuses, drop the buffer too so
                // accounting and residency stay in agreement.
                *slot = None;
                match self.mem.alloc(needed) {
                    Ok(guard) => *slot = Some((guard, needed)),
                    Err(e) => {
                        *self.scratch.borrow_mut() = Mat::zeros(0, 0);
                        return Err(e);
                    }
                }
            }
        }
        let mut s = self.scratch.borrow_mut();
        s.resize(rows, cols);
        Ok(s)
    }
}

impl Default for ForwardCtx {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable view of one named parameter tensor.
pub enum ParamRef<'a> {
    /// A matrix parameter (weights, sketch factors).
    Mat(&'a Mat),
    /// A vector parameter (biases).
    Vec(&'a [f32]),
}

impl ParamRef<'_> {
    /// Number of scalar elements.
    pub fn len(&self) -> usize {
        match self {
            ParamRef::Mat(m) => m.len(),
            ParamRef::Vec(v) => v.len(),
        }
    }

    /// True when the parameter has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Logical shape (`[rows, cols]` for matrices, `[len]` for vectors).
    pub fn shape(&self) -> Vec<usize> {
        match self {
            ParamRef::Mat(m) => vec![m.rows(), m.cols()],
            ParamRef::Vec(v) => vec![v.len()],
        }
    }

    /// Flat element slice.
    pub fn data(&self) -> &[f32] {
        match self {
            ParamRef::Mat(m) => m.data(),
            ParamRef::Vec(v) => v,
        }
    }

    /// Copy into an owned shaped tensor.
    pub fn to_tensor(&self) -> HostTensor {
        HostTensor::new(&self.shape(), self.data().to_vec())
    }
}

/// Mutable view of one named parameter tensor. Shape is fixed; only the
/// element values may change.
pub enum ParamMut<'a> {
    /// A matrix parameter.
    Mat(&'a mut Mat),
    /// A vector parameter.
    Vec(&'a mut [f32]),
}

impl ParamMut<'_> {
    /// Number of scalar elements.
    pub fn len(&self) -> usize {
        match self {
            ParamMut::Mat(m) => m.len(),
            ParamMut::Vec(v) => v.len(),
        }
    }

    /// True when the parameter has no elements.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Logical shape (`[rows, cols]` for matrices, `[len]` for vectors).
    pub fn shape(&self) -> Vec<usize> {
        match self {
            ParamMut::Mat(m) => vec![m.rows(), m.cols()],
            ParamMut::Vec(v) => vec![v.len()],
        }
    }

    /// Flat mutable element slice.
    pub fn data_mut(&mut self) -> &mut [f32] {
        match self {
            ParamMut::Mat(m) => m.data_mut(),
            ParamMut::Vec(v) => &mut **v,
        }
    }
}

/// Named views over per-term sketch factors (`u.{j}`, `v.{j}`) plus
/// `bias` — the shared parameter registry of `SKLinear` and `SKConv2d`,
/// kept in one place so the state-dict key scheme cannot diverge.
pub(crate) fn factored_params<'a>(
    u: &'a [Mat],
    v: &'a [Mat],
    bias: &'a [f32],
) -> Vec<(String, ParamRef<'a>)> {
    let mut out = Vec::with_capacity(2 * u.len() + 1);
    for (j, (uj, vj)) in u.iter().zip(v).enumerate() {
        out.push((format!("u.{j}"), ParamRef::Mat(uj)));
        out.push((format!("v.{j}"), ParamRef::Mat(vj)));
    }
    out.push(("bias".to_string(), ParamRef::Vec(bias)));
    out
}

/// Mutable counterpart of [`factored_params`], same names and order.
pub(crate) fn factored_params_mut<'a>(
    u: &'a mut [Mat],
    v: &'a mut [Mat],
    bias: &'a mut [f32],
) -> Vec<(String, ParamMut<'a>)> {
    let mut out = Vec::with_capacity(2 * u.len() + 1);
    for (j, (uj, vj)) in u.iter_mut().zip(v.iter_mut()).enumerate() {
        out.push((format!("u.{j}"), ParamMut::Mat(uj)));
        out.push((format!("v.{j}"), ParamMut::Mat(vj)));
    }
    out.push(("bias".to_string(), ParamMut::Vec(bias)));
    out
}

/// The unified layer interface implemented by all seven layer types
/// (`Linear`, `SKLinear`, `Conv2d`, `SKConv2d`, `MultiHeadAttention`,
/// `RandMultiHeadAttention`, `Activation`).
///
/// `Send + Sync` because a layer's shared state is plain data — all
/// mutability during `forward` lives in the caller-owned [`ForwardCtx`] —
/// so one model instance can serve concurrent inference workers behind an
/// `Arc` (the [`crate::serve`] subsystem relies on this).
pub trait Module: Send + Sync {
    /// Type name as selectors see it (matches the paper's `"Linear"`,
    /// `"Conv2d"`, …).
    fn type_name(&self) -> &'static str;

    /// Forward pass. Input rows are batch items (or patch rows for conv);
    /// temporaries are accounted against `ctx.mem()`, so a budgeted context
    /// yields an error instead of an OOM.
    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> Result<Mat>;

    /// Training-mode forward: same output as [`Module::forward`] plus an
    /// opaque activation [`Cache`] for the matching [`Module::backward`].
    /// The cache is opt-in — plain `forward` never builds one, so
    /// inference paths pay nothing for differentiability.
    fn forward_train(&self, _x: &Mat, _ctx: &ForwardCtx) -> Result<(Mat, Cache)> {
        bail!("{} does not implement a training forward", self.type_name())
    }

    /// Backward pass: given `∂loss/∂output` and the [`Cache`] from the
    /// *same* `forward_train` call, accumulate `∂loss/∂param` into the
    /// layer's gradient store (visible through [`Module::grads`]) and
    /// return `∂loss/∂input`. Accumulates — callers zero via
    /// [`Module::zero_grads`] between optimizer steps.
    fn backward(&mut self, _grad_out: &Mat, _cache: &Cache, _ctx: &ForwardCtx) -> Result<Mat> {
        bail!("{} does not implement backward", self.type_name())
    }

    /// Named flat views of the accumulated parameter gradients, mirroring
    /// the [`Module::params`] registry names. Empty until the first
    /// [`Module::backward`] (gradient buffers are lazy).
    fn grads(&self) -> Vec<(String, &[f32])> {
        Vec::new()
    }

    /// Zero every accumulated gradient (keeping the buffers).
    fn zero_grads(&mut self) {}

    /// Multiply every accumulated gradient by `s` — the hook global-norm
    /// gradient clipping ([`crate::train::clip_grad_norm`]) applies
    /// between backward and the optimizer step. Layers backed by a
    /// [`GradStore`] forward to [`GradStore::scale`]. The default is only
    /// valid for modules whose [`Module::grads`] is empty — a module that
    /// accumulates gradients but inherits it would be silently skipped by
    /// clipping, so the default panics in that case instead.
    fn scale_grads(&mut self, _s: f32) {
        assert!(
            self.grads().is_empty(),
            "{} accumulates gradients but does not implement scale_grads — \
             global-norm clipping would silently skip it",
            self.type_name()
        );
    }

    /// Named views of every trained parameter, in a stable order. Fixed
    /// (untrained) state — e.g. the Performer's random features — is *not*
    /// a parameter and does not appear here.
    fn params(&self) -> Vec<(String, ParamRef<'_>)>;

    /// Mutable counterpart of [`Module::params`], same names and order.
    ///
    /// Contract: a caller that writes through these views must call
    /// [`Module::on_params_loaded`] afterwards, so layers with state
    /// derived from their parameters can refresh it (none of the six
    /// built-in layers currently cache derived state — the packed GEMM
    /// kernel made `SKLinear`'s factor-transpose caches obsolete — but
    /// the contract keeps third-party layers correct).
    /// [`Module::load_state_dict`] does this automatically and is the
    /// preferred bulk-update path.
    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)>;

    /// Deep copy behind the trait (object-safe `Clone`).
    fn boxed_clone(&self) -> Box<dyn Module>;

    /// The sketched-replacement hook: dense layers return `Some(self)`,
    /// already-sketched layers return `None`. [`super::plan::SketchPlan`]
    /// is the only caller.
    fn as_sketchable(&self) -> Option<&dyn Sketchable> {
        None
    }

    /// Refresh state derived from the parameters. Idempotent; called
    /// automatically by [`Module::load_state_dict`], and required after
    /// any direct write through [`Module::params_mut`].
    fn on_params_loaded(&mut self) {}

    /// Peak-memory knob for layers with per-head (or otherwise
    /// partitionable) transient state: process at most `heads` partitions'
    /// scratch at once on the inference path, trading a little batching
    /// win for a bounded footprint. `0` restores the default (everything
    /// at once). Results must be unaffected — only peak memory changes.
    /// Layers without such state ignore it (the default); the serving
    /// tier config forwards it model-wide so a worker's per-request peak
    /// fits the tier's memory budget.
    fn set_head_group(&mut self, _heads: usize) {}

    /// Fixed per-row interface widths as `(input width, output width)`,
    /// when the layer has them — `Linear`/`SKLinear` report
    /// `(d_in, d_out)`, the attention variants `(embed_dim, embed_dim)`,
    /// conv layers `(c_in·image², c_out)` (per *output* row; conv expands
    /// row counts, not widths). Shape-agnostic layers (activations) and
    /// third-party modules default to `None`, which opts them out of the
    /// [`super::Model::replace`] shape check — a swap is only rejected
    /// when **both** sides report widths and they differ.
    fn io_dims(&self) -> Option<(usize, usize)> {
        None
    }

    /// True for layers whose math couples rows within a sequence and which
    /// therefore consult [`ForwardCtx::seq_batch`] (the attention
    /// variants). The default — `false` — is the row-wise adapter: a layer
    /// that treats every row independently is already correct under any
    /// sequence decomposition and can ignore the batch entirely, which is
    /// why `Linear`/`SKLinear`/conv/activation needed no changes for the
    /// sequence-native path.
    fn is_sequence_aware(&self) -> bool {
        false
    }

    /// Stored trained-parameter count, derived from the [`Module::params`]
    /// registry — never a hand-maintained formula.
    fn param_count(&self) -> usize {
        self.params().iter().map(|(_, p)| p.len()).sum()
    }

    /// Snapshot all parameters as named owned tensors.
    fn state_dict(&self) -> StateDict {
        self.params()
            .into_iter()
            .map(|(name, p)| {
                let t = p.to_tensor();
                (name, t)
            })
            .collect()
    }

    /// Check that `sd` is a complete, exactly-shaped snapshot for this
    /// module: every parameter present, every shape matching, no duplicate
    /// or unknown keys. Read-only — used by [`Module::load_state_dict`]
    /// (and by model-level loads) to validate *before* the first write, so
    /// a failed load never leaves weights half-applied.
    fn validate_state_dict(&self, sd: &[(String, HostTensor)]) -> Result<()> {
        let mut by_name = std::collections::HashMap::with_capacity(sd.len());
        for (k, t) in sd {
            if by_name.insert(k.as_str(), t).is_some() {
                bail!("duplicate state dict key {k}");
            }
        }
        for (name, p) in self.params() {
            let t = by_name
                .remove(name.as_str())
                .ok_or_else(|| anyhow!("state dict missing parameter {name}"))?;
            let want = p.shape();
            ensure!(
                t.shape() == want.as_slice(),
                "shape mismatch for {name}: state dict {:?} vs layer {:?}",
                t.shape(),
                want
            );
        }
        if !by_name.is_empty() {
            let mut extra: Vec<&str> = by_name.keys().copied().collect();
            extra.sort_unstable();
            bail!("state dict has unknown keys {extra:?}");
        }
        Ok(())
    }

    /// Load a full parameter snapshot. Every parameter must be present with
    /// a matching shape, and no unknown keys are tolerated (a loud failure
    /// beats silently half-loaded weights). All-or-nothing at the module
    /// level: validation runs before the first write.
    fn load_state_dict(&mut self, sd: &[(String, HostTensor)]) -> Result<()> {
        self.validate_state_dict(sd)?;
        let by_name: std::collections::HashMap<&str, &HostTensor> =
            sd.iter().map(|(k, t)| (k.as_str(), t)).collect();
        for (name, mut p) in self.params_mut() {
            let t = by_name[name.as_str()];
            p.data_mut().copy_from_slice(t.data());
        }
        self.on_params_loaded();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::Linear;
    use crate::rng::Philox;

    #[test]
    fn forward_ctx_defaults_and_hints() {
        let ctx = ForwardCtx::new().batch_hint(32);
        assert_eq!(ctx.expected_batch(), Some(32));
        assert_eq!(ctx.mem().live_bytes(), 0);
        {
            let s = ctx.scratch_mat(3, 5).unwrap();
            assert_eq!(s.shape(), (3, 5));
        }
        // The scratch's high-water capacity stays charged...
        assert_eq!(ctx.mem().live_bytes(), 3 * 5 * 4);
        // ...and a smaller re-borrow reuses it without re-charging.
        {
            let s = ctx.scratch_mat(2, 2).unwrap();
            assert_eq!(s.shape(), (2, 2));
        }
        assert_eq!(ctx.mem().live_bytes(), 3 * 5 * 4);
    }

    #[test]
    fn scratch_growth_respects_budget() {
        let ctx = ForwardCtx::with_budget(100);
        assert!(ctx.scratch_mat(5, 5).is_ok()); // 100 B exactly
        let err = ctx.scratch_mat(6, 5); // 120 B > budget
        assert!(err.is_err());
        // The failed grow released the old charge and buffer together.
        assert_eq!(ctx.mem().live_bytes(), 0);
        // A fitting request works again afterwards.
        assert!(ctx.scratch_mat(4, 5).is_ok());
        assert_eq!(ctx.mem().live_bytes(), 80);
    }

    #[test]
    fn load_state_dict_rejects_bad_inputs() {
        let mut rng = Philox::seeded(51);
        let mut l = Linear::random(4, 3, &mut rng);
        // Missing key.
        assert!(l.load_state_dict(&[]).is_err());
        // Unknown key.
        let mut sd = l.state_dict();
        sd.push(("ghost".to_string(), HostTensor::scalar(1.0)));
        assert!(l.load_state_dict(&sd).is_err());
        // Shape mismatch.
        let mut sd = l.state_dict();
        sd[0].1 = HostTensor::zeros(&[3, 3]);
        assert!(l.load_state_dict(&sd).is_err());
        // Pristine dict loads.
        let sd = l.state_dict();
        assert!(l.load_state_dict(&sd).is_ok());
    }

    #[test]
    fn grad_store_accumulates_and_zeroes() {
        let mut gs = GradStore::default();
        assert!(gs.is_empty());
        assert!(gs.get("w").is_none());
        gs.accum("w", 1.0, &[1.0, 2.0]);
        gs.accum("w", 0.5, &[2.0, 2.0]);
        gs.accum("b", 2.0, &[3.0]);
        assert_eq!(gs.get("w"), Some(&[2.0, 3.0][..]));
        assert_eq!(gs.get("b"), Some(&[6.0][..]));
        let names: Vec<String> = gs.views().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["w", "b"]);
        gs.zero();
        // Buffers survive zeroing (accumulation restarts from 0).
        assert_eq!(gs.get("w"), Some(&[0.0, 0.0][..]));
        assert!(!gs.is_empty());
    }

    #[test]
    fn grad_store_scale() {
        let mut gs = GradStore::default();
        gs.accum("w", 1.0, &[2.0, -4.0]);
        gs.scale(0.5);
        assert_eq!(gs.get("w"), Some(&[1.0, -2.0][..]));
    }

    #[test]
    fn workspace_recycles_buffers_and_detach_keeps_them() {
        let ws = Workspace::new();
        {
            let a = ws.take(3, 4);
            assert_eq!(a.shape(), (3, 4));
            let b = ws.take_zeroed(2, 2);
            assert!(b.data().iter().all(|&v| v == 0.0));
        } // both return to the arena
        assert_eq!(ws.pooled(), 2);
        {
            let c = ws.take(5, 5); // reuses a pooled buffer
            assert_eq!(ws.pooled(), 1);
            let owned = c.detach(); // leaves the arena for good
            assert_eq!(owned.shape(), (5, 5));
        }
        assert_eq!(ws.pooled(), 1);
        ws.give(Mat::zeros(1, 1));
        assert_eq!(ws.pooled(), 2);
        // The context exposes one shared arena.
        let ctx = ForwardCtx::new();
        {
            let _s = ctx.workspace().take(4, 4);
        }
        assert_eq!(ctx.workspace().pooled(), 1);
    }

    #[test]
    fn cache_downcast_rejects_wrong_type() {
        struct A(#[allow(dead_code)] u32);
        struct B;
        let c = Cache::new(A(7));
        assert!(c.downcast::<A>().is_ok());
        assert!(c.downcast::<B>().is_err());
    }

    #[test]
    fn default_module_impls_report_not_differentiable() {
        // A minimal Module that only implements the required methods: the
        // training API defaults must fail loudly, not silently.
        struct Opaque;
        impl Module for Opaque {
            fn type_name(&self) -> &'static str {
                "Opaque"
            }
            fn forward(&self, x: &Mat, _ctx: &ForwardCtx) -> Result<Mat> {
                Ok(x.clone())
            }
            fn params(&self) -> Vec<(String, ParamRef<'_>)> {
                vec![]
            }
            fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
                vec![]
            }
            fn boxed_clone(&self) -> Box<dyn Module> {
                Box::new(Opaque)
            }
        }
        let mut m = Opaque;
        let ctx = ForwardCtx::new();
        let x = Mat::zeros(1, 1);
        assert!(m.forward_train(&x, &ctx).is_err());
        let cache = Cache::new(());
        assert!(m.backward(&x, &cache, &ctx).is_err());
        assert!(m.grads().is_empty());
        m.zero_grads(); // no-op, must not panic
        m.scale_grads(0.5); // grads empty → the default is a valid no-op
    }

    #[test]
    fn param_count_is_derived_from_registry() {
        let mut rng = Philox::seeded(52);
        let l = Linear::random(7, 5, &mut rng);
        let from_views: usize = l.params().iter().map(|(_, p)| p.len()).sum();
        assert_eq!(Module::param_count(&l), from_views);
        assert_eq!(from_views, 7 * 5 + 5);
    }

    #[test]
    fn seq_batch_packed_layout() {
        let sb = SeqBatch::packed(vec![3, 1, 4]).unwrap();
        assert_eq!(sb.num_seqs(), 3);
        assert_eq!(sb.lens(), &[3, 1, 4]);
        assert_eq!(sb.max_len(), 4);
        assert_eq!(sb.total_tokens(), 8);
        assert_eq!(sb.total_rows(), 8, "packed: no pad rows");
        assert!(sb.is_full());
        assert_eq!(sb.segments(), vec![(0, 3), (3, 1), (4, 4)]);
        assert_eq!(sb.token_mask(), vec![1.0; 8]);
        // Degenerate batches are rejected.
        assert!(SeqBatch::packed(vec![]).is_err());
        assert!(SeqBatch::packed(vec![2, 0]).is_err());
    }

    #[test]
    fn seq_batch_padded_layout() {
        let sb = SeqBatch::padded(vec![3, 2], 4).unwrap();
        assert_eq!(sb.total_tokens(), 5);
        assert_eq!(sb.total_rows(), 8, "2 sequences x stride 4");
        assert!(!sb.is_full());
        assert_eq!(sb.segments(), vec![(0, 3), (4, 2)]);
        assert_eq!(
            sb.token_mask(),
            vec![1.0, 1.0, 1.0, 0.0, 1.0, 1.0, 0.0, 0.0]
        );
        // Full-stride lengths make a padded batch equivalent to packed.
        let full = SeqBatch::padded(vec![4, 4], 4).unwrap();
        assert!(full.is_full());
        assert_eq!(full.segments(), SeqBatch::packed(vec![4, 4]).unwrap().segments());
        // Lengths beyond the stride are rejected.
        assert!(SeqBatch::padded(vec![5], 4).is_err());
        assert!(SeqBatch::padded(vec![0], 4).is_err());
    }

    #[test]
    fn forward_ctx_threads_seq_batch_to_segments() {
        let ctx = ForwardCtx::new();
        // No batch installed: one full-length segment, any row count.
        assert_eq!(ctx.segments_for(6), vec![(0, 6)]);
        assert!(ctx.seq_batch().is_none());
        let sb = SeqBatch::packed(vec![2, 3]).unwrap();
        let ctx = ctx.with_seq(sb.clone());
        assert_eq!(ctx.segments_for(5), vec![(0, 2), (2, 3)]);
        assert_eq!(ctx.seq_batch().unwrap().lens(), sb.lens());
        // Interior mutability: warm contexts swap batches between steps.
        ctx.set_seq_batch(Some(SeqBatch::single(7)));
        assert_eq!(ctx.segments_for(7), vec![(0, 7)]);
        ctx.set_seq_batch(None);
        assert!(ctx.seq_batch().is_none());
        assert_eq!(ctx.segments_for(9), vec![(0, 9)]);
    }

    #[test]
    #[should_panic(expected = "SeqBatch describes")]
    fn segments_for_panics_on_row_mismatch() {
        let ctx = ForwardCtx::new().with_seq(SeqBatch::packed(vec![2, 3]).unwrap());
        ctx.segments_for(6);
    }
}
