//! Elementwise activation layers: ReLU and GELU behind the [`Module`]
//! trait.
//!
//! The layer registry was linear-only between sketched ops — every
//! expressible stack was affine end-to-end, so "fine-tune a compressed
//! model" could only ever relearn a linear map. A parameter-free
//! activation [`Module`] closes that (ROADMAP item): served and trained
//! stacks get nonlinearities through the same registry, selectors, and
//! checkpoint machinery as every other layer (an activation simply
//! contributes no state-dict entries).
//!
//! Both activations are row-wise (in fact element-wise), so stacks using
//! them stay row-independent — exactly what the [`crate::serve`] batcher's
//! registration probe requires.

use super::module::{Cache, ForwardCtx, Module, ParamMut, ParamRef};
use crate::linalg::Mat;
use crate::util::memtrack::MemGuard;

/// Which nonlinearity an [`Activation`] applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActKind {
    /// `max(x, 0)`.
    Relu,
    /// GELU, tanh approximation (Hendrycks & Gimpel 2016):
    /// `0.5·x·(1 + tanh(√(2/π)·(x + 0.044715·x³)))`.
    Gelu,
}

/// `√(2/π)` — the GELU tanh-approximation constant.
#[allow(clippy::excessive_precision)]
const GELU_C: f32 = 0.797_884_560_8;
/// The cubic coefficient of the GELU tanh approximation.
const GELU_A: f32 = 0.044_715;

/// A parameter-free elementwise activation layer.
#[derive(Debug, Clone)]
pub struct Activation {
    pub kind: ActKind,
}

/// Activation cache of [`Activation::forward_train`]: the input (the
/// derivative is evaluated at x), charged against the tracker for the
/// cache's lifetime like every other layer's retained activations.
struct ActCache {
    x: Mat,
    _guard: MemGuard,
}

impl Activation {
    pub fn relu() -> Self {
        Activation {
            kind: ActKind::Relu,
        }
    }

    pub fn gelu() -> Self {
        Activation {
            kind: ActKind::Gelu,
        }
    }

    #[inline]
    fn apply(&self, v: f32) -> f32 {
        match self.kind {
            ActKind::Relu => v.max(0.0),
            ActKind::Gelu => {
                let u = GELU_C * (v + GELU_A * v * v * v);
                0.5 * v * (1.0 + u.tanh())
            }
        }
    }

    /// `d act(v) / dv`.
    #[inline]
    fn derivative(&self, v: f32) -> f32 {
        match self.kind {
            ActKind::Relu => {
                if v > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            ActKind::Gelu => {
                // f = 0.5·v·(1 + tanh u), u = c·(v + a·v³):
                // f' = 0.5·(1 + tanh u) + 0.5·v·(1 − tanh²u)·c·(1 + 3a·v²).
                let u = GELU_C * (v + GELU_A * v * v * v);
                let t = u.tanh();
                0.5 * (1.0 + t) + 0.5 * v * (1.0 - t * t) * GELU_C * (1.0 + 3.0 * GELU_A * v * v)
            }
        }
    }

    fn forward_mat(&self, x: &Mat) -> Mat {
        let data = x.data().iter().map(|&v| self.apply(v)).collect();
        Mat::from_vec(x.rows(), x.cols(), data)
    }
}

impl Module for Activation {
    fn type_name(&self) -> &'static str {
        match self.kind {
            ActKind::Relu => "ReLU",
            ActKind::Gelu => "GELU",
        }
    }

    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<Mat> {
        // One transient: the same-shaped output.
        let _act = ctx.mem().alloc((x.len() * 4) as u64)?;
        Ok(self.forward_mat(x))
    }

    fn forward_train(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<(Mat, Cache)> {
        let _act = ctx.mem().alloc((x.len() * 4) as u64)?;
        let guard = ctx.mem().alloc((x.len() * 4) as u64)?;
        Ok((
            self.forward_mat(x),
            Cache::new(ActCache {
                x: x.clone(),
                _guard: guard,
            }),
        ))
    }

    fn backward(&mut self, g: &Mat, cache: &Cache, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let c: &ActCache = cache.downcast::<ActCache>()?;
        anyhow::ensure!(
            g.shape() == c.x.shape(),
            "grad_out shape {:?} vs cached input {:?}",
            g.shape(),
            c.x.shape()
        );
        let _act = ctx.mem().alloc((g.len() * 4) as u64)?;
        let data = g
            .data()
            .iter()
            .zip(c.x.data())
            .map(|(&gv, &xv)| gv * self.derivative(xv))
            .collect();
        Ok(Mat::from_vec(g.rows(), g.cols(), data))
    }

    // No parameters: grads()/zero_grads()/scale_grads() defaults are
    // correct no-ops, and the layer contributes nothing to a state dict.
    fn params(&self) -> Vec<(String, ParamRef<'_>)> {
        Vec::new()
    }

    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
        Vec::new()
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Philox;

    #[test]
    fn relu_clamps_and_gelu_brackets() {
        let x = Mat::from_vec(1, 4, vec![-2.0, -0.5, 0.5, 2.0]);
        let ctx = ForwardCtx::new();
        let r = Activation::relu().forward(&x, &ctx).unwrap();
        assert_eq!(r.row(0), &[0.0, 0.0, 0.5, 2.0]);
        let g = Activation::gelu().forward(&x, &ctx).unwrap();
        // GELU is sandwiched between 0 and x for x > 0, and in [x, 0] for
        // x < 0; known values: gelu(2) ≈ 1.9546, gelu(-0.5) ≈ -0.1543.
        assert!((g.row(0)[3] - 1.9546).abs() < 1e-3, "{}", g.row(0)[3]);
        assert!((g.row(0)[1] + 0.1543).abs() < 1e-3, "{}", g.row(0)[1]);
        assert!((Activation::gelu().apply(0.0)).abs() < 1e-7);
    }

    #[test]
    fn backward_masks_relu_and_matches_gelu_slope() {
        let x = Mat::from_vec(1, 3, vec![-1.0, 0.5, 2.0]);
        let ctx = ForwardCtx::new();
        let mut relu = Activation::relu();
        let (_, cache) = relu.forward_train(&x, &ctx).unwrap();
        let g = Mat::filled(1, 3, 1.0);
        let dx = relu.backward(&g, &cache, &ctx).unwrap();
        assert_eq!(dx.row(0), &[0.0, 1.0, 1.0]);
        // GELU slope at a few points vs central differences (f64-free
        // spot check; the full-rigor check lives in tests/gradcheck.rs).
        let gelu = Activation::gelu();
        for &v in &[-1.5f32, -0.3, 0.0, 0.7, 2.5] {
            let eps = 1e-3f32;
            let fd = (gelu.apply(v + eps) - gelu.apply(v - eps)) / (2.0 * eps);
            let an = gelu.derivative(v);
            assert!((fd - an).abs() < 1e-3, "v={v}: fd {fd} vs analytic {an}");
        }
    }

    #[test]
    fn module_plumbing_no_params_and_tracked_memory() {
        let mut rng = Philox::seeded(7);
        let act = Activation::gelu();
        assert_eq!(act.type_name(), "GELU");
        assert_eq!(act.param_count(), 0);
        assert!(act.state_dict().is_empty());
        let x = Mat::randn(4, 8, &mut rng);
        let ctx = ForwardCtx::new();
        act.forward(&x, &ctx).unwrap();
        assert_eq!(ctx.mem().live_bytes(), 0, "transients released");
        assert!(ctx.mem().peak_bytes() >= (4 * 8 * 4) as u64);
        // Training keeps the cached input charged until the cache drops.
        let (_, cache) = act.forward_train(&x, &ctx).unwrap();
        assert!(ctx.mem().live_bytes() >= (4 * 8 * 4) as u64);
        drop(cache);
        assert_eq!(ctx.mem().live_bytes(), 0);
        // Budget errors surface cleanly.
        let tiny = ForwardCtx::with_budget(16);
        assert!(act.forward(&x, &tiny).is_err());
    }
}
