//! 2D convolution (dense, via im2col) and its sketched replacement
//! `SKConv2d` [Kasiviswanathan et al. 2017].
//!
//! im2col turns convolution into a GEMM: patches matrix
//! `X_col ∈ R^{(B·H_out·W_out) × (C_in·kh·kw)}` times the reshaped kernel
//! `W_mat ∈ R^{(C_in·kh·kw) × C_out}`. `SKConv2d` sketches that GEMM exactly
//! like `SKLinear` does (d_in = C_in·kh·kw, d_out = C_out), so the whole
//! Figure-2 experiment reduces to the same two-stage low-rank product with a
//! patch-extraction preamble shared by both sides.

use super::module::{col_sums, Cache, ForwardCtx, GradStore, Module, ParamMut, ParamRef};
use super::plan::Sketchable;
use crate::linalg::{gemm_batch, matmul, Mat, MatMut, MatRef};
use crate::rng::Rng;
use crate::util::memtrack::MemGuard;

/// Shape bookkeeping for a (square-kernel, stride-1) convolution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    pub c_in: usize,
    pub c_out: usize,
    pub kernel: usize,
    pub image: usize,
    pub padding: usize,
}

impl ConvShape {
    pub fn out_size(&self) -> usize {
        self.image + 2 * self.padding - self.kernel + 1
    }

    /// im2col inner dimension `C_in·k²`.
    pub fn patch_dim(&self) -> usize {
        self.c_in * self.kernel * self.kernel
    }
}

/// Extract im2col patches from an input batch laid out `B × (C_in·H·W)`
/// (channel-major rows). Output: `(B·H_out·W_out) × (C_in·kh·kw)`.
pub fn im2col(x: &Mat, shape: &ConvShape) -> Mat {
    let mut out = Mat::zeros(0, 0);
    im2col_into(x, shape, &mut out);
    out
}

/// [`im2col`] into a caller-provided matrix (resized in place), so repeated
/// forwards through [`ForwardCtx::scratch_mat`] reuse one allocation for
/// the largest conv temporary. Every element of `out` is overwritten.
pub fn im2col_into(x: &Mat, shape: &ConvShape, out: &mut Mat) {
    let b = x.rows();
    let (c, h) = (shape.c_in, shape.image);
    assert_eq!(x.cols(), c * h * h, "input layout mismatch");
    let ho = shape.out_size();
    let k = shape.kernel;
    let pad = shape.padding as isize;
    out.resize(b * ho * ho, shape.patch_dim());
    for bi in 0..b {
        let img = x.row(bi);
        for oy in 0..ho {
            for ox in 0..ho {
                let orow = out.row_mut((bi * ho + oy) * ho + ox);
                let mut idx = 0;
                for ci in 0..c {
                    for ky in 0..k {
                        let iy = oy as isize + ky as isize - pad;
                        for kx in 0..k {
                            let ix = ox as isize + kx as isize - pad;
                            orow[idx] = if iy >= 0 && iy < h as isize && ix >= 0 && ix < h as isize
                            {
                                img[ci * h * h + iy as usize * h + ix as usize]
                            } else {
                                0.0
                            };
                            idx += 1;
                        }
                    }
                }
            }
        }
    }
}

/// Adjoint of [`im2col`]: scatter-add per-patch-row gradients back onto
/// the input layout. `cols_grad: (B·H_out·W_out) × (C_in·kh·kw)` →
/// `B × (C_in·H·W)`. Every input pixel accumulates the contributions of
/// all patches that read it (overlapping windows), padding positions are
/// dropped — exactly the transpose of the gather `im2col` performs.
pub fn col2im(cols_grad: &Mat, shape: &ConvShape, batch: usize) -> Mat {
    let (c, h) = (shape.c_in, shape.image);
    let ho = shape.out_size();
    let k = shape.kernel;
    let pad = shape.padding as isize;
    assert_eq!(cols_grad.rows(), batch * ho * ho, "patch-row count mismatch");
    assert_eq!(cols_grad.cols(), shape.patch_dim(), "patch width mismatch");
    let mut x = Mat::zeros(batch, c * h * h);
    for bi in 0..batch {
        let img = x.row_mut(bi);
        for oy in 0..ho {
            for ox in 0..ho {
                let grow = cols_grad.row((bi * ho + oy) * ho + ox);
                let mut idx = 0;
                for ci in 0..c {
                    for ky in 0..k {
                        let iy = oy as isize + ky as isize - pad;
                        for kx in 0..k {
                            let ix = ox as isize + kx as isize - pad;
                            if iy >= 0 && iy < h as isize && ix >= 0 && ix < h as isize {
                                img[ci * h * h + iy as usize * h + ix as usize] += grow[idx];
                            }
                            idx += 1;
                        }
                    }
                }
            }
        }
    }
    x
}

/// Dense convolution layer.
#[derive(Clone, Debug)]
pub struct Conv2d {
    pub shape: ConvShape,
    /// Reshaped kernel: `(C_in·k²) × C_out`.
    pub w_mat: Mat,
    pub bias: Vec<f32>,
    grads: GradStore,
}

/// Activation cache of the conv training forwards: the im2col patch
/// matrix (owned — the inference path's shared scratch buffer cannot
/// outlive the call) plus the batch size `col2im` needs to rebuild the
/// input layout. `SKConv2d` additionally keeps the per-term `cols·U_j`.
struct ConvCache {
    cols: Mat,
    batch: usize,
    /// `cols·U_j` per term — empty for the dense layer.
    cu: Vec<Mat>,
    /// Keeps the cached bytes charged for the cache's lifetime.
    _guard: MemGuard,
}

impl Conv2d {
    pub fn random<R: Rng>(shape: ConvShape, rng: &mut R) -> Self {
        let fan_in = shape.patch_dim();
        let w_mat = Mat::randn(fan_in, shape.c_out, rng).scale((2.0 / fan_in as f32).sqrt());
        Conv2d {
            shape,
            w_mat,
            bias: vec![0.0; shape.c_out],
            grads: GradStore::default(),
        }
    }

    /// Forward on `x: B × (C_in·H·W)` → `(B·H_out·W_out) × C_out`
    /// (callers reshape as needed; keeping the GEMM output layout avoids a
    /// transpose on the hot path).
    pub fn forward(&self, x: &Mat) -> Mat {
        let cols = im2col(x, &self.shape);
        self.forward_cols(&cols)
    }

    /// Forward given pre-extracted patches (benches share the im2col).
    pub fn forward_cols(&self, cols: &Mat) -> Mat {
        let mut y = matmul(cols, &self.w_mat);
        for i in 0..y.rows() {
            for (v, b) in y.row_mut(i).iter_mut().zip(&self.bias) {
                *v += b;
            }
        }
        y
    }
}

impl Module for Conv2d {
    fn type_name(&self) -> &'static str {
        "Conv2d"
    }

    fn io_dims(&self) -> Option<(usize, usize)> {
        let s = &self.shape;
        Some((s.c_in * s.image * s.image, s.c_out))
    }

    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let ho = self.shape.out_size();
        let rows = x.rows() * ho * ho;
        // The im2col patch matrix is charged by scratch_mat (and stays
        // charged while it stays resident in the context); the GEMM output
        // is a per-call transient.
        let _act = ctx.mem().alloc((rows * self.shape.c_out * 4) as u64)?;
        let mut cols = ctx.scratch_mat(rows, self.shape.patch_dim())?;
        im2col_into(x, &self.shape, &mut cols);
        Ok(self.forward_cols(&cols))
    }

    fn forward_train(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<(Mat, Cache)> {
        let ho = self.shape.out_size();
        let rows = x.rows() * ho * ho;
        // Transient: the GEMM output. The patch matrix is owned by the
        // cache (it must survive until backward), so its charge rides a
        // guard inside the cache rather than the shared scratch.
        let _act = ctx.mem().alloc((rows * self.shape.c_out * 4) as u64)?;
        let guard = ctx
            .mem()
            .alloc((rows * self.shape.patch_dim() * 4) as u64)?;
        let cols = im2col(x, &self.shape);
        let y = self.forward_cols(&cols);
        Ok((
            y,
            Cache::new(ConvCache {
                cols,
                batch: x.rows(),
                cu: Vec::new(),
                _guard: guard,
            }),
        ))
    }

    fn backward(&mut self, g: &Mat, cache: &Cache, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let c: &ConvCache = cache.downcast::<ConvCache>()?;
        anyhow::ensure!(
            g.shape() == (c.cols.rows(), self.shape.c_out),
            "grad_out shape {:?} vs expected ({}, {})",
            g.shape(),
            c.cols.rows(),
            self.shape.c_out
        );
        let pd = self.shape.patch_dim();
        // Transients: dW (pd×C_out), dcols (rows×pd), dx (B×C_in·H²).
        let _act = ctx.mem().alloc(
            ((pd * self.shape.c_out + g.rows() * pd + c.batch * self.shape.c_in
                * self.shape.image * self.shape.image)
                * 4) as u64,
        )?;
        // y = cols·W + b  ⇒  dW = colsᵀ·g, db = colsum(g),
        // dx = col2im(g·Wᵀ).
        let dw = crate::linalg::matmul_tn(&c.cols, g);
        self.grads.accum("weight", 1.0, dw.data());
        self.grads.accum("bias", 1.0, &col_sums(g));
        let dcols = crate::linalg::matmul_nt(g, &self.w_mat);
        Ok(col2im(&dcols, &self.shape, c.batch))
    }

    fn grads(&self) -> Vec<(String, &[f32])> {
        self.grads.views()
    }

    fn zero_grads(&mut self) {
        self.grads.zero();
    }

    fn scale_grads(&mut self, s: f32) {
        self.grads.scale(s);
    }

    fn params(&self) -> Vec<(String, ParamRef<'_>)> {
        vec![
            ("weight".to_string(), ParamRef::Mat(&self.w_mat)),
            ("bias".to_string(), ParamRef::Vec(&self.bias)),
        ]
    }

    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
        vec![
            ("weight".to_string(), ParamMut::Mat(&mut self.w_mat)),
            ("bias".to_string(), ParamMut::Vec(&mut self.bias)),
        ]
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }

    fn as_sketchable(&self) -> Option<&dyn Sketchable> {
        Some(self)
    }
}

/// Sketched convolution — Panther's `pr.nn.SKConv2d`.
#[derive(Clone, Debug)]
pub struct SKConv2d {
    pub shape: ConvShape,
    pub num_terms: usize,
    pub low_rank: usize,
    /// Per-term factors: `U_j (C_in·k²) × r`, `V_j r × C_out`.
    pub u: Vec<Mat>,
    pub v: Vec<Mat>,
    pub bias: Vec<f32>,
    grads: GradStore,
}

impl SKConv2d {
    pub fn random<R: Rng>(
        shape: ConvShape,
        num_terms: usize,
        low_rank: usize,
        rng: &mut R,
    ) -> Self {
        assert!(num_terms > 0 && low_rank > 0);
        let fan_in = shape.patch_dim();
        let su = (1.0 / low_rank as f32).sqrt();
        let sv = (2.0 / fan_in as f32).sqrt();
        let mut u = Vec::new();
        let mut v = Vec::new();
        for _ in 0..num_terms {
            u.push(Mat::randn(fan_in, low_rank, rng).scale(su));
            v.push(Mat::randn(low_rank, shape.c_out, rng).scale(sv));
        }
        SKConv2d {
            shape,
            num_terms,
            low_rank,
            u,
            v,
            bias: vec![0.0; shape.c_out],
            grads: GradStore::default(),
        }
    }

    /// Compress a trained dense convolution (unbiased weight sketch, same
    /// construction as [`super::SKLinear::from_dense`]).
    pub fn from_dense<R: Rng>(dense: &Conv2d, num_terms: usize, low_rank: usize, rng: &mut R) -> Self {
        let fan_in = dense.shape.patch_dim();
        let scale = (1.0 / low_rank as f32).sqrt();
        let mut u = Vec::new();
        let mut v = Vec::new();
        for _ in 0..num_terms {
            let s = Mat::randn(fan_in, low_rank, rng).scale(scale);
            let vj = crate::linalg::matmul_tn(&s, &dense.w_mat);
            u.push(s);
            v.push(vj);
        }
        SKConv2d {
            shape: dense.shape,
            num_terms,
            low_rank,
            u,
            v,
            bias: dense.bias.clone(),
            grads: GradStore::default(),
        }
    }

    /// Size relative to the dense layer it replaces. The stored parameter
    /// count comes from the [`Module::param_count`] registry (closed form:
    /// `l·r·(C_in·k² + C_out) + C_out`, cross-checked in the tests).
    pub fn compression_ratio(&self) -> f64 {
        self.param_count() as f64
            / (self.shape.patch_dim() * self.shape.c_out + self.shape.c_out) as f64
    }

    pub fn forward(&self, x: &Mat) -> Mat {
        let cols = im2col(x, &self.shape);
        self.forward_cols(&cols)
    }

    pub fn forward_cols(&self, cols: &Mat) -> Mat {
        let mut y = Mat::zeros(cols.rows(), self.shape.c_out);
        super::module::sketched_product_into(
            cols,
            &self.u,
            &self.v,
            &self.bias,
            &mut Mat::zeros(0, 0),
            &mut y,
        );
        y
    }
}

impl Module for SKConv2d {
    fn type_name(&self) -> &'static str {
        "SKConv2d"
    }

    fn io_dims(&self) -> Option<(usize, usize)> {
        let s = &self.shape;
        Some((s.c_in * s.image * s.image, s.c_out))
    }

    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let ho = self.shape.out_size();
        let rows = x.rows() * ho * ho;
        // im2col patches are charged by scratch_mat; the transients are the
        // output plus one rows×r intermediate (workspace-recycled across
        // terms and calls; the second stage accumulates in place via gemm).
        let _act = ctx
            .mem()
            .alloc((rows * (self.shape.c_out + self.low_rank) * 4) as u64)?;
        let mut cols = ctx.scratch_mat(rows, self.shape.patch_dim())?;
        im2col_into(x, &self.shape, &mut cols);
        let mut y = Mat::zeros(rows, self.shape.c_out);
        let mut cu = ctx.workspace().take(rows, self.low_rank);
        super::module::sketched_product_into(&cols, &self.u, &self.v, &self.bias, &mut cu, &mut y);
        Ok(y)
    }

    fn forward_train(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<(Mat, Cache)> {
        let ho = self.shape.out_size();
        let rows = x.rows() * ho * ho;
        // Transient: the output (the per-term second stage accumulates in
        // place via gemm). Cached (charged until the cache drops): patches
        // plus l rows×r intermediates.
        let _act = ctx.mem().alloc((rows * self.shape.c_out * 4) as u64)?;
        let cached = rows * (self.shape.patch_dim() + self.num_terms * self.low_rank);
        let guard = ctx.mem().alloc((cached * 4) as u64)?;
        let cols = im2col(x, &self.shape);
        let mut y = Mat::zeros(rows, self.shape.c_out);
        let inv_l = 1.0 / self.num_terms as f32;
        let mut cu_all = Vec::with_capacity(self.num_terms);
        for (uj, vj) in self.u.iter().zip(&self.v) {
            let cu = matmul(&cols, uj); // rows×r
            crate::linalg::gemm(inv_l, &cu, vj, 1.0, &mut y);
            cu_all.push(cu);
        }
        for i in 0..y.rows() {
            for (vv, b) in y.row_mut(i).iter_mut().zip(&self.bias) {
                *vv += b;
            }
        }
        Ok((
            y,
            Cache::new(ConvCache {
                cols,
                batch: x.rows(),
                cu: cu_all,
                _guard: guard,
            }),
        ))
    }

    fn backward(&mut self, g: &Mat, cache: &Cache, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let c: &ConvCache = cache.downcast::<ConvCache>()?;
        anyhow::ensure!(
            c.cu.len() == self.num_terms,
            "cache holds {} terms, layer has {}",
            c.cu.len(),
            self.num_terms
        );
        anyhow::ensure!(
            g.shape() == (c.cols.rows(), self.shape.c_out),
            "grad_out shape {:?} vs expected ({}, {})",
            g.shape(),
            c.cols.rows(),
            self.shape.c_out
        );
        let pd = self.shape.patch_dim();
        let rows = g.rows();
        let l = self.num_terms;
        // Transients: all per-term gv/dU/dV blocks are alive at once now
        // that each stage runs as one batched dispatch, plus the running
        // dcols and dx.
        let _act = ctx.mem().alloc(
            ((l * self.low_rank * (rows + pd + self.shape.c_out)
                + rows * pd
                + c.batch * self.shape.c_in * self.shape.image * self.shape.image)
                * 4) as u64,
        )?;
        // Same two-stage low-rank product as SKLinear, on the patch matrix;
        // each per-term stage runs as ONE gemm_batch over all l terms
        // (independent problems, one parallel dispatch) instead of l
        // sequential GEMMs. The patch gradient then scatters back through
        // col2im.
        let inv_l = 1.0 / l as f32;
        let ws = ctx.workspace();
        // Stage 1: gv_j = g·V_jᵀ (rows×r), all terms batched.
        let mut gv: Vec<_> = (0..l).map(|_| ws.take(rows, self.low_rank)).collect();
        {
            let a: Vec<MatRef> = (0..l).map(|_| g.view()).collect();
            let b: Vec<MatRef> = self.v.iter().map(|vj| vj.view().t()).collect();
            let mut cb: Vec<MatMut> = gv.iter_mut().map(|m| m.view_mut()).collect();
            gemm_batch(1.0, &a, &b, 0.0, &mut cb);
        }
        // Stage 2: dU_j = colsᵀ·gv_j (pd×r), all terms batched.
        let mut du: Vec<_> = (0..l).map(|_| ws.take(pd, self.low_rank)).collect();
        {
            let a: Vec<MatRef> = (0..l).map(|_| c.cols.view().t()).collect();
            let b: Vec<MatRef> = gv.iter().map(|m| m.view()).collect();
            let mut cb: Vec<MatMut> = du.iter_mut().map(|m| m.view_mut()).collect();
            gemm_batch(1.0, &a, &b, 0.0, &mut cb);
        }
        // Stage 3: dV_j = cu_jᵀ·g (r×C_out), all terms batched.
        let mut dvs: Vec<_> = (0..l)
            .map(|_| ws.take(self.low_rank, self.shape.c_out))
            .collect();
        {
            let a: Vec<MatRef> = c.cu.iter().map(|m| m.view().t()).collect();
            let b: Vec<MatRef> = (0..l).map(|_| g.view()).collect();
            let mut cb: Vec<MatMut> = dvs.iter_mut().map(|m| m.view_mut()).collect();
            gemm_batch(1.0, &a, &b, 0.0, &mut cb);
        }
        for j in 0..l {
            self.grads.accum(&format!("u.{j}"), inv_l, du[j].data());
            self.grads.accum(&format!("v.{j}"), inv_l, dvs[j].data());
        }
        // dcols accumulates every term into one buffer — the outputs alias,
        // so this stage stays sequential (beta = 1 accumulation).
        let mut dcols = Mat::zeros(rows, pd);
        for j in 0..l {
            crate::linalg::gemm(inv_l, &gv[j], &self.u[j], 1.0, &mut dcols);
        }
        self.grads.accum("bias", 1.0, &col_sums(g));
        Ok(col2im(&dcols, &self.shape, c.batch))
    }

    fn grads(&self) -> Vec<(String, &[f32])> {
        self.grads.views()
    }

    fn zero_grads(&mut self) {
        self.grads.zero();
    }

    fn scale_grads(&mut self, s: f32) {
        self.grads.scale(s);
    }

    fn params(&self) -> Vec<(String, ParamRef<'_>)> {
        super::module::factored_params(&self.u, &self.v, &self.bias)
    }

    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
        super::module::factored_params_mut(&mut self.u, &mut self.v, &mut self.bias)
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_error;
    use crate::rng::Philox;
    use crate::util::prop::prop_check;

    fn small_shape() -> ConvShape {
        ConvShape {
            c_in: 3,
            c_out: 5,
            kernel: 3,
            image: 8,
            padding: 1,
        }
    }

    #[test]
    fn im2col_identity_kernel_recovers_input() {
        // 1×1 kernel, no padding: im2col is a reshape.
        let shape = ConvShape {
            c_in: 2,
            c_out: 1,
            kernel: 1,
            image: 4,
            padding: 0,
        };
        let x = Mat::from_fn(1, 2 * 16, |_, j| j as f32);
        let cols = im2col(&x, &shape);
        assert_eq!(cols.shape(), (16, 2));
        // Pixel (y,x) of channel c lands at row y*4+x, col c.
        assert_eq!(cols.get(5, 0), 5.0);
        assert_eq!(cols.get(5, 1), 21.0);
    }

    #[test]
    fn col2im_is_the_adjoint_of_im2col() {
        // ⟨im2col(x), C⟩ = ⟨x, col2im(C)⟩ for any x, C — the defining
        // property of the transpose map the conv backward relies on.
        let shape = small_shape();
        let mut rng = Philox::seeded(124);
        let b = 2;
        let x = Mat::randn(b, shape.c_in * shape.image * shape.image, &mut rng);
        let cols = im2col(&x, &shape);
        let c = Mat::randn(cols.rows(), cols.cols(), &mut rng);
        let back = col2im(&c, &shape, b);
        let lhs: f64 = cols
            .data()
            .iter()
            .zip(c.data())
            .map(|(&a, &bb)| a as f64 * bb as f64)
            .sum();
        let rhs: f64 = x
            .data()
            .iter()
            .zip(back.data())
            .map(|(&a, &bb)| a as f64 * bb as f64)
            .sum();
        assert!(
            (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
            "adjoint identity: {lhs} vs {rhs}"
        );
    }

    #[test]
    fn conv_matches_direct_convolution() {
        let shape = small_shape();
        let mut rng = Philox::seeded(121);
        let conv = Conv2d::random(shape, &mut rng);
        let x = Mat::randn(2, shape.c_in * shape.image * shape.image, &mut rng);
        let y = conv.forward(&x);
        let ho = shape.out_size();
        assert_eq!(y.shape(), (2 * ho * ho, shape.c_out));
        // Check one output element by direct summation.
        let (bi, oy, ox, co) = (1usize, 2usize, 3usize, 4usize);
        let mut acc = conv.bias[co] as f64;
        for ci in 0..shape.c_in {
            for ky in 0..shape.kernel {
                for kx in 0..shape.kernel {
                    let iy = oy as isize + ky as isize - 1;
                    let ix = ox as isize + kx as isize - 1;
                    if iy < 0 || ix < 0 || iy >= 8 || ix >= 8 {
                        continue;
                    }
                    let xv = x.get(bi, ci * 64 + iy as usize * 8 + ix as usize) as f64;
                    let widx = ci * 9 + ky * 3 + kx;
                    acc += xv * conv.w_mat.get(widx, co) as f64;
                }
            }
        }
        let got = y.get((bi * ho + oy) * ho + ox, co) as f64;
        assert!((got - acc).abs() < 1e-3, "direct {acc} vs im2col {got}");
    }

    #[test]
    fn skconv_shape_and_unbiasedness() {
        let shape = small_shape();
        let mut rng = Philox::seeded(122);
        let dense = Conv2d::random(shape, &mut rng);
        let x = Mat::randn(1, shape.c_in * 64, &mut rng);
        let y_ref = dense.forward(&x);
        // Mean over seeds approaches the dense output.
        let mut acc = Mat::zeros(y_ref.rows(), y_ref.cols());
        let trials = 200;
        for t in 0..trials {
            let mut r = Philox::seeded(3000 + t);
            let sk = SKConv2d::from_dense(&dense, 1, 6, &mut r);
            acc.axpy(1.0 / trials as f32, &sk.forward(&x));
        }
        assert!(rel_error(&acc, &y_ref) < 0.25, "rel {}", rel_error(&acc, &y_ref));
    }

    #[test]
    fn param_count_and_compression() {
        let shape = ConvShape {
            c_in: 64,
            c_out: 128,
            kernel: 3,
            image: 16,
            padding: 1,
        };
        let mut rng = Philox::seeded(123);
        let sk = SKConv2d::random(shape, 2, 8, &mut rng);
        assert_eq!(
            sk.param_count(),
            2 * 8 * (64 * 9 + 128) + 128
        );
        assert!(sk.compression_ratio() < 0.5);
    }

    #[test]
    fn property_output_shapes() {
        prop_check("skconv-shapes", 10, |g| {
            let shape = ConvShape {
                c_in: 1 + g.usize(0..4),
                c_out: 1 + g.usize(0..6),
                kernel: *g.choose(&[1usize, 3, 5]),
                image: 6 + g.usize(0..6),
                padding: g.usize(0..2),
            };
            if shape.kernel > shape.image + 2 * shape.padding {
                return;
            }
            let sk = SKConv2d::random(shape, 1 + g.usize(0..2), 1 + g.usize(0..4), g.rng());
            let x = Mat::randn(2, shape.c_in * shape.image * shape.image, g.rng());
            let ho = shape.out_size();
            assert_eq!(sk.forward(&x).shape(), (2 * ho * ho, shape.c_out));
        });
    }
}
