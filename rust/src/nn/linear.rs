//! Dense linear layer and its sketched drop-in replacement `SKLinear`.
//!
//! `SKLinear(d_in, d_out, num_terms=l, low_rank=k)` follows the tensor-
//! sketching formulation of Kasiviswanathan et al. 2017 (the paper's [7]):
//! the effective weight `Wᵀ ∈ R^{d_in×d_out}` is approximated by the average
//! of `l` rank-`k` terms,
//!
//! ```text
//!   Wᵀ ≈ (1/l) Σ_j U_j·V_j,   U_j = S_j (d_in×k),  V_j = S_jᵀ·Wᵀ (k×d_out)
//! ```
//!
//! with `S_j` i.i.d. N(0, 1/k). Since `E[S_j S_jᵀ] = I`, the approximation
//! is *unbiased*; increasing `num_terms` shrinks its variance (this is
//! exactly the paper's "closer to the expected value at the cost of more
//! parameters"). After initialization both factors are free parameters and
//! train like any other weight.
//!
//! Forward cost: `2·l·k·(d_in+d_out)` FLOPs/row vs `2·d_in·d_out` dense —
//! the Figure-1 crossover.

use super::module::{col_sums, Cache, ForwardCtx, GradStore, Module, ParamMut, ParamRef};
use super::plan::Sketchable;
use crate::linalg::{gemm, matmul, Mat};
use crate::rng::Rng;
use crate::util::memtrack::MemGuard;

/// Dense fully-connected layer, `y = x·Wᵀ + b` (PyTorch convention:
/// `weight` is `d_out × d_in`).
#[derive(Clone, Debug)]
pub struct Linear {
    pub weight: Mat, // d_out × d_in
    pub bias: Vec<f32>,
    grads: GradStore,
}

/// Activation cache of [`Linear::forward_train`]: just the input. The
/// guard keeps the cached bytes charged against the tracker for as long
/// as the cache lives, so budgeted training runs account the activations
/// retained across the whole layer stack, not just one layer's
/// transients.
struct LinearCache {
    x: Mat,
    _guard: MemGuard,
}

impl Linear {
    pub fn new(weight: Mat, bias: Vec<f32>) -> Self {
        assert_eq!(weight.rows(), bias.len());
        Linear {
            weight,
            bias,
            grads: GradStore::default(),
        }
    }

    /// Kaiming-ish random init (for tests/benches).
    pub fn random<R: Rng>(d_in: usize, d_out: usize, rng: &mut R) -> Self {
        let scale = (2.0 / d_in as f32).sqrt();
        let weight = Mat::randn(d_out, d_in, rng).scale(scale);
        Linear {
            weight,
            bias: vec![0.0; d_out],
            grads: GradStore::default(),
        }
    }

    pub fn d_in(&self) -> usize {
        self.weight.cols()
    }

    pub fn d_out(&self) -> usize {
        self.weight.rows()
    }

    /// `y = x·Wᵀ + b`, `x: B×d_in`.
    pub fn forward(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols(), self.d_in());
        let mut y = crate::linalg::matmul_nt(x, &self.weight);
        for i in 0..y.rows() {
            let row = y.row_mut(i);
            for (v, b) in row.iter_mut().zip(&self.bias) {
                *v += b;
            }
        }
        y
    }
}

impl Module for Linear {
    fn type_name(&self) -> &'static str {
        "Linear"
    }

    fn io_dims(&self) -> Option<(usize, usize)> {
        Some((self.d_in(), self.d_out()))
    }

    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<Mat> {
        // One transient activation: the B×d_out output.
        let _act = ctx.mem().alloc((x.rows() * self.d_out() * 4) as u64)?;
        Ok(Linear::forward(self, x))
    }

    fn forward_train(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<(Mat, Cache)> {
        // Transient: the B×d_out output. The cached input is charged on a
        // guard that lives inside the cache (released when the cache
        // drops, typically after backward).
        let _act = ctx.mem().alloc((x.rows() * self.d_out() * 4) as u64)?;
        let guard = ctx.mem().alloc((x.rows() * self.d_in() * 4) as u64)?;
        let y = Linear::forward(self, x);
        Ok((
            y,
            Cache::new(LinearCache {
                x: x.clone(),
                _guard: guard,
            }),
        ))
    }

    fn backward(&mut self, g: &Mat, cache: &Cache, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let c: &LinearCache = cache.downcast::<LinearCache>()?;
        anyhow::ensure!(
            g.shape() == (c.x.rows(), self.d_out()),
            "grad_out shape {:?} vs expected ({}, {})",
            g.shape(),
            c.x.rows(),
            self.d_out()
        );
        // Transients: dW (d_out×d_in) and dx (B×d_in).
        let _act = ctx
            .mem()
            .alloc(((self.d_out() + g.rows()) * self.d_in() * 4) as u64)?;
        // y = x·Wᵀ + b  ⇒  dW = gᵀ·x, db = colsum(g), dx = g·W.
        let dw = crate::linalg::matmul_tn(g, &c.x);
        self.grads.accum("weight", 1.0, dw.data());
        self.grads.accum("bias", 1.0, &col_sums(g));
        Ok(matmul(g, &self.weight))
    }

    fn grads(&self) -> Vec<(String, &[f32])> {
        self.grads.views()
    }

    fn zero_grads(&mut self) {
        self.grads.zero();
    }

    fn scale_grads(&mut self, s: f32) {
        self.grads.scale(s);
    }

    fn params(&self) -> Vec<(String, ParamRef<'_>)> {
        vec![
            ("weight".to_string(), ParamRef::Mat(&self.weight)),
            ("bias".to_string(), ParamRef::Vec(&self.bias)),
        ]
    }

    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
        vec![
            ("weight".to_string(), ParamMut::Mat(&mut self.weight)),
            ("bias".to_string(), ParamMut::Vec(&mut self.bias)),
        ]
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }

    fn as_sketchable(&self) -> Option<&dyn Sketchable> {
        Some(self)
    }
}

/// Sketched linear layer — Panther's `pr.nn.SKLinear`.
#[derive(Clone, Debug)]
pub struct SKLinear {
    pub d_in: usize,
    pub d_out: usize,
    /// `l`: number of averaged sketch terms.
    pub num_terms: usize,
    /// `k`: rank of each term.
    pub low_rank: usize,
    /// Per-term left factors `U_j: d_in × k`.
    pub u: Vec<Mat>,
    /// Per-term right factors `V_j: k × d_out`.
    pub v: Vec<Mat>,
    pub bias: Vec<f32>,
    grads: GradStore,
}

/// Activation cache of [`SKLinear::forward_train`]: the input plus the
/// per-term `x·U_j` intermediates (`B × k` each — the tiny matrices the
/// sketch exists to create, so caching them is far cheaper than a dense
/// layer's activations).
struct SKLinearCache {
    x: Mat,
    xu: Vec<Mat>,
    /// Keeps the cached bytes charged for the cache's lifetime.
    _guard: MemGuard,
}

impl SKLinear {
    /// Fresh randomly-initialized sketched layer (both factors random — the
    /// "during development" use case of the paper's Listing 1).
    pub fn random<R: Rng>(
        d_in: usize,
        d_out: usize,
        num_terms: usize,
        low_rank: usize,
        rng: &mut R,
    ) -> Self {
        assert!(num_terms > 0 && low_rank > 0);
        let mut u = Vec::with_capacity(num_terms);
        let mut v = Vec::with_capacity(num_terms);
        let su = (1.0 / low_rank as f32).sqrt();
        let sv = (2.0 / d_in as f32).sqrt();
        for _ in 0..num_terms {
            u.push(Mat::randn(d_in, low_rank, rng).scale(su));
            v.push(Mat::randn(low_rank, d_out, rng).scale(sv));
        }
        Self::assemble(d_in, d_out, num_terms, low_rank, u, v, vec![0.0; d_out])
    }

    fn assemble(
        d_in: usize,
        d_out: usize,
        num_terms: usize,
        low_rank: usize,
        u: Vec<Mat>,
        v: Vec<Mat>,
        bias: Vec<f32>,
    ) -> Self {
        SKLinear {
            d_in,
            d_out,
            num_terms,
            low_rank,
            u,
            v,
            bias,
            grads: GradStore::default(),
        }
    }

    /// Compress an existing dense layer (the "after development" use case,
    /// what `SKAutoTuner(copy_weights=True)` does): `U_j = S_j`,
    /// `V_j = S_jᵀ·Wᵀ` with `S_j` i.i.d. N(0, 1/k) — an unbiased sketch of
    /// the trained weights.
    pub fn from_dense<R: Rng>(
        dense: &Linear,
        num_terms: usize,
        low_rank: usize,
        rng: &mut R,
    ) -> Self {
        assert!(num_terms > 0 && low_rank > 0);
        let d_in = dense.d_in();
        let d_out = dense.d_out();
        let wt = dense.weight.transpose(); // d_in × d_out
        let scale = (1.0 / low_rank as f32).sqrt();
        let mut u = Vec::with_capacity(num_terms);
        let mut v = Vec::with_capacity(num_terms);
        for _ in 0..num_terms {
            let s = Mat::randn(d_in, low_rank, rng).scale(scale);
            let vj = crate::linalg::matmul_tn(&s, &wt); // k × d_out
            u.push(s);
            v.push(vj);
        }
        Self::assemble(d_in, d_out, num_terms, low_rank, u, v, dense.bias.clone())
    }

    /// Size relative to the dense layer it replaces. The stored parameter
    /// count comes from the [`Module::param_count`] registry (closed form:
    /// `l·k·(d_in+d_out) + d_out`, cross-checked in the tests).
    pub fn compression_ratio(&self) -> f64 {
        self.param_count() as f64 / (self.d_in * self.d_out + self.d_out) as f64
    }

    /// `y = (1/l)·Σ_j (x·U_j)·V_j + b`. The packed GEMM kernel resolves
    /// operand layout at packing time, so no factor transposes are cached
    /// (the pre-packing kernel kept `U_jᵀ`/`V_jᵀ` copies in sync just to
    /// stay in its fast dot layout); the second stage accumulates in
    /// place with the 1/l scale folded in — no B×d_out temporary.
    pub fn forward(&self, x: &Mat) -> Mat {
        assert_eq!(x.cols(), self.d_in);
        let mut y = Mat::zeros(x.rows(), self.d_out);
        super::module::sketched_product_into(
            x,
            &self.u,
            &self.v,
            &self.bias,
            &mut Mat::zeros(0, 0),
            &mut y,
        );
        y
    }

    /// Materialize the effective dense weight `Wᵀ_eff = (1/l)ΣU_jV_j`
    /// (d_in×d_out) — used by tests and by `to_dense` round-trips.
    pub fn effective_weight_t(&self) -> Mat {
        let mut w = Mat::zeros(self.d_in, self.d_out);
        for (uj, vj) in self.u.iter().zip(&self.v) {
            w.axpy(1.0 / self.num_terms as f32, &matmul(uj, vj));
        }
        w
    }
}

impl Module for SKLinear {
    fn type_name(&self) -> &'static str {
        "SKLinear"
    }

    fn io_dims(&self) -> Option<(usize, usize)> {
        Some((self.d_in, self.d_out))
    }

    fn forward(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<Mat> {
        assert_eq!(x.cols(), self.d_in);
        // Transients: the B×d_out output plus one B×k intermediate
        // (workspace-recycled across terms and calls).
        let b = x.rows();
        let _act = ctx
            .mem()
            .alloc((b * (self.d_out + self.low_rank) * 4) as u64)?;
        let mut y = Mat::zeros(b, self.d_out);
        let mut xu = ctx.workspace().take(b, self.low_rank);
        super::module::sketched_product_into(x, &self.u, &self.v, &self.bias, &mut xu, &mut y);
        Ok(y)
    }

    fn forward_train(&self, x: &Mat, ctx: &ForwardCtx) -> crate::Result<(Mat, Cache)> {
        assert_eq!(x.cols(), self.d_in);
        let b = x.rows();
        // Transient: the output (the per-term second stage accumulates in
        // place via gemm). Cached (charged until the cache drops): the
        // input plus l B×k intermediates.
        let _act = ctx.mem().alloc((b * self.d_out * 4) as u64)?;
        let cached = b * (self.d_in + self.num_terms * self.low_rank);
        let guard = ctx.mem().alloc((cached * 4) as u64)?;
        let mut y = Mat::zeros(b, self.d_out);
        let inv_l = 1.0 / self.num_terms as f32;
        let mut xu_all = Vec::with_capacity(self.num_terms);
        for (uj, vj) in self.u.iter().zip(&self.v) {
            let xu = matmul(x, uj); // B×k, cached for backward
            gemm(inv_l, &xu, vj, 1.0, &mut y);
            xu_all.push(xu);
        }
        for i in 0..y.rows() {
            for (vv, bb) in y.row_mut(i).iter_mut().zip(&self.bias) {
                *vv += bb;
            }
        }
        Ok((
            y,
            Cache::new(SKLinearCache {
                x: x.clone(),
                xu: xu_all,
                _guard: guard,
            }),
        ))
    }

    fn backward(&mut self, g: &Mat, cache: &Cache, ctx: &ForwardCtx) -> crate::Result<Mat> {
        let c: &SKLinearCache = cache.downcast::<SKLinearCache>()?;
        let b = c.x.rows();
        anyhow::ensure!(
            c.xu.len() == self.num_terms,
            "cache holds {} terms, layer has {}",
            c.xu.len(),
            self.num_terms
        );
        anyhow::ensure!(
            g.shape() == (b, self.d_out),
            "grad_out shape {:?} vs expected ({b}, {})",
            g.shape(),
            self.d_out
        );
        // Transients per term: dV (k×d_out), g·Vᵀ (B×k), dU (d_in×k), plus
        // the running dx (B×d_in).
        let _act = ctx.mem().alloc(
            ((self.low_rank * (self.d_out + self.d_in + b) + b * self.d_in) * 4) as u64,
        )?;
        // y = (1/l)·Σ_j (x·U_j)·V_j + b: per term
        //   dV_j = (1/l)·(x·U_j)ᵀ·g,  dU_j = (1/l)·xᵀ·(g·V_jᵀ),
        // and dx sums (1/l)·(g·V_jᵀ)·U_jᵀ over terms.
        let inv_l = 1.0 / self.num_terms as f32;
        let mut dx = Mat::zeros(b, self.d_in);
        for j in 0..self.num_terms {
            let gv = crate::linalg::matmul_nt(g, &self.v[j]); // B×k
            let du = crate::linalg::matmul_tn(&c.x, &gv); // d_in×k
            self.grads.accum(&format!("u.{j}"), inv_l, du.data());
            let dv = crate::linalg::matmul_tn(&c.xu[j], g); // k×d_out
            self.grads.accum(&format!("v.{j}"), inv_l, dv.data());
            dx.axpy(inv_l, &crate::linalg::matmul_nt(&gv, &self.u[j]));
        }
        self.grads.accum("bias", 1.0, &col_sums(g));
        Ok(dx)
    }

    fn grads(&self) -> Vec<(String, &[f32])> {
        self.grads.views()
    }

    fn zero_grads(&mut self) {
        self.grads.zero();
    }

    fn scale_grads(&mut self, s: f32) {
        self.grads.scale(s);
    }

    fn params(&self) -> Vec<(String, ParamRef<'_>)> {
        super::module::factored_params(&self.u, &self.v, &self.bias)
    }

    fn params_mut(&mut self) -> Vec<(String, ParamMut<'_>)> {
        super::module::factored_params_mut(&mut self.u, &mut self.v, &mut self.bias)
    }

    fn boxed_clone(&self) -> Box<dyn Module> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{fro_norm, rel_error};
    use crate::rng::Philox;
    use crate::util::prop::prop_check;

    #[test]
    fn dense_forward_shapes_and_bias() {
        let mut rng = Philox::seeded(111);
        let mut l = Linear::random(6, 4, &mut rng);
        l.bias = vec![1.0, 2.0, 3.0, 4.0];
        let x = Mat::zeros(3, 6);
        let y = l.forward(&x);
        assert_eq!(y.shape(), (3, 4));
        assert_eq!(y.row(0), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn sk_forward_matches_effective_weight() {
        let mut rng = Philox::seeded(112);
        let sk = SKLinear::random(10, 8, 2, 3, &mut rng);
        let x = Mat::randn(5, 10, &mut rng);
        let direct = sk.forward(&x);
        let via_dense = {
            let w = sk.effective_weight_t();
            let mut y = matmul(&x, &w);
            for i in 0..y.rows() {
                for (v, b) in y.row_mut(i).iter_mut().zip(&sk.bias) {
                    *v += b;
                }
            }
            y
        };
        assert!(rel_error(&direct, &via_dense) < 1e-4);
    }

    #[test]
    fn from_dense_is_unbiased() {
        // Average the sketched effective weight over many seeds → dense W.
        let mut rng = Philox::seeded(113);
        let dense = Linear::random(12, 9, &mut rng);
        let wt = dense.weight.transpose();
        let mut acc = Mat::zeros(12, 9);
        let trials = 300;
        for t in 0..trials {
            let mut r = Philox::seeded(1000 + t);
            let sk = SKLinear::from_dense(&dense, 1, 4, &mut r);
            acc.axpy(1.0 / trials as f32, &sk.effective_weight_t());
        }
        let err = fro_norm(&acc.sub(&wt)) / fro_norm(&wt);
        assert!(err < 0.2, "bias check: rel err {err}");
    }

    #[test]
    fn more_terms_reduce_approximation_variance() {
        let mut rng = Philox::seeded(114);
        let dense = Linear::random(30, 30, &mut rng);
        let wt = dense.weight.transpose();
        let avg_err = |l: usize| {
            let mut tot = 0f64;
            let trials = 30;
            for t in 0..trials {
                let mut r = Philox::seeded(5000 + t);
                let sk = SKLinear::from_dense(&dense, l, 8, &mut r);
                tot += fro_norm(&sk.effective_weight_t().sub(&wt));
            }
            tot / trials as f64
        };
        let e1 = avg_err(1);
        let e4 = avg_err(4);
        assert!(e4 < e1, "l=4 err {e4} should beat l=1 err {e1}");
    }

    #[test]
    fn param_count_formula() {
        let mut rng = Philox::seeded(115);
        let sk = SKLinear::random(100, 60, 2, 16, &mut rng);
        assert_eq!(sk.param_count(), 2 * 16 * 160 + 60);
        assert!(sk.compression_ratio() < 1.0);
    }

    #[test]
    fn property_shapes_all_configs() {
        prop_check("sklinear-shapes", 20, |g| {
            let d_in = 1 + g.usize(0..32);
            let d_out = 1 + g.usize(0..32);
            let l = 1 + g.usize(0..3);
            let k = 1 + g.usize(0..8);
            let b = 1 + g.usize(0..6);
            let sk = SKLinear::random(d_in, d_out, l, k, g.rng());
            let x = Mat::randn(b, d_in, g.rng());
            assert_eq!(sk.forward(&x).shape(), (b, d_out));
        });
    }

    #[test]
    fn bigger_rank_approximates_better() {
        let mut rng = Philox::seeded(116);
        let dense = Linear::random(40, 40, &mut rng);
        let x = Mat::randn(8, 40, &mut rng);
        let y_ref = dense.forward(&x);
        let err = |k: usize| {
            let mut tot = 0f64;
            for t in 0..20 {
                let mut r = Philox::seeded(9000 + t);
                let sk = SKLinear::from_dense(&dense, 1, k, &mut r);
                tot += rel_error(&sk.forward(&x), &y_ref);
            }
            tot / 20.0
        };
        assert!(err(32) < err(4), "k=32 {} vs k=4 {}", err(32), err(4));
    }
}
