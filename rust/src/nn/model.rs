//! Model container: a flat registry of *named* [`Module`]s, mirroring how
//! `SKAutoTuner` navigates a `torch` module hierarchy ("given a torch-saved
//! model provided with regex or specific layers to replace"). Layer names
//! use dotted paths (`encoder.layer3.ffn.fc1`), and [`LayerSelector`]
//! reproduces the paper's `LayerConfig(layer_names={"type": "Linear"})` /
//! regex / explicit-list selection modes.
//!
//! Layers are stored behind the [`Module`] trait — the registry has no
//! knowledge of concrete layer types, so new layers (and new sketched
//! variants, via [`super::plan::SketchPlan`]) plug in without touching this
//! file. Lookups go through a name → index map, so `get`/`replace` are
//! O(1) and duplicate names are rejected with an error instead of a panic.

use super::module::{Module, StateDict};
use crate::linalg::Mat;
use anyhow::{anyhow, ensure, Context, Result};
use std::collections::HashMap;

/// A named layer in the registry.
pub struct NamedModule {
    pub name: String,
    pub module: Box<dyn Module>,
}

/// Typed rejection from [`Model::replace`]: the replacement module's
/// per-row interface ([`Module::io_dims`]) does not match the outgoing
/// layer's. Returned behind `anyhow::Error` — callers that need the
/// shapes (the serve hot-swap path) downcast to this.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplaceShapeMismatch {
    /// The registry name of the layer the swap targeted.
    pub layer: String,
    /// Outgoing module's type name.
    pub old_type: &'static str,
    /// Rejected replacement's type name.
    pub new_type: &'static str,
    /// Outgoing `(input width, output width)`.
    pub old_dims: (usize, usize),
    /// Replacement's `(input width, output width)`.
    pub new_dims: (usize, usize),
}

impl std::fmt::Display for ReplaceShapeMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "replace {}: {} maps {}->{} but replacement {} maps {}->{}",
            self.layer,
            self.old_type,
            self.old_dims.0,
            self.old_dims.1,
            self.new_type,
            self.new_dims.0,
            self.new_dims.1,
        )
    }
}

impl std::error::Error for ReplaceShapeMismatch {}

/// The model: ordered named layers (a flattened module tree) with an index
/// for O(1) name lookups.
#[derive(Default)]
pub struct Model {
    layers: Vec<NamedModule>,
    index: HashMap<String, usize>,
}

impl Model {
    pub fn new() -> Self {
        Model::default()
    }

    /// Register a layer under `name`. Errors on duplicate names.
    pub fn add<M: Module + 'static>(&mut self, name: &str, module: M) -> Result<&mut Self> {
        self.add_boxed(name, Box::new(module))
    }

    /// [`Model::add`] for an already-boxed module (e.g. from
    /// [`Module::boxed_clone`]).
    pub fn add_boxed(&mut self, name: &str, module: Box<dyn Module>) -> Result<&mut Self> {
        ensure!(
            !self.index.contains_key(name),
            "duplicate layer name {name}"
        );
        self.index.insert(name.to_string(), self.layers.len());
        self.layers.push(NamedModule {
            name: name.to_string(),
            module,
        });
        Ok(self)
    }

    /// Look up a layer by name — O(1).
    pub fn get(&self, name: &str) -> Option<&dyn Module> {
        self.index
            .get(name)
            .map(|&i| self.layers[i].module.as_ref())
    }

    /// Mutable lookup by name — O(1).
    pub fn get_mut(&mut self, name: &str) -> Option<&mut dyn Module> {
        match self.index.get(name) {
            Some(&i) => Some(self.layers[i].module.as_mut()),
            None => None,
        }
    }

    /// Swap the module stored under `name`, returning the old one. The
    /// layer keeps its position and name — this is how
    /// [`super::plan::SketchPlan`] installs sketched replacements.
    ///
    /// The replacement's per-row interface is validated against the
    /// outgoing layer: when both sides report [`Module::io_dims`] and
    /// they differ, the swap is rejected with a typed
    /// [`ReplaceShapeMismatch`] (downcastable from the returned error)
    /// instead of deferring to an opaque GEMM dimension panic at the
    /// next forward. Layers that report `None` (activations, third-party
    /// modules) opt out of the check. The serve-layer hot-swap path
    /// depends on this being safe.
    pub fn replace(&mut self, name: &str, module: Box<dyn Module>) -> Result<Box<dyn Module>> {
        let i = *self
            .index
            .get(name)
            .ok_or_else(|| anyhow!("no layer named {name}"))?;
        let old = &self.layers[i].module;
        if let (Some(old_dims), Some(new_dims)) = (old.io_dims(), module.io_dims()) {
            if old_dims != new_dims {
                return Err(anyhow::Error::new(ReplaceShapeMismatch {
                    layer: name.to_string(),
                    old_type: old.type_name(),
                    new_type: module.type_name(),
                    old_dims,
                    new_dims,
                }));
            }
        }
        Ok(std::mem::replace(&mut self.layers[i].module, module))
    }

    /// Iterate layers in registration order.
    pub fn iter(&self) -> impl Iterator<Item = &NamedModule> {
        self.layers.iter()
    }

    /// Mutable iteration in registration order (the optimizer's view).
    pub fn iter_mut(&mut self) -> impl Iterator<Item = &mut NamedModule> {
        self.layers.iter_mut()
    }

    /// Sequential inference forward: feed `x` through every layer in
    /// registration order. The registry is a flattened module tree, so for
    /// feed-forward stacks registration order *is* execution order; layer
    /// output/input widths must chain (each layer asserts its own).
    pub fn forward(&self, x: &Mat, ctx: &super::module::ForwardCtx) -> Result<Mat> {
        // Profiled twin of the plain loop below: attach the profiler to
        // the GEMM substrate for the whole forward and time each layer.
        // The `None` arm is the common path — one never-taken branch.
        if let Some(prof) = ctx.profiler() {
            let _gemm = crate::linalg::install_profiler(std::sync::Arc::clone(&prof));
            let mut cur = x.clone();
            for l in &self.layers {
                let t = std::time::Instant::now();
                cur = l
                    .module
                    .forward(&cur, ctx)
                    .with_context(|| format!("forward through layer {}", l.name))?;
                prof.record(&format!("layer/{}", l.name), t.elapsed());
            }
            return Ok(cur);
        }
        let mut cur = x.clone();
        for l in &self.layers {
            cur = l
                .module
                .forward(&cur, ctx)
                .with_context(|| format!("forward through layer {}", l.name))?;
        }
        Ok(cur)
    }

    /// Batched row-stacking entry point: stacks `rows` (all the same
    /// width) into one matrix, zero-pads it to at least `pad_to` rows,
    /// runs a single sequential [`Model::forward`], and returns one result
    /// row per input row (padding rows are computed and discarded). This
    /// is the public form of the serving contract; the `serve` worker loop
    /// runs a buffer-reusing twin of the same stack/pad/unstack sequence
    /// (validated once per tier by the registration probe), so it stays
    /// allocation-free in steady state.
    ///
    /// Padding to a *fixed* row count is what makes batched serving
    /// reproducible: the GEMM substrate picks kernels from the product
    /// shape, so executing every batch at one shape makes each row's
    /// result a pure function of that row alone — bit-identical across
    /// batch compositions for row-independent stacks (each output row
    /// depends only on its input row; attention couples rows and is the
    /// documented exception). With `pad_to` below the GEMM microkernel
    /// height (8), results are additionally bit-identical to the
    /// single-row `forward` of each row.
    pub fn forward_rows(
        &self,
        rows: &[&[f32]],
        pad_to: usize,
        ctx: &super::module::ForwardCtx,
    ) -> Result<Vec<Vec<f32>>> {
        ensure!(!rows.is_empty(), "forward_rows needs at least one row");
        let d = rows[0].len();
        ensure!(d > 0, "forward_rows needs non-empty rows");
        let b = rows.len().max(pad_to);
        let mut x = Mat::zeros(b, d);
        for (i, r) in rows.iter().enumerate() {
            ensure!(
                r.len() == d,
                "row {i} has width {}, expected {d}",
                r.len()
            );
            x.row_mut(i).copy_from_slice(r);
        }
        let y = self.forward(&x, ctx)?;
        ensure!(
            y.rows() == b,
            "model mapped {b} rows to {} — row routing needs one output row \
             per input row",
            y.rows()
        );
        Ok(rows.iter().enumerate().map(|(i, _)| y.row(i).to_vec()).collect())
    }

    /// Sequence-batched inference forward: install `seq` on the context,
    /// run the sequential [`Model::forward`], restore the previous batch.
    /// `x` must hold exactly `seq.total_rows()` rows in the batch's
    /// packed/padded layout; sequence-aware layers (attention) mask pad
    /// positions structurally, row-wise layers are unaffected. The
    /// restore runs even when the forward errors, so a shared warm
    /// context never leaks a stale sequence batch into later calls.
    pub fn forward_seq(
        &self,
        x: &Mat,
        seq: &super::module::SeqBatch,
        ctx: &super::module::ForwardCtx,
    ) -> Result<Mat> {
        ensure!(
            x.rows() == seq.total_rows(),
            "input has {} rows, sequence batch describes {}",
            x.rows(),
            seq.total_rows()
        );
        let prev = ctx.seq_batch();
        ctx.set_seq_batch(Some(seq.clone()));
        let out = self.forward(x, ctx);
        ctx.set_seq_batch(prev);
        out
    }

    /// Apply the per-layer peak-memory knob model-wide (see
    /// [`Module::set_head_group`]); layers without partitionable state
    /// ignore it.
    pub fn set_head_group(&mut self, heads: usize) {
        for l in &mut self.layers {
            l.module.set_head_group(heads);
        }
    }

    /// Sequential training forward: like [`Model::forward`] but collects
    /// one activation [`super::module::Cache`] per layer, in registration
    /// order, for [`Model::backward`].
    pub fn forward_train(
        &self,
        x: &Mat,
        ctx: &super::module::ForwardCtx,
    ) -> Result<(Mat, Vec<super::module::Cache>)> {
        let mut cur = x.clone();
        let mut caches = Vec::with_capacity(self.layers.len());
        for l in &self.layers {
            let (y, cache) = l
                .module
                .forward_train(&cur, ctx)
                .with_context(|| format!("training forward through layer {}", l.name))?;
            caches.push(cache);
            cur = y;
        }
        Ok((cur, caches))
    }

    /// Sequential backward: walk the layers in reverse, handing each its
    /// cache from the matching [`Model::forward_train`] call. Accumulates
    /// per-parameter gradients inside every layer (read them via
    /// [`Module::grads`]) and returns `∂loss/∂input`.
    pub fn backward(
        &mut self,
        grad_out: &Mat,
        caches: &[super::module::Cache],
        ctx: &super::module::ForwardCtx,
    ) -> Result<Mat> {
        ensure!(
            caches.len() == self.layers.len(),
            "{} caches for {} layers — backward must consume the cache list \
             of the matching forward_train",
            caches.len(),
            self.layers.len()
        );
        let mut g = grad_out.clone();
        for (l, cache) in self.layers.iter_mut().zip(caches).rev() {
            g = l
                .module
                .backward(&g, cache, ctx)
                .with_context(|| format!("backward through layer {}", l.name))?;
        }
        Ok(g)
    }

    /// Zero every layer's accumulated gradients.
    pub fn zero_grads(&mut self) {
        for l in &mut self.layers {
            l.module.zero_grads();
        }
    }

    /// Global L2 norm over every accumulated gradient of every layer
    /// (f64-accumulated) — the quantity global-norm clipping compares
    /// against its threshold. Zero when no backward has run.
    pub fn grad_norm(&self) -> f64 {
        let mut sq = 0f64;
        for l in &self.layers {
            for (_, g) in l.module.grads() {
                for &v in g {
                    sq += v as f64 * v as f64;
                }
            }
        }
        sq.sqrt()
    }

    /// Multiply every layer's accumulated gradients by `s` (see
    /// [`crate::train::clip_grad_norm`]).
    pub fn scale_grads(&mut self, s: f32) {
        for l in &mut self.layers {
            l.module.scale_grads(s);
        }
    }

    /// Number of registered layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Total stored parameter count, derived from each layer's
    /// [`Module::params`] registry.
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.module.param_count()).sum()
    }

    /// Names of layers matching a selector, in registration order.
    pub fn select(&self, sel: &LayerSelector) -> Vec<String> {
        self.layers
            .iter()
            .filter(|l| sel.matches(&l.name, l.module.type_name()))
            .map(|l| l.name.clone())
            .collect()
    }

    /// Replace one dense layer with its sketched counterpart at `(l, k)`,
    /// sketching trained weights (`copy_weights=True` semantics). Attention
    /// layers interpret `k` as the random-feature count. Thin convenience
    /// over [`super::plan::SketchPlan`] — errors if the layer is missing or
    /// not sketchable.
    pub fn sketchify(
        &mut self,
        name: &str,
        num_terms: usize,
        low_rank: usize,
        seed: u64,
    ) -> Result<()> {
        let report = super::plan::SketchPlan::new()
            .select(LayerSelector::by_names(&[name]))
            .with(num_terms, low_rank)
            .seed(seed)
            .apply(self)?;
        if let Some(s) = report.skipped.first() {
            anyhow::bail!("layer {} ({}) is not sketchable", s.name, s.type_name);
        }
        Ok(())
    }

    /// Deep copy of the full layer registry (all weights cloned).
    pub fn clone_model(&self) -> Model {
        let mut m = Model::new();
        for l in &self.layers {
            m.add_boxed(&l.name, l.module.boxed_clone())
                .expect("source model has unique names");
        }
        m
    }

    /// Snapshot every parameter of every layer as a flat name-keyed state
    /// dict (`<layer path>.<param name>`, e.g. `encoder.fc1.weight`).
    pub fn state_dict(&self) -> StateDict {
        let mut sd = Vec::new();
        for l in &self.layers {
            for (pname, t) in l.module.state_dict() {
                sd.push((format!("{}.{pname}", l.name), t));
            }
        }
        sd
    }

    /// Load a full model snapshot produced by [`Model::state_dict`]. Keys
    /// are matched to layers by longest layer-path prefix (layer names may
    /// themselves contain dots); every layer must receive its complete
    /// parameter set and unknown keys are an error. All-or-nothing: every
    /// layer's slice is validated before the first weight is written, so a
    /// failed load leaves the model untouched.
    pub fn load_state_dict(&mut self, sd: &[(String, crate::runtime::HostTensor)]) -> Result<()> {
        let mut per_layer: HashMap<String, StateDict> = HashMap::new();
        for (key, t) in sd {
            let mut best: Option<&str> = None;
            for l in &self.layers {
                let matches = key.len() > l.name.len() + 1
                    && key.starts_with(l.name.as_str())
                    && key.as_bytes()[l.name.len()] == b'.';
                let longer = match best {
                    None => true,
                    Some(b) => l.name.len() > b.len(),
                };
                if matches && longer {
                    best = Some(l.name.as_str());
                }
            }
            let layer = best.ok_or_else(|| anyhow!("state dict key {key} matches no layer"))?;
            let sub_key = key[layer.len() + 1..].to_string();
            // Entries are cloned into per-layer dicts (transiently ~2× the
            // state dict) — acceptable on this cold restore path in exchange
            // for keeping the Module trait's owned-slice signature.
            per_layer
                .entry(layer.to_string())
                .or_default()
                .push((sub_key, t.clone()));
        }
        // Pre-validate every layer so no weight is written unless the whole
        // load will succeed. Module::load_state_dict re-validates its own
        // slice below — redundant but cheap (name/shape checks only), and it
        // keeps the trait free of an unchecked write entry point.
        for l in &self.layers {
            let sub = per_layer.get(&l.name).map(|v| v.as_slice()).unwrap_or(&[]);
            l.module
                .validate_state_dict(sub)
                .with_context(|| format!("validating layer {}", l.name))?;
        }
        for l in &mut self.layers {
            let sub = per_layer.remove(&l.name).unwrap_or_default();
            l.module
                .load_state_dict(&sub)
                .with_context(|| format!("loading layer {}", l.name))?;
        }
        Ok(())
    }
}

/// Layer selection — the three modes of the paper's `LayerConfig`.
#[derive(Clone)]
pub enum LayerSelector {
    /// All layers of a given type: `{"type": "Linear"}`.
    ByType(String),
    /// Regex on the dotted layer path (see [`crate::util::rex`] for the
    /// supported subset).
    ByRegex(crate::util::rex::Regex),
    /// Explicit layer names.
    ByName(Vec<String>),
}

impl LayerSelector {
    pub fn by_type(t: &str) -> Self {
        LayerSelector::ByType(t.to_string())
    }

    pub fn by_regex(pat: &str) -> Result<Self> {
        Ok(LayerSelector::ByRegex(crate::util::rex::Regex::new(pat)?))
    }

    pub fn by_names(names: &[&str]) -> Self {
        LayerSelector::ByName(names.iter().map(|s| s.to_string()).collect())
    }

    pub fn matches(&self, name: &str, type_name: &str) -> bool {
        match self {
            LayerSelector::ByType(t) => t == type_name,
            LayerSelector::ByRegex(re) => re.is_match(name),
            LayerSelector::ByName(ns) => ns.iter().any(|n| n == name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::attention::{AttnWeights, MultiHeadAttention};
    use crate::nn::conv::{Conv2d, ConvShape};
    use crate::nn::linear::Linear;
    use crate::rng::Philox;

    fn toy_model() -> Model {
        let mut rng = Philox::seeded(141);
        let mut m = Model::new();
        m.add("encoder.fc1", Linear::random(32, 64, &mut rng))
            .unwrap();
        m.add("encoder.fc2", Linear::random(64, 32, &mut rng))
            .unwrap();
        m.add(
            "encoder.conv",
            Conv2d::random(
                ConvShape {
                    c_in: 3,
                    c_out: 8,
                    kernel: 3,
                    image: 8,
                    padding: 1,
                },
                &mut rng,
            ),
        )
        .unwrap();
        m.add(
            "encoder.attn",
            MultiHeadAttention::new(AttnWeights::random(16, 4, &mut rng)),
        )
        .unwrap();
        m
    }

    #[test]
    fn select_by_type() {
        let m = toy_model();
        let linears = m.select(&LayerSelector::by_type("Linear"));
        assert_eq!(linears, vec!["encoder.fc1", "encoder.fc2"]);
    }

    #[test]
    fn select_by_regex() {
        let m = toy_model();
        let sel = LayerSelector::by_regex(r"fc\d$").unwrap();
        assert_eq!(m.select(&sel).len(), 2);
        let sel2 = LayerSelector::by_regex(r"^encoder\.(conv|attn)$").unwrap();
        assert_eq!(m.select(&sel2).len(), 2);
    }

    #[test]
    fn select_by_name() {
        let m = toy_model();
        let sel = LayerSelector::by_names(&["encoder.fc2", "missing"]);
        assert_eq!(m.select(&sel), vec!["encoder.fc2"]);
    }

    #[test]
    fn sketchify_reduces_params_and_changes_type() {
        let mut m = toy_model();
        let before = m.total_params();
        m.sketchify("encoder.fc1", 1, 4, 9).unwrap();
        assert_eq!(m.get("encoder.fc1").unwrap().type_name(), "SKLinear");
        assert!(m.total_params() < before);
        // Second sketchify on an already-sketched layer errors.
        assert!(m.sketchify("encoder.fc1", 1, 4, 9).is_err());
    }

    #[test]
    fn sketchify_conv_and_attention() {
        let mut m = toy_model();
        m.sketchify("encoder.conv", 2, 4, 1).unwrap();
        m.sketchify("encoder.attn", 1, 32, 1).unwrap();
        assert_eq!(m.get("encoder.conv").unwrap().type_name(), "SKConv2d");
        assert_eq!(
            m.get("encoder.attn").unwrap().type_name(),
            "RandMultiheadAttention"
        );
    }

    #[test]
    fn duplicate_names_rejected_with_error() {
        let mut rng = Philox::seeded(1);
        let mut m = Model::new();
        m.add("x", Linear::random(2, 2, &mut rng)).unwrap();
        let err = m.add("x", Linear::random(2, 2, &mut rng));
        assert!(err.is_err());
        // The registry is unchanged: one layer, still resolvable.
        assert_eq!(m.len(), 1);
        assert!(m.get("x").is_some());
    }

    #[test]
    fn attention_param_count_matches_closed_form() {
        // The registry-derived count must equal the old hand-maintained
        // 4·d² formula (Q, K, V, output projections, no biases).
        let m = toy_model();
        let attn = m.get("encoder.attn").unwrap();
        assert_eq!(attn.param_count(), 4 * 16 * 16);
        // And the linear layer's count equals d_in·d_out + d_out.
        let fc1 = m.get("encoder.fc1").unwrap();
        assert_eq!(fc1.param_count(), 32 * 64 + 64);
    }

    #[test]
    fn get_is_index_backed_and_replace_preserves_order() {
        let mut m = toy_model();
        assert!(m.get("nope").is_none());
        // A shape-compatible swap (fc2 for a clone of itself) succeeds
        // and keeps registration order.
        let copy = m.get("encoder.fc2").unwrap().boxed_clone();
        let old = m.replace("encoder.fc2", copy).unwrap();
        assert_eq!(old.type_name(), "Linear");
        let names: Vec<&str> = m.iter().map(|l| l.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["encoder.fc1", "encoder.fc2", "encoder.conv", "encoder.attn"]
        );
        assert!(m.replace("nope", old).is_err());
    }

    #[test]
    fn replace_rejects_shape_incompatible_modules_with_typed_error() {
        let mut m = toy_model();
        // fc1 maps 32->64, fc2 maps 64->32: installing a clone of fc1
        // under fc2's name would panic deep inside the next forward's
        // GEMM — replace now rejects it up front with a typed error.
        let wrong = m.get("encoder.fc1").unwrap().boxed_clone();
        let err = m.replace("encoder.fc2", wrong).unwrap_err();
        let mismatch = err
            .downcast_ref::<ReplaceShapeMismatch>()
            .expect("error downcasts to ReplaceShapeMismatch");
        assert_eq!(mismatch.layer, "encoder.fc2");
        assert_eq!(mismatch.old_dims, (64, 32));
        assert_eq!(mismatch.new_dims, (32, 64));
        // The registry is untouched: fc2 still maps 64->32.
        assert_eq!(m.get("encoder.fc2").unwrap().io_dims(), Some((64, 32)));
        // Width-agnostic layers (activations) opt out of the check, so
        // swapping one in over a Linear is allowed — the caller asked
        // for a module that cannot state its interface.
        let act = crate::nn::Activation::relu();
        assert!(m.replace("encoder.fc2", Box::new(act)).is_ok());
    }

    #[test]
    fn sequential_forward_train_backward_roundtrip() {
        let mut rng = Philox::seeded(143);
        let mut m = Model::new();
        m.add("fc1", Linear::random(6, 8, &mut rng)).unwrap();
        m.add("fc2", Linear::random(8, 4, &mut rng)).unwrap();
        let x = crate::linalg::Mat::randn(3, 6, &mut rng);
        let ctx = super::super::module::ForwardCtx::new();
        let y = m.forward(&x, &ctx).unwrap();
        assert_eq!(y.shape(), (3, 4));
        let (yt, caches) = m.forward_train(&x, &ctx).unwrap();
        assert_eq!(yt.shape(), (3, 4));
        assert_eq!(caches.len(), 2);
        let g = crate::linalg::Mat::filled(3, 4, 1.0);
        let gx = m.backward(&g, &caches, &ctx).unwrap();
        assert_eq!(gx.shape(), (3, 6));
        // Both layers accumulated gradients for every parameter.
        for l in m.iter() {
            assert_eq!(l.module.grads().len(), l.module.params().len());
        }
        // Cache-count mismatch is a loud error, not a panic.
        assert!(m.backward(&g, &caches[..1], &ctx).is_err());
        // zero_grads resets accumulation.
        m.zero_grads();
        for l in m.iter() {
            for (_, gbuf) in l.module.grads() {
                assert!(gbuf.iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn forward_rows_matches_single_row_forwards_bitwise() {
        let mut rng = Philox::seeded(145);
        let mut m = Model::new();
        m.add("fc1", Linear::random(6, 8, &mut rng)).unwrap();
        m.add("fc2", Linear::random(8, 4, &mut rng)).unwrap();
        let ctx = super::super::module::ForwardCtx::new();
        let rows: Vec<Vec<f32>> = (0..3)
            .map(|i| {
                crate::linalg::Mat::randn(1, 6, &mut Philox::seeded(500 + i)).into_vec()
            })
            .collect();
        let row_refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        // Pad below the GEMM microkernel height: bit-identical to the
        // single-row forward of each row, padding rows discarded.
        let out = m.forward_rows(&row_refs, 4, &ctx).unwrap();
        assert_eq!(out.len(), 3);
        for (r, got) in rows.iter().zip(&out) {
            let solo = m
                .forward(&crate::linalg::Mat::from_vec(1, 6, r.clone()), &ctx)
                .unwrap();
            assert_eq!(got.as_slice(), solo.row(0), "row result must be exact");
        }
        // Mismatched widths and empty input are loud errors.
        assert!(m.forward_rows(&[], 4, &ctx).is_err());
        let bad: Vec<&[f32]> = vec![&rows[0], &rows[1][..3]];
        assert!(m.forward_rows(&bad, 4, &ctx).is_err());
    }

    #[test]
    fn training_caches_stay_charged_until_dropped() {
        // Inference releases each layer's transients before the next layer
        // runs; training retains every layer's activation cache. A budget
        // sized for one layer's transient (4 KiB per 32×32 f32 mat) must
        // pass inference and reject the training forward, and the charge
        // must persist exactly as long as the caches do.
        let mut rng = Philox::seeded(144);
        let mut m = Model::new();
        m.add("fc1", Linear::random(32, 32, &mut rng)).unwrap();
        m.add("fc2", Linear::random(32, 32, &mut rng)).unwrap();
        let x = crate::linalg::Mat::randn(32, 32, &mut rng);
        let ctx = super::super::module::ForwardCtx::with_budget(10_000);
        assert!(m.forward(&x, &ctx).is_ok(), "inference fits the budget");
        assert!(
            m.forward_train(&x, &ctx).is_err(),
            "training must account caches across the stack"
        );
        let ctx2 = super::super::module::ForwardCtx::with_budget(20_000);
        let (_, caches) = m.forward_train(&x, &ctx2).unwrap();
        assert!(ctx2.mem().live_bytes() >= 2 * 32 * 32 * 4);
        drop(caches);
        assert_eq!(ctx2.mem().live_bytes(), 0);
    }

    #[test]
    fn model_state_dict_keys_are_layer_prefixed() {
        let m = toy_model();
        let sd = m.state_dict();
        let keys: Vec<&str> = sd.iter().map(|(k, _)| k.as_str()).collect();
        assert!(keys.contains(&"encoder.fc1.weight"));
        assert!(keys.contains(&"encoder.fc1.bias"));
        assert!(keys.contains(&"encoder.attn.wq"));
        // Total elements in the dict match the parameter count.
        let total: usize = sd.iter().map(|(_, t)| t.len()).sum();
        assert_eq!(total, m.total_params());
    }

    #[test]
    fn model_load_state_dict_roundtrip_and_errors() {
        let mut rng = Philox::seeded(142);
        let src = toy_model();
        let sd = src.state_dict();
        let mut dst = toy_model();
        // Different seed path: perturb dst so the load is observable.
        dst.sketchify("encoder.fc1", 1, 4, 5).unwrap();
        // Mismatched architecture: fc1 is now SKLinear, its params differ.
        assert!(dst.load_state_dict(&sd).is_err());
        let mut dst2 = {
            let mut m = Model::new();
            m.add("encoder.fc1", Linear::random(32, 64, &mut rng))
                .unwrap();
            m
        };
        // Unknown keys (the conv/attn entries) are an error.
        assert!(dst2.load_state_dict(&sd).is_err());
        // A clone of the architecture loads exactly.
        let mut dst3 = src.clone_model();
        dst3.load_state_dict(&sd).unwrap();
        assert_eq!(dst3.state_dict(), sd);
    }
}
