//! Model container: a flat registry of *named* layers, mirroring how
//! `SKAutoTuner` navigates a `torch` module hierarchy ("given a torch-saved
//! model provided with regex or specific layers to replace"). Layer names
//! use dotted paths (`encoder.layer3.ffn.fc1`), and [`LayerSelector`]
//! reproduces the paper's `LayerConfig(layer_names={"type": "Linear"})` /
//! regex / explicit-list selection modes.

use super::attention::{KernelKind, MultiHeadAttention, RandMultiHeadAttention};
use super::conv::{Conv2d, SKConv2d};
use super::linear::{Linear, SKLinear};
use crate::rng::Philox;

/// Any layer the model registry can hold.
pub enum LayerKind {
    Linear(Linear),
    SKLinear(SKLinear),
    Conv2d(Conv2d),
    SKConv2d(SKConv2d),
    Attention(MultiHeadAttention),
    RandAttention(RandMultiHeadAttention),
}

impl LayerKind {
    /// Type name as the selector sees it (matches the paper's `"Linear"`,
    /// `"Conv2d"`, …).
    pub fn type_name(&self) -> &'static str {
        match self {
            LayerKind::Linear(_) => "Linear",
            LayerKind::SKLinear(_) => "SKLinear",
            LayerKind::Conv2d(_) => "Conv2d",
            LayerKind::SKConv2d(_) => "SKConv2d",
            LayerKind::Attention(_) => "MultiheadAttention",
            LayerKind::RandAttention(_) => "RandMultiheadAttention",
        }
    }

    /// Stored parameter count.
    pub fn param_count(&self) -> usize {
        match self {
            LayerKind::Linear(l) => l.param_count(),
            LayerKind::SKLinear(l) => l.param_count(),
            LayerKind::Conv2d(c) => c.param_count(),
            LayerKind::SKConv2d(c) => c.param_count(),
            LayerKind::Attention(a) => 4 * a.weights.embed_dim * a.weights.embed_dim,
            LayerKind::RandAttention(a) => 4 * a.weights.embed_dim * a.weights.embed_dim,
        }
    }
}

/// A named layer in the registry.
pub struct NamedLayer {
    pub name: String,
    pub layer: LayerKind,
}

/// The model: ordered named layers (a flattened module tree).
#[derive(Default)]
pub struct Model {
    pub layers: Vec<NamedLayer>,
}

impl Model {
    pub fn new() -> Self {
        Model { layers: Vec::new() }
    }

    pub fn add(&mut self, name: &str, layer: LayerKind) -> &mut Self {
        assert!(
            !self.layers.iter().any(|l| l.name == name),
            "duplicate layer name {name}"
        );
        self.layers.push(NamedLayer {
            name: name.to_string(),
            layer,
        });
        self
    }

    pub fn get(&self, name: &str) -> Option<&LayerKind> {
        self.layers
            .iter()
            .find(|l| l.name == name)
            .map(|l| &l.layer)
    }

    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.layer.param_count()).sum()
    }

    /// Names of layers matching a selector.
    pub fn select(&self, sel: &LayerSelector) -> Vec<String> {
        self.layers
            .iter()
            .filter(|l| sel.matches(&l.name, l.layer.type_name()))
            .map(|l| l.name.clone())
            .collect()
    }

    /// Replace a dense layer with its sketched counterpart at `(l, k)`,
    /// sketching trained weights (`copy_weights=True` semantics). Attention
    /// layers interpret `k` as the random-feature count. No-op error if the
    /// layer is already sketched or missing.
    pub fn sketchify(
        &mut self,
        name: &str,
        num_terms: usize,
        low_rank: usize,
        seed: u64,
    ) -> anyhow::Result<()> {
        let slot = self
            .layers
            .iter_mut()
            .find(|l| l.name == name)
            .ok_or_else(|| anyhow::anyhow!("no layer named {name}"))?;
        let mut rng = Philox::seeded(seed);
        let new = match &slot.layer {
            LayerKind::Linear(l) => {
                LayerKind::SKLinear(SKLinear::from_dense(l, num_terms, low_rank, &mut rng))
            }
            LayerKind::Conv2d(c) => {
                LayerKind::SKConv2d(SKConv2d::from_dense(c, num_terms, low_rank, &mut rng))
            }
            LayerKind::Attention(a) => LayerKind::RandAttention(RandMultiHeadAttention::new(
                a.weights.clone(),
                low_rank,
                KernelKind::Softmax,
                seed,
            )),
            other => anyhow::bail!("layer {name} ({}) is not sketchable", other.type_name()),
        };
        slot.layer = new;
        Ok(())
    }
}

/// Layer selection — the three modes of the paper's `LayerConfig`.
pub enum LayerSelector {
    /// All layers of a given type: `{"type": "Linear"}`.
    ByType(String),
    /// Regex on the dotted layer path (see [`crate::util::rex`] for the
    /// supported subset).
    ByRegex(crate::util::rex::Regex),
    /// Explicit layer names.
    ByName(Vec<String>),
}

impl LayerSelector {
    pub fn by_type(t: &str) -> Self {
        LayerSelector::ByType(t.to_string())
    }

    pub fn by_regex(pat: &str) -> anyhow::Result<Self> {
        Ok(LayerSelector::ByRegex(crate::util::rex::Regex::new(pat)?))
    }

    pub fn by_names(names: &[&str]) -> Self {
        LayerSelector::ByName(names.iter().map(|s| s.to_string()).collect())
    }

    pub fn matches(&self, name: &str, type_name: &str) -> bool {
        match self {
            LayerSelector::ByType(t) => t == type_name,
            LayerSelector::ByRegex(re) => re.is_match(name),
            LayerSelector::ByName(ns) => ns.iter().any(|n| n == name),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::attention::AttnWeights;
    use crate::nn::conv::ConvShape;

    fn toy_model() -> Model {
        let mut rng = Philox::seeded(141);
        let mut m = Model::new();
        m.add(
            "encoder.fc1",
            LayerKind::Linear(Linear::random(32, 64, &mut rng)),
        );
        m.add(
            "encoder.fc2",
            LayerKind::Linear(Linear::random(64, 32, &mut rng)),
        );
        m.add(
            "encoder.conv",
            LayerKind::Conv2d(Conv2d::random(
                ConvShape {
                    c_in: 3,
                    c_out: 8,
                    kernel: 3,
                    image: 8,
                    padding: 1,
                },
                &mut rng,
            )),
        );
        m.add(
            "encoder.attn",
            LayerKind::Attention(MultiHeadAttention::new(AttnWeights::random(
                16, 4, &mut rng,
            ))),
        );
        m
    }

    #[test]
    fn select_by_type() {
        let m = toy_model();
        let linears = m.select(&LayerSelector::by_type("Linear"));
        assert_eq!(linears, vec!["encoder.fc1", "encoder.fc2"]);
    }

    #[test]
    fn select_by_regex() {
        let m = toy_model();
        let sel = LayerSelector::by_regex(r"fc\d$").unwrap();
        assert_eq!(m.select(&sel).len(), 2);
        let sel2 = LayerSelector::by_regex(r"^encoder\.(conv|attn)$").unwrap();
        assert_eq!(m.select(&sel2).len(), 2);
    }

    #[test]
    fn select_by_name() {
        let m = toy_model();
        let sel = LayerSelector::by_names(&["encoder.fc2", "missing"]);
        assert_eq!(m.select(&sel), vec!["encoder.fc2"]);
    }

    #[test]
    fn sketchify_reduces_params_and_changes_type() {
        let mut m = toy_model();
        let before = m.total_params();
        m.sketchify("encoder.fc1", 1, 4, 9).unwrap();
        assert_eq!(m.get("encoder.fc1").unwrap().type_name(), "SKLinear");
        assert!(m.total_params() < before);
        // Second sketchify on an already-sketched layer errors.
        assert!(m.sketchify("encoder.fc1", 1, 4, 9).is_err());
    }

    #[test]
    fn sketchify_conv_and_attention() {
        let mut m = toy_model();
        m.sketchify("encoder.conv", 2, 4, 1).unwrap();
        m.sketchify("encoder.attn", 1, 32, 1).unwrap();
        assert_eq!(m.get("encoder.conv").unwrap().type_name(), "SKConv2d");
        assert_eq!(
            m.get("encoder.attn").unwrap().type_name(),
            "RandMultiheadAttention"
        );
    }

    #[test]
    #[should_panic(expected = "duplicate layer name")]
    fn duplicate_names_rejected() {
        let mut rng = Philox::seeded(1);
        let mut m = Model::new();
        m.add("x", LayerKind::Linear(Linear::random(2, 2, &mut rng)));
        m.add("x", LayerKind::Linear(Linear::random(2, 2, &mut rng)));
    }
}
